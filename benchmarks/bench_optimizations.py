"""§4.4/§5.3/§5.4 optimization-claim benchmarks:

  * pseudo quad-max via OR vs true compare-max (paper: ~20% encode gain),
  * packed lookup-table LD decode (vectorized) vs TZCNT-style sequential
    unary reads (paper §5.4: tables win for vectorized decoders),
  * fused unpack+delta vs separate passes (beyond-paper; HBM-bytes derived).
"""

from __future__ import annotations

import numpy as np

from repro.core import codec as codec_lib
from repro.core.layout import quadmax_np
from .util import emit, gaps_and_tfs, mis, timeit


def run(n: int = 1 << 19) -> None:
    gaps, _ = gaps_and_tfs("gov2")
    x = np.tile(gaps, -(-n // len(gaps)))[:n].astype(np.uint32)

    t_or = timeit(lambda: quadmax_np(x, pseudo=True), repeats=5)
    t_max = timeit(lambda: quadmax_np(x, pseudo=False), repeats=5)
    emit("opt/quadmax_or", t_or * 1e6, f"{mis(n, t_or):.0f}mis")
    emit("opt/quadmax_cmp", t_max * 1e6, f"{mis(n, t_max):.0f}mis")
    emit("opt/quadmax_speedup", 0.0, f"{t_max / t_or:.2f}x")

    # packed LD decode (vec path uses zero-position/LUT) vs TZCNT scan (scalar)
    for v in ("1-CU", "8-IU"):
        spec = codec_lib.get(f"group_scheme_{v}")
        enc = spec.encode(x)
        args = spec.jax_args(enc)
        tv = timeit(lambda: spec.decode_jax_vec(**args))
        ts = timeit(lambda: spec.decode_jax_scalar(**args))
        emit(f"opt/packed_ld/{v}", 0.0, f"{ts / tv:.2f}x_vs_tzcnt")

    # fused unpack+delta (kernel ref vs two-pass) — HBM bytes model for v5e
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    bw = int(np.maximum(1, np.ceil(np.log2(x.max() + 1))))
    tiles = ops.pad_to_frames(jnp.asarray(x))
    packed = ref.pack_frames_ref(tiles, bw)
    import jax
    two_pass = jax.jit(lambda p: ref.prefix_sum_ref(ref.unpack_frames_ref(p, bw)))
    fused = jax.jit(lambda p: ref.unpack_delta_ref(p, bw))
    t2 = timeit(lambda: two_pass(packed))
    t1 = timeit(lambda: fused(packed))
    emit("opt/unpack_delta_two_pass", t2 * 1e6, f"{mis(n, t2):.0f}mis")
    emit("opt/unpack_delta_fused", t1 * 1e6, f"{mis(n, t1):.0f}mis")
    n_ints = tiles.size
    hbm_two = n_ints * (bw / 8 + 4 + 4 + 4 + 4)   # packed read + gaps write/read + ids write... two passes
    hbm_fused = n_ints * (bw / 8 + 4)
    emit("opt/unpack_delta_hbm_reduction", 0.0,
         f"{hbm_two / hbm_fused:.2f}x_fewer_HBM_bytes(v5e_roofline)")


if __name__ == "__main__":
    run()
