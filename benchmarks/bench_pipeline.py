"""Framework-integration benchmark: compressed stores (tokens / adjacency /
recsys bags) — ratio + decode throughput; and the compressed gradient
all-reduce wire-byte reduction (int8/int4 vs fp32)."""

from __future__ import annotations

import numpy as np

from repro.data.pipeline import AdjacencyStore, BagStore, TokenStore
from repro.models.sampler import CSRGraph
from .util import emit, mis, timeit


def run(n_tokens: int = 1 << 18) -> None:
    rng = np.random.default_rng(5)
    # LM token stream (zipf over vocab 49152)
    toks = np.minimum(rng.zipf(1.2, n_tokens), 49151).astype(np.uint32)
    for codec in ("bp128", "group_simple", "group_scheme_8-IU"):
        st = TokenStore.build(toks, codec=codec)
        t = timeit(lambda: st.read(0, n_tokens), repeats=3, warmup=1)
        emit(f"pipeline/tokens/{codec}/decode", t * 1e6, f"{mis(n_tokens, t):.0f}mis")
        emit(f"pipeline/tokens/{codec}/ratio", 0.0,
             f"{st.compressed_bytes()/st.raw_bytes:.3f}of_raw")
    # GNN adjacency (CSR, d-gapped columns)
    g = CSRGraph.random(20000, 400000, 1)
    for codec in ("group_pfd", "group_simple"):
        st = AdjacencyStore.build(g.indptr, g.indices, codec=codec)
        emit(f"pipeline/adjacency/{codec}/ratio", 0.0,
             f"{st.compressed_bytes()/st.raw_bytes:.3f}of_raw")
    # recsys multi-hot bags
    bags = [rng.choice(1 << 20, size=rng.integers(10, 100), replace=False)
            for _ in range(2000)]
    st = BagStore.build(bags)
    emit("pipeline/bags/group_scheme_8-IU/ratio", 0.0,
         f"{st.compressed_bytes()/st.raw_bytes:.3f}of_raw")
    # compressed all-reduce wire bytes (model, per DESIGN §3)
    for bits in (8, 4):
        emit(f"pipeline/grad_allreduce_int{bits}", 0.0,
             f"{8.0/(2*bits/8.0):.1f}x_fewer_wire_bytes_vs_fp32_ring")


if __name__ == "__main__":
    run()
