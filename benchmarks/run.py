"""Benchmark driver — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` uses paper-scale
stream lengths (slower); default sizes finish on a laptop-class CPU.

``--smoke`` is DETERMINISTIC on its inputs: every suite draws its corpus /
stream / query workload from fixed RNG seeds (``--seed``, default 0) at
pinned sizes (streams 2**14, 20 queries, 64 serving requests, the
``synth.DATASETS`` corpus shapes), so two smoke runs measure the identical
workload and the JSON artifacts (``BENCH_query.json`` / ``BENCH_mutation.json``
/ ``BENCH_serving.json`` — baselines of the first and last are committed at
the repo root) differ only in timings.  The serving smoke additionally
asserts its CI guarantees: zero shed under the Poisson load and bitwise
parity with the offline plan/execute oracle.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

# allow `python benchmarks/run.py` (script mode) as well as `-m benchmarks.run`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized quick pass (tiny streams, fast suites only)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: speed ratio gsc query index opt pipeline "
                         "roofline kernels serving")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed for the query suite (fixed default "
                         "keeps --smoke deterministic)")
    args = ap.parse_args()
    n = 1 << 21 if args.full else (1 << 14 if args.smoke else 1 << 18)
    suites = {
        "ratio": lambda: __import__("benchmarks.bench_ratio", fromlist=["run"]).run(),
        "gsc": lambda: __import__("benchmarks.bench_group_scheme", fromlist=["run"]).run(n=max(n >> 1, 1 << 16)),
        "speed": lambda: __import__("benchmarks.bench_speed", fromlist=["run"]).run(n=n),
        "opt": lambda: __import__("benchmarks.bench_optimizations", fromlist=["run"]).run(n=n),
        "query": lambda: __import__("benchmarks.bench_query", fromlist=["run"]).run(
            n_queries=200 if args.full else (20 if args.smoke else 60),
            seed=args.seed),
        "index": lambda: __import__("benchmarks.bench_index_size", fromlist=["run"]).run(),
        "pipeline": lambda: __import__("benchmarks.bench_pipeline", fromlist=["run"]).run(
            n_tokens=max(n >> 1, 1 << 16)),
        "roofline": lambda: __import__("benchmarks.bench_roofline", fromlist=["run"]).run(),
        "kernels": lambda: __import__("benchmarks.bench_roofline", fromlist=["run_kernels"]).run_kernels(),
        "serving": lambda: __import__("benchmarks.bench_serving", fromlist=["run"]).run(
            n_requests=512 if args.full else (64 if args.smoke else 192),
            seed=args.seed, smoke=args.smoke),
    }
    todo = args.only or (["speed", "query", "index", "kernels", "serving"]
                         if args.smoke else list(suites))
    print("name,us_per_call,derived")
    failed = []
    for key in todo:
        try:
            suites[key]()
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
