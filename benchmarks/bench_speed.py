"""Table VII analogue: decoding/encoding speed (million ints/second).

Three decode implementations per Group codec map the paper's axis:
  * np      — host oracle (reference point)
  * scalar  — jax sequential scan (the paper's non-SIMD routine)
  * vec     — jax vectorized (the paper's SIMD routine; XLA:CPU vectorizes
    the shift/mask lanes, on TPU the same graph runs on the VPU)

Scalar baselines (VarByte/GVB/Simple/PFD/...) decode via numpy; the
bit-sequential ones (rice/gamma/g8iu) run python loops — their absolute mis
is not comparable to C++, orderings are (EXPERIMENTS.md).
"""

from __future__ import annotations

import numpy as np

from repro.core import codec as codec_lib
from .util import emit, gaps_and_tfs, mis, timeit

GROUP_BENCH = ["group_simple", "group_scheme_1-CU", "group_scheme_8-IU",
               "group_afor", "group_vse", "group_pfd", "group_optpfd", "bp128"]
SCALAR_FAST = ["varbyte", "gvb", "g8cu", "simple9", "simple16", "pfordelta",
               "afor", "packed_binary"]
SCALAR_SLOW = ["rice", "gamma", "g8iu"]


def run(n: int = 1 << 19, n_slow: int = 20000, datasets=("gov2", "clueweb09b"),
        streams=("dgap", "tf")) -> None:
    for ds in datasets:
        gaps, tfs = gaps_and_tfs(ds)
        for sname in streams:
            base = gaps if sname == "dgap" else tfs
            x = np.tile(base, -(-n // len(base)))[:n].astype(np.uint32)
            xs = x[:n_slow]
            for name in GROUP_BENCH:
                spec = codec_lib.get(name)
                enc = spec.encode(x)
                args = spec.jax_args(enc)
                t = timeit(lambda: spec.decode_jax_vec(**args))
                emit(f"speed/{ds}/{sname}/{name}/decode_vec", t * 1e6,
                     f"{mis(n, t):.0f}mis")
                t = timeit(lambda: spec.decode_jax_scalar(**args))
                emit(f"speed/{ds}/{sname}/{name}/decode_scalar", t * 1e6,
                     f"{mis(n, t):.0f}mis")
                t = timeit(lambda: spec.encode(x), repeats=3, warmup=1)
                emit(f"speed/{ds}/{sname}/{name}/encode", t * 1e6,
                     f"{mis(n, t):.0f}mis")
            for name in SCALAR_FAST:
                spec = codec_lib.get(name)
                if x.max() >= 2 ** spec.max_bits:
                    continue
                enc = spec.encode(x)
                t = timeit(lambda: spec.decode(enc), repeats=3, warmup=1)
                emit(f"speed/{ds}/{sname}/{name}/decode_np", t * 1e6,
                     f"{mis(n, t):.0f}mis")
                t = timeit(lambda: spec.encode(x), repeats=3, warmup=1)
                emit(f"speed/{ds}/{sname}/{name}/encode", t * 1e6,
                     f"{mis(n, t):.0f}mis")
            for name in SCALAR_SLOW:
                spec = codec_lib.get(name)
                if xs.max() >= 2 ** spec.max_bits:
                    continue
                enc = spec.encode(xs)
                t = timeit(lambda: spec.decode(enc), repeats=2, warmup=1)
                emit(f"speed/{ds}/{sname}/{name}/decode_np", t * 1e6,
                     f"{mis(len(xs), t):.1f}mis")


if __name__ == "__main__":
    run()
