"""Tables VIII/IX analogue: compression ratio (bits per integer) per codec on
the d-gap and TF streams of all four datasets."""

from __future__ import annotations

from repro.core import codec as codec_lib
from .util import emit, gaps_and_tfs

CODECS = ["rice", "gamma", "group_scheme_1-CU", "varbyte", "gvb", "g8iu",
          "g8cu", "group_scheme_8-IU", "simple9", "simple16", "group_simple",
          "packed_binary", "g_packed_binary", "bp128", "bp_tpu", "pfordelta",
          "afor", "group_afor", "group_vse", "group_pfd", "group_optpfd"]


def run(datasets=("gov2", "clueweb09b", "wikipedia", "twitter")) -> None:
    for ds in datasets:
        gaps, tfs = gaps_and_tfs(ds)
        for sname, x in (("dgap", gaps), ("tf", tfs)):
            for name in CODECS:
                spec = codec_lib.get(name)
                if x.max() >= 2 ** spec.max_bits:
                    continue
                enc = spec.encode(x)
                emit(f"ratio/{ds}/{sname}/{name}", 0.0,
                     f"{enc.bits_per_int:.2f}bits/int")


if __name__ == "__main__":
    run()
