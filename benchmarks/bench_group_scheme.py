"""Fig. 6 analogue: Group-Scheme variant selection on GOV2 d-gaps —
decode/encode speed (scalar vs vectorized) and compression ratio for all 10
CG x LD variants."""

from __future__ import annotations

import numpy as np

from repro.core import codec as codec_lib, group_scheme
from .util import emit, gaps_and_tfs, mis, timeit


def run(n: int = 1 << 18) -> None:
    gaps, _ = gaps_and_tfs("gov2")
    x = np.tile(gaps, -(-n // len(gaps)))[:n].astype(np.uint32)
    for v in group_scheme.VARIANTS:
        spec = codec_lib.get(f"group_scheme_{v}")
        enc = spec.encode(x)
        args = spec.jax_args(enc)
        tv = timeit(lambda: spec.decode_jax_vec(**args))
        ts = timeit(lambda: spec.decode_jax_scalar(**args))
        te = timeit(lambda: spec.encode(x), repeats=3, warmup=1)
        emit(f"gsc/{v}/decode_vec", tv * 1e6, f"{mis(n, tv):.0f}mis")
        emit(f"gsc/{v}/decode_scalar", ts * 1e6, f"{mis(n, ts):.0f}mis")
        emit(f"gsc/{v}/encode", te * 1e6, f"{mis(n, te):.0f}mis")
        emit(f"gsc/{v}/ratio", 0.0, f"{enc.bits_per_int:.2f}bits/int")
        emit(f"gsc/{v}/simd_speedup", 0.0, f"{ts / tv:.2f}x")


if __name__ == "__main__":
    run()
