"""Table XI analogue: index sizes (posting-list bytes) per codec per dataset,
VB fallback for short lists (paper §7.5)."""

from __future__ import annotations

from repro.data import synth
from repro.index.invindex import InvertedIndex
from .util import emit

CODECS = ["gamma", "rice", "group_scheme_1-CU", "varbyte", "gvb", "g8cu",
          "g8iu", "group_scheme_8-IU", "simple9", "simple16", "group_simple",
          "packed_binary", "pfordelta", "afor", "group_afor", "group_pfd",
          "group_optpfd", "bp128"]


def run(datasets=("gov2", "clueweb09b", "wikipedia", "twitter")) -> None:
    for ds in datasets:
        doclen, postings = synth.make_corpus(ds)
        raw = sum(len(d) * 8 for d, _ in postings.values())
        emit(f"index_size/{ds}/uncompressed", 0.0, f"{raw/1e6:.2f}MB")
        for name in CODECS:
            idx = InvertedIndex.build(doclen, postings, codec=name)
            emit(f"index_size/{ds}/{name}", 0.0, f"{idx.size_bytes()/1e6:.2f}MB")


if __name__ == "__main__":
    run()
