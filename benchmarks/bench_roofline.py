"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run
JSONs (see launch/dryrun.py + launch/hlo_census.py).  Prints one row per cell;
the full table + analysis lives in EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

from .util import emit

PEAK_FLOPS = 197e12          # v5e bf16 / chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (1 link assumed per transfer)


def load_records(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def terms(rec: dict):
    c = rec.get("census") or {}
    t_comp = c.get("flops_per_chip", 0) / PEAK_FLOPS
    t_mem = c.get("mem_bytes_per_chip", 0) / HBM_BW
    t_coll = c.get("wire_bytes_per_chip", 0) / ICI_BW
    dom = max((("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
              key=lambda kv: kv[1])[0]
    return t_comp, t_mem, t_coll, dom


def run(out_dir: str = "experiments/dryrun") -> None:
    for rec in load_records(out_dir):
        name = f"roofline/{rec['arch']}/{rec['shape']}/{'x'.join(map(str, rec['mesh']))}"
        if rec.get("status") == "skipped":
            emit(name, 0.0, "skipped:" + rec["skip_reason"][:40])
            continue
        if rec.get("status") != "ok":
            emit(name, 0.0, "FAILED")
            continue
        t_comp, t_mem, t_coll, dom = terms(rec)
        emit(name, max(t_comp, t_mem, t_coll) * 1e6,
             f"comp={t_comp*1e3:.2f}ms|mem={t_mem*1e3:.2f}ms|coll={t_coll*1e3:.2f}ms|dom={dom}")


if __name__ == "__main__":
    run()
