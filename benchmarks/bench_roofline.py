"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run
JSONs (see launch/dryrun.py + launch/hlo_census.py).  Prints one row per cell;
the full table + analysis lives in EXPERIMENTS.md.

``run_kernels`` is the serving-kernel counterpart: each hot ranked/AND kernel
(``kernels/topk.py`` / ``kernels/intersect_rounds.py``) is lowered and
compiled at a canonical gov2-scale serving shape on the CURRENT backend, the
post-fusion HLO is fed through ``launch/hlo_census.py``, and the per-kernel
flop / memory / wire census plus roofline terms (v5e constants) land in
``BENCH_kernel_roofline.json`` (override with ``BENCH_KERNEL_ROOFLINE_JSON``)
— the CI artifact that makes kernel-lowering regressions (a scatter sneaking
back in, a fusion breaking apart) visible per PR as a census diff."""

from __future__ import annotations

import glob
import json
import os

from .util import emit

PEAK_FLOPS = 197e12          # v5e bf16 / chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (1 link assumed per transfer)


def load_records(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def terms(rec: dict):
    c = rec.get("census") or {}
    t_comp = c.get("flops_per_chip", 0) / PEAK_FLOPS
    t_mem = c.get("mem_bytes_per_chip", 0) / HBM_BW
    t_coll = c.get("wire_bytes_per_chip", 0) / ICI_BW
    dom = max((("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
              key=lambda kv: kv[1])[0]
    return t_comp, t_mem, t_coll, dom


def run(out_dir: str = "experiments/dryrun") -> None:
    for rec in load_records(out_dir):
        name = f"roofline/{rec['arch']}/{rec['shape']}/{'x'.join(map(str, rec['mesh']))}"
        if rec.get("status") == "skipped":
            emit(name, 0.0, "skipped:" + rec["skip_reason"][:40])
            continue
        if rec.get("status") != "ok":
            emit(name, 0.0, "FAILED")
            continue
        t_comp, t_mem, t_coll, dom = terms(rec)
        emit(name, max(t_comp, t_mem, t_coll) * 1e6,
             f"comp={t_comp*1e3:.2f}ms|mem={t_mem*1e3:.2f}ms|coll={t_coll*1e3:.2f}ms|dom={dom}")


def _kernel_cases():
    """The serving hot loop at a canonical gov2-scale shape: 64 queries,
    128 work-list entries, 512-posting blocks, 25k-doc bitmap geometry."""
    import jax.numpy as jnp
    from repro.kernels import topk
    from repro.kernels import intersect_rounds as ir

    words, _ = ir.bitmap_geometry(25_000)
    q, p, ow = 64, 128, 512
    acc = jnp.zeros((q, words * 32), jnp.uint32)
    bm = jnp.zeros((q, words), jnp.uint32)
    ids = jnp.zeros((p, ow), jnp.uint32)
    qslot = jnp.zeros((p,), jnp.int32)
    codes = jnp.zeros((p, ow), jnp.uint32)
    ns = jnp.zeros((p,), jnp.int32)
    ub = jnp.zeros((p,), jnp.int32)
    theta = jnp.zeros((q,), jnp.uint32)
    iq = jnp.full((q,), 1 << 16, jnp.uint32)
    margin = jnp.zeros((q,), jnp.int32)
    hits = jnp.zeros((p, ow), jnp.uint32)
    dense_words = jnp.zeros((p, 128), jnp.uint32)
    dense_tiles = jnp.zeros((p, 1024), jnp.uint32)
    w0 = jnp.zeros((p,), jnp.int32)
    act = jnp.zeros((p,), bool)
    active = jnp.zeros((q,), bool)
    return [
        ("score_round", topk.score_round,
         (acc, bm, ids, qslot, codes, ns, bm, ub, theta, iq),
         {"gated": False}),
        ("score_round_gated", topk.score_round,
         (acc, bm, ids, qslot, codes, ns, bm, ub, theta, iq),
         {"gated": True}),
        ("score_round_masked", topk.score_round_masked,
         (acc, bm, ids, qslot, codes, hits, ub, theta, iq), {}),
        ("dense_score_round", topk.dense_score_round,
         (acc, bm, dense_tiles, dense_words, qslot, w0, ub, theta, iq, bm),
         {"gated": True}),
        ("topk_threshold", topk._topk_threshold_jit, (acc,), {"k": 10}),
        ("pooled_threshold", topk.pooled_threshold, (acc,), {"k": 10}),
        ("candidate_bitmap", topk.candidate_bitmap,
         (acc, bm, theta, margin, iq), {}),
        ("round_accumulate", ir.round_accumulate,
         (bm, ids, qslot, ns, bm), {}),
        ("round_accumulate_masked", ir.round_accumulate_masked,
         (bm, ids, qslot, hits), {}),
        ("dense_round_accumulate", ir.dense_round_accumulate,
         (bm, dense_words, qslot, w0, act, bm), {}),
        ("round_commit", ir.round_commit, (bm, bm, active), {}),
    ]


def run_kernels() -> None:
    """Per-kernel flop/memory census of the compiled serving kernels."""
    import jax
    from repro.launch.hlo_census import census

    report = {"backend": jax.default_backend(), "kernels": {}}
    for name, fn, args, kw in _kernel_cases():
        hlo = fn.lower(*args, **kw).compile().as_text()
        c = census(hlo)
        t_comp = c.get("flops_per_chip", 0) / PEAK_FLOPS
        t_mem = c.get("mem_bytes_per_chip", 0) / HBM_BW
        report["kernels"][name] = {
            "flops": c.get("flops_per_chip", 0),
            "mem_bytes": c.get("mem_bytes_per_chip", 0),
            "wire_bytes": c.get("wire_bytes_per_chip", 0),
            "n_computations": c.get("n_computations", 0),
            "t_comp_us": t_comp * 1e6,
            "t_mem_us": t_mem * 1e6,
        }
        emit(f"roofline/kernel/{name}", max(t_comp, t_mem) * 1e6,
             f"flops={c.get('flops_per_chip', 0):.3g}|"
             f"mem={c.get('mem_bytes_per_chip', 0):.3g}B|"
             f"dom={'compute' if t_comp >= t_mem else 'memory'}")
    path = os.environ.get("BENCH_KERNEL_ROOFLINE_JSON",
                          "BENCH_kernel_roofline.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import sys
    if "--kernels" in sys.argv:
        run_kernels()
    else:
        run()
