"""Streaming serving benchmark: open-loop latency/goodput/shed under the
dynamic batcher (``repro.index.serve``), per arrival process and placement.

Offline qps (``bench_query.py``) measures how fast the engine chews a batch
it was handed; this harness measures what a request *stream* experiences:
requests arrive on an open-loop clock (arrivals never wait for responses),
the :class:`~repro.index.serve.IndexServer` forms batches under a
deadline-or-size policy, and every request's five-stage trace is recorded.
Two arrival processes at the same mean rate — Poisson (exponential
interarrivals) and bursty (Gamma interarrivals, shape < 1, so the same load
clumps) — cross ≥ 2 placements (host / device, plus fused when arenas carry
tiles), and each cell reports p50/p99/p999 latency, goodput (on-time served
qps), shed rate, and the achieved batch-size histogram.

Every cell is also *audited*: each batch the server formed is replayed
through the offline ``plan()/execute()`` oracle at the same placement and
the served results must be bitwise identical (``parity_ok``).  Under the
Poisson smoke load the shed rate must be exactly 0 — the CI-tracked
guarantee that admission + batching never drops a request the engine had
budget for.

Arrivals, corpus, and query workload all come from fixed RNG seeds, so two
runs measure the identical stream (timings vary, the workload does not).
Results go to ``BENCH_serving.json`` (override the path with the
``BENCH_SERVING_JSON`` env var); a baseline from a seeded run is committed
at the repo root.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax

from repro.data import synth
from repro.index.invindex import InvertedIndex
from repro.index.engine import QueryBatch, QueryEngine
from repro.index.serve import (Rejected, Request, ServeConfig,
                               bursty_offsets, poisson_offsets, serve_stream)
from .bench_query import git_sha, make_queries
from .util import emit


def _bitwise_equal(a, b) -> bool:
    """Recursive exact comparison: nested lists/tuples of arrays, or bare
    arrays — the shapes the engine's per-mode results take."""
    if isinstance(a, (tuple, list)) and isinstance(b, (tuple, list)):
        return (len(a) == len(b)
                and all(_bitwise_equal(x, y) for x, y in zip(a, b)))
    return np.array_equal(np.asarray(a), np.asarray(b))


def audit_parity(engine: QueryEngine, stats, results: list) -> bool:
    """Replay every batch the server formed through the offline
    ``plan()/execute()`` oracle at the same placement and check the served
    results bitwise.  ``results[rid]`` must be the stream's result for
    request ``rid`` (true for ``serve_stream``'s submission-order list)."""
    for b in stats.batches:
        plan = engine.plan(QueryBatch([list(q) for q in b.queries],
                                      mode=b.mode, k=b.k),
                           placement=b.placement)
        oracle = engine.execute(plan)
        for off, rid in zip(oracle, b.rids):
            if not _bitwise_equal(off, results[rid]):
                return False
    return True


def _drive(engine: QueryEngine, queries: list, offsets, deadline_ms: float,
           placement: str, max_batch: int, max_wait_ms: float,
           tenants: int = 2) -> tuple:
    """One benchmark cell: serve the stream, return (snapshot, parity_ok).

    The stream runs twice and only the second pass is recorded — the same
    ``warmup=1`` discipline as every ``timeit`` suite here.  Dynamic batch
    composition decides which jit worklist buckets get hit, so no synthetic
    priming can cover them all; the unrecorded first pass compiles whatever
    this exact stream forms, and the measured pass reports steady-state
    serving latency rather than first-seen compile stalls (which on the
    CPU-interpret backend run hundreds of ms each)."""
    reqs = [Request(list(q), mode="and", k=10,
                    tenant=f"t{i % tenants}", deadline_ms=deadline_ms)
            for i, q in enumerate(queries)]
    cfg = ServeConfig(
        max_batch=max_batch, max_wait_ms=max_wait_ms, slack_ms=2.0,
        # roomy admission: backpressure behaviour has its own tests; the
        # benchmark measures latency/goodput, not cap-induced shedding
        queue_cap=max(256, 4 * len(queries)),
        default_deadline_ms=deadline_ms,
        tenants={f"t{i}": 1.0 + i for i in range(tenants)},
        placement=placement, warm_terms=32, warm_modes=("and",),
        warm_queries=queries[:max_batch])
    serve_stream(engine, reqs, offsets, cfg)          # unrecorded warm pass
    results, stats = serve_stream(engine, reqs, offsets, cfg)
    served = [r for r in results if not isinstance(r, Rejected)]
    parity = audit_parity(engine, stats, results) if served else True
    return stats.snapshot(), parity


def run(n_requests: int = 192, dataset: str = "gov2",
        codec: str = "group_simple", seed: int = 0, rate_qps: float = 200.0,
        deadline_ms: float = 2500.0, smoke: bool = False) -> None:
    """Poisson + bursty open-loop streams across placements; writes
    ``BENCH_serving.json``.  ``smoke`` additionally *asserts* the two
    CI-tracked guarantees (Poisson shed rate 0, bitwise parity)."""
    doclen, postings = synth.make_corpus(dataset, seed)
    queries = make_queries(postings, n_requests, seed=3 + seed)
    idx = InvertedIndex.build(doclen, postings, codec=codec)
    idx.to_device(build_fused=True)
    engine = QueryEngine(idx).to_device(fused=True)

    max_batch, max_wait_ms = 16, 4.0
    arrivals = {
        "poisson": poisson_offsets(n_requests, rate_qps, seed=41 + seed),
        "bursty": bursty_offsets(n_requests, rate_qps, seed=43 + seed,
                                 shape=0.25),
    }
    placements = ("host", "device", "fused")
    report = {
        "dataset": dataset, "codec": codec, "backend": jax.default_backend(),
        "git_sha": git_sha(), "n_requests": n_requests,
        "rate_qps": rate_qps, "deadline_ms": deadline_ms,
        "config": {"max_batch": max_batch, "max_wait_ms": max_wait_ms,
                   "slack_ms": 2.0, "tenants": 2},
        "arrivals": {},
    }
    for arrival, offsets in arrivals.items():
        report["arrivals"][arrival] = {}
        for placement in placements:
            snap, parity = _drive(engine, queries, offsets, deadline_ms,
                                  placement, max_batch, max_wait_ms)
            cell = dict(snap)
            cell["parity_ok"] = bool(parity)
            report["arrivals"][arrival][placement] = cell
            lat = snap["latency_ms"]
            emit(f"serving/{dataset}/{codec}/{arrival}_{placement}",
                 (lat.get("p50", 0.0)) * 1e3,
                 f"p50={lat.get('p50', 0):.2f}ms,p99={lat.get('p99', 0):.2f}ms,"
                 f"p999={lat.get('p999', 0):.2f}ms,"
                 f"goodput={snap['goodput_qps']:.1f}qps,"
                 f"shed={snap['shed_rate']:.3f},"
                 f"mean_batch={snap['mean_batch']:.1f}")
            if not parity:
                raise AssertionError(
                    f"served results diverged from the offline plan/execute "
                    f"oracle ({arrival}/{placement})")
            if smoke and arrival == "poisson" and snap["shed_rate"] != 0.0:
                raise AssertionError(
                    f"Poisson smoke load shed {snap['shed_rate']:.3f} of "
                    f"requests on {placement} (must be 0)")

    path = os.environ.get("BENCH_SERVING_JSON", "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=192)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean open-loop arrival rate (qps)")
    ap.add_argument("--deadline-ms", type=float, default=2500.0,
                    help="per-request SLO budget; the generous default "
                         "absorbs first-seen jit-bucket compile stalls on "
                         "the CPU-interpret backend")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + arrival seed (fixed default keeps runs "
                         "deterministic)")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + assert shed-rate-0 / parity guarantees")
    args = ap.parse_args()
    run(n_requests=64 if args.smoke and args.n_requests == 192
        else args.n_requests,
        seed=args.seed, rate_qps=args.rate, deadline_ms=args.deadline_ms,
        smoke=args.smoke)
