"""Shared benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""

from __future__ import annotations

import functools
import time

import numpy as np

ROWS = []


def timeit(fn, *args, repeats: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        elif isinstance(out, (tuple, list)) and out and hasattr(out[0], "block_until_ready"):
            out[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def mis(n_ints: int, seconds: float) -> float:
    """Million integers per second (the paper's speed metric)."""
    return n_ints / seconds / 1e6


@functools.lru_cache(maxsize=None)
def gaps_and_tfs(dataset: str, seed: int = 0):
    from repro.data import synth
    lists = synth.make_dataset(dataset, seed)
    return synth.concat_gaps(lists), synth.concat_tfs(lists)
