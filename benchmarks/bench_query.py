"""Table X analogue: query processing rate (queries/second) per codec over
the compressed inverted index (AND + OR BM25 top-10, warm cache)."""

from __future__ import annotations

import numpy as np

from repro.data import synth
from repro.index.invindex import InvertedIndex
from repro.index import query as Q
from .util import emit, timeit

CODECS = ["group_simple", "group_scheme_8-IU", "group_pfd", "bp128",
          "group_afor", "varbyte", "simple9", "pfordelta", "afor", "gvb"]


def run(n_queries: int = 100, dataset: str = "gov2") -> None:
    doclen, postings = synth.make_corpus(dataset)
    rng = np.random.default_rng(3)
    terms = sorted(postings)
    queries = [rng.choice(terms[:120], size=rng.integers(2, 4), replace=False).tolist()
               for _ in range(n_queries)]
    for name in CODECS:
        idx = InvertedIndex.build(doclen, postings, codec=name)

        def run_and():
            for q in queries:
                Q.and_query_scored(idx, q, k=10)

        def run_or():
            for q in queries[: n_queries // 4]:
                Q.or_query(idx, q, k=10)

        t = timeit(run_and, repeats=3, warmup=1)
        emit(f"query/{dataset}/{name}/and", t * 1e6, f"{n_queries / t:.1f}qps")
        t = timeit(run_or, repeats=3, warmup=1)
        emit(f"query/{dataset}/{name}/or", t * 1e6, f"{(n_queries // 4) / t:.1f}qps")


if __name__ == "__main__":
    run()
