"""Table X analogue: query processing rate (queries/second) per codec over
the compressed inverted index (AND + OR BM25 top-10, warm cache), plus the
batched-engine mode: queries/sec at batch sizes {1, 16, 256} against the seed
per-query ``np.isin`` loop (``and_query_ref``)."""

from __future__ import annotations

import numpy as np

from repro.data import synth
from repro.index.invindex import InvertedIndex
from repro.index.engine import QueryBatch, QueryEngine
from repro.index import query as Q
from .util import emit, timeit

CODECS = ["group_simple", "group_scheme_8-IU", "group_pfd", "bp128",
          "group_afor", "varbyte", "stream_vbyte", "simple9", "pfordelta",
          "afor", "gvb"]

BATCH_SIZES = (1, 16, 256)


def make_queries(postings: dict, n_queries: int, seed: int = 3) -> list:
    rng = np.random.default_rng(seed)
    terms = sorted(postings)
    return [rng.choice(terms[:120], size=rng.integers(2, 4), replace=False).tolist()
            for _ in range(n_queries)]


def run(n_queries: int = 100, dataset: str = "gov2") -> None:
    doclen, postings = synth.make_corpus(dataset)
    queries = make_queries(postings, n_queries)
    for name in CODECS:
        idx = InvertedIndex.build(doclen, postings, codec=name)

        def run_and():
            for q in queries:
                Q.and_query_scored(idx, q, k=10)

        def run_or():
            for q in queries[: n_queries // 4]:
                Q.or_query(idx, q, k=10)

        t = timeit(run_and, repeats=3, warmup=1)
        emit(f"query/{dataset}/{name}/and", t * 1e6, f"{n_queries / t:.1f}qps")
        t = timeit(run_or, repeats=3, warmup=1)
        emit(f"query/{dataset}/{name}/or", t * 1e6, f"{(n_queries // 4) / t:.1f}qps")
    # batched mode needs enough queries sharing terms to expose cache reuse —
    # keep the canonical 256 except under CI smoke sizing (n_queries <= 20)
    run_batched(dataset=dataset, n_queries=n_queries if n_queries <= 20 else 256)


def run_batched(dataset: str = "gov2", codec: str = "group_simple",
                n_queries: int = 256) -> None:
    """Batched engine vs the seed scalar loop; prints qps per batch size."""
    doclen, postings = synth.make_corpus(dataset)
    queries = make_queries(postings, n_queries)
    idx = InvertedIndex.build(doclen, postings, codec=codec)

    def seed_loop():
        for q in queries:
            Q.and_query_ref(idx, q)

    t_ref = timeit(seed_loop, repeats=3, warmup=1)
    emit(f"query/{dataset}/{codec}/and_seed_loop", t_ref * 1e6,
         f"{n_queries / t_ref:.1f}qps")

    for bs in BATCH_SIZES:
        batches = [queries[i:i + bs] for i in range(0, len(queries), bs)]

        def run_engine():
            # fresh engine per repeat: cold cache, so the measurement includes
            # every decode the batch actually pays for
            eng = QueryEngine(idx)
            for b in batches:
                eng.execute(QueryBatch(b, mode="and"))

        t = timeit(run_engine, repeats=3, warmup=1)
        emit(f"query/{dataset}/{codec}/and_batched_{bs}", t * 1e6,
             f"{n_queries / t:.1f}qps,{t_ref / t:.1f}x")


if __name__ == "__main__":
    run()
