"""Table X analogue: query processing rate (queries/second) per codec over
the compressed inverted index (AND + OR BM25 top-10, warm cache), plus the
batched-engine mode: queries/sec at batch sizes {1, 16, 256} for the host
numpy path AND the device-arena path (``QueryEngine.to_device()``), against
the seed per-query ``np.isin`` loop (``and_query_ref``).

The batched run also records the device work-list discipline — raw (term,
block) references per batch vs deduped decodes actually issued — plus the
ranked modes (``or`` / ``and_scored`` through the quantized score arenas and
block-max top-k: qps per placement, ``blocks_pruned`` / ``blocks_scored``,
and per-round host syncs, which must be zero on the resident ranked path) —
and writes the whole thing to ``BENCH_query.json`` (override the path with
the ``BENCH_QUERY_JSON`` env var) so CI can track the perf trajectory as an
artifact.  Two more report sections feed the serving stack: ``mode_qps``
(host-vs-device qps per batch size, per query MODE, with the placement
pinned — ``CrossoverTable.from_bench`` derives one demotion cell per mode
from these, so ranked modes demote independently of plain AND) and
``sharded`` (doc-range sharded serving scaling curves over ``--shards``
counts: qps per mode, plus the collective accounting — merge syncs and
collective bytes per ranked batch, and the cross-shard round syncs, which
must be ZERO: doc-wise partitioning keeps every round shard-local).  On the CPU/interpret CI backend the device path's wall-clock is
not the headline (jitted gathers vs raw numpy); the tracked guarantee there
is ``decodes_per_hot_block == 1.0``: each hot (term, block) decodes at most
once per batch, in O(rounds) device calls instead of O(blocks) Python
iterations.

``--mutate`` (also run as part of the default suite) exercises the streaming
mutable index: qps on the device placement at 0% / 1% / 10% tombstone
density, the compaction pause (one ``compact()`` merge re-encoding the live
corpus into the next generation), and the delta-segment scan overhead (qps
with freshly inserted docs pending in the mutable segment vs the compacted
clean index).  Results go to ``BENCH_mutation.json`` (override with
``BENCH_MUTATION_JSON``); the tracked CI guarantees are that tombstone
gating stays resident — ``cand_syncs == 0`` at every density — and that
block-max pruning stays ARMED under the tombstone-only epoch
(``ranked_tomb_1pct.blocks_pruned > 0``: deletes only raise idf, so the
idf-ratio-deflated threshold keeps the upper-bound test sound; see the
re-arm note in ``repro/index/scores.py``).

Every input is derived from fixed RNG seeds (corpus via
``synth.make_corpus(dataset, seed)``, query sets via seeded generators), so
two runs at the same sizes measure the identical workload — the committed
``BENCH_query.json`` baseline at the repo root is reproducible bit-for-bit
on the inputs (timings vary, the workload does not).
"""

from __future__ import annotations

import json
import os
import subprocess
import time

import numpy as np
import jax

from repro.data import synth
from repro.index.invindex import InvertedIndex
from repro.index.engine import QueryBatch, QueryEngine
from repro.index import query as Q
from .util import emit, timeit

CODECS = ["group_simple", "group_scheme_8-IU", "group_pfd", "bp128",
          "group_afor", "varbyte", "stream_vbyte", "simple9", "pfordelta",
          "afor", "gvb"]

BATCH_SIZES = (1, 16, 256)


def git_sha() -> str:
    """Current commit, so the qps trajectory is comparable across PRs."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def make_queries(postings: dict, n_queries: int, seed: int = 3) -> list:
    rng = np.random.default_rng(seed)
    terms = sorted(postings)
    return [rng.choice(terms[:120], size=rng.integers(2, 4), replace=False).tolist()
            for _ in range(n_queries)]


def make_ranked_queries(postings: dict, n_queries: int, seed: int = 7) -> list:
    """Ranked workload: one tail term (high idf -> strong impacts) plus 1-2
    head terms per query — the rare+common shape where block-max pruning
    earns its keep (head-term blocks outside the tail term's docid
    neighbourhood can't reach the top-k threshold)."""
    rng = np.random.default_rng(seed)
    terms = sorted(postings)
    return [[int(rng.choice(terms[120:]))]
            + rng.choice(terms[:120], size=rng.integers(1, 3),
                         replace=False).tolist()
            for _ in range(n_queries)]


def run(n_queries: int = 100, dataset: str = "gov2", seed: int = 0,
        shard_counts: tuple = (1, 2, 4)) -> None:
    doclen, postings = synth.make_corpus(dataset, seed)
    queries = make_queries(postings, n_queries, seed=3 + seed)
    for name in CODECS:
        idx = InvertedIndex.build(doclen, postings, codec=name)

        def run_and():
            for q in queries:
                Q.and_query_scored(idx, q, k=10)

        def run_or():
            for q in queries[: n_queries // 4]:
                Q.or_query(idx, q, k=10)

        t = timeit(run_and, repeats=3, warmup=1)
        emit(f"query/{dataset}/{name}/and", t * 1e6, f"{n_queries / t:.1f}qps")
        t = timeit(run_or, repeats=3, warmup=1)
        emit(f"query/{dataset}/{name}/or", t * 1e6, f"{(n_queries // 4) / t:.1f}qps")
    # batched mode needs enough queries sharing terms to expose cache reuse —
    # keep the canonical 256 except under CI smoke sizing (n_queries <= 20)
    run_batched(dataset=dataset, n_queries=n_queries if n_queries <= 20 else 256,
                seed=seed, shard_counts=shard_counts)
    run_mutation(dataset=dataset, n_queries=n_queries if n_queries <= 20 else 128,
                 seed=seed)


def run_batched(dataset: str = "gov2", codec: str = "group_simple",
                n_queries: int = 256, seed: int = 0,
                shard_counts: tuple = (1, 2, 4)) -> None:
    """Batched engine (host + device paths) vs the seed scalar loop."""
    doclen, postings = synth.make_corpus(dataset, seed)
    queries = make_queries(postings, n_queries, seed=3 + seed)
    idx = InvertedIndex.build(doclen, postings, codec=codec)
    # provenance stamp: codec, jax backend, and commit make the trajectory
    # comparable across PRs and across CI/TPU runners
    report = {"dataset": dataset, "codec": codec, "n_queries": n_queries,
              "backend": jax.default_backend(), "git_sha": git_sha(),
              "host_qps": {}, "device_qps": {}}

    def seed_loop():
        for q in queries:
            Q.and_query_ref(idx, q)

    t_ref = timeit(seed_loop, repeats=3, warmup=1)
    emit(f"query/{dataset}/{codec}/and_seed_loop", t_ref * 1e6,
         f"{n_queries / t_ref:.1f}qps")
    report["seed_loop_qps"] = n_queries / t_ref

    # build arenas once, outside the timers (no fused tiles: the timed
    # device path is the batched work-list decode, not the fused kernel)
    idx.to_device(build_fused=False)
    for bs in BATCH_SIZES:
        batches = [queries[i:i + bs] for i in range(0, len(queries), bs)]

        def run_engine(device: bool):
            # fresh engine per repeat: cold cache, so the measurement includes
            # every decode the batch actually pays for
            eng = QueryEngine(idx)
            if device:
                eng.to_device()
            for b in batches:
                eng.execute(eng.plan(QueryBatch(b, mode="and")))

        t = timeit(lambda: run_engine(False), repeats=3, warmup=1)
        emit(f"query/{dataset}/{codec}/and_batched_{bs}", t * 1e6,
             f"{n_queries / t:.1f}qps,{t_ref / t:.1f}x")
        report["host_qps"][bs] = n_queries / t
        t = timeit(lambda: run_engine(True), repeats=3, warmup=1)
        emit(f"query/{dataset}/{codec}/and_device_{bs}", t * 1e6,
             f"{n_queries / t:.1f}qps,{t_ref / t:.1f}x")
        report["device_qps"][bs] = n_queries / t

    # work-list discipline at the largest batch size: with an eviction-free
    # cache on a cold engine, the unique hot (term, block) set is exactly the
    # decoded-block keys left in the cache, counted independently of the
    # decode counters — a dedup regression shows up as a ratio > 1
    eng = QueryEngine(idx, cache_blocks=1 << 20).to_device()
    eng.execute(eng.plan(QueryBatch(queries, mode="and")))
    refs = eng.dev_stats["worklist_refs"]
    decodes = (eng.dev_stats["worklist_decodes"]
               + eng.dev_stats["fallback_decodes"])
    hot = len({k for k in eng.cache.keys() if k[1] >= 0})
    report["worklist_refs"] = refs
    report["worklist_decodes"] = decodes
    report["hot_blocks"] = hot
    report["decodes_per_hot_block"] = decodes / max(hot, 1)
    emit(f"query/{dataset}/{codec}/device_worklist", 0.0,
         f"{refs}refs,{decodes}decodes,{hot}hot,"
         f"{decodes / max(hot, 1):.2f}per_hot_block")

    # candidate residency per placement: rounds executed with candidates
    # device-resident, and candidate downloads per query (the resident
    # placements must show zero syncs between rounds — their only download
    # is the one final result copy per batch, reported separately)
    report["placements"] = {}
    for placement in ("host", "device", "fused"):
        eng = QueryEngine(idx)
        if placement != "host":
            eng.to_device(fused=placement == "fused")
        eng.execute(eng.plan(QueryBatch(queries, mode="and")))
        stats = {
            "rounds_on_device": eng.dev_stats["resident_rounds"],
            "host_syncs_per_query": eng.dev_stats["cand_syncs"] / n_queries,
            "final_syncs": eng.dev_stats["final_syncs"],
        }
        report["placements"][placement] = stats
        emit(f"query/{dataset}/{codec}/residency_{placement}", 0.0,
             f"{stats['rounds_on_device']}rounds_on_device,"
             f"{stats['host_syncs_per_query']:.3f}syncs_per_query")

    # ranked modes (or / and_scored): quantized score arenas + block-max
    # top-k.  Arenas, fused tiles, and the score column are built once
    # outside the timers; the tracked CI guarantees are blocks_pruned > 0
    # (the upper-bound test actually drops work) and zero per-round host
    # syncs (only the final candidate bitmap is downloaded, once per batch).
    ranked_queries = make_ranked_queries(postings, n_queries, seed=7 + seed)
    idx.to_device(build_fused=True).ensure_scores()
    report["ranked"] = {}
    for mode in ("or", "and_scored"):
        entry = {"k": 10, "qps": {}}
        for placement in ("host", "device", "fused"):

            def run_ranked():
                eng = QueryEngine(idx)
                if placement != "host":
                    eng.to_device(fused=placement == "fused")
                for i in range(0, len(ranked_queries), 64):
                    eng.execute(eng.plan(QueryBatch(
                        ranked_queries[i:i + 64], mode=mode, k=10)))

            t = timeit(run_ranked, repeats=3, warmup=1)
            entry["qps"][placement] = n_queries / t
            emit(f"query/{dataset}/{codec}/{mode}_{placement}", t * 1e6,
                 f"{n_queries / t:.1f}qps")
        eng = QueryEngine(idx).to_device()
        eng.execute(eng.plan(QueryBatch(ranked_queries, mode=mode, k=10)))
        entry["blocks_pruned"] = eng.dev_stats["blocks_pruned"]
        entry["blocks_scored"] = eng.dev_stats["blocks_scored"]
        entry["blocks_dense"] = eng.dev_stats["blocks_dense"]
        entry["score_rounds"] = eng.dev_stats["score_rounds"]
        entry["host_syncs_per_query"] = eng.dev_stats["score_syncs"] / n_queries
        entry["final_syncs"] = eng.dev_stats["final_syncs"]
        report["ranked"][mode] = entry
        emit(f"query/{dataset}/{codec}/{mode}_blockmax", 0.0,
             f"{entry['blocks_pruned']}pruned,{entry['blocks_scored']}scored,"
             f"{entry['host_syncs_per_query']:.3f}syncs_per_query")

    # per-mode placement crossover curves, placement PINNED (the auto-placed
    # curves above fold the planner's own demotion into the measurement):
    # CrossoverTable.from_bench derives one demotion cell per mode from
    # "mode_qps", so ranked modes — which amortize score uploads and the
    # final-merge sync over the batch — demote independently of plain AND
    report["mode_qps"] = {"and": {"host": dict(report["host_qps"]),
                                  "device": dict(report["device_qps"])}}
    for mode in ("or", "and_scored"):
        curves = {"host": {}, "device": {}}
        for bs in BATCH_SIZES:
            rbatches = [ranked_queries[i:i + bs]
                        for i in range(0, len(ranked_queries), bs)]

            def run_mode(device: bool):
                eng = QueryEngine(idx)
                if device:
                    eng.to_device()
                for b in rbatches:
                    eng.execute(eng.plan(
                        QueryBatch(b, mode=mode, k=10),
                        placement="device" if device else "host"))

            t = timeit(lambda: run_mode(False), repeats=3, warmup=1)
            curves["host"][bs] = n_queries / t
            t = timeit(lambda: run_mode(True), repeats=3, warmup=1)
            curves["device"][bs] = n_queries / t
            emit(f"query/{dataset}/{codec}/{mode}_crossover_{bs}", 0.0,
                 f"host={curves['host'][bs]:.1f}qps,"
                 f"device={curves['device'][bs]:.1f}qps")
        report["mode_qps"][mode] = curves

    # doc-range sharded serving: scaling curves over shard counts.  The
    # per-generation shard cache means the slice-and-re-encode build cost is
    # paid once per count (in the warmup), so the timers measure serving.
    # Tracked contracts: ONE top-k merge collective per ranked batch, and
    # ZERO cross-shard round syncs (candidates and score accumulators never
    # leave their shard — doc-wise partitioning, not term-wise).
    report["sharded"] = {}
    for s in shard_counts:
        entry = {"qps": {}}
        for mode in ("and", "or", "and_scored"):
            qs = queries if mode == "and" else ranked_queries

            def run_shard_engine():
                eng = QueryEngine(idx).to_device(shards=s)
                for i in range(0, len(qs), 64):
                    eng.execute(eng.plan(
                        QueryBatch(qs[i:i + 64], mode=mode, k=10),
                        placement="device"))
                return eng

            t = timeit(run_shard_engine, repeats=3, warmup=1)
            entry["qps"][mode] = n_queries / t
            emit(f"query/{dataset}/{codec}/sharded{s}_{mode}", t * 1e6,
                 f"{n_queries / t:.1f}qps")
        eng = QueryEngine(idx).to_device(shards=s)
        n_batches = -(-len(ranked_queries) // 64)
        for i in range(0, len(ranked_queries), 64):
            eng.execute(eng.plan(
                QueryBatch(ranked_queries[i:i + 64], mode="or", k=10),
                placement="device"))
        spec, engs, _ = eng._shard_engines(eng._ctx_now())
        entry["bounds"] = list(spec.bounds)
        entry["merge_syncs_per_batch"] = \
            eng.dev_stats["merge_syncs"] / n_batches
        entry["collective_bytes_per_batch"] = \
            eng.dev_stats["collective_bytes"] / n_batches
        entry["cross_shard_round_syncs"] = sum(
            e.dev_stats["cand_syncs"] + e.dev_stats["score_syncs"]
            for e in engs if e is not None)
        report["sharded"][s] = entry
        emit(f"query/{dataset}/{codec}/sharded{s}_collectives", 0.0,
             f"{entry['merge_syncs_per_batch']:.1f}merges_per_batch,"
             f"{entry['collective_bytes_per_batch']:.0f}B,"
             f"{entry['cross_shard_round_syncs']}cross_shard_syncs")

    path = os.environ.get("BENCH_QUERY_JSON", "BENCH_query.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


def run_mutation(dataset: str = "gov2", codec: str = "group_pfd",
                 n_queries: int = 128, seed: int = 0) -> None:
    """Streaming-mutation serving cost: tombstone-gated qps, compaction
    pause, and delta-segment scan overhead (see the module docstring)."""
    doclen, postings = synth.make_corpus(dataset, seed)
    queries = make_queries(postings, n_queries, seed=3 + seed)
    ranked_queries = make_ranked_queries(postings, n_queries, seed=7 + seed)
    n_docs = len(doclen)
    rng = np.random.default_rng(11 + seed)
    report = {"dataset": dataset, "codec": codec, "n_queries": n_queries,
              "n_docs": n_docs, "backend": jax.default_backend(),
              "git_sha": git_sha(), "tombstone_qps": {}}

    def measure(idx, tag: str) -> dict:
        """Device-placement and-mode qps over the whole query set (fresh
        engine per repeat: the per-epoch live-bitmap upload is part of the
        serving cost being measured)."""
        def go():
            eng = QueryEngine(idx)
            eng.to_device()
            for i in range(0, len(queries), 64):
                eng.execute(eng.plan(QueryBatch(queries[i:i + 64], mode="and")))
            return eng
        t = timeit(go, repeats=3, warmup=1)
        eng = go()   # one extra run for the residency counters
        stats = {"qps": n_queries / t,
                 "cand_syncs": eng.dev_stats["cand_syncs"],
                 "tomb_gates": eng.dev_stats["tomb_gates"]}
        emit(f"query/{dataset}/{codec}/mutate_{tag}", t * 1e6,
             f"{n_queries / t:.1f}qps,{stats['cand_syncs']}cand_syncs")
        return stats

    idx = InvertedIndex.build(doclen, postings, codec=codec)
    idx.to_device(build_fused=False)
    report["tombstone_qps"]["0%"] = clean = measure(idx, "tomb_0pct")

    # tombstone density sweep: each step deletes up to the target fraction of
    # the base doc space; the live bitmap is re-packed once per epoch and the
    # gate must add zero candidate downloads
    victims = rng.permutation(n_docs)
    n_deleted = 0
    for frac, tag in ((0.01, "1%"), (0.10, "10%")):
        target = int(n_docs * frac)
        for d in victims[n_deleted:target]:
            idx.delete(int(d))
        n_deleted = target
        report["tombstone_qps"][tag] = measure(idx, f"tomb_{tag.rstrip('%')}pct")
        if tag == "1%":
            # re-armed block-max pruning under the tombstone-only epoch:
            # deletes only raise idf, so the idf-ratio-deflated threshold
            # keeps the upper-bound test sound and pruning must still fire
            # (blocks_pruned > 0 is the tracked CI guarantee for the re-arm)
            idx.to_device(build_fused=False).ensure_scores()

            def go_ranked():
                eng = QueryEngine(idx).to_device()
                for i in range(0, len(ranked_queries), 64):
                    eng.execute(eng.plan(QueryBatch(
                        ranked_queries[i:i + 64], mode="or", k=10)))
                return eng
            t = timeit(go_ranked, repeats=3, warmup=1)
            eng = go_ranked()
            report["ranked_tomb_1pct"] = {
                "qps": n_queries / t,
                "blocks_pruned": eng.dev_stats["blocks_pruned"],
                "blocks_scored": eng.dev_stats["blocks_scored"],
                "score_syncs": eng.dev_stats["score_syncs"],
            }
            emit(f"query/{dataset}/{codec}/mutate_ranked_tomb_1pct", t * 1e6,
                 f"{n_queries / t:.1f}qps,"
                 f"{eng.dev_stats['blocks_pruned']}pruned,"
                 f"{eng.dev_stats['blocks_scored']}scored")

    # compaction pause: one merge of generation-minus-tombstones through the
    # codec registry into the next generation (10% of the corpus dead)
    t0 = time.perf_counter()
    idx.compact()
    pause = time.perf_counter() - t0
    report["compaction_pause_s"] = pause
    report["compacted_gid"] = idx.gen.gid
    emit(f"query/{dataset}/{codec}/mutate_compact_pause", pause * 1e6,
         f"{n_docs - n_deleted}live_docs,gid{idx.gen.gid}")

    # delta-segment scan overhead: fresh docs pending in the mutable segment
    # are brute-force scanned and merged into every query's result
    idx.to_device(build_fused=False)
    report["post_compact_qps"] = measure(idx, "post_compact")
    terms = sorted(postings)
    base = idx.doc_space
    n_delta = max(16, n_docs // 100)
    for j in range(n_delta):
        picked = rng.choice(terms[:120], size=8, replace=False)
        idx.insert(base + j, {int(t): int(rng.integers(1, 5)) for t in picked},
                   doclen=int(doclen.mean()))
    delta = measure(idx, "delta_1pct")
    report["delta_qps"] = delta
    report["n_delta_docs"] = n_delta
    report["delta_scan_overhead_x"] = clean["qps"] / max(delta["qps"], 1e-9)
    emit(f"query/{dataset}/{codec}/mutate_delta_overhead", 0.0,
         f"{n_delta}delta_docs,{report['delta_scan_overhead_x']:.2f}x")

    path = os.environ.get("BENCH_MUTATION_JSON", "BENCH_mutation.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mutate", action="store_true",
                    help="only the streaming-mutation suite (BENCH_mutation.json)")
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (corpus + query sets); fixed default "
                         "keeps runs deterministic")
    ap.add_argument("--shards", type=str, default="1,2,4",
                    help="comma-separated shard counts for the sharded "
                         "serving scaling curves (BENCH_query.json)")
    args = ap.parse_args()
    shard_counts = tuple(int(x) for x in args.shards.split(",") if x)
    if args.mutate:
        run_mutation(n_queries=args.n_queries or 128, seed=args.seed)
    else:
        run(n_queries=args.n_queries or 100, seed=args.seed,
            shard_counts=shard_counts)
