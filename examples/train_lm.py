"""End-to-end driver: train an LM on a COMPRESSED token store with the
fault-tolerant loop (checkpoint/resume, straggler watchdog).

Default --preset tiny trains a ~1M-param smollm-family model for 200 steps on
CPU in a few minutes and asserts the loss decreases.  --preset full selects
the real smollm-135m config (same code path; run it on real accelerators).

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--preset tiny]
  PYTHONPATH=src python examples/train_lm.py --resume   # continue from ckpt
"""

import argparse
import dataclasses

import numpy as np
import jax

from repro.configs import smollm_135m
from repro.data.pipeline import TokenStore, lm_batch_iter
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import train_loop as TL
from repro.runtime.trainer import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--crash-at", type=int, default=None)
    args = ap.parse_args()

    if args.preset == "full":
        cfg = smollm_135m.make_config()
    else:
        cfg = dataclasses.replace(
            smollm_135m.make_smoke_config(), n_layers=4, d_model=128, n_heads=4,
            n_kv=2, head_dim=32, d_ff=512, vocab=2048)

    # synthetic corpus stored COMPRESSED (bp128 blocks); loader decodes on the fly
    rng = np.random.default_rng(0)
    n_tok = args.batch * (args.seq + 1) * 64
    # markov-ish stream so the model has something to learn
    base = rng.integers(0, cfg.vocab // 4, n_tok).astype(np.uint32)
    toks = np.where(rng.random(n_tok) < 0.7, np.roll(base, 1) % cfg.vocab, base)
    store = TokenStore.build(toks.astype(np.uint32), codec="bp128")
    print(f"token store: {store.compressed_bytes()/1e6:.2f} MB compressed "
          f"({store.raw_bytes/1e6:.2f} MB raw)")

    params = T.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name} {n_params/1e6:.1f}M params")

    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

    def loss_fn(p, batch):
        return T.loss_fn(p, batch["tokens"], batch["labels"], cfg)

    step = jax.jit(make_train_step(loss_fn, ocfg))
    loop_cfg = TL.LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                             ckpt_every=50, log_every=20, crash_at_step=args.crash_at)
    params, opt, info = TL.run(step, params, adamw_init(params),
                               lm_batch_iter(store, args.batch, args.seq), loop_cfg)
    first = info["metrics"][0]["loss"] if info["metrics"] else float("nan")
    last = info["metrics"][-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f}  (stragglers flagged: {len(info['stragglers'])})")
    if args.preset == "tiny" and info["metrics"]:
        assert last < first, "loss did not decrease"
        print("OK: loss decreased on the compressed pipeline")


if __name__ == "__main__":
    main()
