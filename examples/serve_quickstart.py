"""Quickstart: latency-governed online serving over the compressed index.

  1. build a seeded corpus and move it into device-resident arenas,
  2. start an IndexServer (async admission + dynamic batching) — warm-up
     primes the hot-term caches and the jit buckets,
  3. drive an open-loop Poisson request stream with per-request deadlines
     and two weighted tenants through it,
  4. read the SLO snapshot (p50/p99/p999 latency, goodput, shed rate,
     batch-size histogram per placement),
  5. replay one formed batch through the offline plan/execute oracle and
     check the served results are bitwise identical.

Run:  PYTHONPATH=src python examples/serve_quickstart.py
"""

import numpy as np

from repro.data import synth
from repro.index.invindex import InvertedIndex
from repro.index.engine import QueryBatch, QueryEngine
from repro.index.serve import (Rejected, Request, ServeConfig,
                               poisson_offsets, serve_stream)


def main() -> None:
    # 1. corpus + device arenas (same seeded GOV2-like shape the benchmarks use)
    doclen, postings = synth.make_corpus("gov2", seed=0)
    idx = InvertedIndex.build(doclen, postings, codec="group_simple")
    idx.to_device(build_fused=False)
    engine = QueryEngine(idx).to_device()

    # 2-3. a 128-request open-loop Poisson stream at 200 qps: every request
    # carries a 2.5 s deadline — generous on the CPU-interpret backend,
    # where any first-seen jit bucket that slips past warm-up compiles
    # mid-stream and would otherwise shed the whole backlog.  Tenant "pro"
    # has twice "free"'s admission weight, so under contention it gets ~2x
    # the batch slots.
    n, rate = 128, 200.0
    rng = np.random.default_rng(3)
    terms = sorted(postings)
    reqs = [Request(rng.choice(terms[:120], size=3, replace=False).tolist(),
                    mode="and", k=10,
                    tenant="pro" if i % 3 else "free", deadline_ms=2500.0)
            for i in range(n)]
    cfg = ServeConfig(max_batch=16, max_wait_ms=4.0, slack_ms=2.0,
                      queue_cap=n, default_deadline_ms=2500.0,
                      tenants={"pro": 2.0, "free": 1.0}, warm_terms=32)
    results, stats = serve_stream(
        engine, reqs, poisson_offsets(n, rate, seed=41), cfg)
    assert all(not isinstance(r, Rejected) for r in results), "stream shed!"

    # 4. the SLO snapshot
    snap = stats.snapshot()
    lat = snap["latency_ms"]
    print(f"served {snap['served']}/{snap['submitted']} requests at "
          f"{rate:.0f} qps poisson (shed_rate={snap['shed_rate']:.3f}, "
          f"warmup={snap['warmup_s']:.2f}s)")
    print(f"latency ms: p50={lat['p50']:.2f}  p99={lat['p99']:.2f}  "
          f"p999={lat['p999']:.2f}   goodput={snap['goodput_qps']:.0f} qps  "
          f"on_time={snap['on_time_frac']:.2%}")
    print(f"batches: {snap['n_batches']} closed, mean size "
          f"{snap['mean_batch']:.1f}, histogram {snap['batch_hist']}")
    print(f"tenants: { {t: d['served'] for t, d in snap['per_tenant'].items()} }")

    # 5. bitwise parity: any batch the server formed replays through the
    # offline plan/execute discipline to the exact same results
    b = stats.batches[0]
    oracle = engine.execute(engine.plan(
        QueryBatch([list(q) for q in b.queries], mode=b.mode, k=b.k),
        placement=b.placement))
    for off, rid in zip(oracle, b.rids):
        assert np.array_equal(np.asarray(off), np.asarray(results[rid]))
    print(f"parity: batch {b.batch_id} ({len(b.queries)} requests, "
          f"placement={b.placement}) bitwise identical to the offline oracle")


if __name__ == "__main__":
    main()
