"""Serving example: prefill a batch of prompts, then batched greedy decode
against the KV cache (GQA ring / MLA latent caches both exercised).

  PYTHONPATH=src python examples/serve_lm.py [--arch smollm-135m|deepseek-v2-lite-16b] [--tokens 16]
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    spec = configs.get(args.arch)
    cfg = spec.make_smoke_config()           # CPU-sized; same code path as full
    params = T.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg))
    logits, cache = prefill(params, prompts)
    # extend cache capacity for generated tokens (no SWA ring growth needed)
    if not cfg.window:
        cache = {k: jnp.concatenate(
            [v, jnp.zeros(v.shape[:2] + (args.tokens,) + v.shape[3:], v.dtype)], axis=2)
            for k, v in cache.items()}
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.tokens):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.stack(out, axis=1)
    print(f"arch={args.arch} cache={'MLA latent' if cfg.attn == 'mla' else ('SWA ring' if cfg.window else 'GQA')}")
    print(f"generated {gen.shape} tokens in {dt*1e3:.1f} ms "
          f"({args.batch*args.tokens/dt:.0f} tok/s batched greedy)")
    print("sample:", gen[0][:12])


if __name__ == "__main__":
    main()
