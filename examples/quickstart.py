"""Quickstart: the paper's compression approach in five minutes.

  1. encode/decode posting-list d-gaps with every Group codec,
  2. compare scalar vs vectorized decode (the paper's central axis),
  3. run the TPU-layout Pallas kernels (interpret mode on CPU),
  4. build + query a compressed inverted index,
  5. serve a query batch through the fused decode-and-intersect engine
     (plan, then execute: engine.execute(engine.plan(batch))),
  6. move the index into device-resident arenas (engine.to_device()) and
     serve the same batch with round-batched lane-parallel block decodes —
     arena coverage comes from each codec's declared ArenaLayout capability.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.core import codec as codec_lib
from repro.core.dgap import dgap_encode_np
from repro.data import synth
from repro.index.invindex import InvertedIndex
from repro.index.engine import QueryBatch, QueryEngine
from repro.index import query as Q
from repro.kernels import ops


def main() -> None:
    lists = synth.make_dataset("gov2", seed=0)
    gaps = synth.concat_gaps(lists)
    print(f"GOV2-like stream: {len(gaps)} d-gaps, "
          f"{100*float(np.mean(gaps < 256)):.1f}% fit in one byte\n")

    print(f"{'codec':22}{'bits/int':>9}{'scalar(ms)':>12}{'vec(ms)':>9}")
    for name in ("group_simple", "group_scheme_1-CU", "group_scheme_8-IU",
                 "group_afor", "group_pfd", "bp128"):
        spec = codec_lib.get(name)
        enc = spec.encode(gaps)
        args = spec.jax_args(enc)
        out = np.asarray(spec.decode_jax_vec(**args))
        assert np.array_equal(out, gaps)
        for f in (spec.decode_jax_scalar, spec.decode_jax_vec):
            f(**args).block_until_ready()
        t0 = time.perf_counter(); spec.decode_jax_scalar(**args).block_until_ready()
        ts = time.perf_counter() - t0
        t0 = time.perf_counter(); spec.decode_jax_vec(**args).block_until_ready()
        tv = time.perf_counter() - t0
        print(f"{name:22}{enc.bits_per_int:9.2f}{ts*1e3:12.2f}{tv*1e3:9.2f}")

    # Pallas kernels (TPU target, interpret on CPU): pack -> fused unpack+delta
    docids = np.sort(np.random.default_rng(0).choice(1 << 20, 20000, replace=False)).astype(np.uint32)
    g = dgap_encode_np(docids)
    bw = int(np.ceil(np.log2(g.max() + 1)))
    packed = ops.pack_stream(jnp.asarray(g), bw)
    recon = np.asarray(ops.unpack_delta_stream(packed, bw, len(g)))
    assert np.array_equal(recon, docids)
    print(f"\nPallas fused unpack+prefix-sum: {len(g)} gaps at bw={bw} -> docids OK "
          f"({packed.size * 4 / len(g):.2f} B/int vs 4.00 raw)")

    # compressed inverted index + queries
    doclen, postings = synth.make_corpus("gov2")
    idx = InvertedIndex.build(doclen, postings, codec="group_simple")
    hits = Q.and_query_scored(idx, [1, 5], k=5)
    print(f"\nindex: {idx.size_bytes()/1e6:.2f} MB (group_simple); "
          f"AND(1,5) top hit doc={hits[0][0]} bm25={hits[0][1]:.2f}")

    # batched serving: many queries per call, shared decoded-block LRU
    rng = np.random.default_rng(0)
    terms = sorted(postings)
    queries = [rng.choice(terms[:100], size=3, replace=False).tolist()
               for _ in range(256)]
    engine = QueryEngine(idx, cache_blocks=4096)
    plan = engine.plan(QueryBatch(queries, mode="and"))
    t0 = time.perf_counter()
    results = engine.execute(plan)
    dt = time.perf_counter() - t0
    st = engine.cache.stats()
    print(f"batched engine: {len(queries)} AND queries in {dt*1e3:.1f} ms "
          f"({len(queries)/dt:.0f} qps); block cache {st['hits']} hits / "
          f"{st['misses']} misses; first result has {len(results[0])} docs")

    # device-resident serving: compressed blocks flattened into device arenas,
    # each AND round issues ONE lane-parallel decode for the whole batch's
    # deduped (term, block) work-list instead of O(blocks) Python iterations
    dev = QueryEngine(idx, cache_blocks=4096).to_device()
    dev_plan = dev.plan(QueryBatch(queries, mode="and"))
    dev.execute(dev_plan)                               # warm up the jits
    dev = QueryEngine(idx, cache_blocks=4096).to_device()
    calls0 = dev.arena.stats["device_calls"]   # arena (and stats) are shared
    t0 = time.perf_counter()
    dev_results = dev.execute(dev_plan)
    dt = time.perf_counter() - t0
    assert all(np.array_equal(a, b) for a, b in zip(results, dev_results))
    ds = dev.dev_stats
    print(f"device engine:  {len(queries)} AND queries in {dt*1e3:.1f} ms "
          f"({len(queries)/dt:.0f} qps, exact parity); work-list "
          f"{ds['worklist_refs']} block refs -> {ds['worklist_decodes']} decodes "
          f"in {dev.arena.stats['device_calls'] - calls0} device calls")

    # ranked top-k through the quantized score arenas: BM25 impacts ride as
    # u8 score columns next to the docid streams, OR work-lists are block-max
    # pruned, and only the final candidate bitmap returns to the host — the
    # float rescore makes the results exactly the host oracle's (docid ties)
    topk_plan = dev.plan(QueryBatch(queries[:64], mode="or", k=5))
    top = dev.execute(topk_plan)
    host_top = engine.execute(engine.plan(QueryBatch(queries[:64], mode="or", k=5)))
    assert top == host_top
    ds = dev.dev_stats
    print(f"ranked top-k:   64 OR queries, k=5 -> top hit doc={top[0][0][0]} "
          f"bm25={top[0][0][1]:.2f}; {ds['blocks_pruned']} blocks pruned / "
          f"{ds['blocks_scored']} scored, {ds['score_syncs']} per-round syncs "
          f"(exact parity with the host float oracle)")


if __name__ == "__main__":
    main()
