"""Compressed data-parallel training (paper technique -> collective term).

Runs the DIN recsys model on 8 host devices with the int8/int4 compressed
gradient all-reduce + error feedback, and compares the loss trajectory with
the uncompressed fp32 baseline — wire bytes drop 4x/8x, convergence matches.

  PYTHONPATH=src python examples/compressed_dp_training.py [--steps 30]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as PS  # noqa: E402

from repro.configs import din  # noqa: E402
from repro.models import recsys as R  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.runtime.trainer import make_compressed_dp_train_step  # noqa: E402


def make_batch(cfg, rng, b):
    hist = rng.integers(0, cfg.item_vocab, (b, cfg.seq_len))
    target = rng.integers(0, cfg.item_vocab, b)
    # learnable signal: label correlates with target id parity
    label = ((target % 2) ^ (rng.random(b) < 0.1)).astype(np.int32)
    return {
        "target_item": jnp.asarray(target, jnp.int32),
        "target_cate": jnp.asarray(target % cfg.cate_vocab, jnp.int32),
        "hist_items": jnp.asarray(hist, jnp.int32),
        "hist_cates": jnp.asarray(hist % cfg.cate_vocab, jnp.int32),
        "hist_len": jnp.asarray(rng.integers(5, cfg.seq_len, b), jnp.int32),
        "profile": jnp.asarray(rng.integers(0, cfg.profile_vocab, (b, cfg.n_profile)), jnp.int32),
        "label": jnp.asarray(label, jnp.int32),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = din.make_smoke_config()
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=args.steps, weight_decay=0.0)
    batch_specs = {k: PS("data") for k in make_batch(cfg, np.random.default_rng(0), 8)}

    results = {}
    for bits in (None, 8, 4):
        params = R.init(cfg, jax.random.PRNGKey(0))
        step, init_opt = make_compressed_dp_train_step(
            lambda p, b: R.loss_fn(p, b, cfg), ocfg, mesh, batch_specs,
            dp_axes=("data",), bits=bits)
        step = jax.jit(step)
        opt = init_opt(params)
        rng = np.random.default_rng(1)
        losses = []
        for s in range(args.steps):
            batch = make_batch(cfg, rng, args.batch)
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        results[bits] = losses
        tag = "fp32" if bits is None else f"int{bits}+EF"
        print(f"{tag:9}  loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    drop32 = results[None][0] - results[None][-1]
    drop8 = results[8][0] - results[8][-1]
    print(f"\nconvergence ratio int8/fp32: {drop8/max(drop32,1e-9):.2f} "
          f"(1.0 = identical); wire bytes: int8 4x lower, int4 8x lower")


if __name__ == "__main__":
    main()
