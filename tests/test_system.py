"""End-to-end system behaviour tests.

The full stack in one place: compressed token store -> fault-tolerant train
loop -> loss decreases; prefill/decode parity vs full forward; paper-claim
sanity (ratio orderings, SIMD-approach invariants); dry-run artifact
integrity (all 40 cells x 2 meshes compiled, zero failures, skips documented).
"""

import glob
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import codec as codec_lib
from repro.data import synth
from repro.data.pipeline import TokenStore, lm_batch_iter
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import train_loop as TL
from repro.runtime.trainer import make_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_on_compressed_pipeline_loss_decreases(tmp_path):
    cfg = T.LMConfig(name="sys", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                     head_dim=16, d_ff=128, vocab=512, dtype=jnp.float32,
                     q_chunk=16, kv_chunk=16, loss_chunk=16)
    rng = np.random.default_rng(0)
    base = rng.integers(0, 128, 40000).astype(np.uint32)
    toks = np.where(rng.random(40000) < 0.7, np.roll(base, 1) % 512, base)
    store = TokenStore.build(toks.astype(np.uint32), codec="group_simple")
    assert store.compressed_bytes() < store.raw_bytes

    params = T.init(cfg, jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30)
    step = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(p, b["tokens"], b["labels"], cfg), ocfg))
    loop = TL.LoopConfig(total_steps=30, ckpt_dir=str(tmp_path), ckpt_every=10,
                         log_every=1000)
    _, _, info = TL.run(step, params, adamw_init(params),
                        lm_batch_iter(store, 4, 32), loop, log_fn=lambda *a: None)
    losses = [m["loss"] for m in info["metrics"]]
    assert losses[-1] < losses[0], (losses[0], losses[-1])


@pytest.mark.parametrize("attn", ["gqa", "mla"])
def test_decode_matches_full_forward(attn):
    kw = dict(n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16, d_ff=128,
              vocab=256, dtype=jnp.float32, q_chunk=8, kv_chunk=8, loss_chunk=8)
    if attn == "mla":
        kw.update(attn="mla", kv_lora=32, qk_nope=16, qk_rope=8, v_head=16)
    cfg = T.LMConfig(name="parity", **kw)
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 256)
    logits_pf, cache = jax.jit(lambda p, t: T.prefill(p, t, cfg))(params, toks)
    nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)
    cache = {k: jnp.concatenate([v, jnp.zeros(v.shape[:2] + (8,) + v.shape[3:], v.dtype)], axis=2)
             for k, v in cache.items()}
    logits_d, _ = jax.jit(lambda p, c, t: T.decode_step(p, c, t, jnp.int32(32), cfg))(params, cache, nxt)
    toks33 = jnp.concatenate([toks, nxt[:, None]], 1)
    x, _, _ = jax.jit(lambda p, t: T.trunk(p, t, cfg))(params, toks33)
    full = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(x.dtype))
    np.testing.assert_allclose(np.asarray(full), np.asarray(logits_d), atol=2e-4)


def test_paper_ratio_orderings_hold():
    """Paper Table VIII relationships on the GOV2-like d-gap stream."""
    gaps = synth.concat_gaps(synth.make_dataset("gov2"))
    bits = {}
    for name in ("rice", "gamma", "group_scheme_1-CU", "varbyte", "gvb",
                 "g8iu", "group_scheme_8-IU", "simple9", "group_simple",
                 "packed_binary", "bp128", "pfordelta", "afor", "group_afor"):
        bits[name] = codec_lib.get(name).encode(gaps).bits_per_int
    # bit-aligned beat byte-aligned on d-gaps
    assert bits["rice"] < bits["varbyte"]
    assert bits["group_scheme_1-CU"] < bits["group_scheme_8-IU"]
    # GVB-family worst (paper: 9-10 bits); VB better than GVB
    assert bits["varbyte"] < bits["gvb"]
    # GSC-8-IU compresses better than G8IU (paper Table XI finding)
    assert bits["group_scheme_8-IU"] <= bits["g8iu"] + 0.3
    # group variants cost a little vs scalar counterparts (group-level max)
    assert bits["group_simple"] <= bits["simple9"] + 1.5
    assert bits["group_afor"] <= bits["afor"] + 1.5
    # BP128 has lower ratio than PFD/AFOR (paper: -15%-ish, i.e. bigger)
    assert bits["bp128"] >= bits["pfordelta"] - 0.2


def test_dryrun_artifacts_complete_and_green():
    files = glob.glob(os.path.join(ROOT, "experiments/dryrun", "*.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated in this checkout")
    recs = [json.load(open(f)) for f in files]
    keys = {(r["arch"], r["shape"], tuple(r["mesh"])) for r in recs}
    assert len(keys) == 80, len(keys)           # 40 cells x 2 meshes
    bad = [r for r in recs if r.get("status") not in ("ok", "skipped")]
    assert not bad, [(r["arch"], r["shape"]) for r in bad]
    skips = [r for r in recs if r.get("status") == "skipped"]
    assert len(skips) == 6                       # long_500k x 3 archs x 2 meshes
    for r in recs:
        if r["status"] == "ok":
            assert "census" in r and r["census"]["flops_per_chip"] >= 0
            assert "memory" in r or "cost" in r


def test_quadmax_group_bitwidth_invariant():
    """The Group approach's core invariant: every int in a quadruple fits the
    quad-max bit width (so the 4-way vertical layout loses no information)."""
    from repro.core.bits import ebw_np
    from repro.core.layout import quadmax_np, to_vertical_np
    rng = np.random.default_rng(3)
    x = np.minimum(rng.zipf(1.2, 4096), 2**31).astype(np.uint32)
    qm = quadmax_np(x, pseudo=True)
    v = to_vertical_np(x, 4)
    assert np.all(ebw_np(v) <= ebw_np(qm)[:, None])
