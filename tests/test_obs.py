"""Observability layer (``repro.obs``): span tracer integrity, the Chrome
trace-event export contract, the typed metrics registry (scoped sampling,
read-only ``dev_stats`` view, Prometheus exposition, nearest-rank
percentiles), and the perf-regression gate's self-test guarantees.

The trace-integrity tests drive REAL serve streams (host, device, fused and
a 2-shard engine) and assert the full admission -> done span chain, nesting
discipline, and that the exported JSON round-trips ``json.loads`` with the
documented schema."""

import json
import math

import numpy as np
import pytest

from repro.index.engine import QueryBatch, QueryEngine
from repro.index.invindex import InvertedIndex
from repro.index.serve import (Request, ServeConfig, ServerStats, TraceRecord,
                               serve_stream)
from repro.obs import (DevStatsView, MetricsRegistry, Span, Tracer,
                       enable_tracing, get_tracer, nearest_rank, regress,
                       to_chrome_trace, trace_coverage)

RNG = np.random.default_rng(91)
N_DOCS = 2500


def _corpus():
    doclen = RNG.integers(40, 300, N_DOCS).astype(np.int64)
    postings = {}
    for t, df in enumerate([60, 200, 450, 800, 300, 120]):
        ids = np.sort(RNG.choice(N_DOCS, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, RNG.geometric(0.4, df).astype(np.uint32))
    return doclen, postings


DOCLEN, POSTINGS = _corpus()


def _engine(device=False, fused=False):
    idx = InvertedIndex.build(DOCLEN, POSTINGS)
    eng = QueryEngine(idx)
    return eng.to_device(fused=fused) if device or fused else eng


def _serve(engine, n=6, **cfg_kw):
    cfg_kw.setdefault("max_batch", 4)
    cfg_kw.setdefault("max_wait_ms", 2.0)
    cfg_kw.setdefault("warm_terms", 4)
    reqs = [Request([t % 4, (t + 1) % 4], deadline_ms=2000) for t in range(n)]
    return serve_stream(engine, reqs, np.zeros(n), ServeConfig(**cfg_kw))


# --------------------------------------------------------------------------- #
# tracer primitives
# --------------------------------------------------------------------------- #

def test_span_nesting_and_monotone_clocks():
    tr = Tracer(enabled=True)
    with tr.span("outer", lane="t") as outer:
        with tr.span("inner", lane="t", r=1) as inner:
            pass
    spans = {s.name: s for s in tr.spans()}
    assert spans["inner"].parent_sid == spans["outer"].sid
    assert spans["outer"].parent_sid == 0
    for s in spans.values():
        assert s.t1 >= s.t0
    # children are bracketed by their parent
    assert spans["outer"].t0 <= spans["inner"].t0
    assert spans["inner"].t1 <= spans["outer"].t1
    assert spans["inner"].args == {"r": 1}
    assert inner.sid != outer.sid


def test_disabled_tracer_is_noop_and_none_safe():
    tr = Tracer(enabled=False)
    with tr.span("x", lane="t") as sp:
        assert sp is None
    sp = tr.begin("y")
    assert sp is None
    tr.end(sp)                      # None-safe
    tr.fence(object())              # no-op when disabled
    assert tr.spans() == []


def test_detached_begin_end_with_explicit_stamps():
    tr = Tracer(enabled=True)
    sp = tr.begin("detached", lane="t", t0=10.0, rid=3)
    assert sp.t1 is None and sp.dur == 0.0
    tr.end(sp, t1=12.5, outcome="done")
    assert (sp.t0, sp.t1) == (10.0, 12.5)
    assert sp.args == {"rid": 3, "outcome": "done"}
    assert tr.spans() == [sp]


def test_span_buffer_bounded():
    tr = Tracer(enabled=True, max_spans=3)
    for i in range(5):
        with tr.span(f"s{i}", lane="t"):
            pass
    assert len(tr.spans()) == 3 and tr.dropped == 2
    tr.clear()
    assert tr.spans() == [] and tr.dropped == 0


def test_global_tracer_toggle():
    tr = get_tracer()
    assert tr.enabled is False      # engine/kernel spans off by default
    enable_tracing(True)
    try:
        assert get_tracer().enabled is True
    finally:
        enable_tracing(False)
        get_tracer().clear()


# --------------------------------------------------------------------------- #
# chrome trace export (documented schema)
# --------------------------------------------------------------------------- #

def test_chrome_trace_round_trips_with_schema():
    tr = Tracer(enabled=True)
    with tr.span("serve/batch", lane="serve", nq=2):
        with tr.span("serve/plan", lane="serve"):
            pass
    blob = json.dumps(to_chrome_trace(tr))
    doc = json.loads(blob)
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in meta} == {"process_name", "thread_name"}
    assert len(spans) == 2
    for e in spans:
        assert e["pid"] == 1 and e["tid"] >= 1
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["cat"] == e["name"].split("/", 1)[0]
        assert {"sid", "parent_sid"} <= set(e["args"])
    by_name = {e["name"]: e for e in spans}
    assert (by_name["serve/plan"]["args"]["parent_sid"]
            == by_name["serve/batch"]["args"]["sid"])
    # lane -> named thread track
    lanes = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert lanes == {"serve"}


def test_chrome_trace_merges_multiple_sources():
    a, b = Tracer(enabled=True), Tracer(enabled=True)
    with a.span("x", lane="la"):
        pass
    with b.span("y", lane="lb"):
        pass
    doc = to_chrome_trace(a, b)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert names == {"x", "y"}


def test_trace_coverage_math():
    tr = Tracer(enabled=True)
    b = tr.begin("serve/batch", lane="serve", t0=0.0)
    tr.end(b, t1=10.0)
    c = tr.begin("serve/plan", lane="serve", parent=b, t0=0.0)
    tr.end(c, t1=4.0)
    assert trace_coverage(tr.spans()) == pytest.approx(0.4)
    # unrelated spans don't count
    d = tr.begin("serve/plan", lane="serve", t0=0.0)     # no parent
    tr.end(d, t1=10.0)
    assert trace_coverage(tr.spans()) == pytest.approx(0.4)


# --------------------------------------------------------------------------- #
# trace integrity on real serve streams
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("placement", ["host", "device", "fused"])
def test_full_span_chain_per_placement(placement):
    engine = _engine(device=True, fused=(placement == "fused"))
    results, stats = _serve(engine, n=6, placement=placement)
    assert stats.served == 6
    spans = stats.tracer.spans()
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s)
    # the full chain: every request spans admission -> done; every batch has
    # plan/execute/deliver children that tile it exactly
    assert len(by_name["serve/request"]) == 6
    assert len(by_name["serve/batch"]) == len(stats.batches)
    batches = {s.sid: s for s in by_name["serve/batch"]}
    for child in ("serve/plan", "serve/execute", "serve/deliver"):
        assert {c.parent_sid for c in by_name[child]} == set(batches)
    assert trace_coverage(spans) >= 0.9
    # TraceRecord stamps are a view over the same spans
    req = {s.args["rid"]: s for s in by_name["serve/request"]}
    for tr in stats.traces:
        assert tr.outcome == "served"
        s = req[tr.rid]
        assert s.t0 == tr.t_enqueue and s.t1 == tr.t_done
        assert s.args["outcome"] == "served"
        stamps = tr.stages()
        assert all(b >= a for a, b in zip(stamps, stamps[1:]))
    for b in stats.batches:
        bs = next(s for s in by_name["serve/batch"]
                  if s.args["bid"] == b.batch_id)
        assert bs.t0 == b.t_close and bs.t1 == b.t_done


def test_span_chain_two_shard_engine():
    engine = _engine()
    # explicit bounds: derived mass-balanced splits collapse to one shard
    # on a corpus this small
    engine.to_device(fused=True, bounds=(0, N_DOCS // 2, N_DOCS))
    enable_tracing(True)
    try:
        get_tracer().clear()
        results, stats = _serve(engine, n=4, placement="device")
        deep = get_tracer().spans()
    finally:
        enable_tracing(False)
        get_tracer().clear()
    assert stats.served == 4
    lanes = {s.lane for s in deep}
    assert {"shard0", "shard1"} <= lanes
    # the export merges server + engine tracers and keeps one track per lane
    doc = to_chrome_trace(stats.tracer, deep)
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"serve", "shard0", "shard1"} <= tracks
    json.loads(json.dumps(doc))


def test_rejected_and_shed_requests_close_their_spans():
    engine = _engine()
    reqs = [Request([0, 1], deadline_ms=0),          # rejected at enqueue
            Request([0, 1], deadline_ms=2000)]
    results, stats = serve_stream(
        engine, reqs, np.zeros(2),
        ServeConfig(max_batch=4, max_wait_ms=2.0, warm_terms=2))
    outcomes = {s.args["rid"]: s.args["outcome"]
                for s in stats.tracer.spans() if s.name == "serve/request"}
    assert outcomes[0] == "rejected_expired"
    assert outcomes[1] == "served"
    assert all(s.t1 is not None for s in stats.tracer.spans())


def test_engine_spans_disabled_by_default():
    engine = _engine(device=True)
    get_tracer().clear()
    engine.execute(engine.plan(QueryBatch([[0, 1]]), placement="device"))
    assert get_tracer().spans() == []


# --------------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------------- #

def test_registry_duplicate_and_label_vocabulary():
    reg = MetricsRegistry(namespace="t")
    reg.counter("a_counter")
    with pytest.raises(ValueError):
        reg.counter("a_counter")
    with pytest.raises(ValueError):
        reg.counter("bad", labelnames=("nope",))
    with pytest.raises(ValueError):
        MetricsRegistry(const_labels={"nope": "x"})
    with pytest.raises(ValueError):
        reg.get("a_counter").inc(-1)


def test_scoped_sampling_deltas():
    eng = _engine(device=True)
    eng.execute(eng.plan(QueryBatch([[0, 1]]), placement="device"))
    with eng.metrics.scoped() as s:
        eng.execute(eng.plan(QueryBatch([[0, 1]]), placement="device"))
    # the work-list decode already happened in the priming batch: the scoped
    # delta isolates the second batch without hand-rolled subtraction
    assert s.delta("worklist_decodes") == 0
    assert s.delta("resident_rounds") >= 1
    with pytest.raises(KeyError):
        s.delta("no_such_counter")
    assert s.deltas()["final_syncs"] == 1


def test_dev_stats_view_read_only_live():
    eng = _engine(device=True)
    assert eng.dev_stats["worklist_decodes"] == 0
    eng.execute(eng.plan(QueryBatch([[0, 1]]), placement="device"))
    assert eng.dev_stats["worklist_decodes"] >= 1
    assert set(eng.dev_stats) == set(dict(eng.dev_stats))
    with pytest.raises(TypeError):
        eng.dev_stats["worklist_decodes"] = 0
    with pytest.raises(KeyError):
        eng.dev_stats["not_a_counter"]
    assert isinstance(eng.dev_stats, DevStatsView)


def test_prometheus_exposition_format():
    reg = MetricsRegistry(namespace="t", const_labels={"engine": "q0"})
    reg.counter("reqs", "requests", labelnames=("outcome",))
    reg.inc("reqs", outcome="served")
    reg.inc("reqs", 2, outcome="shed")
    reg.gauge("warm", "warmup").set(1.5)
    reg.histogram("lat", "latency", buckets=(1.0, 10.0, float("inf")))
    reg.get("lat").observe(0.5)
    reg.get("lat").observe(5.0)
    text = reg.to_prometheus()
    assert "# TYPE t_reqs counter" in text
    assert 't_reqs{engine="q0",outcome="served"} 1' in text
    assert 't_reqs{engine="q0",outcome="shed"} 2' in text
    assert 't_warm{engine="q0"} 1.5' in text
    assert 't_lat_bucket{engine="q0",le="1"} 1' in text
    assert 't_lat_bucket{engine="q0",le="10"} 2' in text
    assert 't_lat_bucket{engine="q0",le="+Inf"} 2' in text
    assert 't_lat_count{engine="q0"} 2' in text


def test_server_stats_prometheus_snapshot():
    results, stats = _serve(_engine(), n=3)
    snap = stats.snapshot(prometheus=True)
    assert "repro_serve_requests_total" in snap["prometheus"]
    assert 'outcome="served"' in snap["prometheus"]
    assert "prometheus" not in stats.snapshot()     # opt-in only


def test_engine_registries_independent_and_labelled():
    a, b = _engine(), _engine()
    a.metrics.inc("worklist_refs", 5)
    assert b.dev_stats["worklist_refs"] == 0
    assert a.metrics.const_labels["engine"] != b.metrics.const_labels["engine"]
    assert a.metrics.schema() == b.metrics.schema()


# --------------------------------------------------------------------------- #
# nearest-rank percentiles
# --------------------------------------------------------------------------- #

def test_nearest_rank_rule():
    # n == 1: the single sample for every q
    assert nearest_rank([7.0], 50) == 7.0
    assert nearest_rank([7.0], 99.9) == 7.0
    # n == 2: p50 -> first, p99/p999 -> second; monotone in q
    assert nearest_rank([1.0, 9.0], 50) == 1.0
    assert nearest_rank([1.0, 9.0], 99) == 9.0
    assert nearest_rank([1.0, 9.0], 99.9) == 9.0
    # n == 10: ceil(q/100 * 10) ranks, never interpolated
    vals = [float(i) for i in range(1, 11)]
    assert nearest_rank(vals, 50) == 5.0
    assert nearest_rank(vals, 99) == 10.0
    assert nearest_rank(vals, 10) == 1.0
    assert nearest_rank(vals, 100) == 10.0
    qs = [1, 10, 50, 90, 99, 99.9]
    got = [nearest_rank(vals, q) for q in qs]
    assert got == sorted(got)
    with pytest.raises(ValueError):
        nearest_rank([], 50)


def test_snapshot_percentiles_tiny_n():
    for n in (1, 2, 10):
        stats = ServerStats()
        for i in range(n):
            stats.record(TraceRecord(
                i, "t", "and", 10, "served", deadline=1e9,
                t_enqueue=0.0, t_close=0.0, t_plan=0.0, t_execute=0.0,
                t_done=(i + 1) * 1e-3, on_time=True))
        lat = sorted((i + 1.0) for i in range(n))
        pct = stats.snapshot()["latency_ms"]
        for name, q in (("p50", 50.0), ("p99", 99.0), ("p999", 99.9)):
            r = min(max(math.ceil(q / 100.0 * n), 1), n)
            assert pct[name] == pytest.approx(lat[r - 1])
        assert pct["p50"] <= pct["p99"] <= pct["p999"] == pct["max"]


# --------------------------------------------------------------------------- #
# the regression gate
# --------------------------------------------------------------------------- #

_QUERY_REPORT = {
    "dataset": "gov2", "codec": "group_simple", "backend": "cpu",
    "n_queries": 20,
    "host_qps": {"1": 100.0, "16": 400.0},
    "decodes_per_hot_block": 1.0,
    "placements": {"device": {"host_syncs_per_query": 0},
                   "fused": {"host_syncs_per_query": 0}},
    "ranked": {"or": {"qps": {"host": 50.0}, "host_syncs_per_query": 0,
                      "blocks_pruned": 12}},
}


def test_gate_identity_passes_and_2x_regression_fails():
    tol = regress.load_tolerances(None)
    v, n = regress.compare_reports("query", _QUERY_REPORT, _QUERY_REPORT, tol)
    assert not v and n == 3          # host_qps x2 + ranked or qps
    bad = regress.synthesize_regression(_QUERY_REPORT, factor=0.5)
    assert bad["host_qps"]["1"] == 50.0
    assert bad["decodes_per_hot_block"] == 1.0       # non-qps leaf untouched
    assert bad["ranked"]["or"]["blocks_pruned"] == 12
    v, _ = regress.compare_reports("query", bad, _QUERY_REPORT, tol)
    assert len(v) == 3 and all(x.kind == "ratio" for x in v)


def test_gate_min_ratio_override_and_disable():
    tol = {"defaults": {"min_ratio": 0.55},
           "overrides": [{"artifact": "query", "pattern": "host_qps.*",
                          "min_ratio": 0}]}
    bad = regress.synthesize_regression(_QUERY_REPORT, factor=0.5)
    v, n = regress.compare_reports("query", bad, _QUERY_REPORT, tol)
    paths = {x.path for x in v}
    assert paths == {"ranked.or.qps.host"}           # host_qps ungated
    assert n == 1


def test_gate_workload_stamp_mismatch_refuses():
    other = dict(_QUERY_REPORT, n_queries=256)
    v = regress.check_workload(
        "query", ("dataset", "codec", "backend", "n_queries"),
        other, _QUERY_REPORT)
    assert len(v) == 1 and v[0].kind == "workload" and v[0].path == "n_queries"


def test_gate_hard_invariants():
    ok, n = regress.check_invariants("query", _QUERY_REPORT)
    assert not ok and n >= 4
    broken = json.loads(json.dumps(_QUERY_REPORT))
    broken["placements"]["device"]["host_syncs_per_query"] = 3
    broken["ranked"]["or"]["blocks_pruned"] = 0
    v, _ = regress.check_invariants("query", broken)
    assert {x.path for x in v} == {"placements.device.host_syncs_per_query",
                                   "ranked.or.blocks_pruned"}
    mut = {"tombstone_qps": {"0.01": {"cand_syncs": 0, "qps": 5.0}},
           "ranked_tomb_1pct": {"score_syncs": 0, "blocks_pruned": 3}}
    v, _ = regress.check_invariants("mutation", mut)
    assert not v
    mut["ranked_tomb_1pct"]["blocks_pruned"] = 0
    v, _ = regress.check_invariants("mutation", mut)
    assert [x.path for x in v] == ["ranked_tomb_1pct.blocks_pruned"]
    srv = {"arrivals": {"poisson": {"host": {"shed_rate": 0.0,
                                             "parity_ok": True}},
                        "bursty": {"host": {"shed_rate": 0.25,
                                            "parity_ok": False}}}}
    v, _ = regress.check_invariants("serving", srv)
    # bursty shed is allowed (overload by design); bursty parity is not
    assert [x.path for x in v] == ["arrivals.bursty.host.parity_ok"]


def test_gate_missing_fresh_report_is_a_violation(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir()
    fresh.mkdir()
    (base / "BENCH_query.json").write_text(json.dumps(_QUERY_REPORT))
    res = regress.run_gate(str(fresh), str(base))
    assert not res.passed
    assert res.violations[0].kind == "workload"
    # with the fresh report present, identity passes end to end
    (fresh / "BENCH_query.json").write_text(json.dumps(_QUERY_REPORT))
    res = regress.run_gate(str(fresh), str(base))
    assert res.passed and res.checked_ratios == 3


def test_committed_tolerances_keep_selftest_teeth():
    """The committed floors must stay in (0.5, 1.0] or the CI self-test's
    synthetic 2x regression would slip through."""
    import os
    tol = regress.load_tolerances(
        os.path.join(os.path.dirname(__file__), "..",
                     regress.TOLERANCES_FILE))
    floors = [float(tol["defaults"]["min_ratio"])]
    floors += [float(ov["min_ratio"]) for ov in tol["overrides"]
               if float(ov.get("min_ratio", 1)) > 0]
    assert all(0.5 < f <= 1.0 for f in floors), floors
