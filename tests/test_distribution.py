"""Distribution-layer tests on 8 forced host devices (subprocess isolation so
the rest of the suite keeps a single device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> str:
    code = "import os\nos.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_compressed_allreduce_matches_pmean():
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as PS
    from repro.distributed import collectives as C
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh((8,), ('dp',))
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8*5000,)).astype(np.float32))
    from jax.experimental.shard_map import shard_map
    def f(xl):
        red, ef = C.compressed_allreduce_flat(xl.reshape(-1), ('dp',), bits=8)
        return red, ef
    red, ef = jax.jit(shard_map(f, mesh=mesh, in_specs=PS('dp'),
                                out_specs=(PS(None), PS('dp')), check_rep=False))(x)
    exact = np.mean(np.asarray(x).reshape(8, 5000), axis=0)
    err = np.abs(np.asarray(red)[:5000] - exact)
    assert err.max() < 0.05 * (np.abs(exact).max() + 1e-6), err.max()
    print('OK', err.max())
    """)
    assert "OK" in out


def test_sharded_lm_forward_matches_single_device():
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smollm_135m
    from repro.distributed import sharding as shlib
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    cfg = smollm_135m.make_smoke_config()
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (4, 32)), jnp.int32)
    ref, _ = jax.jit(lambda p, t: T.loss_fn(p, t[:, :-1], t[:, 1:], cfg))(params, toks)
    mesh = make_host_mesh((4, 2), ('data', 'model'))
    plan = shlib.lm_dense_plan()
    with shlib.activate(mesh, plan):
        sh, _ = jax.jit(lambda p, t: T.loss_fn(p, t[:, :-1], t[:, 1:], cfg))(params, toks)
    assert abs(float(ref) - float(sh)) < 1e-4, (float(ref), float(sh))
    print('OK', float(ref), float(sh))
    """)
    assert "OK" in out


def test_embedding_ep_lookup_matches_plain():
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed import sharding as shlib
    from repro.launch.mesh import make_host_mesh
    from repro.models import embedding as E
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(0, 1, (64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (16, 5)), jnp.int32)
    ref = np.asarray(jnp.take(table, ids, axis=0))
    mesh = make_host_mesh((2, 4), ('data', 'model'))
    with shlib.activate(mesh, shlib.recsys_plan()):
        got = np.asarray(jax.jit(E.lookup)(table, ids))
    np.testing.assert_allclose(got, ref, rtol=1e-6)
    # stacked
    tables = jnp.asarray(rng.normal(0, 1, (3, 64, 8)).astype(np.float32))
    ids2 = jnp.asarray(rng.integers(0, 64, (16, 3)), jnp.int32)
    ref2 = np.stack([np.asarray(tables[t])[np.asarray(ids2)[:, t]] for t in range(3)], axis=1)
    with shlib.activate(mesh, shlib.recsys_plan()):
        got2 = np.asarray(jax.jit(E.lookup_stacked)(tables, ids2))
    np.testing.assert_allclose(got2, ref2, rtol=1e-6)
    print('OK')
    """)
    assert "OK" in out


def test_checkpoint_reshard_elastic():
    out = run_py("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro.checkpoint import Checkpointer
    from repro.launch.mesh import make_host_mesh
    mesh8 = make_host_mesh((8, 1), ('data', 'model'))
    mesh4 = make_host_mesh((4, 1), ('data', 'model'))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    x8 = jax.device_put(x, NamedSharding(mesh8, PS('data', None)))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, {'x': x8}, {'cursor': 5})
        tmpl = {'x': jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        sh = {'x': NamedSharding(mesh4, PS('data', None))}
        state, step, extra = ck.restore(tmpl, shardings=sh)
        assert extra['cursor'] == 5 and step == 1
        np.testing.assert_array_equal(np.asarray(state['x']), np.asarray(x))
        assert state['x'].sharding.mesh.shape['data'] == 4
    print('OK elastic reshard 8->4 devices')
    """)
    assert "OK" in out
