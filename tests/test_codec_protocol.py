"""Registry-wide conformance sweep for the Codec protocol v2.

Every registered codec must round-trip adversarial inputs (empty, single
value, the 2**max_bits - 1 boundary, and 512-block-boundary lengths) through
``decode_np`` and, where declared, through the JAX (``JaxDecode``) and
device-arena (``ArenaLayout``) entry points — and the capability
*declarations* must match actual behavior (alias coherence, padded-width
contracts, zero padding past ``n_valid``)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import codec

RNG = np.random.default_rng(7)

ALL = codec.names()


def _cases(max_bits: int) -> dict:
    top = 2 ** max_bits - 1
    return {
        "empty": np.zeros(0, np.uint32),
        "single": np.array([7], np.uint32),
        "single_max": np.array([top], np.uint32),
        "max_bits_boundary": np.full(130, top, np.uint32),
        "block_511": RNG.integers(0, 1 << 16, 511, dtype=np.int64).astype(np.uint32),
        "block_512": RNG.integers(0, 1 << 16, 512, dtype=np.int64).astype(np.uint32),
        "block_513": RNG.integers(0, 1 << 16, 513, dtype=np.int64).astype(np.uint32),
    }


def _arena_roundtrip(spec, x: np.ndarray) -> None:
    """Decode one encoded block through the declared ArenaLayout exactly the
    way ``repro.index.device`` does: one padded fixed-shape slice per
    declared column plus dynamic per-column lengths."""
    lay = spec.arena
    enc = spec.encode(x)
    slices, lens = [], []
    for col in lay.columns:
        words = np.asarray(col.extract(enc), col.dtype).reshape(-1)
        # declared padded maxima actually bound the block's words
        assert words.size <= col.width, (spec.name, col.name, words.size,
                                         col.width)
        padded = np.zeros(col.width, col.dtype)
        padded[: words.size] = words
        slices.append(jnp.asarray(padded))
        lens.append(jnp.int32(words.size))
    out = np.asarray(lay.decode_block(*slices, *lens, jnp.int32(enc.n)))
    assert out.shape == (lay.out_width,), (spec.name, out.shape)
    np.testing.assert_array_equal(out[: enc.n], x, err_msg=f"{spec.name}/arena")
    assert not out[enc.n:].any(), f"{spec.name}: arena decode not zero-padded"


@pytest.mark.parametrize("name", ALL)
def test_conformance_sweep(name):
    spec = codec.get(name)
    for case, x in _cases(spec.max_bits).items():
        enc = spec.encode(x)
        assert enc.n == len(x)
        np.testing.assert_array_equal(spec.decode_np(enc), x,
                                      err_msg=f"{name}/{case}/decode_np")
        if spec.jax is not None and enc.n:
            args = spec.jax.args(enc)
            np.testing.assert_array_equal(np.asarray(spec.jax.vec(**args)), x,
                                          err_msg=f"{name}/{case}/jax.vec")
            np.testing.assert_array_equal(np.asarray(spec.jax.scalar(**args)), x,
                                          err_msg=f"{name}/{case}/jax.scalar")
        if spec.arena is not None and 0 < enc.n <= spec.arena.max_n:
            _arena_roundtrip(spec, x)


@pytest.mark.parametrize("name", ALL)
def test_capability_declarations_match_behavior(name):
    spec = codec.get(name)
    # required protocol surface
    assert spec.name == name and callable(spec.encode) and callable(spec.decode_np)
    assert spec.category in ("bit", "byte", "word", "frame")
    assert 1 <= spec.max_bits <= 32
    # v1 alias coherence: the deprecated attributes mirror the capabilities
    assert spec.decode is spec.decode_np
    if spec.jax is None:
        assert spec.jax_args is None
        assert spec.decode_jax_scalar is None and spec.decode_jax_vec is None
    else:
        assert spec.jax_args is spec.jax.args
        assert spec.decode_jax_scalar is spec.jax.scalar
        assert spec.decode_jax_vec is spec.jax.vec
    if spec.arena is not None:
        lay = spec.arena
        assert len(lay.columns) >= 2
        assert all(c.width > 0 and c.name and callable(c.extract)
                   for c in lay.columns)
        # the 2-column alias surface stays coherent with the columns
        assert lay.ctrl_width == lay.columns[0].width
        assert lay.data_width == lay.columns[1].width
        assert lay.block_ctrl is lay.columns[0].extract
        assert lay.block_data is lay.columns[1].extract
        assert lay.out_width >= lay.max_n > 0
        assert callable(lay.decode_block)
        assert callable(lay.supports)
        # the declared layout accepts this codec's own encodings
        assert lay.supports(spec.encode(np.arange(20, dtype=np.uint32)))
        # a codec that stores exceptions must give them a declared column
        probe = np.arange(40, dtype=np.uint32) % 13
        probe[::17] = np.uint32(2 ** min(spec.max_bits, 32) - 1)
        enc = spec.encode(probe)
        if enc.exceptions is not None and len(enc.exceptions):
            assert any(c.name == "exceptions" for c in lay.columns), spec.name


def test_bp_arena_supports_guards_frame_layout():
    """A block encoded at a frame size other than the layout's falls outside
    the declared capability (it would decode silently wrong on the fixed
    shapes) and must report unsupported -> host oracle fallback."""
    from repro.core import bp128
    x = np.arange(300, dtype=np.uint32)
    bp = codec.get("bp128")
    gpb = codec.get("g_packed_binary")
    assert bp.arena.supports(bp.encode(x))
    assert gpb.arena.supports(gpb.encode(x))
    alien = bp128.encode(x, frame_quads=64)     # same codec name, other layout
    assert not bp.arena.supports(alien)
    assert not gpb.arena.supports(bp.encode(x))  # fq=32 block vs fq=128 layout


def test_get_unknown_codec_lists_names_and_suggests():
    with pytest.raises(KeyError) as ei:
        codec.get("group_simpel")
    msg = str(ei.value)
    assert "group_simple" in msg            # nearest-name suggestion
    assert "registered codecs:" in msg
    for name in codec.names():
        assert name in msg
    with pytest.raises(KeyError):
        codec.get("definitely_not_a_codec_xyz")


def test_names_is_deterministically_sorted():
    assert codec.names() == sorted(codec.names())
    assert codec.names() == codec.names()
    assert set(codec.names(group_only=True)) <= set(codec.names())
    for n in codec.names(category="frame"):
        assert codec.get(n).category == "frame"
    # the short-list fast path and both ISSUE-3 arena graduates declare arenas
    for n in ("stream_vbyte", "group_scheme_8-B", "group_scheme_8-IU"):
        assert codec.get(n).arena is not None, n
