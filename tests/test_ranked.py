"""Ranked retrieval (OR / and_scored) acceptance: quantized score arenas +
device-resident block-max top-k must match the host float-BM25 oracle — same
doc set, same scores, docid-tiebreak order — across host/device/fused
placements on >= 3 arena codecs including an exception-bearing one, with zero
per-round host syncs on the device ranked path; plus the ScoreArena
quantization contract (floor codes, consistent block-max/term-max/stripe
tables, sound theta0) and the Pallas score-unpack tile."""

import heapq

import numpy as np
import pytest

from repro.core import codec
from repro.index.engine import QueryBatch, QueryEngine
from repro.index.invindex import InvertedIndex
from repro.index import scores as scores_lib
from repro.index.scores import ScoreArena, bm25_scores, topk_select, unpack_words_np
from repro.kernels import topk as topk_kern

# three arena codecs incl. the exception-bearing PFD family (acceptance)
RANKED_CODECS = ["group_simple", "stream_vbyte", "group_pfd"]
assert all(codec.get(n).arena is not None for n in RANKED_CODECS)

RNG = np.random.default_rng(2024)
N_DOCS = 3000


def _corpus(heavy=False, ties=False):
    rng = np.random.default_rng(7 if heavy else (9 if ties else 5))
    n_docs = 40_000 if heavy else N_DOCS    # heavy gaps need docid headroom
    postings = {}
    dfs = [15, 40, 64, 300, 511, 512, 700, 1200, 900, 150]
    for t, df in enumerate(dfs):
        if heavy:
            gaps = rng.integers(1, 4, df).astype(np.int64)
            gaps[rng.random(df) < 0.03] += rng.integers(1 << 8, 1 << 10)
            ids = np.cumsum(gaps).astype(np.uint32)
            assert int(ids[-1]) < n_docs
        else:
            ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        tfs = (np.ones(df, np.uint32) if ties
               else rng.geometric(0.4, df).astype(np.uint32))
        postings[t] = (ids, tfs)
    doclen = (np.full(n_docs, 120, np.int64) if ties
              else rng.integers(60, 400, n_docs).astype(np.int64))
    return doclen, postings


DOCLEN, POSTINGS = _corpus()
# term 10: rare AND docid-clustered (topical locality) — the shape that lets
# block-max pruning drop the common terms' blocks outside the cluster
POSTINGS[10] = (np.sort(RNG.choice(256, 20, replace=False)).astype(np.uint32),
                RNG.geometric(0.4, 20).astype(np.uint32))
HDOCLEN, HPOSTINGS = _corpus(heavy=True)
TDOCLEN, TPOSTINGS = _corpus(ties=True)

QUERIES = ([RNG.choice(10, size=int(RNG.integers(2, 5)), replace=False).tolist()
            for _ in range(16)]
           + [[0, 7],                   # rare + common (the WAND shape)
              [3], [5],                 # single term
              [0, 999],                 # unknown term ignored
              [999], []])               # all-unknown / empty


def brute_or_topk(doclen, postings, n_docs, terms, k):
    avdl = doclen.mean()
    acc = {}
    for t in terms:
        if t not in postings:
            continue
        ids, tfs = postings[t]
        sc = bm25_scores(tfs, doclen[ids], len(ids), n_docs, avdl)
        for d, s in zip(ids.tolist(), sc.tolist()):
            acc[d] = acc.get(d, 0.0) + s
    return heapq.nsmallest(k, acc.items(), key=lambda kv: (-kv[1], kv[0]))


@pytest.mark.parametrize("name", RANKED_CODECS)
def test_ranked_placement_parity_and_float_oracle(name):
    """Acceptance: or/and_scored top-k identical (docids, float scores,
    order) across host, device, and fused placements, and the OR results
    match an independent brute-force float oracle with docid tie-break."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
    host = QueryEngine(idx)
    for mode in ("or", "and_scored"):
        want = host.execute(QueryBatch(QUERIES, mode=mode, k=7))
        for fused in (False, True):
            eng = QueryEngine(idx).to_device(fused=fused)
            got = eng.execute(eng.plan(QueryBatch(QUERIES, mode=mode, k=7)))
            assert want == got, (name, mode, fused)
    for q, res in zip(QUERIES, host.execute(QueryBatch(QUERIES, mode="or", k=7))):
        oracle = brute_or_topk(DOCLEN, POSTINGS, N_DOCS, q, 7)
        assert [(d, pytest.approx(s, rel=1e-12)) for d, s in oracle] == res, q


@pytest.mark.parametrize("name", RANKED_CODECS)
def test_ranked_heavy_tail_exception_corpus(name):
    """Exception-bearing blocks (PFD patch streams on the heavy-tailed
    corpus) flow through the score path with exact parity."""
    idx = InvertedIndex.build(HDOCLEN, HPOSTINGS, codec=name)
    if name == "group_pfd":
        assert any(encg.exceptions is not None and len(encg.exceptions)
                   for tp in idx.terms.values()
                   for _, encg, _ in tp.blocks), "corpus exercises no exceptions"
    host = QueryEngine(idx)
    for mode in ("or", "and_scored"):
        want = host.execute(QueryBatch(QUERIES, mode=mode, k=9))
        for fused in (False, True):
            eng = QueryEngine(idx).to_device(fused=fused)
            got = eng.execute(eng.plan(QueryBatch(QUERIES, mode=mode, k=9)))
            assert want == got, (name, mode, fused)


def test_ranked_quantization_ties_docid_tiebreak():
    """All-equal TFs and flat doclens collapse most quantized sums into
    ties: the margin + rescore contract must still reproduce the float
    oracle's docid-tiebreak order exactly."""
    idx = InvertedIndex.build(TDOCLEN, TPOSTINGS, codec="group_simple")
    host = QueryEngine(idx)
    for mode in ("or", "and_scored"):
        want = host.execute(QueryBatch(QUERIES, mode=mode, k=11))
        eng = QueryEngine(idx).to_device()
        got = eng.execute(eng.plan(QueryBatch(QUERIES, mode=mode, k=11)))
        assert want == got, mode


def test_ranked_device_path_zero_per_round_syncs():
    """Acceptance: the resident ranked path accumulates scores across >= 2
    device rounds with zero per-round host syncs — the only download is the
    single final candidate bitmap per batch."""
    queries = [q for q in QUERIES if len([t for t in q if t in POSTINGS]) >= 2]
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    for fused in (False, True):
        for mode, final in (("or", 1), ("and_scored", 1)):
            eng = QueryEngine(idx).to_device(fused=fused)
            eng.execute(eng.plan(QueryBatch(queries, mode=mode, k=5)))
            assert eng.dev_stats["score_rounds"] >= 2
            assert eng.dev_stats["score_syncs"] == 0
            assert eng.dev_stats["cand_syncs"] == 0
            assert eng.dev_stats["final_syncs"] == final, (mode, fused)
            assert eng.dev_stats["blocks_scored"] > 0
            if fused:
                assert eng.arena.stats["fused_calls"] > 0


def test_or_blockmax_pruning_fires_and_stays_exact():
    """The rare-clustered + common query shape prunes (term, block)
    work-list entries by upper bound, and pruned execution is still bitwise
    exact."""
    queries = [[10, 7], [10, 3], [10, 7, 5]] * 4
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    want = QueryEngine(idx).execute(QueryBatch(queries, mode="or", k=5))
    eng = QueryEngine(idx).to_device()
    got = eng.execute(eng.plan(QueryBatch(queries, mode="or", k=5)))
    assert want == got
    assert eng.dev_stats["blocks_pruned"] > 0
    assert eng.dev_stats["blocks_scored"] > 0


def test_zero_posting_term_in_ranked_queries_on_device():
    """A term present in the index with zero postings must score 0 and not
    crash the ranked device path (regression: the block-lazy rescore indexed
    an empty skip table)."""
    postings = dict(POSTINGS)
    postings[99] = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    idx = InvertedIndex.build(DOCLEN, postings, codec="group_simple")
    host = QueryEngine(idx)
    queries = [[99, 3, 7], [99], [3, 99, 5]]
    for mode in ("or", "and_scored"):
        want = host.execute(QueryBatch(queries, mode=mode, k=5))
        for fused in (False, True):
            eng = QueryEngine(idx).to_device(fused=fused)
            got = eng.execute(eng.plan(QueryBatch(queries, mode=mode, k=5)))
            assert want == got, (mode, fused)
    assert host.execute(QueryBatch([[99]], mode="or", k=5)) == [[]]


def test_ranked_eviction_pressure_stays_exact():
    idx = InvertedIndex.build(HDOCLEN, HPOSTINGS, codec="group_pfd")
    host = QueryEngine(idx)
    tiny = QueryEngine(idx, cache_blocks=2, cache_score_terms=1).to_device()
    for mode in ("or", "and_scored"):
        want = host.execute(QueryBatch(QUERIES, mode=mode, k=6))
        got = tiny.execute(tiny.plan(QueryBatch(QUERIES, mode=mode, k=6)))
        assert want == got, mode
    assert tiny.cache.evictions > 0


# --------------------------------------------------------------------------- #
# ScoreArena quantization contract
# --------------------------------------------------------------------------- #


def test_score_arena_tables_consistent_with_codes():
    """block-max == max stored code, term-max == max block-max, stripe table
    bounds every posting's code, floor(build float block-max / delta) ==
    stored block-max (floor is monotone)."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    sa = ScoreArena.from_index(idx)
    tiles = np.asarray(sa.tiles)
    for t, tp in idx.terms.items():
        per_block = []
        for bi in range(len(tp.blocks)):
            ids, tfs = idx.decode_block(t, bi)
            codes = unpack_words_np(tiles[sa.slot[(t, bi)]], len(ids))
            sc = bm25_scores(tfs, np.asarray(idx.doclen)[ids], tp.df,
                             idx.n_docs, float(np.asarray(idx.doclen).mean()))
            np.testing.assert_array_equal(
                codes, np.minimum(np.floor(sc / sa.delta), 255))
            bm = int(sa.block_max[sa.slot[(t, bi)]])
            assert bm == int(codes.max(initial=0))
            assert bm == min(int(idx.impact_block_max(t)[bi] / sa.delta), 255)
            per_block.append(bm)
            stripe = sa.stripes[t][ids // sa.stripe_width]
            assert np.all(stripe >= codes.astype(np.int64))
        assert sa.term_max[t] == max(per_block, default=0)
        tops = sa.term_tops[t]
        assert np.all(tops[:-1] >= tops[1:])          # sorted descending
        assert len(tops) == min(tp.df, scores_lib.TOP_TABLE)


def test_theta0_is_a_sound_lower_bound():
    """k docs provably reach theta0: the k-th best true OR score of any
    query is >= theta0 * delta."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    sa = ScoreArena.from_index(idx)
    for q in ([0, 7], [3, 5, 8], [1, 2, 9]):
        k = 5
        oracle = brute_or_topk(DOCLEN, POSTINGS, N_DOCS, q, k)
        assert oracle[-1][1] >= sa.theta0(q, k) * sa.delta - 1e-12


def test_topk_select_docid_tiebreak_and_partial_sort():
    docs = np.array([5, 1, 9, 3, 7, 2], np.uint32)
    scores = np.array([1.0, 2.0, 2.0, 2.0, 0.5, 1.0])
    # ties at 2.0 resolve by ascending docid; ties at 1.0 straddle the cut
    assert topk_select(docs, scores, 4) == [(1, 2.0), (3, 2.0), (9, 2.0),
                                            (2, 1.0)]
    assert topk_select(docs, scores, 100) == [(1, 2.0), (3, 2.0), (9, 2.0),
                                              (2, 1.0), (5, 1.0), (7, 0.5)]
    assert topk_select(docs, scores, 0) == []
    assert topk_select(np.zeros(0, np.uint32), np.zeros(0), 3) == []


# --------------------------------------------------------------------------- #
# adaptive theta promotion + threshold/compact kernels
# --------------------------------------------------------------------------- #


def test_topk_threshold_k_exceeds_candidate_count():
    """k larger than the number of nonzero sums must degenerate to 0 —
    keep-everything, never a positive threshold that could drop real
    candidates."""
    import jax.numpy as jnp
    acc = jnp.zeros((3, 128), jnp.uint32)
    acc = acc.at[0, 3].set(9).at[0, 70].set(5)     # q0: two candidates
    acc = acc.at[1, 0].set(2)                      # q1: one candidate
    assert np.asarray(topk_kern.topk_threshold(acc, 5)).tolist() == [0, 0, 0]
    assert np.asarray(topk_kern.pooled_threshold(acc, 5)).tolist() == [0, 0, 0]
    # sanity: with k <= candidates the same kernels return the exact k-th
    assert np.asarray(topk_kern.topk_threshold(acc, 2)).tolist() == [5, 0, 0]
    assert np.asarray(topk_kern.topk_threshold(acc, 1)).tolist() == [9, 2, 0]


def test_candidate_bitmap_all_pruned_worklist():
    """A work-list whose every entry fails the promoted-theta upper-bound
    test scatters nothing, and the final compact returns an all-zero
    candidate bitmap (no candidates, no crash)."""
    import jax.numpy as jnp
    q, words, p, ow = 2, 4, 3, 8
    acc = jnp.zeros((q, words * 32), jnp.uint32)
    member = jnp.zeros((q, words), jnp.uint32)
    ids = jnp.tile(jnp.arange(ow, dtype=jnp.uint32), (p, 1))
    codes = jnp.ones((p, ow), jnp.uint32)
    qslot = jnp.array([0, 1, 0], jnp.int32)
    ns = jnp.full((p,), ow, jnp.int32)
    theta = jnp.array([7, 7], jnp.uint32)
    iq = jnp.full((q,), 1 << 16, jnp.uint32)       # identity scale
    ub = jnp.array([7, 3, 0], jnp.int32)           # all <= scaled theta
    acc, member = topk_kern.score_round(
        acc, member, ids, qslot, codes, ns, member, ub, theta, iq,
        gated=False)
    assert not np.asarray(acc).any() and not np.asarray(member).any()
    got = topk_kern.candidate_bitmap(acc, member, theta,
                                     jnp.zeros((q,), jnp.int32), iq)
    assert not np.asarray(got).any()


def test_theta_promotion_monotone_and_never_over_promotes():
    """The superset contract per round: the promoted theta is monotone
    nondecreasing and NEVER exceeds the k-th largest sum of the final
    accumulator — so a block dropped mid-flight (ub <= promoted theta) holds
    only docs that end below the final threshold, outside the top-k."""
    import jax.numpy as jnp
    rng = np.random.default_rng(42)
    q, width, k, rounds = 8, 256, 5, 6
    acc = jnp.zeros((q, width), jnp.uint32)
    theta = jnp.zeros((q,), jnp.uint32)
    trail = []
    for _ in range(rounds):
        add = ((rng.random((q, width)) < 0.08)
               * rng.integers(1, 200, (q, width)))
        acc = acc + jnp.asarray(add.astype(np.uint32))
        theta = jnp.maximum(theta, topk_kern.pooled_threshold(acc, k))
        trail.append(np.asarray(theta).copy())
    final_kth = np.sort(np.asarray(acc), axis=1)[:, -k]
    for r, th in enumerate(trail):
        assert np.all(th <= final_kth), r          # sound lower bound
        if r:
            assert np.all(th >= trail[r - 1]), r   # monotone promotion


# --------------------------------------------------------------------------- #
# density-adaptive bitmap blocks + adaptive theta: end-to-end parity
# --------------------------------------------------------------------------- #


def _dense_corpus():
    """Clustered postings (avg gap ~2.5 << DENSE_GAP): the build stores most
    blocks as raw 128-word bitmaps via the dense_bitmap capability."""
    rng = np.random.default_rng(13)
    n_docs = 6000
    postings = {}
    for t, df in enumerate([500, 512, 700, 1024, 300, 64]):
        gaps = rng.integers(1, 5, df).astype(np.int64)
        ids = (int(rng.integers(0, 900)) + np.cumsum(gaps)).astype(np.uint32)
        assert int(ids[-1]) < n_docs
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    return rng.integers(60, 400, n_docs).astype(np.int64), postings


DENSE_QUERIES = ([[0, 1], [2, 3], [0, 3, 4], [1, 2, 5], [4], [0, 1, 2, 3],
                  [5, 3], [2, 4, 5]] * 2)


@pytest.mark.parametrize("name", RANKED_CODECS)
def test_dense_bitmap_corpus_ranked_parity(name):
    """The density-adaptive representation serves the ranked modes
    word-parallel with exact parity across all placements."""
    from repro.core import dense_bitmap
    doclen, postings = _dense_corpus()
    idx = InvertedIndex.build(doclen, postings, codec=name)
    assert any(encg.codec == dense_bitmap.NAME
               for tp in idx.terms.values()
               for _, encg, _ in tp.blocks), "corpus stores no dense blocks"
    host = QueryEngine(idx)
    for mode in ("or", "and_scored"):
        want = host.execute(QueryBatch(DENSE_QUERIES, mode=mode, k=7))
        for fused in (False, True):
            eng = QueryEngine(idx).to_device(fused=fused)
            got = eng.execute(eng.plan(QueryBatch(DENSE_QUERIES, mode=mode,
                                                  k=7)))
            assert want == got, (name, mode, fused)
            assert eng.dev_stats["blocks_dense"] > 0, (name, mode, fused)
        oracle_q = [q for q in DENSE_QUERIES]
        for q, res in zip(oracle_q, host.execute(
                QueryBatch(oracle_q, mode="or", k=7))):
            oracle = brute_or_topk(doclen, postings, len(doclen), q, 7)
            assert [(d, pytest.approx(s, rel=1e-12)) for d, s in oracle] == res


def test_adaptive_theta_corpus_parity_and_pruning():
    """The rare-clustered + common shape at a multi-round k=10: adaptive
    promotion engages (several rounds, armed theta) and stays bitwise exact
    while the static prune still drops blocks."""
    queries = [[10, 7, 5], [10, 3, 8], [10, 7], [10, 1, 4, 6]] * 4
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    want = QueryEngine(idx).execute(QueryBatch(queries, mode="or", k=10))
    for fused in (False, True):
        eng = QueryEngine(idx).to_device(fused=fused)
        got = eng.execute(eng.plan(QueryBatch(queries, mode="or", k=10)))
        assert want == got, fused
        assert eng.dev_stats["blocks_pruned"] > 0
        assert eng.dev_stats["score_syncs"] == 0


def test_tombstone_only_epoch_keeps_pruning_armed_and_exact():
    """Deletes only raise idf, so the ranked path stays ARMED under a
    tombstone-only epoch (idf-ratio deflated thresholds): blocks still
    prune, and every placement matches a from-scratch rebuild of the live
    corpus bitwise."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    rng = np.random.default_rng(31)
    dead = set()
    for d in rng.choice(N_DOCS, 30, replace=False):
        idx.delete(int(d))
        dead.add(int(d))
    live = {}
    for t, (ids, tfs) in POSTINGS.items():
        keep = [j for j, d in enumerate(ids.tolist()) if d not in dead]
        if keep:
            live[t] = (ids[np.asarray(keep)], tfs[np.asarray(keep)])
    rebuilt = InvertedIndex.build(DOCLEN, live, codec="group_simple")
    queries = [[10, 7], [10, 3], [10, 7, 5], [0, 7], [3, 5, 8]] * 3
    for mode in ("or", "and_scored"):
        want = QueryEngine(rebuilt).execute(QueryBatch(queries, mode=mode,
                                                       k=6))
        assert QueryEngine(idx).execute(QueryBatch(queries, mode=mode,
                                                   k=6)) == want, mode
        for fused in (False, True):
            eng = QueryEngine(idx).to_device(fused=fused)
            got = eng.execute(eng.plan(QueryBatch(queries, mode=mode, k=6)))
            assert want == got, (mode, fused)
            assert eng.dev_stats["score_syncs"] == 0
            if mode == "or":
                assert eng.dev_stats["blocks_pruned"] > 0, fused


def test_unpack_codes_pallas_matches_host():
    rng = np.random.default_rng(0)
    import jax.numpy as jnp
    from repro.kernels.decode_fused import pack_gaps
    blocks = [rng.integers(0, 256, n).astype(np.uint32)
              for n in (512, 511, 100, 1, 0)]
    tiles = jnp.asarray(np.stack([pack_gaps(c, 8)[0] for c in blocks]))
    slots = jnp.asarray(np.arange(len(blocks), dtype=np.int32))
    got = np.asarray(topk_kern.unpack_codes(tiles, slots)).reshape(len(blocks), -1)
    for j, c in enumerate(blocks):
        np.testing.assert_array_equal(got[j, :len(c)], c)
        np.testing.assert_array_equal(got[j, len(c):], 0)
