"""Per-kernel shape/bit-width sweeps: Pallas (interpret=True) vs ref.py oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import bitpack, ops, quadmax, ref, scan_add, unpack_delta

RNG = np.random.default_rng(7)
BWS = [1, 2, 3, 4, 5, 6, 7, 8, 9, 11, 12, 13, 16, 17, 20, 24, 27, 31, 32]


def _tiles(n_frames: int, bw: int) -> jnp.ndarray:
    x = RNG.integers(0, 2**bw, n_frames * bitpack.FRAME_INTS, dtype=np.uint64).astype(np.uint32)
    return jnp.asarray(x.reshape(n_frames * bitpack.FRAME_ROWS, bitpack.LANES))


@pytest.mark.parametrize("bw", BWS)
def test_pack_matches_ref(bw):
    t = _tiles(2, bw)
    got = bitpack.pack_frames(t, bw, interpret=True, frames_per_block=1)
    want = ref.pack_frames_ref(t, bw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bw", BWS)
def test_unpack_roundtrip(bw):
    t = _tiles(3, bw)
    packed = ref.pack_frames_ref(t, bw)
    got = bitpack.unpack_frames(packed, bw, interpret=True, frames_per_block=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(t))


@pytest.mark.parametrize("frames", [1, 2, 5, 8])
def test_frame_or_matches_ref(frames):
    t = _tiles(frames, 32)
    got = quadmax.frame_or(t, interpret=True, frames_per_block=2)
    want = ref.frame_or_ref(t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rows,rpb", [(8, 8), (64, 16), (96, 32), (256, 256)])
def test_prefix_sum_matches_ref(rows, rpb):
    x = jnp.asarray(RNG.integers(0, 2**20, rows * 128, dtype=np.uint64)
                    .astype(np.uint32).reshape(rows, 128))
    got = scan_add.prefix_sum_blocks(x, rows_per_block=rpb, interpret=True)
    want = ref.prefix_sum_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prefix_sum_uint32_wraparound():
    x = jnp.full((8, 128), 2**31, jnp.uint32)
    got = scan_add.prefix_sum_blocks(x, rows_per_block=8, interpret=True)
    want = ref.prefix_sum_ref(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bw", [1, 5, 8, 13, 17, 32])
def test_fused_unpack_delta_matches_ref(bw):
    t = _tiles(2, bw)
    packed = ref.pack_frames_ref(t, bw)
    got = unpack_delta.unpack_delta_frames(packed, bw, interpret=True, frames_per_block=2)
    want = ref.unpack_delta_ref(packed, bw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 32), st.integers(1, 3), st.integers(0, 4095))
def test_property_stream_roundtrip(bw, frames, tail):
    n = (frames - 1) * 4096 + tail + 1
    x = RNG.integers(0, 2**bw, n, dtype=np.uint64).astype(np.uint32)
    xj = jnp.asarray(x)
    packed = ops.pack_stream(xj, bw)
    out = ops.unpack_stream(packed, bw, n)
    np.testing.assert_array_equal(np.asarray(out), x)


def test_select_bw_matches_effective_width():
    # each frame gets values of a known max width
    widths = [3, 11, 26]
    xs = [RNG.integers(2**(w - 1), 2**w, 4096, dtype=np.uint64).astype(np.uint32) for w in widths]
    x = jnp.asarray(np.concatenate(xs))
    got = np.asarray(ops.select_bw(x))
    np.testing.assert_array_equal(got, widths)
