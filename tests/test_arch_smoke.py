"""Per-architecture smoke tests: a REDUCED config of the same family runs one
forward/train step on CPU; asserts output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs.base import STEP_FNS, ShapeCell
from repro.optim import AdamWConfig, adamw_init

RNG = np.random.default_rng(11)
OPT = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)


def _smoke_batch(spec, cfg, cell):
    """Small concrete batch matching the smoke config."""
    if spec.family == "lm":
        b, s = 2, 32
        if cell.kind == "train":
            t = RNG.integers(0, cfg.vocab, (b, s + 1))
            return {"tokens": jnp.asarray(t[:, :-1], jnp.int32),
                    "labels": jnp.asarray(t[:, 1:], jnp.int32)}
        if cell.kind == "prefill":
            return {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)}
        from repro.models import transformer as T
        cache = jax.tree.map(lambda st: jnp.zeros(st.shape, st.dtype),
                             T.cache_spec(cfg, b, s))
        return {"token": jnp.asarray(RNG.integers(0, cfg.vocab, (b,)), jnp.int32),
                "pos": jnp.int32(s - 1), "cache": cache}
    if spec.family == "gnn":
        n, e = 40, 120
        batch = {
            "feats": jnp.asarray(RNG.random((n, cfg.d_feat)), jnp.float32),
            "coords": jnp.asarray(RNG.random((n, 3)), jnp.float32),
            "src": jnp.asarray(RNG.integers(0, n, e), jnp.int32),
            "dst": jnp.asarray(RNG.integers(0, n, e), jnp.int32),
        }
        if cfg.task == "node_class":
            batch["labels"] = jnp.asarray(RNG.integers(0, cfg.n_classes, n), jnp.int32)
            batch["label_mask"] = jnp.ones(n, jnp.float32)
        else:
            batch["graph_id"] = jnp.asarray(RNG.integers(0, 4, n), jnp.int32)
            batch["targets"] = jnp.asarray(RNG.random(4), jnp.float32)
        return batch
    # recsys
    b = 8
    if cfg.model in ("dlrm", "wide_deep"):
        batch = {"sparse": jnp.asarray(RNG.integers(0, cfg.table_rows, (b, cfg.n_sparse)), jnp.int32)}
        if cfg.model == "dlrm":
            batch["dense"] = jnp.asarray(RNG.random((b, cfg.n_dense)), jnp.float32)
    else:
        batch = {
            "target_item": jnp.asarray(RNG.integers(0, cfg.item_vocab, b), jnp.int32),
            "target_cate": jnp.asarray(RNG.integers(0, cfg.cate_vocab, b), jnp.int32),
            "hist_items": jnp.asarray(RNG.integers(0, cfg.item_vocab, (b, cfg.seq_len)), jnp.int32),
            "hist_cates": jnp.asarray(RNG.integers(0, cfg.cate_vocab, (b, cfg.seq_len)), jnp.int32),
            "hist_len": jnp.asarray(RNG.integers(1, cfg.seq_len, b), jnp.int32),
            "profile": jnp.asarray(RNG.integers(0, cfg.profile_vocab, (b, cfg.n_profile)), jnp.int32),
        }
    if cell.kind == "train":
        batch["label"] = jnp.asarray(RNG.integers(0, 2, b), jnp.int32)
    if cell.kind == "retrieval":
        batch["cand_items"] = jnp.asarray(RNG.integers(0, cfg.item_vocab if cfg.model in ("din", "dien") else cfg.table_rows, 64), jnp.int32)
        if cfg.model in ("din", "dien"):
            batch["cand_cates"] = jnp.asarray(RNG.integers(0, cfg.cate_vocab, 64), jnp.int32)
    return batch


def _model_mod(spec):
    from repro.models import egnn, recsys, transformer
    return {"lm": transformer, "gnn": egnn, "recsys": recsys}[spec.family]


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", sorted(configs.ARCHS))
def test_smoke_train_step(arch_id):
    spec = configs.get(arch_id)
    train_cells = [c for c in spec.shapes.values() if c.kind == "train"]
    cell = train_cells[0]
    cfg = spec.config_for_cell(spec.make_smoke_config(), cell)
    mod = _model_mod(spec)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    step_fn, is_train = STEP_FNS[spec.family](cfg, cell, OPT)
    assert is_train
    batch = _smoke_batch(spec, cfg, cell)
    params2, opt2, metrics = jax.jit(step_fn)(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"])), arch_id
    assert _finite(params2), f"{arch_id}: non-finite params after update"
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch_id", sorted(a for a, s in configs.ARCHS.items() if s.family == "lm"))
def test_smoke_lm_serve(arch_id):
    spec = configs.get(arch_id)
    cfg = spec.make_smoke_config()
    from repro.models import transformer as T
    params = T.init(cfg, jax.random.PRNGKey(0))
    pre_cell = spec.shapes["prefill_32k"]
    step_fn, _ = STEP_FNS["lm"](cfg, pre_cell, None)
    batch = _smoke_batch(spec, cfg, pre_cell)
    logits, cache = jax.jit(step_fn)(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert _finite(logits)
    dec_cell = spec.shapes["decode_32k"]
    step_fn, _ = STEP_FNS["lm"](cfg, dec_cell, None)
    batch = _smoke_batch(spec, cfg, dec_cell)
    logits, cache = jax.jit(step_fn)(params, batch)
    assert logits.shape == (2, cfg.vocab)
    assert _finite(logits)


@pytest.mark.parametrize("arch_id", sorted(a for a, s in configs.ARCHS.items() if s.family == "recsys"))
def test_smoke_recsys_serve_and_retrieval(arch_id):
    spec = configs.get(arch_id)
    cfg = spec.make_smoke_config()
    from repro.models import recsys as R
    params = R.init(cfg, jax.random.PRNGKey(0))
    serve_cell = spec.shapes["serve_p99"]
    step_fn, _ = STEP_FNS["recsys"](cfg, serve_cell, None)
    probs = jax.jit(step_fn)(params, _smoke_batch(spec, cfg, serve_cell))
    assert probs.shape == (8,) and _finite(probs)
    assert float(probs.min()) >= 0 and float(probs.max()) <= 1
    retr_cell = spec.shapes["retrieval_cand"]
    step_fn, _ = STEP_FNS["recsys"](cfg, retr_cell, None)
    batch = _smoke_batch(spec, cfg, retr_cell)
    batch = {k: (v[:1] if k not in ("cand_items", "cand_cates") else v) for k, v in batch.items()}
    scores, ids = jax.jit(step_fn)(params, batch)
    assert scores.shape == (64,) if False else scores.shape[0] <= 100
    assert _finite(scores)


def test_gnn_molecule_smoke():
    spec = configs.get("egnn")
    cell = spec.shapes["molecule"]
    cfg = spec.config_for_cell(spec.make_smoke_config(), cell)
    from repro.models import egnn as E
    import dataclasses
    cfg = dataclasses.replace(cfg, d_feat=8)
    params = E.init(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(spec, cfg, cell)
    loss, m = jax.jit(lambda p, b: E.loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))


def test_all_cells_enumerate_40():
    cells = list(configs.all_cells())
    assert len(cells) == 40, len(cells)
    skipped = [(a, s) for a, s, c in cells if c.skip_reason]
    assert len(skipped) == 3  # long_500k for starcoder2-3b/7b + smollm
