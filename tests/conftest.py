"""Test-suite bootstrap: make the suite collect with or without ``hypothesis``.

When hypothesis is installed the property tests run as written.  When it is
absent (the serving containers ship without dev extras) we install a minimal
stand-in module into ``sys.modules`` *before* the test modules import it.  The
stand-in degrades ``@given(strategy...)`` to a fixed seed-corpus sweep: each
strategy can generate deterministic examples itself (numpy Generator seeded
0..N-1, example 0 pinned to the minimal case), so the tests still exercise a
small adversarial corpus instead of being skipped.

Only the strategy surface this repo uses is implemented: ``integers``,
``lists``, ``tuples`` and ``.map``; ``settings`` is a no-op decorator.
"""

from __future__ import annotations

import os
import sys
import types

import numpy as np

# repo-root/src on the path so `repro` imports work without external PYTHONPATH
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

N_FALLBACK_EXAMPLES = 8


class _Strategy:
    """Self-generating stand-in for a hypothesis strategy.

    ``draw(rng)`` produces one random example; ``minimal()`` the smallest one
    (empty/min-size lists, lower-bound integers) so the seed corpus always
    contains the degenerate case property tests most often catch bugs with.
    """

    def __init__(self, draw, minimal):
        self._draw = draw
        self._minimal = minimal

    def draw(self, rng):
        return self._draw(rng)

    def minimal(self):
        return self._minimal()

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)), lambda: f(self._minimal()))


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                     lambda: int(min_value))


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw, lambda: [elements.minimal() for _ in range(min_size)])


def _tuples(*elems):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems),
                     lambda: tuple(e.minimal() for e in elems))


def _given(*strats):
    def deco(fn):
        def run_examples():
            fn(*(s.minimal() for s in strats))
            for seed in range(1, N_FALLBACK_EXAMPLES):
                rng = np.random.default_rng(seed)
                fn(*(s.draw(rng) for s in strats))

        run_examples.__name__ = fn.__name__
        run_examples.__doc__ = fn.__doc__
        return run_examples

    return deco


def _settings(**_kw):
    return lambda fn: fn


def _install_hypothesis_shim() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.lists = _lists
    st.tuples = _tuples
    mod.given = _given
    mod.settings = _settings
    mod.strategies = st
    mod.__is_repro_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
