"""Doc-range sharded serving: bitwise parity with the unsharded host oracle
on every mode and placement, shard-locality of the rounds (zero cross-shard
candidate syncs, ONE top-k merge collective per ranked batch), the per-shard
ranked superset contract, mutation epochs under shards (insert / delete /
compact with atomic per-generation shard sets), uneven and empty explicit
bounds, and the boundary-sliced tombstone upload.

The shards here are LOGICAL (the CI backend exposes one CPU device): every
shard runs on the default device through the exact same code path a mesh
placement uses, except the merge collective stacks host-side.  The one true
multi-device case runs in a subprocess with a forced 8-device CPU backend
(the ``test_distribution`` pattern) and goes through ``shard_map``.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.index.engine import QueryBatch, QueryEngine
from repro.index.invindex import InvertedIndex
from repro.index.shards import ShardSpec, TILE_DOCS, shard_generation
from repro.kernels.intersect_rounds import (bitmap_geometry, pack_live_words,
                                            pack_live_words_range)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_DOCS = 20_000
MODES = ("and", "or", "and_scored")


def _corpus(seed=0, n_terms=24):
    rng = np.random.default_rng(seed)
    doclen = rng.integers(5, 120, N_DOCS).astype(np.int64)
    postings = {}
    for t in range(n_terms):
        df = int(rng.integers(60, 6000))
        ids = np.sort(rng.choice(N_DOCS, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.integers(1, 8, df).astype(np.uint32))
    return doclen, postings


DOCLEN, POSTINGS = _corpus()
QUERIES = [[0, 1], [2, 3, 5], [7], [11, 13, 17, 19], [2, 4, 8], [1],
           [23, 6], []]


def _build(codec="group_simple"):
    return InvertedIndex.build(DOCLEN, POSTINGS, codec=codec)


def _assert_equal(ref, got, tag):
    for i, (a, b) in enumerate(zip(ref, got)):
        if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (tag, i)
        else:
            assert a == b, (tag, i, a, b)


def _sweep(host, sharded, tag, k=10, queries=QUERIES):
    for mode in MODES:
        b = QueryBatch([list(q) for q in queries], mode=mode, k=k)
        ref = host.execute(host.plan(b, placement="host"))
        got = sharded.execute(sharded.plan(b, placement="device"))
        _assert_equal(ref, got, (tag, mode))


# --------------------------------------------------------------------------- #
# parity: 1 shard == unsharded, multi-shard sweeps
# --------------------------------------------------------------------------- #

def test_one_shard_bitwise_equals_unsharded_every_mode_and_placement():
    idx = _build()
    host = QueryEngine(idx)
    dev = QueryEngine(idx).to_device(fused=True)
    sh1 = QueryEngine(idx).to_device(fused=True, shards=1)
    for mode in MODES:
        b = QueryBatch([list(q) for q in QUERIES], mode=mode, k=10)
        ref = host.execute(host.plan(b, placement="host"))
        for placement in ("device", "fused"):
            _assert_equal(ref, dev.execute(dev.plan(b, placement=placement)),
                          ("unsharded", mode, placement))
            _assert_equal(ref, sh1.execute(sh1.plan(b, placement=placement)),
                          ("1shard", mode, placement))


@pytest.mark.parametrize("codec", ["group_simple", "group_pfd"])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_multi_shard_parity_sweep(codec, n_shards):
    idx = _build(codec)
    host = QueryEngine(idx)
    sh = QueryEngine(idx).to_device(shards=n_shards)
    _sweep(host, sh, (codec, n_shards))


def test_fused_placement_parity_under_shards():
    idx = _build("group_pfd")
    host = QueryEngine(idx)
    sh = QueryEngine(idx).to_device(fused=True, shards=3)
    for mode in MODES:
        b = QueryBatch([list(q) for q in QUERIES], mode=mode, k=10)
        ref = host.execute(host.plan(b, placement="host"))
        _assert_equal(ref, sh.execute(sh.plan(b, placement="fused")),
                      ("fused", mode))


def test_uneven_and_empty_explicit_bounds():
    idx = _build()
    host = QueryEngine(idx)
    # a deliberately lopsided split with an EMPTY middle shard and cuts not
    # aligned to bitmap tiles — correctness may not depend on where they fall
    sh = QueryEngine(idx).to_device(bounds=(0, 100, 100, 17_001, N_DOCS))
    _sweep(host, sh, "uneven")
    spec, engs, _ = sh._shard_engines(sh._ctx_now())
    assert spec.bounds == (0, 100, 100, 17_001, N_DOCS)
    assert engs[1] is None                  # empty shard gets no engine
    assert sum(e is not None for e in engs) == 3


# --------------------------------------------------------------------------- #
# shard locality + the single merge collective
# --------------------------------------------------------------------------- #

def test_zero_cross_shard_syncs_and_one_merge_per_ranked_batch():
    idx = _build()
    sh = QueryEngine(idx).to_device(shards=4)
    b = QueryBatch([list(q) for q in QUERIES], mode="or", k=10)
    with sh.metrics.scoped() as sample:
        sh.execute(sh.plan(b, placement="device"))
    assert sample.delta("merge_syncs") == 1         # ONE collective per batch
    assert sample.delta("collective_bytes") > 0
    spec, engs, _ = sh._shard_engines(sh._ctx_now())
    live = [e for e in engs if e is not None]
    assert live and spec.n_shards == 4
    for eng in live:                # rounds never sync candidates or scores
        assert eng.dev_stats["cand_syncs"] == 0
        assert eng.dev_stats["score_syncs"] == 0
    # each non-empty shard contributes exactly one final bitmap download
    assert sample.delta("shard_final_syncs") == len(live)
    with sh.metrics.scoped() as sample:
        sh.execute(sh.plan(QueryBatch([[0, 1], [2, 3]], mode="and"),
                           placement="device"))
    assert sample.delta("merge_syncs") == 0         # AND merges nothing


def test_plan_note_records_shard_topology():
    idx = _build()
    sh = QueryEngine(idx).to_device(shards=2)
    note = sh.plan(QueryBatch([[0, 1]] * 8, mode="or", k=10),
                   placement="device").note
    assert "sharded x2" in note and "bounds=" in note and "logical" in note


# --------------------------------------------------------------------------- #
# ranked superset contract, per shard
# --------------------------------------------------------------------------- #

def test_per_shard_candidates_superset_of_global_topk():
    idx = _build()
    host = QueryEngine(idx)
    sh = QueryEngine(idx).to_device(shards=4)
    queries = [list(q) for q in QUERIES if q]
    k = 10
    for mode in ("or", "and_scored"):
        b = QueryBatch(queries, mode=mode, k=k)
        ref = host.execute(host.plan(b, placement="host"))
        sh.execute(sh.plan(b, placement="device"))
        spec, engs, _ = sh._shard_engines(sh._ctx_now())
        shard_cands = sh._last_shard_cands
        ranges = [r for r, e in zip(spec.ranges(), engs) if e is not None]
        assert len(shard_cands) == len(ranges)
        for (lo, hi), cands in zip(ranges, shard_cands):
            for i, top in enumerate(ref):
                want = [d for d, _ in top if lo <= d < hi]
                got = set((cands[i] + np.uint32(lo)).tolist())
                assert got.issuperset(want), (mode, i, lo, hi)


# --------------------------------------------------------------------------- #
# mutation epochs under shards
# --------------------------------------------------------------------------- #

def test_mutation_epochs_and_atomic_generation_swap():
    rng = np.random.default_rng(9)
    idx = _build("group_pfd")
    host = QueryEngine(idx)
    sh = QueryEngine(idx).to_device(shards=3)
    gid0 = idx.gen.gid
    spec0, engs0, _ = sh._shard_engines(sh._ctx_now())
    assert all(e.idx.gid == gid0 for e in engs0 if e is not None)

    # tombstone-only epoch (pruning stays armed, per-shard sliced gates)
    for d in rng.choice(N_DOCS, 200, replace=False):
        idx.delete(int(d))
    _sweep(host, sh, "tomb-only")

    # delta-bearing epoch: fresh inserts served by the parent's delta scan
    for j in range(25):
        idx.insert(N_DOCS + j,
                   {int(t): int(rng.integers(1, 5))
                    for t in rng.choice(24, 4, replace=False)},
                   int(rng.integers(5, 100)))
    _sweep(host, sh, "delta")

    # pin a plan, compact underneath it: the pinned plan must keep serving
    # the OLD generation's shard set; fresh plans serve the new one
    b = QueryBatch([list(q) for q in QUERIES], mode="or", k=10)
    pinned = sh.plan(b, placement="device")
    ref_pinned = sh.execute(pinned)
    idx.compact()
    assert idx.gen.gid != gid0
    assert sh.execute(pinned) == ref_pinned         # epoch pinning holds
    _sweep(host, sh, "post-compact")
    # the new generation's shard set is a fresh atomic build, all on gid+1
    _, engs1, _ = sh._shard_engines(sh._ctx_now())
    gids = {e.idx.gid for e in engs1 if e is not None}
    assert gids == {idx.gen.gid}


# --------------------------------------------------------------------------- #
# shard building blocks
# --------------------------------------------------------------------------- #

def test_shard_spec_derive_covers_and_aligns():
    idx = _build()
    spec = ShardSpec.derive(idx.gen, 4)
    b = spec.bounds
    assert b[0] == 0 and b[-1] == N_DOCS and len(b) == 5
    assert all(x <= y for x, y in zip(b, b[1:]))
    assert all(x % TILE_DOCS == 0 for x in b[1:-1])     # interior cuts aligned
    assert spec.shard_of(0) == 0 and spec.shard_of(N_DOCS - 1) == 3
    for s, (lo, hi) in enumerate(spec.ranges()):
        if hi > lo:
            assert spec.shard_of(lo) == s and spec.shard_of(hi - 1) == s


def test_shard_generation_stats_fixed_to_parent():
    idx = _build()
    gen = idx.gen
    lo, hi = 4096, 12_288
    sg = shard_generation(gen, lo, hi)
    assert sg.gid == gen.gid and (sg.doc_lo, sg.doc_hi) == (lo, hi)
    assert sg.n_docs == hi - lo
    assert sg.stat_n_docs == gen.n_docs and sg.stat_avdl == gen.avdl
    for t, tp in sg.terms.items():
        assert tp.df == gen.terms[t].df             # GLOBAL df after fixup
        ids, tfs = sg.decode_term(t)
        gids_, gtfs = gen.decode_term(t)
        m = (gids_ >= lo) & (gids_ < hi)
        assert np.array_equal(ids.astype(np.int64) + lo,
                              gids_[m].astype(np.int64))
        assert np.array_equal(tfs, gtfs[m])


def test_pack_live_words_range_equals_sliced_translation():
    rng = np.random.default_rng(3)
    dead = np.sort(rng.choice(N_DOCS, 300, replace=False)).astype(np.int64)
    for lo, hi in ((0, N_DOCS), (4096, 12_288), (100, 17_001), (50, 51)):
        words, _ = bitmap_geometry(hi - lo)
        sub = dead[(dead >= lo) & (dead < hi)] - lo
        assert np.array_equal(pack_live_words_range(dead, lo, hi, words),
                              pack_live_words(sub, hi - lo, words))


def test_shard_spec_rejects_bad_bounds():
    with pytest.raises(ValueError):
        ShardSpec((5, 10))              # must start at 0
    with pytest.raises(ValueError):
        ShardSpec((0, 10, 5))           # must be non-decreasing
    with pytest.raises(ValueError):
        ShardSpec((0,))                 # needs at least (0, n_docs)
    with pytest.raises(ValueError):
        shard_generation(_build().gen, 10, 10)      # empty range


# --------------------------------------------------------------------------- #
# true multi-device mesh (subprocess, forced 8-device CPU backend)
# --------------------------------------------------------------------------- #

def test_mesh_sharded_parity_subprocess():
    body = textwrap.dedent("""
    import numpy as np, jax
    from repro.index.invindex import InvertedIndex
    from repro.index.engine import QueryEngine, QueryBatch
    from repro.launch.mesh import serving_mesh
    rng = np.random.default_rng(2)
    n_docs = 16000
    doclen = rng.integers(5, 120, n_docs).astype(np.int64)
    postings = {}
    for t in range(16):
        df = int(rng.integers(60, 4000))
        ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.integers(1, 8, df).astype(np.uint32))
    idx = InvertedIndex.build(doclen, postings, codec="group_simple")
    host = QueryEngine(idx)
    mesh = serving_mesh(4)
    assert mesh is not None and mesh.devices.size == 4
    sh = QueryEngine(idx).to_device(shards=4, mesh=mesh)
    queries = [[0, 1], [2, 3, 5], [7], [11, 13, 14, 15]]
    for mode in ("and", "or", "and_scored"):
        b = QueryBatch(queries, mode=mode, k=10)
        ref = host.execute(host.plan(b, placement="host"))
        got = sh.execute(sh.plan(b, placement="device"))
        for a, g in zip(ref, got):
            if mode == "and":
                assert np.array_equal(a, g)
            else:
                assert a == g
    note = sh.plan(QueryBatch(queries, mode="or", k=10),
                   placement="device").note
    assert "mesh-placed" in note
    assert sh.dev_stats["merge_syncs"] == 2
    print("MESH_PARITY_OK")
    """)
    code = ("import os\n"
            "os.environ['XLA_FLAGS']="
            "'--xla_force_host_platform_device_count=8'\n" + body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "MESH_PARITY_OK" in r.stdout
