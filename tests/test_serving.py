"""Latency-governed serving: batcher edge cases, bitwise parity with the
offline plan/execute oracle, per-tenant weighted admission, epoch pinning
across a racing ``compact()``, and the measured placement-crossover table.

The server runs a real asyncio event loop per test (``asyncio.run`` inside
the sync test body — no plugin dependency); every stream is tiny and seeded,
so the suite stays tier-1 fast."""

import asyncio

import numpy as np
import pytest

from repro.index import engine as engine_mod
from repro.index.engine import (CrossoverTable, HOST_BATCH_MAX, QueryBatch,
                                QueryEngine, set_crossover)
from repro.index.invindex import InvertedIndex
from repro.index.serve import (IndexServer, Rejected, Request, ServeConfig,
                               bursty_offsets, poisson_offsets, serve_stream,
                               tenant_cap, weighted_fill)

RNG = np.random.default_rng(77)
N_DOCS = 2000


def _corpus():
    doclen = RNG.integers(40, 300, N_DOCS).astype(np.int64)
    postings = {}
    for t, df in enumerate([50, 180, 420, 700, 260, 90]):
        ids = np.sort(RNG.choice(N_DOCS, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, RNG.geometric(0.4, df).astype(np.uint32))
    return doclen, postings


DOCLEN, POSTINGS = _corpus()


def _engine(device=False):
    idx = InvertedIndex.build(DOCLEN, POSTINGS)
    eng = QueryEngine(idx)
    return eng.to_device() if device else eng


def _serve(engine, reqs, offsets=None, **cfg_kw):
    cfg_kw.setdefault("max_batch", 4)
    cfg_kw.setdefault("max_wait_ms", 2.0)
    cfg_kw.setdefault("warm_terms", 4)
    if offsets is None:
        offsets = np.zeros(len(reqs))
    return serve_stream(engine, reqs, offsets, ServeConfig(**cfg_kw))


# --------------------------------------------------------------------------- #
# batcher edge cases
# --------------------------------------------------------------------------- #

def test_expired_at_enqueue_is_rejected_immediately():
    results, stats = _serve(_engine(), [Request([0, 1], deadline_ms=0),
                                        Request([0, 1], deadline_ms=-5.0),
                                        Request([0, 1], deadline_ms=500)])
    assert isinstance(results[0], Rejected) and results[0].reason == "expired"
    assert isinstance(results[1], Rejected) and results[1].reason == "expired"
    assert not isinstance(results[2], Rejected)
    assert stats.rejected_expired == 2 and stats.served == 1
    # rejected traces stop at enqueue but still record the outcome
    dead = [tr for tr in stats.traces if tr.outcome == "rejected_expired"]
    assert len(dead) == 2 and all(tr.stages() == (tr.t_enqueue,) for tr in dead)


def test_batch_of_one_bitwise_parity_with_offline_plan():
    engine = _engine()
    results, stats = _serve(engine, [Request([0, 2], deadline_ms=500)])
    assert stats.served == 1 and len(stats.batches) == 1
    b = stats.batches[0]
    assert len(b.queries) == 1
    oracle = engine.execute(engine.plan(
        QueryBatch([list(b.queries[0])], mode=b.mode, k=b.k),
        placement=b.placement))
    assert np.array_equal(np.asarray(results[0]), np.asarray(oracle[0]))


def test_mixed_modes_never_cobatched():
    engine = _engine()
    reqs = [Request([0, 2], mode="and" if i % 2 == 0 else "or",
                    deadline_ms=1000) for i in range(8)]
    results, stats = _serve(engine, reqs, max_batch=8, max_wait_ms=5.0)
    assert stats.served == 8
    assert all(not isinstance(r, Rejected) for r in results)
    # each batch carries exactly one (mode, k); and/or landed in different ones
    modes_by_batch = {b.batch_id: b.mode for b in stats.batches}
    for tr in stats.traces:
        assert modes_by_batch[tr.batch_id] == tr.mode
    assert {b.mode for b in stats.batches} == {"and", "or"}
    # different k never co-batches either
    reqs = [Request([0, 2], k=5 + (i % 2) * 5, mode="or", deadline_ms=1000)
            for i in range(6)]
    _, stats2 = _serve(engine, reqs, max_batch=8, max_wait_ms=5.0)
    assert all(len({tr.k for tr in stats2.traces
                    if tr.batch_id == b.batch_id}) == 1
               for b in stats2.batches)


def test_flush_on_idle_queue_beats_full_deadline():
    """A lone request on an idle queue must flush after ``max_wait_ms``, not
    sit until its (much longer) deadline closes the batch."""
    engine = _engine()
    results, stats = _serve(engine, [Request([0, 1], deadline_ms=10_000)],
                            max_batch=64, max_wait_ms=5.0)
    assert stats.served == 1
    tr = stats.traces[-1]
    # closed by the max_wait flush: far sooner than the 10s deadline
    assert (tr.t_close - tr.t_enqueue) < 1.0
    assert stats.batches[0].queries == (tuple([0, 1]),)


def test_compact_between_plan_and_execute_serves_pinned_epoch():
    """A ``compact()`` landing between plan and execute must not change the
    served results (the plan pins its epoch) and the trace must carry the
    pre-compact epoch key."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS)
    idx.delete(int(POSTINGS[0][0][0]))           # make compaction non-trivial
    engine = QueryEngine(idx)
    oracle_plan = engine.plan(QueryBatch([[0, 2]], mode="and"))
    pinned_key = oracle_plan.ctx.skey
    oracle = engine.execute(oracle_plan)

    server = IndexServer(engine, ServeConfig(max_batch=4, max_wait_ms=2.0,
                                             warm_terms=2))
    compacted = []

    def boom(plan):
        assert plan.ctx.skey == pinned_key
        compacted.append(idx.compact())

    server._after_plan = boom

    async def go():
        await server.start()
        try:
            return await server.submit(Request([0, 2], deadline_ms=2000))
        finally:
            await server.stop()

    got = asyncio.run(go())
    assert compacted and idx.epoch != pinned_key
    assert np.array_equal(np.asarray(got), np.asarray(oracle[0]))
    tr = [t for t in server.stats.traces if t.outcome == "served"][-1]
    assert tr.epoch == pinned_key


def test_queue_full_backpressure_sheds_explicitly():
    engine = _engine()

    async def go():
        server = IndexServer(engine, ServeConfig(queue_cap=3))
        # batcher not started: nothing drains, so the cap must bite
        futs = [server.submit_nowait(Request([0, 1], deadline_ms=1000))
                for _ in range(5)]
        out = [f.result() if f.done() else None for f in futs]
        for f in futs:            # the queued futures never resolve; drop them
            f.cancel()
        return out, server.stats

    out, stats = asyncio.run(go())
    rejected = [r for r in out if isinstance(r, Rejected)]
    assert len(rejected) == 2
    assert all(r.reason == "queue_full" for r in rejected)
    assert stats.rejected_queue_full == 2


# --------------------------------------------------------------------------- #
# per-tenant weighted admission
# --------------------------------------------------------------------------- #

def test_tenant_cap_is_weighted_share():
    assert tenant_cap(100, {}, "anyone") == 100
    assert tenant_cap(90, {"a": 2.0, "b": 1.0}, "a") == 60
    assert tenant_cap(90, {"a": 2.0, "b": 1.0}, "b") == 30
    # unknown tenant weighs 1.0 against the configured total
    assert tenant_cap(80, {"a": 3.0}, "ghost") == 20
    assert tenant_cap(4, {"a": 100.0, "b": 0.001}, "b") >= 1


def test_weighted_fill_is_proportional_and_skips_incompatible():
    queues = {"a": [("and", i) for i in range(8)],
              "b": [("and", 10 + i) for i in range(8)]}
    got = weighted_fill(queues, {"a": 2.0, "b": 1.0},
                        lambda e: e[0] == "and", 6)
    by_tenant = {"a": sum(1 for e in got if e[1] < 10),
                 "b": sum(1 for e in got if e[1] >= 10)}
    assert by_tenant == {"a": 4, "b": 2}
    # an incompatible head must not block a tenant's later compatible entries
    queues = {"a": [("or", 0), ("and", 1)]}
    got = weighted_fill(queues, {}, lambda e: e[0] == "and", 4)
    assert got == [("and", 1)]
    assert queues["a"] == [("or", 0)]


def test_weighted_fill_carries_credit_across_batches():
    credit = {}
    queues = {"a": [1] * 10, "b": [2] * 10}
    first = weighted_fill(queues, {"a": 3.0, "b": 1.0}, lambda e: True, 4,
                          credit)
    second = weighted_fill(queues, {"a": 3.0, "b": 1.0}, lambda e: True, 4,
                           credit)
    both = first + second
    assert both.count(1) == 6 and both.count(2) == 2


# --------------------------------------------------------------------------- #
# placement crossover table
# --------------------------------------------------------------------------- #

def test_crossover_from_bench_true_crossing():
    # host wins at 1 and 4, device at 16 and 256 -> cut at 4
    table = CrossoverTable.from_bench({
        "host_qps": {"1": 100.0, "4": 90.0, "16": 50.0, "256": 40.0},
        "device_qps": {"1": 20.0, "4": 80.0, "16": 200.0, "256": 400.0}})
    assert table.host_batch_max == 4
    assert table.sizes == (1, 4, 16, 256)


def test_crossover_from_bench_no_crossing_or_degenerate():
    # host still winning at the largest measured size: no crossing
    assert CrossoverTable.from_bench({
        "host_qps": {"1": 10.0, "16": 90.0, "256": 70.0},
        "device_qps": {"1": 20.0, "16": 40.0, "256": 60.0}
    }).host_batch_max is None
    # device wins everywhere: never demote
    assert CrossoverTable.from_bench({
        "host_qps": {"1": 10.0, "16": 20.0},
        "device_qps": {"1": 15.0, "16": 40.0}}).host_batch_max == 0
    # non-monotone curve (host re-wins in the middle): only the LAST
    # host-winning size with device winning all larger sizes counts
    table = CrossoverTable.from_bench({
        "host_qps": {"1": 50.0, "4": 10.0, "16": 90.0, "64": 10.0},
        "device_qps": {"1": 20.0, "4": 40.0, "16": 50.0, "64": 80.0}})
    assert table.host_batch_max == 16
    assert CrossoverTable.from_bench({}).host_batch_max is None


def test_crossover_from_bench_per_mode_cells():
    # per-mode curves ("mode_qps") yield per-mode cells; cut_for falls back
    # to the pooled host_batch_max only for modes with no measured curve
    table = CrossoverTable.from_bench({
        "host_qps": {"1": 100.0, "4": 90.0, "16": 50.0},
        "device_qps": {"1": 20.0, "4": 80.0, "16": 200.0},
        "mode_qps": {
            "or": {"host": {"1": 50.0, "16": 40.0},
                   "device": {"1": 60.0, "16": 90.0}},      # device always
            "and_scored": {"host": {"1": 90.0, "16": 80.0},
                           "device": {"1": 10.0, "16": 20.0}},  # no crossing
        }})
    assert table.host_batch_max == 4
    assert dict(table.mode_cuts) == {"or": 0, "and_scored": None}
    assert table.cut_for("or") == 0                 # never demote ranked-or
    assert table.cut_for("and_scored") is None      # host wins everywhere
    assert table.cut_for("and") == 4                # pooled fallback


def test_plan_demotes_via_measured_crossover_table():
    engine = _engine(device=True)
    try:
        set_crossover(CrossoverTable(host_batch_max=8, sizes=(1, 8, 64),
                                     source="SYNTHETIC.json"))
        small = engine.plan(QueryBatch([[0, 1]] * 8, mode="and"))
        assert small.placement == "host"
        assert "measured crossover" in small.note
        assert "SYNTHETIC.json" in small.note
        big = engine.plan(QueryBatch([[0, 1]] * 9, mode="and"))
        assert big.placement == "device" and big.note == ""
    finally:
        set_crossover()


def test_plan_static_fallback_when_baseline_absent():
    engine = _engine(device=True)
    try:
        set_crossover(None)
        tiny = engine.plan(QueryBatch([[0, 1]], mode="and"))
        assert tiny.placement == "host"
        assert f"HOST_BATCH_MAX={HOST_BATCH_MAX}" in tiny.note
        assert "static rule" in tiny.note
    finally:
        set_crossover()


def test_plan_explicit_placement_bypasses_demotion():
    engine = _engine(device=True)
    plan = engine.plan(QueryBatch([[0, 1]], mode="and"), placement="device")
    assert plan.placement == "device" and "pinned by caller" in plan.note
    host_only = _engine(device=False)
    with pytest.raises(ValueError, match="needs device arenas"):
        host_only.plan(QueryBatch([[0, 1]], mode="and"), placement="device")
    with pytest.raises(ValueError, match="fused tile arenas"):
        engine.plan(QueryBatch([[0, 1]], mode="and"), placement="fused")
    with pytest.raises(ValueError, match="unknown placement"):
        engine.plan(QueryBatch([[0, 1]], mode="and"), placement="gpu")


# --------------------------------------------------------------------------- #
# streams, warm-up, stats
# --------------------------------------------------------------------------- #

def test_open_loop_stream_parity_and_stats():
    engine = _engine(device=True)
    n = 16
    reqs = [Request([0, 2] if i % 2 == 0 else [1, 3], deadline_ms=2000,
                    tenant=f"t{i % 2}") for i in range(n)]
    offsets = poisson_offsets(n, rate_qps=2000.0, seed=5)
    results, stats = _serve(engine, reqs, offsets, max_batch=4,
                            max_wait_ms=3.0, tenants={"t0": 1.0, "t1": 2.0})
    assert stats.served == n and stats.shed == 0
    snap = stats.snapshot()
    assert snap["shed_rate"] == 0.0
    assert snap["latency_ms"]["p50"] <= snap["latency_ms"]["p99"] <= \
        snap["latency_ms"]["p999"]
    assert sum(stats.per_tenant[t]["served"] for t in ("t0", "t1")) == n
    assert sum(n_b * size for hist in snap["batch_hist"].values()
               for size, n_b in hist.items()) == n
    # every batch replays bitwise through the offline oracle
    for b in stats.batches:
        oracle = engine.execute(engine.plan(
            QueryBatch([list(q) for q in b.queries], mode=b.mode, k=b.k),
            placement=b.placement))
        for off, rid in zip(oracle, b.rids):
            assert np.array_equal(np.asarray(off), np.asarray(results[rid]))
    # trace stage stamps are monotone (the lint's contract)
    for tr in stats.traces:
        s = tr.stages()
        assert all(a <= b2 for a, b2 in zip(s, s[1:]))


def test_arrival_processes_are_seeded_and_distinct():
    a = poisson_offsets(64, 500.0, seed=9)
    b = poisson_offsets(64, 500.0, seed=9)
    assert np.array_equal(a, b)
    g = bursty_offsets(64, 500.0, seed=9, shape=0.25)
    assert not np.array_equal(a, g)
    # same mean rate, heavier clumping: larger interarrival variance
    assert np.diff(g, prepend=0.0).var() > np.diff(a, prepend=0.0).var()
    assert np.all(np.diff(a) >= 0) and np.all(np.diff(g) >= 0)


def test_warmup_populates_hot_term_score_cache():
    engine = _engine(device=True)
    server = IndexServer(engine, ServeConfig(warm_terms=3, max_batch=2))

    async def go():
        await server.start()
        await server.stop()

    asyncio.run(go())
    assert server.stats.warmup_s > 0.0
    gen = engine.idx.gen
    hot = sorted(gen.terms, key=lambda t: -gen.terms[t].df)[:3]
    skey = engine._cur().skey
    for t in hot:
        assert engine.score_cache.get((t,) + skey) is not None


def test_shed_at_batch_close_when_deadline_passed():
    """A request whose deadline expires while queued is shed with an
    explicit Rejected at batch close, not silently stalled.  With
    ``slack_ms=0`` a lone under-sized batch waits until exactly the seed's
    deadline before closing, so the close stamp lands strictly after the
    deadline and the shed branch must fire."""
    engine = _engine()

    async def go():
        server = IndexServer(engine, ServeConfig(
            max_batch=4, max_wait_ms=1000.0, slack_ms=0.0, warm_terms=2))
        await server.start()
        try:
            return await server.submit(Request([0, 1], deadline_ms=5.0))
        finally:
            await server.stop()

    got = asyncio.run(go())
    assert isinstance(got, Rejected) and got.reason == "deadline"
