"""Batched query engine: exact parity with brute force across all registered
codecs, the stream_vbyte short-list path, the decoded-block LRU (hit and
eviction paths), and the intersection kernels."""

import heapq

import numpy as np
import pytest

from repro.core import codec
from repro.index.invindex import SHORT, SHORT_CODEC, InvertedIndex
from repro.index.engine import B, K1, BlockCache, QueryBatch, QueryEngine
from repro.index import query as Q
from repro.kernels import intersect

RNG = np.random.default_rng(11)
N_DOCS = 2000


def small_corpus():
    """Synthetic index inputs small enough to build with every codec,
    including the python-loop scalar baselines: 12 terms, df 10..900 (both
    short-list and multi-block terms)."""
    doclen = RNG.integers(50, 400, N_DOCS).astype(np.int64)
    postings = {}
    for t, df in enumerate([10, 20, 40, 63, 64, 120, 300, 500, 700, 900, 55, 250]):
        ids = np.sort(RNG.choice(N_DOCS, df, replace=False)).astype(np.uint32)
        tfs = RNG.geometric(0.4, df).astype(np.uint32)
        postings[t] = (ids, tfs)
    return doclen, postings


DOCLEN, POSTINGS = small_corpus()
QUERIES = [RNG.choice(12, size=int(RNG.integers(2, 4)), replace=False).tolist()
           for _ in range(24)]


def brute_and(postings, terms):
    out = None
    for t in terms:
        ids = postings[t][0]
        out = ids if out is None else np.intersect1d(out, ids)
    return out.astype(np.uint32)


def brute_or_topk(doclen, postings, n_docs, terms, k):
    avdl = doclen.mean()
    acc = {}
    for t in terms:
        ids, tfs = postings[t]
        df = len(ids)
        idf = np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
        tf = tfs.astype(np.float64)
        sc = idf * tf * (K1 + 1) / (tf + K1 * (1 - B + B * doclen[ids] / avdl))
        for d, s in zip(ids.tolist(), sc.tolist()):
            acc[d] = acc.get(d, 0.0) + s
    return heapq.nlargest(k, acc.items(), key=lambda kv: kv[1])


@pytest.mark.parametrize("name", codec.names())
def test_batched_and_or_match_bruteforce(name):
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
    eng = QueryEngine(idx)
    got = eng.execute(QueryBatch(QUERIES, mode="and"))
    for q, res in zip(QUERIES, got):
        np.testing.assert_array_equal(res, brute_and(POSTINGS, q),
                                      err_msg=f"{name}/{q}")
        assert res.dtype == np.uint32
    top = eng.execute(QueryBatch(QUERIES[:6], mode="or", k=8))
    for q, res in zip(QUERIES[:6], top):
        want = brute_or_topk(DOCLEN, POSTINGS, N_DOCS, q, 8)
        assert len(res) == len(want)
        np.testing.assert_allclose(sorted(s for _, s in res),
                                   sorted(s for _, s in want), rtol=1e-12)
        assert all(res[i][1] >= res[i + 1][1] for i in range(len(res) - 1))


def test_short_lists_use_stream_vbyte():
    from repro.core import dense_bitmap
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    for t, (ids, _) in POSTINGS.items():
        # per-block, build picks: dense bitmap past the density cutoff, the
        # short-list codec under the df cutoff, the requested codec otherwise
        for bi, (_, encg, _) in enumerate(idx.terms[t].blocks):
            if dense_bitmap.eligible(ids[bi * 512:(bi + 1) * 512]):
                assert encg.codec == dense_bitmap.NAME, (t, bi, len(ids))
            elif len(ids) < SHORT:
                assert encg.codec == SHORT_CODEC, (t, bi, len(ids))
            else:
                assert encg.codec == "group_simple", (t, bi, len(ids))
    # both the dense and the sparse arm are actually exercised
    codecs = {encg.codec for tp in idx.terms.values() for _, encg, _ in tp.blocks}
    assert dense_bitmap.NAME in codecs and "group_simple" in codecs
    # short-list-only AND goes entirely through the stream_vbyte path
    got = QueryEngine(idx).and_query([0, 1, 2])
    np.testing.assert_array_equal(got, brute_and(POSTINGS, [0, 1, 2]))


def test_one_shot_helpers_match_seed_reference():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_pfd")
    for q in QUERIES:
        np.testing.assert_array_equal(Q.and_query(idx, q), Q.and_query_ref(idx, q))
        scored = Q.and_query_scored(idx, q, k=5)
        docs = Q.and_query(idx, q)
        assert len(scored) == min(5, len(docs))
    # unknown terms are ignored, all-unknown -> empty
    assert len(Q.and_query(idx, [999])) == 0
    assert Q.or_query(idx, [999]) == []


def test_block_cache_hit_and_eviction_paths():
    c = BlockCache(2)
    assert c.get((0, 0, 0)) is None
    c.put((0, 0, 0), "a")
    c.put((0, 1, 0), "b")
    assert c.get((0, 0, 0)) == "a"          # hit refreshes LRU order
    c.put((0, 2, 0), "c")                   # evicts (0,1,0), the LRU entry
    assert c.get((0, 1, 0)) is None
    assert c.get((0, 0, 0)) == "a"
    assert c.evictions == 1 and c.hits == 2
    # capacity 0 disables caching entirely
    c0 = BlockCache(0)
    c0.put("k", "v")
    assert c0.get("k") is None and len(c0) == 0


def test_engine_cache_reuse_and_eviction_correctness():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    eng = QueryEngine(idx)
    r1 = eng.execute(QueryBatch(QUERIES, mode="and"))
    h1 = eng.cache.hits
    r2 = eng.execute(QueryBatch(QUERIES, mode="and"))
    assert eng.cache.hits > h1              # second pass served from cache
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a, b)
    # a pathologically small cache must evict constantly yet stay exact
    tiny = QueryEngine(idx, cache_blocks=2, cache_score_terms=1)
    r3 = tiny.execute(QueryBatch(QUERIES, mode="and"))
    assert tiny.cache.evictions > 0
    for a, b in zip(r1, r3):
        np.testing.assert_array_equal(a, b)


def test_score_cache_eviction_and_recompute_on_miss():
    """cache_score_terms bounds the BM25 score-vector cache: a capacity-1
    cache under multi-term OR queries must evict, recompute evicted terms on
    the next miss, and stay exact throughout."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    ref = QueryEngine(idx)
    eng = QueryEngine(idx, cache_score_terms=1)
    queries = [[5, 9, 7], [9, 5], [7, 9, 5]] * 3
    want = ref.execute(QueryBatch(queries, mode="or", k=8))
    got = eng.execute(QueryBatch(queries, mode="or", k=8))
    assert want == got
    assert eng.score_cache.evictions > 0
    assert eng.score_cache.cost_used <= eng.score_cache.capacity
    # an evicted term recomputes on miss with an identical score vector
    ids0, sc0 = map(np.copy, eng.term_scores(5))
    eng.term_scores(9)                      # capacity 1: evicts term 5
    assert eng.score_cache.get(5) is None   # miss (recorded as such)
    misses = eng.score_cache.misses
    ids1, sc1 = eng.term_scores(5)          # recompute path
    assert eng.score_cache.misses == misses + 1
    np.testing.assert_array_equal(ids0, ids1)
    np.testing.assert_array_equal(sc0, sc1)
    # recomputed vectors serve OR queries exactly
    assert eng.or_query([5, 9], k=6) == ref.or_query([5, 9], k=6)


def test_score_cache_zero_capacity_always_recomputes():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    eng = QueryEngine(idx, cache_score_terms=0)
    r1 = eng.or_query([5, 9, 2], k=5)
    r2 = eng.or_query([5, 9, 2], k=5)
    assert r1 == r2 == QueryEngine(idx).or_query([5, 9, 2], k=5)
    assert len(eng.score_cache) == 0 and eng.score_cache.hits == 0


def test_zero_posting_term_does_not_crash():
    postings = dict(POSTINGS)
    postings[99] = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    idx = InvertedIndex.build(DOCLEN, postings, codec="group_simple")
    eng = QueryEngine(idx)
    assert len(eng.and_query([99])) == 0
    assert len(eng.and_query([99, 0])) == 0
    assert eng.or_query([99]) == []


def test_single_term_result_mutation_does_not_corrupt_cache():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    eng = QueryEngine(idx)
    r = eng.and_query([5])
    assert r.flags.writeable                 # results are caller-owned
    r[0] = 12345
    np.testing.assert_array_equal(eng.and_query([5]), POSTINGS[5][0])
    np.testing.assert_array_equal(eng.and_query([5, 9]), brute_and(POSTINGS, [5, 9]))
    # cache-backed accessors hand out frozen arrays
    with pytest.raises(ValueError):
        eng.term_ids(5)[0] = 1


def test_batch_results_align_with_input_order():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    eng = QueryEngine(idx)
    queries = [[9, 8], [0, 1], [9, 8], [5, 6, 7], [0, 1]]
    got = eng.execute(QueryBatch(queries, mode="and"))
    for q, res in zip(queries, got):
        np.testing.assert_array_equal(res, brute_and(POSTINGS, q))


# --------------------------------------------------------------------------- #
# intersection kernels
# --------------------------------------------------------------------------- #


def _sorted_unique(rng, n, hi):
    return np.sort(rng.choice(hi, size=min(n, hi), replace=False)).astype(np.uint32)


@pytest.mark.parametrize("na,nb,hi", [(0, 10, 100), (10, 0, 100), (5, 1000, 4000),
                                      (300, 400, 600), (1000, 1000, 1 << 20),
                                      (512, 4096, 5000)])
def test_intersection_kernels_match_intersect1d(na, nb, hi):
    rng = np.random.default_rng(na * 7919 + nb)
    a, b = _sorted_unique(rng, na, hi), _sorted_unique(rng, nb, hi)
    want = np.intersect1d(a, b)
    np.testing.assert_array_equal(intersect.gallop_intersect_np(a, b), want)
    np.testing.assert_array_equal(intersect.bitmap_intersect_np(a, b), want)
    np.testing.assert_array_equal(intersect.intersect_sorted(a, b), want)


def test_gallop_contains_jnp_matches_np():
    rng = np.random.default_rng(0)
    hay = _sorted_unique(rng, 500, 3000)
    needles = _sorted_unique(rng, 200, 3000)
    want = intersect.gallop_contains_np(hay, needles)
    import jax.numpy as jnp
    got = np.asarray(intersect.gallop_contains_jnp(jnp.asarray(hay), jnp.asarray(needles)))
    np.testing.assert_array_equal(got, want)


def test_bitmap_and_pallas_kernel_matches_host():
    rng = np.random.default_rng(1)
    for nwords in (7, 128, 300):
        wa = rng.integers(0, 1 << 32, nwords, dtype=np.uint64).astype(np.uint32)
        wb = rng.integers(0, 1 << 32, nwords, dtype=np.uint64).astype(np.uint32)
        got = intersect.bitmap_and_words(wa, wb, use_pallas=True)
        np.testing.assert_array_equal(got, wa & wb)
