"""2-stage pipeline parallelism: parity with sequential layer application."""

import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_2stage_matches_sequential():
    code = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_2stage
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh((2, 4), ('pod', 'data'))
    rng = np.random.default_rng(0)
    L, D, n_micro, mb = 4, 16, 3, 8
    Ws = jnp.asarray(rng.normal(0, 0.5, (L, D, D)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (n_micro, mb, D)).astype(np.float32))

    def layer(w, h):
        return jnp.tanh(h @ w)

    got = jax.jit(lambda Ws, x: pipeline_2stage(layer, Ws, x, mesh))(Ws, x)
    ref = x
    for l in range(L):
        ref = jnp.tanh(ref @ Ws[l])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)
    print('OK pipeline parity', got.shape)
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK pipeline parity" in r.stdout
