"""Compressed data pipeline + inverted index behaviour tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import synth
from repro.data.pipeline import AdjacencyStore, BagStore, TokenStore, lm_batch_iter
from repro.index.invindex import InvertedIndex
from repro.index import query as Q
from repro.models.sampler import CSRGraph


def test_token_store_roundtrip_and_ratio():
    rng = np.random.default_rng(0)
    toks = np.minimum(rng.zipf(1.3, 200000), 49151).astype(np.uint32)
    st_ = TokenStore.build(toks, codec="bp128", block=4096)
    np.testing.assert_array_equal(st_.read(0, len(toks)), toks)
    np.testing.assert_array_equal(st_.read(5000, 1234), toks[5000:6234])
    assert st_.compressed_bytes() < st_.raw_bytes


def test_lm_batch_iter_deterministic_resume():
    toks = np.arange(100000, dtype=np.uint32) % 1000
    store = TokenStore.build(toks, codec="group_simple", block=8192)
    it = lm_batch_iter(store, batch=4, seq=16)
    b0, c = it(0)
    b0again, _ = it(0)
    np.testing.assert_array_equal(b0["tokens"], b0again["tokens"])
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])


def test_adjacency_store_roundtrip():
    g = CSRGraph.random(500, 20000, 0)
    st_ = AdjacencyStore.build(g.indptr, g.indices, codec="group_pfd")
    for r in (0, 13, 499):
        want = np.sort(g.indices[g.indptr[r]:g.indptr[r + 1]])
        np.testing.assert_array_equal(st_.neighbors(r), want)
    assert st_.compressed_bytes() < st_.raw_bytes


def test_bag_store_roundtrip():
    rng = np.random.default_rng(1)
    bags = [rng.choice(10000, size=rng.integers(5, 60), replace=False) for _ in range(50)]
    st_ = BagStore.build(bags)
    for i in (0, 25, 49):
        np.testing.assert_array_equal(st_.read(i), np.sort(bags[i]))


def test_index_and_query_vs_bruteforce():
    doclen, postings = synth.make_corpus("wikipedia")
    idx = InvertedIndex.build(doclen, postings, codec="group_simple")
    t1, t2 = sorted(postings)[:2]
    got = Q.and_query(idx, [t1, t2])
    want = np.intersect1d(postings[t1][0], postings[t2][0])
    np.testing.assert_array_equal(np.sort(got), want)
    top = Q.or_query(idx, [t1, t2], k=5)
    assert len(top) == 5
    assert top[0][1] >= top[-1][1]


def test_index_decode_term_with_skip():
    doclen, postings = synth.make_corpus("twitter")
    t = max(postings, key=lambda k: len(postings[k][0]))
    idx = InvertedIndex.build(doclen, postings, codec="bp128")
    ids_all, tfs_all = idx.decode_term(t)
    np.testing.assert_array_equal(ids_all, postings[t][0])
    np.testing.assert_array_equal(tfs_all, postings[t][1])
    mid = int(postings[t][0][len(postings[t][0]) // 2])
    ids_skip, _ = idx.decode_term(t, min_docid=mid)
    assert ids_skip[-1] == ids_all[-1]
    assert len(ids_skip) <= len(ids_all)
    assert mid in ids_skip or mid not in ids_all


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 500))
def test_property_index_sizes_consistent(df):
    docids = np.sort(np.random.default_rng(df).choice(10000, df, replace=False)).astype(np.uint32)
    tfs = np.ones(df, np.uint32)
    idx = InvertedIndex.build(np.full(10000, 100), {0: (docids, tfs)}, codec="group_simple")
    got, gtf = idx.decode_term(0)
    np.testing.assert_array_equal(got, docids)


def test_dataset_stats_match_paper_characteristics():
    for name in synth.DATASETS:
        stats = synth.dataset_stats(synth.make_dataset(name))
        assert stats["gap_fit8"] > 0.9 or stats["gap_mean"] < 300, (name, stats)
        assert stats["tf_fit8"] > 0.9, (name, stats)
