"""Fault-tolerance: crash/resume bit-exactness, corruption detection,
straggler watchdog."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import train_loop as TL
from repro.runtime.trainer import make_train_step


def _setup():
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    rng = np.random.default_rng(0)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    Y = (X @ np.asarray([1., -2., 3., .5], np.float32)).astype(np.float32)

    def batch_iter(cursor):
        i = cursor % 4
        return {"x": jnp.asarray(X[i * 16:(i + 1) * 16]),
                "y": jnp.asarray(Y[i * 16:(i + 1) * 16])}, cursor + 1

    ocfg = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=40, weight_decay=0.0)
    step = jax.jit(make_train_step(loss_fn, ocfg))
    p0 = {"w": jnp.zeros(4), "b": jnp.zeros(())}
    return step, p0, batch_iter


def test_crash_resume_bit_exact():
    step, p0, batch_iter = _setup()
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        cfg = TL.LoopConfig(total_steps=40, ckpt_dir=d1, ckpt_every=10, log_every=1000)
        pA, _, _ = TL.run(step, p0, adamw_init(p0), batch_iter, cfg, log_fn=lambda *a: None)
        cfg2 = TL.LoopConfig(total_steps=40, ckpt_dir=d2, ckpt_every=10,
                             log_every=1000, crash_at_step=23)
        with pytest.raises(RuntimeError):
            TL.run(step, p0, adamw_init(p0), batch_iter, cfg2, log_fn=lambda *a: None)
        cfg3 = TL.LoopConfig(total_steps=40, ckpt_dir=d2, ckpt_every=10, log_every=1000)
        pB, _, _ = TL.run(step, p0, adamw_init(p0), batch_iter, cfg3, log_fn=lambda *a: None)
        for k in pA:
            np.testing.assert_array_equal(np.asarray(pA[k]), np.asarray(pB[k]))


def test_corrupted_checkpoint_detected_and_skipped():
    step, p0, batch_iter = _setup()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        state = (p0, adamw_init(p0))
        ck.save(10, state, {"cursor": 10})
        ck.save(20, state, {"cursor": 20})
        # corrupt the newest checkpoint's array blob
        path = os.path.join(d, "step_000020", "arrays.npz")
        with open(path, "r+b") as f:
            f.seek(200)
            f.write(b"\xde\xad\xbe\xef" * 8)
        restored, step_got, extra = ck.restore(state)
        assert step_got == 10 and extra["cursor"] == 10


def test_straggler_watchdog_flags_slow_steps():
    dog = TL.StragglerWatchdog(factor=3.0)
    for i in range(10):
        dog.observe(i, 0.01)
    assert dog.observe(10, 0.2)          # 20x the EMA -> flagged
    assert len(dog.flagged) == 1
    assert not dog.observe(11, 0.012)


def test_checkpoint_gc_keeps_last_k():
    step, p0, _ = _setup()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"w": jnp.zeros(3)}, {})
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_"))
        assert steps == [3, 4]
        assert latest_step(d) == 4
