"""Stateful differential harness for the streaming mutable index.

The acceptance gate of the LSM mutation subsystem (``repro.index.segments`` +
the generation-aware engine): a randomized interleaving of insert / delete /
compact / query steps runs against a plain-dict numpy oracle, and EVERY query
step asserts bit-identical results — docids for ``and``, (docid, score) pairs
for ``or`` / ``and_scored`` — across the host, device, and fused placements
versus a from-scratch rebuild (``InvertedIndex.build(doclen_now,
live_postings)`` served by a fresh host engine).  The device engines persist
across the whole run, so generation swaps, tombstone epochs, and cache keying
are exercised exactly the way a serving process would hit them; the zero
-sync contract (no per-round candidate/score downloads, tombstone gating is
upload-only) is asserted at the end of every run.

Under real ``hypothesis`` the same model also runs as a
``RuleBasedStateMachine``; under the conftest shim (no stateful API) the
seeded interleaving loops below are the workhorse — they execute well over
200 randomized steps per run by construction (``N_STEPS``).
"""

from __future__ import annotations

import numpy as np
import pytest

import hypothesis

from repro.index.invindex import InvertedIndex
from repro.index.engine import QueryBatch, QueryEngine

N_STEPS = 240           # per seeded run; the ISSUE acceptance floor is 200
QUERY_EVERY = 6         # differential check cadence within a run
MODES = ("and", "or", "and_scored")
K = 5


class MutationModel:
    """The differential model: a mutable index under test, three persistent
    engines (host / device / fused), and a plain-dict oracle of the live
    corpus that can be rebuilt from scratch at any step."""

    def __init__(self, doclen, postings, codec, n_terms, device=True):
        self.codec = codec
        self.n_terms = n_terms
        self.idx = InvertedIndex.build(doclen, postings, codec=codec)
        # oracle truth: docid -> {term: tf} for LIVE docs (every base doc is
        # live at the start, postings or not); docid -> last-set doclen for
        # every docid ever seen (deletes don't erase doclens)
        self.live: dict = {d: {} for d in range(len(doclen))}
        self.dl: dict = {d: int(l) for d, l in enumerate(doclen)}
        for t, (ids, tfs) in postings.items():
            for d, f in zip(ids.tolist(), tfs.tolist()):
                self.live[int(d)][int(t)] = int(f)
        self.base_docs = len(doclen)
        self.engines = [("host", QueryEngine(self.idx))]
        if device:
            self.engines += [
                ("device", QueryEngine(self.idx).to_device(fused=False)),
                ("fused", QueryEngine(self.idx).to_device(fused=True))]
        self.steps = 0

    # ---- mutation rules ----------------------------------------------------- #

    def insert(self, docid, terms, doclen):
        self.idx.insert(docid, terms, doclen)
        self.live[docid] = dict(terms)
        self.dl[docid] = int(doclen)
        self.steps += 1

    def delete(self, docid):
        got = self.idx.delete(docid)
        if docid in self.live:
            assert got, f"delete({docid}) missed a live doc"
        # the converse is NOT asserted: a postings-less docid inside the
        # append-only doc space reports True once per generation (its doclen
        # survives compaction, so the index — exactly like a from-scratch
        # rebuild — cannot distinguish it from a live doc with no postings);
        # query parity below is the authoritative liveness check
        self.live.pop(docid, None)
        self.steps += 1

    def compact(self):
        gid = self.idx.gen.gid
        gen = self.idx.compact()
        assert gen.gid == gid + 1
        assert not self.idx.mutated
        self.steps += 1

    # ---- the differential query step ---------------------------------------- #

    def oracle(self):
        """Rebuild the index from scratch from the oracle dicts — the bitwise
        parity target for every placement and mode."""
        space = max(max(self.dl, default=-1) + 1, self.base_docs)
        doclen = np.zeros(space, np.int64)
        for d, l in self.dl.items():
            doclen[d] = l
        postings: dict = {}
        for d in sorted(self.live):
            for t, f in self.live[d].items():
                postings.setdefault(t, ([], []))
                postings[t][0].append(d)
                postings[t][1].append(f)
        postings = {t: (np.asarray(ids, np.uint32), np.asarray(tfs, np.uint32))
                    for t, (ids, tfs) in postings.items()}
        return QueryEngine(InvertedIndex.build(doclen, postings,
                                               codec=self.codec))

    def check_queries(self, queries):
        """Assert bit-identical results vs the rebuilt oracle for every mode
        on every placement."""
        ora = self.oracle()
        for mode in MODES:
            batch = QueryBatch(queries, mode=mode, k=K)
            want = ora.execute(batch)
            for name, eng in self.engines:
                got = eng.execute(QueryBatch(queries, mode=mode, k=K))
                for q, w, g in zip(queries, want, got):
                    where = f"{name}/{mode}/{q} @step {self.steps}"
                    if mode == "and":
                        np.testing.assert_array_equal(g, w, err_msg=where)
                        assert g.dtype == np.uint32, where
                    else:
                        # bitwise: float equality, order, and docid ties
                        assert g == w, f"{where}: {g} != {w}"
        self.steps += 1

    def assert_zero_syncs(self):
        """The resident paths must not have added ANY per-round host syncs
        under mutation: tombstone gating is upload-only."""
        for name, eng in self.engines:
            if name == "host":
                continue
            assert eng.dev_stats["cand_syncs"] == 0, name
            assert eng.dev_stats["score_syncs"] == 0, name
            assert eng.dev_stats["final_syncs"] > 0, name
            assert eng.dev_stats["tomb_gates"] > 0, name


def _seed_corpus(rng, n_docs, n_terms):
    doclen = rng.integers(20, 200, n_docs).astype(np.int64)
    postings = {}
    for t in range(n_terms):
        df = int(rng.integers(5, max(6, n_docs // 2)))
        ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    return doclen, postings


def _random_doc(rng, n_terms):
    terms = {int(t): int(rng.integers(1, 6))
             for t in rng.choice(n_terms, int(rng.integers(1, 4)),
                                 replace=False)}
    return terms, int(rng.integers(5, 120))


def _random_queries(rng, n_terms, nq=4):
    return [rng.choice(n_terms, size=int(rng.integers(1, 4)),
                       replace=False).tolist() for _ in range(nq)]


def _run_interleaving(model, rng, n_steps):
    """The seeded fallback for hypothesis' stateful driver: a weighted random
    interleaving of the model's rules, with a differential query check every
    ``QUERY_EVERY`` steps and once more at the end."""
    next_docid = model.base_docs
    while model.steps < n_steps:
        op = rng.random()
        if model.steps % QUERY_EVERY == QUERY_EVERY - 1:
            model.check_queries(_random_queries(rng, model.n_terms))
        elif op < 0.40:
            # mix of fresh docids, upserts of base docs, upserts of delta docs
            r = rng.random()
            if r < 0.5:
                d, next_docid = next_docid, next_docid + 1
            elif r < 0.8:
                d = int(rng.integers(0, model.base_docs))
            else:
                d = int(rng.integers(model.base_docs, next_docid + 1))
            terms, dl = _random_doc(rng, model.n_terms)
            model.insert(d, terms, dl)
        elif op < 0.70:
            model.delete(int(rng.integers(0, next_docid + 2)))
        elif op < 0.78 and model.idx.mutated:
            model.compact()
        else:
            model.delete(int(rng.integers(0, model.base_docs)))
    model.check_queries(_random_queries(rng, model.n_terms))


@pytest.mark.parametrize("codec,seed", [("group_simple", 0),
                                        ("group_pfd", 1)])
def test_stateful_mutation_differential(codec, seed):
    """The acceptance harness: >= 200 randomized insert/delete/compact/query
    steps, every query step bit-identical to the rebuild-from-scratch oracle
    across host/device/fused and all three modes — including the exception
    -bearing ``group_pfd`` codec — with zero per-round syncs preserved."""
    rng = np.random.default_rng(seed)
    doclen, postings = _seed_corpus(rng, n_docs=400, n_terms=8)
    model = MutationModel(doclen, postings, codec, n_terms=8)
    _run_interleaving(model, rng, N_STEPS)
    assert model.steps >= 200
    model.assert_zero_syncs()


def test_delta_only_corpus_all_placements():
    """A corpus living ENTIRELY in the delta segment (the generation has docs
    but zero terms): every mode and placement must serve it bit-identically
    to the rebuilt oracle, before and after its first compaction."""
    rng = np.random.default_rng(7)
    model = MutationModel(np.full(10, 25, np.int64), {}, "group_pfd",
                          n_terms=5)
    for _ in range(30):
        terms, dl = _random_doc(rng, 5)
        model.insert(int(rng.integers(0, 40)), terms, dl)
    model.check_queries([[0, 1], [2], [3, 4, 0], [1, 2, 3]])
    model.compact()
    model.check_queries([[0, 1], [2], [3, 4, 0], [1, 2, 3]])
    model.assert_zero_syncs()


def test_tombstone_only_mutation():
    """Deletes with an empty delta segment: the pure live-bitmap-gate path
    (no delta union at all), checked across all placements and modes."""
    rng = np.random.default_rng(3)
    doclen, postings = _seed_corpus(rng, n_docs=300, n_terms=6)
    model = MutationModel(doclen, postings, "group_simple", n_terms=6)
    for d in rng.choice(300, 40, replace=False).tolist():
        model.delete(int(d))
    assert not model.idx.delta and model.idx.tomb
    model.check_queries(_random_queries(rng, 6, nq=5))
    model.assert_zero_syncs()


# --------------------------------------------------------------------------- #
# generation pinning
# --------------------------------------------------------------------------- #


def _pin_fixture():
    rng = np.random.default_rng(11)
    doclen, postings = _seed_corpus(rng, n_docs=350, n_terms=6)
    idx = InvertedIndex.build(doclen, postings, codec="group_pfd")
    return rng, idx


@pytest.mark.parametrize("fused", [False, True])
def test_plan_pins_generation_across_compact(fused):
    """A plan built before ``compact()`` keeps executing bit-identically
    against its pinned generation + epoch, while a fresh plan (same engine)
    serves the new generation."""
    rng, idx = _pin_fixture()
    eng = QueryEngine(idx).to_device(fused=fused)
    queries = [[0, 1], [2, 3, 4], [1, 5], [0, 2]]
    for mode in MODES:
        plans = {mode: eng.plan(QueryBatch(queries, mode=mode, k=K))}
    plans = {m: eng.plan(QueryBatch(queries, mode=m, k=K)) for m in MODES}
    before = {m: eng.execute(plans[m]) for m in MODES}
    # mutate + compact underneath the pinned plans
    for d in (3, 50, 51, 120):
        idx.delete(d)
    idx.insert(5, {0: 4, 1: 1}, 30)
    idx.insert(360, {2: 2}, 15)
    old_gid = plans["and"].ctx.gen.gid
    idx.compact()
    assert idx.gen.gid == old_gid + 1
    for m in MODES:
        after = eng.execute(plans[m])        # pinned: pre-mutation results
        for w, g in zip(before[m], after):
            if m == "and":
                np.testing.assert_array_equal(g, w)
            else:
                assert g == w
    # a fresh plan sees the new generation and the post-compact truth
    fresh = eng.plan(QueryBatch(queries, mode="and"))
    assert fresh.ctx.gen.gid == old_gid + 1
    want = QueryEngine(idx).execute(QueryBatch(queries, mode="and"))
    for w, g in zip(want, eng.execute(fresh)):
        np.testing.assert_array_equal(g, w)


def test_plan_pins_mutation_epoch_without_compact():
    """Pinning is per epoch, not just per generation: a plan snapshots the
    delta/tombstone state at plan time, so later writes don't leak in."""
    rng, idx = _pin_fixture()
    eng = QueryEngine(idx).to_device(fused=False)
    idx.delete(10)
    idx.insert(400, {0: 2, 3: 1}, 20)
    queries = [[0, 3], [1, 2], [0, 1, 2]]
    plan = eng.plan(QueryBatch(queries, mode="and_scored", k=K))
    before = eng.execute(plan)
    idx.delete(0)                   # post-plan writes...
    idx.insert(401, {0: 9}, 10)
    assert eng.execute(plan) == before   # ...invisible to the pinned plan
    live_now = eng.execute(eng.plan(QueryBatch(queries, mode="and_scored",
                                               k=K)))
    assert live_now != before       # docid 0 had term-0 postings in seed df


@pytest.mark.parametrize("fused", [False, True])
def test_tombstone_only_ranked_superset_contract(fused):
    """Ranked top-k under tombstones WITHOUT compaction: the device candidate
    set (quantization-margin superset, live-gated) must still contain the
    true top-k — results bit-identical to the rebuilt oracle — and deleted
    docs must never appear."""
    rng, idx = _pin_fixture()
    dead = sorted(int(d) for d in rng.choice(350, 60, replace=False))
    for d in dead:
        idx.delete(d)
    eng = QueryEngine(idx).to_device(fused=fused)
    queries = [[0, 1, 2], [3, 4], [1, 5], [2, 4, 5]]
    # rebuild-from-scratch oracle (host) for the same tombstoned corpus
    model = MutationModel(np.zeros(0, np.int64), {}, "group_pfd", 6,
                          device=False)
    model.idx = idx
    ora = None
    doclen = np.asarray(idx.doclen_now())
    postings = {}
    deadset = set(dead)
    for t in range(6):
        ids, tfs = idx.gen.decode_term(t)
        keep = [j for j, d in enumerate(ids.tolist()) if d not in deadset]
        if keep:
            postings[t] = (ids[keep], tfs[keep])
    ora = QueryEngine(InvertedIndex.build(doclen, postings, codec="group_pfd"))
    for mode in ("or", "and_scored"):
        want = ora.execute(QueryBatch(queries, mode=mode, k=K))
        got = eng.execute(QueryBatch(queries, mode=mode, k=K))
        assert got == want, mode
        for res in got:
            assert not any(d in deadset for d, _ in res)
    assert eng.dev_stats["score_syncs"] == 0
    assert eng.dev_stats["tomb_gates"] > 0


# --------------------------------------------------------------------------- #
# generation-keyed caches (the stale-cache regression)
# --------------------------------------------------------------------------- #


def test_caches_keyed_by_generation_not_stale_after_compact():
    """The (term, block) LRU and the score cache must be keyed by generation
    / epoch: after a ``compact()`` that rewrites a term's blocks in place
    (same term id, same block index, different postings), a warm engine must
    serve the NEW postings.  Single-generation keying fails this test by
    serving the evicted generation's decoded blocks and score vectors."""
    rng, idx = _pin_fixture()
    eng = QueryEngine(idx)
    queries = [[0, 1], [0], [1, 2]]
    eng.execute(QueryBatch(queries, mode="and"))        # warm block cache
    eng.execute(QueryBatch(queries, mode="or", k=K))    # warm score cache
    gid0 = idx.gen.gid
    keys0 = set(eng.cache.keys())
    assert keys0 and all(k[-1] == gid0 for k in keys0)
    # rewrite term 0's first block: delete some of its early postings and
    # insert a brand-new doc carrying term 0, then compact
    t0_ids = idx.gen.decode_term(0)[0]
    for d in t0_ids[:5].tolist():
        idx.delete(int(d))
    idx.insert(500, {0: 3, 1: 1}, 40)
    idx.compact()
    want = QueryEngine(idx).execute(QueryBatch(queries, mode="and"))
    got = eng.execute(QueryBatch(queries, mode="and"))
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)     # stale gen-0 blocks would differ
    assert any(k[-1] == gid0 + 1 for k in eng.cache.keys())
    want = QueryEngine(idx).execute(QueryBatch(queries, mode="or", k=K))
    assert eng.execute(QueryBatch(queries, mode="or", k=K)) == want
    # score-cache entries carry the full epoch key (term, gid, tomb_v, delta_v)
    assert any(k[1] == gid0 + 1 for k in eng.score_cache.keys())


def test_score_cache_keyed_by_tombstone_epoch():
    """Score vectors depend on live df/avdl, so even a tombstone WITHOUT
    compaction must miss the old cache entry."""
    rng, idx = _pin_fixture()
    eng = QueryEngine(idx)
    r0 = eng.or_query([0, 1], k=K)
    ids0 = idx.gen.decode_term(0)[0]
    idx.delete(int(ids0[0]))                # changes term 0's df and scores
    r1 = eng.or_query([0, 1], k=K)
    want = QueryEngine(idx).or_query([0, 1], k=K)
    assert r1 == want
    assert r1 != r0


# --------------------------------------------------------------------------- #
# hypothesis stateful machine (runs under real hypothesis; the conftest shim
# has no stateful API, so the seeded interleavings above are the fallback)
# --------------------------------------------------------------------------- #

if not getattr(hypothesis, "__is_repro_shim__", False):
    from hypothesis import settings, strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)

    class MutationMachine(RuleBasedStateMachine):
        """hypothesis drives the same MutationModel the seeded loops use;
        host placement only (device jit per shrunken example is too slow for
        a stateful search) — the seeded loops cover the device placements."""

        @initialize()
        def setup(self):
            rng = np.random.default_rng(0)
            doclen, postings = _seed_corpus(rng, n_docs=60, n_terms=4)
            self.model = MutationModel(doclen, postings, "group_pfd",
                                       n_terms=4, device=False)
            self.next_docid = 60

        @rule(fresh=st.booleans(), docid=st.integers(0, 80),
              tf=st.integers(1, 5), dl=st.integers(1, 50),
              term=st.integers(0, 3))
        def insert(self, fresh, docid, tf, dl, term):
            if fresh:
                docid, self.next_docid = self.next_docid, self.next_docid + 1
            self.model.insert(docid, {term: tf}, dl)

        @rule(docid=st.integers(0, 90))
        def delete(self, docid):
            self.model.delete(docid)

        @rule()
        def compact(self):
            self.model.compact()

        @rule(q=st.lists(st.integers(0, 4), min_size=1, max_size=3))
        def query(self, q):
            self.model.check_queries([q, q[:1]])

        @invariant()
        def doc_space_is_append_only(self):
            assert self.model.idx.doc_space >= self.model.base_docs

    MutationMachine.TestCase.settings = settings(
        max_examples=15, stateful_step_count=25, deadline=None)
    TestMutationMachine = MutationMachine.TestCase
