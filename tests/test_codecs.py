"""Round-trip + equivalence tests for every codec (numpy oracle, JAX scalar,
JAX vectorized), including hypothesis property tests on the system invariant
decode(encode(x)) == x."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codec
from repro.core import dgap, layout
from repro.core.bits import ebw_np, pack_bits_np, gather_bits_np, unary_stream_np, unary_decode_np

RNG = np.random.default_rng(42)

CASES = {
    "uniform_small": RNG.integers(0, 256, 4096).astype(np.uint32),
    "zipf_tail": np.minimum(RNG.zipf(1.3, 4096), 2**27 - 1).astype(np.uint32),
    "dgap_like": RNG.geometric(0.3, 4096).astype(np.uint32),
    "zeros": np.zeros(100, np.uint32),
    "all_max27": np.full(130, 2**27 - 1, np.uint32),
    "single": np.array([7], np.uint32),
    "len2": np.array([0, 2**20], np.uint32),
    "exceptions": np.where(RNG.random(4096) < 0.08,
                            RNG.integers(1 << 20, 1 << 27, 4096),
                            RNG.integers(0, 64, 4096)).astype(np.uint32),
    "ramp": np.arange(1, 1000, dtype=np.uint32),
    # adversarial corpus for the differential sweep
    "all_max32": np.full(40, 2**32 - 1, np.uint32),
    "single_outlier": np.concatenate([RNG.integers(0, 8, 1280, dtype=np.int64),
                                      [1 << 26]]).astype(np.uint32)[RNG.permutation(1281)],
    "odd_len_257": RNG.integers(0, 1 << 16, 257, dtype=np.int64).astype(np.uint32),
    "block_minus_1": RNG.integers(0, 1 << 10, 127, dtype=np.int64).astype(np.uint32),
}

ALL = codec.names()
GROUP = codec.names(group_only=True)


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("case", list(CASES))
def test_roundtrip_numpy(name, case):
    spec = codec.get(name)
    x = CASES[case]
    if x.size and int(x.max()) >= 2**spec.max_bits:
        pytest.skip("value range unsupported by codec (paper §4.1.2)")
    enc = spec.encode(x)
    out = spec.decode(enc)
    np.testing.assert_array_equal(out, x)
    assert enc.n == len(x)
    assert enc.total_bits >= 0


@pytest.mark.parametrize("name", GROUP)
def test_group_jax_decoders_match_oracle(name):
    spec = codec.get(name)
    if spec.jax_args is None:
        pytest.skip("numpy/kernel-path codec (bp_tpu decodes via kernels/ref)")
    for case, x in CASES.items():
        if x.size and int(x.max()) >= 2**spec.max_bits:
            continue
        enc = spec.encode(x)
        args = spec.jax_args(enc)
        vec = np.asarray(spec.decode_jax_vec(**args))
        np.testing.assert_array_equal(vec, x, err_msg=f"{name}/{case}/vec")
        sca = np.asarray(spec.decode_jax_scalar(**args))
        np.testing.assert_array_equal(sca, x, err_msg=f"{name}/{case}/scalar")


@pytest.mark.parametrize("name", ALL)
def test_differential_sweep(name):
    """Every registered codec: decode(encode(x)) == x, and when JAX decoders
    exist, decode_jax_scalar == decode_jax_vec == numpy oracle — over the
    adversarial corpus (empty, all-zero, all-max, exception-heavy, lengths
    not a multiple of the block size)."""
    spec = codec.get(name)
    sweep = ["zeros", "all_max27", "all_max32", "single", "exceptions",
             "single_outlier", "odd_len_257", "block_minus_1"]
    for case in sweep + ["empty"]:
        x = np.zeros(0, np.uint32) if case == "empty" else CASES[case]
        if x.size and int(x.max()) >= 2**spec.max_bits:
            continue
        enc = spec.encode(x)
        oracle = spec.decode(enc)
        np.testing.assert_array_equal(oracle, x, err_msg=f"{name}/{case}/oracle")
        if spec.jax_args is None or enc.n == 0:
            continue
        args = spec.jax_args(enc)
        np.testing.assert_array_equal(np.asarray(spec.decode_jax_vec(**args)), x,
                                      err_msg=f"{name}/{case}/vec")
        np.testing.assert_array_equal(np.asarray(spec.decode_jax_scalar(**args)), x,
                                      err_msg=f"{name}/{case}/scalar")


def test_empty_input_all_codecs():
    x = np.zeros(0, np.uint32)
    for name in ALL:
        spec = codec.get(name)
        out = spec.decode(spec.encode(x))
        assert out.size == 0, name


# --------------------------------------------------------------------------- #
# hypothesis property tests
# --------------------------------------------------------------------------- #

uint27_arrays = st.lists(st.integers(0, 2**27 - 1), min_size=0, max_size=300).map(
    lambda v: np.asarray(v, np.uint32))
uint32_arrays = st.lists(st.integers(0, 2**32 - 1), min_size=0, max_size=300).map(
    lambda v: np.asarray(v, np.uint32))

# fast codecs get the full sweep; python-loop codecs get a lighter one
FAST = [n for n in ALL if n not in ("g8iu", "rice", "gamma", "simple9", "simple16")]


@settings(max_examples=25, deadline=None)
@given(uint27_arrays)
def test_property_roundtrip_small_values(x):
    for name in FAST:
        spec = codec.get(name)
        np.testing.assert_array_equal(spec.decode(spec.encode(x)), x, err_msg=name)


@settings(max_examples=15, deadline=None)
@given(uint32_arrays)
def test_property_roundtrip_full_range(x):
    for name in FAST:
        spec = codec.get(name)
        if x.size and int(x.max()) >= 2**spec.max_bits:
            continue
        np.testing.assert_array_equal(spec.decode(spec.encode(x)), x, err_msg=name)


@settings(max_examples=10, deadline=None)
@given(uint27_arrays)
def test_property_group_vec_equals_scalar(x):
    for name in ("group_simple", "group_scheme_8-IU", "group_scheme_1-CU", "group_pfd", "bp128"):
        spec = codec.get(name)
        enc = spec.encode(x)
        if enc.n == 0:
            continue
        args = spec.jax_args(enc)
        np.testing.assert_array_equal(
            np.asarray(spec.decode_jax_vec(**args)),
            np.asarray(spec.decode_jax_scalar(**args)), err_msg=name)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200))
def test_property_dgap_roundtrip(v):
    x = np.sort(np.asarray(v, np.uint32))
    g = dgap.dgap_encode_np(x)
    np.testing.assert_array_equal(dgap.dgap_decode_np(g), x)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2**32 - 1), st.integers(1, 64)), min_size=1, max_size=200))
def test_property_bitstream_roundtrip(pairs):
    vals = np.asarray([v & ((1 << b) - 1) for v, b in pairs], np.uint64)
    lens = np.asarray([b for _, b in pairs], np.int64)
    readable = lens <= 32      # gather reads up to 32 bits
    words, total = pack_bits_np(vals, lens)
    assert total == int(lens.sum())
    offs = np.cumsum(lens) - lens
    got = gather_bits_np(words, offs[readable], lens[readable])
    np.testing.assert_array_equal(got, vals[readable].astype(np.uint32))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 40), min_size=1, max_size=300))
def test_property_unary_roundtrip(counts):
    c = np.asarray(counts, np.int64)
    words, total = unary_stream_np(c)
    np.testing.assert_array_equal(unary_decode_np(words, total, len(c)), c)


# --------------------------------------------------------------------------- #
# paper-claim sanity: quad-max OR trick preserves effective bit width (§4.4)
# --------------------------------------------------------------------------- #


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2**32 - 1), min_size=4, max_size=64))
def test_property_pseudo_quadmax_same_ebw(v):
    x = np.asarray(v, np.uint32)
    pseudo = layout.quadmax_np(x, 4, pseudo=True)
    true = layout.quadmax_np(x, 4, pseudo=False)
    np.testing.assert_array_equal(ebw_np(pseudo), ebw_np(true))
