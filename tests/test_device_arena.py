"""Device/host parity for the posting arenas: batched arena decode and the
``device=True`` engine must be bit-identical to the numpy engine across every
registered group codec, including block-boundary (df == 512/513/1024) and
empty-intersection edge cases; the fused decode+AND kernel must match the
host intersection exactly; and the work-list discipline (<= 1 decode per hot
(term, block) per batch) must hold."""

import numpy as np
import pytest

from repro.core import codec
from repro.index.device import KIND_HOST, SUPPORTED, DeviceArena
from repro.index.engine import QueryBatch, QueryEngine
from repro.index.invindex import InvertedIndex

RNG = np.random.default_rng(1234)
N_DOCS = 1500

# df values straddle the short-list cutoff (64) and the 512-posting block
# boundary; the last two are docid-disjoint so AND over them is empty
DFS = [12, 63, 64, 200, 512, 513, 1024, 300, 280]


def _corpus():
    doclen = RNG.integers(40, 300, N_DOCS).astype(np.int64)
    postings = {}
    for t, df in enumerate(DFS[:-2]):
        ids = np.sort(RNG.choice(N_DOCS, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, RNG.geometric(0.4, df).astype(np.uint32))
    lo = np.sort(RNG.choice(N_DOCS // 2, DFS[-2], replace=False)).astype(np.uint32)
    hi = (np.sort(RNG.choice(N_DOCS // 2, DFS[-1], replace=False))
          + N_DOCS // 2).astype(np.uint32)
    postings[len(DFS) - 2] = (lo, RNG.geometric(0.4, DFS[-2]).astype(np.uint32))
    postings[len(DFS) - 1] = (hi, RNG.geometric(0.4, DFS[-1]).astype(np.uint32))
    return doclen, postings


DOCLEN, POSTINGS = _corpus()
NT = len(DFS)
QUERIES = ([RNG.choice(NT, size=int(RNG.integers(2, 4)), replace=False).tolist()
            for _ in range(12)]
           + [[NT - 2, NT - 1],          # disjoint -> empty intersection
              [4], [6],                  # single term, block-boundary terms
              [0, 999]])                 # unknown term ignored


def _engines(name, fused=False):
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
    return QueryEngine(idx), QueryEngine(idx, device=True, fused=fused)


@pytest.mark.parametrize("name", codec.names(group_only=True))
def test_device_engine_matches_host_engine(name):
    host, dev = _engines(name)
    want = host.execute(QueryBatch(QUERIES, mode="and"))
    got = dev.execute(QueryBatch(QUERIES, mode="and"))
    for q, a, b in zip(QUERIES, want, got):
        np.testing.assert_array_equal(a, b, err_msg=f"{name}/and/{q}")
        assert b.dtype == np.uint32
    assert (host.execute(QueryBatch(QUERIES[:5], mode="or", k=7))
            == dev.execute(QueryBatch(QUERIES[:5], mode="or", k=7))), name
    assert (host.execute(QueryBatch(QUERIES[:5], mode="and_scored", k=7))
            == dev.execute(QueryBatch(QUERIES[:5], mode="and_scored", k=7))), name


@pytest.mark.parametrize("name", ["group_simple", "bp128", "g_packed_binary",
                                  "group_pfd"])
def test_fused_decode_and_matches_host_engine(name):
    host, dev = _engines(name, fused=True)
    want = host.execute(QueryBatch(QUERIES, mode="and"))
    got = dev.execute(QueryBatch(QUERIES, mode="and"))
    for q, a, b in zip(QUERIES, want, got):
        np.testing.assert_array_equal(a, b, err_msg=f"{name}/fused/{q}")
    assert dev.arena.stats["fused_calls"] > 0   # the kernel actually ran


@pytest.mark.parametrize("name", ["group_simple", "bp128", "stream_vbyte",
                                  "group_scheme_8-IU"])
def test_arena_block_decode_matches_numpy_oracle(name):
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
    arena = DeviceArena.from_index(idx, build_fused=False)
    entries = [(t, bi, f) for t in idx.terms
               for bi in range(idx.n_blocks(t)) for f in (0, 1)]
    got = arena.decode_blocks(entries)
    for (t, bi, f), a in zip(entries, got):
        want = idx.decode_block_ids(t, bi) if f == 0 else idx.decode_block_tfs(t, bi)
        np.testing.assert_array_equal(a, want, err_msg=f"{name}/{t}/{bi}/{f}")
    if name in SUPPORTED:
        assert arena.stats["blocks_device"] > 0
        # short lists (< 64 postings) still fall back to stream_vbyte on host
        assert any(k == KIND_HOST for k, _ in arena._loc.values())


def test_device_worklist_decodes_each_hot_block_once():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    eng = QueryEngine(idx, cache_blocks=1 << 20, device=True)
    eng.execute(QueryBatch(QUERIES, mode="and"))
    # cold eviction-free cache: every decode is a distinct hot (term, block),
    # and the hot set is counted independently of the decode counters
    hot = {k for k in eng.cache.keys() if k[1] >= 0}
    decodes = (eng.dev_stats["worklist_decodes"]
               + eng.dev_stats["fallback_decodes"])
    assert decodes == len(hot)
    assert eng.dev_stats["fallback_decodes"] == 0
    assert eng.dev_stats["worklist_refs"] >= eng.dev_stats["worklist_decodes"]
    # a second pass over the same batch is fully cache-served
    before = eng.dev_stats["worklist_decodes"]
    r1 = eng.execute(QueryBatch(QUERIES, mode="and"))
    assert eng.dev_stats["worklist_decodes"] == before
    r0 = QueryEngine(idx).execute(QueryBatch(QUERIES, mode="and"))
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a, b)


def test_device_engine_eviction_pressure_stays_exact():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="bp128")
    host = QueryEngine(idx)
    tiny = QueryEngine(idx, cache_blocks=2, cache_score_terms=1, device=True)
    want = host.execute(QueryBatch(QUERIES, mode="and"))
    got = tiny.execute(QueryBatch(QUERIES, mode="and"))
    assert tiny.cache.evictions > 0
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)


def test_zero_posting_term_and_empty_results_on_device():
    postings = dict(POSTINGS)
    postings[99] = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    idx = InvertedIndex.build(DOCLEN, postings, codec="group_simple")
    eng = QueryEngine(idx, device=True, fused=True)
    res = eng.execute(QueryBatch([[99], [99, 0], [NT - 2, NT - 1]], mode="and"))
    for r in res:
        assert len(r) == 0 and r.dtype == np.uint32 and r.flags.writeable
    assert eng.or_query([99]) == []


def test_term_concat_empty_is_frozen_and_consistent():
    postings = dict(POSTINGS)
    postings[99] = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    idx = InvertedIndex.build(DOCLEN, postings, codec="group_simple")
    eng = QueryEngine(idx)
    v = eng.term_ids(99)
    assert len(v) == 0 and v.dtype == np.uint32
    # same contract as every other accessor: cache-backed arrays are frozen
    assert not v.flags.writeable
    assert not eng.term_tfs(99).flags.writeable
    np.testing.assert_array_equal(v, eng.term_ids(99))
    # but and_query results stay caller-owned
    assert eng.and_query([99]).flags.writeable


def test_invalid_mode_raises_on_both_paths():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    for eng in (QueryEngine(idx), QueryEngine(idx, device=True)):
        with pytest.raises(KeyError):
            eng.execute(QueryBatch([[0, 1]], mode="And"))


def test_fused_arena_buckets_by_block_bit_width():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    arena = idx.to_device()
    # the corpus mixes dense (df=1024) and sparse (df=64) terms, so blocks
    # must land in more than one width bucket and every block must be covered
    assert len(arena._pk) > 1
    assert set(arena._pk) <= set(arena.FUSED_BW_BUCKETS)
    covered = set(arena._pk_slot)
    assert covered == {(t, bi) for t in idx.terms
                       for bi in range(idx.n_blocks(t))}


def test_to_device_upgrades_unfused_arena_in_place():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    a1 = idx.to_device(build_fused=False)
    assert a1._pk is None
    a2 = idx.to_device(build_fused=True)     # cached arena gains fused tiles
    assert a2 is a1 and a1._pk is not None
    eng = QueryEngine(idx, device=True, fused=True)
    eng.execute(QueryBatch(QUERIES[:4], mode="and"))
    assert eng.arena.stats["fused_calls"] > 0


def test_to_device_is_cached_and_idempotent():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    a1 = idx.to_device()
    a2 = idx.to_device()
    assert a1 is a2
    eng = QueryEngine(idx).to_device()
    assert eng.arena is a1
    assert eng.to_device(fused=True) is eng and eng._fused
