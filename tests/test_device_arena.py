"""Device/host parity for the posting arenas: batched arena decode and the
device-placed engine must be bit-identical to the numpy engine across every
registered group codec, including block-boundary (df == 512/513/1024) and
empty-intersection edge cases; the fused decode+AND kernel must match the
host intersection exactly; and the work-list discipline (<= 1 decode per hot
(term, block) per batch) must hold.

The native-decode sweep derives its codec list from the registry's *declared*
arena capabilities (``codec.get(name).arena``), so a codec gaining an
``ArenaLayout`` is parity-tested automatically — no hand-maintained list."""

import numpy as np
import pytest

from repro.core import codec
from repro.index.device import DeviceArena
from repro.index.engine import ExecutionPlan, QueryBatch, QueryEngine
from repro.index.invindex import SHORT_CODEC, InvertedIndex
from repro.kernels import decode_fused

# every codec declaring the ArenaLayout capability decodes natively on device
# and is swept below; the registry lint (tools/registry_lint.py) cross-checks
# this derivation against the declarations
ARENA_CODECS = [n for n in codec.names() if codec.get(n).arena is not None]

RNG = np.random.default_rng(1234)
N_DOCS = 1500

# df values straddle the short-list cutoff (64) and the 512-posting block
# boundary; the last two are docid-disjoint so AND over them is empty
DFS = [12, 63, 64, 200, 512, 513, 1024, 300, 280]


def _corpus():
    doclen = RNG.integers(40, 300, N_DOCS).astype(np.int64)
    postings = {}
    for t, df in enumerate(DFS[:-2]):
        ids = np.sort(RNG.choice(N_DOCS, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, RNG.geometric(0.4, df).astype(np.uint32))
    lo = np.sort(RNG.choice(N_DOCS // 2, DFS[-2], replace=False)).astype(np.uint32)
    hi = (np.sort(RNG.choice(N_DOCS // 2, DFS[-1], replace=False))
          + N_DOCS // 2).astype(np.uint32)
    postings[len(DFS) - 2] = (lo, RNG.geometric(0.4, DFS[-2]).astype(np.uint32))
    postings[len(DFS) - 1] = (hi, RNG.geometric(0.4, DFS[-1]).astype(np.uint32))
    return doclen, postings


DOCLEN, POSTINGS = _corpus()
NT = len(DFS)
QUERIES = ([RNG.choice(NT, size=int(RNG.integers(2, 4)), replace=False).tolist()
            for _ in range(12)]
           + [[NT - 2, NT - 1],          # disjoint -> empty intersection
              [4], [6],                  # single term, block-boundary terms
              [0, 999]])                 # unknown term ignored


def _engines(name, fused=False):
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
    return QueryEngine(idx), QueryEngine(idx).to_device(fused=fused)


@pytest.mark.parametrize("name", codec.names(group_only=True))
def test_device_engine_matches_host_engine(name):
    host, dev = _engines(name)
    want = host.execute(QueryBatch(QUERIES, mode="and"))
    got = dev.execute(dev.plan(QueryBatch(QUERIES, mode="and")))
    for q, a, b in zip(QUERIES, want, got):
        np.testing.assert_array_equal(a, b, err_msg=f"{name}/and/{q}")
        assert b.dtype == np.uint32
    assert (host.execute(QueryBatch(QUERIES[:5], mode="or", k=7))
            == dev.execute(dev.plan(QueryBatch(QUERIES[:5], mode="or", k=7)))), name
    assert (host.execute(QueryBatch(QUERIES[:5], mode="and_scored", k=7))
            == dev.execute(dev.plan(QueryBatch(QUERIES[:5], mode="and_scored", k=7)))), name


@pytest.mark.parametrize("name", ["group_simple", "bp128", "g_packed_binary",
                                  "group_pfd"])
def test_fused_decode_and_matches_host_engine(name):
    host, dev = _engines(name, fused=True)
    want = host.execute(QueryBatch(QUERIES, mode="and"))
    plan = dev.plan(QueryBatch(QUERIES, mode="and"))
    assert plan.placement == "fused"
    got = dev.execute(plan)
    for q, a, b in zip(QUERIES, want, got):
        np.testing.assert_array_equal(a, b, err_msg=f"{name}/fused/{q}")
    assert dev.arena.stats["fused_calls"] > 0   # the kernel actually ran


@pytest.mark.parametrize("name", ARENA_CODECS)
def test_arena_block_decode_matches_numpy_oracle(name):
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
    arena = DeviceArena.from_index(idx, build_fused=False)
    entries = [(t, bi, f) for t in idx.terms
               for bi in range(idx.n_blocks(t)) for f in (0, 1)]
    got = arena.decode_blocks(entries)
    for (t, bi, f), a in zip(entries, got):
        want = idx.decode_block_ids(t, bi) if f == 0 else idx.decode_block_tfs(t, bi)
        np.testing.assert_array_equal(a, want, err_msg=f"{name}/{t}/{bi}/{f}")
    # full native coverage: the short-list codec declares an arena too, so no
    # block of this corpus falls back to the host oracle
    assert codec.get(SHORT_CODEC).arena is not None
    assert arena.stats["blocks_device"] == len(entries)
    assert arena.stats["blocks_host"] == 0


def test_non_arena_codec_falls_back_to_host_oracle():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="varbyte")
    arena = DeviceArena.from_index(idx, build_fused=False)
    entries = [(t, bi, f) for t in idx.terms
               for bi in range(idx.n_blocks(t)) for f in (0, 1)]
    got = arena.decode_blocks(entries)
    for (t, bi, f), a in zip(entries, got):
        want = idx.decode_block_ids(t, bi) if f == 0 else idx.decode_block_tfs(t, bi)
        np.testing.assert_array_equal(a, want, err_msg=f"varbyte/{t}/{bi}/{f}")
    # varbyte declares no arena; its sparse blocks decode on host, while the
    # stream_vbyte short lists and the density-promoted bitmap blocks still
    # go native
    assert arena.stats["blocks_host"] > 0
    assert arena.stats["blocks_device"] > 0
    assert not arena.covers((2, 0, 0))       # df=64 sparse term -> varbyte
    assert arena.covers((0, 0, 0))           # df=12 term -> stream_vbyte


def test_plan_resolves_placement_and_term_caps():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    host = QueryEngine(idx)
    p = host.plan(QueryBatch(QUERIES, mode="and"))
    assert isinstance(p, ExecutionPlan) and p.placement == "host"
    assert 999 not in p.terms                # unknown terms omitted
    assert p.terms[0].codec == SHORT_CODEC   # df=12 -> short-list fast path
    # df=512 over 1500 docs sits past the density cutoff, so build stored the
    # term's block as a raw bitmap — the caps surface the per-block decision
    assert p.terms[4].codec == "dense_bitmap"
    assert p.terms[4].arena and not p.terms[4].fused
    dev = QueryEngine(idx).to_device(fused=True)
    pf = dev.plan(QueryBatch(QUERIES, mode="and"))
    assert pf.placement == "fused" and pf.terms[4].fused
    # plans are snapshots: the host plan still executes on the host path and
    # reproduces the device results exactly
    for a, b in zip(host.execute(p), dev.execute(pf)):
        np.testing.assert_array_equal(a, b)


def test_execute_querybatch_shim_matches_plan_path():
    """Acceptance: plan()/execute(plan) reproduce the deprecated
    execute(QueryBatch) shim bit-identically on every placement."""
    for name in ("group_simple", "stream_vbyte", "varbyte"):
        idx = InvertedIndex.build(DOCLEN, POSTINGS, codec=name)
        for eng in (QueryEngine(idx), QueryEngine(idx).to_device(),
                    QueryEngine(idx).to_device(fused=True)):
            want = eng.execute(QueryBatch(QUERIES, mode="and"))
            got = eng.execute(eng.plan(QueryBatch(QUERIES, mode="and")))
            for a, b in zip(want, got):
                np.testing.assert_array_equal(a, b, err_msg=name)


def test_plan_placement_mismatch_raises_clearly():
    """A device/fused plan executed on an engine without the matching arenas
    must fail with a clear error, not deep inside intersection."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    fused_plan = QueryEngine(idx).to_device(fused=True).plan(
        QueryBatch(QUERIES[:2], mode="and"))
    with pytest.raises(ValueError, match="to_device"):
        QueryEngine(idx).execute(fused_plan)
    with pytest.raises(ValueError, match="fused"):
        eng = QueryEngine(idx)
        eng.arena = idx.to_device(build_fused=False)
        eng.arena._pk = None
        eng.execute(fused_plan)
    # a host plan on a device engine is fine (host path works everywhere) and
    # stays pinned to host intersection: the fused kernel must not run
    host_plan = QueryEngine(idx).plan(QueryBatch(QUERIES[:2], mode="and"))
    dev = QueryEngine(idx).to_device(fused=True)
    calls0 = dev.arena.stats["fused_calls"]
    for a, b in zip(QueryEngine(idx).execute(host_plan), dev.execute(host_plan)):
        np.testing.assert_array_equal(a, b)
    assert dev.arena.stats["fused_calls"] == calls0
    assert dev._fused          # the engine's own configuration is untouched


def test_mismatched_bp_frame_layout_falls_back_to_host():
    """A bp128-named block at an alien frame size is outside the declared
    ArenaLayout (supports() says no) and must take the host oracle, exactly."""
    from repro.core import bp128 as bp128_lib
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="bp128")
    t = 6                                        # df=1024 -> two bp128 blocks
    first, encg, enct = idx.terms[t].blocks[0]
    gaps = codec.get(encg.codec).decode_np(encg)
    idx.terms[t].blocks[0] = (first, bp128_lib.encode(gaps, frame_quads=64), enct)
    arena = DeviceArena.from_index(idx, build_fused=False)
    assert not arena.covers((t, 0, 0))           # alien layout -> host oracle
    assert arena.covers((t, 1, 0))               # sibling block stays native
    got = arena.decode_blocks([(t, 0, 0), (t, 1, 0)])
    np.testing.assert_array_equal(got[0], idx.decode_block_ids(t, 0))
    np.testing.assert_array_equal(got[1], idx.decode_block_ids(t, 1))
    assert arena.stats["blocks_host"] == 1 and arena.stats["blocks_device"] == 1


def test_deprecated_constructor_flags_still_work():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    with pytest.warns(DeprecationWarning):
        legacy = QueryEngine(idx, device=True, fused=True)
    want = QueryEngine(idx).execute(QueryBatch(QUERIES, mode="and"))
    for a, b in zip(want, legacy.execute(QueryBatch(QUERIES, mode="and"))):
        np.testing.assert_array_equal(a, b)


def test_device_worklist_decodes_each_hot_block_once():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    eng = QueryEngine(idx, cache_blocks=1 << 20).to_device()
    eng.execute(eng.plan(QueryBatch(QUERIES, mode="and")))
    # cold eviction-free cache: every decode is a distinct hot (term, block),
    # and the hot set is counted independently of the decode counters
    hot = {k for k in eng.cache.keys() if k[1] >= 0}
    decodes = (eng.dev_stats["worklist_decodes"]
               + eng.dev_stats["fallback_decodes"])
    assert decodes == len(hot)
    assert eng.dev_stats["fallback_decodes"] == 0
    assert eng.dev_stats["worklist_refs"] >= eng.dev_stats["worklist_decodes"]
    # a second pass over the same batch is fully cache-served
    with eng.metrics.scoped() as sample:
        r1 = eng.execute(eng.plan(QueryBatch(QUERIES, mode="and")))
    assert sample.delta("worklist_decodes") == 0
    r0 = QueryEngine(idx).execute(QueryBatch(QUERIES, mode="and"))
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a, b)


def test_device_engine_eviction_pressure_stays_exact():
    # a sparse corpus (average docid gap far above the density cutoff) so
    # every block is served through the decode path — dense-bitmap blocks
    # never touch the block cache and would defuse the eviction pressure
    # this test is about
    rng = np.random.default_rng(77)
    n = 60000
    doclen = rng.integers(40, 300, n).astype(np.int64)
    postings = {}
    for t, df in enumerate([900, 1100, 1300, 700]):
        ids = np.sort(rng.choice(n, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    idx = InvertedIndex.build(doclen, postings, codec="bp128")
    host = QueryEngine(idx)
    tiny = QueryEngine(idx, cache_blocks=2, cache_score_terms=1).to_device()
    queries = [[0, 1], [1, 2], [2, 3], [0, 3], [1, 3], [0, 2], [0, 1, 2]]
    want = host.execute(QueryBatch(queries, mode="and"))
    got = tiny.execute(tiny.plan(QueryBatch(queries, mode="and")))
    assert tiny.cache.evictions > 0
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)


def test_zero_posting_term_and_empty_results_on_device():
    postings = dict(POSTINGS)
    postings[99] = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    idx = InvertedIndex.build(DOCLEN, postings, codec="group_simple")
    eng = QueryEngine(idx).to_device(fused=True)
    res = eng.execute(eng.plan(QueryBatch([[99], [99, 0], [NT - 2, NT - 1]],
                                          mode="and")))
    for r in res:
        assert len(r) == 0 and r.dtype == np.uint32 and r.flags.writeable
    assert eng.or_query([99]) == []


def test_term_concat_empty_is_frozen_and_consistent():
    postings = dict(POSTINGS)
    postings[99] = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))
    idx = InvertedIndex.build(DOCLEN, postings, codec="group_simple")
    eng = QueryEngine(idx)
    v = eng.term_ids(99)
    assert len(v) == 0 and v.dtype == np.uint32
    # same contract as every other accessor: cache-backed arrays are frozen
    assert not v.flags.writeable
    assert not eng.term_tfs(99).flags.writeable
    np.testing.assert_array_equal(v, eng.term_ids(99))
    # but and_query results stay caller-owned
    assert eng.and_query([99]).flags.writeable


def test_invalid_mode_raises_on_both_paths():
    """Unknown modes fail with a ValueError that lists MODES and suggests
    the nearest name (the ``codec.get`` convention), on plan and execute."""
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    for eng in (QueryEngine(idx), QueryEngine(idx).to_device()):
        with pytest.raises(ValueError, match="did you mean 'and'"):
            eng.plan(QueryBatch([[0, 1]], mode="And"))
        with pytest.raises(ValueError, match="and, or, and_scored"):
            eng.execute(QueryBatch([[0, 1]], mode="And"))
    with pytest.raises(ValueError, match="unknown query mode"):
        QueryEngine(idx).execute(QueryBatch([[0, 1]], mode="bm25"))


def test_fused_arena_buckets_by_block_bit_width():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    arena = idx.to_device()
    # the corpus mixes dense (df=1024) and sparse (df=64) terms, so blocks
    # must land in more than one width bucket and every block must be covered
    assert len(arena._pk) > 1
    assert set(arena._pk) <= set(decode_fused.BW_BUCKETS)
    covered = set(arena._pk_slot)
    assert covered == {(t, bi) for t in idx.terms
                       for bi in range(idx.n_blocks(t))}


def test_to_device_upgrades_unfused_arena_in_place():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    a1 = idx.to_device(build_fused=False)
    assert a1._pk is None
    a2 = idx.to_device(build_fused=True)     # cached arena gains fused tiles
    assert a2 is a1 and a1._pk is not None
    eng = QueryEngine(idx).to_device(fused=True)
    # sparse terms only (df 12/63/64): dense-bitmap blocks are served
    # word-parallel and would never reach the fused decode kernel
    eng.execute(eng.plan(QueryBatch([[0, 1], [1, 2], [0, 2], [0, 1, 2]],
                                    mode="and")))
    assert eng.arena.stats["fused_calls"] > 0


def test_to_device_is_cached_and_idempotent():
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    a1 = idx.to_device()
    a2 = idx.to_device()
    assert a1 is a2
    eng = QueryEngine(idx).to_device()
    assert eng.arena is a1
    assert eng.to_device(fused=True) is eng and eng._fused


# --------------------------------------------------------------------------- #
# exception-bearing arena codecs + device-resident rounds
# --------------------------------------------------------------------------- #

EXC_CODECS = ["group_afor", "group_vse", "group_pfd", "group_optpfd"]


def _heavy_corpus():
    """Heavy-tailed postings: big docid-gap outliers drive the PFD family to
    emit non-empty exception streams, and the dfs straddle the 512-posting
    block boundary so frame/exception state crosses blocks."""
    rng = np.random.default_rng(77)
    n_docs = 400_000
    postings = {}
    for t, df in enumerate([511, 512, 513, 1024, 700, 300]):
        gaps = rng.integers(1, 12, df).astype(np.int64)
        gaps[rng.random(df) < 0.02] += rng.integers(1 << 10, 1 << 14)
        ids = np.cumsum(gaps)
        assert ids[-1] < n_docs
        postings[t] = (ids.astype(np.uint32),
                       rng.geometric(0.4, df).astype(np.uint32))
    doclen = np.full(n_docs, 100, np.int64)
    return doclen, postings


HDOCLEN, HPOSTINGS = _heavy_corpus()
HQUERIES = [[0, 1], [1, 2, 3], [0, 3, 4, 5], [2, 4], [3], [5, 1, 0]]


@pytest.mark.parametrize("name", EXC_CODECS)
def test_exception_codecs_decode_natively_no_oracle_fallback(name):
    """Acceptance: the AFOR/PFD/VSE families decode in the device arena with
    no numpy-oracle fallback on their blocks, bit-identical to decode_np."""
    idx = InvertedIndex.build(HDOCLEN, HPOSTINGS, codec=name)
    if name in ("group_pfd", "group_optpfd"):
        # the corpus actually exercises the exception path
        assert any(encg.exceptions is not None and len(encg.exceptions)
                   for tp in idx.terms.values()
                   for _, encg, _ in tp.blocks), "corpus has no exceptions"
    arena = DeviceArena.from_index(idx, build_fused=False)
    entries = [(t, bi, f) for t in idx.terms
               for bi in range(idx.n_blocks(t)) for f in (0, 1)]
    got = arena.decode_blocks(entries)
    for (t, bi, f), a in zip(entries, got):
        want = idx.decode_block_ids(t, bi) if f == 0 else idx.decode_block_tfs(t, bi)
        np.testing.assert_array_equal(a, want, err_msg=f"{name}/{t}/{bi}/{f}")
    assert arena.stats["blocks_host"] == 0
    assert arena.stats["blocks_device"] == len(entries)


@pytest.mark.parametrize("name", EXC_CODECS)
def test_exception_codecs_eviction_and_block_boundary_parity(name):
    """Device engine under pathological cache eviction pressure stays exact
    across the 511/512/513/1024 block boundaries for the new arena codecs."""
    idx = InvertedIndex.build(HDOCLEN, HPOSTINGS, codec=name)
    host = QueryEngine(idx)
    tiny = QueryEngine(idx, cache_blocks=2, cache_score_terms=1).to_device()
    want = host.execute(QueryBatch(HQUERIES, mode="and"))
    got = tiny.execute(tiny.plan(QueryBatch(HQUERIES, mode="and")))
    assert tiny.cache.evictions > 0
    for q, a, b in zip(HQUERIES, want, got):
        np.testing.assert_array_equal(a, b, err_msg=f"{name}/{q}")


def test_multi_round_device_and_is_resident_with_zero_cand_syncs():
    """Acceptance: a >= 3-term AND batch executes with zero host candidate
    syncs between rounds, on both device and fused placements, with exact
    result parity against the host placement."""
    queries = [q for q in HQUERIES if len(q) >= 3] * 2
    for name in ("group_pfd", "group_simple"):
        idx = InvertedIndex.build(HDOCLEN, HPOSTINGS, codec=name)
        want = QueryEngine(idx).execute(QueryBatch(queries, mode="and"))
        for fused in (False, True):
            eng = QueryEngine(idx).to_device(fused=fused)
            got = eng.execute(eng.plan(QueryBatch(queries, mode="and")))
            for q, a, b in zip(queries, want, got):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{name}/fused={fused}/{q}")
                assert b.dtype == np.uint32 and b.flags.writeable
            # >= 2 intersect rounds ran device-resident; candidates came
            # back to the host exactly once (the final result copy)
            assert eng.dev_stats["resident_rounds"] >= 2
            assert eng.dev_stats["cand_syncs"] == 0
            assert eng.dev_stats["final_syncs"] == 1
            if fused:
                assert eng.arena.stats["fused_calls"] > 0


def test_plan_auto_places_tiny_batches_on_host():
    """engine.plan() places batches of <= HOST_BATCH_MAX queries on the host
    even when device arenas exist, and records why in the plan's repr."""
    from repro.index.engine import HOST_BATCH_MAX
    idx = InvertedIndex.build(DOCLEN, POSTINGS, codec="group_simple")
    dev = QueryEngine(idx).to_device(fused=True)
    tiny = dev.plan(QueryBatch(QUERIES[:1], mode="and"))
    assert tiny.placement == "host"
    assert "HOST_BATCH_MAX" in tiny.note and tiny.note in repr(tiny)
    big = dev.plan(QueryBatch(QUERIES, mode="and"))
    assert big.placement == "fused" and big.note == ""
    assert len(QUERIES) > HOST_BATCH_MAX
    # the demoted plan still executes correctly on the device engine
    want = QueryEngine(idx).execute(QueryBatch(QUERIES[:1], mode="and"))
    for a, b in zip(want, dev.execute(tiny)):
        np.testing.assert_array_equal(a, b)
