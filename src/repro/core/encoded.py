"""Uniform container for a compressed integer sequence.

Every codec encodes to an ``Encoded`` and decodes from one.  Sizes are tracked
in *bits actually used* so compression-ratio accounting is exact even when the
backing numpy arrays are word-padded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass
class Encoded:
    codec: str
    n: int                                  # number of source integers
    control: np.ndarray                     # control area (uint8 or uint32 words)
    data: np.ndarray                        # data area (uint32 words)
    control_bits: int = 0                   # bits used in the control area
    data_bits: int = 0                      # bits used in the data area
    exceptions: Optional[np.ndarray] = None # exception area (uint32 words), PFD only
    exception_bits: int = 0
    header_bits: int = 0                    # per-stream fixed header cost
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def total_bits(self) -> int:
        return self.control_bits + self.data_bits + self.exception_bits + self.header_bits

    @property
    def bits_per_int(self) -> float:
        return self.total_bits / max(self.n, 1)

    def nbytes(self) -> int:
        return (self.total_bits + 7) // 8
