"""Stream VByte (Lemire, Kurz & Rupp 2018): byte-aligned codec with a
*separated* control stream.

Classic VByte interleaves the continuation bit with the payload, so decoding
is a byte-at-a-time branch.  Stream VByte moves all length information into a
dedicated control stream — one byte holds the 2-bit byte-lengths of four
integers — and keeps the data stream as raw little-endian payload bytes.  The
decoder then reads a control byte and consumes a whole quadruple at once with
no data-dependent branches, which is what makes it SIMD-friendly (the x86
implementation is a single ``pshufb`` per quadruple; here the same structure
becomes one vectorized byte-gather across all integers).

This is the repo's byte-oriented fast path for *short* posting lists (the
``invindex`` short-list fallback), replacing interleaved VByte:

  control[i // 4] bits 2*(i%4) .. 2*(i%4)+1  =  nbytes(x[i]) - 1   (1..4 bytes)
  data = concat(little-endian payload bytes of each x[i])

Decoders: numpy oracle (vectorized), JAX scalar (sequential ``lax.scan``, the
paper-style non-SIMD baseline), JAX vectorized (cumsum of lengths + one
byte-gather for all integers, the SIMD analogue).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np
from .encoded import Encoded

NAME = "stream_vbyte"


def encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded(NAME, 0, np.zeros(0, np.uint8), np.zeros(0, np.uint8),
                       header_bits=32)
    nb = np.maximum(1, -(-ebw_np(x) // 8)).astype(np.int64)        # 1..4 bytes
    pad = (-n) % 4
    codes = np.concatenate([nb - 1, np.zeros(pad, np.int64)]).reshape(-1, 4)
    control = (codes[:, 0] | (codes[:, 1] << 2) | (codes[:, 2] << 4)
               | (codes[:, 3] << 6)).astype(np.uint8)
    ends = np.cumsum(nb)
    total = int(ends[-1])
    starts = ends - nb
    data = np.zeros(total, np.uint8)
    for j in range(4):
        sel = nb > j
        data[starts[sel] + j] = (x[sel].astype(np.uint64) >> np.uint64(8 * j)).astype(np.uint8)
    return Encoded(NAME, n, control, data, control_bits=len(control) * 8,
                   data_bits=total * 8, header_bits=32)


def decode_np(enc: Encoded) -> np.ndarray:
    n = enc.n
    if n == 0:
        return np.zeros(0, np.uint32)
    ctrl = enc.control
    codes = np.stack([(ctrl >> (2 * c)) & 3 for c in range(4)], axis=1)
    nb = codes.astype(np.int64).reshape(-1)[:n] + 1
    ends = np.cumsum(nb)
    starts = ends - nb
    by = np.concatenate([enc.data, np.zeros(4, np.uint8)])
    vals = np.zeros(n, np.uint64)
    for j in range(4):
        sel = nb > j
        vals[sel] |= by[starts[sel] + j].astype(np.uint64) << np.uint64(8 * j)
    return vals.astype(np.uint32)


def jax_args(enc: Encoded) -> dict:
    # byte streams widened to uint32 lanes (TPU has no 8-bit lanes), with
    # slack so the quadruple gather never reads past the end
    control = np.concatenate([enc.control, np.zeros(1, np.uint8)]).astype(np.uint32)
    data = np.concatenate([enc.data, np.zeros(4, np.uint8)]).astype(np.uint32)
    return {"control": jnp.asarray(control), "data": jnp.asarray(data), "n": enc.n}


@functools.partial(jax.jit, static_argnames=("n",))
def decode_jax_vec(control, data, n: int):
    """SIMD-style decode: all byte-lengths at once, one gather per byte slot."""
    if n == 0:
        return jnp.zeros(0, jnp.uint32)
    i = jnp.arange(n, dtype=jnp.int32)
    code = (control[i >> 2] >> ((i & 3).astype(jnp.uint32) * 2)) & jnp.uint32(3)
    nb = code.astype(jnp.int32) + 1
    starts = jnp.cumsum(nb) - nb
    val = jnp.zeros(n, jnp.uint32)
    for j in range(4):
        byte = data[starts + j]
        val = val | jnp.where(j < nb, byte << jnp.uint32(8 * j), jnp.uint32(0))
    return val


def decode_arena_block(control: jnp.ndarray, data: jnp.ndarray,
                       ctrl_len: jnp.ndarray, n_valid: jnp.ndarray) -> jnp.ndarray:
    """Fixed-shape single-block decode for the device arena
    (``repro.index.device``): padded static shapes + dynamic length, so a
    work-list of (term, block) pairs decodes lane-parallel under ``vmap``.

    control: (C_MAX,) uint32, one control byte per entry (entries past
             ``ctrl_len`` are arena slack — possibly the next block's bytes —
             and every read they feed is masked by ``i < n_valid`` below).
    data:    (D_MAX,) uint32, one payload byte per entry, gathered from the
             data arena with >= 3 entries of slack past the worst-case block.
    ctrl_len, n_valid: dynamic control-byte / integer counts of this block.
    Returns (4 * C_MAX,) uint32 values, zero beyond ``n_valid``.
    """
    nmax = 4 * control.shape[0]
    i = jnp.arange(nmax, dtype=jnp.int32)
    code = (control[i >> 2] >> ((i & 3).astype(jnp.uint32) * 2)) & jnp.uint32(3)
    # invalid lanes consume 0 payload bytes so the cumsum of lengths (and
    # therefore every valid lane's byte offset) is unaffected by slack
    nb = jnp.where(i < n_valid, code.astype(jnp.int32) + 1, 0)
    starts = jnp.cumsum(nb) - nb
    val = jnp.zeros(nmax, jnp.uint32)
    for j in range(4):
        byte = data[starts + j]            # in-bounds: data has >= 3 slack bytes
        val = val | jnp.where(j < nb, byte << jnp.uint32(8 * j), jnp.uint32(0))
    return jnp.where(i < n_valid, val, 0)


@functools.partial(jax.jit, static_argnames=("n",))
def decode_jax_scalar(control, data, n: int):
    """Paper-style sequential decode: one integer per scan step."""
    if n == 0:
        return jnp.zeros(0, jnp.uint32)

    def step(pos, i):
        code = (control[i >> 2] >> ((i & 3).astype(jnp.uint32) * 2)) & jnp.uint32(3)
        nb = code.astype(jnp.int32) + 1
        val = data[pos]
        for j in range(1, 4):
            val = val | jnp.where(nb > j, data[pos + j] << jnp.uint32(8 * j), jnp.uint32(0))
        return pos + nb, val

    _, vals = jax.lax.scan(step, jnp.int32(0), jnp.arange(n, dtype=jnp.int32))
    return vals
