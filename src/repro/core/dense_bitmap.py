"""Dense posting blocks as raw 128-word bitmaps.

"SIMD Compression and the Intersection of Sorted Integers" observes that past
a density threshold a sorted docid block is intersected fastest as an
uncompressed bitmap — word-parallel AND/probe, no unpack, no prefix-sum.  This
codec is the declared-capability carrier for that representation: the index
build (``repro.index.invindex``) decides per block at build time whether the
block is dense enough (:func:`eligible`), and everything downstream — device
arena staging, the word-parallel intersect/score rounds, the host oracle —
discovers the choice through the registry instead of codec-name branches.

Wire format (one :class:`~repro.core.encoded.Encoded` per block):

* ``fmt == "bitmap"`` — ``data`` is exactly :data:`WINDOW_WORDS` uint32 words,
  bit ``p`` (LSB-first within each word) set iff the block contains the value
  ``base + p`` where ``base`` is the block's first prefix-sum (``control[1]``).
  Chosen whenever the prefix sums are strictly increasing and span less than
  :data:`WINDOW_BITS` — a *mechanism* test, so arbitrary eligible streams
  round-trip and the conformance/arena harnesses need no special cases.
* ``fmt == "raw"`` — verbatim uint32 values; the fallback that keeps the codec
  total over arbitrary streams (the registry lint and conformance sweeps feed
  streams no bitmap can hold).

The *policy* cutoff — when a posting block is worth storing this way — is
:func:`eligible`: average docid gap (span/count) at most :data:`DENSE_GAP`.
For a full 512-posting block that is exactly the 4096-bit window.
"""

from __future__ import annotations

import numpy as np

from .encoded import Encoded

WINDOW_WORDS = 128                       # bitmap window: 128 uint32 words
WINDOW_BITS = WINDOW_WORDS * 32          # = 4096 docid positions
DENSE_GAP = 8                            # density cutoff: span <= DENSE_GAP * n

NAME = "dense_bitmap"


def eligible(ids: np.ndarray) -> bool:
    """Build-time density decision for one posting block's docids.

    Besides the density cutoff, the block must fit a 128-word window whose
    first word is rounded down to a 4-word (128-bit) phase: the serving arena
    stores dense windows at ``w0 = (ids[0] >> 5) & ~3`` so their global column
    offset ``w0 * 32`` is a multiple of 128 lanes — a tile-aligned dynamic
    slice on TPU instead of an unaligned gather.
    """
    n = len(ids)
    if n == 0:
        return False
    span = int(ids[-1]) - int(ids[0]) + 1
    w_last = int(ids[-1]) >> 5
    w0 = (int(ids[0]) >> 5) & ~3
    return span <= DENSE_GAP * n and w_last - w0 <= WINDOW_WORDS - 1


def is_bitmap(enc: Encoded) -> bool:
    """True iff this block is stored word-parallel servable (bitmap format)."""
    return enc.meta.get("fmt") == "bitmap" and enc.n > 0


def encode(vals: np.ndarray) -> Encoded:
    vals = np.ascontiguousarray(vals, dtype=np.uint32)
    n = int(vals.size)
    pos = np.cumsum(vals, dtype=np.uint64)
    fits = (n > 0 and int(pos[-1] - pos[0]) < WINDOW_BITS
            and (n == 1 or int(vals[1:].min()) >= 1))
    if fits:
        rel = (pos - pos[0]).astype(np.int64)
        bits = np.zeros(WINDOW_BITS, np.uint8)
        bits[rel] = 1
        data = np.packbits(bits, bitorder="little").view(np.uint32).copy()
        control = np.array([1, vals[0]], np.uint32)
        return Encoded(NAME, n, control, data, control_bits=64,
                       data_bits=WINDOW_BITS, meta={"fmt": "bitmap"})
    control = np.array([0, 0], np.uint32)
    return Encoded(NAME, n, control, vals.copy(), control_bits=64,
                   data_bits=32 * n, meta={"fmt": "raw"})


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.meta.get("fmt") != "bitmap":
        return np.asarray(enc.data[:enc.n], np.uint32).copy()
    bits = np.unpackbits(np.asarray(enc.data, np.uint32).view(np.uint8),
                         bitorder="little")
    rel = np.flatnonzero(bits)
    assert rel.size == enc.n, (rel.size, enc.n)
    pos = rel.astype(np.uint64) + np.uint64(enc.control[1])
    return np.diff(pos, prepend=np.uint64(0)).astype(np.uint32)


def block_positions(enc: Encoded) -> np.ndarray:
    """Bit positions relative to ``base`` for a bitmap-format block."""
    bits = np.unpackbits(np.asarray(enc.data, np.uint32).view(np.uint8),
                         bitorder="little")
    return np.flatnonzero(bits)


def decode_arena_block(ctrl, data, ctrl_len, data_len, n_valid):
    """Fixed-shape device decode for one block (both formats, jit/vmap safe).

    ``ctrl = [fmt, base]``; bitmap blocks recover the value stream by ranking
    set bits with a prefix-sum and scattering bit positions into posting
    order, raw blocks are an identity copy.  Both branches are computed and
    selected — the shapes are static either way.
    """
    import jax.numpy as jnp

    from .codec import ARENA_BLOCK

    fmt = ctrl[0]
    base = ctrl[1]
    words = data[:WINDOW_WORDS].astype(jnp.uint32)
    bits = ((words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & 1)
    bits = bits.reshape(-1).astype(jnp.int32)                  # (WINDOW_BITS,)
    rank = jnp.cumsum(bits) - 1
    scat = jnp.where(bits == 1, rank, ARENA_BLOCK)             # pad slot drops
    posv = jnp.arange(WINDOW_BITS, dtype=jnp.uint32)
    pos = jnp.zeros(ARENA_BLOCK + 1, jnp.uint32).at[scat].add(
        jnp.where(bits == 1, posv, 0))[:ARENA_BLOCK]
    prev = jnp.concatenate([jnp.zeros(1, jnp.uint32), pos[:-1]])
    gaps_bm = (pos - prev).at[0].add(base.astype(jnp.uint32))
    gaps_raw = data[:ARENA_BLOCK].astype(jnp.uint32)
    out = jnp.where(fmt == 1, gaps_bm, gaps_raw)
    idx = jnp.arange(ARENA_BLOCK, dtype=jnp.int32)
    return jnp.where(idx < n_valid, out, jnp.uint32(0))
