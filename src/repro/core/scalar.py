"""Scalar (non-Group) baseline codecs from the paper's comparison set (§2, §7).

All are host-side numpy implementations with exact bit accounting: VarByte,
GVB(-Binary), G8IU, G8CU, Simple-9, Simple-16, Rice, Elias Gamma, PForDelta,
AFOR, PackedBinary.  They serve the compression-ratio tables (Table VIII/IX/XI)
and as scalar decode-speed baselines.  x86 `pshufb`-style SIMD variants of the
byte-aligned codecs (SIMD-G8IU etc.) have no TPU analogue (DESIGN.md §2) and
are represented by their scalar forms.
"""

from __future__ import annotations

import numpy as np

from .bits import ebw_np, gather_bits_np, mask_np, pack_bits_np, words_to_bits_np
from .encoded import Encoded

# --------------------------------------------------------------------------- #
# Variable Byte
# --------------------------------------------------------------------------- #


def vb_encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    nb = np.maximum(1, -(-ebw_np(x) // 7)).astype(np.int64)      # bytes per int
    ends = np.cumsum(nb)
    total = int(ends[-1]) if n else 0
    out = np.zeros(total, np.uint8)
    starts = ends - nb
    for j in range(5):
        sel = nb > j
        idx = starts[sel] + j
        byte = ((x[sel].astype(np.uint64) >> np.uint64(7 * j)) & np.uint64(0x7F)).astype(np.uint8)
        last = (j == nb[sel] - 1)
        out[idx] = byte | (last.astype(np.uint8) << 7)           # high bit marks last byte
    return Encoded("varbyte", n, np.zeros(0, np.uint8), out.view(np.uint8),
                   data_bits=total * 8, header_bits=32)


def vb_decode(enc: Encoded) -> np.ndarray:
    by = enc.data
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    ends = np.flatnonzero(by & 0x80)[: enc.n]
    starts = np.concatenate([[0], ends[:-1] + 1])
    j = np.arange(len(by)) - np.repeat(starts, ends - starts + 1)
    contrib = ((by & 0x7F).astype(np.uint64)) << (7 * j).astype(np.uint64)
    return np.add.reduceat(contrib, starts).astype(np.uint32)


# --------------------------------------------------------------------------- #
# Group Variable Byte (binary descriptors) — Dean 2009
# --------------------------------------------------------------------------- #


def gvb_encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    pad = (-n) % 4
    xp = np.concatenate([x, np.zeros(pad, np.uint32)])
    nb = np.maximum(1, -(-ebw_np(xp) // 8)).astype(np.int64)     # 1..4 bytes
    groups = nb.reshape(-1, 4)
    control = (groups[:, 0] - 1) | ((groups[:, 1] - 1) << 2) | ((groups[:, 2] - 1) << 4) | ((groups[:, 3] - 1) << 6)
    ends = np.cumsum(nb)
    total = int(ends[-1]) if len(xp) else 0
    data = np.zeros(total, np.uint8)
    starts = ends - nb
    for j in range(4):
        sel = nb > j
        data[starts[sel] + j] = (xp[sel].astype(np.uint64) >> np.uint64(8 * j)).astype(np.uint8)
    return Encoded("gvb", n, control.astype(np.uint8), data,
                   control_bits=len(control) * 8, data_bits=total * 8, header_bits=32,
                   meta={"pad": pad})


def gvb_decode(enc: Encoded) -> np.ndarray:
    ctrl = enc.control
    nb = np.stack([(ctrl >> (2 * c)) & 3 for c in range(4)], axis=1).astype(np.int64).reshape(-1) + 1
    ends = np.cumsum(nb)
    starts = ends - nb
    by = np.concatenate([enc.data, np.zeros(4, np.uint8)])
    vals = np.zeros(len(nb), np.uint64)
    for j in range(4):
        sel = nb > j
        vals[sel] |= by[starts[sel] + j].astype(np.uint64) << np.uint64(8 * j)
    return vals.astype(np.uint32)[: enc.n]


# --------------------------------------------------------------------------- #
# G8IU / G8CU (unary descriptors, 8-byte data areas) — Stepanov et al. 2011
# --------------------------------------------------------------------------- #


def g8iu_encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    nb = np.maximum(1, -(-ebw_np(x) // 8)).astype(np.int64)
    blocks = []  # (control byte, 8 data bytes)
    i = 0
    while i < n:
        used, ctrl, data = 0, 0, np.zeros(8, np.uint8)
        cbit = 0
        while i < n and used + nb[i] <= 8:
            L = int(nb[i])
            for j in range(L):
                data[used + j] = (int(x[i]) >> (8 * j)) & 0xFF
            ctrl |= ((1 << (L - 1)) - 1) << cbit                 # (L-1) ones + implicit 0
            cbit += L
            used += L
            i += 1
        ctrl |= ((1 << (8 - cbit)) - 1) << cbit                  # pad descriptors with ones
        blocks.append((ctrl, data))
    control = np.asarray([b[0] for b in blocks], np.uint8)
    data = np.concatenate([b[1] for b in blocks]) if blocks else np.zeros(0, np.uint8)
    bits = len(blocks) * 9 * 8
    return Encoded("g8iu", n, control, data, control_bits=len(blocks) * 8,
                   data_bits=len(blocks) * 64, header_bits=32)


def g8iu_decode(enc: Encoded) -> np.ndarray:
    out = np.zeros(enc.n, np.uint32)
    k = 0
    for bi in range(len(enc.control)):
        ctrl = int(enc.control[bi])
        data = enc.data[bi * 8:(bi + 1) * 8]
        pos = 0
        run = 0
        start = 0
        for bit in range(8):
            if (ctrl >> bit) & 1:
                run += 1
            else:
                L = run + 1
                v = 0
                for j in range(L):
                    v |= int(data[start + j]) << (8 * j)
                if k < enc.n:
                    out[k] = v
                k += 1
                start += L
                run = 0
    return out


def g8cu_encode(x: np.ndarray) -> Encoded:
    """G8CU: integers may span 8-byte areas; control bit c=1 means 'byte
    continues the current integer' (complete unary across control bytes)."""
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    nb = np.maximum(1, -(-ebw_np(x) // 8)).astype(np.int64)
    total = int(nb.sum())
    data = np.zeros(total, np.uint8)
    ends = np.cumsum(nb)
    starts = ends - nb
    for j in range(4):
        sel = nb > j
        data[starts[sel] + j] = (x[sel].astype(np.uint64) >> np.uint64(8 * j)).astype(np.uint8)
    # continuation bit per data byte: 1 unless byte is the last of its int
    cont = np.ones(total, np.uint8)
    cont[ends - 1] = 0
    nareas = (total + 7) // 8
    contp = np.concatenate([cont, np.ones(nareas * 8 - total, np.uint8)])  # pad=1 (ignored)
    control = np.packbits(contp.reshape(-1, 8)[:, ::-1], axis=1).reshape(-1)  # LSB-first per byte
    datap = np.concatenate([data, np.zeros(nareas * 8 - total, np.uint8)])
    return Encoded("g8cu", n, control, datap, control_bits=nareas * 8,
                   data_bits=nareas * 64, header_bits=32, meta={"total": total})


def g8cu_decode(enc: Encoded) -> np.ndarray:
    total = enc.meta["total"]
    cont = np.unpackbits(enc.control, bitorder="little")[:total]
    ends = np.flatnonzero(cont == 0)[: enc.n]
    starts = np.concatenate([[0], ends[:-1] + 1])
    nb = ends - starts + 1
    vals = np.zeros(len(ends), np.uint64)
    by = np.concatenate([enc.data, np.zeros(4, np.uint8)])
    for j in range(4):
        sel = nb > j
        vals[sel] |= by[starts[sel] + j].astype(np.uint64) << np.uint64(8 * j)
    return vals.astype(np.uint32)[: enc.n]


# --------------------------------------------------------------------------- #
# Simple-9 / Simple-16 (Anh & Moffat; Zhang et al.)
# --------------------------------------------------------------------------- #

S9 = [(28, 1), (14, 2), (9, 3), (7, 4), (5, 5), (4, 7), (3, 9), (2, 14), (1, 28)]
# selector -> list of (count, bits), sum(count*bits) <= 28
S16 = [
    [(28, 1)], [(7, 2), (14, 1)], [(7, 1), (7, 2), (7, 1)], [(14, 1), (7, 2)],
    [(14, 2)], [(1, 4), (8, 3)], [(1, 3), (4, 4), (3, 3)], [(7, 4)],
    [(4, 5), (2, 4)], [(2, 4), (4, 5)], [(3, 6), (2, 5)], [(2, 5), (3, 6)],
    [(4, 7)], [(1, 10), (2, 9)], [(2, 14)], [(1, 28)],
]


def _runlen_leq(e: np.ndarray, b: int) -> np.ndarray:
    fits = e <= b
    q = len(fits)
    fp = np.flatnonzero(~fits)
    if len(fp) == 0:
        return q - np.arange(q)
    nxt = np.searchsorted(fp, np.arange(q))
    nxtf = np.where(nxt < len(fp), fp[np.minimum(nxt, len(fp) - 1)], q)
    return nxtf - np.arange(q)


def simple9_encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    e = ebw_np(x)
    if n and e.max() > 28:
        raise ValueError("Simple-9 supports at most 28-bit values (paper §4.1.2)")
    runs = {b: _runlen_leq(e, b) for _, b in S9}
    words, sels = [], []
    i = 0
    while i < n:
        for s, (cnt, b) in enumerate(S9):
            take = min(cnt, n - i)
            if runs[b][i] >= take and take == min(cnt, n - i) and (take == cnt or i + take == n):
                w = np.uint64(s) << np.uint64(28)
                for k in range(take):
                    w |= np.uint64(x[i + k]) << np.uint64(k * b)
                words.append(np.uint32(w & np.uint64(0xFFFFFFFF)))
                sels.append(s)
                i += take
                break
    data = np.asarray(words, np.uint32)
    return Encoded("simple9", n, np.zeros(0, np.uint8), data,
                   data_bits=len(data) * 32, header_bits=32, meta={"table": "S9"})


def simple9_decode(enc: Encoded) -> np.ndarray:
    data = enc.data
    sels = (data >> 28).astype(np.int64)
    counts = np.asarray([c for c, _ in S9])[sels]
    starts = np.cumsum(counts) - counts
    total = int(starts[-1] + counts[-1]) if len(data) else 0
    out = np.zeros(total, np.uint32)
    for s, (cnt, b) in enumerate(S9):
        rows = np.flatnonzero(sels == s)
        if not len(rows):
            continue
        vals = (data[rows][:, None].astype(np.uint64) >> (np.arange(cnt) * b).astype(np.uint64)[None, :]) & np.uint64(mask_np(b))
        idx = starts[rows][:, None] + np.arange(cnt)[None, :]
        keep = idx < total
        out[idx[keep]] = vals.astype(np.uint32)[keep]
    return out[: enc.n]


def simple16_encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    e = ebw_np(x).astype(np.int64)
    if n and e.max() > 28:
        raise ValueError("Simple-16 supports at most 28-bit values")
    # per-selector per-slot widths
    widths = []
    for spec in S16:
        w = []
        for cnt, b in spec:
            w += [b] * cnt
        widths.append(np.asarray(w, np.int64))
    words, sels = [], []
    i = 0
    while i < n:
        for s, w in enumerate(widths):
            take = min(len(w), n - i)
            if not np.all(e[i:i + take] <= w[:take]):
                continue
            word = np.uint64(s) << np.uint64(28)
            off = 0
            for k in range(take):
                word |= np.uint64(x[i + k]) << np.uint64(off)
                off += int(w[k])
            words.append(np.uint32(word & np.uint64(0xFFFFFFFF)))
            sels.append(s)
            i += take
            break
        else:
            raise AssertionError("no simple16 selector fits")
    data = np.asarray(words, np.uint32)
    return Encoded("simple16", n, np.zeros(0, np.uint8), data,
                   data_bits=len(data) * 32, header_bits=32)


def simple16_decode(enc: Encoded) -> np.ndarray:
    data = enc.data
    sels = (data >> 28).astype(np.int64)
    widths = []
    for spec in S16:
        w = []
        for cnt, b in spec:
            w += [b] * cnt
        widths.append(w)
    counts = np.asarray([len(w) for w in widths])[sels]
    starts = np.cumsum(counts) - counts
    total = int(starts[-1] + counts[-1]) if len(data) else 0
    out = np.zeros(total, np.uint32)
    for s, w in enumerate(widths):
        rows = np.flatnonzero(sels == s)
        if not len(rows):
            continue
        offs = np.cumsum([0] + w[:-1])
        for k, (o, b) in enumerate(zip(offs, w)):
            idx = starts[rows] + k
            keep = idx < total
            out[idx[keep]] = ((data[rows].astype(np.uint64) >> np.uint64(o)) & np.uint64(mask_np(b))).astype(np.uint32)[keep]
    return out[: enc.n]


# --------------------------------------------------------------------------- #
# Rice / Elias Gamma (bit-aligned)
# --------------------------------------------------------------------------- #


def _unary_binary_encode(q: np.ndarray, extra_vals: np.ndarray, extra_bits: np.ndarray):
    """Per code: q ones, a zero, then extra_bits low bits of extra_vals."""
    q = q.astype(np.int64)
    full_chunks = q // 32
    vals, lens = [], []
    # expand: per code, full_chunks 32-one words, then remainder+terminator+extra
    reps = full_chunks
    order = np.repeat(np.arange(len(q)), reps + 1)               # chunk rows per code
    is_last = np.concatenate([[True] if r == 0 else [False] * r + [True] for r in reps]) if len(q) else np.zeros(0, bool)
    # build via python-free vector ops:
    rem = (q % 32).astype(np.uint64)
    last_val = (np.uint64(1) << rem) - np.uint64(1)              # rem ones, then 0 implicit
    last_val |= extra_vals.astype(np.uint64) << (rem + np.uint64(1))
    last_len = rem.astype(np.int64) + 1 + extra_bits.astype(np.int64)
    ones32 = np.uint64(0xFFFFFFFF)
    all_vals = np.where(is_last, 0, ones32).astype(np.uint64)
    all_lens = np.where(is_last, 0, 32).astype(np.int64)
    lastpos = np.cumsum(reps + 1) - 1
    all_vals[lastpos] = last_val
    all_lens[lastpos] = last_len
    return pack_bits_np(all_vals, all_lens)


def rice_k(x: np.ndarray) -> int:
    x = np.asarray(x, np.uint32)
    if len(x) == 0:
        return 0
    mean = float(x.astype(np.float64).mean())
    k = int(np.floor(np.log2(max(0.69 * mean, 1.0))))
    # cap the worst-case quotient so pathological tails stay linear
    kmin = max(0, int(ebw_np(np.asarray([x.max()]))[0]) - 20)
    return max(k, kmin, 0)


def rice_encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    k = rice_k(x)
    q = (x >> k).astype(np.int64)
    extra = (x.astype(np.uint64) & np.uint64(mask_np(k))) if k else np.zeros(len(x), np.uint64)
    words, bits = _unary_binary_encode(q, extra, np.full(len(x), k, np.int64))
    return Encoded("rice", len(x), np.zeros(0, np.uint8), words,
                   data_bits=bits, header_bits=32 + 8, meta={"k": k})


def rice_decode(enc: Encoded) -> np.ndarray:
    k = enc.meta["k"]
    n = enc.n
    if n == 0:
        return np.zeros(0, np.uint32)
    bits = words_to_bits_np(enc.data, len(enc.data) * 32)
    zpos = np.flatnonzero(bits == 0)
    w = np.concatenate([enc.data, np.zeros(2, np.uint32)])
    out = np.zeros(n, np.uint32)
    pos = 0
    for i in range(n):
        z = zpos[np.searchsorted(zpos, pos)]
        q = z - pos
        extra = int(gather_bits_np(w, np.asarray([z + 1]), np.asarray([k]))[0]) if k else 0
        out[i] = (q << k) | extra
        pos = z + 1 + k
    return out


def gamma_encode(x: np.ndarray) -> Encoded:
    """Elias Gamma on x+1 (gamma cannot code 0)."""
    x1 = np.asarray(x, dtype=np.uint32).astype(np.uint64) + 1
    b = ebw_np(x1).astype(np.int64)                              # 1..33
    q = b - 1                                                    # unary ones
    extra_bits = b - 1
    extra = x1 & ((np.uint64(1) << extra_bits.astype(np.uint64)) - np.uint64(1))
    words, bits = _unary_binary_encode(q, extra, extra_bits)
    return Encoded("gamma", len(x1), np.zeros(0, np.uint8), words,
                   data_bits=bits, header_bits=32)


def gamma_decode(enc: Encoded) -> np.ndarray:
    n = enc.n
    if n == 0:
        return np.zeros(0, np.uint32)
    bits = words_to_bits_np(enc.data, len(enc.data) * 32)
    zpos = np.flatnonzero(bits == 0)
    w = np.concatenate([enc.data, np.zeros(2, np.uint32)])
    out = np.zeros(n, np.uint32)
    pos = 0
    for i in range(n):
        z = zpos[np.searchsorted(zpos, pos)]
        q = z - pos                                              # = b-1
        extra = int(gather_bits_np(w, np.asarray([z + 1]), np.asarray([q]))[0]) if q else 0
        val = (np.uint64(1) << np.uint64(q)) | np.uint64(extra)
        out[i] = np.uint32(val - np.uint64(1))
        pos = z + 1 + q
    return out


# --------------------------------------------------------------------------- #
# scalar frame codecs: PForDelta / AFOR / PackedBinary (horizontal layout)
# --------------------------------------------------------------------------- #

PFD_FRAME = 128
W_CHOICES = np.array([8, 16, 32], np.int32)


def pfd_encode(x: np.ndarray, zeta: float = 0.10) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("pfordelta", 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32),
                       exceptions=np.zeros(0, np.uint32), header_bits=32, meta={"n_exc": np.zeros(0, np.int32)})
    nf = (n + PFD_FRAME - 1) // PFD_FRAME
    e = ebw_np(x)
    ep = np.concatenate([e, np.zeros(nf * PFD_FRAME - n, np.int32)]).reshape(nf, PFD_FRAME)
    k = int(np.ceil((1.0 - zeta) * PFD_FRAME)) - 1
    bws = np.maximum(np.partition(ep, k, axis=1)[:, k], 1).astype(np.int32)
    xp = np.concatenate([x, np.zeros(nf * PFD_FRAME - n, np.uint32)])
    b_int = np.repeat(bws, PFD_FRAME)
    exc_mask = np.concatenate([e, np.zeros(nf * PFD_FRAME - n, np.int32)]) > b_int
    exc_mask[n:] = False
    exc_idx = np.flatnonzero(exc_mask)
    exc_frame = exc_idx // PFD_FRAME
    n_exc = np.bincount(exc_frame, minlength=nf).astype(np.int32)
    wcodes = np.zeros(nf, np.int32)
    if len(exc_idx):
        maxe = np.zeros(nf, np.int32)
        np.maximum.at(maxe, exc_frame, ebw_np(xp[exc_idx]))
        wcodes = np.minimum(np.searchsorted(W_CHOICES, np.maximum(maxe, 1)), 2)
    ws = W_CHOICES[wcodes]
    vals_list, lens_list = [], []
    for f in np.flatnonzero(n_exc):
        sel = exc_frame == f
        pos = (exc_idx[sel] % PFD_FRAME).astype(np.uint64)
        vals = xp[exc_idx[sel]].astype(np.uint64)
        vals_list += [pos, vals]
        lens_list += [np.full(sel.sum(), 8, np.int64), np.full(sel.sum(), int(ws[f]), np.int64)]
    if vals_list:
        exc_words, exc_bits = pack_bits_np(np.concatenate(vals_list), np.concatenate(lens_list))
    else:
        exc_words, exc_bits = np.zeros(0, np.uint32), 0
    data, dbits = pack_bits_np(xp[:n].astype(np.uint64) & mask_np(b_int[:n]).astype(np.uint64), b_int[:n].astype(np.int64))
    control = np.stack([(bws.astype(np.uint8) | (wcodes.astype(np.uint8) << 6)), n_exc.astype(np.uint8)], axis=1).reshape(-1)
    return Encoded("pfordelta", n, control, data, control_bits=nf * 16,
                   data_bits=dbits, exceptions=exc_words, exception_bits=exc_bits,
                   header_bits=32, meta={"n_exc": n_exc})


def pfd_decode(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    c = enc.control.reshape(-1, 2)
    bws = (c[:, 0] & 63).astype(np.int64)
    ws = W_CHOICES[(c[:, 0] >> 6).astype(np.int64)]
    n_exc = c[:, 1].astype(np.int64)
    b_int = np.repeat(bws, PFD_FRAME)[: enc.n]
    offs = np.cumsum(b_int) - b_int
    out = gather_bits_np(enc.data, offs, b_int)
    tot = int(n_exc.sum())
    if tot:
        frame_bits = n_exc * (8 + ws)
        base = np.cumsum(frame_bits) - frame_bits
        fid = np.repeat(np.arange(len(n_exc)), n_exc)
        j = np.arange(tot) - np.repeat(np.cumsum(n_exc) - n_exc, n_exc)
        pos = gather_bits_np(enc.exceptions, base[fid] + j * 8, np.full(tot, 8))
        vals = gather_bits_np(enc.exceptions, base[fid] + n_exc[fid] * 8 + j * ws[fid], ws[fid])
        g = fid * PFD_FRAME + pos
        out[g[g < enc.n]] = vals[g < enc.n]
    return out


def afor_encode(x: np.ndarray) -> Encoded:
    """Scalar AFOR: frames of {8,16,32} integers, DP partition, 1-byte headers."""
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("afor", 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32), header_bits=32)
    e = ebw_np(x)
    nb = (n + 7) // 8
    ep = np.concatenate([e, np.zeros(nb * 8 - n, np.int32)])
    m1 = np.maximum(ep.reshape(-1, 8).max(axis=1), 1)
    m2 = np.maximum(m1[:-1], m1[1:]) if nb > 1 else np.zeros(0, np.int32)
    m4 = np.maximum(m2[:-2], m2[2:]) if nb > 3 else np.zeros(0, np.int32)
    dp = np.zeros(nb + 1, np.int64)
    ch = np.zeros(nb, np.int8)
    for i in range(nb - 1, -1, -1):
        best = 8 + 8 * int(m1[i]) + dp[i + 1]
        c = 0
        if i + 2 <= nb and 8 + 16 * int(m2[i]) + dp[i + 2] < best:
            best, c = 8 + 16 * int(m2[i]) + dp[i + 2], 1
        if i + 4 <= nb and 8 + 32 * int(m4[i]) + dp[i + 4] < best:
            best, c = 8 + 32 * int(m4[i]) + dp[i + 4], 2
        dp[i], ch[i] = best, c
    sizes, bws = [], []
    i = 0
    while i < nb:
        c = int(ch[i])
        blocks = (1, 2, 4)[c]
        sizes.append(blocks * 8)
        if c == 0:
            bws.append(int(m1[i]))
        elif c == 1:
            bws.append(int(m2[i]))
        else:
            bws.append(int(m4[i]))
        i += blocks
    sizes = np.asarray(sizes, np.int64)
    bws = np.asarray(bws, np.int64)
    b_int = np.repeat(bws, sizes)[:n]
    data, dbits = pack_bits_np(x.astype(np.uint64) & mask_np(b_int).astype(np.uint64), b_int)
    control = (np.searchsorted([8, 16, 32], sizes).astype(np.uint8) | (bws.astype(np.uint8) << 2))
    return Encoded("afor", n, control, data, control_bits=len(control) * 8,
                   data_bits=dbits, header_bits=32)


def afor_decode(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    sizes = np.asarray([8, 16, 32])[(enc.control & 3).astype(np.int64)]
    bws = (enc.control >> 2).astype(np.int64)
    b_int = np.repeat(bws, sizes)[: enc.n]
    offs = np.cumsum(b_int) - b_int
    return gather_bits_np(enc.data, offs, b_int)


def packedbinary_encode(x: np.ndarray, frame: int = 512) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("packed_binary", 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32), header_bits=32, meta={"frame": frame})
    nf = (n + frame - 1) // frame
    e = np.concatenate([ebw_np(x), np.zeros(nf * frame - n, np.int32)]).reshape(nf, frame)
    bws = np.maximum(e.max(axis=1), 1).astype(np.int64)
    b_int = np.repeat(bws, frame)[:n]
    data, dbits = pack_bits_np(x.astype(np.uint64), b_int)
    return Encoded("packed_binary", n, bws.astype(np.uint8), data,
                   control_bits=nf * 8, data_bits=dbits, header_bits=32, meta={"frame": frame})


def packedbinary_decode(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    frame = enc.meta["frame"]
    bws = enc.control.astype(np.int64)
    b_int = np.repeat(bws, frame)[: enc.n]
    offs = np.cumsum(b_int) - b_int
    return gather_bits_np(enc.data, offs, b_int)
