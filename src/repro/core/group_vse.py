"""Group-VSEncoding (paper §6.1): VSEncoding wrapped in the Group approach.

VSEncoding partitions via dynamic programming over a richer frame-length set
than AFOR; the Group version multiplies lengths by 4 (quadruples) and runs the
DP on the quad max array.  Frame lengths (in quadruples): {1, 2, 4, 8, 12,
16, 32, 64}.  Header: 1 byte/frame = 3-bit length code | 5-bit bit width
(bw <= 32 fits).  Data: 4-way vertical component streams, same unpack
machinery as the other frame codecs.

The paper reports SIMD-Group-VSEncoding ~2x the original VSEncoding but still
behind SIMD-Group-AFOR — our ratio/speed rows let the same comparison be made
(bench_ratio / bench_speed include it).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np
from .encoded import Encoded
from .frames import pack_data, quads_of, unpack_data_jnp, unpack_data_np, unpack_data_scalar_jnp
from .layout import quadmax_np

SIZES_Q = np.array([1, 2, 4, 8, 12, 16, 32, 64])   # frame sizes in quadruples
HEADER_BITS = 8

# device-arena geometry: one 512-posting index block is at most ARENA_Q
# quadruples; the DP may emit frames as small as one quad
ARENA_Q = 128
ARENA_F = ARENA_Q


def _partition(e: np.ndarray):
    """DP over quad positions; steps = SIZES_Q.  O(8Q) python — encode side."""
    q = len(e)
    # sliding maxima per size via running max trick
    dp = np.full(q + 1, np.int64(1) << 60)
    dp[q] = 0
    choice = np.zeros(q, np.int8)
    # precompute prefix sparse-table-ish: for each size, max over [i, i+s)
    maxes = {}
    for si, s in enumerate(SIZES_Q):
        if s > q:
            break
        sl = np.lib.stride_tricks.sliding_window_view(e, min(s, q))
        maxes[si] = sl.max(axis=1)
    for i in range(q - 1, -1, -1):
        best, ch = dp[i], 0
        for si, s in enumerate(SIZES_Q):
            if i + s > q:             # size 1 always fits; larger ones may not
                break
            m = int(maxes[si][i])
            cost = HEADER_BITS + 4 * s * max(m, 1) + dp[i + s]
            if cost < best:
                best, ch = cost, si
        dp[i] = best
        choice[i] = ch
    sizes, bws = [], []
    i = 0
    while i < q:
        s = int(SIZES_Q[choice[i]])
        m = int(e[i:min(i + s, q)].max(initial=0))
        sizes.append(s)
        bws.append(max(m, 1))
        i += s
    return np.asarray(sizes, np.int32), np.asarray(bws, np.int32)


def encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("group_vse", 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32),
                       header_bits=32, meta={"Q": 0})
    v = quads_of(x)
    e = ebw_np(quadmax_np(x, 4, pseudo=True))
    sizes, bws = _partition(e)
    q = len(e)
    bw_quads = np.repeat(bws, sizes)[:q]
    data, dbits = pack_data(v, bw_quads)
    size_code = np.searchsorted(SIZES_Q, sizes).astype(np.uint8)
    control = np.stack([size_code, bws.astype(np.uint8)], axis=1).reshape(-1)
    return Encoded(
        "group_vse", n, control, data.reshape(-1),
        control_bits=len(sizes) * 16, data_bits=dbits * 4, header_bits=32,
        meta={"Q": q},
    )


def _headers(control: np.ndarray):
    c = control.reshape(-1, 2)
    return SIZES_Q[c[:, 0].astype(np.int64)].astype(np.int64), c[:, 1].astype(np.int32)


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    sizes, bws = _headers(enc.control)
    bw_quads = np.repeat(bws, sizes)[: enc.meta["Q"]]
    return unpack_data_np(enc.data.reshape(-1, 4), bw_quads, enc.n)


def jax_args(enc: Encoded) -> dict:
    data = enc.data.reshape(-1, 4)
    data = np.concatenate([data, np.zeros((1, 4), np.uint32)])
    return {
        "control": jnp.asarray(enc.control.astype(np.int32)),
        "data": jnp.asarray(data),
        "n": enc.n,
        "q": enc.meta["Q"],
    }


SIZES_J = jnp.asarray(SIZES_Q)


def _bw_quads(control, q: int):
    c = control.reshape(-1, 2)
    return jnp.repeat(c[:, 1], SIZES_J[c[:, 0]], total_repeat_length=max(q, 1))


@functools.partial(jax.jit, static_argnames=("n", "q"))
def decode_jax_vec(control, data, n: int, q: int):
    return unpack_data_jnp(data, _bw_quads(control, q), n)


@functools.partial(jax.jit, static_argnames=("n", "q"))
def decode_jax_scalar(control, data, n: int, q: int):
    return unpack_data_scalar_jnp(data, _bw_quads(control, q), n, q)


def decode_arena_block(ctrl, data, ctrl_len, data_len, n_valid):
    """Fixed-shape single-block decode for the device arena
    (``repro.index.device``): padded static shapes + dynamic lengths, so a
    work-list of (term, block) pairs decodes lane-parallel under ``vmap``.

    ctrl:  (2 * ARENA_F,) int32 header bytes, interleaved (size code, bw) per
           frame; bytes >= ``ctrl_len`` are arena slack and are masked out.
    data:  (4 * (W + 2),) flat uint32 words gathered from the data arena.
    ctrl_len, data_len, n_valid: dynamic word / integer counts of this block.
    Returns (4 * ARENA_Q,) uint32 values, zero beyond ``n_valid``.
    """
    c = ctrl.reshape(-1, 2)
    fmax = c.shape[0]
    f_valid = jnp.arange(fmax, dtype=jnp.int32) < (ctrl_len >> 1)
    sizes = jnp.where(f_valid, SIZES_J[jnp.clip(c[:, 0], 0, 7)], 0)
    bws = c[:, 1].astype(jnp.int32)
    starts = jnp.cumsum(sizes) - sizes
    # valid frames are >= 1 quad, so their starts are strictly increasing
    marks = jnp.zeros(ARENA_Q, jnp.int32).at[
        jnp.where(f_valid, starts, ARENA_Q)].add(1, mode="drop")
    fid = jnp.clip(jnp.cumsum(marks) - 1, 0, fmax - 1)
    q = jnp.arange(ARENA_Q, dtype=jnp.int32)
    q_len = (n_valid + 3) >> 2
    bw_quads = jnp.where(q < q_len, bws[fid], 0)
    out = unpack_data_jnp(data.reshape(-1, 4), bw_quads, 4 * ARENA_Q)
    i = jnp.arange(4 * ARENA_Q, dtype=jnp.int32)
    return jnp.where(i < n_valid, out, 0)
