"""Core library: the paper's general SIMD compression approach in JAX.

Public API:
  codec.REGISTRY / codec.get / codec.names — all codecs (Table VI)
  Encoded — compressed stream container with exact bit accounting
  dgap — d-gap transform (paper §2.1.1)
  layout — k-way vertical layout + quad-max (paper §3.1/§4.4)
"""

from . import (bits, bp128, bp_tpu, codec, dgap, frames, group_afor,
               group_pfd, group_scheme, group_simple, group_vse, layout,
               scalar, stream_vbyte)
from .encoded import Encoded

__all__ = [
    "bits", "bp128", "bp_tpu", "codec", "dgap", "frames", "group_afor",
    "group_pfd", "group_scheme", "group_simple", "group_vse", "layout",
    "scalar", "stream_vbyte", "Encoded",
]
