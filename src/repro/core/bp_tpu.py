"""BP-TPU: the beyond-paper, TPU-native wide vertical layout codec.

Generalizes SIMD-BP128's 4-lane frames to the kernel tile (DESIGN §2): a
frame is 4096 integers in a (32, 128) tile, packed at the frame's OR-pseudo-
max bit width into exactly (bw, 128) words — the layout consumed directly by
kernels/bitpack (VPU shift+mask) and kernels/unpack_delta (fused d-gap
decode).  Ratio cost vs BP128: one bit width now covers 4096 ints instead of
128 (measured +0.5-1.5 bits/int on posting streams) in exchange for
full-vreg-width decode with zero per-group control flow.

Encode/decode here run the pure-jnp ref kernels under jit (CPU); on TPU the
same arrays feed the Pallas kernels unchanged.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .bits import ebw_np
from .encoded import Encoded
from repro.kernels import ref
from repro.kernels.bitpack import FRAME_INTS, FRAME_ROWS, LANES


def encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("bp_tpu", 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32),
                       header_bits=32, meta={"bws": np.zeros(0, np.int32)})
    f = -(-n // FRAME_INTS)
    xp = np.concatenate([x, np.zeros(f * FRAME_INTS - n, np.uint32)])
    tiles = xp.reshape(f, FRAME_ROWS, LANES)
    # OR pseudo-max per frame (paper §4.4 on the TPU tile)
    bws = np.maximum(ebw_np(np.bitwise_or.reduce(tiles.reshape(f, -1), axis=1)), 1)
    parts = []
    for bw in np.unique(bws):
        sel = np.flatnonzero(bws == bw)
        packed = ref.pack_frames_ref(
            jnp.asarray(tiles[sel].reshape(-1, LANES)), int(bw))
        parts.append((int(bw), sel, np.asarray(packed)))
    data = np.concatenate([p[2].reshape(-1) for p in parts]) if parts else np.zeros(0, np.uint32)
    return Encoded(
        "bp_tpu", n, bws.astype(np.uint8), data,
        control_bits=f * 8, data_bits=int((bws.astype(np.int64) * FRAME_INTS).sum()),
        header_bits=32,
        meta={"bws": bws, "parts": [(p[0], p[1]) for p in parts]},
    )


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    bws = enc.meta["bws"]
    f = len(bws)
    out = np.zeros((f, FRAME_ROWS, LANES), np.uint32)
    off = 0
    for bw, sel in enc.meta["parts"]:
        words = bw * LANES * len(sel)
        packed = enc.data[off:off + words].reshape(-1, LANES)
        off += words
        tiles = np.asarray(ref.unpack_frames_ref(jnp.asarray(packed), int(bw)))
        out[sel] = tiles.reshape(len(sel), FRAME_ROWS, LANES)
    return out.reshape(-1)[: enc.n]
