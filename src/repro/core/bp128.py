"""(SIMD-)BP128 and Group-PackedBinary as special cases of the approach (§6.3).

BP128: fixed frames of 128 integers (32 quadruples), one 8-bit bw header per
frame, 4-way vertical layout.  Group-PackedBinary: same with 512-integer
frames (the paper's PackedBinary experimental setting).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np
from .encoded import Encoded
from .frames import pack_data, quads_of, unpack_data_jnp, unpack_data_np, unpack_data_scalar_jnp
from .layout import quadmax_np


def encode(x: np.ndarray, frame_quads: int = 32, name: str = "bp128") -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded(name, 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32),
                       header_bits=32, meta={"Q": 0, "frame_quads": frame_quads})
    v = quads_of(x)
    qm = quadmax_np(x, 4, pseudo=True)
    e = ebw_np(qm)
    q = len(qm)
    nf = (q + frame_quads - 1) // frame_quads
    epad = np.concatenate([e, np.zeros(nf * frame_quads - q, np.int32)])
    bws = np.maximum(epad.reshape(nf, frame_quads).max(axis=1), 1).astype(np.int32)
    bw_quads = np.repeat(bws, frame_quads)[:q]
    data, dbits = pack_data(v, bw_quads)
    return Encoded(
        name, n, bws.astype(np.uint8), data.reshape(-1),
        control_bits=nf * 8, data_bits=dbits * 4, header_bits=32,
        meta={"Q": q, "frame_quads": frame_quads},
    )


def encode_packed_binary(x: np.ndarray) -> Encoded:
    return encode(x, frame_quads=128, name="g_packed_binary")


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    q = enc.meta["Q"]
    bw_quads = np.repeat(enc.control.astype(np.int32), enc.meta["frame_quads"])[:q]
    return unpack_data_np(enc.data.reshape(-1, 4), bw_quads, enc.n)


def jax_args(enc: Encoded) -> dict:
    data = enc.data.reshape(-1, 4)
    data = np.concatenate([data, np.zeros((1, 4), np.uint32)])
    return {
        "control": jnp.asarray(enc.control.astype(np.int32)),
        "data": jnp.asarray(data),
        "n": enc.n,
        "q": enc.meta["Q"],
        "frame_quads": enc.meta["frame_quads"],
    }


@functools.partial(jax.jit, static_argnames=("n", "q", "frame_quads"))
def decode_jax_vec(control, data, n: int, q: int, frame_quads: int):
    bw_quads = jnp.repeat(control, frame_quads, total_repeat_length=max(q, 1))
    return unpack_data_jnp(data, bw_quads, n)


def decode_arena_block(control: jnp.ndarray, data: jnp.ndarray,
                       n_valid: jnp.ndarray, frame_quads: int) -> jnp.ndarray:
    """Fixed-shape single-block decode for the device arena
    (``repro.index.device``): padded static shapes + dynamic length, so a
    work-list of (term, block) pairs decodes lane-parallel under ``vmap``.

    control: (C_MAX,) int32 per-frame bit widths (rows >= the block's frame
             count are arena slack; they are masked to bw=0 below).
    data:    (W_MAX + 2, 4) uint32 words gathered from the data arena (slack
             rows past the block are garbage but every read they feed is
             masked by a bw=0 quad or sits below the value's mask).
    n_valid: dynamic integer count of this block.
    Returns (4 * C_MAX * frame_quads,) uint32 values, zero beyond ``n_valid``.
    """
    qmax = control.shape[0] * frame_quads
    q = jnp.arange(qmax, dtype=jnp.int32)
    q_len = (n_valid + 3) >> 2
    bw_quads = jnp.where(q < q_len, control[q // frame_quads], 0)
    out = unpack_data_jnp(data, bw_quads, 4 * qmax)
    i = jnp.arange(4 * qmax, dtype=jnp.int32)
    return jnp.where(i < n_valid, out, 0)


@functools.partial(jax.jit, static_argnames=("n", "q", "frame_quads"))
def decode_jax_scalar(control, data, n: int, q: int, frame_quads: int):
    bw_quads = jnp.repeat(control, frame_quads, total_repeat_length=max(q, 1))
    return unpack_data_scalar_jnp(data, bw_quads, n, q)
