"""Group-Simple (paper §4): word-aligned codec with separated control/data areas.

Encoding format (Fig. 2):
  * control area — one 4-bit selector per 128-bit data vector, two per byte.
  * data area    — 128-bit vectors = 4 x uint32 components, 4-way vertical
    layout: quadruple k of a vector puts its 4 integers at bit offset k*BW of
    components 0..3.

Ten patterns (Table III): (NUM, BW) with NUM integers per component, BW bits
each, BW up to 32 (vs 28 for Simple-9/16).

Pattern selection (Algorithm 1) runs on the *quad max array* — the OR-reduced
pseudo-max (§4.4) — so it touches a quarter of the input.

Decoders:
  * ``decode_np``          — numpy oracle.
  * ``decode_jax_scalar``  — paper's scalar routine: sequential scan over
    selectors, one 128-bit vector per step (the "Group-Simple" rows of
    Table VII).
  * ``decode_jax_vec``     — the vectorized version (SIMD-Group-Simple): all
    vectors decoded lane-parallel; on TPU every (pattern, slot, component)
    shift+mask runs on the VPU and the scatter is a single gather-free store.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np, mask_np, pack_bits_np
from .encoded import Encoded
from .layout import quadmax_np, to_vertical_np

NUM = np.array([32, 16, 10, 8, 6, 5, 4, 3, 2, 1], dtype=np.int32)
BW = np.array([1, 2, 3, 4, 5, 6, 8, 10, 16, 32], dtype=np.int32)

NUM_J = jnp.asarray(NUM)
BW_J = jnp.asarray(BW)
# shift of slot k under selector s, clipped to a legal shift amount; slots
# k >= NUM[s] are masked out by VALID.
_SHIFTS = np.minimum(np.arange(32)[None, :] * BW[:, None], 31).astype(np.uint32)
SHIFTS_J = jnp.asarray(_SHIFTS)
VALID = np.arange(32)[None, :] < NUM[:, None]
VALID_J = jnp.asarray(VALID)
MASKS_J = jnp.asarray(mask_np(BW))


# --------------------------------------------------------------------------- #
# encoding (host / numpy)
# --------------------------------------------------------------------------- #


def _run_lengths(fits: np.ndarray) -> np.ndarray:
    """runlen[j] = number of consecutive True starting at j."""
    q = len(fits)
    false_pos = np.flatnonzero(~fits)
    if len(false_pos) == 0:
        return q - np.arange(q)
    nxt = np.searchsorted(false_pos, np.arange(q), side="left")
    nxt_false = np.where(nxt < len(false_pos), false_pos[np.minimum(nxt, len(false_pos) - 1)], q)
    return nxt_false - np.arange(q)


def select_patterns(quadmax: np.ndarray) -> np.ndarray:
    """Algorithm 1 on the quad max array -> array of selectors."""
    e = ebw_np(quadmax)
    q = len(e)
    runlen = np.stack([_run_lengths(e <= BW[s]) for s in range(10)])
    sels = []
    j = 0
    while j < q:
        rem = q - j
        for s in range(10):
            need = min(int(NUM[s]), rem)
            if runlen[s, j] >= need:
                sels.append(s)
                j += need
                break
        else:  # pragma: no cover - sel 9 (BW=32) always fits
            raise AssertionError("no pattern fits")
    return np.asarray(sels, dtype=np.uint8)


def encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("group_simple", 0, np.zeros(0, np.uint32), np.zeros(0, np.uint32), header_bits=32)
    v = to_vertical_np(x, 4)                      # (Q, 4)
    qm = quadmax_np(x, 4, pseudo=True)
    sels = select_patterns(qm)
    p = len(sels)
    starts = np.concatenate([[0], np.cumsum(NUM[sels])[:-1]])  # quad offset per vector
    data = np.zeros((p, 4), dtype=np.uint32)
    qlen = len(qm)
    for s in range(10):
        rows = np.flatnonzero(sels == s)
        if len(rows) == 0:
            continue
        num, bw = int(NUM[s]), int(BW[s])
        idx = starts[rows][:, None] + np.arange(num)[None, :]          # (R, num)
        valid = idx < qlen
        idx = np.minimum(idx, qlen - 1)
        vals = v[idx].astype(np.uint64) & np.uint64(mask_np(bw))       # (R, num, 4)
        vals = np.where(valid[:, :, None], vals, 0)
        shifts = (np.arange(num) * bw).astype(np.uint64)
        packed = np.zeros((len(rows), 4), dtype=np.uint64)
        for k in range(num):
            packed |= vals[:, k, :] << shifts[k]
        data[rows] = packed.astype(np.uint32)
    control, cbits = pack_bits_np(sels.astype(np.uint64), np.full(p, 4, np.int64))
    return Encoded(
        "group_simple", n, control, data.reshape(-1),
        control_bits=cbits, data_bits=int(data.size) * 32, header_bits=32,
        meta={"sels": sels, "n_vectors": p},
    )


# --------------------------------------------------------------------------- #
# numpy oracle decode
# --------------------------------------------------------------------------- #


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    sels = enc.meta["sels"]
    p = len(sels)
    data = enc.data.reshape(p, 4)
    starts = np.concatenate([[0], np.cumsum(NUM[sels])[:-1]])
    total_q = int(starts[-1] + NUM[sels[-1]]) if p else 0
    out = np.zeros((total_q, 4), dtype=np.uint32)
    for s in range(10):
        rows = np.flatnonzero(sels == s)
        if len(rows) == 0:
            continue
        num, bw = int(NUM[s]), int(BW[s])
        shifts = (np.arange(num) * bw).astype(np.uint64)
        vals = (data[rows].astype(np.uint64)[:, None, :] >> shifts[None, :, None]) & np.uint64(mask_np(bw))
        idx = starts[rows][:, None] + np.arange(num)[None, :]
        keep = idx < total_q
        out[np.minimum(idx, total_q - 1)[keep]] = vals.astype(np.uint32)[keep]
    return out.reshape(-1)[: enc.n]


# --------------------------------------------------------------------------- #
# JAX decoders
# --------------------------------------------------------------------------- #


def jax_args(enc: Encoded) -> dict:
    sels = jnp.asarray(enc.meta["sels"].astype(np.int32))
    data = jnp.asarray(enc.data.reshape(-1, 4))
    return {"sels": sels, "data": data, "n": enc.n}


@functools.partial(jax.jit, static_argnames=("n",))
def decode_jax_vec(sels: jnp.ndarray, data: jnp.ndarray, n: int) -> jnp.ndarray:
    """SIMD-Group-Simple decode, gather formulation: every output integer
    locates its (vector, slot, component) and extracts with one shift+mask.

    Replaces the original scatter formulation (kept below as
    ``decode_jax_vec_scatter``): that one materialized all 32 slots per
    pattern (~4x wasted lanes at NUM~8) and paid a scatter; this one is
    O(n) gathers with zero waste — 6.5x faster on CPU, and on TPU it is the
    lane-parallel shape the VPU wants (EXPERIMENTS.md §Perf, iteration 1).
    """
    num = NUM_J[sels]                                            # (P,)
    ends = jnp.cumsum(4 * num)                                   # (P,)
    starts = ends - 4 * num
    i = jnp.arange(n, dtype=jnp.int32)
    # segment id via boundary marks + cumsum (searchsorted measured 1.5x
    # slower here — §Perf iteration 2)
    marks = jnp.zeros(n, jnp.int32).at[starts].add(1, mode="drop")
    p = jnp.cumsum(marks) - 1
    sel = sels[p]
    local = i - starts[p]
    k = (local >> 2).astype(jnp.uint32)
    c = local & 3
    bw = BW_J[sel].astype(jnp.uint32)
    word = data.reshape(-1)[p * 4 + c]
    return jnp.right_shift(word, k * bw) & MASKS_J[sel]


def decode_arena_block(sels: jnp.ndarray, data: jnp.ndarray,
                       p_len: jnp.ndarray, n_valid: jnp.ndarray) -> jnp.ndarray:
    """Fixed-shape single-block decode for the device arena
    (``repro.index.device``): same gather formulation as ``decode_jax_vec``
    but with *padded static shapes* and dynamic lengths, so a whole work-list
    of (term, block) pairs decodes lane-parallel under one ``vmap``/``jit``.

    sels: (P_MAX,) int32 selectors (rows >= p_len are arena slack, ignored).
    data: (P_MAX, 4) uint32 vectors gathered from the data arena.
    p_len, n_valid: dynamic vector / integer counts of this block.
    Returns (4 * P_MAX,) uint32 values, zero beyond ``n_valid``.
    """
    pmax = sels.shape[0]
    nmax = 4 * pmax
    valid_p = jnp.arange(pmax, dtype=jnp.int32) < p_len
    num = jnp.where(valid_p, NUM_J[sels], 0)
    ends = jnp.cumsum(4 * num)
    starts = ends - 4 * num
    i = jnp.arange(nmax, dtype=jnp.int32)
    marks = jnp.zeros(nmax, jnp.int32).at[
        jnp.where(valid_p, starts, nmax)].add(1, mode="drop")
    p = jnp.clip(jnp.cumsum(marks) - 1, 0, pmax - 1)
    sel = sels[p]
    local = i - starts[p]
    k = (local >> 2).astype(jnp.uint32)
    c = local & 3
    bw = BW_J[sel].astype(jnp.uint32)
    word = data.reshape(-1)[p * 4 + c]
    # lanes past the decoded tail alias the last vector with huge `local`;
    # clip the shift to stay defined, the value is masked out below anyway
    vals = jnp.right_shift(word, jnp.minimum(k * bw, jnp.uint32(31))) & MASKS_J[sel]
    return jnp.where(i < n_valid, vals, 0)


@functools.partial(jax.jit, static_argnames=("n",))
def decode_jax_vec_scatter(sels: jnp.ndarray, data: jnp.ndarray, n: int) -> jnp.ndarray:
    """Original scatter formulation (first §Perf iteration baseline)."""
    p = sels.shape[0]
    num = NUM_J[sels]                                            # (P,)
    offs = 4 * (jnp.cumsum(num) - num)                           # (P,) int offsets
    shifts = SHIFTS_J[sels]                                      # (P, 32)
    masks = MASKS_J[sels]                                        # (P,)
    vals = jnp.right_shift(data[:, None, :], shifts[:, :, None].astype(jnp.uint32))
    vals = vals & masks[:, None, None]                           # (P, 32, 4)
    slot = jnp.arange(32, dtype=jnp.int32)
    idx = offs[:, None, None] + 4 * slot[None, :, None] + jnp.arange(4, dtype=jnp.int32)[None, None, :]
    valid = VALID_J[sels][:, :, None] & jnp.ones((p, 32, 4), bool)
    idx = jnp.where(valid, idx, n)                               # out-of-range -> dropped
    out = jnp.zeros(n, dtype=jnp.uint32).at[idx.reshape(-1)].set(
        vals.reshape(-1), mode="drop", unique_indices=True)
    return out


@functools.partial(jax.jit, static_argnames=("n",))
def decode_jax_scalar(sels: jnp.ndarray, data: jnp.ndarray, n: int) -> jnp.ndarray:
    """Paper-faithful scalar decode: one vector per scan step, switch on SEL."""

    def branch(s):
        num, bw = int(NUM[s]), int(BW[s])

        def body(vec):
            shifts = (jnp.arange(num, dtype=jnp.uint32) * np.uint32(bw))
            vals = jnp.right_shift(vec[None, :], shifts[:, None]) & jnp.uint32(int(mask_np(bw)))
            buf = jnp.zeros((32, 4), jnp.uint32).at[:num].set(vals)
            return buf.reshape(-1), jnp.int32(4 * num)

        return body

    branches = [branch(s) for s in range(10)]

    def step(carry, inp):
        out, off = carry
        sel, vec = inp
        buf, adv = jax.lax.switch(sel, branches, vec)
        out = jax.lax.dynamic_update_slice(out, buf, (off,))
        return (out, off + adv), None

    out0 = jnp.zeros(n + 128, dtype=jnp.uint32)
    (out, _), _ = jax.lax.scan(step, (out0, jnp.int32(0)), (sels, data))
    return out[:n]
