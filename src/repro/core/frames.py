"""Shared machinery for the frame-based Group codecs (paper §6).

A frame codec assigns one bit width to a *run of quadruples*; after expanding
per-frame headers to a per-quad bit-width array, packing/unpacking is identical
for Group-AFOR, Group-PFD, (SIMD-)BP128 and Group-PackedBinary: four vertical
component bitstreams, values of bw[q] bits at offset cumsum(bw)[q-1].
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .bits import gather_bits_np, mask_jnp, mask_np, pack_bits_np
from .layout import to_vertical_np


def pack_data(v: np.ndarray, bw: np.ndarray) -> tuple[np.ndarray, int]:
    """Pack (Q, 4) ints with bw[q] bits per value into a (W, 4) word array."""
    bw = np.asarray(bw, dtype=np.int64)
    msk = mask_np(bw).astype(np.uint64)
    cols, total = [], 0
    for c in range(4):
        w, total = pack_bits_np(v[:, c].astype(np.uint64) & msk, bw)
        cols.append(w)
    if total == 0:
        return np.zeros((0, 4), np.uint32), 0
    return np.stack(cols, axis=1), total


def unpack_data_np(data: np.ndarray, bw: np.ndarray, n: int) -> np.ndarray:
    bw = np.asarray(bw, dtype=np.int64)
    ends = np.cumsum(bw)
    offs = ends - bw
    out = np.stack([gather_bits_np(data[:, c], offs, bw) for c in range(4)], axis=1)
    return out.reshape(-1)[:n]


def unpack_data_jnp(data: jnp.ndarray, bw: jnp.ndarray, n: int) -> jnp.ndarray:
    """Vectorized unpack: data (W+1, 4) with slack row, bw (Q,) int32."""
    bw = bw.astype(jnp.uint32)
    ends = jnp.cumsum(bw)
    offs = (ends - bw).astype(jnp.int32)
    word = offs >> 5
    bit = (offs & 31).astype(jnp.uint32)[:, None]
    lo = data[word]
    hi = data[word + 1]
    val = jnp.right_shift(lo, bit) | jnp.where(
        bit == 0, jnp.uint32(0), jnp.left_shift(hi, jnp.uint32(32) - bit))
    return (val & mask_jnp(bw)[:, None]).reshape(-1)[:n]


def unpack_data_scalar_jnp(data: jnp.ndarray, bw: jnp.ndarray, n: int, q: int) -> jnp.ndarray:
    """Scalar unpack: one quadruple per scan step (paper's non-SIMD decode)."""

    def step(pos, bwq):
        bwq = bwq.astype(jnp.uint32)
        w = pos >> 5
        b = (pos & 31).astype(jnp.uint32)
        lo = data[w]
        hi = jnp.where(b == 0, jnp.zeros(4, jnp.uint32),
                       jnp.left_shift(data[w + 1], jnp.uint32(32) - b))
        vals = (jnp.right_shift(lo, b) | hi) & mask_jnp(bwq)
        return pos + bwq.astype(jnp.int32), vals

    _, vals = jax.lax.scan(step, jnp.int32(0), bw[:q].astype(jnp.int32))
    return vals.reshape(-1)[:n]


def quads_of(x: np.ndarray) -> np.ndarray:
    return to_vertical_np(np.asarray(x, np.uint32), 4)
