"""Group-AFOR (paper §6.1): adaptive frames over the quad max array.

Frame sizes {32, 64, 128} integers = {8, 16, 32} quadruples.  The optimal
partition minimizes total bits via dynamic programming on the quad max array
(boundaries land on 8-quad blocks because all sizes are multiples of 8).
Header: 1 byte per frame = 2-bit size code + 6-bit bit width.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np
from .encoded import Encoded
from .frames import pack_data, quads_of, unpack_data_jnp, unpack_data_np, unpack_data_scalar_jnp
from .layout import quadmax_np

SIZES_Q = np.array([8, 16, 32])          # frame sizes in quadruples
HEADER_BITS = 8

# device-arena geometry: one 512-posting index block is at most ARENA_Q
# quadruples, partitioned into frames of >= SIZES_Q.min() quads each
ARENA_Q = 128
ARENA_F = ARENA_Q // 8


def _partition(qm_ebw: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """DP partition -> (sizes_in_quads, bw) per frame."""
    q = len(qm_ebw)
    nb = (q + 7) // 8
    e = np.concatenate([qm_ebw, np.zeros(nb * 8 - q, np.int32)])
    bmax1 = e.reshape(-1, 8).max(axis=1)                       # max over 1 block
    bmax2 = np.maximum(bmax1[:-1], bmax1[1:]) if nb > 1 else np.zeros(0, np.int32)
    bmax4 = (np.maximum(bmax2[:-2], bmax2[2:]) if nb > 3 else np.zeros(0, np.int32))
    bmax1 = np.maximum(bmax1, 1)  # a frame of all zeros still needs bw >= 1
    dp = np.zeros(nb + 1, dtype=np.int64)
    choice = np.zeros(nb, dtype=np.int8)
    for i in range(nb - 1, -1, -1):
        best = HEADER_BITS + 32 * 1 * int(bmax1[i]) + dp[i + 1]
        ch = 0
        if i + 2 <= nb:
            c = HEADER_BITS + 32 * 2 * int(max(bmax2[i], 1)) + dp[i + 2]
            if c < best:
                best, ch = c, 1
        if i + 4 <= nb:
            c = HEADER_BITS + 32 * 4 * int(max(bmax4[i], 1)) + dp[i + 4]
            if c < best:
                best, ch = c, 2
        dp[i] = best
        choice[i] = ch
    sizes, bws = [], []
    i = 0
    while i < nb:
        ch = int(choice[i])
        nblocks = (1, 2, 4)[ch]
        sizes.append(nblocks * 8)
        if ch == 0:
            bws.append(int(bmax1[i]))
        elif ch == 1:
            bws.append(int(max(bmax2[i], 1)))
        else:
            bws.append(int(max(bmax4[i], 1)))
        i += nblocks
    return np.asarray(sizes, np.int32), np.asarray(bws, np.int32)


def encode(x: np.ndarray) -> Encoded:
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded("group_afor", 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32),
                       header_bits=32, meta={"Q": 0})
    v = quads_of(x)
    qm = quadmax_np(x, 4, pseudo=True)
    e = ebw_np(qm)
    sizes, bws = _partition(e)
    q = len(qm)
    bw_quads = np.repeat(bws, sizes)[:q]  # DP padded to 8-quad blocks; trim
    # tail frame may extend past Q; packing uses only the first Q quads
    data, dbits = pack_data(v, bw_quads)
    size_code = np.searchsorted(SIZES_Q, sizes).astype(np.uint8)
    control = (size_code | (bws.astype(np.uint8) << 2))
    return Encoded(
        "group_afor", n, control, data.reshape(-1),
        control_bits=len(control) * 8, data_bits=dbits * 4, header_bits=32,
        meta={"Q": q, "sizes": sizes, "bws": bws},
    )


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    q = enc.meta["Q"]
    sizes = (enc.control & 3).astype(np.int64)
    sizes = SIZES_Q[sizes]
    bws = (enc.control >> 2).astype(np.int32)
    bw_quads = np.repeat(bws, sizes)[:q]
    return unpack_data_np(enc.data.reshape(-1, 4), bw_quads, enc.n)


def jax_args(enc: Encoded) -> dict:
    data = enc.data.reshape(-1, 4)
    data = np.concatenate([data, np.zeros((1, 4), np.uint32)])
    return {
        "control": jnp.asarray(enc.control.astype(np.int32)),
        "data": jnp.asarray(data),
        "n": enc.n,
        "q": enc.meta["Q"],
    }


SIZES_J = jnp.asarray(SIZES_Q)


def _bw_quads(control: jnp.ndarray, q: int) -> jnp.ndarray:
    sizes = SIZES_J[control & 3]
    bws = (control >> 2).astype(jnp.int32)
    return jnp.repeat(bws, sizes, total_repeat_length=max(q, 1))


@functools.partial(jax.jit, static_argnames=("n", "q"))
def decode_jax_vec(control, data, n: int, q: int):
    return unpack_data_jnp(data, _bw_quads(control, q), n)


@functools.partial(jax.jit, static_argnames=("n", "q"))
def decode_jax_scalar(control, data, n: int, q: int):
    return unpack_data_scalar_jnp(data, _bw_quads(control, q), n, q)


def decode_arena_block(ctrl, data, ctrl_len, data_len, n_valid):
    """Fixed-shape single-block decode for the device arena
    (``repro.index.device``): padded static shapes + dynamic lengths, so a
    work-list of (term, block) pairs decodes lane-parallel under ``vmap``.

    ctrl:  (ARENA_F,) int32 frame headers (2-bit size code | 6-bit bw); rows
           >= ``ctrl_len`` are arena slack and are masked out.
    data:  (4 * (W + 2),) flat uint32 words gathered from the data arena
           (trailing slack rows feed only bw=0 quads / masked reads).
    ctrl_len, data_len, n_valid: dynamic word / integer counts of this block.
    Returns (4 * ARENA_Q,) uint32 values, zero beyond ``n_valid``.
    """
    fmax = ctrl.shape[0]
    f_valid = jnp.arange(fmax, dtype=jnp.int32) < ctrl_len
    sizes = jnp.where(f_valid, SIZES_J[ctrl & 3], 0)
    bws = (ctrl >> 2).astype(jnp.int32)
    starts = jnp.cumsum(sizes) - sizes
    # per-quad frame id via boundary marks (the group_simple arena idiom):
    # frames are >= 8 quads so valid starts are strictly increasing
    marks = jnp.zeros(ARENA_Q, jnp.int32).at[
        jnp.where(f_valid, starts, ARENA_Q)].add(1, mode="drop")
    fid = jnp.clip(jnp.cumsum(marks) - 1, 0, fmax - 1)
    q = jnp.arange(ARENA_Q, dtype=jnp.int32)
    q_len = (n_valid + 3) >> 2
    bw_quads = jnp.where(q < q_len, bws[fid], 0)
    out = unpack_data_jnp(data.reshape(-1, 4), bw_quads, 4 * ARENA_Q)
    i = jnp.arange(4 * ARENA_Q, dtype=jnp.int32)
    return jnp.where(i < n_valid, out, 0)
