"""k-way vertical layout + quad-max (paper §3.1, §4.2, §4.4).

The paper distributes each quadruple of consecutive integers across the four
32-bit components of a 128-bit vector.  We keep the paper-faithful k=4 and a
TPU-native wide variant (k = lane count) — both are pure index transforms.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def pad_to_multiple(x: np.ndarray, m: int, fill=0) -> np.ndarray:
    r = (-len(x)) % m
    if r == 0:
        return np.asarray(x)
    return np.concatenate([x, np.full(r, fill, dtype=np.asarray(x).dtype)])


def to_vertical_np(x: np.ndarray, k: int = 4) -> np.ndarray:
    """n ints -> (n/k, k): row q holds the q-th group; column c is component c.

    Integer i lands at [i // k, i % k]: consecutive integers spread across
    components — exactly Fig. 1(b) of the paper.
    """
    x = pad_to_multiple(np.asarray(x, dtype=np.uint32), k)
    return x.reshape(-1, k)


def from_vertical_np(v: np.ndarray, n: int) -> np.ndarray:
    return np.asarray(v, dtype=np.uint32).reshape(-1)[:n]


def quadmax_np(x: np.ndarray, k: int = 4, pseudo: bool = True) -> np.ndarray:
    """Quad-max array (paper §4.2); pseudo=True uses the OR trick (§4.4).

    The pseudo quad-max may differ from the true max but has the same effective
    bit width, which is all the encoders need.
    """
    v = to_vertical_np(x, k)
    if pseudo:
        out = v[:, 0]
        for c in range(1, k):
            out = out | v[:, c]
        return out
    return v.max(axis=1)


def quadmax_jnp(x: jnp.ndarray, k: int = 4) -> jnp.ndarray:
    v = x.reshape(-1, k)
    out = v[:, 0]
    for c in range(1, k):
        out = out | v[:, c]
    return out
