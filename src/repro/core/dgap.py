"""d-gap (delta) transform for strictly/weakly increasing integer sequences.

Paper §2.1.1: postings are docid-sorted; d-gap replaces d_i with d_i - d_{i-1}
(first element kept raw).  Decoding is an inclusive prefix sum — on TPU this is
the ``kernels/scan_add`` hot spot; here are the host and pure-jnp versions.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def dgap_encode_np(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.uint32)
    out = x.copy()
    out[1:] = x[1:] - x[:-1]
    return out


def dgap_decode_np(g: np.ndarray) -> np.ndarray:
    return np.cumsum(np.asarray(g, dtype=np.uint64)).astype(np.uint32)


def dgap_decode_jnp(g: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(g.astype(jnp.uint32), dtype=jnp.uint32)
