"""Low-level bit utilities shared by every codec.

numpy side: vectorized bit-stream writer/reader used by the (offline) encoders.
jax side: effective-bit-width and masked shift helpers used by the decoders.

Bit order convention (everywhere in this repo): LSB-first within a 32-bit word,
words in increasing index order.  A value written at global bit offset ``o``
occupies bits ``o .. o+len-1`` of the stream, i.e. bits ``o%32 ..`` of word
``o//32`` upward.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# effective bit width
# --------------------------------------------------------------------------- #


def ebw_np(x: np.ndarray) -> np.ndarray:
    """Effective bit width: minimum bits to represent x in binary. ebw(0) = 0."""
    x = np.asarray(x, dtype=np.uint64)
    # log2(x+1) is exact at powers of two in float64, and x+1 <= 2**32 is exact.
    return np.ceil(np.log2(x.astype(np.float64) + 1.0)).astype(np.int32)


def ebw_jnp(x: jnp.ndarray) -> jnp.ndarray:
    """Effective bit width in JAX via count-leading-zeros. ebw(0) = 0."""
    x = x.astype(jnp.uint32)
    return (32 - jax.lax.clz(x)).astype(jnp.int32)


def mask_np(bw) -> np.ndarray:
    """All-ones mask of bw bits as uint32 (bw may be an array; bw=32 handled)."""
    bw = np.asarray(bw, dtype=np.uint64)
    return ((np.uint64(1) << bw) - np.uint64(1)).astype(np.uint32)


def mask_jnp(bw) -> jnp.ndarray:
    bw = jnp.asarray(bw, dtype=jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    return jnp.where(bw >= 32, full, (jnp.uint32(1) << bw) - jnp.uint32(1))


# --------------------------------------------------------------------------- #
# vectorized bit-stream writer (numpy, encode side)
# --------------------------------------------------------------------------- #


def pack_bits_np(values: np.ndarray, lengths: np.ndarray) -> tuple[np.ndarray, int]:
    """Concatenate variable-length codes into a uint32 word stream.

    values[i] (< 2**lengths[i], lengths[i] <= 64) is written at bit offset
    cumsum(lengths)[i-1].  Returns (words: uint32[ceil(total/32)], total_bits).
    The lo<<bit / hi>>(64-bit) pair covers any code spanning two u64 words,
    i.e. any length <= 64.
    """
    values = np.asarray(values, dtype=np.uint64)
    lengths = np.asarray(lengths, dtype=np.int64)
    if values.size == 0:
        return np.zeros(0, dtype=np.uint32), 0
    assert lengths.max(initial=0) <= 64, "pack_bits_np supports codes up to 64 bits"
    ends = np.cumsum(lengths)
    total = int(ends[-1])
    offs = ends - lengths
    nw64 = total // 64 + 2  # slack word for the hi-part scatter
    buf = np.zeros(nw64, dtype=np.uint64)
    word = (offs >> 6).astype(np.int64)
    bit = (offs & 63).astype(np.uint64)
    np.bitwise_or.at(buf, word, values << bit)
    hi = np.where(bit == 0, np.uint64(0), values >> (np.uint64(64) - bit))
    np.bitwise_or.at(buf, word + 1, hi)
    words = buf.view(np.uint32)  # little-endian host assumed (x86/ARM)
    return words[: (total + 31) // 32].copy(), total


def gather_bits_np(words: np.ndarray, offs: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Read lengths[i] (<= 32) bits at bit offset offs[i] from a uint32 stream."""
    words = np.asarray(words, dtype=np.uint32)
    offs = np.asarray(offs, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.uint64)
    w = np.concatenate([words, np.zeros(2, dtype=np.uint32)])
    word = offs >> 5
    bit = (offs & 31).astype(np.uint64)
    lo = w[word].astype(np.uint64)
    hi = w[word + 1].astype(np.uint64)
    v = ((lo | (hi << np.uint64(32))) >> bit)
    msk = np.where(lengths >= 64, ~np.uint64(0), (np.uint64(1) << lengths) - np.uint64(1))
    return (v & msk).astype(np.uint32)


# --------------------------------------------------------------------------- #
# vectorized bit gather (jax, decode side)
# --------------------------------------------------------------------------- #


def gather_bits_jnp(words: jnp.ndarray, offs: jnp.ndarray, bws: jnp.ndarray) -> jnp.ndarray:
    """JAX analogue of gather_bits_np: read bws[i] (<=32) bits at offs[i].

    words: uint32[W] (caller must pad with >=1 slack word), offs: int32, bws: int32.
    """
    word = (offs >> 5).astype(jnp.int32)
    bit = (offs & 31).astype(jnp.uint32)
    lo = words[word]
    hi = words[word + 1]
    # (lo | hi<<32) >> bit, in two 32-bit halves to stay in uint32 lanes (TPU
    # has no 64-bit lanes): lo>>bit | hi<<(32-bit), guarding the bit==0 case.
    lo_part = jnp.right_shift(lo, bit)
    hi_part = jnp.where(bit == 0, jnp.uint32(0), jnp.left_shift(hi, jnp.uint32(32) - bit))
    return (lo_part | hi_part) & mask_jnp(bws)


# --------------------------------------------------------------------------- #
# unary helpers (Rice / Gamma / unary length descriptors)
# --------------------------------------------------------------------------- #


def unary_stream_np(counts: np.ndarray) -> tuple[np.ndarray, int]:
    """Encode counts[i] >= 1 as (counts[i]-1) one-bits + one zero-bit, LSB-first.

    Returns (words uint32, total_bits).  Vectorized: the stream is all-ones with
    zeros at positions cumsum(counts)-1.
    """
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.uint32), 0
    nw = (total + 31) // 32
    bits = np.ones(nw * 32, dtype=np.uint8)
    zpos = np.cumsum(counts) - 1
    bits[zpos] = 0
    bits[total:] = 0  # pad with zeros past the end
    words = np.packbits(bits.reshape(-1, 32)[:, ::-1], axis=1, bitorder="big")
    words = words[:, ::-1].copy().view(np.uint32).reshape(-1)
    return words, total


def unary_decode_np(words: np.ndarray, total_bits: int, n: int) -> np.ndarray:
    """Decode the first n unary counts from a stream produced by unary_stream_np."""
    words = np.asarray(words, dtype=np.uint32)
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")[:total_bits]
    zpos = np.flatnonzero(bits == 0)[:n]
    prev = np.concatenate([[-1], zpos[:-1]])
    return (zpos - prev).astype(np.int64)


def bits_to_words_np(bits: np.ndarray) -> np.ndarray:
    """uint8 bit array (LSB-first stream order) -> uint32 words."""
    pad = (-len(bits)) % 32
    if pad:
        bits = np.concatenate([bits, np.zeros(pad, dtype=np.uint8)])
    by = np.packbits(bits, bitorder="little")
    padb = (-len(by)) % 4
    if padb:
        by = np.concatenate([by, np.zeros(padb, dtype=np.uint8)])
    return by.view(np.uint32)


def words_to_bits_np(words: np.ndarray, total_bits: int) -> np.ndarray:
    return np.unpackbits(np.asarray(words, np.uint32).view(np.uint8), bitorder="little")[:total_bits]
