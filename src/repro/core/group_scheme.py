"""Group-Scheme family (paper §5): CG x LD generalization of Elias Gamma / GVB.

A variant is "CG-LD" with compression granularity CG in {1,2,4,8} bits and
length descriptor LD in {B (binary), CU (complete unary), IU (incomplete
unary, CG in {4,8} only)}.  "1-CU" is k-Gamma (k=4).

Per quadruple q: nunits[q] = max(1, ceil(ebw(quadmax[q]) / CG)); the four
integers are packed with bw = nunits*CG bits each into the four vertical
component bitstreams of the data area (values may cross word boundaries —
Fig. 4).  The control area stores the length descriptors:

  * B  — nunits-1 in a fixed-width field, alignment per Fig. 5:
         CG=1: 3 x 5-bit fields per 16 bits; CG=2: 2 x 4-bit per byte;
         CG=4: 2 x 3-bit per byte; CG=8: 4 x 2-bit per byte.
  * CU — unary (nunits-1 ones + a zero), continuous across bytes.
  * IU — unary, never crossing a byte; a byte's trailing ones are padding.

Decoders: numpy oracle, JAX scalar (sequential scan, TZCNT-style unary reads —
paper §5.4), JAX vectorized (packed LD decode via zero-position arithmetic /
256-entry lookup tables — paper §5.3.1 — then one gather-shift-mask for all
quadruples at once — §5.3.2).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np, gather_bits_jnp, mask_jnp, mask_np, pack_bits_np, unary_stream_np, words_to_bits_np
from .encoded import Encoded
from .layout import to_vertical_np, quadmax_np

CGS = (1, 2, 4, 8)
# binary-LD layout per CG: (quads per group, field bits, group bits)
B_LAYOUT = {1: (3, 5, 16), 2: (2, 4, 8), 4: (2, 3, 8), 8: (4, 2, 8)}
VARIANTS = tuple(f"{cg}-B" for cg in CGS) + tuple(f"{cg}-CU" for cg in CGS) + ("4-IU", "8-IU")


def _split(variant: str) -> tuple[int, str]:
    cg, ld = variant.split("-")
    return int(cg), ld


# --------------------------------------------------------------------------- #
# incomplete-unary lookup tables (paper §5.3.1): decode a whole control byte
# --------------------------------------------------------------------------- #


def _build_iu_tables() -> tuple[np.ndarray, np.ndarray]:
    count = np.zeros(256, np.int32)
    lds = np.zeros((256, 8), np.int32)
    for b in range(256):
        k, pos = 0, 0
        run = 0
        while pos < 8:
            if (b >> pos) & 1:
                run += 1
            else:
                lds[b, k] = run + 1
                k += 1
                run = 0
            pos += 1
        count[b] = k  # trailing ones (run > 0 at exit) are padding
    return count, lds


IU_COUNT_NP, IU_LDS_NP = _build_iu_tables()
IU_COUNT_J = jnp.asarray(IU_COUNT_NP)
IU_LDS_J = jnp.asarray(IU_LDS_NP)


# --------------------------------------------------------------------------- #
# encoding (host / numpy)
# --------------------------------------------------------------------------- #


def _nunits(x: np.ndarray, cg: int) -> np.ndarray:
    qm = quadmax_np(x, 4, pseudo=True)
    e = ebw_np(qm)
    return np.maximum(1, -(-e // cg)).astype(np.int64)


def _encode_control(nunits: np.ndarray, cg: int, ld: str) -> tuple[np.ndarray, int, dict]:
    if ld == "B":
        gsz, fb, gb = B_LAYOUT[cg]
        q = len(nunits)
        pad = (-q) % gsz
        f = np.concatenate([nunits - 1, np.zeros(pad, np.int64)]).reshape(-1, gsz)
        group_vals = np.zeros(len(f), np.uint64)
        for i in range(gsz):
            group_vals |= f[:, i].astype(np.uint64) << np.uint64(i * fb)
        words, bits = pack_bits_np(group_vals, np.full(len(f), gb, np.int64))
        return words, bits, {}
    if ld == "CU":
        words, bits = unary_stream_np(nunits)
        return words, bits, {}
    # IU: greedy byte fill, codes never cross bytes
    out_bytes = []
    cur, used = 0, 0
    for u in nunits:
        u = int(u)
        if used + u > 8:
            cur |= ((1 << (8 - used)) - 1) << used  # pad remainder with ones
            out_bytes.append(cur)
            cur, used = 0, 0
        cur |= ((1 << (u - 1)) - 1) << used          # u-1 ones then an implicit 0
        used += u
        if used == 8:
            out_bytes.append(cur)
            cur, used = 0, 0
    if used:
        cur |= ((1 << (8 - used)) - 1) << used
        out_bytes.append(cur)
    by = np.asarray(out_bytes, dtype=np.uint8)
    padb = (-len(by)) % 4
    words = np.concatenate([by, np.zeros(padb, np.uint8)]).view(np.uint32)
    return words, len(by) * 8, {"n_control_bytes": len(by)}


def encode(x: np.ndarray, variant: str) -> Encoded:
    cg, ld = _split(variant)
    assert variant in VARIANTS, variant
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    name = f"group_scheme_{variant}"
    if n == 0:
        return Encoded(name, 0, np.zeros(0, np.uint32), np.zeros(0, np.uint32),
                       header_bits=32, meta={"variant": variant, "Q": 0})
    v = to_vertical_np(x, 4)                       # (Q, 4)
    nunits = _nunits(x, cg)                        # (Q,)
    bw = (nunits * cg).astype(np.int64)
    control, cbits, cmeta = _encode_control(nunits, cg, ld)
    msk = mask_np(bw).astype(np.uint64)
    cols = []
    for c in range(4):
        w, dbits = pack_bits_np(v[:, c].astype(np.uint64) & msk, bw)
        cols.append(w)
    data = np.stack(cols, axis=1)                  # (W, 4)
    meta = {"variant": variant, "Q": len(nunits), "nunits": nunits, **cmeta}
    return Encoded(name, n, control, data.reshape(-1),
                   control_bits=cbits, data_bits=int(bw.sum()) * 4,
                   header_bits=32, meta=meta)


# --------------------------------------------------------------------------- #
# numpy oracle decode
# --------------------------------------------------------------------------- #


def _decode_control_np(enc: Encoded) -> np.ndarray:
    cg, ld = _split(enc.meta["variant"])
    q = enc.meta["Q"]
    control = enc.control
    if ld == "B":
        gsz, fb, gb = B_LAYOUT[cg]
        idx = np.arange(q)
        offs = (idx // gsz) * gb + (idx % gsz) * fb
        from .bits import gather_bits_np
        return gather_bits_np(control, offs, np.full(q, fb)) + 1
    if ld == "CU":
        bits = words_to_bits_np(control, enc.control_bits)
        zpos = np.flatnonzero(bits == 0)[:q]
        prev = np.concatenate([[-1], zpos[:-1]])
        return (zpos - prev).astype(np.int64)
    by = control.view(np.uint8)[: enc.meta["n_control_bytes"]]
    counts = IU_COUNT_NP[by]
    lds = IU_LDS_NP[by]
    out = np.zeros(q, np.int64)
    base = np.cumsum(counts) - counts
    for s in range(8):
        sel = s < counts
        tgt = base[sel] + s
        keep = tgt < q
        out[tgt[keep]] = lds[sel, s][keep]
    return out


def decode_np(enc: Encoded) -> np.ndarray:
    cg, _ = _split(enc.meta["variant"])
    q = enc.meta["Q"]
    if q == 0:
        return np.zeros(0, np.uint32)
    nunits = _decode_control_np(enc)
    bw = nunits * cg
    ends = np.cumsum(bw)
    offs = ends - bw
    data = enc.data.reshape(-1, 4)
    from .bits import gather_bits_np
    out = np.stack([gather_bits_np(data[:, c], offs, bw) for c in range(4)], axis=1)
    return out.reshape(-1)[: enc.n]


# --------------------------------------------------------------------------- #
# JAX decoders
# --------------------------------------------------------------------------- #


def jax_args(enc: Encoded) -> dict:
    data = enc.data.reshape(-1, 4)
    data = np.concatenate([data, np.zeros((1, 4), np.uint32)])   # slack row for hi gather
    control = np.concatenate([enc.control, np.zeros(2, np.uint32)])
    return {
        "control": jnp.asarray(control),
        "data": jnp.asarray(data),
        "n": enc.n,
        "q": enc.meta["Q"],
        "variant": enc.meta["variant"],
        "n_control_bytes": enc.meta.get("n_control_bytes", 0),
    }


def _control_bits_jnp(control: jnp.ndarray) -> jnp.ndarray:
    """uint32 words -> flat bit array (LSB-first)."""
    sh = jnp.arange(32, dtype=jnp.uint32)
    return ((control[:, None] >> sh[None, :]) & jnp.uint32(1)).reshape(-1)


def _decode_nunits_vec(control: jnp.ndarray, q: int, variant: str, n_control_bytes: int) -> jnp.ndarray:
    cg, ld = _split(variant)
    if ld == "B":
        gsz, fb, gb = B_LAYOUT[cg]
        idx = jnp.arange(q, dtype=jnp.int32)
        offs = (idx // gsz) * gb + (idx % gsz) * fb
        return gather_bits_jnp(control, offs, jnp.full(q, fb, jnp.int32)).astype(jnp.int32) + 1
    if ld == "CU":
        bits = _control_bits_jnp(control)
        zcum = jnp.cumsum(jnp.uint32(1) - bits)                 # rank of zeros
        # position of the q-th zero via scatter (searchsorted is ~4x slower
        # on CPU and scatter is equally lane-parallel on TPU — §Perf)
        j = jnp.arange(bits.shape[0], dtype=jnp.int32)
        idx = jnp.where(bits == 0, (zcum - 1).astype(jnp.int32), q)
        zpos = jnp.zeros(q, jnp.int32).at[idx].set(j, mode="drop", unique_indices=True)
        prev = jnp.concatenate([jnp.full(1, -1, jnp.int32), zpos[:-1]])
        return zpos - prev
    # IU: packed decode via the 256-entry LUT (paper §5.3.1)
    by = (control.view(jnp.uint8) if control.dtype == jnp.uint32 else control)
    by = by[:n_control_bytes].astype(jnp.int32)
    counts = IU_COUNT_J[by]                                     # (B,)
    lds = IU_LDS_J[by]                                          # (B, 8)
    base = jnp.cumsum(counts) - counts
    idx = base[:, None] + jnp.arange(8, dtype=jnp.int32)[None, :]
    slot_ok = jnp.arange(8, dtype=jnp.int32)[None, :] < counts[:, None]
    idx = jnp.where(slot_ok, idx, q)
    return jnp.zeros(q, jnp.int32).at[idx.reshape(-1)].set(lds.reshape(-1), mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "q", "variant", "n_control_bytes"))
def decode_jax_vec(control, data, n: int, q: int, variant: str, n_control_bytes: int = 0):
    """SIMD-Group-Scheme decode: packed LD decode + one vectorized unpack."""
    cg, _ = _split(variant)
    nunits = _decode_nunits_vec(control, q, variant, n_control_bytes)
    bw = (nunits * cg).astype(jnp.uint32)
    ends = jnp.cumsum(bw)
    offs = (ends - bw).astype(jnp.int32)
    word = (offs >> 5)
    bit = (offs & 31).astype(jnp.uint32)[:, None]
    lo = data[word]                                             # (Q, 4)
    hi = data[word + 1]
    val = jnp.right_shift(lo, bit) | jnp.where(
        bit == 0, jnp.uint32(0), jnp.left_shift(hi, jnp.uint32(32) - bit))
    val = val & mask_jnp(bw)[:, None]
    return val.reshape(-1)[:n]


# --------------------------------------------------------------------------- #
# fixed-shape arena decode (device work-lists)
# --------------------------------------------------------------------------- #


def arena_ctrl_width(variant: str, qmax: int = 128) -> int:
    """Padded control words (B/CU) or control bytes (IU) for a ``qmax``-quad
    block, including gather slack — the ``ctrl_width`` of this variant's
    declared :class:`repro.core.codec.ArenaLayout`."""
    cg, ld = _split(variant)
    if ld == "B":
        gsz, _, gb = B_LAYOUT[cg]
        return -(-(-(-qmax // gsz) * gb) // 32) + 2
    if ld == "CU":
        return -(-qmax * (-(-32 // cg)) // 32) + 1
    return qmax                     # IU: one entry per byte, <= 1 byte per quad


def arena_block_ctrl(enc: Encoded) -> np.ndarray:
    """One encoded block's control stream in arena form: packed uint32 words
    for B/CU, one byte per uint32 entry for IU (byte-addressed LUT decode)."""
    _, ld = _split(enc.meta["variant"])
    if ld == "IU":
        by = enc.control.view(np.uint8)[: enc.meta["n_control_bytes"]]
        return by.astype(np.uint32)
    return np.asarray(enc.control, np.uint32)


def _arena_nunits(control: jnp.ndarray, ctrl_len: jnp.ndarray, qmax: int,
                  cg: int, ld: str) -> jnp.ndarray:
    """Per-quad unit counts from a padded control slice.  Slack past the
    block's own control words may hold the *next* block's stream; every lane
    it could pollute sits at quad index >= the block's own quad count and is
    masked by the bw=0 clamp in ``decode_arena_block``."""
    if ld == "B":
        gsz, fb, gb = B_LAYOUT[cg]
        idx = jnp.arange(qmax, dtype=jnp.int32)
        offs = (idx // gsz) * gb + (idx % gsz) * fb
        return gather_bits_jnp(control, offs,
                               jnp.full(qmax, fb, jnp.int32)).astype(jnp.int32) + 1
    if ld == "CU":
        bits = _control_bits_jnp(control)
        zcum = jnp.cumsum(jnp.uint32(1) - bits)
        j = jnp.arange(bits.shape[0], dtype=jnp.int32)
        # the block's own stream contains its quads' zeros first, so slots
        # below the block's quad count are written only by genuine zeros
        # no unique_indices promise: every bits==1 lane shares the qmax
        # sentinel (dropped), and duplicate sentinels are undefined behavior
        # under that flag on compiled backends
        idx = jnp.where(bits == 0, (zcum - 1).astype(jnp.int32), qmax)
        zpos = jnp.zeros(qmax, jnp.int32).at[idx].set(j, mode="drop")
        prev = jnp.concatenate([jnp.full(1, -1, jnp.int32), zpos[:-1]])
        return zpos - prev
    # IU: byte-at-a-time LUT decode; ctrl_len masks slack bytes entirely
    by = control.astype(jnp.int32)
    counts = jnp.where(jnp.arange(by.shape[0]) < ctrl_len, IU_COUNT_J[by], 0)
    lds = IU_LDS_J[by]
    base = jnp.cumsum(counts) - counts
    idx = base[:, None] + jnp.arange(8, dtype=jnp.int32)[None, :]
    slot_ok = jnp.arange(8, dtype=jnp.int32)[None, :] < counts[:, None]
    idx = jnp.where(slot_ok, idx, qmax)
    return jnp.zeros(qmax, jnp.int32).at[idx.reshape(-1)].set(
        lds.reshape(-1), mode="drop")


def decode_arena_block(control: jnp.ndarray, data: jnp.ndarray,
                       ctrl_len: jnp.ndarray, n_valid: jnp.ndarray,
                       *, variant: str) -> jnp.ndarray:
    """Fixed-shape single-block decode for the device arena
    (``repro.index.device``): the ``decode_jax_vec`` formulation with padded
    static shapes and dynamic lengths, so a work-list of (term, block) pairs
    decodes lane-parallel under one ``vmap``/``jit``.

    control: (ctrl_width,) uint32 slice of the control arena (see
             ``arena_block_ctrl`` for the per-LD layout).
    data:    (4 * (qmax + 2),) uint32 gathered from the data arena; reshaped
             to (qmax + 2, 4) component words with 2 rows of gather slack.
    ctrl_len: dynamic control length (bytes for IU, words otherwise).
    n_valid:  dynamic integer count of this block.
    Returns (4 * qmax,) uint32 values, zero beyond ``n_valid``.
    """
    cg, ld = _split(variant)
    dataw = data.reshape(-1, 4)
    qmax = dataw.shape[0] - 2
    q = jnp.arange(qmax, dtype=jnp.int32)
    q_len = (n_valid + 3) >> 2
    nunits = _arena_nunits(control, ctrl_len, qmax, cg, ld)
    # quads past the block consume 0 data bits, so valid quads' offsets are
    # unaffected by whatever the slack lanes decoded
    bw = jnp.where(q < q_len, nunits * cg, 0).astype(jnp.uint32)
    ends = jnp.cumsum(bw)
    offs = (ends - bw).astype(jnp.int32)
    word = offs >> 5
    bit = (offs & 31).astype(jnp.uint32)[:, None]
    lo = dataw[word]
    hi = dataw[word + 1]
    val = jnp.right_shift(lo, bit) | jnp.where(
        bit == 0, jnp.uint32(0), jnp.left_shift(hi, jnp.uint32(32) - bit))
    val = val & mask_jnp(bw)[:, None]
    out = val.reshape(-1)
    i = jnp.arange(4 * qmax, dtype=jnp.int32)
    return jnp.where(i < n_valid, out, 0)


@functools.partial(jax.jit, static_argnames=("n", "q", "variant", "n_control_bytes"))
def decode_jax_scalar(control, data, n: int, q: int, variant: str, n_control_bytes: int = 0):
    """Paper-faithful scalar decode: one quadruple per scan step.

    Unary LDs are read with the TZCNT-style bit trick (paper §5.4): the number
    of units is 1 + the index of the lowest zero bit of a 32-bit window.
    """
    cg, ld = _split(variant)

    def read_window(pos):
        w = pos >> 5
        b = (pos & 31).astype(jnp.uint32)
        lo = jnp.right_shift(control[w], b)
        hi = jnp.where(b == 0, jnp.uint32(0), jnp.left_shift(control[w + 1], jnp.uint32(32) - b))
        return lo | hi

    def lowest_zero(x):  # index of lowest 0-bit of x (must exist)
        y = ~x
        return (jnp.uint32(31) - jax.lax.clz(y & (~y + jnp.uint32(1)))).astype(jnp.int32)

    if ld == "B":
        gsz, fb, gb = B_LAYOUT[cg]

        def read_ld(qidx, ldpos):
            off = (qidx // gsz) * gb + (qidx % gsz) * fb
            f = read_window(off) & mask_jnp(jnp.uint32(fb))
            return f.astype(jnp.int32) + 1, ldpos
    elif ld == "CU":

        def read_ld(qidx, ldpos):
            u = lowest_zero(read_window(ldpos)) + 1
            return u, ldpos + u
    else:  # IU

        def read_ld(qidx, ldpos):
            rem = (jnp.int32(8) - (ldpos & 7)).astype(jnp.uint32)
            win = read_window(ldpos) & mask_jnp(rem)
            is_pad = win == mask_jnp(rem)                        # all ones -> padding
            ldpos = jnp.where(is_pad, (ldpos >> 3) * 8 + 8, ldpos)
            u = lowest_zero(read_window(ldpos)) + 1
            return u, ldpos + u

    def step(carry, qidx):
        datapos, ldpos = carry
        u, ldpos = read_ld(qidx, ldpos)
        bw = (u * cg).astype(jnp.uint32)
        w = datapos >> 5
        b = (datapos & 31).astype(jnp.uint32)
        lo = data[w]
        hi = jnp.where(b == 0, jnp.zeros(4, jnp.uint32), jnp.left_shift(data[w + 1], jnp.uint32(32) - b))
        vals = (jnp.right_shift(lo, b) | hi) & mask_jnp(bw)
        return (datapos + bw.astype(jnp.int32), ldpos), vals

    (_, _), vals = jax.lax.scan(step, (jnp.int32(0), jnp.int32(0)), jnp.arange(q, dtype=jnp.int32))
    return vals.reshape(-1)[:n]
