"""Uniform codec registry (Table VI of the paper).

Each entry exposes:
  encode(np.uint32[N]) -> Encoded
  decode(Encoded) -> np.uint32[N]          (numpy oracle)
and, for the Group family, JAX decoders:
  jax_args(Encoded) -> kwargs
  decode_jax_scalar(**kwargs), decode_jax_vec(**kwargs)
where "scalar" mirrors the paper's sequential non-SIMD routine and "vec" the
SIMD-vectorized one.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import numpy as np

from . import bp128, group_afor, group_pfd, group_scheme, group_simple, scalar
from .encoded import Encoded


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    name: str
    category: str                  # bit | byte | word | frame
    encode: Callable[[np.ndarray], Encoded]
    decode: Callable[[Encoded], np.ndarray]
    jax_args: Optional[Callable] = None
    decode_jax_scalar: Optional[Callable] = None
    decode_jax_vec: Optional[Callable] = None
    max_bits: int = 32             # values above 2**max_bits-1 unsupported
    is_group: bool = False         # uses the paper's Group approach


REGISTRY: dict[str, CodecSpec] = {}


def _reg(spec: CodecSpec) -> None:
    REGISTRY[spec.name] = spec


# ---- scalar baselines ------------------------------------------------------ #
_reg(CodecSpec("varbyte", "byte", scalar.vb_encode, scalar.vb_decode))
from . import stream_vbyte  # noqa: E402
_reg(CodecSpec("stream_vbyte", "byte", stream_vbyte.encode, stream_vbyte.decode_np,
               stream_vbyte.jax_args, stream_vbyte.decode_jax_scalar,
               stream_vbyte.decode_jax_vec))
_reg(CodecSpec("gvb", "byte", scalar.gvb_encode, scalar.gvb_decode))
_reg(CodecSpec("g8iu", "byte", scalar.g8iu_encode, scalar.g8iu_decode))
_reg(CodecSpec("g8cu", "byte", scalar.g8cu_encode, scalar.g8cu_decode))
_reg(CodecSpec("simple9", "word", scalar.simple9_encode, scalar.simple9_decode, max_bits=28))
_reg(CodecSpec("simple16", "word", scalar.simple16_encode, scalar.simple16_decode, max_bits=28))
_reg(CodecSpec("rice", "bit", scalar.rice_encode, scalar.rice_decode))
_reg(CodecSpec("gamma", "bit", scalar.gamma_encode, scalar.gamma_decode, max_bits=31))
_reg(CodecSpec("pfordelta", "frame", scalar.pfd_encode, scalar.pfd_decode))
_reg(CodecSpec("afor", "frame", scalar.afor_encode, scalar.afor_decode))
_reg(CodecSpec("packed_binary", "frame", scalar.packedbinary_encode, scalar.packedbinary_decode))

# ---- Group family (this paper) --------------------------------------------- #
_reg(CodecSpec("group_simple", "word", group_simple.encode, group_simple.decode_np,
               group_simple.jax_args, group_simple.decode_jax_scalar,
               group_simple.decode_jax_vec, is_group=True))

for v in group_scheme.VARIANTS:
    _reg(CodecSpec(
        f"group_scheme_{v}", "bit" if int(v.split("-")[0]) < 8 else "byte",
        functools.partial(group_scheme.encode, variant=v), group_scheme.decode_np,
        group_scheme.jax_args, group_scheme.decode_jax_scalar,
        group_scheme.decode_jax_vec, is_group=True))

_reg(CodecSpec("group_afor", "frame", group_afor.encode, group_afor.decode_np,
               group_afor.jax_args, group_afor.decode_jax_scalar,
               group_afor.decode_jax_vec, is_group=True))

from . import group_vse  # noqa: E402
_reg(CodecSpec("group_vse", "frame", group_vse.encode, group_vse.decode_np,
               group_vse.jax_args, group_vse.decode_jax_scalar,
               group_vse.decode_jax_vec, is_group=True))
_reg(CodecSpec("group_pfd", "frame", group_pfd.encode, group_pfd.decode_np,
               group_pfd.jax_args, group_pfd.decode_jax_scalar,
               group_pfd.decode_jax_vec, is_group=True))
_reg(CodecSpec("group_optpfd", "frame", functools.partial(group_pfd.encode, opt=True),
               group_pfd.decode_np, group_pfd.jax_args, group_pfd.decode_jax_scalar,
               group_pfd.decode_jax_vec, is_group=True))
_reg(CodecSpec("bp128", "frame", bp128.encode, bp128.decode_np,
               bp128.jax_args, bp128.decode_jax_scalar, bp128.decode_jax_vec, is_group=True))

from . import bp_tpu  # noqa: E402  (imports kernels; kept after core codecs)
_reg(CodecSpec("bp_tpu", "frame", bp_tpu.encode, bp_tpu.decode_np, is_group=True))
_reg(CodecSpec("g_packed_binary", "frame", bp128.encode_packed_binary, bp128.decode_np,
               bp128.jax_args, bp128.decode_jax_scalar, bp128.decode_jax_vec, is_group=True))


def get(name: str) -> CodecSpec:
    return REGISTRY[name]


def names(category: str | None = None, group_only: bool = False) -> list[str]:
    out = []
    for k, s in REGISTRY.items():
        if category and s.category != category:
            continue
        if group_only and not s.is_group:
            continue
        out.append(k)
    return out
