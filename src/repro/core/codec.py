"""Codec protocol v2: capability-declaring codecs (Table VI of the paper).

The paper's claim is that the Group layout is *general* — every compression
algorithm instantiates the same group-unpack skeleton — so the registry is the
single place where a codec says what it can do and every consumer (index,
engine, device arenas, benchmarks, tests, CI lint) discovers it from there
instead of special-casing codec names.

A :class:`Codec` always provides the host surface:

  encode(np.uint32[N]) -> Encoded
  decode_np(Encoded)   -> np.uint32[N]          (numpy oracle)

and *declares* optional capabilities:

  * :class:`JaxDecode` — device decode entry points: ``args(Encoded)`` packs
    the jit kwargs, ``scalar(**kw)`` mirrors the paper's sequential routine,
    ``vec(**kw)`` the SIMD-vectorized one (Table VII rows).
  * :class:`ArenaLayout` — the fixed-shape device-arena contract consumed by
    ``repro.index.device``: N named padded columns (:class:`ArenaColumn` —
    ctrl / data / exceptions / …) for one posting block plus a
    ``decode_block(*column_slices, *column_lens, n_valid)`` entry that
    decodes under ``vmap``/``jit`` with static shapes.  Any codec declaring
    this gets the lane-parallel batched work-list decode for free — the arena
    builder contains no per-codec or per-column-count branches.  The
    2-column (ctrl, data) form every pre-exception codec uses is the
    :meth:`ArenaLayout.two_column` alias, so those codecs register
    unchanged; exception-bearing codecs (the Group-PFD family) declare a
    third ``exceptions`` column and patch inside ``decode_block``.

The v1 ``CodecSpec`` attribute surface (``decode``, ``jax_args``,
``decode_jax_scalar``, ``decode_jax_vec``) is kept as read-only aliases so
existing callers migrate at their own pace; ``CodecSpec`` itself now names
this class.
"""

from __future__ import annotations

import dataclasses
import difflib
import functools
from typing import Any, Callable, Optional

import numpy as np

from . import bp128, group_afor, group_pfd, group_scheme, group_simple, scalar
from . import bp_tpu, dense_bitmap, group_vse, stream_vbyte
from .encoded import Encoded

# One posting block of the inverted index is at most this many integers; all
# declared arena widths are padded maxima for a block of this size.
ARENA_BLOCK = 512


# --------------------------------------------------------------------------- #
# capability declarations
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class JaxDecode:
    """Device decode capability: jit argument packing + scalar/vec entries."""

    args: Callable[[Encoded], dict]
    scalar: Callable[..., Any]
    vec: Callable[..., Any]


def _block_ctrl_default(enc: Encoded) -> np.ndarray:
    return np.asarray(enc.control).reshape(-1)


def _block_data_default(enc: Encoded) -> np.ndarray:
    return np.asarray(enc.data, np.uint32).reshape(-1)


def _supports_default(enc: Encoded) -> bool:
    return True


@dataclasses.dataclass(frozen=True)
class ArenaColumn:
    """One named padded stream of an :class:`ArenaLayout`.

    name: column role — ``"ctrl"``, ``"data"``, ``"exceptions"``, … (the
        registry lint keys the exception-consistency check off this name).
    width: padded per-block maximum (flat words) — slack past a block's own
        words may contain the *next* block's words, so ``decode_block`` must
        mask everything past the column's dynamic length.
    extract(enc): pull one encoded block's words for this column (host side,
        at arena build time).
    dtype: the arena array dtype this column is stored as.
    """

    name: str
    width: int
    extract: Callable[[Encoded], np.ndarray] = _block_data_default
    dtype: Any = np.uint32


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Fixed-shape device-arena contract for one posting block.

    The device arena concatenates, per declared column, every block's words
    into one contiguous device array of that column's dtype, then decodes a
    work-list lane-parallel: each lane gathers one padded ``(width,)`` slice
    per column (``dynamic_slice`` under ``vmap``) and calls ``decode_block``.

    columns: the declared :class:`ArenaColumn` streams, in ``decode_block``
        argument order.  Pre-exception codecs declare (ctrl, data); the
        Group-PFD family adds an ``exceptions`` column for its patch stream.
    out_width: static length of ``decode_block``'s result (zero-padded past
        ``n_valid``).
    decode_block(*column_slices, *column_lens, n_valid) -> uint32[out_width]:
        jit/vmap traceable, static shapes, dynamic per-column word counts.
    supports(enc): per-block eligibility — a block whose encoding does not
        match this fixed layout (e.g. a BP frame size other than the one the
        layout was declared for) falls back to the host oracle instead of
        decoding silently wrong.
    max_n: largest block the widths are sized for (the index block size).
    """

    columns: tuple
    out_width: int
    decode_block: Callable[..., Any]
    supports: Callable[[Encoded], bool] = _supports_default
    max_n: int = ARENA_BLOCK
    # bitmap-block capability: a layout whose blocks may be raw docid bitmaps
    # declares the window size (words) and a per-block predicate; the arena
    # then also stages those blocks globally aligned for the word-parallel
    # intersect/score rounds.  Zero engine branches: consumers only ever ask
    # the arena's staging tables.
    bitmap_words: int = 0
    is_bitmap: Optional[Callable[[Encoded], bool]] = None

    @classmethod
    def two_column(cls, ctrl_width: int, data_width: int, out_width: int,
                   decode_block: Callable[..., Any],
                   block_ctrl: Callable[[Encoded], np.ndarray] = _block_ctrl_default,
                   block_data: Callable[[Encoded], np.ndarray] = _block_data_default,
                   supports: Callable[[Encoded], bool] = _supports_default,
                   ctrl_dtype: Any = np.int32,
                   max_n: int = ARENA_BLOCK) -> "ArenaLayout":
        """Thin alias for the original (ctrl, data) form: ``decode_block``
        keeps its v2 ``(ctrl, data, ctrl_len, n_valid)`` signature and the
        codec registers unchanged."""
        return cls(
            columns=(ArenaColumn("ctrl", ctrl_width, block_ctrl, ctrl_dtype),
                     ArenaColumn("data", data_width, block_data, np.uint32)),
            out_width=out_width,
            decode_block=_adapt_two_column(decode_block),
            supports=supports, max_n=max_n)

    # ---- 2-column aliases (the pre-column attribute surface) --------------- #

    @property
    def ctrl_width(self) -> int:
        return self.columns[0].width

    @property
    def data_width(self) -> int:
        return self.columns[1].width

    @property
    def ctrl_dtype(self) -> Any:
        return self.columns[0].dtype

    @property
    def block_ctrl(self) -> Callable[[Encoded], np.ndarray]:
        return self.columns[0].extract

    @property
    def block_data(self) -> Callable[[Encoded], np.ndarray]:
        return self.columns[1].extract


def _adapt_two_column(fn: Callable[..., Any]) -> Callable[..., Any]:
    """Bind a legacy ``(ctrl, data, ctrl_len, n_valid)`` decoder to the
    generic N-column ``(*slices, *lens, n_valid)`` contract (the data
    column's dynamic length was never consumed by the 2-column codecs).
    Created once per layout at registration, so its identity is stable for
    the arena's jit cache."""

    def decode(ctrl, data, ctrl_len, data_len, n_valid):
        return fn(ctrl, data, ctrl_len, n_valid)

    return decode


# --------------------------------------------------------------------------- #
# the Codec protocol
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Codec:
    """A registered codec: required host surface + declared capabilities."""

    name: str
    category: str                  # bit | byte | word | frame
    encode: Callable[[np.ndarray], Encoded]
    decode_np: Callable[[Encoded], np.ndarray]
    max_bits: int = 32             # values above 2**max_bits-1 unsupported
    is_group: bool = False         # uses the paper's Group approach
    jax: Optional[JaxDecode] = None
    arena: Optional[ArenaLayout] = None

    # ---- v1 CodecSpec aliases (deprecated; see the migration note in
    # src/repro/index/__init__.py) ------------------------------------------ #

    @property
    def decode(self) -> Callable[[Encoded], np.ndarray]:
        return self.decode_np

    @property
    def jax_args(self) -> Optional[Callable[[Encoded], dict]]:
        return self.jax.args if self.jax else None

    @property
    def decode_jax_scalar(self) -> Optional[Callable[..., Any]]:
        return self.jax.scalar if self.jax else None

    @property
    def decode_jax_vec(self) -> Optional[Callable[..., Any]]:
        return self.jax.vec if self.jax else None


CodecSpec = Codec  # v1 name


REGISTRY: dict[str, Codec] = {}


def register(spec: Codec) -> Codec:
    REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> Codec:
    try:
        return REGISTRY[name]
    except KeyError:
        known = names()
        near = difflib.get_close_matches(str(name), known, n=1)
        hint = f" (did you mean {near[0]!r}?)" if near else ""
        raise KeyError(
            f"unknown codec {name!r}{hint}; registered codecs: {', '.join(known)}"
        ) from None


def names(category: str | None = None, group_only: bool = False) -> list[str]:
    """Registered codec names, deterministically sorted."""
    return sorted(
        k for k, s in REGISTRY.items()
        if (category is None or s.category == category)
        and (not group_only or s.is_group)
    )


# --------------------------------------------------------------------------- #
# arena adapters: thin shims binding each codec module's fixed-shape decoder
# to the uniform (ctrl, data, ctrl_len, n_valid) contract.  Defined at module
# level (or as one-time partials below) so their identity is stable — the
# arena jits with decode_block as a static argument.
# --------------------------------------------------------------------------- #

_GS_PMAX = ARENA_BLOCK // 4            # max Group-Simple vectors per block


def _gs_block_ctrl(enc: Encoded) -> np.ndarray:
    return np.asarray(enc.meta["sels"], np.int32)


def _gs_decode_block(ctrl, data, ctrl_len, n_valid):
    return group_simple.decode_arena_block(ctrl, data.reshape(-1, 4),
                                           ctrl_len, n_valid)


_GS_ARENA = ArenaLayout.two_column(
    ctrl_width=_GS_PMAX, data_width=4 * _GS_PMAX, out_width=ARENA_BLOCK,
    decode_block=_gs_decode_block, block_ctrl=_gs_block_ctrl)

_BP_WMAX = ARENA_BLOCK // 4            # max data words per component per block


def _bp_block_ctrl(enc: Encoded) -> np.ndarray:
    return np.asarray(enc.control, np.int32)


def _bp_decode_block(ctrl, data, ctrl_len, n_valid, *, frame_quads):
    return bp128.decode_arena_block(ctrl, data.reshape(-1, 4), n_valid,
                                    frame_quads)


def _bp_supports(enc: Encoded, *, frame_quads) -> bool:
    # the layout's frame size is baked into its fixed shapes; a block encoded
    # at any other frame size must take the host oracle (replaces the old
    # arena builder's "mixed BP layouts" assert)
    return enc.meta.get("frame_quads") == frame_quads


def _bp_arena(frame_quads: int) -> ArenaLayout:
    return ArenaLayout.two_column(
        ctrl_width=-(-_BP_WMAX // frame_quads),
        data_width=4 * (_BP_WMAX + 2),
        out_width=ARENA_BLOCK,
        decode_block=functools.partial(_bp_decode_block,
                                       frame_quads=frame_quads),
        block_ctrl=_bp_block_ctrl,
        supports=functools.partial(_bp_supports, frame_quads=frame_quads))


def _svb_block_data(enc: Encoded) -> np.ndarray:
    # payload bytes widened to one uint32 word each (TPU has no 8-bit lanes)
    return np.asarray(enc.data, np.uint32)


_SVB_ARENA = ArenaLayout.two_column(
    ctrl_width=ARENA_BLOCK // 4,               # one control byte per quadruple
    data_width=4 * ARENA_BLOCK + 4,            # worst-case payload + gather slack
    out_width=ARENA_BLOCK,
    decode_block=stream_vbyte.decode_arena_block,
    block_ctrl=_block_ctrl_default,            # control bytes, one per word
    block_data=_svb_block_data,
    ctrl_dtype=np.uint32)


def _gsch_arena(variant: str) -> ArenaLayout:
    return ArenaLayout.two_column(
        ctrl_width=group_scheme.arena_ctrl_width(variant),
        data_width=4 * (ARENA_BLOCK // 4 + 2),
        out_width=ARENA_BLOCK,
        decode_block=functools.partial(group_scheme.decode_arena_block,
                                       variant=variant),
        block_ctrl=group_scheme.arena_block_ctrl,
        ctrl_dtype=np.uint32)


# ---- frame-family layouts (AFOR / VSE / PFD): shared vertical data stream -- #

_FR_WMAX = ARENA_BLOCK // 4        # max data words per component per block
_FR_DATA = 4 * (_FR_WMAX + 2)      # flat words incl. the unpack slack rows


def _ctrl_col(width: int) -> ArenaColumn:
    return ArenaColumn("ctrl", width, _block_ctrl_default, np.int32)


_AFOR_ARENA = ArenaLayout(
    columns=(_ctrl_col(group_afor.ARENA_F), ArenaColumn("data", _FR_DATA)),
    out_width=ARENA_BLOCK, decode_block=group_afor.decode_arena_block)

_VSE_ARENA = ArenaLayout(
    columns=(_ctrl_col(2 * group_vse.ARENA_F), ArenaColumn("data", _FR_DATA)),
    out_width=ARENA_BLOCK, decode_block=group_vse.decode_arena_block)


def _pfd_block_exc(enc: Encoded) -> np.ndarray:
    exc = enc.exceptions
    return np.zeros(0, np.uint32) if exc is None else np.asarray(exc, np.uint32)


_PFD_ARENA = ArenaLayout(
    columns=(_ctrl_col(2 * group_pfd.ARENA_F), ArenaColumn("data", _FR_DATA),
             ArenaColumn("exceptions", group_pfd.ARENA_EXC_WORDS + 2,
                         _pfd_block_exc)),
    out_width=ARENA_BLOCK, decode_block=group_pfd.decode_arena_block)


def _dense_block_ctrl(enc: Encoded) -> np.ndarray:
    return np.asarray(enc.control, np.uint32).reshape(-1)


# dense-bitmap blocks: ctrl = [fmt, base]; bitmap format stores exactly the
# 128 window words, the raw fallback stores up to ARENA_BLOCK verbatim values
# (identity decode), so the layout is total over the codec's own encodings.
_DENSE_ARENA = ArenaLayout(
    columns=(ArenaColumn("ctrl", 2, _dense_block_ctrl, np.uint32),
             ArenaColumn("data", ARENA_BLOCK)),
    out_width=ARENA_BLOCK,
    decode_block=dense_bitmap.decode_arena_block,
    bitmap_words=dense_bitmap.WINDOW_WORDS,
    is_bitmap=dense_bitmap.is_bitmap)


# --------------------------------------------------------------------------- #
# registry: every codec module registered through the protocol
# --------------------------------------------------------------------------- #

# ---- scalar baselines ------------------------------------------------------ #
register(Codec("varbyte", "byte", scalar.vb_encode, scalar.vb_decode))
register(Codec("stream_vbyte", "byte", stream_vbyte.encode,
               stream_vbyte.decode_np,
               jax=JaxDecode(stream_vbyte.jax_args,
                             stream_vbyte.decode_jax_scalar,
                             stream_vbyte.decode_jax_vec),
               arena=_SVB_ARENA))
register(Codec("gvb", "byte", scalar.gvb_encode, scalar.gvb_decode))
register(Codec("g8iu", "byte", scalar.g8iu_encode, scalar.g8iu_decode))
register(Codec("g8cu", "byte", scalar.g8cu_encode, scalar.g8cu_decode))
register(Codec("simple9", "word", scalar.simple9_encode, scalar.simple9_decode,
               max_bits=28))
register(Codec("simple16", "word", scalar.simple16_encode,
               scalar.simple16_decode, max_bits=28))
register(Codec("rice", "bit", scalar.rice_encode, scalar.rice_decode))
register(Codec("gamma", "bit", scalar.gamma_encode, scalar.gamma_decode,
               max_bits=31))
register(Codec("pfordelta", "frame", scalar.pfd_encode, scalar.pfd_decode))
register(Codec("afor", "frame", scalar.afor_encode, scalar.afor_decode))
register(Codec("packed_binary", "frame", scalar.packedbinary_encode,
               scalar.packedbinary_decode))

# ---- Group family (this paper) --------------------------------------------- #
register(Codec("group_simple", "word", group_simple.encode,
               group_simple.decode_np, is_group=True,
               jax=JaxDecode(group_simple.jax_args,
                             group_simple.decode_jax_scalar,
                             group_simple.decode_jax_vec),
               arena=_GS_ARENA))

for _v in group_scheme.VARIANTS:
    register(Codec(
        f"group_scheme_{_v}", "bit" if int(_v.split("-")[0]) < 8 else "byte",
        functools.partial(group_scheme.encode, variant=_v),
        group_scheme.decode_np, is_group=True,
        jax=JaxDecode(group_scheme.jax_args, group_scheme.decode_jax_scalar,
                      group_scheme.decode_jax_vec),
        arena=_gsch_arena(_v)))

register(Codec("group_afor", "frame", group_afor.encode, group_afor.decode_np,
               is_group=True,
               jax=JaxDecode(group_afor.jax_args, group_afor.decode_jax_scalar,
                             group_afor.decode_jax_vec),
               arena=_AFOR_ARENA))
register(Codec("group_vse", "frame", group_vse.encode, group_vse.decode_np,
               is_group=True,
               jax=JaxDecode(group_vse.jax_args, group_vse.decode_jax_scalar,
                             group_vse.decode_jax_vec),
               arena=_VSE_ARENA))
register(Codec("group_pfd", "frame", group_pfd.encode, group_pfd.decode_np,
               is_group=True,
               jax=JaxDecode(group_pfd.jax_args, group_pfd.decode_jax_scalar,
                             group_pfd.decode_jax_vec),
               arena=_PFD_ARENA))
register(Codec("group_optpfd", "frame",
               functools.partial(group_pfd.encode, opt=True),
               group_pfd.decode_np, is_group=True,
               jax=JaxDecode(group_pfd.jax_args, group_pfd.decode_jax_scalar,
                             group_pfd.decode_jax_vec),
               arena=_PFD_ARENA))       # same block format -> shared layout
register(Codec("bp128", "frame", bp128.encode, bp128.decode_np, is_group=True,
               jax=JaxDecode(bp128.jax_args, bp128.decode_jax_scalar,
                             bp128.decode_jax_vec),
               arena=_bp_arena(32)))
register(Codec("bp_tpu", "frame", bp_tpu.encode, bp_tpu.decode_np,
               is_group=True))
register(Codec("dense_bitmap", "word", dense_bitmap.encode,
               dense_bitmap.decode_np, arena=_DENSE_ARENA))
register(Codec("g_packed_binary", "frame", bp128.encode_packed_binary,
               bp128.decode_np, is_group=True,
               jax=JaxDecode(bp128.jax_args, bp128.decode_jax_scalar,
                             bp128.decode_jax_vec),
               arena=_bp_arena(128)))
