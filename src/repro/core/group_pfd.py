"""Group-PFD (paper §6.2): PForDelta wrapped in the Group approach.

Frames of 128 integers (32 quadruples).  Per frame the bit width b is the
smallest width such that at most zeta (=10%, the paper's setting) of the quad
max entries exceed b.  Exceptions are detected on the quad max array first and
then refined to individual integers (§6.2 Step 3).  All slots store the low b
bits; exceptional integers are re-written from the exception area, which
stores (8-bit frame-local position, value) pairs with the most economical
value width w in {8, 16, 32} per frame (Zhang et al. 2008).

Header: 2 bytes/frame = bw (6 bits) | wcode (2 bits), n_exceptions (8 bits).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .bits import ebw_np, gather_bits_jnp, gather_bits_np, pack_bits_np
from .encoded import Encoded
from .frames import pack_data, quads_of, unpack_data_jnp, unpack_data_np, unpack_data_scalar_jnp
from .layout import quadmax_np

FRAME_QUADS = 32
FRAME_INTS = 128
ZETA = 0.10
W_CHOICES = np.array([8, 16, 32], np.int32)

# device-arena geometry: one 512-posting index block is at most ARENA_Q quads
# = ARENA_F fixed frames; every one of its <= 512 integers may be an
# exception, and an exception costs at most 8 + 32 bits in the patch stream
ARENA_Q = 128
ARENA_F = ARENA_Q // FRAME_QUADS
ARENA_EXC = 4 * ARENA_Q
ARENA_EXC_WORDS = ARENA_EXC * (8 + 32) // 32


def encode(x: np.ndarray, zeta: float = ZETA, opt: bool = False) -> Encoded:
    """opt=False: paper-faithful zeta rule on the quad max array (§6.2 Step 2).

    opt=True (beyond-paper, OptPFD-flavoured): per frame, pick the bit width
    minimizing 128*b + n_exc(b)*(8+w) directly — immune to the quad-level
    exception-rate inflation of the 4-way grouping on heavy-tailed data.
    """
    name = "group_optpfd" if opt else "group_pfd"
    x = np.asarray(x, dtype=np.uint32)
    n = len(x)
    if n == 0:
        return Encoded(name, 0, np.zeros(0, np.uint8), np.zeros(0, np.uint32),
                       exceptions=np.zeros(0, np.uint32), header_bits=32,
                       meta={"Q": 0, "n_exc": np.zeros(0, np.int32)})
    v = quads_of(x)
    q = len(v)
    e = ebw_np(quadmax_np(x, 4, pseudo=True))
    nf = (q + FRAME_QUADS - 1) // FRAME_QUADS
    xpad = np.concatenate([x, np.zeros(q * 4 - n, np.uint32)])
    e_int = ebw_np(xpad)
    if opt:
        ei = e_int.copy()
        ei[n:] = 0
        epad_i = np.concatenate([ei, np.zeros(nf * FRAME_INTS - q * 4, np.int32)]).reshape(nf, FRAME_INTS)
        hist = np.stack([(epad_i == b).sum(axis=1) for b in range(33)], axis=1)  # (nf, 33)
        nexc_at = hist[:, ::-1].cumsum(axis=1)[:, ::-1]          # nexc_at[:, b] = count(e >= b)
        maxe = epad_i.max(axis=1)
        w = W_CHOICES[np.minimum(np.searchsorted(W_CHOICES, np.maximum(maxe, 1)), 2)]
        bcand = np.arange(1, 33)
        # count(e > b) = nexc_at[:, b+1]; b=32 has no exceptions
        nexc_b = np.concatenate([nexc_at[:, 2:], np.zeros((nf, 1), np.int64)], axis=1)
        cost = FRAME_INTS * bcand[None, :] + nexc_b * (8 + w[:, None])
        bws = bcand[np.argmin(cost, axis=1)].astype(np.int32)
    else:
        epad = np.concatenate([e, np.zeros(nf * FRAME_QUADS - q, np.int32)]).reshape(nf, FRAME_QUADS)
        k = int(np.ceil((1.0 - zeta) * FRAME_QUADS)) - 1
        bws = np.maximum(np.partition(epad, k, axis=1)[:, k], 1).astype(np.int32)
    b_int = np.repeat(bws, FRAME_INTS)[: q * 4]
    exc_mask = e_int > b_int
    exc_mask[n:] = False
    exc_idx = np.flatnonzero(exc_mask)
    exc_frame = exc_idx // FRAME_INTS
    n_exc = np.bincount(exc_frame, minlength=nf).astype(np.int32)
    assert n_exc.max(initial=0) <= 255, "frame exception overflow"

    # most economical exception width per frame
    wcodes = np.zeros(nf, np.int32)
    if len(exc_idx):
        maxe = np.zeros(nf, np.int32)
        np.maximum.at(maxe, exc_frame, e_int[exc_idx])
        wcodes = np.searchsorted(W_CHOICES, np.maximum(maxe, 1), side="left")
        wcodes = np.minimum(wcodes, 2)
    ws = W_CHOICES[wcodes]

    # exception stream: per frame, n_exc 8-bit positions then n_exc w-bit values
    vals_list, lens_list = [], []
    for f in np.flatnonzero(n_exc):
        sel = exc_frame == f
        pos = (exc_idx[sel] % FRAME_INTS).astype(np.uint64)
        vals = xpad[exc_idx[sel]].astype(np.uint64)
        vals_list += [pos, vals]
        lens_list += [np.full(len(pos), 8, np.int64), np.full(len(pos), int(ws[f]), np.int64)]
    if vals_list:
        exc_words, exc_bits = pack_bits_np(np.concatenate(vals_list), np.concatenate(lens_list))
    else:
        exc_words, exc_bits = np.zeros(0, np.uint32), 0

    bw_quads = np.repeat(bws, FRAME_QUADS)[:q]
    data, dbits = pack_data(v, bw_quads)
    control = np.stack([(bws.astype(np.uint8) | (wcodes.astype(np.uint8) << 6)),
                        n_exc.astype(np.uint8)], axis=1).reshape(-1)
    return Encoded(
        name, n, control, data.reshape(-1),
        control_bits=nf * 16, data_bits=dbits * 4,
        exceptions=exc_words, exception_bits=exc_bits, header_bits=32,
        meta={"Q": q, "bws": bws, "n_exc": n_exc, "ws": ws},
    )


def _headers(control: np.ndarray):
    c = control.reshape(-1, 2)
    bws = (c[:, 0] & 63).astype(np.int32)
    wcodes = (c[:, 0] >> 6).astype(np.int32)
    n_exc = c[:, 1].astype(np.int32)
    return bws, W_CHOICES[wcodes], n_exc


def decode_np(enc: Encoded) -> np.ndarray:
    if enc.n == 0:
        return np.zeros(0, np.uint32)
    q = enc.meta["Q"]
    bws, ws, n_exc = _headers(enc.control)
    bw_quads = np.repeat(bws, FRAME_QUADS)[:q]
    out = unpack_data_np(enc.data.reshape(-1, 4), bw_quads, enc.n).copy()
    tot = int(n_exc.sum())
    if tot:
        frame_bits = n_exc * (8 + ws)
        base = np.cumsum(frame_bits) - frame_bits
        fid = np.repeat(np.arange(len(n_exc)), n_exc)
        j = np.arange(tot) - np.repeat(np.cumsum(n_exc) - n_exc, n_exc)
        pos_off = base[fid] + j * 8
        val_off = base[fid] + n_exc[fid] * 8 + j * ws[fid]
        pos = gather_bits_np(enc.exceptions, pos_off, np.full(tot, 8))
        vals = gather_bits_np(enc.exceptions, val_off, ws[fid])
        g = fid * FRAME_INTS + pos
        out[g[g < enc.n]] = vals[g < enc.n]
    return out


def jax_args(enc: Encoded) -> dict:
    data = enc.data.reshape(-1, 4)
    data = np.concatenate([data, np.zeros((1, 4), np.uint32)])
    exc = np.concatenate([enc.exceptions, np.zeros(2, np.uint32)])
    return {
        "control": jnp.asarray(enc.control.astype(np.int32)),
        "data": jnp.asarray(data),
        "exceptions": jnp.asarray(exc),
        "n": enc.n,
        "q": enc.meta["Q"],
        "total_exc": int(enc.meta["n_exc"].sum()),
    }


def _apply_exceptions(out, control, exceptions, n: int, total_exc: int):
    if total_exc == 0:
        return out
    c = control.reshape(-1, 2)
    bws = c[:, 0] & 63
    ws = jnp.asarray(W_CHOICES)[c[:, 0] >> 6]
    n_exc = c[:, 1]
    frame_bits = n_exc * (8 + ws)
    base = jnp.cumsum(frame_bits) - frame_bits
    nf = c.shape[0]
    fid = jnp.repeat(jnp.arange(nf, dtype=jnp.int32), n_exc, total_repeat_length=total_exc)
    seg_start = jnp.repeat(jnp.cumsum(n_exc) - n_exc, n_exc, total_repeat_length=total_exc)
    j = jnp.arange(total_exc, dtype=jnp.int32) - seg_start
    pos_off = base[fid] + j * 8
    val_off = base[fid] + n_exc[fid] * 8 + j * ws[fid]
    pos = gather_bits_jnp(exceptions, pos_off, jnp.full(total_exc, 8, jnp.int32))
    vals = gather_bits_jnp(exceptions, val_off, ws[fid])
    g = fid * FRAME_INTS + pos.astype(jnp.int32)
    return out.at[g].set(vals, mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "q", "total_exc"))
def decode_jax_vec(control, data, exceptions, n: int, q: int, total_exc: int):
    bws = control.reshape(-1, 2)[:, 0] & 63
    bw_quads = jnp.repeat(bws, FRAME_QUADS, total_repeat_length=max(q, 1))
    out = unpack_data_jnp(data, bw_quads, n)
    return _apply_exceptions(out, control, exceptions, n, total_exc)


@functools.partial(jax.jit, static_argnames=("n", "q", "total_exc"))
def decode_jax_scalar(control, data, exceptions, n: int, q: int, total_exc: int):
    bws = control.reshape(-1, 2)[:, 0] & 63
    bw_quads = jnp.repeat(bws, FRAME_QUADS, total_repeat_length=max(q, 1))
    out = unpack_data_scalar_jnp(data, bw_quads, n, q)
    return _apply_exceptions(out, control, exceptions, n, total_exc)


W_J = jnp.asarray(W_CHOICES)


def decode_arena_block(ctrl, data, exc, ctrl_len, data_len, exc_len, n_valid):
    """Fixed-shape single-block decode + vectorized exception patch for the
    device arena (``repro.index.device``): padded static shapes + dynamic
    lengths, so a work-list of (term, block) pairs decodes lane-parallel
    under ``vmap`` — the patch application never leaves the device.

    ctrl: (2 * ARENA_F,) int32 header bytes, interleaved (bw | wcode << 6,
          n_exc) per 128-integer frame; bytes >= ``ctrl_len`` are slack.
    data: (4 * (W + 2),) flat uint32 words gathered from the data arena.
    exc:  (ARENA_EXC_WORDS + 2,) uint32 patch-stream words; per frame,
          ``n_exc`` 8-bit positions then ``n_exc`` w-bit values.
    ctrl_len, data_len, exc_len, n_valid: dynamic word / integer counts.
    Returns (4 * ARENA_Q,) uint32 values, zero beyond ``n_valid``.

    Shared by ``group_pfd`` and ``group_optpfd`` (identical block format).
    """
    c = ctrl.reshape(-1, 2)
    fmax = c.shape[0]
    f_valid = jnp.arange(fmax, dtype=jnp.int32) < (ctrl_len >> 1)
    bws = jnp.where(f_valid, c[:, 0] & 63, 0).astype(jnp.int32)
    ws = W_J[c[:, 0] >> 6]
    n_exc = jnp.where(f_valid, c[:, 1], 0).astype(jnp.int32)
    q = jnp.arange(ARENA_Q, dtype=jnp.int32)
    q_len = (n_valid + 3) >> 2
    bw_quads = jnp.where(q < q_len, bws[jnp.minimum(q >> 5, fmax - 1)], 0)
    out = unpack_data_jnp(data.reshape(-1, 4), bw_quads, 4 * ARENA_Q)
    # vectorized patch: one fixed lane per potential exception slot, masked
    # past the block's dynamic total (same bit layout as _apply_exceptions)
    frame_bits = n_exc * (8 + ws)
    base = jnp.cumsum(frame_bits) - frame_bits
    cum = jnp.cumsum(n_exc)
    j = jnp.arange(ARENA_EXC, dtype=jnp.int32)
    fid = jnp.minimum(jnp.searchsorted(cum, j, side="right").astype(jnp.int32),
                      fmax - 1)
    jj = j - (cum[fid] - n_exc[fid])
    pos = gather_bits_jnp(exc, base[fid] + jj * 8,
                          jnp.full(ARENA_EXC, 8, jnp.int32))
    vals = gather_bits_jnp(exc, base[fid] + n_exc[fid] * 8 + jj * ws[fid],
                           ws[fid])
    g = fid * FRAME_INTS + pos.astype(jnp.int32)
    g = jnp.where((j < cum[-1]) & (g < n_valid), g, out.shape[0])
    out = out.at[g].set(vals, mode="drop")
    i = jnp.arange(4 * ARENA_Q, dtype=jnp.int32)
    return jnp.where(i < n_valid, out, 0)
