import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes (16x16 single-pod, 2x16x16 multi-pod) with
ShapeDtypeStruct inputs — no allocation.  Proves the distribution config is
coherent: sharding mismatches, compile-time OOM or unsupported collectives
fail here.

Per cell it records: memory_analysis (bytes/device), cost_analysis (FLOPs /
bytes for §Roofline), and the collective-op byte census parsed from the
post-SPMD HLO.  Results cached as JSON under --out (incremental; --force to
redo).  ``--all`` drives every cell in subprocesses (one compile per process
keeps 512-device XLA memory bounded).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod both] --out experiments/dryrun
"""

import argparse
import json
import re
import subprocess
import sys
import time


# regex over post-SPMD HLO: "<shape> <collective>(" — result shape precedes op
_COLL_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

# per-chip wire-byte factor per result byte (ring algorithms)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo_text: str) -> dict:
    per_kind_bytes = {}
    per_kind_count = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES.get(dt, 4)
        per_kind_bytes[kind] = per_kind_bytes.get(kind, 0) + b
        per_kind_count[kind] = per_kind_count.get(kind, 0) + 1
    wire = sum(_WIRE_FACTOR[k] * v for k, v in per_kind_bytes.items())
    return {"per_kind_bytes": per_kind_bytes, "per_kind_count": per_kind_count,
            "wire_bytes_per_chip": wire}


def cell_path(out_dir: str, arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    import jax
    from repro import configs
    from repro.configs.base import STEP_FNS
    from repro.distributed import sharding as shlib
    from repro.launch.mesh import make_production_mesh
    from repro.optim import adamw_init

    spec = configs.get(arch_id)
    cell = spec.shapes[shape_name]
    record = {
        "arch": arch_id, "shape": shape_name,
        "mesh": [2, 16, 16] if multi_pod else [16, 16],
        "kind": cell.kind, "dims": {k: v for k, v in cell.dims.items()
                                    if isinstance(v, (int, float, str))},
    }
    if cell.skip_reason:
        record["status"] = "skipped"
        record["skip_reason"] = cell.skip_reason
        return record

    cfg = spec.config_for_cell(spec.make_config(), cell)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = spec.plan_for(cfg, cell)
    record["plan"] = plan.name

    from repro.models import egnn, recsys, transformer
    mod = {"lm": transformer, "gnn": egnn, "recsys": recsys}[spec.family]

    t0 = time.time()
    with shlib.activate(mesh, plan):
        params_abs = mod.abstract(cfg)
        axes = mod.axes(cfg)
        p_shard = shlib.sharding_for_axes_tree(axes, params_abs)
        inputs = spec.input_specs(cfg, cell)
        b_axes = spec.batch_axes(cfg, cell)
        b_shard = shlib.sharding_for_axes_tree(b_axes, inputs)
        step_fn, is_train = STEP_FNS[spec.family](cfg, cell)
        if is_train:
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            o_shard = {
                "m": p_shard, "v": p_shard,
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            }
            if "master" in opt_abs:
                o_shard["master"] = p_shard
            lowered = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard)) \
                .lower(params_abs, opt_abs, inputs)
        else:
            lowered = jax.jit(step_fn, in_shardings=(p_shard, b_shard)) \
                .lower(params_abs, inputs)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for key in ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes",
                        "alias_size_in_bytes"):
                v = getattr(mem, key, None)
                if v is not None:
                    record.setdefault("memory", {})[key] = int(v)
            print("memory_analysis:", record.get("memory"))
        cost = compiled.cost_analysis()
        if cost:
            c = cost[0] if isinstance(cost, (list, tuple)) else cost
            record["cost"] = {k: float(v) for k, v in c.items()
                              if isinstance(v, (int, float)) and (
                                  k in ("flops", "bytes accessed")
                                  or k.startswith("bytes accessed"))}
            print("cost_analysis: flops=%.3e bytes=%.3e" % (
                record["cost"].get("flops", 0), record["cost"].get("bytes accessed", 0)))
        try:
            hlo = compiled.as_text()
            record["collectives"] = parse_collectives(hlo)
            record["hlo_lines"] = hlo.count("\n")
            from repro.launch.hlo_census import census
            record["census"] = census(hlo)   # trip-count-aware roofline terms
            print("census: flops/chip=%.3e mem/chip=%.3e wire/chip=%.3e" % (
                record["census"]["flops_per_chip"],
                record["census"]["mem_bytes_per_chip"],
                record["census"]["wire_bytes_per_chip"]))
        except Exception as e:  # pragma: no cover
            record["collectives_error"] = str(e)
        # parameter/input footprint per device (from shardings; exact)
        def sharded_bytes(tree_abs, tree_shard):
            tot = 0
            for a, s in zip(jax.tree.leaves(tree_abs), jax.tree.leaves(
                    tree_shard, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))):
                n = 1
                for d in a.shape:
                    n *= d
                shards = 1
                spec_ = s.spec
                for i, pp in enumerate(spec_):
                    if pp is None:
                        continue
                    ax = (pp,) if isinstance(pp, str) else pp
                    k = 1
                    for aa in ax:
                        k *= mesh.shape[aa]
                    if a.shape[i] % k == 0:
                        shards *= k
                tot += n * a.dtype.itemsize // shards
            return tot
        record["param_bytes_per_device"] = sharded_bytes(params_abs, p_shard)
        record["status"] = "ok"
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", dest="multi_pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both",
                    help="which meshes to run with --all")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro import configs
        meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
        todo = []
        for aid, sname, cell in configs.all_cells():
            for mp in meshes:
                path = cell_path(args.out, aid, sname, mp)
                if os.path.exists(path) and not args.force:
                    continue
                todo.append((aid, sname, mp))
        print(f"[dryrun] {len(todo)} cells to run")
        fails = []
        for i, (aid, sname, mp) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", aid,
                   "--shape", sname, "--out", args.out] + (["--multi-pod"] if mp else [])
            print(f"[{i+1}/{len(todo)}] {aid} x {sname} x {'2x16x16' if mp else '16x16'}",
                  flush=True)
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True, timeout=args.timeout)
            if r.returncode != 0:
                fails.append((aid, sname, mp))
                err_path = cell_path(args.out, aid, sname, mp) + ".err"
                with open(err_path, "w") as f:
                    f.write(r.stdout[-5000:] + "\n" + r.stderr[-10000:])
                print(f"  FAILED ({time.time()-t0:.0f}s) -> {err_path}")
            else:
                print(f"  ok ({time.time()-t0:.0f}s)")
        print(f"[dryrun] done; {len(fails)} failures: {fails}")
        sys.exit(1 if fails else 0)

    record = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    path = cell_path(args.out, args.arch, args.shape, args.multi_pod)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(json.dumps({k: v for k, v in record.items() if k != "collectives"}, indent=2))
    print("->", path)


if __name__ == "__main__":
    main()
