"""Generate the §Roofline markdown table from the dry-run JSONs.

Per (arch x shape x mesh): the three roofline terms (v5e constants), dominant
bottleneck, MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE for training, 2*N*D
for serving) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

  PYTHONPATH=src python -m repro.launch.roofline_report > experiments/roofline.md
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def _lm_params(cfg):
    from repro.models import transformer as T
    from repro.models.specs import count_params
    specs = T.param_specs(cfg)
    total = count_params(specs)
    active = total
    if cfg.moe:
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert
        inactive = (cfg.n_experts - cfg.top_k) * per_expert * cfg.n_moe_layers
        active = total - inactive
    return total, active


def _model_flops(rec) -> float:
    from repro import configs
    spec = configs.get(rec["arch"])
    cell = spec.shapes[rec["shape"]]
    cfg = spec.config_for_cell(spec.make_config(), cell)
    kind = rec["kind"]
    if spec.family == "lm":
        total, active = _lm_params(cfg)
        if kind == "train":
            d = cell.dims["batch"] * cell.dims["seq"]
            return 6.0 * active * d
        if kind == "prefill":
            return 2.0 * active * cell.dims["batch"] * cell.dims["seq"]
        return 2.0 * active * cell.dims["batch"]          # decode: 1 tok/seq
    if spec.family == "gnn":
        dh = cfg.d_hidden
        e = cell.dims["n_edges"]
        n = cell.dims["n_nodes"]
        per_edge = 2 * ((2 * dh + 1) * dh + dh * dh) + 2 * (dh * dh + dh)
        per_node = 2 * (2 * dh * dh + dh * dh)
        fwd = cfg.n_layers * (e * per_edge + n * per_node) + 2 * n * cell.dims["d_feat"] * dh
        return 3.0 * fwd                                   # fwd+bwd
    # recsys: MLP + interaction flops per sample
    def mlp_flops(dims, d_in):
        f, cur = 0, d_in
        for d in dims:
            f += 2 * cur * d
            cur = d
        return f
    if cfg.model == "dlrm":
        per = mlp_flops(cfg.bot_mlp, cfg.n_dense) + mlp_flops(cfg.top_mlp, 415) + 2 * 27 * 27 * 64
    elif cfg.model == "wide_deep":
        per = mlp_flops(cfg.top_mlp, cfg.n_sparse * cfg.embed_dim)
    elif cfg.model == "din":
        pair = cfg.pair_dim
        per = cfg.seq_len * mlp_flops(cfg.attn_mlp + (1,), 4 * pair) + mlp_flops(cfg.mlp + (1,), 3 * pair + cfg.n_profile * cfg.embed_dim)
    else:  # dien
        per = cfg.seq_len * (2 * 3 * (cfg.pair_dim + cfg.gru_dim) * cfg.gru_dim * 2
                             + mlp_flops(cfg.attn_mlp + (1,), 2 * cfg.gru_dim))
    b = cell.dims.get("n_candidates", cell.dims["batch"])
    mult = 3.0 if kind == "train" else 1.0
    return mult * per * b


def rows(out_dir="experiments/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(path))
        mesh = "x".join(map(str, rec["mesh"]))
        chips = int(np.prod(rec["mesh"]))
        if rec.get("status") == "skipped":
            out.append((rec["arch"], rec["shape"], mesh, None, rec["skip_reason"]))
            continue
        c = rec["census"]
        tc = c["flops_per_chip"] / PEAK
        tm = c["mem_bytes_per_chip"] / HBM
        tl = c["wire_bytes_per_chip"] / ICI
        dom = max((("compute", tc), ("memory", tm), ("collective", tl)), key=lambda kv: kv[1])[0]
        mf = _model_flops(rec)
        hlo_total = c["flops_per_chip"] * chips
        ratio = mf / hlo_total if hlo_total else float("nan")
        frac = tc / max(tc, tm, tl)
        out.append((rec["arch"], rec["shape"], mesh,
                    (tc, tm, tl, dom, mf, ratio, frac), None))
    return out


def main() -> None:
    print("| arch | shape | mesh | T_comp (ms) | T_mem (ms) | T_coll (ms) | dominant | MODEL_FLOPS | useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for arch, shape, mesh, t, skip in rows():
        if t is None:
            print(f"| {arch} | {shape} | {mesh} | — | — | — | SKIPPED | — | — | — |")
            continue
        tc, tm, tl, dom, mf, ratio, frac = t
        print(f"| {arch} | {shape} | {mesh} | {tc*1e3:.2f} | {tm*1e3:.2f} | "
              f"{tl*1e3:.2f} | {dom} | {mf:.2e} | {ratio:.2f} | {frac:.3f} |")


if __name__ == "__main__":
    main()
