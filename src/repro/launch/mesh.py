"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16x16 = 256 chips (v5e pod), axes
("data", "model").  Multi-pod: 2x16x16 = 512 chips, axes ("pod", "data",
"model") — the "pod" axis carries data parallelism across pods (its
collectives traverse DCN, which is why gradient compression targets it
first).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit/auto axis types; older jax has implicit Auto only
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _axis_kwargs(n_axes: int) -> dict:
    """Mesh kwargs asking for Auto axis types, on jax versions that have them."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}; have {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    import numpy as np
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests / examples)."""
    import numpy as np
    ndev = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:ndev]).reshape(shape)
    return jax.sharding.Mesh(dev, axes, **_axis_kwargs(len(axes)))


def serving_mesh(n_shards: int, axis: str = "shards"):
    """1-D mesh for doc-range sharded serving: one device per shard, or None
    when the backend has fewer devices than shards (the engine then runs the
    shards logically on one device — same results, no placement)."""
    if n_shards < 1 or len(jax.devices()) < n_shards:
        return None
    return make_host_mesh((n_shards,), (axis,))
