"""Serving launcher: prefill + batched decode (LM) or batched scoring /
retrieval (recsys) under the serving sharding plan.

  python -m repro.launch.serve --arch smollm-135m --smoke --tokens 8
  python -m repro.launch.serve --arch din --shape serve_p99 --smoke
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import STEP_FNS
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--shape", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    spec = configs.get(args.arch)
    serve_cells = [c for c in spec.shapes.values()
                   if c.kind in ("prefill", "decode", "serve", "retrieval")]
    cell = spec.shapes[args.shape] if args.shape else serve_cells[0]
    cfg = spec.config_for_cell(
        spec.make_smoke_config() if args.smoke else spec.make_config(), cell)
    mesh = (make_host_mesh((len(jax.devices()), 1), ("data", "model"))
            if args.smoke or len(jax.devices()) < 256
            else make_production_mesh(multi_pod=args.multi_pod))
    plan = spec.plan_for(cfg, cell)

    from repro.models import recsys, transformer
    with shlib.activate(mesh, plan):
        if spec.family == "lm":
            params = transformer.init(cfg, jax.random.PRNGKey(0))
            b, s = 2, 32
            prompts = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (b, s)), jnp.int32)
            logits, cache = jax.jit(lambda p, t: transformer.prefill(p, t, cfg))(params, prompts)
            if not cfg.window:
                cache = {k: jnp.concatenate([v, jnp.zeros(v.shape[:2] + (args.tokens,) + v.shape[3:], v.dtype)], axis=2)
                         for k, v in cache.items()}
            decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            t0 = time.perf_counter()
            for i in range(args.tokens):
                logits, cache = decode(params, cache, tok, jnp.int32(s + i))
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            print(f"decoded {args.tokens} steps x batch {b} in {(time.perf_counter()-t0)*1e3:.1f} ms")
        else:
            params = recsys.init(cfg, jax.random.PRNGKey(0))
            step_fn, _ = STEP_FNS["recsys"](cfg, cell, None)
            from tests.test_arch_smoke import _smoke_batch
            batch = _smoke_batch(spec, cfg, cell)
            if cell.kind == "retrieval":
                batch = {k: (v[:1] if not k.startswith("cand_") else v) for k, v in batch.items()}
            out = jax.jit(step_fn)(params, batch)
            out0 = out[0] if isinstance(out, tuple) else out
            print(f"{cell.name}: output {np.asarray(out0).shape} ok")


if __name__ == "__main__":
    main()
