"""Serving launcher: prefill + batched decode (LM), batched scoring /
retrieval (recsys) under the serving sharding plan, or the latency-governed
index serving loop (``--index``: async admission + dynamic batching over the
``QueryEngine``, see ``repro.index.serve``).

  python -m repro.launch.serve --arch smollm-135m --smoke --tokens 8
  python -m repro.launch.serve --arch din --shape serve_p99 --smoke
  python -m repro.launch.serve --index --smoke
  python -m repro.launch.serve --index --rate 300 --requests 512 --placement device
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import STEP_FNS
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh, make_production_mesh


def serve_index(args) -> None:
    """Index retrieval serving: build a seeded corpus, start the
    :class:`~repro.index.serve.IndexServer`, drive an open-loop Poisson
    stream through it, and print the SLO snapshot.  ``--smoke`` shrinks the
    stream to CI size and asserts nothing was shed."""
    import json

    from repro.data import synth
    from repro.index.invindex import InvertedIndex
    from repro.index.engine import QueryEngine
    from repro.index.serve import (Rejected, Request, ServeConfig,
                                   poisson_offsets, serve_stream)
    from repro.obs import (enable_tracing, get_tracer, to_chrome_trace,
                           trace_coverage)

    n = 32 if args.smoke else args.requests
    if args.trace_out:
        # deep engine/kernel spans ride the process-global tracer; the
        # server's lifecycle spans are always on (server-owned tracer)
        enable_tracing(True, fenced=args.fenced)
    doclen, postings = synth.make_corpus(args.dataset, args.seed)
    idx = InvertedIndex.build(doclen, postings)
    idx.to_device(build_fused=True)
    engine = QueryEngine(idx).to_device(fused=True)
    # head-term conjunctions, same shape as benchmarks.bench_query's workload
    rng = np.random.default_rng(3 + args.seed)
    terms = sorted(postings)
    queries = [rng.choice(terms[:120], size=rng.integers(2, 4),
                          replace=False).tolist() for _ in range(n)]
    reqs = [Request(list(q), mode="and", k=10, deadline_ms=args.deadline_ms)
            for q in queries]
    offsets = poisson_offsets(n, args.rate, seed=41 + args.seed)
    cfg = ServeConfig(max_batch=16, max_wait_ms=4.0, slack_ms=2.0,
                      queue_cap=max(256, 4 * n),
                      default_deadline_ms=args.deadline_ms,
                      placement=args.placement, warm_terms=32,
                      # prime the jit buckets with the (seeded, known)
                      # workload so the stream measures serving, not
                      # first-seen compile stalls
                      warm_queries=queries)
    results, stats = serve_stream(engine, reqs, offsets, cfg)
    snap = stats.snapshot()
    lat = snap["latency_ms"]
    print(f"served {snap['served']}/{snap['submitted']} "
          f"(shed_rate={snap['shed_rate']:.3f}) at {args.rate:.0f} qps "
          f"poisson on placement={args.placement or 'auto'}")
    print(f"latency ms: p50={lat.get('p50', 0):.2f} p99={lat.get('p99', 0):.2f} "
          f"p999={lat.get('p999', 0):.2f}  goodput={snap['goodput_qps']:.1f} qps  "
          f"mean_batch={snap['mean_batch']:.1f}  warmup={snap['warmup_s']:.2f}s")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(stats.to_prometheus())
        print(f"wrote prometheus metrics to {args.metrics_out}")
    if args.trace_out:
        trace = to_chrome_trace(stats.tracer, get_tracer())
        with open(args.trace_out, "w") as f:
            json.dump(trace, f)
        cov = trace_coverage(stats.tracer.spans())
        print(f"wrote {len(trace['traceEvents'])} trace events to "
              f"{args.trace_out} (batch coverage {cov:.3f}) — load at "
              f"https://ui.perfetto.dev")
        if args.smoke:
            # the exported trace must round-trip as JSON and the
            # plan/execute/deliver children must account for >= 90% of
            # measured batch wall-clock
            with open(args.trace_out) as f:
                assert json.load(f)["traceEvents"], "empty trace export"
            assert cov >= 0.9, f"trace covers {cov:.3f} < 0.9 of batch time"
        enable_tracing(False)
    if args.smoke:
        shed = [r for r in results if isinstance(r, Rejected)]
        assert not shed, f"smoke stream shed {len(shed)} requests: {shed[:3]}"
        print("index serve smoke ok")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(configs.ARCHS))
    ap.add_argument("--index", action="store_true",
                    help="serve the inverted index (async admission + "
                         "dynamic batching) instead of a model arch")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dataset", default="gov2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="index mode: mean Poisson arrival rate (qps)")
    ap.add_argument("--deadline-ms", type=float, default=2500.0,
                    help="index mode: per-request SLO budget (generous "
                         "default absorbs jit compile stalls on CPU)")
    ap.add_argument("--placement", default=None,
                    choices=["host", "device", "fused"],
                    help="index mode: pin every batch's placement "
                         "(default: engine auto-placement)")
    ap.add_argument("--trace-out", default=None,
                    help="index mode: write a Perfetto-loadable Chrome "
                         "trace-event JSON of the run (also enables the "
                         "deep engine/kernel spans)")
    ap.add_argument("--metrics-out", default=None,
                    help="index mode: write the server's Prometheus text "
                         "exposition to this file after the stream")
    ap.add_argument("--fenced", action="store_true",
                    help="with --trace-out: block_until_ready inside round "
                         "spans so durations attribute device wall-clock "
                         "to the producing kernel")
    args = ap.parse_args()

    if args.index:
        serve_index(args)
        return
    if args.arch is None:
        ap.error("either --arch or --index is required")

    spec = configs.get(args.arch)
    serve_cells = [c for c in spec.shapes.values()
                   if c.kind in ("prefill", "decode", "serve", "retrieval")]
    cell = spec.shapes[args.shape] if args.shape else serve_cells[0]
    cfg = spec.config_for_cell(
        spec.make_smoke_config() if args.smoke else spec.make_config(), cell)
    mesh = (make_host_mesh((len(jax.devices()), 1), ("data", "model"))
            if args.smoke or len(jax.devices()) < 256
            else make_production_mesh(multi_pod=args.multi_pod))
    plan = spec.plan_for(cfg, cell)

    from repro.models import recsys, transformer
    with shlib.activate(mesh, plan):
        if spec.family == "lm":
            params = transformer.init(cfg, jax.random.PRNGKey(0))
            b, s = 2, 32
            prompts = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (b, s)), jnp.int32)
            logits, cache = jax.jit(lambda p, t: transformer.prefill(p, t, cfg))(params, prompts)
            if not cfg.window:
                cache = {k: jnp.concatenate([v, jnp.zeros(v.shape[:2] + (args.tokens,) + v.shape[3:], v.dtype)], axis=2)
                         for k, v in cache.items()}
            decode = jax.jit(lambda p, c, t, pos: transformer.decode_step(p, c, t, pos, cfg))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            t0 = time.perf_counter()
            for i in range(args.tokens):
                logits, cache = decode(params, cache, tok, jnp.int32(s + i))
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
            print(f"decoded {args.tokens} steps x batch {b} in {(time.perf_counter()-t0)*1e3:.1f} ms")
        else:
            params = recsys.init(cfg, jax.random.PRNGKey(0))
            step_fn, _ = STEP_FNS["recsys"](cfg, cell, None)
            from tests.test_arch_smoke import _smoke_batch
            batch = _smoke_batch(spec, cfg, cell)
            if cell.kind == "retrieval":
                batch = {k: (v[:1] if not k.startswith("cand_") else v) for k, v in batch.items()}
            out = jax.jit(step_fn)(params, batch)
            out0 = out[0] if isinstance(out, tuple) else out
            print(f"{cell.name}: output {np.asarray(out0).shape} ok")


if __name__ == "__main__":
    main()
