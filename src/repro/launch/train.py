"""Full-scale training launcher.

On a real pod this runs under the production mesh with the arch's sharding
plan; on CPU it falls back to a host mesh so the same entry point is testable
everywhere.  Exposes the XLA latency-hiding/overlap flags used at scale.

  python -m repro.launch.train --arch smollm-135m --shape train_4k \
      --steps 1000 --ckpt /data/ckpt [--smoke]
"""

from __future__ import annotations

import argparse
import os

# compute/comm overlap knobs (documented defaults for v5e pods)
os.environ.setdefault("LIBTPU_INIT_ARGS",
                      "--xla_tpu_enable_async_collective_fusion=true "
                      "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
                      "--xla_enable_async_all_gather=true")

import numpy as np
import jax

from repro import configs
from repro.configs.base import STEP_FNS
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import train_loop as TL


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true", help="reduced config on host devices")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    spec = configs.get(args.arch)
    train_cells = [c for c in spec.shapes.values() if c.kind == "train"]
    cell = spec.shapes[args.shape] if args.shape else train_cells[0]
    assert cell.kind == "train", f"{cell.name} is a serving shape; use launch.serve"
    cfg = spec.config_for_cell(
        spec.make_smoke_config() if args.smoke else spec.make_config(), cell)

    if args.smoke or len(jax.devices()) < 256:
        mesh = make_host_mesh((len(jax.devices()), 1), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    plan = spec.plan_for(cfg, cell)
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps)

    from repro.models import egnn, recsys, transformer
    mod = {"lm": transformer, "gnn": egnn, "recsys": recsys}[spec.family]

    with shlib.activate(mesh, plan):
        params = mod.init(cfg, jax.random.PRNGKey(0))
        step_fn, is_train = STEP_FNS[spec.family](cfg, cell, ocfg)
        step = jax.jit(step_fn)

        rng = np.random.default_rng(0)

        def batch_iter(cursor):
            # synthetic batches matching the smoke/full input shapes
            from tests.test_arch_smoke import _smoke_batch
            return _smoke_batch(spec, cfg, cell), cursor + 1

        loop = TL.LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                             ckpt_every=max(args.steps // 4, 1), log_every=10)
        params, opt, info = TL.run(step, params, adamw_init(params), batch_iter, loop)
        print(f"final loss {info['metrics'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
