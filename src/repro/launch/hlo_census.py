"""HLO census: exact roofline accounting from the compiled (post-SPMD,
post-fusion) HLO module.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scanned-layer models (EXPERIMENTS.md §Roofline documents the 14x undercount we
measured).  This parser instead:

  * splits the HLO text into computations and builds the call graph
    (fusion ``calls=``, while ``body=``/``condition=``, call/map/reduce...)
  * extracts while TRIP COUNTS from the loop-condition constant
    (lax.scan/fori_loop lower to a counted while),
  * counts per op: dot FLOPs (2*M*N*K from the result shape x contracting
    dims), collective wire bytes, and memory traffic (operand+result bytes of
    top-level ops; fusion-called computations contribute FLOPs only, their
    bytes are accounted at the fusion call site),
  * multiplies everything by the product of enclosing trip counts.

Shapes in the partitioned module are per-device, so all outputs are per-chip;
multiply by chip count for totals.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)(.*)$")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_CMP_RE = re.compile(r"compare\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(dt_dims) -> int:
    dt, dims = dt_dims
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    kind: str
    args: str
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_entry: bool = False

    def symtab(self) -> dict:
        return {op.name: op.result_type for op in self.ops}


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        hdr = _COMP_HDR_RE.match(stripped)
        if hdr and stripped.endswith("{"):
            cur = Computation(hdr.group(1), [], is_entry=stripped.startswith("ENTRY"))
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4), m.group(5)))
    return comps


def _called(op: Op) -> list:
    out = []
    for m in _CALLED_RE.finditer(op.attrs + " " + op.args):
        for name in m.group(1).split(","):
            out.append(name.strip().lstrip("%"))
    return out


def _while_trip_count(cond: Computation) -> int:
    """Counted loops compare the induction var against a constant."""
    consts = {}
    for op in cond.ops:
        if op.kind == "constant":
            mv = re.search(r"constant\((-?\d+)\)", "constant(" + op.args + ")")
            if mv:
                consts[op.name] = int(mv.group(1))
    for op in cond.ops:
        if op.kind == "compare":
            for arg in re.findall(r"%([\w.\-]+)", op.args):
                if arg in consts:
                    return max(consts[arg], 1)
            # inline constant operand: s32[] constant(30) inside compare args
            mv = re.search(r"constant\((-?\d+)\)", op.args)
            if mv:
                return max(int(mv.group(1)), 1)
    return 1


def _arg_names(op: Op) -> list:
    return re.findall(r"%([\w.\-]+)", op.args)


def _operand_bytes(op: Op, symtab: dict) -> int:
    # operand types may be inline or referenced by name
    total = _shape_bytes(op.args)
    if total:
        return total
    return sum(_shape_bytes(symtab.get(a, "")) for a in _arg_names(op))


def _dot_flops(op: Op, symtab: dict) -> float:
    # result elems x contracting size x 2
    shapes_res = _SHAPE_RE.findall(op.result_type)
    if not shapes_res:
        return 0.0
    out_elems = _shape_elems(shapes_res[0])
    arg_shapes = _SHAPE_RE.findall(op.args)
    if not arg_shapes:
        names = _arg_names(op)
        if names:
            arg_shapes = _SHAPE_RE.findall(symtab.get(names[0], ""))
    if not arg_shapes:
        return 0.0
    lhs = arg_shapes[0]
    mc = _CONTRACT_RE.search(op.args + " " + op.attrs)
    k = 1
    if mc:
        dims = [int(x) for x in mc.group(1).split(",") if x]
        lhs_dims = [int(d) for d in lhs[1].split(",") if d]
        for d in dims:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
    return 2.0 * out_elems * k


def census(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"error": "no entry computation"}

    # multipliers via DFS from entry
    mult = {c: 0.0 for c in comps}
    fusion_called = set()

    def visit(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op in comp.ops:
            called = _called(op)
            if not called:
                continue
            if op.kind == "while":
                body = cond = None
                blob = op.attrs + op.args
                for attr_m in re.finditer(r"(body|condition)=%?([\w.\-]+)", blob):
                    if attr_m.group(1) == "body":
                        body = attr_m.group(2)
                    else:
                        cond = attr_m.group(2)
                tc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', blob)
                if tc:
                    trips = max(int(tc.group(1)), 1)
                else:
                    trips = _while_trip_count(comps[cond]) if cond in comps else 1
                if body:
                    visit(body, m * trips)
                if cond:
                    visit(cond, m * (trips + 1))
            else:
                for cal in called:
                    if op.kind == "fusion":
                        fusion_called.add(cal)
                    visit(cal, m)

    visit(entry.name, 1.0)

    flops = 0.0
    bytes_mem = 0.0
    coll_bytes = {}
    coll_count = {}
    wire = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_called
        symtab = comp.symtab()
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                flops += m * _dot_flops(op, symtab)
            base_kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base_kind in COLLECTIVES:
                b = _shape_bytes(op.result_type)
                coll_bytes[base_kind] = coll_bytes.get(base_kind, 0.0) + m * b
                coll_count[base_kind] = coll_count.get(base_kind, 0) + int(m)
                wire += m * b * _WIRE_FACTOR[base_kind]
            if in_fusion or op.kind in ("parameter", "constant", "tuple",
                                        "get-tuple-element", "bitcast",
                                        "copy", "copy-start", "copy-done"):
                continue
            res_b = _shape_bytes(op.result_type)
            if op.kind in ("dynamic-slice", "gather", "slice", "while",
                           "conditional", "broadcast", "iota", "reshape",
                           "transpose"):
                # reads only what it produces (loop-invariant operands like the
                # stacked layer params must not count once per iteration)
                bytes_mem += m * 2 * res_b
            elif op.kind in ("dynamic-update-slice", "scatter"):
                names = _arg_names(op)
                upd = _shape_bytes(symtab.get(names[1], "")) if len(names) > 1 else res_b
                bytes_mem += m * 2 * upd
            else:
                bytes_mem += m * (res_b + _operand_bytes(op, symtab))
    return {
        "flops_per_chip": flops,
        "mem_bytes_per_chip": bytes_mem,
        "collective_bytes_per_chip": coll_bytes,
        "collective_counts_weighted": coll_count,
        "wire_bytes_per_chip": wire,
        "n_computations": len(comps),
    }
