"""Sharded, atomic, mesh-agnostic checkpointing.

Layout per step::

    <dir>/step_000123.tmp/...   (written first)
    <dir>/step_000123/          (atomic rename on success)
        manifest.json           {step, tree structure, shapes, dtypes, sha256}
        arrays.npz              flat param/opt arrays (addressable values)
        extra.json              data cursor, rng state, arbitrary metadata

Checkpoints store *logical* (unsharded) arrays, so a run can restart on a
different mesh shape — elasticity is a reload with new shardings
(test_fault_tolerance.py saves on an 8-device mesh and restores on 4).
Integrity: every array blob is sha256'd into the manifest; a truncated or
bit-flipped checkpoint is detected and the previous step is used instead.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


@dataclasses.dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def save(self, step: int, state, extra: dict | None = None) -> str:
        os.makedirs(self.directory, exist_ok=True)
        final = os.path.join(self.directory, f"step_{step:06d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(state)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "sha256": {k: hashlib.sha256(v.tobytes()).hexdigest() for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "extra.json"), "w") as f:
            json.dump(extra or {}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:06d}"), ignore_errors=True)

    def _verify(self, path: str) -> dict:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        for k in manifest["keys"]:
            blob = data[k]
            if hashlib.sha256(blob.tobytes()).hexdigest() != manifest["sha256"][k]:
                raise IOError(f"checkpoint corruption detected: {path}:{k}")
        return {k: data[k] for k in manifest["keys"]}

    def restore(self, state_template, step: int | None = None, shardings=None):
        """Restore into the structure of state_template.  Skips corrupted
        checkpoints (falls back to older steps).  shardings: optional pytree
        of NamedShardings for resharded (elastic) restore."""
        step = step if step is not None else latest_step(self.directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        candidates = sorted(
            (int(n.split("_")[1]) for n in os.listdir(self.directory)
             if n.startswith("step_") and not n.endswith(".tmp")), reverse=True)
        candidates = [s for s in candidates if s <= step]
        last_err = None
        for s in candidates:
            path = os.path.join(self.directory, f"step_{s:06d}")
            try:
                arrays = self._verify(path)
                break
            except Exception as e:   # corrupted -> try previous
                last_err = e
        else:
            raise IOError(f"no intact checkpoint found: {last_err}")
        flat_t, treedef = _flatten_with_paths(state_template)
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten_with_paths(shardings)
        leaves = []
        for k in sorted(flat_t):
            arr = arrays[k]
            tmpl = flat_t[k]
            assert tuple(arr.shape) == tuple(tmpl.shape), (k, arr.shape, tmpl.shape)
            if shard_flat is not None:
                leaves.append(jax.device_put(arr.astype(tmpl.dtype), shard_flat[k]))
            else:
                leaves.append(jax.numpy.asarray(arr.astype(tmpl.dtype)))
        # rebuild in template order
        flat_sorted_keys = sorted(flat_t)
        _, treedef2 = _flatten_with_paths(state_template)
        key_to_leaf = dict(zip(flat_sorted_keys, leaves))
        flat_all, td = _flatten_with_paths(state_template)
        ordered = [key_to_leaf[k] for k in flat_all]
        with open(os.path.join(path, "extra.json")) as f:
            extra = json.load(f)
        return jax.tree_util.tree_unflatten(td, ordered), s, extra
