"""Perf-regression gate: committed ``BENCH_*.json`` baselines as contracts.

The benchmark harness writes three JSON artifacts per run —
``BENCH_query.json``, ``BENCH_mutation.json``, ``BENCH_serving.json`` — and a
baseline of each (produced by a seeded ``benchmarks/run.py --smoke`` pass) is
committed at the repo root.  Until this module they were write-only: a qps
regression or a silently-disarmed pruning path only got caught if a human
read the artifact diff.  ``tools/bench_gate.py`` drives the functions here in
CI to make them enforced contracts:

1. **Workload stamps** must match: a fresh report produced at a different
   dataset / codec / backend / size than its baseline is not comparable —
   the gate refuses (rather than green-lighting) the comparison.
2. **Throughput ratios**: every ``*qps*`` leaf shared by fresh and baseline
   must satisfy ``fresh >= baseline * min_ratio``.  ``min_ratio`` comes from
   the committed ``BENCH_tolerances.json`` next to the baselines (default
   0.55 — same-machine run-to-run noise is well inside that, while a true
   2x regression lands at ratio 0.5 and fails; the gate's ``--self-test``
   proves exactly that by synthesizing one).
3. **Hard invariants** on the fresh report — deterministic structural
   guarantees, never subject to tolerance: the resident paths' zero
   per-round host syncs (``cand_syncs == 0`` / ``score_syncs == 0``),
   block-max pruning armed under 1% tombstones (``blocks_pruned > 0``),
   per-batch decode dedup (``decodes_per_hot_block <= 1``), zero cross-shard
   round syncs, zero Poisson shed, and bitwise serving parity.

Timings vary between runs; the workload does not (fixed RNG seeds), which is
what makes 2 and 3 sound.
"""

from __future__ import annotations

import copy
import dataclasses
import fnmatch
import json
import os

# the artifacts under contract: (kind, filename, workload-stamp keys)
ARTIFACTS = (
    ("query", "BENCH_query.json",
     ("dataset", "codec", "backend", "n_queries")),
    ("mutation", "BENCH_mutation.json",
     ("dataset", "codec", "backend", "n_queries", "n_docs", "n_delta_docs")),
    ("serving", "BENCH_serving.json",
     ("dataset", "codec", "backend", "n_requests", "rate_qps",
      "deadline_ms")),
)

DEFAULT_MIN_RATIO = 0.55
TOLERANCES_FILE = "BENCH_tolerances.json"


@dataclasses.dataclass(frozen=True)
class Violation:
    artifact: str
    kind: str           # "workload" | "ratio" | "invariant"
    path: str
    detail: str

    def __str__(self):
        return f"[{self.artifact}:{self.kind}] {self.path}: {self.detail}"


@dataclasses.dataclass
class GateResult:
    violations: list
    checked_ratios: int = 0
    checked_invariants: int = 0

    @property
    def passed(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        head = (f"bench gate: {self.checked_ratios} ratio(s) + "
                f"{self.checked_invariants} invariant(s) checked, "
                f"{len(self.violations)} violation(s)")
        return "\n".join([head] + [f"  FAIL {v}" for v in self.violations])


# --------------------------------------------------------------------------- #
# qps-leaf discovery + tolerances
# --------------------------------------------------------------------------- #

def iter_qps_leaves(report, _path=()):
    """Yield ``(dotted_path, value)`` for every numeric leaf whose path
    names a throughput metric (a component containing ``qps``) — the set of
    ratio-gated metrics.  Latency percentiles are deliberately not gated by
    default (tail latencies on shared CI runners are too noisy for a hard
    floor); add explicit patterns to the tolerances file to gate more."""
    if isinstance(report, dict):
        for k in sorted(report):
            yield from iter_qps_leaves(report[k], _path + (str(k),))
    elif isinstance(report, (int, float)) and not isinstance(report, bool):
        if any("qps" in comp for comp in _path):
            yield ".".join(_path), float(report)


def load_tolerances(path: str) -> dict:
    """``BENCH_tolerances.json``: ``{"defaults": {"min_ratio": ...},
    "overrides": [{"artifact": ..., "pattern": ..., "min_ratio": ...}]}``.
    Missing file -> library defaults."""
    if path is None or not os.path.exists(path):
        return {"defaults": {"min_ratio": DEFAULT_MIN_RATIO}, "overrides": []}
    with open(path) as f:
        tol = json.load(f)
    tol.setdefault("defaults", {}).setdefault("min_ratio", DEFAULT_MIN_RATIO)
    tol.setdefault("overrides", [])
    return tol


def min_ratio_for(tol: dict, artifact: str, path: str) -> float:
    """The floor for one metric: the last matching override wins, else the
    default.  ``min_ratio: 0`` disables the metric's ratio check."""
    r = float(tol["defaults"]["min_ratio"])
    for ov in tol["overrides"]:
        if ov.get("artifact") not in (None, artifact):
            continue
        if fnmatch.fnmatchcase(path, ov.get("pattern", "*")):
            r = float(ov.get("min_ratio", r))
    return r


# --------------------------------------------------------------------------- #
# the three checks
# --------------------------------------------------------------------------- #

def check_workload(artifact: str, keys: tuple, fresh: dict,
                   baseline: dict) -> list:
    out = []
    for k in keys:
        fv, bv = fresh.get(k), baseline.get(k)
        if fv != bv:
            out.append(Violation(
                artifact, "workload", k,
                f"fresh={fv!r} baseline={bv!r} — reports are not comparable "
                f"(regenerate the committed baseline at the CI workload)"))
    return out


def compare_reports(artifact: str, fresh: dict, baseline: dict,
                    tol: dict) -> tuple:
    """Ratio-gate every qps leaf present in BOTH reports.  Returns
    (violations, n_checked).  Leaves only one side has (a new benchmark
    section mid-PR) are skipped — the next baseline refresh picks them up."""
    base = dict(iter_qps_leaves(baseline))
    out, n = [], 0
    for path, fv in iter_qps_leaves(fresh):
        bv = base.get(path)
        if bv is None or bv <= 0.0:
            continue
        floor = min_ratio_for(tol, artifact, path)
        if floor <= 0.0:
            continue
        n += 1
        ratio = fv / bv
        if ratio < floor:
            out.append(Violation(
                artifact, "ratio", path,
                f"fresh {fv:.1f} / baseline {bv:.1f} = {ratio:.3f}x "
                f"< min_ratio {floor}"))
    return out, n


def _get(d: dict, *path):
    for p in path:
        if not isinstance(d, dict) or p not in d:
            return None
        d = d[p]
    return d


def check_invariants(artifact: str, fresh: dict) -> tuple:
    """The deterministic structural guarantees on a fresh report (never
    subject to tolerance).  Returns (violations, n_checked)."""
    out, n = [], 0

    def req(cond, path, detail):
        nonlocal n
        n += 1
        if not cond:
            out.append(Violation(artifact, "invariant", path, detail))

    if artifact == "query":
        d = _get(fresh, "decodes_per_hot_block")
        if d is not None:
            req(d <= 1.0 + 1e-9, "decodes_per_hot_block",
                f"{d} > 1: a hot (term, block) decoded more than once per "
                f"batch (work-list dedup regressed)")
        for pl in ("device", "fused"):
            s = _get(fresh, "placements", pl, "host_syncs_per_query")
            if s is not None:
                req(s == 0, f"placements.{pl}.host_syncs_per_query",
                    f"{s} != 0: resident AND rounds synced candidates")
        for mode in ("or", "and_scored"):
            s = _get(fresh, "ranked", mode, "host_syncs_per_query")
            if s is not None:
                req(s == 0, f"ranked.{mode}.host_syncs_per_query",
                    f"{s} != 0: resident ranked rounds synced scores")
        p = _get(fresh, "ranked", "or", "blocks_pruned")
        if p is not None:
            req(p > 0, "ranked.or.blocks_pruned",
                "0: block-max pruning disarmed on the OR path")
        for nsh, cell in (fresh.get("sharded") or {}).items():
            s = _get(cell, "cross_shard_round_syncs")
            if s is not None:
                req(s == 0, f"sharded.{nsh}.cross_shard_round_syncs",
                    f"{s} != 0: shard rounds crossed the doc partition")
    elif artifact == "mutation":
        for dens, cell in (fresh.get("tombstone_qps") or {}).items():
            req(_get(cell, "cand_syncs") == 0,
                f"tombstone_qps.{dens}.cand_syncs",
                f"{_get(cell, 'cand_syncs')} != 0: tombstone gating left "
                f"the device")
        r = fresh.get("ranked_tomb_1pct") or {}
        req(_get(r, "score_syncs") == 0, "ranked_tomb_1pct.score_syncs",
            f"{_get(r, 'score_syncs')} != 0")
        req((_get(r, "blocks_pruned") or 0) > 0,
            "ranked_tomb_1pct.blocks_pruned",
            "0: block-max pruning disarmed under the 1% tombstone epoch "
            "(the idf-ratio re-arm regressed)")
    elif artifact == "serving":
        for arrival, cells in (fresh.get("arrivals") or {}).items():
            for pl, cell in cells.items():
                if arrival == "poisson":
                    req(_get(cell, "shed_rate") == 0.0,
                        f"arrivals.poisson.{pl}.shed_rate",
                        f"{_get(cell, 'shed_rate')} != 0: the Poisson smoke "
                        f"load shed requests the engine had budget for")
                req(_get(cell, "parity_ok") is True,
                    f"arrivals.{arrival}.{pl}.parity_ok",
                    "served results diverged from the offline "
                    "plan/execute oracle")
    return out, n


# --------------------------------------------------------------------------- #
# the gate + the self-test synthesizer
# --------------------------------------------------------------------------- #

def load_report(path: str):
    with open(path) as f:
        return json.load(f)


def run_gate(fresh_dir: str, baseline_dir: str,
             tolerances_path: str = None, artifacts=None) -> GateResult:
    """Gate every artifact present in ``baseline_dir`` against its fresh
    counterpart in ``fresh_dir``.  A committed baseline whose fresh file is
    missing is a violation (the benchmark that produces it stopped
    running); a fresh file with no baseline is skipped."""
    if tolerances_path is None:
        tolerances_path = os.path.join(baseline_dir, TOLERANCES_FILE)
    tol = load_tolerances(tolerances_path)
    res = GateResult(violations=[])
    for kind, fname, stamp_keys in (artifacts or ARTIFACTS):
        bpath = os.path.join(baseline_dir, fname)
        fpath = os.path.join(fresh_dir, fname)
        if not os.path.exists(bpath):
            continue
        if not os.path.exists(fpath):
            res.violations.append(Violation(
                kind, "workload", fname,
                f"baseline committed but no fresh report at {fpath}"))
            continue
        fresh, baseline = load_report(fpath), load_report(bpath)
        res.violations += check_workload(kind, stamp_keys, fresh, baseline)
        v, n = compare_reports(kind, fresh, baseline, tol)
        res.violations += v
        res.checked_ratios += n
        v, n = check_invariants(kind, fresh)
        res.violations += v
        res.checked_invariants += n
    return res


def synthesize_regression(report: dict, factor: float = 0.5) -> dict:
    """A deep copy of ``report`` with every ratio-gated qps leaf (exactly
    the :func:`iter_qps_leaves` set) scaled by ``factor`` — the gate
    self-test's synthetic 2x regression (``factor=0.5``).  Workload stamps
    and invariant fields are untouched, so only ratio checks should fire.
    Operates on JSON-loaded reports (string keys throughout)."""
    out = copy.deepcopy(report)
    for path, _ in iter_qps_leaves(report):
        comps = path.split(".")
        node = out
        for c in comps[:-1]:
            node = node[c]
        node[comps[-1]] = node[comps[-1]] * factor
    return out
