"""Unified observability: span tracing, typed metrics, perf-regression gate.

The serving stack spans admission -> batcher -> plan -> resident rounds ->
collectives -> rescore across shards and placements; this package is the one
place all of it reports to:

``obs.trace``
    Lightweight span tracer (context-manager API, monotonic clocks,
    parent/child nesting, thread-safe) plus a Chrome trace-event exporter —
    ``to_chrome_trace()`` output loads directly in Perfetto.  Deep engine /
    kernel spans follow the process-global tracer (disabled by default; the
    disabled path is a single attribute check), while ``IndexServer`` keeps
    its own always-on tracer for the request lifecycle — the five-stamp
    ``TraceRecord`` is a view over those spans.

``obs.metrics``
    Typed counter / gauge / histogram registry with ``(engine, shard,
    placement, mode, codec)`` labels, Prometheus text exposition, and
    ``scoped()`` delta sampling.  ``QueryEngine.dev_stats`` is a read-only
    compatibility view over the engine's registry.

``obs.regress``
    The CI perf-regression gate: diff freshly produced ``BENCH_*.json``
    reports against the committed baselines with per-metric tolerances and
    hard invariants (driven by ``tools/bench_gate.py``).

See ``repro/index/__init__.py`` for the full observability walkthrough
(span taxonomy, metric names, opening a trace in Perfetto, gate tolerances).
"""

from .trace import (Span, Tracer, get_tracer, set_tracer, enable_tracing,
                    to_chrome_trace, trace_coverage)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      DevStatsView, nearest_rank, LABEL_KEYS)
from .regress import (GateResult, Violation, compare_reports,
                      check_invariants, run_gate, synthesize_regression)

__all__ = [
    "Span", "Tracer", "get_tracer", "set_tracer", "enable_tracing",
    "to_chrome_trace", "trace_coverage",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "DevStatsView",
    "nearest_rank", "LABEL_KEYS",
    "GateResult", "Violation", "compare_reports", "check_invariants",
    "run_gate", "synthesize_regression",
]
