"""Span tracer for the serving stack, with a Perfetto-loadable exporter.

One :class:`Tracer` holds a bounded buffer of finished :class:`Span` records.
Spans are stamped with ``time.monotonic()`` — the same clock family as every
``TraceRecord`` stage stamp in ``repro.index.serve``, so server lifecycle
spans and deep engine spans land on one comparable timeline.

Two usage styles:

* **Context manager** (nesting tracked per thread)::

      with tracer.span("and/round", lane="engine", r=2):
          ...                         # children opened here nest under it

* **Detached begin/end** for spans that cross threads or whose endpoints are
  externally stamped (the serving request lifecycle: a request span begins
  on the event loop at admission and ends on the executor thread at
  delivery)::

      sp = tracer.begin("serve/request", lane="serve", rid=7)
      ...
      tracer.end(sp, outcome="served")

The **disabled fast path** costs one attribute check: ``span()`` returns a
shared no-op context manager and ``begin()/end()`` return/accept ``None``.
Deep engine and kernel span sites go through the process-global tracer
(:func:`get_tracer`), disabled by default, so the serving hot path is
untouched unless tracing is explicitly enabled (``enable_tracing()`` or
``launch.serve --trace-out``).

**Fenced device timing** (off by default): ``tracer.fenced = True`` makes
``tracer.fence(x)`` call ``jax.block_until_ready`` inside round spans, so a
span's duration attributes device wall-clock to the kernel that produced it
instead of to whichever later op happens to force the value.  For real-TPU
runs, :meth:`Tracer.profiler` brackets a region with ``jax.profiler.trace``.

Span taxonomy (the names emitted across the stack):

=====================  =====================================================
``serve/request``      admission -> delivery, one per request (detached)
``serve/close``        batch forming: seed pop -> batch close
``serve/batch``        batch close -> results stamped (executor thread)
``serve/plan``         ``engine.plan`` inside a served batch
``serve/execute``      ``engine.execute`` inside a served batch
``serve/deliver``      result split + trace records inside a served batch
``engine/plan``        plan resolution (any caller)
``engine/execute``     planned execution (any caller)
``and/seed``           resident AND round 0 (seed scatter)
``and/round``          one resident AND round (args: r, plain/fused/dense)
``and/tomb_gate``      live-bitmap AND of the seed (tombstone gating)
``ranked/round``       one ranked accumulate round (args: r, splits)
``ranked/tomb_gate``   OR-mode live-row gate upload
``ranked/rescore``     the exact float tail
``sharded/merge``      the one top-k merge collective per ranked batch
``decode/<codec>``     one per-codec arena decode call (work-list group)
``kernel/extract_ids`` final bitmap -> sorted docid extraction
``kernel/topk``        k-th threshold / top-k stats reduction
=====================  =====================================================

Engine spans carry ``lane="engine"`` (sub-engines: ``shard0``, ``shard1``,
...), serving spans ``lane="serve"``, arena decodes ``lane="device"`` — the
exporter gives each lane its own named track.
"""

from __future__ import annotations

import contextlib
import itertools
import threading
import time

_now = time.monotonic


class Span:
    """One finished (or in-flight) span.  ``t1`` is None until ended."""

    __slots__ = ("sid", "name", "lane", "t0", "t1", "parent_sid", "args")

    def __init__(self, sid: int, name: str, lane: str, t0: float,
                 parent_sid: int, args: dict):
        self.sid = sid
        self.name = name
        self.lane = lane
        self.t0 = t0
        self.t1 = None
        self.parent_sid = parent_sid
        self.args = args

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def __repr__(self):
        return (f"Span({self.name!r}, lane={self.lane!r}, sid={self.sid}, "
                f"parent={self.parent_sid}, t0={self.t0:.6f}, "
                f"dur={self.dur * 1e3:.3f}ms)")


class _Noop:
    """Shared do-nothing context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NOOP = _Noop()


class _SpanCM:
    """Context-manager span: nesting tracked on the tracer's per-thread
    stack, so children opened inside automatically parent to it."""

    __slots__ = ("_tr", "_name", "_lane", "_args", "_span")

    def __init__(self, tr: "Tracer", name: str, lane: str, args: dict):
        self._tr = tr
        self._name = name
        self._lane = lane
        self._args = args
        self._span = None

    def __enter__(self) -> Span:
        tr = self._tr
        stack = tr._stack()
        parent = stack[-1].sid if stack else 0
        sp = Span(next(tr._ids), self._name, self._lane, _now(), parent,
                  self._args)
        stack.append(sp)
        self._span = sp
        return sp

    def __exit__(self, *exc):
        sp = self._span
        sp.t1 = _now()
        stack = self._tr._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        self._tr._record(sp)
        return False


class Tracer:
    """Bounded, thread-safe span collector (see the module docstring)."""

    def __init__(self, enabled: bool = False, max_spans: int = 200_000,
                 fenced: bool = False):
        self.enabled = enabled
        self.fenced = fenced
        self.max_spans = max_spans
        self.dropped = 0
        self._spans: list[Span] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._ids = itertools.count(1)

    # ---- recording ------------------------------------------------------- #

    def _stack(self) -> list:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _record(self, sp: Span) -> None:
        with self._lock:
            if len(self._spans) < self.max_spans:
                self._spans.append(sp)
            else:
                self.dropped += 1

    def span(self, name: str, lane: str = "main", **args):
        """A context-manager span; no-op (and allocation-free beyond the
        call itself) when the tracer is disabled."""
        if not self.enabled:
            return _NOOP
        return _SpanCM(self, name, lane, args)

    def begin(self, name: str, lane: str = "main", parent: Span = None,
              t0: float = None, **args):
        """Open a detached span (not on the nesting stack — safe to end from
        another thread).  ``t0`` overrides the start stamp for spans whose
        boundary was clocked elsewhere.  Returns None when disabled."""
        if not self.enabled:
            return None
        sp = Span(next(self._ids), name, lane, _now() if t0 is None else t0,
                  parent.sid if parent is not None else 0, args)
        return sp

    def end(self, sp, t1: float = None, **args) -> None:
        """Close a span from :meth:`begin` (None-safe).  ``t1`` overrides
        the end stamp; extra kwargs merge into the span's args."""
        if sp is None:
            return
        sp.t1 = _now() if t1 is None else t1
        if args:
            sp.args.update(args)
        self._record(sp)

    # ---- device fencing --------------------------------------------------- #

    def fence(self, *values) -> None:
        """With ``fenced`` sampling on, block until the given device values
        are ready, so the enclosing span's duration is the kernel's true
        wall-clock rather than async-dispatch time.  A no-op otherwise —
        the resident paths' zero-sync discipline is untouched by default."""
        if not (self.enabled and self.fenced):
            return
        import jax
        for v in values:
            if v is not None:
                jax.block_until_ready(v)

    def profiler(self, logdir=None):
        """Context manager bracketing a region with ``jax.profiler.trace``
        (the real-TPU hook).  Null when disabled or no ``logdir``."""
        if not self.enabled or logdir is None:
            return contextlib.nullcontext()
        import jax
        return jax.profiler.trace(str(logdir))

    # ---- access ----------------------------------------------------------- #

    def spans(self) -> list:
        """Snapshot of the finished spans (chronological by completion)."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0


# process-global tracer for deep engine / kernel spans; disabled by default
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    global _TRACER
    _TRACER = tracer
    return tracer


def enable_tracing(enabled: bool = True, fenced: bool = False) -> Tracer:
    """Toggle the process-global tracer (engine + kernel spans)."""
    _TRACER.enabled = enabled
    _TRACER.fenced = fenced
    return _TRACER


# --------------------------------------------------------------------------- #
# Chrome trace-event export (Perfetto-loadable)
# --------------------------------------------------------------------------- #

def _iter_spans(sources) -> list:
    out = []
    for src in sources:
        out.extend(src.spans() if isinstance(src, Tracer) else src)
    return [sp for sp in out if sp.t1 is not None]


def to_chrome_trace(*sources) -> dict:
    """Export spans (from :class:`Tracer` objects and/or span iterables)
    as Chrome trace-event JSON — load the dumped file directly at
    https://ui.perfetto.dev.

    Schema (the documented contract ``tests/test_obs.py`` round-trips):

    * top level: ``{"traceEvents": [...], "displayTimeUnit": "ms"}``
    * one complete event (``"ph": "X"``) per span: ``name``, ``cat`` (the
      span name's first ``/`` segment), ``ts`` / ``dur`` (microseconds,
      ``ts`` relative to the earliest span), ``pid`` (always 1), ``tid``
      (one lane — shard / placement / serve — per thread track), and
      ``args`` carrying the span's kwargs plus ``sid`` / ``parent_sid``.
    * one metadata event (``"ph": "M"``) naming the process and each lane's
      thread track.
    """
    spans = _iter_spans(sources)
    events = [{"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
               "args": {"name": "repro-index-serving"}}]
    lanes = sorted({sp.lane for sp in spans})
    tid_of = {lane: i + 1 for i, lane in enumerate(lanes)}
    for lane in lanes:
        events.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": tid_of[lane], "args": {"name": lane}})
    t_base = min((sp.t0 for sp in spans), default=0.0)
    for sp in sorted(spans, key=lambda s: s.t0):
        args = {str(k): v for k, v in sp.args.items()}
        args["sid"] = sp.sid
        args["parent_sid"] = sp.parent_sid
        events.append({
            "name": sp.name,
            "cat": sp.name.split("/", 1)[0],
            "ph": "X",
            "ts": round((sp.t0 - t_base) * 1e6, 3),
            "dur": round(sp.dur * 1e6, 3),
            "pid": 1,
            "tid": tid_of[sp.lane],
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def trace_coverage(spans, parent: str = "serve/batch",
                   children: tuple = ("serve/plan", "serve/execute",
                                      "serve/deliver")) -> float:
    """Fraction of total ``parent``-span wall-clock covered by the given
    child span names (children attributed by ``parent_sid``).  The smoke
    gate asserts this >= 0.9: the exported trace accounts for at least 90%
    of measured batch wall-clock."""
    spans = _iter_spans([spans])
    parents = {sp.sid: sp for sp in spans if sp.name == parent}
    total = sum(sp.dur for sp in parents.values())
    if total <= 0.0:
        return 0.0
    covered = sum(sp.dur for sp in spans
                  if sp.name in children and sp.parent_sid in parents)
    return min(covered / total, 1.0)
