"""Typed counter / gauge / histogram registry with Prometheus exposition.

Replaces the free-form ``dev_stats`` dict on :class:`~repro.index.engine
.QueryEngine` (kept as a read-only compatibility view, :class:`DevStatsView`)
and backs :class:`~repro.index.serve.ServerStats`'s exposition.

Design points:

* **Typed metrics.**  A :class:`MetricsRegistry` owns named metrics, each
  one of three kinds: :class:`Counter` (monotone ``inc``), :class:`Gauge`
  (``set``), :class:`Histogram` (``observe`` into fixed buckets).
  Registering the same name twice raises — the registry lint
  (``tools/registry_lint.py lint_metrics``) checks that, plus snake_case
  names and consistent label sets across engine instances.

* **Labels.**  The label vocabulary is fixed: :data:`LABEL_KEYS` =
  ``(engine, shard, placement, mode, codec, tenant, outcome)``.  A registry
  carries constant labels (e.g. ``engine="q3", shard="s1"``) stamped on
  every exposition line; individual metrics may declare extra per-sample
  label names (e.g. a latency histogram labelled by ``placement``).

* **Scoped sampling.**  Counters accumulate for the life of their owner —
  there is deliberately no ``reset()`` (resetting under a live server would
  tear half-formed deltas).  Per-call assertions use ``scoped()``::

      with engine.metrics.scoped() as s:
          engine.execute(plan)
      assert s.delta("worklist_decodes") == 0

* **Prometheus text exposition.**  ``to_prometheus()`` renders the 0.0.4
  text format (``# HELP`` / ``# TYPE`` + one line per label set; histograms
  expose ``_bucket`` / ``_sum`` / ``_count``), wired into
  ``ServerStats.snapshot(prometheus=True)`` and ``launch.serve
  --metrics-out``.
"""

from __future__ import annotations

import math
import threading
from typing import Mapping

# the full label vocabulary — lint rejects metrics labelled outside it
LABEL_KEYS = ("engine", "shard", "placement", "mode", "codec", "tenant",
              "outcome")

_DEFAULT_BUCKETS = (0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                    500.0, 1000.0, 2500.0, float("inf"))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple,
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._vals: dict = {}

    def _key(self, labels: Mapping) -> tuple:
        if labels and set(labels) - set(self.labelnames):
            extra = sorted(set(labels) - set(self.labelnames))
            raise ValueError(
                f"metric {self.name!r} has no label(s) {extra}; declared: "
                f"{list(self.labelnames)}")
        return tuple(str(labels.get(k, "")) for k in self.labelnames)

    def samples(self) -> list:
        """[(labels_tuple, value)] snapshot."""
        with self._lock:
            return list(self._vals.items())

    def total(self) -> float:
        """Sum across label sets (counters/gauges)."""
        with self._lock:
            return sum(self._vals.values())


class Counter(_Metric):
    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease by {n}")
        key = self._key(labels)
        with self._lock:
            self._vals[key] = self._vals.get(key, 0) + n

    def value(self, **labels) -> float:
        with self._lock:
            return self._vals.get(self._key(labels), 0)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        with self._lock:
            self._vals[self._key(labels)] = v

    def value(self, **labels) -> float:
        with self._lock:
            return self._vals.get(self._key(labels), 0)


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets=_DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        b = tuple(float(x) for x in buckets)
        if list(b) != sorted(b) or b[-1] != float("inf"):
            raise ValueError(
                f"histogram {name!r} buckets must be ascending and end at "
                f"+Inf, got {b}")
        self.buckets = b

    def observe(self, v: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            st = self._vals.get(key)
            if st is None:
                st = self._vals[key] = {
                    "counts": [0] * len(self.buckets), "sum": 0.0, "n": 0}
            for i, ub in enumerate(self.buckets):
                if v <= ub:
                    st["counts"][i] += 1
                    break
            st["sum"] += v
            st["n"] += 1

    def total(self) -> float:
        with self._lock:
            return sum(st["n"] for st in self._vals.values())


class ScopedSample:
    """Counter deltas over a ``with`` block (or since entry, if still
    open) — the replacement for hand-rolled before/after subtraction."""

    def __init__(self, registry: "MetricsRegistry"):
        self._reg = registry
        self._start: dict = {}
        self._end: dict = None

    def _totals(self) -> dict:
        return {name: m.total() for name, m in self._reg.metrics().items()
                if m.kind == "counter"}

    def __enter__(self) -> "ScopedSample":
        self._start = self._totals()
        self._end = None
        return self

    def __exit__(self, *exc):
        self._end = self._totals()
        return False

    def delta(self, name: str) -> float:
        """Counter ``name``'s increase across the scope (current value if
        the scope is still open; 0 baseline for counters created inside)."""
        end = self._end if self._end is not None else self._totals()
        if name not in end:
            raise KeyError(f"no counter {name!r} in registry "
                           f"{self._reg.describe()}")
        d = end[name] - self._start.get(name, 0)
        return int(d) if float(d).is_integer() else d

    def deltas(self) -> dict:
        end = self._end if self._end is not None else self._totals()
        return {k: v - self._start.get(k, 0) for k, v in end.items()}


class MetricsRegistry:
    """One owner's metric namespace (an engine, a server).  ``const_labels``
    are stamped on every exposition line; per-metric ``labelnames`` add
    sample-time dimensions.  Duplicate registration raises."""

    def __init__(self, namespace: str = "repro",
                 const_labels: Mapping = None):
        self.namespace = namespace
        self.const_labels = dict(const_labels or {})
        bad = set(self.const_labels) - set(LABEL_KEYS)
        if bad:
            raise ValueError(f"unknown const label(s) {sorted(bad)}; "
                             f"vocabulary: {LABEL_KEYS}")
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def describe(self) -> str:
        lbl = ",".join(f"{k}={v}" for k, v in sorted(self.const_labels.items()))
        return f"{self.namespace}{{{lbl}}}"

    def relabel(self, **const_labels) -> "MetricsRegistry":
        """Update constant labels (e.g. stamping a sub-engine's shard)."""
        bad = set(const_labels) - set(LABEL_KEYS)
        if bad:
            raise ValueError(f"unknown const label(s) {sorted(bad)}; "
                             f"vocabulary: {LABEL_KEYS}")
        self.const_labels.update(const_labels)
        return self

    def _register(self, cls, name: str, help: str, labelnames: tuple,
                  **kw) -> _Metric:
        bad = set(labelnames) - set(LABEL_KEYS)
        if bad:
            raise ValueError(f"metric {name!r} labelled outside the "
                             f"vocabulary: {sorted(bad)}; allowed: "
                             f"{LABEL_KEYS}")
        if name in self._metrics:
            raise ValueError(f"metric {name!r} already registered in "
                             f"{self.describe()}")
        m = cls(name, help, tuple(labelnames), self._lock, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames: tuple = (),
                  buckets=_DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    def get(self, name: str) -> _Metric:
        return self._metrics[name]

    def metrics(self) -> dict:
        return dict(self._metrics)

    def inc(self, name: str, n: float = 1, **labels) -> None:
        """Increment counter ``name`` — the engine hot-path shorthand."""
        self._metrics[name].inc(n, **labels)

    def value(self, name: str, **labels) -> float:
        m = self._metrics[name]
        if labels:
            return m.value(**labels)
        return m.total()

    def scoped(self) -> ScopedSample:
        return ScopedSample(self)

    # ---- exposition ------------------------------------------------------- #

    @staticmethod
    def _fmt_labels(pairs) -> str:
        body = ",".join(f'{k}="{v}"' for k, v in pairs if v != "")
        return f"{{{body}}}" if body else ""

    @staticmethod
    def _fmt_val(v: float) -> str:
        if v == float("inf"):
            return "+Inf"
        return repr(int(v)) if float(v).is_integer() else repr(float(v))

    def to_prometheus(self) -> str:
        """Prometheus 0.0.4 text exposition of every metric."""
        const = sorted(self.const_labels.items())
        out = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            full = f"{self.namespace}_{name}"
            out.append(f"# HELP {full} {m.help or name}")
            out.append(f"# TYPE {full} {m.kind}")
            for key, val in sorted(m.samples()):
                pairs = const + list(zip(m.labelnames, key))
                if m.kind == "histogram":
                    cum = 0
                    for ub, c in zip(m.buckets, val["counts"]):
                        cum += c
                        bl = self._fmt_labels(
                            pairs + [("le", self._fmt_val(ub))])
                        out.append(f"{full}_bucket{bl} {cum}")
                    lbl = self._fmt_labels(pairs)
                    out.append(f"{full}_sum{lbl} {self._fmt_val(val['sum'])}")
                    out.append(f"{full}_count{lbl} {val['n']}")
                else:
                    lbl = self._fmt_labels(pairs)
                    out.append(f"{full}{lbl} {self._fmt_val(val)}")
        return "\n".join(out) + "\n"

    def schema(self) -> dict:
        """{name: (kind, labelnames)} — what the lint compares across
        instances for label-set consistency."""
        return {n: (m.kind, m.labelnames) for n, m in self._metrics.items()}


class DevStatsView(Mapping):
    """Read-only mapping view over a registry's counters — the
    ``QueryEngine.dev_stats`` compatibility surface.  Reads are live
    (``view["worklist_decodes"]`` is the counter's current total);
    writes raise ``TypeError`` like any :class:`Mapping`."""

    def __init__(self, registry: MetricsRegistry, names: tuple):
        self._reg = registry
        self._names = tuple(names)

    def __getitem__(self, k: str):
        if k not in self._names:
            raise KeyError(k)
        v = self._reg.get(k).total()
        return int(v) if float(v).is_integer() else v

    def __iter__(self):
        return iter(self._names)

    def __len__(self):
        return len(self._names)

    def __repr__(self):
        return f"DevStatsView({dict(self)!r})"


def nearest_rank(sorted_vals, q: float) -> float:
    """Deterministic percentile for tiny samples: the nearest-rank method
    with clamping — ``sorted_vals[min(max(ceil(q/100 * n), 1), n) - 1]``.

    Rule (documented contract, tested at n in {1, 2, 10}):

    * never interpolates and never indexes past the sample — every returned
      value is an observed one;
    * n == 1 -> the single sample for every q;
    * monotone in q, so p50 <= p99 <= p999 always holds;
    * q = 100 -> the maximum.
    """
    n = len(sorted_vals)
    if n == 0:
        raise ValueError("nearest_rank of an empty sample")
    r = min(max(int(math.ceil(q / 100.0 * n)), 1), n)
    return float(sorted_vals[r - 1])
