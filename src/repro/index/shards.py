"""Doc-range sharding: one generation split into self-contained per-shard
generations at contiguous docid boundaries.

Sharding the serving path **doc-wise** (ROADMAP "Sharded multi-device
serving") is what keeps every per-round kernel shard-local: a doc's postings
for *every* term live in exactly one shard, so AND candidates and ranked
score accumulators never cross shards — rounds run with zero inter-device
traffic and the only collective in a batch is the final top-k merge
(``kernels/topk.topk_stats`` + ``distributed/collectives.merge_topk_stats``).

A shard is an ordinary immutable :class:`repro.index.invindex.Generation`
over the *local* docid space [0, hi - lo): postings of the parent generation
are decoded, sliced to the range, translated by -lo, and re-encoded with the
parent's codec (block structure, skip tables, dense-bitmap eligibility all
re-derived locally — a shard is exactly what a from-scratch build of its
slice would produce, geometry-wise).  What is **not** local is the
statistics: BM25 and the impact quantizer must see the parent corpus, or the
per-(term, doc) quantized codes would drift across shards and the merged
threshold would be meaningless.  :func:`shard_generation` therefore fixes up
every shard after the local build:

  * ``TermPostings.df``    := the parent's global df,
  * ``impact_bmax``        := recomputed per local block with the parent's
                              (df, n_docs, avdl) — the local doclen slice is
                              the parent's, so the floats are bitwise equal
                              to the parent's impacts for the same docs,
  * ``stat_n_docs`` / ``stat_avdl`` / ``stat_gmax`` — consumed by
    ``ScoreArena`` so shard quantization uses the parent's scale,
  * ``doc_lo`` / ``doc_hi`` / ``gid``: the global window served and the
    parent generation id (all shards of one generation share its gid; the
    registry lint checks this).

:meth:`ShardSpec.derive` picks the boundaries from build-derived metadata
only (skip tables — no decode): per-tile posting mass, balanced by
``distributed.sharding.balanced_range_bounds``, with boundaries aligned to
whole :data:`TILE_DOCS` bitmap tiles so a shard's packed-bitmap geometry
starts on a lane-tile edge.  Explicit bounds (uneven splits, deliberately
empty shards) need no alignment at all — shard-local docid spaces are
0-based, so correctness never depends on where the cuts fall.
"""

from __future__ import annotations

import numpy as np

from repro.distributed.sharding import balanced_range_bounds
from repro.kernels.bitpack import LANES

from .invindex import SKIP, Generation
from .scores import bm25_scores

TILE_DOCS = LANES * 32          # docids per (1, 128)-word bitmap tile row


class ShardSpec:
    """Contiguous doc-range partition of one generation's docid space.

    ``bounds`` is a non-decreasing int tuple ``(0, b1, ..., n_docs)``; shard
    s serves the half-open global range [bounds[s], bounds[s+1]) — possibly
    empty (repeated bounds are legal and exercised by the tests).
    """

    __slots__ = ("bounds",)

    def __init__(self, bounds):
        b = tuple(int(x) for x in bounds)
        if len(b) < 2:
            raise ValueError("ShardSpec needs at least (0, n_docs)")
        if b[0] != 0:
            raise ValueError(f"shard bounds must start at 0, got {b[0]}")
        if any(b[i] > b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"shard bounds must be non-decreasing: {b}")
        self.bounds = b

    @property
    def n_shards(self) -> int:
        return len(self.bounds) - 1

    def ranges(self) -> list:
        """[(lo, hi)] per shard, in docid order."""
        return list(zip(self.bounds[:-1], self.bounds[1:]))

    def shard_of(self, docid: int) -> int:
        """The shard serving a global docid."""
        return int(np.searchsorted(np.asarray(self.bounds), docid,
                                   side="right")) - 1

    def __repr__(self) -> str:
        return f"ShardSpec{self.bounds}"

    @classmethod
    def derive(cls, gen: Generation, n_shards: int) -> "ShardSpec":
        """Build-derived boundaries: balance per-tile posting mass read off
        the skip tables (block first/last docids + the SKIP-chunk posting
        counts — no block is decoded), then align interior cuts to whole
        bitmap tiles."""
        n_docs = gen.n_docs
        if n_shards <= 1 or n_docs <= TILE_DOCS:
            return cls((0, n_docs))
        tiles = -(-n_docs // TILE_DOCS)
        mass = np.ones(tiles, np.float64)       # smooths posting-free tiles
        for t, tp in gen.terms.items():
            nb = len(tp.blocks)
            if not nb:
                continue
            counts = np.full(nb, SKIP, np.float64)
            counts[-1] = tp.df - SKIP * (nb - 1)
            firsts = gen.block_firsts(t).astype(np.int64)
            lasts = gen.block_lasts(t).astype(np.int64)
            mid = np.minimum((firsts + lasts) // 2 // TILE_DOCS, tiles - 1)
            np.add.at(mass, mid, counts)
        cuts = balanced_range_bounds(mass, n_shards)
        bounds = [0]
        for c in cuts[1:-1]:
            bounds.append(max(bounds[-1], min(c * TILE_DOCS, n_docs)))
        bounds.append(n_docs)
        return cls(bounds)


def shard_generation(gen: Generation, lo: int, hi: int) -> Generation:
    """One shard of ``gen``: a self-contained Generation over the local docid
    space [0, hi - lo), statistics fixed up to the parent's (see module
    docstring).  ``hi > lo`` required — empty ranges get no generation."""
    if not 0 <= lo < hi <= gen.n_docs:
        raise ValueError(f"bad shard range [{lo}, {hi}) for n_docs={gen.n_docs}")
    sub_post: dict = {}
    for t in gen.terms:
        ids, tfs = gen.decode_term(t, min_docid=lo)
        m = (ids >= lo) & (ids < hi)
        if not m.any():
            continue
        sub_post[t] = ((ids[m] - np.uint32(lo)).astype(np.uint32),
                       tfs[m].astype(np.uint32))
    sub_dl = np.asarray(gen.doclen)[lo:hi]
    sg = Generation.build(sub_dl, sub_post, codec=gen.codec, gid=gen.gid)
    # parent-statistics fixup: global df, block maxima at global stats, and
    # the quantizer pins ScoreArena consumes via getattr
    n_docs, avdl = gen.n_docs, gen.avdl
    gmax = 0.0
    for t in gen.terms:
        gmax = max(gmax, float(gen.impact_block_max(t).max(initial=0.0)))
    for t, (ids, tfs) in sub_post.items():
        tp = sg.terms[t]
        gdf = gen.terms[t].df
        bmax = []
        for i in range(0, len(ids), SKIP):
            sc = bm25_scores(tfs[i:i + SKIP], sub_dl[ids[i:i + SKIP]], gdf,
                             n_docs, avdl)
            bmax.append(float(sc.max(initial=0.0)))
        tp.df = gdf
        tp.impact_bmax = np.asarray(bmax, np.float64)
    sg.stat_n_docs = n_docs
    sg.stat_avdl = avdl
    sg.stat_gmax = gmax
    sg.doc_lo, sg.doc_hi = int(lo), int(hi)
    return sg
