"""Query evaluation over the compressed index (paper §7.4).

AND queries: ascending-df intersection with block skipping; OR queries: BM25
DAAT accumulation with top-k heap (k=10).  Decoding d-gaps and TFs dominates
the codec-dependent cost, exactly as in the paper (15-35% of total)."""

from __future__ import annotations

import heapq

import numpy as np

from .invindex import InvertedIndex

K1, B = 1.2, 0.75


def and_query(idx: InvertedIndex, terms: list) -> np.ndarray:
    terms = sorted((t for t in terms if t in idx.terms), key=lambda t: idx.terms[t].df)
    if not terms:
        return np.zeros(0, np.uint32)
    ids, _ = idx.decode_term(terms[0])
    for t in terms[1:]:
        if len(ids) == 0:
            break
        cand, _ = idx.decode_term(t, min_docid=int(ids[0]))
        ids = ids[np.isin(ids, cand, assume_unique=True)]
    return ids


def bm25_scores(idx: InvertedIndex, t: int):
    ids, tfs = idx.decode_term(t)
    df = idx.terms[t].df
    idf = np.log(1.0 + (idx.n_docs - df + 0.5) / (df + 0.5))
    dl = idx.doclen[ids]
    avdl = idx.doclen.mean()
    tf = tfs.astype(np.float64)
    return ids, idf * tf * (K1 + 1) / (tf + K1 * (1 - B + B * dl / avdl))


def or_query(idx: InvertedIndex, terms: list, k: int = 10):
    acc = {}
    for t in terms:
        if t not in idx.terms:
            continue
        ids, sc = bm25_scores(idx, t)
        for d, s in zip(ids.tolist(), sc.tolist()):
            acc[d] = acc.get(d, 0.0) + s
    return heapq.nlargest(k, acc.items(), key=lambda kv: kv[1])


def and_query_scored(idx: InvertedIndex, terms: list, k: int = 10):
    docs = and_query(idx, terms)
    if len(docs) == 0:
        return []
    scores = np.zeros(len(docs))
    for t in terms:
        if t not in idx.terms:
            continue
        ids, sc = bm25_scores(idx, t)
        pos = np.searchsorted(ids, docs)
        pos = np.clip(pos, 0, len(ids) - 1)
        hit = ids[pos] == docs
        scores += np.where(hit, sc[pos], 0.0)
    order = np.argsort(-scores)[:k]
    return [(int(docs[i]), float(scores[i])) for i in order]
