"""One-shot query evaluation over the compressed index (paper §7.4).

AND queries: ascending-df fused decode-and-intersect (skip-table block
pruning + the vectorized intersection kernels in ``repro.kernels.intersect``);
OR queries: BM25 DAAT accumulation with top-k (k=10).  These helpers are
stateless — each call runs on an uncached :class:`repro.index.engine.
QueryEngine`.  For batched serving (many queries, shared decoded-block LRU)
use ``QueryEngine``/``QueryBatch`` directly.

``and_query_ref`` keeps the seed scalar path (full per-term decode +
``np.isin``) as the correctness/throughput baseline.
"""

from __future__ import annotations

import numpy as np

from .engine import K1, B, QueryEngine  # noqa: F401  (re-export BM25 constants)
from .invindex import InvertedIndex


def _engine(idx: InvertedIndex) -> QueryEngine:
    return QueryEngine(idx, cache_blocks=0, cache_score_terms=0)


def and_query(idx: InvertedIndex, terms: list) -> np.ndarray:
    return _engine(idx).and_query(terms)


def or_query(idx: InvertedIndex, terms: list, k: int = 10):
    return _engine(idx).or_query(terms, k)


def and_query_scored(idx: InvertedIndex, terms: list, k: int = 10):
    return _engine(idx).and_query_scored(terms, k)


def bm25_scores(idx: InvertedIndex, t: int):
    return _engine(idx).term_scores(t)


def and_query_ref(idx: InvertedIndex, terms: list) -> np.ndarray:
    """Seed baseline: full decode per term + scalar ``np.isin`` intersection."""
    terms = sorted((t for t in terms if t in idx.terms), key=lambda t: idx.terms[t].df)
    if not terms:
        return np.zeros(0, np.uint32)
    ids, _ = idx.decode_term(terms[0])
    for t in terms[1:]:
        if len(ids) == 0:
            break
        cand, _ = idx.decode_term(t, min_docid=int(ids[0]))
        ids = ids[np.isin(ids, cand, assume_unique=True)]
    return ids
