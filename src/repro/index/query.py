"""One-shot query evaluation over the compressed index (paper §7.4).

Deprecated shims: each helper builds an uncached
:class:`repro.index.engine.QueryEngine`, resolves an
:class:`repro.index.engine.ExecutionPlan` for its single query, and executes
it — results are bit-identical to planning explicitly.  For batched serving
(many queries, shared decoded-block LRU) use ``QueryEngine.plan`` /
``execute`` directly; see the migration note in ``repro/index/__init__.py``.

``and_query_ref`` keeps the seed scalar path (full per-term decode +
``np.isin``) as the correctness/throughput baseline.
"""

from __future__ import annotations

import numpy as np

from .engine import K1, B, QueryBatch, QueryEngine  # noqa: F401  (re-export BM25 constants)
from .invindex import InvertedIndex


def _engine(idx: InvertedIndex) -> QueryEngine:
    return QueryEngine(idx, cache_blocks=0, cache_score_terms=0)


def _run_one(idx: InvertedIndex, terms: list, mode: str, k: int = 10):
    eng = _engine(idx)
    return eng.execute(eng.plan(QueryBatch([list(terms)], mode=mode, k=k)))[0]


def and_query(idx: InvertedIndex, terms: list) -> np.ndarray:
    return _run_one(idx, terms, "and")


def or_query(idx: InvertedIndex, terms: list, k: int = 10):
    return _run_one(idx, terms, "or", k)


def and_query_scored(idx: InvertedIndex, terms: list, k: int = 10):
    return _run_one(idx, terms, "and_scored", k)


def bm25_scores(idx: InvertedIndex, t: int):
    return _engine(idx).term_scores(t)


def and_query_ref(idx: InvertedIndex, terms: list) -> np.ndarray:
    """Seed baseline: full decode per term + scalar ``np.isin`` intersection."""
    terms = sorted((t for t in terms if t in idx.terms), key=lambda t: idx.terms[t].df)
    if not terms:
        return np.zeros(0, np.uint32)
    ids, _ = idx.decode_term(terms[0])
    for t in terms[1:]:
        if len(ids) == 0:
            break
        cand, _ = idx.decode_term(t, min_docid=int(ids[0]))
        ids = ids[np.isin(ids, cand, assume_unique=True)]
    return ids
