"""Device-resident posting arenas: the compressed index as contiguous device
arrays, decodable in bulk without host round-trips.

The host engine (``repro.index.engine``) stores every (term, block) as its own
little ``Encoded`` and decodes through Python one block at a time, so the
paper's SIMD-decode win (Table VII) never reaches the serving path: per AND
round the engine pays O(selected blocks) interpreter iterations.  A
``DeviceArena`` flattens the whole index once at build time:

  * **data arena** — every supported block's data words, concatenated into one
    uint32 device array (ids and TFs are separate entries of the same arena).
  * **control arena** — the matching selector / bit-width streams.
  * **tables** — per-entry offset, length, posting count and first-docid
    (skip-table) columns, so any (term, block, field) is addressable on device
    by a handful of integers.

On top sit two batched execution paths:

  * ``decode_blocks`` — ONE jitted call decodes a whole work-list of entries
    lane-parallel: each work-list lane gathers its padded selector/data slice
    from the arenas (``dynamic_slice`` under ``vmap``) and runs the
    fixed-shape arena decoders (``group_simple.decode_arena_block``,
    ``bp128.decode_arena_block``), fused with the d-gap prefix sum and
    first-docid add.  Work-lists are padded to power-of-two buckets so jit
    variants stay bounded.  Supported codecs: ``group_simple`` and the
    BP128 family (``bp128``, ``g_packed_binary``); anything else (notably the
    ``stream_vbyte`` short-list blocks) falls back to the numpy decoder per
    block, preserving exact results for every registered codec.
  * ``fused_and`` — the ``kernels/decode_fused`` Pallas path: block gaps
    re-packed into fixed (rows, 128) tiles at the block's own bit width
    rounded up to a small bucket set (the same TPU-native re-layout
    ``bp_tpu`` applies to streams), decoded *and*
    intersected against a query's candidate bitmap inside VMEM, with the
    skip-selected next block's DMA double-buffered via scalar-prefetched
    work-list indices.

``stats`` counts device calls and blocks decoded per path; the engine's
work-list dedup guarantees <= 1 decode per hot (term, block) per batch, which
``benchmarks/bench_query.py`` records alongside the qps numbers.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bp128 as bp128_lib
from repro.core import group_simple
from repro.core.bits import ebw_np
from repro.kernels import decode_fused
from repro.kernels.bitpack import LANES
from repro.kernels.decode_fused import BLOCK_ROWS
from repro.kernels.intersect import bitmap_build_np

KIND_GS, KIND_BP, KIND_HOST = 0, 1, 2
BP_FAMILY = ("bp128", "g_packed_binary")
SUPPORTED = ("group_simple",) + BP_FAMILY

GS_PMAX = 128                 # max Group-Simple vectors in a 512-posting block
BP_WMAX = 128                 # max data words per component per block
_MIN_WORKLIST = 8             # smallest jit bucket


def _bucket(k: int) -> int:
    w = _MIN_WORKLIST
    while w < k:
        w *= 2
    return w


def _pad_rows(cols: list[np.ndarray], w: int) -> list[jnp.ndarray]:
    """Pad every per-entry column to the jit bucket by repeating entry 0."""
    k = len(cols[0])
    out = []
    for c in cols:
        c = np.asarray(c)
        if k < w:
            c = np.concatenate([c, np.repeat(c[:1], w - k)])
        out.append(jnp.asarray(c))
    return out


@jax.jit
def _gs_decode_batch(sels_arena, data_arena, sel_off, p_len, dat_off, n,
                     first, is_delta):
    """Work-list decode over the Group-Simple arenas, one lane per block."""

    def one(so, pl_, do, nn, fi, dl):
        sels = jax.lax.dynamic_slice(sels_arena, (so,), (GS_PMAX,))
        data = jax.lax.dynamic_slice(data_arena, (do,), (4 * GS_PMAX,))
        vals = group_simple.decode_arena_block(sels, data.reshape(GS_PMAX, 4),
                                               pl_, nn)
        ids = jnp.cumsum(vals, dtype=jnp.uint32) + fi
        i = jnp.arange(vals.shape[0], dtype=jnp.int32)
        return jnp.where(dl, jnp.where(i < nn, ids, 0), vals)

    return jax.vmap(one)(sel_off, p_len, dat_off, n, first, is_delta)


@functools.partial(jax.jit, static_argnames=("frame_quads",))
def _bp_decode_batch(ctrl_arena, data_arena, ctrl_off, dat_off, n, first,
                     is_delta, frame_quads):
    """Work-list decode over the BP128-family arenas, one lane per block."""
    cmax = -(-BP_WMAX // frame_quads)

    def one(co, do, nn, fi, dl):
        ctrl = jax.lax.dynamic_slice(ctrl_arena, (co,), (cmax,))
        data = jax.lax.dynamic_slice(data_arena, (do,), (4 * (BP_WMAX + 2),))
        vals = bp128_lib.decode_arena_block(ctrl, data.reshape(BP_WMAX + 2, 4),
                                            nn, frame_quads)
        ids = jnp.cumsum(vals, dtype=jnp.uint32) + fi
        i = jnp.arange(vals.shape[0], dtype=jnp.int32)
        return jnp.where(dl, jnp.where(i < nn, ids, 0), vals)

    return jax.vmap(one)(ctrl_off, dat_off, n, first, is_delta)


class DeviceArena:
    """Flattened device-resident copy of an ``InvertedIndex``.

    Build once via ``DeviceArena.from_index(idx)`` (or ``idx.to_device()`` /
    ``QueryEngine.to_device()``); decode any work-list of (term, block, field)
    entries with ``decode_blocks`` (field 0 = docids, 1 = TFs), or intersect a
    term's skip-selected blocks against a candidate set on device with
    ``fused_and``.
    """

    def __init__(self, idx, build_fused: bool = True):
        self.idx = idx
        self.n_docs = idx.n_docs
        self.stats = {"device_calls": 0, "blocks_device": 0, "blocks_host": 0,
                      "fused_calls": 0, "fused_blocks": 0}
        self._loc: dict = {}
        self._build_compressed_arenas(idx)
        self._pk = None
        if build_fused:
            self.ensure_fused()

    # ---- build ------------------------------------------------------------- #

    def _build_compressed_arenas(self, idx) -> None:
        gs_sels, gs_data = [], []
        gs = {k: [] for k in ("sel_off", "p_len", "dat_off", "n", "first")}
        bp_ctrl, bp_data = [], []
        bp = {k: [] for k in ("ctrl_off", "dat_off", "n", "first")}
        so = do = co = bo = 0
        self._bp_frame_quads = None
        for t, tp in idx.terms.items():
            for bi, (first, encg, enct) in enumerate(tp.blocks):
                for field, enc, fi in ((0, encg, first), (1, enct, 0)):
                    key = (t, bi, field)
                    if enc.codec == "group_simple" and enc.n:
                        sels = np.asarray(enc.meta["sels"], np.int32)
                        self._loc[key] = (KIND_GS, len(gs["n"]))
                        gs["sel_off"].append(so)
                        gs["p_len"].append(len(sels))
                        gs["dat_off"].append(do)
                        gs["n"].append(enc.n)
                        gs["first"].append(fi)
                        gs_sels.append(sels)
                        gs_data.append(np.asarray(enc.data, np.uint32).reshape(-1))
                        so += sels.size
                        do += gs_data[-1].size
                    elif enc.codec in BP_FAMILY and enc.n:
                        fq = enc.meta["frame_quads"]
                        if self._bp_frame_quads is None:
                            self._bp_frame_quads = fq
                        assert self._bp_frame_quads == fq, "mixed BP layouts"
                        ctrl = np.asarray(enc.control, np.int32)
                        self._loc[key] = (KIND_BP, len(bp["n"]))
                        bp["ctrl_off"].append(co)
                        bp["dat_off"].append(bo)
                        bp["n"].append(enc.n)
                        bp["first"].append(fi)
                        bp_ctrl.append(ctrl)
                        bp_data.append(np.asarray(enc.data, np.uint32).reshape(-1))
                        co += ctrl.size
                        bo += bp_data[-1].size
                    else:
                        self._loc[key] = (KIND_HOST, -1)
        # trailing slack so the fixed-size dynamic_slice gathers never clamp
        self._gs = None
        if gs["n"]:
            self._gs = {k: np.asarray(v, np.uint32 if k == "first" else np.int32)
                        for k, v in gs.items()}
            self._gs_sels = jnp.asarray(np.concatenate(
                gs_sels + [np.zeros(GS_PMAX, np.int32)]))
            self._gs_data = jnp.asarray(np.concatenate(
                gs_data + [np.zeros(4 * GS_PMAX, np.uint32)]))
        self._bp = None
        if bp["n"]:
            self._bp = {k: np.asarray(v, np.uint32 if k == "first" else np.int32)
                        for k, v in bp.items()}
            cmax = -(-BP_WMAX // self._bp_frame_quads)
            self._bp_ctrl = jnp.asarray(np.concatenate(
                bp_ctrl + [np.zeros(cmax, np.int32)]))
            self._bp_data = jnp.asarray(np.concatenate(
                bp_data + [np.zeros(4 * (BP_WMAX + 2), np.uint32)]))

    # per-block widths round up to one of these, so a single outlier gap
    # widens only its own bucket instead of the whole arena (and the fused
    # kernel compiles at most this many bw variants)
    FUSED_BW_BUCKETS = (4, 8, 12, 16, 24, 32)

    def ensure_fused(self) -> "DeviceArena":
        """Build the fused-kernel tile arenas if absent: every block's d-gaps
        re-packed into fixed (rows, 128) tiles — the layout
        ``kernels/decode_fused`` consumes — grouped into per-bit-width
        buckets."""
        if self._pk is not None:
            return self
        idx = self.idx
        self._pk = {}
        self._pk_slot = {}
        cw = -(-self.n_docs // 32)
        self._cand_rows = max(1, -(-cw // LANES))
        staged: dict = {bw: [] for bw in self.FUSED_BW_BUCKETS}
        for t, tp in idx.terms.items():
            for bi in range(len(tp.blocks)):
                ids = idx.decode_block_ids(t, bi)
                g = np.zeros(len(ids), np.uint32)
                g[1:] = ids[1:] - ids[:-1]
                ebw = max(1, int(ebw_np(g.max(initial=0))))
                bw = next(b for b in self.FUSED_BW_BUCKETS if b >= ebw)
                staged[bw].append(((t, bi), tp.blocks[bi][0], g))
        for bw, items in staged.items():
            if not items:
                continue
            rpb = decode_fused.rows_per_block(bw)
            tiles = np.zeros((len(items) * rpb, LANES), np.uint32)
            firsts, ns = [], []
            for s, (key, first, g) in enumerate(items):
                self._pk_slot[key] = (bw, s)
                firsts.append(first)
                ns.append(len(g))
                vals = np.zeros(BLOCK_ROWS * LANES, np.uint32)
                vals[: len(g)] = g
                vals = vals.reshape(BLOCK_ROWS, LANES).astype(np.uint64)
                tile = tiles[s * rpb:(s + 1) * rpb]
                for r in range(BLOCK_ROWS):
                    start = r * bw
                    w, off = start // 32, start % 32
                    tile[w] |= ((vals[r] << off) & 0xFFFFFFFF).astype(np.uint32)
                    if off + bw > 32:
                        tile[w + 1] |= (vals[r] >> (32 - off)).astype(np.uint32)
            self._pk[bw] = {"tiles": jnp.asarray(tiles),
                            "first": np.asarray(firsts, np.uint32),
                            "n": np.asarray(ns, np.int32)}
        return self

    @classmethod
    def from_index(cls, idx, build_fused: bool = True) -> "DeviceArena":
        return cls(idx, build_fused=build_fused)

    # ---- batched work-list decode ------------------------------------------ #

    def decode_blocks(self, entries: list) -> list:
        """Decode a work-list of (term, block, field) entries; field 0 decodes
        docids (d-gap prefix sum + first docid fused in), field 1 raw TFs.

        One jitted device call per represented kind; unsupported-codec entries
        decode through the numpy oracle.  Returns arrays aligned with
        ``entries``.
        """
        out: list = [None] * len(entries)
        by_kind: dict = {KIND_GS: [], KIND_BP: [], KIND_HOST: []}
        for j, e in enumerate(entries):
            kind, slot = self._loc[e]
            by_kind[kind].append((j, slot, e))
        if by_kind[KIND_GS]:
            self._run_batch(by_kind[KIND_GS], out, KIND_GS)
        if by_kind[KIND_BP]:
            self._run_batch(by_kind[KIND_BP], out, KIND_BP)
        for j, _, (t, bi, field) in by_kind[KIND_HOST]:
            out[j] = (self.idx.decode_block_ids(t, bi) if field == 0
                      else self.idx.decode_block_tfs(t, bi))
            self.stats["blocks_host"] += 1
        return out

    def _run_batch(self, items: list, out: list, kind: int) -> None:
        tab = self._gs if kind == KIND_GS else self._bp
        slots = np.asarray([slot for _, slot, _ in items], np.int64)
        w = _bucket(len(items))
        ns = tab["n"][slots]
        delta = np.asarray([e[2] == 0 for _, _, e in items])
        if kind == KIND_GS:
            cols = _pad_rows([tab["sel_off"][slots], tab["p_len"][slots],
                              tab["dat_off"][slots], ns,
                              tab["first"][slots], delta], w)
            res = _gs_decode_batch(self._gs_sels, self._gs_data, *cols)
        else:
            cols = _pad_rows([tab["ctrl_off"][slots], tab["dat_off"][slots],
                              ns, tab["first"][slots], delta], w)
            res = _bp_decode_batch(self._bp_ctrl, self._bp_data, *cols,
                                   frame_quads=self._bp_frame_quads)
        res = np.asarray(res)
        for row, ((j, _, _), n) in enumerate(zip(items, ns)):
            out[j] = res[row, :n].copy()
        self.stats["device_calls"] += 1
        self.stats["blocks_device"] += len(items)

    # ---- fused decode + AND ------------------------------------------------ #

    def has_fused(self, t, blocks) -> bool:
        return (self._pk is not None
                and all((t, int(bi)) in self._pk_slot for bi in blocks))

    def fused_and(self, t, blocks, cand: np.ndarray) -> np.ndarray:
        """Intersect sorted candidates with term t's skip-selected blocks
        through the fused decode+AND kernel (one call per bit-width bucket
        present in the work-list); exact ``intersect_sorted`` parity."""
        k = len(blocks)
        if k == 0 or len(cand) == 0:
            return np.zeros(0, np.uint32)
        groups: dict = {}
        for j, bi in enumerate(blocks):
            bw, row = self._pk_slot[(t, int(bi))]
            groups.setdefault(bw, []).append((j, row))
        words = bitmap_build_np(cand, 0, self._cand_rows * LANES * 32)
        cand_rows = jnp.asarray(words.reshape(self._cand_rows, LANES))
        parts: list = [None] * k
        for bw, items in groups.items():
            pk = self._pk[bw]
            rows = np.asarray([r for _, r in items], np.int64)
            slots = rows.astype(np.int32)
            firsts = pk["first"][rows]
            ns = pk["n"][rows]
            w = _bucket(len(items))
            if len(items) < w:   # pad: repeated entries with n=0 hit nothing
                slots = np.concatenate([slots, np.repeat(slots[:1], w - len(items))])
                firsts = np.concatenate([firsts, np.repeat(firsts[:1], w - len(items))])
                ns = np.concatenate([ns, np.zeros(w - len(items), np.int32)])
            ids, hits = decode_fused.fused_decode_and(
                pk["tiles"], jnp.asarray(slots), jnp.asarray(firsts),
                jnp.asarray(ns), cand_rows, bw=bw)
            ids = np.asarray(ids).reshape(w, -1)
            hits = np.asarray(hits).reshape(w, -1).astype(bool)
            for g, (j, _) in enumerate(items):
                parts[j] = ids[g][hits[g]]
            self.stats["fused_calls"] += 1
            self.stats["fused_blocks"] += len(items)
        return np.concatenate(parts)
