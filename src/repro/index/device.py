"""Device-resident posting arenas: the compressed index as contiguous device
arrays, decodable in bulk without host round-trips.

The host engine (``repro.index.engine``) stores every (term, block) as its own
little ``Encoded`` and decodes through Python one block at a time, so the
paper's SIMD-decode win (Table VII) never reaches the serving path: per AND
round the engine pays O(selected blocks) interpreter iterations.  A
``DeviceArena`` flattens the whole index once at build time — and it does so
*generically*: any codec whose registry entry declares an
:class:`repro.core.codec.ArenaLayout` capability participates, with zero
codec-name (or column-count) dispatch in this module.  Per declared layout
the arena holds:

  * **one arena per declared column** — every block's words for that column
    (ctrl / data / exceptions / …, per the codec's own
    :class:`repro.core.codec.ArenaColumn` declarations), concatenated into
    one device array of the column's dtype.  Exception-bearing codecs (the
    Group-PFD family) are therefore first-class: their patch streams live in
    a third column and are applied inside the fixed-shape ``decode_block``.
  * **tables** — per-entry per-column offset/length plus posting count and
    first-docid (skip-table) columns, so any (term, block, field) is
    addressable on device by a handful of integers.

On top sit two batched execution paths:

  * ``decode_blocks`` — ONE jitted call per codec present in the work-list
    decodes all of that codec's entries lane-parallel: each work-list lane
    gathers its padded control/data slice from the arenas (``dynamic_slice``
    under ``vmap``) and runs the layout's fixed-shape ``decode_block``, fused
    with the d-gap prefix sum and first-docid add.  Work-lists are padded to
    power-of-two buckets so jit variants stay bounded.  Blocks whose codec
    declares no arena capability (and empty blocks) fall back to the numpy
    decoder per block, preserving exact results for every registered codec.
  * ``fused_and`` — the ``kernels/decode_fused`` Pallas path: block gaps
    re-packed into fixed (rows, 128) tiles at the block's own bit width
    rounded up to ``decode_fused.BW_BUCKETS``, decoded *and* intersected
    against a query's candidate bitmap inside VMEM, with the skip-selected
    next block's DMA double-buffered via scalar-prefetched work-list indices.

``stats`` counts device calls and blocks decoded per path; the engine's
work-list dedup guarantees <= 1 decode per hot (term, block) per batch, which
``benchmarks/bench_query.py`` records alongside the qps numbers.

Generations (the streaming mutable index): an arena is built from — and
belongs to — exactly one immutable ``Generation`` (``repro.index.segments``
holds the mutable side).  ``Generation.to_device`` caches the arena on the
generation object, so an ``ExecutionPlan`` pinned to an old generation keeps
resolving the old arena after a ``compact()`` swap, while new plans build (or
reuse) the next generation's arena; nothing in this module is mutated in
place.  Tombstone gating happens above, in the engine, as one packed
live-bitmap AND per epoch (``intersect_rounds.pack_live_words``) — the arena
tables themselves never change under deletes.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import codec as codec_lib
from repro.core.bits import ebw_np
from repro.obs.trace import get_tracer
from repro.kernels import decode_fused, intersect_rounds, topk
from repro.kernels.bitpack import LANES
from repro.kernels.intersect import bitmap_build_np

_MIN_WORKLIST = 8             # smallest jit bucket


def _bucket(k: int) -> int:
    w = _MIN_WORKLIST
    while w < k:
        w *= 2
    return w


def _pad_rows(cols: list[np.ndarray], w: int) -> list[jnp.ndarray]:
    """Pad every per-entry column to the jit bucket by repeating entry 0."""
    k = len(cols[0])
    out = []
    for c in cols:
        c = np.asarray(c)
        if k < w:
            c = np.concatenate([c, np.repeat(c[:1], w - k)])
        out.append(jnp.asarray(c))
    return out


@functools.partial(jax.jit, static_argnames=("decode", "widths"))
def _decode_worklist(arenas, offs, lens, n, first, is_delta, *, decode, widths):
    """Work-list decode over one codec's column arenas, one lane per block.

    ``arenas`` / ``offs`` / ``lens`` are tuples with one element per declared
    column; each lane gathers one padded fixed-width slice per column and
    calls ``decode(*slices, *lens, n_valid)``.  ``decode`` is the codec's
    declared ``ArenaLayout.decode_block`` — a stable registry object, so the
    jit cache stays bounded by the number of registered arena layouts times
    the work-list buckets.
    """

    def one(off, ln, nn, fi, dl):
        cols = tuple(jax.lax.dynamic_slice(a, (o,), (w,))
                     for a, o, w in zip(arenas, off, widths))
        vals = decode(*cols, *ln, nn)
        ids = jnp.cumsum(vals, dtype=jnp.uint32) + fi
        i = jnp.arange(vals.shape[0], dtype=jnp.int32)
        return jnp.where(dl, jnp.where(i < nn, ids, 0), vals)

    return jax.vmap(one)(offs, lens, n, first, is_delta)


class _ArenaGroup:
    """Per-codec contiguous column arenas + per-entry tables, built from the
    codec's declared :class:`repro.core.codec.ArenaColumn` tuple — two
    columns or five, the group never branches on the count."""

    def __init__(self, name: str, layout):
        self.name = name
        self.layout = layout
        k = len(layout.columns)
        self._parts: list = [[] for _ in range(k)]
        self._off = [0] * k
        self.offs: list = [[] for _ in range(k)]
        self.lens: list = [[] for _ in range(k)]
        self.tab: dict = {"n": [], "first": []}

    def add(self, enc, first: int) -> int:
        lay = self.layout
        assert enc.n <= lay.max_n, (self.name, enc.n)
        slot = len(self.tab["n"])
        for c, col in enumerate(lay.columns):
            w = np.asarray(col.extract(enc), col.dtype).reshape(-1)
            assert w.size <= col.width, (self.name, col.name, w.size, col.width)
            self._parts[c].append(w)
            self.offs[c].append(self._off[c])
            self.lens[c].append(w.size)
            self._off[c] += w.size
        self.tab["n"].append(enc.n)
        self.tab["first"].append(first)
        return slot

    def finalize(self) -> "_ArenaGroup":
        # trailing slack so the fixed-size dynamic_slice gathers never clamp
        self.arenas = tuple(
            jnp.asarray(np.concatenate(parts + [np.zeros(col.width, col.dtype)]))
            for parts, col in zip(self._parts, self.layout.columns))
        self.offs = [np.asarray(o, np.int32) for o in self.offs]
        self.lens = [np.asarray(v, np.int32) for v in self.lens]
        self.tab = {k: np.asarray(v, np.uint32 if k == "first" else np.int32)
                    for k, v in self.tab.items()}
        self._parts = None
        return self

    def _run(self, slots: np.ndarray, delta: np.ndarray):
        """One jitted lane-parallel decode of ``slots``; returns the padded
        (bucket, out_width) device array (rows with delta get the d-gap
        prefix sum + first docid fused in, zero past their n)."""
        w = _bucket(len(slots))
        ns = self.tab["n"][slots]
        offs = _pad_rows([o[slots] for o in self.offs], w)
        lens = _pad_rows([v[slots] for v in self.lens], w)
        rest = _pad_rows([ns, self.tab["first"][slots], delta], w)
        return _decode_worklist(
            self.arenas, tuple(offs), tuple(lens), *rest,
            decode=self.layout.decode_block,
            widths=tuple(col.width for col in self.layout.columns)), ns

    def decode(self, items: list, out: list) -> None:
        """Decode [(out_index, slot, (t, bi, field)), ...] in one jitted call;
        field 0 entries get the d-gap prefix sum + first docid fused in."""
        slots = np.asarray([slot for _, slot, _ in items], np.int64)
        delta = np.asarray([e[2] == 0 for _, _, e in items])
        res, ns = self._run(slots, delta)
        res = np.asarray(res)
        for row, ((j, _, _), n) in enumerate(zip(items, ns)):
            out[j] = res[row, :n].copy()

    def decode_rows(self, slots: np.ndarray):
        """Device-resident decode: padded (bucket, out_width) docid rows
        (prefix sum + first fused, zero past n) kept on device, plus per-slot
        posting counts.  The round-resident engine consumes the rows without
        any host copy."""
        res, ns = self._run(np.asarray(slots, np.int64),
                            np.ones(len(slots), bool))
        return res, ns


class DeviceArena:
    """Flattened device-resident copy of an ``InvertedIndex``.

    Build once via ``DeviceArena.from_index(idx)`` (or ``idx.to_device()`` /
    ``QueryEngine.to_device()``); decode any work-list of (term, block, field)
    entries with ``decode_blocks`` (field 0 = docids, 1 = TFs), or intersect a
    term's skip-selected blocks against a candidate set on device with
    ``fused_and``.  Coverage is capability-driven: every codec declaring an
    ``ArenaLayout`` in the registry decodes natively; the rest fall back to
    the numpy oracle per block.
    """

    # kept as a class attribute for callers that sized things off the arena;
    # the buckets themselves are owned by the fused kernel
    FUSED_BW_BUCKETS = decode_fused.BW_BUCKETS

    def __init__(self, idx, build_fused: bool = True):
        self.idx = idx
        self.n_docs = idx.n_docs
        # doc-range shard generations (repro.index.shards) declare the global
        # docid window they serve; unsharded indexes cover [0, n_docs)
        self.doc_lo = int(getattr(idx, "doc_lo", 0))
        self.doc_hi = int(getattr(idx, "doc_hi", idx.n_docs))
        self.stats = {"device_calls": 0, "blocks_device": 0, "blocks_host": 0,
                      "fused_calls": 0, "fused_blocks": 0}
        self._loc: dict = {}
        self._groups: dict = {}
        self._build_compressed_arenas(idx)
        self._pk = None
        self.scores = None
        if build_fused:
            self.ensure_fused()

    # ---- build ------------------------------------------------------------- #

    def _build_compressed_arenas(self, idx) -> None:
        staging: dict = {}
        dense_rows, dense_w0 = [], []
        self.dense_slot: dict = {}
        words_total = intersect_rounds.bitmap_geometry(idx.n_docs)[0]
        for t, tp in idx.terms.items():
            for bi, (first, encg, enct) in enumerate(tp.blocks):
                for field, enc, fi in ((0, encg, first), (1, enct, 0)):
                    key = (t, bi, field)
                    spec = codec_lib.get(enc.codec) if enc.n else None
                    lay = spec.arena if spec is not None else None
                    if lay is None or not lay.supports(enc):
                        self._loc[key] = (None, -1)
                        continue
                    g = staging.get(enc.codec)
                    if g is None:
                        g = staging[enc.codec] = _ArenaGroup(enc.codec, lay)
                    self._loc[key] = (enc.codec, g.add(enc, fi))
                    if (field == 0 and lay.bitmap_words
                            and lay.is_bitmap is not None
                            and lay.is_bitmap(enc)):
                        # word-parallel-servable block: stage its raw bitmap
                        # window realigned to the serving bitmap geometry
                        # (first window word rounded down to a 4-word phase,
                        # so the window's column offset is lane-tile aligned;
                        # clamped so the window stays inside the geometry).
                        ids = first + np.cumsum(spec.decode_np(enc),
                                                dtype=np.uint64)
                        w0 = min((int(ids[0]) >> 5) & ~3,
                                 words_total - lay.bitmap_words)
                        bits = np.zeros(lay.bitmap_words * 32, np.uint8)
                        bits[(ids - np.uint64(w0 * 32)).astype(np.int64)] = 1
                        self.dense_slot[(t, bi)] = len(dense_rows)
                        dense_rows.append(np.packbits(
                            bits, bitorder="little").view(np.uint32))
                        dense_w0.append(w0)
        self._groups = {name: g.finalize() for name, g in staging.items()}
        self.dense_w0 = np.asarray(dense_w0, np.int32)
        self.dense_words = (jnp.asarray(np.stack(dense_rows)) if dense_rows
                            else None)

    def ensure_fused(self) -> "DeviceArena":
        """Build the fused-kernel tile arenas if absent: every block's d-gaps
        re-packed into the fixed (rows, 128) tiles ``kernels/decode_fused``
        consumes, grouped into per-bit-width buckets."""
        if self._pk is not None:
            return self
        idx = self.idx
        self._pk = {}
        self._pk_slot = {}
        # one source of truth with the engine's segmented-bitmap geometry
        self._cand_rows = intersect_rounds.bitmap_geometry(self.n_docs)[1]
        staged: dict = {bw: [] for bw in decode_fused.BW_BUCKETS}
        for t, tp in idx.terms.items():
            for bi in range(len(tp.blocks)):
                ids = idx.decode_block_ids(t, bi)
                g = np.zeros(len(ids), np.uint32)
                g[1:] = ids[1:] - ids[:-1]
                ebw = max(1, int(ebw_np(g.max(initial=0))))
                bw = next(b for b in decode_fused.BW_BUCKETS if b >= ebw)
                staged[bw].append(((t, bi), tp.blocks[bi][0], g))
        for bw, items in staged.items():
            if not items:
                continue
            rpb = decode_fused.rows_per_block(bw)
            tiles = np.zeros((len(items) * rpb, LANES), np.uint32)
            firsts, ns = [], []
            for s, (key, first, g) in enumerate(items):
                self._pk_slot[key] = (bw, s)
                firsts.append(first)
                ns.append(len(g))
                tiles[s * rpb:(s + 1) * rpb] = decode_fused.pack_gaps(g, bw)
            self._pk[bw] = {"tiles": jnp.asarray(tiles),
                            "first": np.asarray(firsts, np.uint32),
                            "n": np.asarray(ns, np.int32)}
        return self

    def ensure_scores(self) -> "DeviceArena":
        """Build the quantized impact score arena if absent: per posting
        block one packed 128-word score column (``repro.index.scores``) plus
        the block-max / term-max WAND tables, all device-resident."""
        if self.scores is None:
            from .scores import ScoreArena
            self.scores = ScoreArena.from_index(self.idx)
        return self

    @classmethod
    def from_index(cls, idx, build_fused: bool = True) -> "DeviceArena":
        return cls(idx, build_fused=build_fused)

    # ---- capability probes -------------------------------------------------- #

    def covers(self, key) -> bool:
        """True if (term, block, field) decodes natively on device."""
        return self._loc[key][0] is not None

    # ---- batched work-list decode ------------------------------------------ #

    def decode_blocks(self, entries: list) -> list:
        """Decode a work-list of (term, block, field) entries; field 0 decodes
        docids (d-gap prefix sum + first docid fused in), field 1 raw TFs.

        One jitted device call per codec represented in the work-list;
        entries without an arena capability decode through the numpy oracle.
        Returns arrays aligned with ``entries``.
        """
        out: list = [None] * len(entries)
        by_codec: dict = {}
        host: list = []
        for j, e in enumerate(entries):
            name, slot = self._loc[e]
            if name is None:
                host.append((j, e))
            else:
                by_codec.setdefault(name, []).append((j, slot, e))
        for name, items in by_codec.items():
            with get_tracer().span(f"decode/{name}", lane="device",
                                   blocks=len(items)):
                self._groups[name].decode(items, out)
            self.stats["device_calls"] += 1
            self.stats["blocks_device"] += len(items)
        for j, (t, bi, field) in host:
            out[j] = (self.idx.decode_block_ids(t, bi) if field == 0
                      else self.idx.decode_block_tfs(t, bi))
            self.stats["blocks_host"] += 1
        return out

    def decode_blocks_device(self, entries: list):
        """Decode a work-list of (term, block) docid entries WITHOUT copying
        the results to the host: returns (rows, ns) where ``rows[j]`` is a
        padded (ARENA_BLOCK,) device array of absolute docids (d-gap prefix
        sum + first fused, zero past ``ns[j]``).  One jitted call per codec
        present; blocks without an arena capability decode through the numpy
        oracle and are *uploaded* in one batch — postings may flow host ->
        device here, but candidates never flow back.
        """
        rows: list = [None] * len(entries)
        ns: list = [0] * len(entries)
        by_codec: dict = {}
        host: list = []
        for j, (t, bi) in enumerate(entries):
            name, slot = self._loc[(t, bi, 0)]
            if name is None:
                host.append((j, t, bi))
            else:
                by_codec.setdefault(name, []).append((j, slot))
        for name, items in by_codec.items():
            g = self._groups[name]
            with get_tracer().span(f"decode/{name}", lane="device",
                                   blocks=len(items), resident=True):
                res, n_arr = g.decode_rows(np.asarray([s for _, s in items]))
            if res.shape[1] != codec_lib.ARENA_BLOCK:       # defensive: all
                res = res[:, :codec_lib.ARENA_BLOCK]        # layouts use 512
            for r, ((j, _), n) in enumerate(zip(items, n_arr)):
                rows[j] = res[r]
                ns[j] = int(n)
            self.stats["device_calls"] += 1
            self.stats["blocks_device"] += len(items)
        if host:
            batch = np.zeros((len(host), codec_lib.ARENA_BLOCK), np.uint32)
            for k, (j, t, bi) in enumerate(host):
                ids = self.idx.decode_block_ids(t, bi)
                batch[k, :len(ids)] = ids
                ns[j] = len(ids)
            up = jnp.asarray(batch)
            for k, (j, _, _) in enumerate(host):
                rows[j] = up[k]
            self.stats["blocks_host"] += len(host)
        return rows, ns

    # ---- fused decode + AND ------------------------------------------------ #

    def has_fused(self, t, blocks) -> bool:
        return (self._pk is not None
                and all((t, int(bi)) in self._pk_slot for bi in blocks))

    def fused_and(self, t, blocks, cand: np.ndarray) -> np.ndarray:
        """Intersect sorted candidates with term t's skip-selected blocks
        through the fused decode+AND kernel (one call per bit-width bucket
        present in the work-list); exact ``intersect_sorted`` parity."""
        k = len(blocks)
        if k == 0 or len(cand) == 0:
            return np.zeros(0, np.uint32)
        groups: dict = {}
        for j, bi in enumerate(blocks):
            bw, row = self._pk_slot[(t, int(bi))]
            groups.setdefault(bw, []).append((j, row))
        words = bitmap_build_np(cand, 0, self._cand_rows * LANES * 32)
        cand_rows = jnp.asarray(words.reshape(self._cand_rows, LANES))
        parts: list = [None] * k
        for bw, items in groups.items():
            pk = self._pk[bw]
            rows = np.asarray([r for _, r in items], np.int64)
            slots = rows.astype(np.int32)
            firsts = pk["first"][rows]
            ns = pk["n"][rows]
            w = _bucket(len(items))
            if len(items) < w:   # pad: repeated entries with n=0 hit nothing
                slots = np.concatenate([slots, np.repeat(slots[:1], w - len(items))])
                firsts = np.concatenate([firsts, np.repeat(firsts[:1], w - len(items))])
                ns = np.concatenate([ns, np.zeros(w - len(items), np.int32)])
            ids, hits = decode_fused.fused_decode_and(
                pk["tiles"], jnp.asarray(slots), jnp.asarray(firsts),
                jnp.asarray(ns), cand_rows, bw=bw)
            ids = np.asarray(ids).reshape(w, -1)
            hits = np.asarray(hits).reshape(w, -1).astype(bool)
            for g, (j, _) in enumerate(items):
                parts[j] = ids[g][hits[g]]
            self.stats["fused_calls"] += 1
            self.stats["fused_blocks"] += len(items)
        return np.concatenate(parts)

    def _fused_rounds(self, pairs: list, cand_tiles, with_scores: bool,
                      ubs=None):
        """One ``segmented_decode_and`` call per bit-width bucket present in
        the work-list (plus, with scores, one ``topk.unpack_codes`` call for
        the bucket's packed score column): the shared body of the AND and
        ranked fused rounds — grouping, n=0 bucket padding, and stats live
        here exactly once.  ``ubs`` (optional, aligned with ``pairs``) are
        per-entry quantized upper bounds the ranked caller threads through to
        the adaptive-theta masking; they ride the same grouping/padding so
        the returned array aligns with the output rows (padded rows have
        n=0 and hit nothing, so their ub value is irrelevant)."""
        sa = self.ensure_scores().scores if with_scores else None
        if ubs is None:
            ubs = [0] * len(pairs)
        groups: dict = {}
        for (qs, t, bi), ub in zip(pairs, ubs):
            bw, row = self._pk_slot[(t, int(bi))]
            groups.setdefault(bw, []).append(
                (qs, row, sa.slot[(t, int(bi))] if with_scores else 0, ub))
        parts: list = [[] for _ in range(5)]   # ids, hits, codes, qs, ubs
        for bw, items in groups.items():
            pk = self._pk[bw]
            rows = np.asarray([r for _, r, _, _ in items], np.int64)
            cols = [rows.astype(np.int32),
                    np.asarray([q for q, _, _, _ in items], np.int32),
                    np.asarray([s for _, _, s, _ in items], np.int32),
                    pk["first"][rows], pk["n"][rows],
                    np.asarray([u for _, _, _, u in items], np.int32)]
            w = _bucket(len(items))
            if len(items) < w:   # pad: repeated entries with n=0 hit nothing
                pad = w - len(items)
                cols = [np.concatenate([c, np.repeat(c[:1], pad)]) for c in cols]
                cols[4][-pad:] = 0
            slots, qs, sslots, firsts, ns, ub = cols
            ids, hits = intersect_rounds.segmented_decode_and(
                pk["tiles"], jnp.asarray(slots), jnp.asarray(qs),
                jnp.asarray(firsts), jnp.asarray(ns), cand_tiles,
                bw=bw, crows=self._cand_rows)
            parts[0].append(ids.reshape(w, -1))
            parts[1].append(hits.reshape(w, -1))
            if with_scores:
                codes = topk.unpack_codes(sa.tiles, jnp.asarray(sslots))
                parts[2].append(codes.reshape(w, -1))
            parts[3].append(qs)
            parts[4].append(ub)
            self.stats["fused_calls"] += 1
            self.stats["fused_blocks"] += len(items)
        cat = (lambda xs: xs[0] if len(xs) == 1 else jnp.concatenate(xs))
        ncat = (lambda xs: xs[0] if len(xs) == 1 else np.concatenate(xs))
        return (cat(parts[0]), cat(parts[1]),
                cat(parts[2]) if with_scores else None,
                ncat(parts[3]), ncat(parts[4]))

    def fused_round(self, pairs: list, cand_tiles):
        """Segmented fused decode + probe for one device-resident AND round.

        pairs: [(qslot, t, bi), ...] — this round's work-list, every entry
            probing its own query's candidate tile block.
        cand_tiles: (Q * _cand_rows, 128) uint32 — the segmented bitmap.

        Returns (ids, hits, qslots) device/host arrays of matching leading
        length, ready for the survivor scatter.  The decoded ids and hit
        masks never touch the host.
        """
        ids, hits, _, qs, _ = self._fused_rounds(pairs, cand_tiles, False)
        return ids, hits, qs

    def fused_round_scored(self, pairs: list, cand_tiles, ubs=None):
        """Segmented fused decode + probe + score-unpack for one ranked
        round: like :meth:`fused_round` but each work-list entry also runs
        its block's packed score words through the ``kernels/topk`` Pallas
        unpack tile, so the engine can scatter ``codes * hits`` straight into
        the segmented accumulator.  Returns (ids, hits, codes, qslots, ubs);
        the decoded ids, hit masks, and codes never touch the host.
        """
        return self._fused_rounds(pairs, cand_tiles, True, ubs)
