"""Compressed inverted index + query serving.

Layers (bottom up):

  * ``invindex`` — per-term blocked storage: d-gapped docids + TFs compressed
    with any codec from ``repro.core.codec.REGISTRY``; lists shorter than 64
    use the Stream VByte short-list fast path.  Every 512-posting block keeps
    its first docid as a skip pointer and decodes independently.
  * ``query`` — stateless one-shot AND/OR/BM25 helpers (deprecation shims
    over single-query plans).
  * ``engine`` — the batched query engine: ``engine.plan(batch)`` resolves a
    ``QueryBatch`` into a typed ``ExecutionPlan`` — placement (host / device
    / fused, with small batches auto-placed on the host per the measured
    ``CrossoverTable`` from the committed ``BENCH_query.json``, falling back
    to the static ``HOST_BATCH_MAX`` rule when the baseline is absent or
    shows no true host->device crossing; the deciding source is recorded in
    the plan's ``note``) plus every referenced term's
    codec capabilities, read once from the registry — and
    ``engine.execute(plan)`` runs it: AND queries fuse skip-table block
    pruning with the vectorized intersection kernels
    (``repro.kernels.intersect``), and hot decoded blocks live in an LRU
    keyed by (term, block) so a batch decodes each block at most once.
  * ``device`` — device-resident posting arenas, built *generically* from
    each codec's declared ``ArenaLayout``: the compressed blocks flattened
    into contiguous per-declared-column device arrays with per-(term, block)
    offset/length/first-docid tables.  ``engine.to_device()`` switches the
    serving path onto batched lane-parallel work-list decodes (one jitted
    call per codec per AND round, deduped across the batch); AND candidates
    then stay in a device-resident segmented bitmap across rounds
    (``repro.kernels.intersect_rounds`` — only the final result is copied to
    host), optionally through the segmented fused decode+probe Pallas kernel.
  * ``scores`` — the ranked-retrieval subsystem: per-(term, doc) BM25
    impacts quantized to u8 and packed as an additional score column per
    posting block (``ScoreArena``, same padded-``ArenaColumn`` contract as
    the codec arenas), with block-max / term-max / top-impact / docid-stripe
    tables precomputed for WAND/BMW-style pruning.  ``or`` / ``and_scored``
    plans accumulate the codes into a segmented device score buffer
    (``repro.kernels.topk``) and sync one compacted candidate bitmap per
    batch.
  * ``segments`` — the streaming mutable layer: ``DeltaSegment`` (a small
    doc-major mutable segment absorbing inserts/upserts) and ``Tombstones``
    (a versioned dead-docid set with frozen memoized views) sit beside the
    immutable ``Generation``; ``InvertedIndex`` composes the three into a
    mutable handle that serves bit-identically to a from-scratch rebuild.
  * ``shards`` — doc-range sharding: one generation split at contiguous
    docid boundaries into per-shard self-contained generations whose BM25 /
    quantizer statistics are pinned to the parent's, so the sharded serving
    path (``engine.to_device(shards=N)`` / ``mesh=``) runs every round
    shard-local and merges ranked top-k with one collective (see the sharded
    serving walkthrough further down).
  * ``serve`` — latency-governed online serving on top of ``engine``: an
    async admission queue + dynamic batcher turning a request *stream* into
    the ``QueryBatch``-shaped work everything below is built for (see the
    serving walkthrough further down).

Streaming mutation (insert -> tombstone -> compact -> generation swap):
``InvertedIndex`` wraps one immutable ``Generation`` (gid-stamped: blocks,
skip tables, impact tables, and the cached device arena all belong to a
generation) plus the mutable delta/tombstone pair.  ``insert(docid, terms,
doclen)`` lands in the delta segment — a docid the generation already holds
is tombstoned first (the *shadowing invariant*: generation and delta doc
sets stay disjoint, so result unions are plain sorted merges).  ``delete``
drops delta copies outright and tombstones base copies (their blocks are
immutable; serving gates them out).  Serving under mutation pins a frozen
*epoch* (``(gid, tombstone version, delta version)``) per ``plan()`` /
``execute()``: generation results are tombstone-filtered (on the resident
placements via ONE packed live-bitmap AND after the seed round —
``intersect_rounds.pack_live_words``, one upload per epoch, zero downloads)
and merged with a brute-force scan of the small delta segment; BM25 stats
(df, doclen, avdl) are recomputed live per epoch so scores match a rebuild
bitwise.  Ranked modes under a delta-bearing epoch disarm block-max pruning
(the quantized tables carry generation-time stats) — the candidate superset
contract still holds, and the exact float rescore restores bit-identity —
but TOMBSTONE-ONLY epochs (the common few-deletes case) stay armed: deletes
only shrink df, so every live/generation idf ratio is >= 1, and a per-query
Q16.16 deflation ``iq = floor(2**16 / Rmax)`` applied to every threshold
comparison keeps the generation-time upper bounds sound against live scores
(the full derivation is the re-arm note in ``index/scores.py``; theta0 is
re-derived from the tombstone-filtered top-code tables via
``ScoreArena.theta0_live``, and ``BENCH_mutation.json`` tracks
``ranked_tomb_1pct.blocks_pruned > 0`` as the CI guarantee);
``compact()`` fully re-arms pruning: it merge-sorts generation-minus-tombstones
with the delta per term, re-encodes through the codec registry into
generation ``gid + 1``, and swaps it in atomically — in-flight plans keep
executing against their pinned generation's arenas (all engine caches are
keyed by gid / epoch, so nothing stale survives the swap).  The governing
**rebuild-parity contract**: at any epoch, every mode on every placement is
bitwise identical to ``InvertedIndex.build(doclen_now(), live_postings)``
(enforced by the stateful differential harness in ``tests/test_mutation.py``
and the segment-consistency registry lint; ``BENCH_mutation.json`` tracks
qps per tombstone density, compaction pause, and delta-scan overhead).

Ranked retrieval (score columns, quantization contract, block-max pruning):
``ScoreArena`` quantizes with a single global scale ``delta = max impact /
255`` and ``code = floor(impact / delta)``; floor is monotone, so the stored
block-max tables equal the maxima of the stored codes (the registry lint
cross-checks this), and for a query of ``m`` known term occurrences any
doc's true score S obeys ``C*delta <= S < (C+m)*delta`` around its quantized
sum C.  Two consequences anchor exactness: the k-th largest quantized sum
``theta`` lower-bounds the k-th best true score, so the device path syncs
the candidate set ``{C >= theta - m}`` (as a bitmap, once per batch) and
rescores it with the shared float oracle — top-k sets and scores match the
host BM25 path bitwise, ties broken by ascending docid — and an OR
(term, block) work-list entry is *pruned* before decode when its upper bound
(own block-max + every other occurrence's max code over the block's docid
range, read from the per-term docid-stripe tables + the margin m) cannot
reach the threshold: first the static theta0 (the k-th top impact code of
the query's strongest term) on the host, then — **adaptive BMW theta** —
a per-query threshold PROMOTED on device after every round (the pooled
k-th statistic of the accumulated sums, ``kernels/topk.pooled_threshold``,
a sound monotone lower bound on the final k-th sum), which each later
round's kernels re-test against every entry's staged upper bound so the
work-list compacts itself with zero per-round host syncs.  Pruned blocks
only lose contributions of docs provably outside the true top-k.
``and_scored`` reuses the AND machinery — the intersection bitmap gates the
score scatter on device and is never downloaded.  ``BENCH_query.json``
tracks ``blocks_pruned`` / ``blocks_scored`` / ``blocks_dense`` and
per-round host syncs (zero on the resident ranked path) per mode.

Density-adaptive bitmap blocks (word-parallel dense postings):
posting blocks whose docids are dense — average gap (span / count) at most
``repro.core.dense_bitmap.DENSE_GAP``, fitting one 128-word window at a
4-word-aligned phase — are stored as RAW 128-word bitmaps instead of
d-gap-compressed streams, per "SIMD Compression and the Intersection of
Sorted Integers": at that density the fastest intersect is a word-parallel
AND of the bitmap against the candidate window, with no unpack and no
prefix-sum at all.  The decision is made once per block at build time
(``invindex.Generation.build`` asks ``dense_bitmap.eligible(ids)``) and the
chosen representation travels as a *declared capability*, never an engine
branch: ``dense_bitmap`` is a registered codec whose ``ArenaLayout``
declares ``bitmap_words`` / ``is_bitmap`` alongside the ordinary two-column
(ctrl, data) contract, so

  * the conformance harness / registry lint round-trip it like any codec
    (a ``"raw"`` wire fallback keeps it total on ineligible streams, and
    the lint checks the density boundary cases: exactly-at-threshold,
    singleton, window-overflow);
  * the device arena (``index/device.py``) and score arena
    (``index/scores.py``) notice ``is_bitmap(block)`` at staging time and
    keep, per dense block, its 128-word window + window origin ``w0``
    (4-word aligned, so column ``w0 * 32`` is a 128-lane-aligned slice) —
    plus, on the score side, a packed 4096-position code window;
  * the engine routes each (term, block) work-list entry by a dict lookup
    (``dense_slot``) into the word-parallel round kernels
    (``intersect_rounds.dense_round_accumulate``,
    ``topk.dense_score_round``) while sparse blocks of the same query take
    the decode path in the same round — exact composition, since each block
    owns disjoint docids (``BENCH_query.json`` counts the dense-served
    entries as ``blocks_dense``).

Mixed dense/sparse lists therefore fall out of the registry machinery with
zero engine special cases, and a new density policy is one codec swap.

Online serving (admission -> batch -> plan -> execute, SLO semantics):
``serve.IndexServer`` fronts one ``QueryEngine`` with an async admission
queue and a dynamic batcher.  A ``Request(terms, mode, k, tenant,
deadline_ms)`` is admitted into its tenant's bounded queue (each tenant's
share of ``queue_cap`` is proportional to its configured weight; over-share
-> explicit ``Rejected("queue_full")``, already-spent deadline ->
``Rejected("expired")`` — backpressure is always an explicit result, never
a silent stall).  The batcher seeds each batch with the earliest-deadline
pending request (EDF) and fills it by smooth weighted round-robin with
*compatible* requests only — same ``(mode, k)``; mixed modes never co-batch
— closing on size (``max_batch``) OR time (earliest member deadline minus
``slack_ms``, capped by the seed's ``max_wait_ms`` so a lone request on an
idle queue still flushes promptly), whichever hits first.  Members whose
deadline passed while queued are shed at close (``Rejected("deadline")``);
the survivors become ONE ``QueryBatch`` through the ordinary
``engine.plan()/execute()`` discipline, so served results are bitwise the
offline path's and the plan's pinned epoch makes a racing ``compact()``
invisible.  A request that starts in time but finishes late is served, not
shed — it counts against ``on_time_frac`` / ``goodput_qps`` instead of
``shed_rate``.  ``start()`` warms the hottest terms' decoded-block + score
caches and primes the jit buckets before the first real request.  Every
request leaves a five-stamp ``TraceRecord`` (enqueue <= close <= plan <=
execute <= done — monotonicity is registry-linted) and every batch a
replayable ``BatchRecord`` in ``ServerStats``; ``snapshot()`` derives
p50/p99/p999 latency, goodput, shed rate, and the per-placement batch-size
histogram.  ``benchmarks/bench_serving.py`` drives seeded Poisson and
bursty (Gamma) open-loop streams through all of this into
``BENCH_serving.json`` (committed baseline at the repo root; the smoke run
asserts zero shed under Poisson and bitwise oracle parity), and
``python -m repro.launch.serve --index --smoke`` is the end-to-end entry
point.

Sharded multi-device serving (doc-range partitioning, margin-preserving
merge): ``engine.to_device(shards=N)`` (or ``mesh=launch.mesh.serving_mesh(N)``
to pin one shard per device, ``bounds=(0, ..., n_docs)`` for explicit —
possibly uneven or empty — splits) partitions the generation **doc-wise by
contiguous docid ranges** (``index/shards.py``; ``ShardSpec.derive`` balances
per-tile posting mass read off the skip tables alone).  Doc-wise is the
partitioning under which every per-round kernel is already shard-local: a
doc's postings for *every* term live in exactly one shard, so AND candidate
bitmaps and ranked score accumulators never reference another shard's docids
— rounds run with ZERO inter-device traffic, and each shard is an ordinary
single-device ``QueryEngine`` over its slice (own arenas, skip / block-max /
stripe tables, caches).  The one subtlety is statistics: each shard
generation is rebuilt over its local docid space but with the parent's
(df, n_docs, avdl, global max impact) pinned (``shard_generation``'s fixup;
registry-linted), so per-(term, doc) quantized codes are bitwise the
unsharded arena's and per-shard quantized sums are globally comparable.
Ranked merge: every shard reports its local k-th quantized sum (ONE
all-gather of (theta, count) pairs per batch — under a mesh via
``jax.shard_map`` + ``distributed.collectives.merge_topk_stats``, else a
host stack); the merged threshold ``max_s(theta_s)`` lower-bounds the global
k-th sum, so applying the ordinary quantization-margin contract
*shard-locally* at that threshold keeps the union of per-shard candidate
bitmaps a guaranteed superset of the float top-k, and the shared block-lazy
float rescore restores bit-identity with the unsharded host oracle (every
mode, every placement — ``tests/test_sharded.py``).  Adaptive theta
promotion starts from the max pooled theta0 across shards (the argmax shard
really holds k docs reaching it); tombstone gates are sliced at shard
boundaries (``intersect_rounds.pack_live_words_range``); mutation epochs pin
per-shard generation sets atomically — the shard set is cached ON the
generation, so a racing ``compact()`` builds a fresh set for gid+1 while
in-flight plans keep serving the old one; ``plan.note`` records the shard
topology.  ``BENCH_query.json`` tracks the scaling curves per shard count
(qps per mode, merge syncs and collective bytes per ranked batch, and
cross-shard round syncs — ZERO by construction).

Observability (spans, typed metrics, the perf-regression gate): the
``repro.obs`` package is the one instrumentation layer over everything
above.

  * **Spans** (``repro.obs.trace``): the serving lifecycle is recorded on
    the server's own always-enabled ``Tracer`` — ``serve/request``
    (admission -> delivery, one detached span per request whose endpoints
    ARE the ``TraceRecord``'s enqueue/done stamps), ``serve/close`` (batch
    forming), and ``serve/batch`` with ``serve/plan`` / ``serve/execute`` /
    ``serve/deliver`` children that tile it exactly, so an exported trace
    accounts for 100% of measured batch wall-clock (the CI smoke asserts
    >= 90% via ``trace_coverage``).  Deep engine and kernel spans —
    ``engine/plan``, ``engine/execute``, ``and/seed``, ``and/round``,
    ``and/tomb_gate``, ``ranked/round``, ``ranked/tomb_gate``,
    ``ranked/rescore``, ``sharded/merge``, ``decode/<codec>``,
    ``kernel/extract_ids``, ``kernel/topk`` — go through the process-global
    tracer (``repro.obs.enable_tracing()``), DISABLED by default so the
    resident hot paths pay one attribute check; sub-engines stamp their own
    ``shard<i>`` lane.  ``to_chrome_trace(stats.tracer, get_tracer())``
    exports Chrome trace-event JSON loadable directly at
    https://ui.perfetto.dev (one named track per lane: serve / engine /
    shard<i> / device); ``python -m repro.launch.serve --index --smoke
    --trace-out trace.json`` is the one-command path (CI uploads it as the
    ``trace_smoke`` artifact).  Fenced device timing (``--fenced`` /
    ``enable_tracing(True, fenced=True)``) brackets round spans with
    ``jax.block_until_ready`` so durations attribute device wall-clock to
    the producing kernel — off by default, keeping the zero-sync
    discipline untouched; ``Tracer.profiler(logdir)`` hooks
    ``jax.profiler.trace`` for real-TPU runs.
  * **Typed metrics** (``repro.obs.metrics``): every engine owns a
    ``MetricsRegistry`` of declared counters (labels drawn from the fixed
    ``LABEL_KEYS`` vocabulary: engine / shard / placement / mode / codec /
    tenant / outcome; duplicate registration raises; schema consistency
    across instances is registry-linted via ``lint_metrics``).  The old
    free-form ``engine.dev_stats`` dict survives as a live READ-ONLY view
    (``DevStatsView``) over the same counters.  Per-call assertions use
    scoped sampling — ``with engine.metrics.scoped() as s: ...;
    s.delta("worklist_decodes")`` — instead of hand-rolled before/after
    subtraction.  ``ServerStats`` carries its own registry
    (requests/batches/latency by tenant + outcome) with Prometheus 0.0.4
    text exposition: ``stats.snapshot(prometheus=True)`` or ``launch.serve
    --metrics-out``.  Latency percentiles use the deterministic
    nearest-rank rule (``repro.obs.metrics.nearest_rank``) so tiny-n
    snapshots are reproducible observed values, monotone in q.
  * **Perf-regression gate** (``repro.obs.regress`` +
    ``tools/bench_gate.py``): the committed ``BENCH_query/mutation/
    serving.json`` baselines are enforced contracts — CI regenerates them
    at the smoke workload, then every shared ``*qps*`` leaf must hold
    ``fresh >= baseline * min_ratio`` (floors in ``BENCH_tolerances.json``,
    default 0.55) and the deterministic invariants are re-checked hard:
    ``cand_syncs == 0`` / ``score_syncs == 0`` on the resident paths,
    ``blocks_pruned > 0`` under 1% tombstones, decode dedup <= 1 per hot
    block, zero cross-shard round syncs, zero Poisson shed, bitwise serving
    parity.  ``bench_gate.py --self-test`` proves the gate has teeth by
    synthesizing a 2x qps regression and asserting it fails (which pins
    every floor into (0.5, 1.0]).

Adding a codec (protocol v2): implement ``encode(np.uint32[N]) -> Encoded``
and ``decode_np(Encoded) -> np.uint32[N]`` and register a
``repro.core.codec.Codec`` in ``repro/core/codec.py``.  Capabilities are
*declared*, not special-cased:

  * add a ``JaxDecode(args, scalar, vec)`` capability and the codec joins the
    scalar-vs-SIMD decode benchmarks and differential tests;
  * add an ``ArenaLayout`` (named padded ``ArenaColumn`` streams for one
    512-posting block + a fixed-shape ``decode_block(*column_slices,
    *column_lens, n_valid)``) and the codec's blocks decode natively in the
    device arena's batched work-lists — the arena, engine, parity tests
    (``tests/test_device_arena.py`` derives its sweep from the declarations),
    and the CI registry lint (``tools/registry_lint.py``) pick it up with no
    engine edits.  Most codecs need only the classic (ctrl, data) pair —
    declare it with the ``ArenaLayout.two_column(...)`` alias and a
    ``decode_block(ctrl, data, ctrl_len, n_valid)``.

Exception columns: a codec whose encoder patches outliers through a separate
stream (non-empty ``Encoded.exceptions`` — the Group-PFD family) must declare
a third column named ``"exceptions"`` whose ``extract`` pulls the patch
words, and apply the patch *inside* ``decode_block`` (see
``repro/core/group_pfd.py::decode_arena_block``: unpack the low bits, then a
fixed-lane vectorized ``gather_bits`` + masked scatter of (position, value)
pairs — one lane per potential exception, masked past the block's dynamic
total, so the patch never leaves the device).  Width the column for the worst
case the encoder can emit (``group_pfd.ARENA_EXC_WORDS``: every integer an
exception at the widest value width).  The registry lint round-trips a
heavy-tailed probe through every arena codec and fails any that stores
exceptions without declaring such a column, so a forgotten column is caught
in CI rather than as silently-unpatched decodes.

Migration note (deprecated v1 surface, kept as delegating shims):

  * ``engine.execute(QueryBatch(...))`` -> ``engine.execute(engine.plan(
    QueryBatch(...)))``; results are bit-identical.
  * ``QueryEngine(idx, device=True, fused=True)`` -> ``QueryEngine(idx)
    .to_device(fused=True)`` (the constructor flags warn ``DeprecationWarning``).
  * ``repro.index.query.and_query/or_query/and_query_scored`` -> build an
    engine and execute plans; the helpers now delegate to single-query plans.
  * ``CodecSpec`` and its ``decode`` / ``jax_args`` / ``decode_jax_scalar`` /
    ``decode_jax_vec`` attributes -> ``Codec`` with ``decode_np`` and the
    ``jax`` / ``arena`` capability objects (old attributes remain as
    read-only aliases).
"""

from . import (device, engine, invindex, query, scores, serve,  # noqa: F401
               shards)
