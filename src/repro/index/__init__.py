from . import invindex, query  # noqa: F401
