"""Compressed inverted index + query serving.

Layers (bottom up):

  * ``invindex`` — per-term blocked storage: d-gapped docids + TFs compressed
    with any codec from ``repro.core.codec.REGISTRY``; lists shorter than 64
    use the Stream VByte short-list fast path.  Every 512-posting block keeps
    its first docid as a skip pointer and decodes independently.
  * ``query`` — stateless one-shot AND/OR/BM25 helpers.
  * ``engine`` — the batched query engine: ``QueryBatch`` groups queries by
    term overlap, AND queries fuse skip-table block pruning with the
    vectorized intersection kernels (``repro.kernels.intersect``), and hot
    decoded blocks live in an LRU keyed by (term, block) so a batch decodes
    each block at most once.
  * ``device`` — device-resident posting arenas: the compressed blocks
    flattened into contiguous device arrays with per-(term, block)
    offset/length/first-docid tables.  ``engine.to_device()`` switches the
    serving path onto batched lane-parallel work-list decodes (one jitted
    call per AND round, deduped across the batch) and optionally the fused
    decode+bitmap-AND Pallas kernel (``repro.kernels.decode_fused``).

Adding a codec: implement ``encode(np.uint32[N]) -> Encoded`` and
``decode(Encoded) -> np.uint32[N]`` (plus optional JAX scalar/vec decoders),
register a ``CodecSpec`` in ``repro/core/codec.py``, and the index, engine,
differential tests, and benchmarks pick it up by name automatically.
"""

from . import device, engine, invindex, query  # noqa: F401
