"""Quantized impact score arenas: BM25 impacts as a device-resident column.

The ranked modes (``or`` / ``and_scored``) were the engine's last scalar
holdout: BM25 was recomputed per term on cache miss, merged on host, and
full-sorted with ``np.argsort``.  This module gives the ranked path the same
treatment the docid streams got — per-(term, doc) impacts quantized to u8 and
packed as an additional named arena column per posting block, plus the
block-max metadata a WAND/BMW-style top-k needs:

  * **global-max scalar quantization** — one scale for the whole index:
    ``delta = global_max_impact / 255`` and ``code = floor(impact / delta)``
    (clipped to 255).  Floor is *monotone*, so equal float impacts always map
    to equal codes and ``max(codes of a block) == floor(block_max / delta)``
    — the stored block-max tables are exactly the maxima of the stored codes
    (the registry lint cross-checks this).
  * **score column** — each block's <= 512 codes packed four-per-word into a
    fixed 128-word uint32 stream (:data:`SCORE_COLUMN`, the same padded
    ``ArenaColumn`` contract the codec arenas declare: value ``i`` lives in
    word ``i % 128``, bits ``8 * (i // 128)`` — the bw=8 case of
    ``decode_fused.pack_gaps``), concatenated into one ``(S, 128)`` device
    arena aligned with the block slots.
  * **block-max / term-max / top-impact tables** — per (term, block) the max
    code, per term the max code and its top-:data:`TOP_TABLE` codes sorted
    descending.  ``InvertedIndex.build`` precomputes the float form of the
    block/term maxima from the raw postings (before compression); hand-built
    indexes reconstruct them here from a decode pass.

Quantization-rank parity contract
---------------------------------
Quantized ranks need not equal float ranks; exactness is restored by a
*candidate margin*.  For a query with ``m`` (known) term occurrences and a
doc matching with quantized sum ``C``, the true score ``S`` satisfies

    C * delta <= S < (C + m) * delta                      (floor, per term)

so (1) the k-th largest quantized sum ``theta`` lower-bounds the k-th best
true score by ``theta * delta``, and (2) any doc of the true top-k must have
``C > theta - m``.  The device path therefore syncs the candidate set
``{C >= theta - m}`` (as a bitmap, one copy per batch) and rescores it with
the exact float oracle — top-k sets and scores match the host float-BM25
path bitwise, with ties broken by ascending docid (:func:`topk_select`).
The same bound makes block-max pruning sound: a (term, block) work-list
entry whose upper bound ``block_max + sum(other term maxima) + m`` cannot
reach a static threshold (``theta0``, the k-th top impact of the query's
strongest term — k docs provably score at least that) only loses
contributions of docs that are provably outside the true top-k.

Mutation epochs: every table above is computed from one generation's corpus
stats (df, doclen, avdl) and rebuilt per generation at ``compact()`` time —
never patched in place.  Between compactions, epochs that carry a delta
segment (or changed doclens) are served with pruning *disarmed* (``theta0 =
0`` and a keep-all margin): the generation-time codes then act only as
membership markers, the candidate set degenerates to the full live
membership superset, and the exact float rescore — which recomputes
:func:`bm25_scores` from the epoch's *live* df / doclen / avdl — restores
bitwise parity with a from-scratch rebuild.

**Tombstone-only epochs keep pruning armed.**  When the only mutation is
deletes (no delta docs, doclen/avdl unchanged), the live score of doc d is
``S' = sum_t R_t * s_t(d)`` where ``R_t = idf_live(t) / idf_gen(t) >= 1``
(deletes can only shrink df, which only raises idf; the tf/doclen factor is
untouched).  With ``Rmax = max_t R_t`` over the query's terms and the live
-gated accumulator (tombstoned docs never enter, so every quantized sum C is
a live doc's):

    C * delta <= S' < Rmax * delta * (C + m)

so the k-th largest live quantized sum ``theta`` still bounds the k-th best
live score by ``theta * delta``, and every true-top-k doc has
``C > theta / Rmax - m``.  The engine carries ``iq = floor(2**16 / Rmax)``
as a per-query Q16.16 deflation: thresholds compare against
``(theta * iq) >> 16 <= theta / Rmax``, which keeps both the block-max prune
and the candidate compact sound with the *generation-time* tables — blocks
whose upper bound cannot beat the deflated theta only lose docs provably
outside the live top-k.  The static seed ``theta0`` comes from
:meth:`ScoreArena.theta0_live`: the per-term top-code tables carry their
docids (``term_top_ids``) so tombstoned entries are filtered before taking
the k-th survivor.  Delta epochs still disarm as above; compaction re-arms
with fresh tables either way.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.codec import ARENA_BLOCK, ArenaColumn, get as codec_get
from repro.kernels.decode_fused import pack_gaps
from repro.kernels.intersect_rounds import bitmap_geometry

K1, B = 1.2, 0.75

CODE_MAX = 255                    # u8 quantization ceiling
TOP_TABLE = 32                    # per-term top-impact codes kept for theta0
SCORE_WORDS = ARENA_BLOCK // 4    # 512 codes packed four-per-word
STRIPE_TARGET = 512               # docid stripes per index for range bounds
STRIPE_MIN = 32                   # smallest stripe width (docids)

# the score stream as the same named-padded-column contract the codec arenas
# declare (repro.core.codec.ArenaColumn): fixed width, uint32 words, values
# masked past the block's dynamic posting count
SCORE_COLUMN = ArenaColumn("scores", SCORE_WORDS, dtype=np.uint32)


# --------------------------------------------------------------------------- #
# shared float BM25 (the exact oracle — one formula for every path)
# --------------------------------------------------------------------------- #


def bm25_scores(tfs: np.ndarray, dls: np.ndarray, df: int, n_docs: int,
                avdl: float) -> np.ndarray:
    """Element-wise float64 BM25 impacts; the host oracle, the quantizer, and
    the candidate rescore all call exactly this, so their floats are bitwise
    identical regardless of which slice of a term they score."""
    idf = np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
    tf = tfs.astype(np.float64)
    return idf * tf * (K1 + 1) / (tf + K1 * (1 - B + B * dls / avdl))


def topk_select(docs: np.ndarray, scores: np.ndarray, k: int) -> list:
    """Top-k (docid, score) pairs by descending score, ties broken by
    ascending docid — the one selection rule of every ranked path.

    ``np.argpartition`` pre-selects the k-th score so the full
    (-score, docid) lexsort only touches the k best plus their boundary ties
    (the seed path full-sorted everything with ``np.argsort``).
    """
    k = min(k, len(docs))
    if k <= 0:
        return []
    if len(docs) > 2 * k:
        kth = scores[np.argpartition(-scores, k - 1)[:k]].min()
        cand = np.flatnonzero(scores >= kth)
    else:
        cand = np.arange(len(docs))
    order = cand[np.lexsort((docs[cand], -scores[cand]))][:k]
    return [(int(docs[i]), float(scores[i])) for i in order]


# --------------------------------------------------------------------------- #
# the quantized score arena
# --------------------------------------------------------------------------- #


@jax.jit
def _unpack_rows(tiles: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """Gather + unpack packed score words: (P,) slots -> (P, 512) uint32
    codes (value i of a block at word i % 128, bits 8 * (i // 128))."""
    w = tiles[slots]                                    # (P, 128)
    parts = [(w >> jnp.uint32(8 * r)) & jnp.uint32(0xFF) for r in range(4)]
    return jnp.stack(parts, axis=1).reshape(slots.shape[0], -1)


def unpack_words_np(words: np.ndarray, n: int) -> np.ndarray:
    """Host-side unpack of one block's packed score words (lint/tests)."""
    w = np.asarray(words, np.uint32)
    out = np.stack([(w >> np.uint32(8 * r)) & np.uint32(0xFF)
                    for r in range(4)]).reshape(-1)
    return out[:n]


class ScoreArena:
    """Device-resident quantized impact scores for one ``InvertedIndex``.

    tiles:     (S, 128) uint32 device arena — slot s holds block s's packed
               codes (:data:`SCORE_COLUMN` layout).
    block_max: (S,) int32 — max code per slot (== max of the stored codes).
    slot:      {(term, block) -> s}.
    term_max:  {term -> int} max code over the term.
    term_tops: {term -> int32[<=TOP_TABLE]} top codes sorted descending.
    term_top_ids: {term -> uint32[<=TOP_TABLE]} the docids carrying those
               codes (same order; code ties broken by ascending docid), so a
               tombstone-only epoch can filter dead entries and re-derive a
               sound theta0 (:meth:`theta0_live`).
    dense_slot / dense_w0 / dense_tiles: blocks whose docid stream is
               word-parallel servable (the posting codec declares
               ``ArenaLayout.bitmap_words`` and the block is in bitmap
               format) additionally get a *window-aligned* code tile: (D,
               1024) uint32, window position p (docid ``w0 * 32 + p``) at
               word ``p >> 2``, bits ``8 * (p & 3)`` — the layout
               ``kernels/topk.dense_score_round`` adds as one contiguous
               4096-column window, no unpack/scatter.  ``w0`` follows the
               device arena's 4-word-aligned clamp, so both views of a dense
               block agree on the window.
    stripes:   {term -> int32[n_stripes]} max code per fixed docid stripe of
               ``stripe_width`` docids — the range bound for block-max
               pruning.  Posting blocks of a sparse term span the whole
               docid space, so block granularity cannot localize it; the
               stripe table is keyed by *docid*, so a range where the term
               has no posting bounds to 0.
    delta:     the quantization scale (global max impact / 255).
    """

    def __init__(self, idx):
        self.idx = idx
        n_docs = idx.n_docs
        doclen = np.asarray(idx.doclen)
        avdl = idx.avdl
        # doc-range shard generations (repro.index.shards) carry the PARENT
        # index's corpus statistics: df is already global in their fixed-up
        # TermPostings, and stat_n_docs / stat_avdl / stat_gmax pin n_docs,
        # avdl, and the quantizer scale to the parent's values so a shard's
        # code for (term, doc) is bitwise the unsharded arena's code.  Only
        # the *geometry* (stripe width, bitmap words, dense windows) stays
        # local to the shard's doc range.
        stat_n = int(getattr(idx, "stat_n_docs", n_docs))
        stat_avdl = float(getattr(idx, "stat_avdl", avdl))
        # pass 1: float impacts per block (build-time tables give the global
        # max without decoding; hand-assembled indexes reconstruct lazily)
        gmax = 0.0
        for t in idx.terms:
            gmax = max(gmax, float(idx.impact_block_max(t).max(initial=0.0)))
        gmax = float(getattr(idx, "stat_gmax", gmax))
        self.gmax = gmax
        self.delta = (gmax / CODE_MAX) if gmax > 0 else 1.0
        # docid stripes sized for ~STRIPE_TARGET range-bound cells per index
        self.stripe_width = max(STRIPE_MIN, -(-n_docs // STRIPE_TARGET))
        n_stripes = max(1, -(-n_docs // self.stripe_width))
        words_total = bitmap_geometry(n_docs)[0]
        # pass 2: quantize per-posting impacts into the packed column
        tiles, bmax = [], []
        dense_tiles, dense_w0 = [], []
        self.slot: dict = {}
        self.dense_slot: dict = {}
        self.term_max: dict = {}
        self.term_tops: dict = {}
        self.term_top_ids: dict = {}
        self.stripes: dict = {}
        for t, tp in idx.terms.items():
            codes_all, ids_all = [], []
            stripe = np.zeros(n_stripes, np.int32)
            for bi in range(len(tp.blocks)):
                ids, tfs = idx.decode_block(t, bi)
                sc = bm25_scores(tfs, doclen[ids], tp.df, stat_n, stat_avdl)
                codes = np.minimum(np.floor(sc / self.delta),
                                   CODE_MAX).astype(np.uint32)
                self.slot[(t, bi)] = len(tiles)
                tiles.append(pack_gaps(codes, 8)[0])
                bmax.append(int(codes.max(initial=0)))
                codes_all.append(codes)
                ids_all.append(ids)
                np.maximum.at(stripe, ids // self.stripe_width,
                              codes.astype(np.int32))
                encg = tp.blocks[bi][1]
                lay = codec_get(encg.codec).arena
                if (lay is not None and lay.bitmap_words
                        and lay.is_bitmap is not None and lay.is_bitmap(encg)):
                    # window-aligned code tile for word-parallel serving:
                    # same w0 formula as the device arena's dense windows
                    bw = lay.bitmap_words
                    w0 = min((int(ids[0]) >> 5) & ~3, words_total - bw)
                    pos = ids.astype(np.int64) - w0 * 32
                    tile = np.zeros(bw * 8, np.uint32)     # bw*32 / 4 words
                    np.bitwise_or.at(tile, pos >> 2,
                                     codes << ((pos & 3) * 8).astype(np.uint32))
                    self.dense_slot[(t, bi)] = len(dense_tiles)
                    dense_tiles.append(tile)
                    dense_w0.append(w0)
            cat = (np.concatenate(codes_all) if codes_all
                   else np.zeros(0, np.uint32))
            ids_cat = (np.concatenate(ids_all) if ids_all
                       else np.zeros(0, np.uint32))
            self.term_max[t] = int(cat.max(initial=0))
            order = np.lexsort((ids_cat, -cat.astype(np.int64)))[:TOP_TABLE]
            self.term_tops[t] = cat[order].astype(np.int32)
            self.term_top_ids[t] = ids_cat[order].astype(np.uint32)
            self.stripes[t] = stripe
        self.block_max = np.asarray(bmax, np.int32)
        self.tiles = (jnp.asarray(np.stack(tiles)) if tiles
                      else jnp.zeros((1, SCORE_WORDS), jnp.uint32))
        self.dense_w0 = np.asarray(dense_w0, np.int32)
        self.dense_tiles = (jnp.asarray(np.stack(dense_tiles)) if dense_tiles
                            else None)

    @classmethod
    def from_index(cls, idx) -> "ScoreArena":
        return cls(idx)

    # ---- device decode ------------------------------------------------------ #

    def rows(self, pairs: list) -> jnp.ndarray:
        """Decode a work-list of (term, block) score entries WITHOUT a host
        copy: (len(pairs), 512) uint32 code rows, zero past each block's
        posting count (the packing zero-pads)."""
        slots = np.asarray([self.slot[p] for p in pairs], np.int64)
        return _unpack_rows(self.tiles, jnp.asarray(slots))

    # ---- WAND metadata ------------------------------------------------------ #

    def theta0(self, terms: list, k: int) -> int:
        """Static per-query threshold: the k-th top impact code of the
        query's strongest term — k docs of that term provably reach it, so it
        lower-bounds the k-th best total (sound for OR; see the module
        docstring).  0 when no term has k postings or k > TOP_TABLE."""
        best = 0
        for t in terms:
            tops = self.term_tops.get(t)
            if tops is not None and k <= len(tops):
                best = max(best, int(tops[k - 1]))
        return best

    def theta0_live(self, terms: list, k: int, dead: np.ndarray) -> int:
        """:meth:`theta0` for a tombstone-only epoch: tombstoned entries are
        filtered out of the per-term top-code table (``term_top_ids``)
        before taking the k-th survivor, so the k docs backing the bound are
        all live.  Sound but weaker than a rebuild's table when more than
        ``TOP_TABLE - k`` of a term's top codes are dead (the k-th survivor
        may fall off the table — then that term contributes 0)."""
        if len(dead) == 0:
            return self.theta0(terms, k)
        best = 0
        for t in terms:
            tops = self.term_tops.get(t)
            if tops is None or not len(tops):
                continue
            alive = tops[~np.isin(self.term_top_ids[t].astype(np.int64),
                                  dead)]
            if k <= len(alive):
                best = max(best, int(alive[k - 1]))
        return best

    def range_max(self, t: int, lo: int, hi: int) -> int:
        """Max code of term t over the docid range [lo, hi] — the BMW-style
        aligned bound, from the stripe table: 0 when the term has no posting
        in any stripe the range touches."""
        stripe = self.stripes[t]
        j0 = lo // self.stripe_width
        j1 = hi // self.stripe_width + 1
        return int(stripe[j0:j1].max(initial=0))

    def range_max_many(self, t: int, los: np.ndarray,
                       his: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`range_max` over per-block [lo, hi] ranges (the
        prune pass calls this once per other term per round, not per block):
        segment maxima via ``np.maximum.reduceat`` over the stripe table."""
        if len(los) == 0:
            return np.zeros(0, np.int64)
        j0 = np.asarray(los) // self.stripe_width
        j1 = np.asarray(his) // self.stripe_width + 1
        # sentinel keeps every reduceat index in range (j1 can equal the
        # stripe count); a [j0, j1) segment never reaches it since j1 > j0
        ext = np.append(self.stripes[t], np.int32(0))
        idx = np.empty(2 * len(j0), np.int64)
        idx[0::2] = j0
        idx[1::2] = j1
        return np.maximum.reduceat(ext, idx)[0::2].astype(np.int64)
