"""Compressed inverted index (paper §7.4/§7.5).

Per term: d-gapped docids + TFs compressed with a selected codec; posting
lists shorter than 64 fall back to Variable Byte (paper §7.5).  Block-level
skip pointers every 512 postings (first docid + compressed offsets per block)
support AND-query skipping without decoding whole lists.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import codec as codec_lib
from repro.core.dgap import dgap_decode_np, dgap_encode_np

SKIP = 512
SHORT = 64


@dataclasses.dataclass
class TermPostings:
    df: int
    blocks: list                   # list of (first_docid, enc_gaps, enc_tfs)

    def nbytes(self) -> int:
        return sum(g.nbytes() + t.nbytes() for _, g, t in self.blocks) + 8 * len(self.blocks)


@dataclasses.dataclass
class InvertedIndex:
    codec: str
    terms: dict
    n_docs: int
    doclen: np.ndarray

    @staticmethod
    def build(doclen: np.ndarray, postings: dict, codec: str = "group_simple") -> "InvertedIndex":
        spec = codec_lib.get(codec)
        vb = codec_lib.get("varbyte")
        terms = {}
        for t, (docids, tfs) in postings.items():
            use = spec if len(docids) >= SHORT else vb
            blocks = []
            for i in range(0, len(docids), SKIP):
                ids = docids[i:i + SKIP]
                gaps = dgap_encode_np(ids)
                gaps = gaps.copy()
                gaps[0] = 0                      # first docid kept in the skip entry
                blocks.append((int(ids[0]), use.encode(gaps), use.encode(tfs[i:i + SKIP])))
            terms[t] = TermPostings(len(docids), blocks)
        return InvertedIndex(codec, terms, len(doclen), np.asarray(doclen))

    def decode_term(self, t: int, min_docid: int = 0):
        """Decode postings, skipping blocks entirely below min_docid."""
        tp = self.terms[t]
        ids_out, tf_out = [], []
        for bi, (first, encg, enct) in enumerate(tp.blocks):
            nxt = tp.blocks[bi + 1][0] if bi + 1 < len(tp.blocks) else None
            if nxt is not None and nxt <= min_docid:
                continue                         # skip pointer: whole block below
            gaps = codec_lib.get(encg.codec).decode(encg)
            ids = dgap_decode_np(gaps) + np.uint32(first)
            ids_out.append(ids)
            tf_out.append(codec_lib.get(enct.codec).decode(enct))
        if not ids_out:
            return np.zeros(0, np.uint32), np.zeros(0, np.uint32)
        return np.concatenate(ids_out), np.concatenate(tf_out)

    def size_bytes(self) -> int:
        return sum(tp.nbytes() for tp in self.terms.values())
