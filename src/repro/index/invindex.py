"""Compressed inverted index (paper §7.4/§7.5), now an LSM handle over
immutable compressed **generations**.

:class:`Generation` is the paper's one-shot index made explicit as an
immutable segment: per term, d-gapped docids + TFs compressed with a selected
codec from the ``repro.core.codec`` registry (any
:class:`repro.core.codec.Codec`); posting lists shorter than 64 fall back to
Stream VByte (the §7.5 VByte fallback upgraded to a separated-control layout
that decodes branch-free).  Block-level skip pointers every 512 postings
(first docid + compressed blocks) support AND-query skipping without decoding
whole lists.  The block is also the unit of the batched query engine
(``repro.index.engine``): ``decode_block`` decompresses exactly one block,
and ``block_firsts`` exposes the skip table so the engine can prune blocks by
candidate docid range *before* any decompression happens.  Once built, a
generation's blocks, skip tables, impact tables, and device arenas never
change — caches and in-flight execution plans key on its ``gid``.

:class:`InvertedIndex` is the mutable handle serving reads while absorbing
writes, LSM-style (``repro.index.segments``):

  * ``insert(docid, terms, doclen)`` lands in a small host-side
    :class:`~repro.index.segments.DeltaSegment`; inserting a docid the
    current generation holds tombstones the base copy first (shadowing), so
    generation and delta stay disjoint per doc.
  * ``delete(docid)`` drops the delta copy or adds a
    :class:`~repro.index.segments.Tombstones` entry for the base copy —
    served as a live-bitmap gate on every probe, never by touching blocks.
  * ``compact()`` re-encodes the merged live postings (generation minus
    tombstones, plus delta) through the same codec registry into the next
    generation (``gid + 1``) — the short-list fallback is re-evaluated per
    term — and atomically swaps it in; delta, tombstones, and doclen
    overrides reset to empty.

Query results under mutation are the union of generation results (tombstone
-gated) and a brute-force scan of the small delta segment, bitwise identical
to rebuilding from scratch with ``InvertedIndex.build(doclen_now(),
live_postings)`` — the contract ``tests/test_mutation.py`` enforces.  Docid
space is append-only: deleting never shrinks ``doc_space`` and a deleted
doc's last doclen stays in ``doclen_now()`` (exactly what a from-scratch
rebuild would be given).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import codec as codec_lib
from repro.core.dgap import dgap_decode_np, dgap_encode_np
from .segments import DeltaSegment, Tombstones

SKIP = 512
SHORT = 64
SHORT_CODEC = "stream_vbyte"

_EMPTY_POSTINGS = (np.zeros(0, np.uint32), np.zeros(0, np.uint32))


@dataclasses.dataclass
class TermPostings:
    df: int
    blocks: list                   # list of (first_docid, enc_gaps, enc_tfs)
    lasts: np.ndarray = None       # last docid per block (skip upper bounds)
    impact_bmax: np.ndarray = None  # max float BM25 impact per block (WAND)

    def nbytes(self) -> int:
        # + 4 per block for the last-docid column next to the skip pointer
        return sum(g.nbytes() + t.nbytes() for _, g, t in self.blocks) + 12 * len(self.blocks)


class Generation:
    """One immutable compressed index segment.

    Everything the serving paths consume — compressed blocks, skip tables,
    WAND impact tables, the cached device arena — hangs off a generation and
    is identified by its ``gid``; ``compact()`` builds the next generation
    instead of editing this one, so plans pinned to it keep executing
    bit-identically while the handle swaps forward.
    """

    def __init__(self, codec: str, terms: dict, n_docs: int,
                 doclen: np.ndarray, gid: int = 0):
        self.codec = codec
        self.terms = terms
        self.n_docs = n_docs
        self.doclen = doclen
        self.gid = gid

    @property
    def avdl(self) -> float:
        """Mean document length — THE value every BM25 site uses (scorer,
        quantizer, rescore): one cached implementation so their floats
        cannot drift apart."""
        a = getattr(self, "_avdl", None)
        if a is None:
            a = float(np.asarray(self.doclen).mean()) if self.n_docs else 1.0
            self._avdl = a
        return a

    @staticmethod
    def build(doclen: np.ndarray, postings: dict,
              codec: str = "group_simple", gid: int = 0) -> "Generation":
        from repro.core import dense_bitmap   # the density policy lives there
        from .scores import bm25_scores   # local: scores sits above invindex
        spec = codec_lib.get(codec)
        short = codec_lib.get(SHORT_CODEC)
        dense = codec_lib.get(dense_bitmap.NAME)
        doclen = np.asarray(doclen)
        n_docs = len(doclen)
        # built empty-first so the impact tables read the one cached avdl
        gen = Generation(codec, {}, n_docs, doclen, gid)
        avdl = gen.avdl
        terms = gen.terms
        for t, (docids, tfs) in postings.items():
            base = spec if len(docids) >= SHORT else short
            blocks, lasts, bmax = [], [], []
            for i in range(0, len(docids), SKIP):
                ids = docids[i:i + SKIP]
                # density decision, per block at build time: past the cutoff
                # the docid stream is stored as a raw 128-word bitmap and
                # served word-parallel; everything downstream discovers the
                # choice through the registry (the Encoded names its codec)
                use = dense if dense_bitmap.eligible(ids) else base
                gaps = dgap_encode_np(ids)
                gaps = gaps.copy()
                gaps[0] = 0                      # first docid kept in the skip entry
                # TFs are not a sorted docid stream: always the base codec
                blocks.append((int(ids[0]), use.encode(gaps), base.encode(tfs[i:i + SKIP])))
                lasts.append(int(ids[-1]))
                # WAND block-max metadata, from the raw postings (no decode)
                sc = bm25_scores(tfs[i:i + SKIP], doclen[ids], len(docids),
                                 n_docs, avdl)
                bmax.append(float(sc.max(initial=0.0)))
            terms[t] = TermPostings(len(docids), blocks,
                                    np.asarray(lasts, np.int64),
                                    np.asarray(bmax, np.float64))
        return gen

    def to_device(self, build_fused: bool = True):
        """Flatten the compressed blocks into device-resident arenas
        (``repro.index.device.DeviceArena``); cached per generation after the
        first call.  A cached arena built without fused tiles is upgraded in
        place when ``build_fused=True`` asks for them later."""
        arena = getattr(self, "_arena", None)
        if arena is None:
            from .device import DeviceArena
            arena = DeviceArena.from_index(self, build_fused=build_fused)
            self._arena = arena
        elif build_fused:
            arena.ensure_fused()
        return arena

    def n_blocks(self, t: int) -> int:
        return len(self.terms[t].blocks)

    def block_firsts(self, t: int) -> np.ndarray:
        """Skip table: first docid of each block of term t (ascending)."""
        return np.asarray([b[0] for b in self.terms[t].blocks], np.int64)

    def block_lasts(self, t: int) -> np.ndarray:
        """Skip upper bounds: last docid of each block of term t.  Stored at
        build time; reconstructed once (and cached) for indexes whose blocks
        were assembled by hand."""
        tp = self.terms[t]
        if tp.lasts is None or len(tp.lasts) != len(tp.blocks):
            tp.lasts = np.asarray(
                [int(self.decode_block_ids(t, bi)[-1])
                 for bi in range(len(tp.blocks))], np.int64)
        return tp.lasts

    def impact_block_max(self, t: int) -> np.ndarray:
        """WAND metadata: max float BM25 impact per block of term t.  Stored
        at build time (computed from the raw postings); reconstructed once
        (and cached) from a decode pass for hand-assembled indexes."""
        tp = self.terms[t]
        if tp.impact_bmax is None or len(tp.impact_bmax) != len(tp.blocks):
            from .scores import bm25_scores
            doclen = np.asarray(self.doclen)
            out = []
            for bi in range(len(tp.blocks)):
                ids, tfs = self.decode_block(t, bi)
                sc = bm25_scores(tfs, doclen[ids], tp.df, self.n_docs,
                                 self.avdl)
                out.append(float(sc.max(initial=0.0)))
            tp.impact_bmax = np.asarray(out, np.float64)
        return tp.impact_bmax

    def decode_block_ids(self, t: int, bi: int) -> np.ndarray:
        """Decompress only the docids of one block (AND queries skip TFs)."""
        first, encg, _ = self.terms[t].blocks[bi]
        gaps = codec_lib.get(encg.codec).decode_np(encg)
        return dgap_decode_np(gaps) + np.uint32(first)

    def decode_block_tfs(self, t: int, bi: int) -> np.ndarray:
        _, _, enct = self.terms[t].blocks[bi]
        return codec_lib.get(enct.codec).decode_np(enct)

    def decode_block(self, t: int, bi: int):
        """Decompress exactly one posting block -> (docids, tfs)."""
        return self.decode_block_ids(t, bi), self.decode_block_tfs(t, bi)

    def decode_term(self, t: int, min_docid: int = 0):
        """Decode postings, skipping blocks entirely below min_docid."""
        tp = self.terms[t]
        ids_out, tf_out = [], []
        for bi in range(len(tp.blocks)):
            nxt = tp.blocks[bi + 1][0] if bi + 1 < len(tp.blocks) else None
            if nxt is not None and nxt <= min_docid:
                continue                         # skip pointer: whole block below
            ids, tfs = self.decode_block(t, bi)
            ids_out.append(ids)
            tf_out.append(tfs)
        if not ids_out:
            return _EMPTY_POSTINGS
        return np.concatenate(ids_out), np.concatenate(tf_out)

    def size_bytes(self) -> int:
        return sum(tp.nbytes() for tp in self.terms.values())


class InvertedIndex:
    """Mutable LSM handle over one current :class:`Generation`.

    Reads delegate to the current generation (``codec`` / ``terms`` /
    ``decode_block`` / ``to_device`` / … keep their one-shot semantics, so
    the entire pre-mutation surface is unchanged); writes go to ``delta`` /
    ``tomb`` (see the module docstring for the lifecycle).  ``epoch`` is the
    mutation clock callers key caches and plan snapshots on.
    """

    def __init__(self, codec: str = "group_simple", terms: dict | None = None,
                 n_docs: int = 0, doclen: np.ndarray | None = None, *,
                 gen: Generation | None = None):
        if gen is None:
            doclen = (np.asarray(doclen) if doclen is not None
                      else np.zeros(n_docs, np.int64))
            gen = Generation(codec, {} if terms is None else terms,
                             n_docs, doclen)
        self._gen = gen
        self.delta = DeltaSegment()
        self.tomb = Tombstones()
        self._dl_over: dict = {}     # docid -> last-set doclen, cleared at compact
        self._dl_cache = None        # (delta.version, doclen_now array)

    @staticmethod
    def build(doclen: np.ndarray, postings: dict,
              codec: str = "group_simple") -> "InvertedIndex":
        return InvertedIndex(gen=Generation.build(doclen, postings, codec))

    # ---- the immutable read surface (delegated to the current generation) --- #

    @property
    def gen(self) -> Generation:
        return self._gen

    @property
    def codec(self) -> str:
        return self._gen.codec

    @property
    def terms(self) -> dict:
        return self._gen.terms

    @property
    def n_docs(self) -> int:
        """Docs in the current generation (the device bitmap geometry); the
        mutable doc space including delta-only docids is ``doc_space``."""
        return self._gen.n_docs

    @property
    def doclen(self) -> np.ndarray:
        """The current generation's doclen column; the live view including
        delta inserts and doclen overrides is ``doclen_now()``."""
        return self._gen.doclen

    @property
    def avdl(self) -> float:
        return self._gen.avdl

    def to_device(self, build_fused: bool = True):
        return self._gen.to_device(build_fused=build_fused)

    def n_blocks(self, t: int) -> int:
        return self._gen.n_blocks(t)

    def block_firsts(self, t: int) -> np.ndarray:
        return self._gen.block_firsts(t)

    def block_lasts(self, t: int) -> np.ndarray:
        return self._gen.block_lasts(t)

    def impact_block_max(self, t: int) -> np.ndarray:
        return self._gen.impact_block_max(t)

    def decode_block_ids(self, t: int, bi: int) -> np.ndarray:
        return self._gen.decode_block_ids(t, bi)

    def decode_block_tfs(self, t: int, bi: int) -> np.ndarray:
        return self._gen.decode_block_tfs(t, bi)

    def decode_block(self, t: int, bi: int):
        return self._gen.decode_block(t, bi)

    def decode_term(self, t: int, min_docid: int = 0):
        return self._gen.decode_term(t, min_docid)

    def size_bytes(self) -> int:
        return self._gen.size_bytes()

    # ---- mutation ----------------------------------------------------------- #

    @property
    def mutated(self) -> bool:
        """True when serving must consult delta/tombstone state (i.e. the
        handle has diverged from its current generation)."""
        return bool(self.tomb) or bool(self.delta) or bool(self._dl_over)

    @property
    def epoch(self) -> tuple:
        """(gid, tombstone version, delta version) — changes on every
        mutation and every compaction; cache keys and plan snapshots carry
        it so no state from one epoch can serve another."""
        return (self._gen.gid, self.tomb.version, self.delta.version)

    @property
    def doc_space(self) -> int:
        """Size of the append-only docid space: generation docs plus every
        docid ever inserted since (deletes never shrink it)."""
        return max(self._gen.n_docs, max(self._dl_over, default=-1) + 1)

    def insert(self, docid: int, terms: dict, doclen: int) -> None:
        """Insert (or upsert) one document into the delta segment.  A docid
        the current generation holds is tombstoned first, so its base
        postings are shadowed and the generation/delta doc sets stay
        disjoint."""
        self.delta.insert(docid, terms, doclen)      # validates its inputs
        docid = int(docid)
        if docid < self._gen.n_docs:
            self.tomb.add(docid)
        self._dl_over[docid] = int(doclen)

    def delete(self, docid: int) -> bool:
        """Delete one document; True if it was live.  Delta copies are
        dropped outright; base copies become tombstones (their blocks are
        immutable — serving gates them out instead)."""
        docid = int(docid)
        if self.delta.remove(docid):
            return True
        if docid < self._gen.n_docs and docid not in self.tomb:
            self.tomb.add(docid)
            return True
        return False

    def doclen_now(self) -> np.ndarray:
        """Frozen int64 doclen over [0, doc_space): the generation column
        extended by every doclen override since (inserts win; deleted docs
        keep their last-set length; never-inserted slots past the generation
        are 0) — exactly the array a from-scratch rebuild would be given."""
        if not self.mutated:
            return self._gen.doclen
        if self._dl_cache is not None and self._dl_cache[0] == self.delta.version:
            return self._dl_cache[1]
        g = self._gen
        dl = np.zeros(self.doc_space, np.int64)
        dl[:g.n_docs] = np.asarray(g.doclen)
        if self._dl_over:
            k = np.fromiter(self._dl_over.keys(), np.int64, len(self._dl_over))
            v = np.fromiter(self._dl_over.values(), np.int64, len(self._dl_over))
            dl[k] = v
        dl.setflags(write=False)
        self._dl_cache = (self.delta.version, dl)
        return dl

    def compact(self) -> Generation:
        """Merge generation-minus-tombstones with the delta segment and
        re-encode through the codec registry into the next generation
        (``gid + 1``), atomically swapped in; delta/tombstone state resets.

        The merge is the rebuild contract made literal: per term, the
        generation's live postings (tombstoned docids dropped via the skip
        -aware decode) and the delta postings — disjoint by the shadowing
        invariant — are merge-sorted and handed to :meth:`Generation.build`
        with ``doclen_now()``.  Terms with zero live postings are dropped,
        and the short-list codec fallback is re-decided per term from the
        merged length.  Returns the new generation.
        """
        g = self._gen
        new_doclen = np.array(self.doclen_now())         # unfrozen copy
        dead = self.tomb.sorted_ids(below=g.n_docs)
        all_terms = set(g.terms)
        for _, (_, ts) in self.delta.items():
            all_terms.update(ts)
        merged = {}
        for t in sorted(all_terms):
            if t in g.terms:
                ids, tfs = g.decode_term(t)
                if len(dead) and len(ids):
                    keep = ~np.isin(ids.astype(np.int64), dead)
                    ids, tfs = ids[keep], tfs[keep]
            else:
                ids, tfs = _EMPTY_POSTINGS
            dids, dtfs = self.delta.postings(t)
            if len(dids):
                ids = np.concatenate([ids, dids])
                tfs = np.concatenate([tfs, dtfs])
                order = np.argsort(ids, kind="stable")
                ids, tfs = ids[order], tfs[order]
            if len(ids):
                merged[t] = (ids.astype(np.uint32), tfs.astype(np.uint32))
        self._gen = Generation.build(new_doclen, merged, codec=g.codec,
                                     gid=g.gid + 1)
        self.delta = DeltaSegment()
        self.tomb = Tombstones()
        self._dl_over = {}
        self._dl_cache = None
        return self._gen
