"""Compressed inverted index (paper §7.4/§7.5).

Per term: d-gapped docids + TFs compressed with a selected codec from the
``repro.core.codec`` registry (any :class:`repro.core.codec.Codec`); posting
lists shorter than 64 fall back to Stream VByte (the byte-oriented short-list
fast path — the paper's §7.5 VByte fallback upgraded to a separated-control
layout that decodes branch-free).  Block-level skip pointers every 512
postings (first docid + compressed blocks) support AND-query skipping without
decoding whole lists.

The block is also the unit of the batched query engine
(``repro.index.engine``): ``decode_block`` decompresses exactly one block, and
``block_firsts`` exposes the skip table so the engine can prune blocks by
candidate docid range *before* any decompression happens (fused
decode-and-intersect).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import codec as codec_lib
from repro.core.dgap import dgap_decode_np, dgap_encode_np

SKIP = 512
SHORT = 64
SHORT_CODEC = "stream_vbyte"


@dataclasses.dataclass
class TermPostings:
    df: int
    blocks: list                   # list of (first_docid, enc_gaps, enc_tfs)
    lasts: np.ndarray = None       # last docid per block (skip upper bounds)
    impact_bmax: np.ndarray = None  # max float BM25 impact per block (WAND)

    def nbytes(self) -> int:
        # + 4 per block for the last-docid column next to the skip pointer
        return sum(g.nbytes() + t.nbytes() for _, g, t in self.blocks) + 12 * len(self.blocks)


@dataclasses.dataclass
class InvertedIndex:
    codec: str
    terms: dict
    n_docs: int
    doclen: np.ndarray

    @property
    def avdl(self) -> float:
        """Mean document length — THE value every BM25 site uses (scorer,
        quantizer, rescore): one cached implementation so their floats
        cannot drift apart."""
        a = getattr(self, "_avdl", None)
        if a is None:
            a = float(np.asarray(self.doclen).mean()) if self.n_docs else 1.0
            self._avdl = a
        return a

    @staticmethod
    def build(doclen: np.ndarray, postings: dict, codec: str = "group_simple") -> "InvertedIndex":
        from .scores import bm25_scores   # local: scores sits above invindex
        spec = codec_lib.get(codec)
        short = codec_lib.get(SHORT_CODEC)
        doclen = np.asarray(doclen)
        n_docs = len(doclen)
        # built empty-first so the impact tables read the one cached avdl
        idx = InvertedIndex(codec, {}, n_docs, doclen)
        avdl = idx.avdl
        terms = idx.terms
        for t, (docids, tfs) in postings.items():
            use = spec if len(docids) >= SHORT else short
            blocks, lasts, bmax = [], [], []
            for i in range(0, len(docids), SKIP):
                ids = docids[i:i + SKIP]
                gaps = dgap_encode_np(ids)
                gaps = gaps.copy()
                gaps[0] = 0                      # first docid kept in the skip entry
                blocks.append((int(ids[0]), use.encode(gaps), use.encode(tfs[i:i + SKIP])))
                lasts.append(int(ids[-1]))
                # WAND block-max metadata, from the raw postings (no decode)
                sc = bm25_scores(tfs[i:i + SKIP], doclen[ids], len(docids),
                                 n_docs, avdl)
                bmax.append(float(sc.max(initial=0.0)))
            terms[t] = TermPostings(len(docids), blocks,
                                    np.asarray(lasts, np.int64),
                                    np.asarray(bmax, np.float64))
        return idx

    def to_device(self, build_fused: bool = True):
        """Flatten the compressed blocks into device-resident arenas
        (``repro.index.device.DeviceArena``); cached after the first call.
        A cached arena built without fused tiles is upgraded in place when
        ``build_fused=True`` asks for them later."""
        arena = getattr(self, "_arena", None)
        if arena is None:
            from .device import DeviceArena
            arena = DeviceArena.from_index(self, build_fused=build_fused)
            self._arena = arena
        elif build_fused:
            arena.ensure_fused()
        return arena

    def n_blocks(self, t: int) -> int:
        return len(self.terms[t].blocks)

    def block_firsts(self, t: int) -> np.ndarray:
        """Skip table: first docid of each block of term t (ascending)."""
        return np.asarray([b[0] for b in self.terms[t].blocks], np.int64)

    def block_lasts(self, t: int) -> np.ndarray:
        """Skip upper bounds: last docid of each block of term t.  Stored at
        build time; reconstructed once (and cached) for indexes whose blocks
        were assembled by hand."""
        tp = self.terms[t]
        if tp.lasts is None or len(tp.lasts) != len(tp.blocks):
            tp.lasts = np.asarray(
                [int(self.decode_block_ids(t, bi)[-1])
                 for bi in range(len(tp.blocks))], np.int64)
        return tp.lasts

    def impact_block_max(self, t: int) -> np.ndarray:
        """WAND metadata: max float BM25 impact per block of term t.  Stored
        at build time (computed from the raw postings); reconstructed once
        (and cached) from a decode pass for hand-assembled indexes."""
        tp = self.terms[t]
        if tp.impact_bmax is None or len(tp.impact_bmax) != len(tp.blocks):
            from .scores import bm25_scores
            doclen = np.asarray(self.doclen)
            out = []
            for bi in range(len(tp.blocks)):
                ids, tfs = self.decode_block(t, bi)
                sc = bm25_scores(tfs, doclen[ids], tp.df, self.n_docs,
                                 self.avdl)
                out.append(float(sc.max(initial=0.0)))
            tp.impact_bmax = np.asarray(out, np.float64)
        return tp.impact_bmax

    def decode_block_ids(self, t: int, bi: int) -> np.ndarray:
        """Decompress only the docids of one block (AND queries skip TFs)."""
        first, encg, _ = self.terms[t].blocks[bi]
        gaps = codec_lib.get(encg.codec).decode_np(encg)
        return dgap_decode_np(gaps) + np.uint32(first)

    def decode_block_tfs(self, t: int, bi: int) -> np.ndarray:
        _, _, enct = self.terms[t].blocks[bi]
        return codec_lib.get(enct.codec).decode_np(enct)

    def decode_block(self, t: int, bi: int):
        """Decompress exactly one posting block -> (docids, tfs)."""
        return self.decode_block_ids(t, bi), self.decode_block_tfs(t, bi)

    def decode_term(self, t: int, min_docid: int = 0):
        """Decode postings, skipping blocks entirely below min_docid."""
        tp = self.terms[t]
        ids_out, tf_out = [], []
        for bi in range(len(tp.blocks)):
            nxt = tp.blocks[bi + 1][0] if bi + 1 < len(tp.blocks) else None
            if nxt is not None and nxt <= min_docid:
                continue                         # skip pointer: whole block below
            ids, tfs = self.decode_block(t, bi)
            ids_out.append(ids)
            tf_out.append(tfs)
        if not ids_out:
            return np.zeros(0, np.uint32), np.zeros(0, np.uint32)
        return np.concatenate(ids_out), np.concatenate(tf_out)

    def size_bytes(self) -> int:
        return sum(tp.nbytes() for tp in self.terms.values())
