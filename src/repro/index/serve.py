"""Latency-governed online serving over the batched query engine.

Every other entry point in this repo measures *offline* batch throughput:
the caller already holds a ``QueryBatch`` and wants it executed as fast as
possible.  Production traffic is the opposite shape — single requests
arriving as a stream, each with a tail-latency budget — and the
device-resident kernels only pay off if batches *form* fast enough to feed
them.  This module is the bridge: an async admission queue in front of the
existing ``plan()/execute()`` discipline.

    ┌─ submit(Request) ──► per-tenant bounded queues ──► dynamic batcher ─┐
    │   (admission: expired / queue-full requests get    (close on size   │
    │    an explicit Rejected, never a silent stall)      OR earliest     │
    │                                                     deadline)       │
    └──────────► QueryBatch ──► engine.plan() ──► engine.execute() ◄──────┘
                 (one plan per batch; only same-(mode, k) requests
                  co-batch — results are bitwise the offline path's)

Lifecycle of one request (the five trace stages, stamped monotonically):

  1. **enqueue** — ``submit()`` validates the deadline (a request whose
     budget is already spent is rejected *now*, not after wasting a batch
     slot) and appends to its tenant's queue; a tenant over its weighted
     share of the global ``queue_cap`` gets ``Rejected("queue_full")``
     (backpressure, never unbounded growth).
  2. **batch close** — the batcher seeds a batch with the earliest-deadline
     pending request and fills it by smooth weighted round-robin across
     tenants (``tenants`` weights: a tenant with twice the weight gets
     about twice the slots under contention) with *compatible* requests
     only (same ``mode`` and ``k`` — mixed modes never co-batch).  The
     batch closes when it reaches ``max_batch`` OR when the earliest
     member deadline (minus ``slack_ms``) or the seed's ``max_wait_ms``
     budget hits — whichever comes first.  Members whose deadline already
     passed at close are shed with ``Rejected("deadline")``.
  3. **plan** — one ``engine.plan(QueryBatch(...), placement=...)`` per
     batch; the plan pins the mutation epoch, so a ``compact()`` landing
     between close and execution cannot change results.
  4. **execute** — ``engine.execute(plan)`` in a single worker thread (the
     engine is not thread-safe; admission stays live on the event loop
     while the batch runs, so arrivals keep their true enqueue stamps).
  5. **rescore / deliver** — results are split back to the per-request
     futures; the stamp closes the trace.

Every request leaves a :class:`TraceRecord` and every batch a
:class:`BatchRecord` in :class:`ServerStats` — enough to recompute latency
percentiles, goodput, shed rate, the achieved batch-size histogram per
placement, AND to replay any batch through the offline ``plan()/execute()``
oracle for bitwise parity (``benchmarks/bench_serving.py`` does exactly
that).  The registry lint checks that every trace's stage timestamps are
monotone non-decreasing.

The same stage boundaries are recorded as spans on the server's always-on
:class:`repro.obs.trace.Tracer` (``server.tracer``, also reachable as
``stats.tracer`` from ``serve_stream`` callers): ``serve/request`` per
request, ``serve/close`` per batch-forming window, and ``serve/batch``
with ``serve/plan`` / ``serve/execute`` / ``serve/deliver`` children that
tile it exactly.  ``repro.obs.trace.to_chrome_trace`` exports them (plus
any enabled engine/kernel spans) as Perfetto-loadable JSON; aggregate
counters live on ``stats.metrics`` with Prometheus text exposition via
``stats.to_prometheus()``.

SLO semantics: ``deadline_ms`` is a *relative* budget from enqueue.  A
request is shed (``Rejected``) only when its deadline has already passed at
admission or at batch close; a request that starts executing in time but
finishes late is still served — it simply counts against ``on_time_frac`` /
``goodput_qps`` instead of ``shed_rate``.  ``slack_ms`` is the close-time
margin reserved for execution: closing a batch at ``deadline - slack``
gives the batch ``slack`` milliseconds to finish on time.

Typical use::

    engine = QueryEngine(idx).to_device()
    server = IndexServer(engine, ServeConfig(max_batch=16, max_wait_ms=4.0))
    await server.start()            # warm-up: hot-term caches + jit priming
    result = await server.submit(Request([1, 5], mode="and", deadline_ms=50))
    ...
    await server.stop()             # drains the queues first
    print(server.stats.snapshot())

or, synchronously, the open-loop driver used by the benchmark harness::

    results, stats = serve_stream(engine, requests, offsets, config)
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Mapping, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry, nearest_rank
from repro.obs.trace import Tracer

from .device import _bucket
from .engine import QueryBatch, QueryEngine, MODES

_now = time.monotonic        # one clock for every stage stamp (thread-safe)


# --------------------------------------------------------------------------- #
# request / result types
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class Request:
    """One query in the stream.  ``deadline_ms`` is relative to enqueue
    (None uses the server's ``default_deadline_ms``)."""
    terms: list
    mode: str = "and"
    k: int = 10
    tenant: str = "default"
    deadline_ms: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Explicit shed/reject result — the server never silently stalls a
    request.  ``reason``: "expired" (deadline already spent at enqueue),
    "queue_full" (tenant over its weighted admission share), or "deadline"
    (deadline passed while queued; shed at batch close)."""
    reason: str
    tenant: str
    detail: str = ""


# trace stage names, in order — ``TraceRecord.stages()`` returns the stamps
# in this order and the registry lint checks them monotone non-decreasing
STAGES = ("enqueue", "close", "plan", "execute", "done")


@dataclasses.dataclass
class TraceRecord:
    """Per-request trace: outcome + the five stage timestamps (monotonic
    seconds; later stages are None for rejected/shed requests)."""
    rid: int
    tenant: str
    mode: str
    k: int
    outcome: str                 # served | shed | rejected_expired | rejected_queue_full
    deadline: float              # absolute (monotonic clock)
    t_enqueue: float
    t_close: Optional[float] = None
    t_plan: Optional[float] = None
    t_execute: Optional[float] = None
    t_done: Optional[float] = None
    batch_id: int = -1
    batch_size: int = 0
    placement: str = ""
    epoch: tuple = ()
    on_time: bool = False

    def stages(self) -> tuple:
        """The stamped stages in ``STAGES`` order, Nones dropped (a shed
        request legitimately stops at ``close``)."""
        return tuple(t for t in (self.t_enqueue, self.t_close, self.t_plan,
                                 self.t_execute, self.t_done) if t is not None)

    @property
    def latency_ms(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return (self.t_done - self.t_enqueue) * 1e3


@dataclasses.dataclass
class BatchRecord:
    """Per-batch trace: enough to replay the batch through the offline
    ``plan()/execute()`` oracle (queries + mode/k + placement + pinned
    epoch) and to build the batch-size histogram."""
    batch_id: int
    mode: str
    k: int
    placement: str
    epoch: tuple
    queries: tuple               # tuple of term tuples, batch order
    rids: tuple                  # request ids aligned with ``queries``
    t_close: float
    t_plan: float
    t_execute: float
    t_done: float


class ServerStats:
    """Aggregated serving telemetry: every trace and batch record, a typed
    :class:`~repro.obs.metrics.MetricsRegistry` (Prometheus exposition via
    :meth:`to_prometheus`), and a ``snapshot()`` that derives the SLO
    metrics (latency percentiles, goodput, shed rate, batch-size histogram
    per placement).  ``tracer`` is the owning server's span tracer (set by
    :class:`IndexServer`) so ``serve_stream`` callers can export traces."""

    def __init__(self):
        self.traces: list[TraceRecord] = []
        self.batches: list[BatchRecord] = []
        self.submitted = 0
        self.served = 0
        self.shed = 0
        self.rejected_expired = 0
        self.rejected_queue_full = 0
        self.per_tenant: dict = {}
        self.warmup_s = 0.0
        self.tracer: Optional[Tracer] = None
        self.metrics = MetricsRegistry(namespace="repro_serve")
        self.metrics.counter(
            "requests_total", "requests by tenant and outcome",
            labelnames=("tenant", "outcome"))
        self.metrics.counter(
            "batches_total", "closed batches by placement and mode",
            labelnames=("placement", "mode"))
        self.metrics.histogram(
            "request_latency_ms", "end-to-end served latency (ms)",
            labelnames=("tenant",))
        self.metrics.gauge("warmup_seconds", "server warm-up wall-clock")

    def _tenant(self, tenant: str) -> dict:
        d = self.per_tenant.get(tenant)
        if d is None:
            d = self.per_tenant[tenant] = {
                "submitted": 0, "served": 0, "shed": 0, "rejected": 0}
        return d

    def record(self, tr: TraceRecord) -> None:
        self.traces.append(tr)
        t = self._tenant(tr.tenant)
        self.submitted += 1
        t["submitted"] += 1
        self.metrics.inc("requests_total", tenant=tr.tenant,
                         outcome=tr.outcome)
        if tr.latency_ms is not None:
            self.metrics.get("request_latency_ms").observe(
                tr.latency_ms, tenant=tr.tenant)
        if tr.outcome == "served":
            self.served += 1
            t["served"] += 1
        elif tr.outcome == "shed":
            self.shed += 1
            t["shed"] += 1
        elif tr.outcome == "rejected_expired":
            self.rejected_expired += 1
            t["rejected"] += 1
        elif tr.outcome == "rejected_queue_full":
            self.rejected_queue_full += 1
            t["rejected"] += 1

    def record_batch(self, b: BatchRecord) -> None:
        self.batches.append(b)
        self.metrics.inc("batches_total", placement=b.placement, mode=b.mode)

    def set_warmup(self, seconds: float) -> None:
        self.warmup_s = seconds
        self.metrics.get("warmup_seconds").set(seconds)

    def to_prometheus(self) -> str:
        """The registry's Prometheus 0.0.4 text exposition (what
        ``launch.serve --metrics-out`` writes)."""
        return self.metrics.to_prometheus()

    def snapshot(self, prometheus: bool = False) -> dict:
        """SLO metrics over everything recorded so far.  ``shed_rate``
        counts every non-served outcome (shed at close + both admission
        rejects); ``goodput_qps`` is on-time served requests per second of
        stream wall-clock (first enqueue to last delivery).

        Latency percentiles use the nearest-rank rule
        (:func:`repro.obs.metrics.nearest_rank`): deterministic for tiny
        samples — never interpolated, always an observed value, monotone in
        q (p50 <= p99 <= p999), and n == 1 returns the single sample.

        With ``prometheus=True`` the snapshot also carries the registry's
        text exposition under the ``"prometheus"`` key."""
        lat = sorted(tr.latency_ms for tr in self.traces
                     if tr.latency_ms is not None)
        on_time = sum(tr.on_time for tr in self.traces)
        if self.traces:
            t0 = min(tr.t_enqueue for tr in self.traces)
            t1 = max((tr.t_done for tr in self.traces
                      if tr.t_done is not None), default=t0)
            wall = max(t1 - t0, 1e-9)
        else:
            wall = 0.0
        hist: dict = {}
        for b in self.batches:
            hist.setdefault(b.placement, {})
            hist[b.placement][len(b.queries)] = (
                hist[b.placement].get(len(b.queries), 0) + 1)
        sizes = [len(b.queries) for b in self.batches]
        pct = {}
        if lat:
            for name, q in (("p50", 50.0), ("p99", 99.0), ("p999", 99.9)):
                pct[name] = nearest_rank(lat, q)
            pct["mean"] = float(sum(lat) / len(lat))
            pct["max"] = float(lat[-1])
        dropped = self.shed + self.rejected_expired + self.rejected_queue_full
        extra = {"prometheus": self.to_prometheus()} if prometheus else {}
        return {
            **extra,
            "submitted": self.submitted,
            "served": self.served,
            "shed": self.shed,
            "rejected_expired": self.rejected_expired,
            "rejected_queue_full": self.rejected_queue_full,
            "shed_rate": dropped / max(self.submitted, 1),
            "on_time_frac": on_time / max(self.submitted, 1),
            "goodput_qps": (on_time / wall) if wall else 0.0,
            "wall_s": wall,
            "latency_ms": pct,
            "n_batches": len(self.batches),
            "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
            "batch_hist": hist,
            "per_tenant": self.per_tenant,
            "warmup_s": self.warmup_s,
        }


# --------------------------------------------------------------------------- #
# configuration + admission helpers
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ServeConfig:
    """Serving policy.

    max_batch: close a batch at this many requests (size trigger).
    max_wait_ms: close no later than this long after the seed request was
        enqueued, even with deadline room to spare (latency floor for
        lightly-loaded streams — the idle-queue flush).
    slack_ms: execution margin — a batch closes at the earliest member
        deadline MINUS this, so the batch has ``slack_ms`` to finish on time.
    queue_cap: global admission bound (requests queued across all tenants).
    default_deadline_ms: budget for requests that don't carry one.
    tenants: tenant -> admission weight.  A tenant's share of ``queue_cap``
        and of contended batch slots is proportional to its weight; tenants
        absent from the map weigh 1.0.  Empty map = no per-tenant split
        (only the global bound applies).
    placement: force every batch's plan placement ("host" / "device" /
        "fused"); None lets ``engine.plan()`` auto-place (crossover table).
    warm_terms: warm this many hottest (highest-df) terms' block + score
        caches at ``start()``.
    warm_modes: prime the jit caches by executing one priming batch per
        batch-size bucket per listed mode during warm-up.
    warm_queries: optional representative sample of the expected query
        distribution; when given, warm-up primes with THESE queries (bucket
        sweep + a full pass in ``max_batch`` chunks), so the jit worklist
        buckets real traffic hits are compiled before the first request.
        Defaults to synthetic hot-term pairs, which cover the batch-size
        buckets but can miss workload-specific worklist shapes.
    """
    max_batch: int = 32
    max_wait_ms: float = 5.0
    slack_ms: float = 0.0
    queue_cap: int = 1024
    default_deadline_ms: float = 100.0
    tenants: Mapping[str, float] = dataclasses.field(default_factory=dict)
    placement: Optional[str] = None
    warm_terms: int = 16
    warm_modes: tuple = ("and",)
    warm_queries: Optional[list] = None


def tenant_cap(queue_cap: int, tenants: Mapping[str, float],
               tenant: str) -> int:
    """``tenant``'s admission bound: its weighted share of ``queue_cap``
    (at least 1), or the whole cap when no weights are configured."""
    if not tenants:
        return queue_cap
    w = float(tenants.get(tenant, 1.0))
    total = sum(float(v) for v in tenants.values())
    if tenant not in tenants:
        total += w
    return max(1, int(queue_cap * w / max(total, 1e-12)))


def weighted_fill(queues: Mapping[str, list], weights: Mapping[str, float],
                  compatible, max_n: int, credit: Optional[dict] = None) -> list:
    """Smooth weighted round-robin drain: pop up to ``max_n`` entries for
    which ``compatible(entry)`` holds, giving each tenant slots in
    proportion to its weight (absent tenants weigh 1.0).  ``credit``
    carries the WRR state across calls (tenants keep their deficit between
    batches).  Per tenant, entries pop in FIFO order *among compatible
    ones* — an incompatible head does not block the tenant's later
    compatible requests.  Returns the popped entries in drain order."""
    if credit is None:
        credit = {}
    out: list = []
    while len(out) < max_n:
        avail = [t for t, q in queues.items() if any(compatible(e) for e in q)]
        if not avail:
            break
        for t in avail:
            credit[t] = credit.get(t, 0.0) + float(weights.get(t, 1.0))
        # deterministic tie-break by tenant name
        pick = max(avail, key=lambda t: (credit[t], t))
        credit[pick] -= sum(float(weights.get(t, 1.0)) for t in avail)
        q = queues[pick]
        for i, e in enumerate(q):
            if compatible(e):
                out.append(q.pop(i))
                break
    return out


@dataclasses.dataclass
class _Pending:
    rid: int
    req: Request
    fut: asyncio.Future
    t_enqueue: float
    deadline: float              # absolute
    sp: object = None            # the request's serve/request span (detached)


# --------------------------------------------------------------------------- #
# the server
# --------------------------------------------------------------------------- #

class IndexServer:
    """Async admission + dynamic batching in front of one
    :class:`~repro.index.engine.QueryEngine` (see the module docstring for
    the full lifecycle).  One batcher task, one executor thread: admission
    never blocks on execution, execution never races itself."""

    def __init__(self, engine: QueryEngine, config: Optional[ServeConfig] = None):
        self.engine = engine
        self.config = config or ServeConfig()
        self.stats = ServerStats()
        # the server's own always-on tracer: every TraceRecord stage stamp
        # below is a boundary of one of these spans (serve/request,
        # serve/close, serve/batch + plan/execute/deliver children), so the
        # five-stamp record is a *view* over the span timeline, not a second
        # clock.  Deep engine/kernel spans live on the process-global tracer
        # (repro.obs.trace.get_tracer), disabled unless explicitly enabled.
        self.tracer = Tracer(enabled=True)
        self.stats.tracer = self.tracer
        self._queues: dict[str, list[_Pending]] = {}
        self._credit: dict[str, float] = {}
        self._queued = 0
        self._rid = 0
        self._batch_id = 0
        self._event: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._stopping = False
        self._inflight = False
        # test hook: called (with the plan) between plan and execute —
        # lets tests land a compact() there and check epoch pinning
        self._after_plan = None

    # ---- lifecycle ------------------------------------------------------- #

    async def start(self) -> "IndexServer":
        cfg = self.config
        if cfg.placement is not None:
            if cfg.placement not in ("host", "device", "fused"):
                raise ValueError(f"unknown placement {cfg.placement!r}")
            if (cfg.placement != "host" and self.engine.arena is None
                    and getattr(self.engine, "_shard_cfg", None) is None):
                raise ValueError(
                    f"placement {cfg.placement!r} needs device arenas; call "
                    f"engine.to_device() before starting the server")
        self._event = asyncio.Event()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-serve")
        loop = asyncio.get_running_loop()
        await loop.run_in_executor(self._pool, self._warmup)
        self._stopping = False
        self._task = asyncio.create_task(self._batcher())
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop the batcher; with ``drain`` (default) serve out everything
        queued first, so no accepted request is abandoned."""
        if drain:
            while self._queued or self._inflight:
                await asyncio.sleep(0.002)
        self._stopping = True
        if self._event is not None:
            self._event.set()
        if self._task is not None:
            await self._task
            self._task = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def _warmup(self) -> None:
        """Warm the hot-term caches and prime the jit buckets before the
        first real request: the hottest (highest-df) terms' posting blocks
        land in the decoded-block LRU, their BM25 score vectors in the
        score cache, and one tiny + one full-sized priming batch per
        configured mode compiles the round kernels for the batch-size
        buckets real traffic will hit."""
        t0 = _now()
        eng, cfg = self.engine, self.config
        gen = getattr(eng.idx, "gen", eng.idx)
        hot = sorted(gen.terms, key=lambda t: -gen.terms[t].df)[:cfg.warm_terms]
        if not hot:
            self.stats.set_warmup(_now() - t0)
            return
        if eng.arena is not None:
            eng._prefetch_terms(hot, fields=(0,))
            if any(m in ("or", "and_scored") for m in cfg.warm_modes):
                eng.arena.ensure_scores()
        for t in hot:
            eng.term_scores(t)
        # prime every batch-size jit bucket real traffic can hit: the device
        # round kernels compile per power-of-2 nq bucket (device._bucket),
        # so one priming batch per bucket up to max_batch turns mid-stream
        # compile stalls into warm-up time
        sizes = {1}
        w = _bucket(1)
        while w <= _bucket(max(1, cfg.max_batch)):
            sizes.add(min(w, max(1, cfg.max_batch)))
            w *= 2
        pool = ([list(q) for q in cfg.warm_queries] if cfg.warm_queries
                else [[hot[i % len(hot)], hot[(i + 1) % len(hot)]]
                      for i in range(max(sizes))])
        for mode in cfg.warm_modes:
            for size in sorted(sizes):
                qs = [pool[i % len(pool)] for i in range(size)]
                eng.execute(eng.plan(QueryBatch(qs, mode=mode, k=10),
                                     placement=cfg.placement))
            if cfg.warm_queries:
                # one full pass in max_batch chunks: compiles the worklist
                # buckets this exact workload will form at steady state
                step = max(1, cfg.max_batch)
                for i in range(0, len(pool), step):
                    eng.execute(eng.plan(QueryBatch(pool[i:i + step],
                                                    mode=mode, k=10),
                                         placement=cfg.placement))
        self.stats.set_warmup(_now() - t0)

    # ---- admission ------------------------------------------------------- #

    def submit_nowait(self, req: Request) -> asyncio.Future:
        """Admit one request; returns a future resolving to the result list
        (or an explicit :class:`Rejected`).  Rejections resolve
        immediately — admission never stalls the caller."""
        if req.mode not in MODES:
            raise ValueError(f"unknown mode {req.mode!r}; modes: {MODES}")
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        rid = self._rid
        self._rid += 1
        # the request span opens here and its t0 IS the enqueue stamp — one
        # clock read serves both the trace record and the span timeline
        sp = self.tracer.begin("serve/request", lane="serve", rid=rid,
                               tenant=req.tenant, mode=req.mode, k=req.k)
        t = sp.t0
        budget = (self.config.default_deadline_ms
                  if req.deadline_ms is None else req.deadline_ms)
        deadline = t + budget / 1e3
        if budget <= 0:
            fut.set_result(Rejected("expired", req.tenant,
                                    f"deadline_ms={budget} already spent at enqueue"))
            self.tracer.end(sp, t1=t, outcome="rejected_expired")
            self.stats.record(TraceRecord(
                rid, req.tenant, req.mode, req.k, "rejected_expired",
                deadline, t))
            return fut
        q = self._queues.setdefault(req.tenant, [])
        cap = tenant_cap(self.config.queue_cap, self.config.tenants, req.tenant)
        if self._queued >= self.config.queue_cap or len(q) >= cap:
            fut.set_result(Rejected("queue_full", req.tenant,
                                    f"tenant share {len(q)}/{cap}, "
                                    f"global {self._queued}/{self.config.queue_cap}"))
            self.tracer.end(sp, t1=t, outcome="rejected_queue_full")
            self.stats.record(TraceRecord(
                rid, req.tenant, req.mode, req.k, "rejected_queue_full",
                deadline, t))
            return fut
        q.append(_Pending(rid, req, fut, t, deadline, sp))
        self._queued += 1
        if self._event is not None:
            self._event.set()
        return fut

    async def submit(self, req: Request):
        return await self.submit_nowait(req)

    # ---- batching -------------------------------------------------------- #

    def _pop_seed(self) -> Optional[_Pending]:
        """The earliest-deadline pending request across all tenants (EDF
        seeding: an expired request is picked first and shed immediately
        instead of rotting in its queue)."""
        best_t, best_i, best = None, None, None
        for t, q in self._queues.items():
            for i, p in enumerate(q):
                if best is None or p.deadline < best.deadline:
                    best_t, best_i, best = t, i, p
        if best is None:
            return None
        self._queues[best_t].pop(best_i)
        self._queued -= 1
        return best

    def _fill(self, key: tuple, n: int) -> list:
        got = weighted_fill(
            self._queues, self.config.tenants,
            lambda p: (p.req.mode, p.req.k) == key, n, self._credit)
        self._queued -= len(got)
        return got

    async def _batcher(self) -> None:
        loop = asyncio.get_running_loop()
        cfg = self.config
        while True:
            while not self._queued:
                if self._stopping:
                    return
                self._event.clear()
                if self._queued:        # raced an enqueue past the clear
                    break
                await self._event.wait()
            seed = self._pop_seed()
            if seed is None:
                continue
            self._inflight = True
            try:
                csp = self.tracer.begin("serve/close", lane="serve",
                                        seed_rid=seed.rid)
                batch = [seed]
                key = (seed.req.mode, seed.req.k)
                close_at = min(seed.deadline - cfg.slack_ms / 1e3,
                               seed.t_enqueue + cfg.max_wait_ms / 1e3)
                while len(batch) < cfg.max_batch:
                    more = self._fill(key, cfg.max_batch - len(batch))
                    if more:
                        batch.extend(more)
                        close_at = min([close_at]
                                       + [p.deadline - cfg.slack_ms / 1e3
                                          for p in more])
                        continue
                    dt = close_at - _now()
                    if dt <= 0:
                        break
                    self._event.clear()
                    try:
                        await asyncio.wait_for(self._event.wait(), dt)
                    except asyncio.TimeoutError:
                        break
                t_close = _now()
                self.tracer.end(csp, t1=t_close, n=len(batch))
                live = []
                for p in batch:
                    if p.deadline < t_close:        # shed: budget already spent
                        p.fut.set_result(Rejected(
                            "deadline", p.req.tenant,
                            f"deadline passed {1e3 * (t_close - p.deadline):.2f}"
                            f" ms before batch close"))
                        self.tracer.end(p.sp, t1=t_close, outcome="shed")
                        self.stats.record(TraceRecord(
                            p.rid, p.req.tenant, p.req.mode, p.req.k, "shed",
                            p.deadline, p.t_enqueue, t_close=t_close))
                    else:
                        live.append(p)
                if not live:
                    continue
                try:
                    results, records = await loop.run_in_executor(
                        self._pool, self._run_batch, live, t_close)
                except Exception as e:      # noqa: BLE001 — fail the batch's futures
                    for p in live:
                        self.tracer.end(p.sp, outcome="error")
                        if not p.fut.done():
                            p.fut.set_exception(
                                RuntimeError(f"batch execution failed: {e!r}"))
                    continue
                for p, r in zip(live, results):
                    if not p.fut.done():
                        p.fut.set_result(r)
                for tr in records:
                    self.stats.record(tr)
            finally:
                self._inflight = False

    def _run_batch(self, live: list, t_close: float):
        """Executor-thread half of one batch: plan, (optional test hook),
        execute, stamp the remaining trace stages.

        The stage stamps ARE span boundaries: ``serve/batch`` runs
        ``t_close -> t_done`` with children ``serve/plan`` (close -> plan
        done), ``serve/execute`` (plan -> execute done) and
        ``serve/deliver`` (execute -> done) tiling it exactly — the
        exported trace accounts for 100% of measured batch wall-clock, and
        the :class:`TraceRecord` five-stamp view is derived from the same
        clock reads."""
        cfg = self.config
        queries = [list(p.req.terms) for p in live]
        mode, k = live[0].req.mode, live[0].req.k
        bid = self._batch_id
        self._batch_id += 1
        bsp = self.tracer.begin("serve/batch", lane="serve", t0=t_close,
                                bid=bid, mode=mode, k=k, nq=len(live))
        psp = self.tracer.begin("serve/plan", lane="serve", parent=bsp,
                                t0=t_close)
        plan = self.engine.plan(QueryBatch(queries, mode=mode, k=k),
                                placement=cfg.placement)
        self.tracer.end(psp, placement=plan.placement)
        t_plan = psp.t1
        if self._after_plan is not None:
            self._after_plan(plan)
        esp = self.tracer.begin("serve/execute", lane="serve", parent=bsp,
                                t0=t_plan)
        results = self.engine.execute(plan)
        self.tracer.end(esp)
        t_execute = esp.t1
        dsp = self.tracer.begin("serve/deliver", lane="serve", parent=bsp,
                                t0=t_execute)
        epoch = plan.ctx.skey if plan.ctx is not None else ()
        self.tracer.end(dsp)
        t_done = dsp.t1
        self.tracer.end(bsp, t1=t_done, placement=plan.placement)
        self.stats.record_batch(BatchRecord(
            bid, mode, k, plan.placement, epoch,
            tuple(tuple(q) for q in queries), tuple(p.rid for p in live),
            t_close, t_plan, t_execute, t_done))
        records = []
        for p in live:
            self.tracer.end(p.sp, t1=t_done, outcome="served", bid=bid)
            records.append(TraceRecord(
                p.rid, p.req.tenant, mode, k, "served", p.deadline,
                p.t_enqueue, t_close=t_close, t_plan=t_plan,
                t_execute=t_execute, t_done=t_done, batch_id=bid,
                batch_size=len(live), placement=plan.placement, epoch=epoch,
                on_time=t_done <= p.deadline))
        return results, records


# --------------------------------------------------------------------------- #
# open-loop drivers (benchmark harness + launch entry point)
# --------------------------------------------------------------------------- #

def poisson_offsets(n: int, rate_qps: float, seed: int = 0) -> np.ndarray:
    """Arrival offsets (seconds from stream start) of an open-loop Poisson
    process at ``rate_qps`` — exponential interarrivals, fixed seed."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, n))


def bursty_offsets(n: int, rate_qps: float, seed: int = 0,
                   shape: float = 0.25) -> np.ndarray:
    """Bursty open-loop arrivals: Gamma interarrivals with ``shape`` < 1
    (same mean rate as the Poisson stream, heavier clumping — the squared
    coefficient of variation is ``1/shape``)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.gamma(shape, 1.0 / (rate_qps * shape), n))


async def drive_open_loop(server: IndexServer, requests: list,
                          offsets) -> list:
    """Submit ``requests[i]`` at ``offsets[i]`` seconds after start (open
    loop: arrivals never wait for responses) and gather every result in
    submission order."""
    t0 = _now()
    futs = []
    for req, off in zip(requests, offsets):
        delay = t0 + float(off) - _now()
        if delay > 0:
            await asyncio.sleep(delay)
        futs.append(server.submit_nowait(req))
    return list(await asyncio.gather(*futs))


def serve_stream(engine: QueryEngine, requests: list, offsets,
                 config: Optional[ServeConfig] = None):
    """Synchronous convenience wrapper: start a server, drive the open-loop
    stream, drain, stop.  Returns ``(results, stats)`` with ``results`` in
    submission order."""
    server = IndexServer(engine, config)

    async def go():
        await server.start()
        try:
            return await drive_open_loop(server, requests, offsets)
        finally:
            await server.stop()

    results = asyncio.run(go())
    return results, server.stats
