"""Batched query engine: fused decode-and-intersect over the compressed index.

The seed path (`repro.index.query`) decoded every term's full posting list per
query and intersected with ``np.isin``.  This engine makes the serving path
hardware-speed along three axes:

  1. **Vectorized intersection** — per-block candidates are intersected with
     the kernels in ``repro.kernels.intersect`` (galloping ``searchsorted``
     probes or packed-bitmap AND, picked by density) instead of a scalar
     ``np.isin`` over the whole list.
  2. **Fused decode-and-intersect** — AND queries walk the rarest term first;
     for every other term the skip table (first docid per 512-posting block)
     is consulted *before* decompression, so blocks containing no candidate
     docids are never decoded.  Short candidate lists therefore touch only a
     handful of blocks of even the longest posting lists.
  3. **Batched execution with a decoded-block LRU** — ``QueryBatch`` groups
     queries by term signature so queries sharing terms run adjacently; each
     hot (term, block) is decompressed once into an LRU cache
     (``BlockCache``) and reused across the whole batch.  BM25 per-term score
     vectors are cached the same way for OR queries.
  4. **Device-resident execution** (``to_device()``) — the compressed blocks
     live in ``repro.index.device.DeviceArena`` arenas; per AND round the
     engine dedupes the *whole batch's* (term, block) work-list and issues
     ONE jitted lane-parallel decode instead of O(blocks) Python iterations.
     The per-query candidate sets live in a **device-resident segmented
     bitmap** across rounds (``kernels/intersect_rounds``): every round
     probes the old bitmap and scatters the survivors on device, block
     selection uses only static skip metadata (block first/last docids), and
     the only candidate download is the final result — zero host candidate
     syncs between rounds.  Under the ``fused`` placement the rounds run the
     segmented Pallas kernel instead: unpack + d-gap prefix sum + per-query
     bitmap probe in VMEM, with both the gap tile and the query's candidate
     tile DMA double-buffered.  Results are bit-identical to the host path.
  5. **Device-resident ranked top-k** — ``or`` / ``and_scored`` batches
     accumulate u8-quantized BM25 impact codes (``repro.index.scores``: one
     packed score column per posting block, next to the docid streams) into
     a segmented device score buffer across rounds (``kernels/topk``), with
     OR work-lists block-max pruned against a static per-query threshold
     before any decode and ``and_scored`` gated by the AND-result bitmap
     that never left the device.  The single download per batch is the
     compacted candidate bitmap (k-th quantized sum minus the quantization
     margin — a provable superset of the float top-k), rescored exactly by
     the block-lazy float oracle: results are bitwise identical to the host
     BM25 path, ties broken by ascending docid.
  6. **Streaming mutation** (``repro.index.segments``) — the engine serves an
     :class:`~repro.index.invindex.InvertedIndex` handle that may carry
     tombstones and a delta segment on top of its immutable compressed
     generation.  Every query resolves a frozen :class:`_ExecCtx` (generation
     + delta snapshot + tombstone set + live corpus stats); plans pin their
     ctx, so a ``compact()`` under an in-flight plan cannot change its
     results.  Device paths gate probes with the epoch's packed live bitmap
     (one upload per epoch, zero downloads) and the host merges in a brute
     -force scan of the small delta segment; all block/score caches are keyed
     by generation / epoch so no stale state can serve across a compaction.
     Results stay bitwise identical to rebuilding the index from scratch.

Execution is planned, then run: ``engine.plan(batch)`` resolves *once* where
the batch runs (placement: host / device / fused) and what every referenced
term's codec is capable of (:class:`TermCaps`, read from the codec registry's
declared capabilities), and ``engine.execute(plan)`` just follows the plan —
the engine contains no per-codec special cases.

Typical use::

    engine = QueryEngine(idx, cache_blocks=4096)
    plan = engine.plan(QueryBatch(queries=[[1, 5], [2, 5, 9]], mode="and"))
    results = engine.execute(plan)
    engine.to_device()                       # device arenas from here on
    results = engine.execute(engine.plan(QueryBatch([[1, 5]], mode="and")))

Deprecated shims (see the migration note in ``repro/index/__init__.py``):
``execute(QueryBatch)`` plans implicitly; ``QueryEngine(idx, device=True,
fused=True)`` maps to ``to_device(fused=True)``; the one-shot helpers in
``repro.index.query`` delegate to plans.
"""

from __future__ import annotations

import contextlib
import dataclasses
import difflib
import itertools
import json
import os
import warnings
from collections import OrderedDict
from typing import Mapping, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import codec as codec_lib
from repro.kernels import intersect, intersect_rounds, topk
from repro.obs.metrics import DevStatsView, MetricsRegistry
from repro.obs.trace import get_tracer
from .device import _bucket     # one shared jit-bucket policy with the arena
from .invindex import InvertedIndex
from .scores import B, K1, bm25_scores, topk_select  # noqa: F401  (B/K1 re-export)

# plan-time auto-placement, static fallback: below this batch size the host
# numpy path beats the device round machinery on every backend measured so
# far, so tiny batches are planned onto the host even when arenas exist.
# When a committed BENCH_query.json baseline is present, ``plan()`` instead
# derives a :class:`CrossoverTable` from its measured host/device qps curves
# and only falls back to this constant when the curves show no true
# host->device crossing (see ``CrossoverTable.from_bench``).
HOST_BATCH_MAX = 1


@dataclasses.dataclass(frozen=True)
class CrossoverTable:
    """Host-vs-device placement crossover derived from a measured
    ``BENCH_query.json`` baseline.

    ``host_batch_max`` is the demotion threshold ``plan()`` uses: batches of
    at most this many queries are auto-placed on the host.  It is derived
    conservatively — the largest measured batch size where the host wins
    (host_qps >= device_qps) AND the device wins at *every* larger measured
    size.  That second clause matters: a backend where the host wins at the
    largest measured size (true of CPU-emulated device backends) has no
    real crossing, and extrapolating one would demote production-sized
    batches off the arenas.  In that case ``host_batch_max`` is None and
    ``plan()`` falls back to the static ``HOST_BATCH_MAX`` rule.  A backend
    where the device wins everywhere yields 0 (never demote).

    ``mode_cuts`` refines the single cell per query mode: a baseline whose
    report carries per-mode qps curves (``mode_qps``: mode -> {"host"/
    "device": {batch: qps}}) yields one cell per measured mode, derived with
    the same conservative rule.  Ranked modes amortize quantized-score
    uploads and the final-merge sync over the batch, so they typically cross
    to the device EARLIER than plain AND — one blended cell would demote
    ranked batches the device already wins.  ``cut_for(mode)`` resolves the
    cell ``plan()`` applies: the mode's own cell when measured (even a
    no-crossing None — then the static rule decides), else the blended
    ``host_batch_max``."""
    host_batch_max: Optional[int]
    sizes: tuple = ()
    source: str = "BENCH_query.json"
    mode_cuts: tuple = ()       # ((mode, cut_or_None), ...) measured cells

    def cut_for(self, mode: str) -> Optional[int]:
        """The demotion threshold for one query mode (see class docstring)."""
        for m, c in self.mode_cuts:
            if m == mode:
                return c
        return self.host_batch_max

    @staticmethod
    def _derive(host: Mapping, dev: Mapping):
        """The conservative crossover rule over one pair of qps curves:
        (cut, common sizes) — cut None when there is no true crossing."""
        sizes = sorted(set(host) & set(dev))
        if not sizes:
            return None, ()
        if all(dev[b] > host[b] for b in sizes):
            return 0, tuple(sizes)
        cut = None
        for b in sizes:
            larger = [s for s in sizes if s > b]
            if (host[b] >= dev[b] and larger
                    and all(dev[s] > host[s] for s in larger)):
                cut = b
        return cut, tuple(sizes)

    @classmethod
    def from_bench(cls, report: Mapping, source: str = "BENCH_query.json"
                   ) -> "CrossoverTable":
        host = {int(b): float(q)
                for b, q in (report.get("host_qps") or {}).items()}
        dev = {int(b): float(q)
               for b, q in (report.get("device_qps") or {}).items()}
        cut, sizes = cls._derive(host, dev)
        mode_cuts = []
        for m in sorted(report.get("mode_qps") or {}):
            curves = report["mode_qps"][m] or {}
            mh = {int(b): float(q)
                  for b, q in (curves.get("host") or {}).items()}
            md = {int(b): float(q)
                  for b, q in (curves.get("device") or {}).items()}
            mc, msz = cls._derive(mh, md)
            if msz:
                mode_cuts.append((m, mc))
        return cls(cut, sizes, source, tuple(mode_cuts))


def _repo_root() -> str:
    here = os.path.abspath(__file__)            # src/repro/index/engine.py
    for _ in range(4):
        here = os.path.dirname(here)
    return here


def _load_crossover() -> Optional[CrossoverTable]:
    """The crossover table from the committed benchmark baseline
    (``BENCH_QUERY_JSON`` env override, else ``BENCH_query.json`` at the
    repo root), or None when the file is absent/unreadable — ``plan()``
    then applies the static ``HOST_BATCH_MAX`` rule."""
    path = (os.environ.get("BENCH_QUERY_JSON")
            or os.path.join(_repo_root(), "BENCH_query.json"))
    try:
        with open(path) as f:
            report = json.load(f)
        return CrossoverTable.from_bench(report, source=os.path.basename(path))
    except (OSError, ValueError, TypeError, AttributeError):
        return None


_CROSSOVER_UNSET = object()
_crossover = _CROSSOVER_UNSET


def get_crossover() -> Optional[CrossoverTable]:
    """The cached placement crossover table (loaded once per process)."""
    global _crossover
    if _crossover is _CROSSOVER_UNSET:
        _crossover = _load_crossover()
    return _crossover


def set_crossover(table=_CROSSOVER_UNSET) -> None:
    """Override the cached crossover table.  Pass a :class:`CrossoverTable`
    to force one, ``None`` to simulate an absent baseline (static-rule
    fallback), or no argument to drop the override and reload from disk on
    next use.  Test hook — production code never calls this."""
    global _crossover
    _crossover = table

_EMPTY_U32 = np.zeros(0, np.uint32)
_EMPTY_U32.setflags(write=False)
_EMPTY_I64 = np.zeros(0, np.int64)
_EMPTY_I64.setflags(write=False)

# a ranked margin so large the candidate compact keeps EVERY member doc:
# under a delta-bearing mutation epoch the quantized accumulator uses
# generation-time impact codes (stale df/avdl), so the theta-margin cut is
# disarmed and the exact float rescore (live stats) does all the ranking.
# Tombstone-ONLY epochs stay armed through the idf-ratio deflation instead
# (see the re-arm note in ``repro/index/scores.py``).
_KEEP_ALL_MARGIN = 1 << 30

# per-entry quantized upper bound so large the adaptive-theta work-list
# masking never drops the entry (``and_scored`` rounds, whose membership
# must cover the whole intersection, always scatter)
_UB_ALWAYS = 1 << 30

# stacked-work-list memo entries kept per engine (each holds a round's
# gathered device arrays; hot repeated batches skip the restacking)
_ROUND_CACHE = 32


def _merge_disjoint(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Union of two sorted uint32 docid arrays known to be disjoint (the
    generation half and the delta half of a result share no docids by the
    shadowing invariant of ``repro.index.segments``)."""
    if len(b) == 0:
        return a if a.flags.writeable else a.copy()
    if len(a) == 0:
        return b if b.flags.writeable else b.copy()
    out = np.concatenate([a, b])
    out.sort()
    return out


def _dead_hits(dead: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Bool mask over ``ids`` marking tombstoned docids (``dead`` sorted
    int64, non-empty; ``ids`` sorted uint32)."""
    pos = np.minimum(np.searchsorted(dead, ids), len(dead) - 1)
    return dead[pos] == ids


class BlockCache:
    """Cost-weighted LRU cache keyed by (term, block) for decoded postings.

    ``capacity`` is in cost units; a single decoded 512-posting block costs 1
    and callers caching larger objects (whole-term concatenations) pass their
    block count as ``cost``, so one giant entry cannot masquerade as one
    block.  An entry costing more than the whole capacity is simply never
    retained.  Capacity 0 disables caching entirely (every lookup misses),
    which is what the stateless one-shot query helpers use.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self._cost: dict = {}
        self.cost_used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        v = self._d.get(key)
        if v is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def contains(self, key) -> bool:
        """Membership probe that touches neither the LRU order nor the stats
        (used by the device prefetch planner)."""
        return key in self._d

    def keys(self):
        return list(self._d.keys())

    def put(self, key, value, cost: int = 1) -> None:
        if self.capacity <= 0:
            return
        if key in self._d:
            self.cost_used -= self._cost[key]
            del self._d[key]
        self._d[key] = value
        self._cost[key] = cost
        self.cost_used += cost
        while self.cost_used > self.capacity and self._d:
            k, _ = self._d.popitem(last=False)
            self.cost_used -= self._cost.pop(k)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._d)

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._d),
                "cost_used": self.cost_used}


@dataclasses.dataclass
class QueryBatch:
    """A batch of term queries executed together for cache locality.

    mode: "and" (docid arrays), "or" (BM25 top-k), or "and_scored"
    (AND semantics + BM25 top-k over the matches).
    """
    queries: list
    mode: str = "and"
    k: int = 10


MODES = ("and", "or", "and_scored")
PLACEMENTS = ("host", "device", "fused")


def _check_mode(mode) -> None:
    """Reject unknown batch modes with the registry's nearest-name
    convention (``codec.get``): list what exists, suggest what was meant."""
    if mode in MODES:
        return
    near = difflib.get_close_matches(str(mode), MODES, n=1)
    hint = f" (did you mean {near[0]!r}?)" if near else ""
    raise ValueError(
        f"unknown query mode {mode!r}{hint}; modes: {', '.join(MODES)}")


@dataclasses.dataclass(frozen=True)
class TermCaps:
    """One term's execution capabilities, resolved once at plan time from the
    codec registry's declarations (no codec-name dispatch at run time).

    codec: the codec of the term's posting blocks (None for terms that only
        exist in the mutable delta segment — they have no compressed blocks).
    arena: the codec declares an ``ArenaLayout`` — its blocks decode natively
        in the batched device work-list (otherwise they fall back to the
        per-block numpy oracle inside the arena).
    fused: the arena's fused decode+AND tiles cover every block of the term.
    """
    codec: Optional[str]
    arena: bool
    fused: bool


class _ExecCtx:
    """One mutation epoch's frozen serving view: everything a query (or a
    pinned plan) needs to execute bit-identically regardless of writes or
    compactions that land afterwards.

    gen: the immutable :class:`~repro.index.invindex.Generation`.
    delta: frozen delta-segment snapshot (None when the epoch is unmutated).
    dead: sorted int64 tombstoned base docids (all < ``gen.n_docs``).
    doclen / n_docs / avdl: live corpus stats over the full append-only doc
        space — exactly what a from-scratch rebuild would compute, so BM25
        floats match the rebuild bitwise.
    mutated: whether serving must consult delta/tombstone state at all.
    skey: the epoch key (gid, tombstone version, delta version) that score
        -cache entries carry.
    """
    __slots__ = ("gen", "delta", "dead", "doclen", "n_docs", "avdl",
                 "mutated", "skey", "_df", "_live_dev", "_live_host")

    def __init__(self, idx):
        gen = getattr(idx, "gen", idx)
        self.gen = gen
        self.mutated = bool(getattr(idx, "mutated", False))
        self._df: dict = {}        # term -> live df memo
        self._live_dev = None      # uploaded packed live bitmap (per epoch)
        self._live_host = None     # pre-packed host words (shard ctxs only)
        if self.mutated:
            self.delta = idx.delta.snapshot()
            self.dead = idx.tomb.sorted_ids(below=gen.n_docs)
            self.doclen = idx.doclen_now()
            self.n_docs = int(idx.doc_space)
            # the same expression Generation.build's avdl uses, on the same
            # array a rebuild would be given -> bitwise-equal BM25 floats
            self.avdl = (float(np.asarray(self.doclen).mean())
                         if self.n_docs else 1.0)
            self.skey = idx.epoch
        else:
            self.delta = None
            self.dead = _EMPTY_I64
            self.doclen = gen.doclen
            self.n_docs = gen.n_docs
            self.avdl = gen.avdl
            self.skey = (gen.gid, 0, 0)

    def live_dev(self, words: int):
        """The epoch's packed live bitmap as ONE device row, uploaded on
        first use and reused for every round of every batch in the epoch
        (the gate never downloads anything).  Shard ctxs pre-pack their
        boundary-sliced words (``pack_live_words_range``), so a tombstone
        epoch uploads only each shard's span of the live bitmap."""
        if self._live_dev is None:
            packed = (self._live_host if self._live_host is not None
                      else intersect_rounds.pack_live_words(
                          self.dead, self.gen.n_docs, words))
            self._live_dev = jnp.asarray(packed)
        return self._live_dev


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A typed, resolved execution of one ``QueryBatch``.

    placement: where the batch runs — "host" (numpy per query, grouped by
        term signature), "device" (round-batched arena work-list decode with
        device-resident candidates), or "fused" (device + the segmented fused
        decode+probe kernel for covered terms).  Tiny batches (<=
        ``HOST_BATCH_MAX`` queries) are auto-placed on the host even when
        arenas exist; ``note`` records that decision in the plan's repr.
    terms: per distinct referenced term, its :class:`TermCaps`.  Unknown
        terms (absent from the index) are omitted — execution ignores them.
    ctx: the pinned :class:`_ExecCtx` — the mutation epoch (generation +
        delta snapshot + tombstones) this plan executes against.  Mutations
        or ``compact()`` calls after planning do not affect this plan's
        results; re-plan to serve the new epoch.

    A plan snapshots engine state (placement follows ``to_device``); build
    plans after the engine is in its serving configuration.
    """
    mode: str
    k: int
    placement: str
    queries: tuple
    terms: Mapping[int, TermCaps]
    note: str = ""
    ctx: object = dataclasses.field(default=None, repr=False, compare=False)


# per-engine counter taxonomy (registered on every QueryEngine's registry;
# the long-form semantics are documented inline in ``__init__`` below)
_DEV_COUNTERS = (
    ("worklist_refs", "raw (term, block) work-list references, pre-dedup"),
    ("worklist_decodes", "deduped batched arena decodes actually issued"),
    ("fallback_decodes", "per-block arena decodes outside the work-list"),
    ("resident_rounds", "AND rounds run with candidates device-resident"),
    ("cand_syncs", "per-round candidate downloads (0 on resident paths)"),
    ("final_syncs", "end-of-batch result downloads (one per batch)"),
    ("score_rounds", "ranked accumulate rounds run device-resident"),
    ("score_syncs", "per-round score downloads (always 0 when resident)"),
    ("blocks_pruned", "ranked work-list entries dropped by block-max"),
    ("blocks_scored", "ranked work-list entries actually scored"),
    ("blocks_dense", "entries served from the dense-bitmap representation"),
    ("tomb_gates", "device live-bitmap gates applied (uploads, not syncs)"),
    ("merge_syncs", "sharded ranked top-k merge collectives (one/batch)"),
    ("collective_bytes", "wire bytes moved by the top-k merge collectives"),
    ("shard_final_syncs", "per-shard end-of-batch result downloads"),
)
_ENGINE_SEQ = itertools.count()


class QueryEngine:
    def __init__(self, idx: InvertedIndex, cache_blocks: int = 4096,
                 cache_score_terms: int = 512, device: bool = False,
                 fused: bool = False):
        self.idx = idx
        self.cache = BlockCache(cache_blocks)
        self.score_cache = BlockCache(cache_score_terms)
        self.arena = None
        self._fused = fused
        self._ctx = None           # pinned ctx while executing a plan
        self._ctx_cache = None     # (epoch, _ExecCtx) for the live handle
        # resident_rounds: AND rounds executed with candidates device-resident
        # cand_syncs: per-round candidate downloads (legacy device loop only;
        #   the resident path never syncs between rounds)
        # final_syncs: end-of-batch result downloads (one per resident batch)
        # score_rounds / score_syncs: ranked accumulate rounds executed
        #   device-resident / per-round score downloads (always 0 on the
        #   resident ranked path — only the final candidate bitmap syncs)
        # blocks_pruned / blocks_scored: ranked (term, block) work-list
        #   entries dropped by the block-max upper-bound test vs. scattered
        # blocks_dense: work-list entries served word-parallel from the
        #   density-adaptive bitmap representation (no unpack / prefix sum)
        # tomb_gates: live-bitmap gates applied on device (uploads, not
        #   downloads — the resident paths stay download-free under deletes)
        # merge_syncs / collective_bytes: sharded ranked batches' final
        #   top-k merges (the ONE collective per batch) and their wire bytes
        # shard_final_syncs: per-shard end-of-batch result downloads under
        #   sharded execution (each shard contributes one, like final_syncs)
        #
        # The counters live in a typed MetricsRegistry (repro.obs.metrics);
        # ``dev_stats`` is a read-only live view over it, so every existing
        # read keeps working while Prometheus exposition and ``scoped()``
        # delta sampling come from the registry.  Counts are per engine
        # (sub-engines own their own registries), starting at zero — the
        # same semantics as the old per-engine dict.
        self.metrics = MetricsRegistry(
            namespace="repro_index",
            const_labels={"engine": f"q{next(_ENGINE_SEQ)}", "shard": ""})
        for mname, mhelp in _DEV_COUNTERS:
            self.metrics.counter(mname, mhelp)
        self.dev_stats = DevStatsView(self.metrics,
                                      tuple(n for n, _ in _DEV_COUNTERS))
        self.tracer = get_tracer()   # process-global; disabled by default
        self.trace_lane = "engine"   # sub-engines relabel to "shard<i>"
        self._shard_cfg = None     # doc-range sharded serving config
        self._sctx_cache: dict = {}  # (skey, lo, hi) -> shard _ExecCtx
        self._last_shard_cands = None  # debug: last ranked per-shard cands
        # (gid, kind, work-list) -> the round's gathered device arrays
        # (docid rows / score rows / dense windows), immutable per
        # generation; see _round_memo
        self._round_cache: OrderedDict = OrderedDict()
        if device or fused:
            # deprecated: construct with defaults and call to_device() instead
            warnings.warn(
                "QueryEngine(device=..., fused=...) is deprecated; use "
                "QueryEngine(idx).to_device(fused=...) and execute plans "
                "(engine.execute(engine.plan(batch)))",
                DeprecationWarning, stacklevel=2)
        if device:
            self.to_device(fused=fused)

    # ---- mutation-epoch resolution ------------------------------------------ #

    def _ctx_now(self) -> _ExecCtx:
        """The live handle's current epoch ctx (rebuilt when the epoch
        changes, shared otherwise so per-ctx memos and uploads amortize)."""
        e = getattr(self.idx, "epoch", None)
        c = self._ctx_cache
        if c is None or c[0] != e:
            self._ctx_cache = c = (e, _ExecCtx(self.idx))
        return c[1]

    def _cur(self) -> _ExecCtx:
        """The ctx this call executes under: the plan-pinned ctx inside
        ``execute``, else the live epoch — walking ``self.arena`` forward to
        the current generation after a compaction swap."""
        if self._ctx is not None:
            return self._ctx
        ctx = self._ctx_now()
        if (self.arena is not None
                and getattr(self.arena.idx, "gen", self.arena.idx)
                is not ctx.gen):
            self.arena = ctx.gen.to_device(build_fused=self._fused)
        return ctx

    def _arena_ctx(self, ctx: _ExecCtx):
        """The device arena serving ``ctx``'s generation: the engine's own
        arena when it matches, else the generation's cached arena (how a
        plan pinned to a pre-compaction generation keeps its blocks)."""
        a = self.arena
        if a is not None and getattr(a.idx, "gen", a.idx) is ctx.gen:
            return a
        return ctx.gen.to_device(build_fused=self._fused)

    def to_device(self, fused=None, shards=None, mesh=None,
                  bounds=None) -> "QueryEngine":
        """Switch the engine onto the device-resident arenas: all subsequent
        decodes go through batched lane-parallel device calls (with numpy
        fallback per block for codecs the arena doesn't cover).  ``fused``
        additionally routes eligible AND rounds through the fused
        decode+bitmap-AND Pallas kernel; its tile arenas are only built (or
        upgraded onto a cached arena) when actually requested.

        Doc-range sharded serving: any of ``shards`` (a count — boundaries
        derived from build metadata, :meth:`repro.index.shards.ShardSpec
        .derive`), ``bounds`` (explicit boundary tuple ``(0, ..., n_docs)``,
        uneven and empty ranges legal), or ``mesh`` (a 1-D jax mesh, one
        device per shard — absent or undersized, the shards run logically on
        the default device with identical results) splits every generation
        into self-contained per-shard engines (``_shard_engines``).  All
        resident rounds then run shard-local; ranked modes merge with ONE
        collective per batch (``_execute_sharded``)."""
        if fused is not None:
            self._fused = fused
        if shards is not None or bounds is not None or mesh is not None:
            b = tuple(int(x) for x in bounds) if bounds is not None else None
            n = (int(shards) if shards is not None
                 else len(b) - 1 if b is not None
                 else int(mesh.devices.size))
            if n < 1:
                raise ValueError(f"need at least one shard, got {n}")
            if b is not None and len(b) - 1 != n:
                raise ValueError(
                    f"bounds {b} define {len(b) - 1} shard(s), not {n}")
            self._shard_cfg = {"n": n, "bounds": b, "mesh": mesh}
            self.arena = None           # shards own the arenas
            self._shard_engines(self._ctx_now())    # build eagerly
            return self
        arena = self.idx.to_device(build_fused=self._fused)
        if (self.arena is None
                or getattr(self.arena.idx, "gen", self.arena.idx)
                is not getattr(self.idx, "gen", self.idx)):
            self.arena = arena
        return self

    # ---- decode through the cache ------------------------------------------ #
    # Block entries are keyed (term, block, field, gid) with field 0 = docids
    # and field 1 = TFs, so AND queries (which never touch TFs) only pay for
    # the docid stream.  Whole-term concatenations are cached as
    # (term, -1, field, gid) at cost = block count: a hot term used both as
    # the rarest term (concat) and as a skip target (blocks) is deliberately
    # held twice — that trades bounded memory, correctly charged against
    # capacity, for not re-decoding or re-concatenating on either path.  The
    # trailing gid keys every entry to its immutable generation: a compaction
    # swap simply stops referencing the old gid's entries (they age out of
    # the LRU) and can never serve them to the new generation's queries.
    # Every cached array is frozen read-only before insertion: accessors hand
    # out the cache's backing arrays, and a caller mutating one would
    # otherwise silently corrupt later query results.

    @staticmethod
    def _freeze(a: np.ndarray) -> np.ndarray:
        a.setflags(write=False)
        return a

    def _decode_block_field(self, t: int, bi: int, field: int) -> np.ndarray:
        ctx = self._cur()
        key = (t, bi, field, ctx.gen.gid)
        v = self.cache.get(key)
        if v is None:
            if self.arena is not None:
                # cache-eviction stragglers outside the batched work-list
                self.metrics.inc("fallback_decodes")
                v = self._arena_ctx(ctx).decode_blocks([(t, bi, field)])[0]
            elif field == 0:
                v = ctx.gen.decode_block_ids(t, bi)
            else:
                v = ctx.gen.decode_block_tfs(t, bi)
            v = self._freeze(v)
            self.cache.put(key, v)
        return v

    def decode_block_ids(self, t: int, bi: int) -> np.ndarray:
        return self._decode_block_field(t, bi, 0)

    def decode_block_tfs(self, t: int, bi: int) -> np.ndarray:
        return self._decode_block_field(t, bi, 1)

    def decode_block(self, t: int, bi: int):
        return self.decode_block_ids(t, bi), self.decode_block_tfs(t, bi)

    def _term_concat(self, t: int, field: int, decode_one) -> np.ndarray:
        ctx = self._cur()
        key = (t, -1, field, ctx.gen.gid)
        v = self.cache.get(key)
        if v is None:
            nb = ctx.gen.n_blocks(t)
            if nb == 0:
                # frozen like every other accessor result (zero-length, so one
                # shared read-only singleton is contract-equivalent to caching)
                return _EMPTY_U32
            if self.arena is not None:
                self._prefetch_blocks([(t, bi, field) for bi in range(nb)])
            parts = [decode_one(t, bi) for bi in range(nb)]
            v = self._freeze(parts[0] if nb == 1 else np.concatenate(parts))
            self.cache.put(key, v, cost=nb)
        return v

    # ---- device prefetch planner ------------------------------------------- #

    def _prefetch_blocks(self, entries: list) -> None:
        """Dedupe a (term, block, field) work-list against the cache and
        decode the misses in one batched arena call."""
        ctx = self._cur()
        gid = ctx.gen.gid
        missing, seen = [], set()
        for e in entries:
            if e in seen or self.cache.contains(e + (gid,)):
                continue
            seen.add(e)
            missing.append(e)
        self.metrics.inc("worklist_decodes", len(missing))
        if not missing:
            return
        arena = self._arena_ctx(ctx)
        for e, a in zip(missing, arena.decode_blocks(missing)):
            self.cache.put(e + (gid,), self._freeze(a))

    def _prefetch_terms(self, terms, fields=(0, 1)) -> None:
        ctx = self._cur()
        entries = []
        for t in terms:
            if t not in ctx.gen.terms:
                continue
            nb = ctx.gen.n_blocks(t)
            for f in fields:
                if not self.cache.contains((t, -1, f, ctx.gen.gid)):
                    entries.extend((t, bi, f) for bi in range(nb))
        self._prefetch_blocks(entries)

    def term_ids(self, t: int) -> np.ndarray:
        return self._term_concat(t, 0, self.decode_block_ids)

    def term_tfs(self, t: int) -> np.ndarray:
        return self._term_concat(t, 1, self.decode_block_tfs)

    def term_postings(self, t: int):
        return self.term_ids(t), self.term_tfs(t)

    # ---- live (mutation-aware) posting views -------------------------------- #

    def _df_live(self, t: int, ctx: _ExecCtx) -> int:
        """Live document frequency of term t under ``ctx``: generation df
        minus tombstoned postings plus delta postings (memoized per ctx).
        ``known`` under mutation means df_live > 0 — exactly the terms a
        from-scratch rebuild would still contain."""
        if not ctx.mutated:
            tp = ctx.gen.terms.get(t)
            return tp.df if tp is not None else 0
        v = ctx._df.get(t)
        if v is None:
            tp = ctx.gen.terms.get(t)
            base = tp.df if tp is not None else 0
            if base and len(ctx.dead):
                base -= int(_dead_hits(ctx.dead, self.term_ids(t)).sum())
            ctx._df[t] = v = base + ctx.delta.df(t)
        return v

    def _live_postings(self, t: int, ctx: _ExecCtx):
        """Term t's live postings under ``ctx``: generation postings minus
        tombstones, merge-sorted with the delta postings (disjoint by the
        shadowing invariant) — identical arrays to a from-scratch rebuild's
        ``term_ids``/``term_tfs``."""
        if t in ctx.gen.terms:
            ids, tfs = self.term_ids(t), self.term_tfs(t)
            if len(ctx.dead) and len(ids):
                keep = ~_dead_hits(ctx.dead, ids)
                ids, tfs = ids[keep], tfs[keep]
        else:
            ids, tfs = _EMPTY_U32, _EMPTY_U32
        dids, dtfs = ctx.delta.postings(t)
        if len(dids):
            if len(ids) == 0:
                return dids.copy(), dtfs.copy()
            ids = np.concatenate([ids, dids])
            tfs = np.concatenate([tfs, dtfs])
            order = np.argsort(ids, kind="stable")
            ids, tfs = ids[order], tfs[order]
        return ids, tfs

    # ---- fused decode-and-intersect ---------------------------------------- #

    def _block_plan(self, t: int, cand: np.ndarray):
        """Skip-table pruning: candidate cut points per block of term t and
        the indices of blocks whose docid range contains a candidate."""
        gen = self._cur().gen
        firsts = gen.block_firsts(t).astype(cand.dtype)  # avoid a cast copy
        cut = np.empty(len(firsts) + 1, np.int64)
        cut[:-1] = np.searchsorted(cand, firsts)
        cut[-1] = len(cand)
        return cut, np.flatnonzero(cut[1:] > cut[:-1])

    def _term_fused(self, t: int, sel) -> bool:
        """Fallback capability probe for un-planned calls (``and_query`` and
        friends); plans resolve this once per term instead."""
        return (self._fused and self.arena is not None
                and self.arena.has_fused(t, sel))

    def _intersect_plan(self, t: int, cut: np.ndarray, sel: np.ndarray,
                        cand: np.ndarray, fused: bool | None = None) -> np.ndarray:
        if len(sel) == 0:
            return np.zeros(0, np.uint32)
        if self._term_fused(t, sel) if fused is None else fused:
            return self.arena.fused_and(t, sel, cand)
        out = [intersect.intersect_sorted(self.decode_block_ids(t, int(bi)),
                                          cand[cut[bi]:cut[bi + 1]])
               for bi in sel]
        return np.concatenate(out)

    def _intersect_term(self, t: int, cand: np.ndarray) -> np.ndarray:
        """Intersect sorted candidates with term t, decoding only the blocks
        whose docid range [first_i, first_{i+1}) contains a candidate."""
        cut, sel = self._block_plan(t, cand)
        return self._intersect_plan(t, cut, sel, cand)

    def and_many(self, queries: list,
                 terms: Mapping[int, TermCaps] | None = None) -> list:
        """AND all queries together, round-batched for the device arenas —
        the legacy loop that syncs every query's candidates to the host
        between rounds (planned execution now runs the device-resident
        ``_and_many_resident`` instead; this stays for direct callers and as
        the host-candidate reference).  Serves the current generation only —
        planned execution layers tombstones and the delta on top.

        Round r intersects every still-active query with its (r+1)-th rarest
        term; the round's (term, block) needs across the WHOLE batch are
        deduped and decoded in one arena call, so each hot block decodes at
        most once per batch and the Python-loop count drops from O(total
        selected blocks) to O(rounds).  Results are bit-identical to
        ``and_query`` per query.

        ``terms`` is the plan's resolved per-term capability map; when absent
        (direct calls) capabilities are probed on the fly.
        """
        def term_fused(t, sel):
            return (terms[t].fused if terms is not None
                    else self._term_fused(t, sel))

        gen = self._cur().gen
        qterms = [sorted((t for t in q if t in gen.terms),
                         key=lambda t: gen.terms[t].df) for q in queries]
        for ts in qterms:               # raw seed-term block references,
            if ts:                      # pre-dedup (work-list metric)
                self.metrics.inc("worklist_refs", gen.n_blocks(ts[0]))
        if self.arena is not None:
            self._prefetch_terms({ts[0] for ts in qterms if ts}, fields=(0,))
        cands = [self.term_ids(ts[0]) if ts else _EMPTY_U32 for ts in qterms]
        owned = [False] * len(queries)
        r = 1
        while True:
            active = [i for i, ts in enumerate(qterms)
                      if len(ts) > r and len(cands[i])]
            if not active:
                break
            plans, worklist = {}, []
            for i in active:
                t = qterms[i][r]
                cut, sel = self._block_plan(t, cands[i])
                fused = term_fused(t, sel)
                plans[i] = (t, cut, sel, fused)
                self.metrics.inc("worklist_refs", len(sel))
                if self.arena is not None and not fused:
                    worklist.extend((t, int(bi), 0) for bi in sel)
            if self.arena is not None:
                self._prefetch_blocks(worklist)
            for i in active:
                t, cut, sel, fused = plans[i]
                cands[i] = self._intersect_plan(t, cut, sel, cands[i], fused)
                owned[i] = True
            if self.arena is not None:
                # every active query's surviving candidates just landed on
                # the host for the next round's block plan
                self.metrics.inc("cand_syncs", len(active))
            r += 1
        return [c if o else c.copy() for c, o in zip(cands, owned)]

    # ---- device-resident AND rounds ---------------------------------------- #

    def _select_blocks_static(self, t: int, cov_f: np.ndarray,
                              cov_l: np.ndarray) -> np.ndarray:
        """Blocks of term t whose [first, last] docid range overlaps any of
        the seed coverage intervals — computed purely from build-time skip
        metadata, so no candidate state is needed on the host.  The selection
        is a superset of the blocks holding candidates, which is all the
        probe-and-scatter round needs for exactness."""
        gen = self._cur().gen
        f = gen.block_firsts(t)
        l = gen.block_lasts(t)
        j = np.searchsorted(cov_l, f)            # first interval ending >= f
        hit = j < len(cov_l)
        jc = np.minimum(j, max(len(cov_f) - 1, 0))
        return np.flatnonzero(hit & (cov_f[jc] <= l))

    def _round_rows(self, entries: list) -> dict:
        """Dedupe a round's (term, block) docid work-list against the cache
        and decode the misses in one device-resident arena call; returns
        {(t, bi): (padded_device_row, n)} for every entry, pinned for the
        round regardless of cache eviction pressure."""
        ctx = self._cur()
        gid = ctx.gen.gid
        out: dict = {}
        missing: list = []
        for e in entries:
            if e in out:
                continue
            v = self.cache.get((e[0], e[1], 2, gid))
            if v is None:
                out[e] = None
                missing.append(e)
            else:
                out[e] = v
        self.metrics.inc("worklist_decodes", len(missing))
        if missing:
            rows, ns = self._arena_ctx(ctx).decode_blocks_device(missing)
            for e, row, n in zip(missing, rows, ns):
                out[e] = (row, n)
                self.cache.put((e[0], e[1], 2, gid), (row, n))
        return out

    def _round_memo(self, key, build):
        """Bounded memo for a round's stacked device arrays: identical
        work-lists (the benchmark loop, hot repeated batches) reuse the
        gathered rows instead of re-walking caches and re-gathering.  Keys
        carry the gid, so entries are immutable for their lifetime."""
        v = self._round_cache.get(key)
        if v is None:
            v = build()
            self._round_cache[key] = v
            while len(self._round_cache) > _ROUND_CACHE:
                self._round_cache.popitem(last=False)
        else:
            self._round_cache.move_to_end(key)
        return v

    def _stack_worklist(self, entries: list):
        """Shared round discipline for the resident AND and ranked paths:
        dedupe a round's (qslot, term, block) entries, decode the unique
        (term, block) rows once (``_round_rows``), and fan them out to the
        entries with one device gather, padded to the jit bucket (padding
        repeats entry 0 with n=0, which scatters nothing).  Returns
        (rows, qslots, ns, bucket); memoized per (gid, work-list)."""
        key = (self._cur().gen.gid, "ids", tuple(entries))
        return self._round_memo(key,
                                lambda: self._stack_worklist_build(entries))

    def _stack_worklist_build(self, entries: list):
        pairs = [(t, bi) for _, t, bi in entries]
        rows = self._round_rows(pairs)
        ent = list(rows)
        ent_row = {e: j for j, e in enumerate(ent)}
        mat = (rows[ent[0]][0][None] if len(ent) == 1
               else jnp.stack([rows[e][0] for e in ent]))
        p = _bucket(len(entries))
        sel = np.zeros(p, np.int64)
        sel[:len(entries)] = [ent_row[e] for e in pairs]
        qs = np.zeros(p, np.int32)
        qs[:len(entries)] = [q for q, _, _ in entries]
        ns = np.zeros(p, np.int32)
        ns[:len(entries)] = [rows[e][1] for e in pairs]
        return mat[jnp.asarray(sel)], qs, ns, p

    def _stack_dense(self, entries: list, ubs=None, with_codes: bool = False):
        """Gather a round's dense-bitmap work-list (``repro.core
        .dense_bitmap`` blocks, selected per block through the arena's
        ``dense_slot`` capability table): the entries' 128-word posting
        windows — and, ``with_codes``, their window-aligned score tiles —
        in one device gather each, padded to the jit bucket.  Returns
        (words, tiles, qslots, w0, act, ub); padding carries act=False and
        ub=0, which every dense kernel treats as inert.  The device gathers
        are memoized per (gid, block-list)."""
        ctx = self._cur()
        ar = self._arena_ctx(ctx)
        n = len(entries)
        p = _bucket(n)
        blocks = tuple((t, bi) for _, t, bi in entries)

        def build():
            sel = np.zeros(p, np.int64)
            sel[:n] = [ar.dense_slot[b] for b in blocks]
            words = ar.dense_words[jnp.asarray(sel)]
            tiles = None
            if with_codes:
                sa = ar.ensure_scores().scores
                srows = np.zeros(p, np.int64)
                srows[:n] = [sa.dense_slot[b] for b in blocks]
                tiles = sa.dense_tiles[jnp.asarray(srows)]
            w0 = np.zeros(p, np.int32)
            w0[:n] = ar.dense_w0[sel[:n]]
            return words, tiles, jnp.asarray(w0)

        key = (ctx.gen.gid, "dense", with_codes, blocks)
        words, tiles, w0 = self._round_memo(key, build)
        qs = np.zeros(p, np.int32)
        qs[:n] = [q for q, _, _ in entries]
        act = np.zeros(p, bool)
        act[:n] = True
        ub = np.zeros(p, np.int32)
        ub[:n] = ubs if ubs is not None else _UB_ALWAYS
        return (words, tiles, jnp.asarray(qs), w0, jnp.asarray(act),
                jnp.asarray(ub))

    def _score_rows(self, sa, pairs: list, p: int):
        """Memoized ``ScoreArena.rows`` for a round's (term, block) work
        -list, padded to the jit bucket by repeating entry 0 (padded lanes
        scatter with n=0, so the values are inert)."""
        key = (self._cur().gen.gid, "codes", p, tuple(pairs))
        return self._round_memo(
            key, lambda: sa.rows(pairs + [pairs[0]] * (p - len(pairs))))

    def _and_qterms(self, queries: list, ctx: _ExecCtx) -> list:
        """Per-query known terms sorted rarest-first (df ascending) with the
        resident AND path's mutation-epoch semantics: a query whose live
        terms include a delta-only term has no generation matches at all and
        collapses to the ``[]`` sentinel (seeds empty; the caller unions in
        the delta-segment scan).  Factored out so sharded execution can
        resolve the batch ONCE on the parent and hand each shard its
        restriction (``_shard_qterms``)."""
        idx = ctx.gen
        if not ctx.mutated:
            return [sorted((t for t in q if t in idx.terms),
                           key=lambda t: idx.terms[t].df) for q in queries]
        qterms = []
        for q in queries:
            known = [t for t in q if self._df_live(t, ctx) > 0]
            if any(t not in idx.terms for t in known):
                qterms.append([])       # delta-only live term: no base match
            else:
                qterms.append(sorted(known, key=lambda t: idx.terms[t].df))
        return qterms

    def _and_many_resident(self, queries: list,
                           terms: Mapping[int, TermCaps] | None = None,
                           use_fused: bool = False,
                           qterms: list | None = None) -> list:
        """AND the batch device-resident; the single host copy turns the
        final bitmaps into sorted docid arrays (``_and_bitmap_resident``
        keeps everything before that copy on device — the ``and_scored``
        path consumes the bitmap directly and never downloads it)."""
        bm, _, _ = self._and_bitmap_resident(queries, terms, use_fused,
                                             qterms=qterms)
        self.metrics.inc("final_syncs")
        return intersect_rounds.extract_ids(np.asarray(bm)[:len(queries)],
                                            self._cur().gen.n_docs)

    def _and_bitmap_resident(self, queries: list,
                             terms: Mapping[int, TermCaps] | None = None,
                             use_fused: bool = False,
                             qterms: list | None = None):
        """AND the batch with candidates device-resident across rounds.

        Round 0 scatters every query's rarest term into its row of a
        segmented candidate bitmap (one device array for the whole batch);
        round r >= 1 decodes the round's deduped (term, block) work-list,
        probes each decoded docid against its query's bitmap segment and
        scatters the survivors — all on device
        (``kernels/intersect_rounds``).  Block selection is conservative and
        static (seed-term coverage intervals from the skip tables), so no
        candidate ever returns to the host until the single final copy.
        Under ``use_fused`` the rounds run the segmented Pallas
        decode+probe kernel over the packed gap tiles instead.

        Under a mutation epoch the seed bitmap is ANDed with the epoch's
        packed live row right after round 0 (one upload, zero downloads):
        tombstoned docs fail every later probe, so the final bitmaps hold
        exactly the generation's LIVE intersections.  A query whose live
        terms include a delta-only term has no generation matches at all and
        seeds empty; the caller unions in the delta-segment scan.

        Returns (bitmap, qterms, cov) — the (nqp, words) device bitmap, the
        per-query known terms sorted rarest-first, and the per-query seed
        coverage intervals (for further static block selection).  Results
        are bit-identical to ``and_query`` per query.  An injected
        ``qterms`` (sharded execution) replaces the per-query resolution —
        the caller already computed it against the GLOBAL epoch and
        restricted it to this engine's doc range.
        """
        ctx = self._cur()
        idx = ctx.gen
        ar = self._arena_ctx(ctx)
        nq = len(queries)
        words, crows = intersect_rounds.bitmap_geometry(idx.n_docs)
        if nq == 0:
            return jnp.zeros((0, words), jnp.uint32), [], {}
        if qterms is None:
            qterms = self._and_qterms(queries, ctx)
        nqp = _bucket(nq)
        bm = jnp.zeros((nqp, words), jnp.uint32)

        def run_round(bm, plain, fused_pairs, dense, active_idx, probe):
            """One committed AND round: every representation split (sparse
            arena decode, fused Pallas decode, dense bitmap windows) probes
            the same OLD bitmap and ORs survivors into ONE shared new bitmap
            — exact because a block is served by exactly one representation,
            so the splits' docid sets are disjoint — then a single commit
            folds active rows forward (empty splits leave active rows
            empty: with no survivors their intersections are empty)."""
            active = np.zeros(nqp, bool)
            active[active_idx] = True
            new = jnp.zeros_like(bm)
            if plain:
                rows, qs, ns, _ = self._stack_worklist(plain)
                new = intersect_rounds.round_accumulate(
                    new, rows, jnp.asarray(qs), jnp.asarray(ns), bm,
                    probe=probe)
            if fused_pairs:
                ids, hits, qs = ar.fused_round(
                    fused_pairs, bm.reshape(nqp * crows, -1))
                new = intersect_rounds.round_accumulate_masked(
                    new, ids.reshape(len(qs), -1), jnp.asarray(qs),
                    hits.reshape(len(qs), -1))
            if dense:
                dw, _, dqs, dw0, dact, _ = self._stack_dense(dense)
                new = intersect_rounds.dense_round_accumulate(
                    new, dw, dqs, dw0, dact, bm, probe=probe)
            return intersect_rounds.round_commit(bm, new, jnp.asarray(active))

        def split_dense(pairs):
            """Route (qslot, t, bi) entries to their serving representation
            (per-block capability: the arena's dense window table)."""
            sparse, dense = [], []
            for e in pairs:
                (dense if (e[1], e[2]) in ar.dense_slot else sparse).append(e)
            self.metrics.inc("blocks_dense", len(dense))
            return sparse, dense

        # round 0: seed every query's bitmap row with its rarest term
        seeds = [i for i, ts in enumerate(qterms)
                 if ts and idx.terms[ts[0]].df]
        for ts in qterms:               # raw seed-term block references,
            if ts:                      # pre-dedup (work-list metric)
                self.metrics.inc("worklist_refs", idx.n_blocks(ts[0]))
        pairs0 = [(i, qterms[i][0], bi) for i in seeds
                  for bi in range(idx.n_blocks(qterms[i][0]))]
        plain0, dense0 = split_dense(pairs0)
        with self.tracer.span("and/seed", lane=self.trace_lane, nq=nq,
                              plain=len(plain0), dense=len(dense0)):
            bm = run_round(bm, plain0, [], dense0, seeds, probe=False)
            self.tracer.fence(bm)
        if ctx.mutated and len(ctx.dead):
            # gate the seed with the epoch's live row: every later round
            # only keeps survivors, so one AND suffices for the whole batch
            with self.tracer.span("and/tomb_gate", lane=self.trace_lane,
                                  dead=len(ctx.dead)):
                bm = bm & ctx.live_dev(words)[None, :]
                self.tracer.fence(bm)
            self.metrics.inc("tomb_gates")
        cov = {i: (idx.block_firsts(qterms[i][0]),
                   idx.block_lasts(qterms[i][0])) for i in seeds}

        live = set(seeds)
        r = 1
        while True:
            active = [i for i in live if len(qterms[i]) > r]
            if not active:
                break
            self.metrics.inc("resident_rounds")
            plain, fused_pairs, dense = [], [], []
            for i in active:
                t = qterms[i][r]
                sel = self._select_blocks_static(t, *cov[i])
                self.metrics.inc("worklist_refs", len(sel))
                f = use_fused and (terms[t].fused if terms is not None
                                   else ar.has_fused(t, sel))
                for bi in sel:
                    e = (i, t, int(bi))
                    if (t, int(bi)) in ar.dense_slot:
                        dense.append(e)
                        self.metrics.inc("blocks_dense")
                    elif f:
                        fused_pairs.append(e)
                    else:
                        plain.append(e)
            with self.tracer.span("and/round", lane=self.trace_lane, r=r,
                                  plain=len(plain), fused=len(fused_pairs),
                                  dense=len(dense)):
                bm = run_round(bm, plain, fused_pairs, dense, active,
                               probe=True)
                self.tracer.fence(bm)
            r += 1

        return bm, qterms, cov

    def and_query(self, terms: list) -> np.ndarray:
        ctx = self._cur()
        if ctx.mutated:
            return self._and_query_mut(list(terms), ctx)
        return self._and_gen([t for t in terms if t in ctx.gen.terms], ctx)

    def _and_gen(self, terms: list, ctx: _ExecCtx) -> np.ndarray:
        """AND over generation postings only (terms already known)."""
        terms = sorted(terms, key=lambda t: ctx.gen.terms[t].df)
        if not terms:
            return np.zeros(0, np.uint32)
        cand = self.term_ids(terms[0])
        owned = False                           # does the caller own `cand`?
        for t in terms[1:]:
            if len(cand) == 0:
                break
            cand = self._intersect_term(t, cand)
            owned = True
        # single-term (or empty-first-term) queries would otherwise hand back
        # the cache's frozen backing array
        return cand if owned else cand.copy()

    def _and_query_mut(self, terms: list, ctx: _ExecCtx) -> np.ndarray:
        """Live AND under a mutation epoch: the generation intersection
        (tombstone-filtered) unioned with the delta-segment scan — bitwise
        what ``and_query`` on a from-scratch rebuild returns.

        ``known`` keeps terms with live postings (df_live > 0), matching the
        rebuild's unknown-term semantics: a term whose postings are all
        tombstoned vanishes from the rebuilt index and is ignored, while a
        live term still ANDs.  If any live term exists only in the delta, no
        generation doc can match it (delta docids shadow their base copies),
        so the generation half is empty.
        """
        known = [t for t in terms if self._df_live(t, ctx) > 0]
        if not known:
            return np.zeros(0, np.uint32)
        if all(t in ctx.gen.terms for t in known):
            base = self._and_gen(known, ctx)
            if len(ctx.dead) and len(base):
                base = base[~_dead_hits(ctx.dead, base)]
        else:
            base = _EMPTY_U32
        return _merge_disjoint(base, ctx.delta.scan_and(known))

    # ---- BM25 -------------------------------------------------------------- #

    def term_scores(self, t: int):
        ctx = self._cur()
        key = (t,) + ctx.skey
        v = self.score_cache.get(key)
        if v is None:
            if ctx.mutated:
                ids, tfs = self._live_postings(t, ctx)
                ids = self._freeze(ids)
                df = len(ids)
            else:
                ids, tfs = self.term_ids(t), self.term_tfs(t)
                df = ctx.gen.terms[t].df
            sc = bm25_scores(tfs, ctx.doclen[ids], df, ctx.n_docs, ctx.avdl)
            v = (ids, self._freeze(sc))
            self.score_cache.put(key, v)
        return v

    def or_query(self, terms: list, k: int = 10):
        ctx = self._cur()
        if ctx.mutated:
            use = [t for t in terms if self._df_live(t, ctx) > 0]
        else:
            use = [t for t in terms if t in ctx.gen.terms]
        parts = [self.term_scores(t) for t in use]
        if not parts:
            return []
        ids = np.concatenate([p[0] for p in parts])
        sc = np.concatenate([p[1] for p in parts])
        docs, inv = np.unique(ids, return_inverse=True)
        if len(docs) == 0:
            return []
        tot = np.zeros(len(docs))
        np.add.at(tot, inv, sc)
        return topk_select(docs, tot, k)

    def _score_docs(self, terms: list, docs: np.ndarray, k: int) -> list:
        """The host float top-k oracle: exact BM25 over ``docs`` (term-level
        score vectors through the score cache), selected with the shared
        argpartition + docid-tiebreak rule (:func:`repro.index.scores
        .topk_select`).  Under a mutation epoch the score vectors are the
        LIVE ones (``_live_postings``), accumulated in the same query-term
        order as the unmutated path."""
        if len(docs) == 0:
            return []
        ctx = self._cur()
        scores = np.zeros(len(docs))
        for t in terms:
            if ctx.mutated:
                if self._df_live(t, ctx) <= 0:
                    continue        # unknown (or fully tombstoned) scores 0
            elif t not in ctx.gen.terms or not ctx.gen.terms[t].blocks:
                continue            # unknown or zero-posting term scores 0
            ids, sc = self.term_scores(t)
            pos = np.searchsorted(ids, docs)
            pos = np.clip(pos, 0, len(ids) - 1)
            hit = ids[pos] == docs
            scores += np.where(hit, sc[pos], 0.0)
        return topk_select(docs, scores, k)

    def _score_docs_blockwise(self, terms: list, docs: np.ndarray,
                              k: int) -> list:
        """Exact float rescore touching only the blocks that hold ``docs``
        (the ranked device path's final stage: candidates are few, so whole
        -term decodes would waste the pruning win).  Bitwise identical to
        :meth:`_score_docs` — same float formula (``bm25_scores``), same
        per-doc term accumulation order, same tie rule.  Generation-only
        (the mutated ranked path rescores with :meth:`_score_docs`, whose
        score vectors carry the live stats)."""
        if len(docs) == 0:
            return []
        ctx = self._cur()
        idx = ctx.gen
        scores = np.zeros(len(docs))
        plans = []
        prefetch = []
        for t in terms:
            if t not in idx.terms or not idx.terms[t].blocks:
                continue            # unknown or zero-posting term scores 0
            firsts = idx.block_firsts(t)
            bi = np.searchsorted(firsts, docs, side="right") - 1
            bi = np.where(idx.block_lasts(t)[np.maximum(bi, 0)] >=
                          docs.astype(np.int64), bi, -1)
            plans.append((t, bi))
            if self.arena is not None:
                prefetch.extend((t, int(b), f)
                                for b in np.unique(bi[bi >= 0]) for f in (0, 1))
        if prefetch:
            self._prefetch_blocks(prefetch)
        for t, bi in plans:
            df = idx.terms[t].df
            for b in np.unique(bi[bi >= 0]):
                sel = np.flatnonzero(bi == b)
                ids, tfs = self.decode_block(t, int(b))
                pos = np.searchsorted(ids, docs[sel])
                pos = np.clip(pos, 0, len(ids) - 1)
                hit = ids[pos] == docs[sel]
                sub = sel[hit]
                sc = bm25_scores(tfs[pos[hit]], ctx.doclen[docs[sub]], df,
                                 ctx.n_docs, ctx.avdl)
                scores[sub] += sc
        return topk_select(docs, scores, k)

    def _rescore_batch_blockwise(self, queries: list, cand: list,
                                 k: int) -> list:
        """Batch form of :meth:`_score_docs_blockwise`: the per-(term, block)
        decode + score work is amortized over the WHOLE batch — each term
        scores the union of its queries' candidates once, then every query
        accumulates its own docs in query-term order from the shared
        per-term vectors.  Bitwise identical to mapping
        :meth:`_score_docs_blockwise` over the batch: same elementwise
        ``bm25_scores`` values, same per-doc term accumulation order, and a
        candidate a term doesn't hold adds +0.0 exactly as the host oracle's
        ``np.where`` does (contributions are strictly positive, so no -0.0
        can ever sit in an accumulator).  Generation-only, like the
        per-query form."""
        union = {}
        for q, c in zip(queries, cand):
            if len(c) == 0:
                continue
            for t in dict.fromkeys(q):
                union.setdefault(t, []).append(c)
        ctx = self._cur()
        idx = ctx.gen
        plans, prefetch = [], []
        for t, parts in union.items():
            if t not in idx.terms or not idx.terms[t].blocks:
                continue            # unknown or zero-posting term scores 0
            docs = (parts[0] if len(parts) == 1
                    else np.unique(np.concatenate(parts)))
            firsts = idx.block_firsts(t)
            bi = np.searchsorted(firsts, docs, side="right") - 1
            bi = np.where(idx.block_lasts(t)[np.maximum(bi, 0)] >=
                          docs.astype(np.int64), bi, -1)
            plans.append((t, docs, bi))
            if self.arena is not None:
                prefetch.extend((t, int(b), f)
                                for b in np.unique(bi[bi >= 0])
                                for f in (0, 1))
        if prefetch:
            self._prefetch_blocks(prefetch)
        shared = {}
        for t, docs, bi in plans:
            df = idx.terms[t].df
            vals = np.zeros(len(docs))
            for b in np.unique(bi[bi >= 0]):
                sel = np.flatnonzero(bi == b)
                ids, tfs = self.decode_block(t, int(b))
                pos = np.searchsorted(ids, docs[sel])
                pos = np.clip(pos, 0, len(ids) - 1)
                hit = ids[pos] == docs[sel]
                sub = sel[hit]
                vals[sub] = bm25_scores(tfs[pos[hit]], ctx.doclen[docs[sub]],
                                        df, ctx.n_docs, ctx.avdl)
            shared[t] = (docs, vals)
        out = []
        for q, c in zip(queries, cand):
            if len(c) == 0:
                out.append([])
                continue
            scores = np.zeros(len(c))
            for t in q:             # query-term order, duplicates kept
                e = shared.get(t)
                if e is not None:
                    docs, vals = e
                    scores += vals[np.searchsorted(docs, c)]
            out.append(topk_select(c, scores, k))
        return out

    def and_query_scored(self, terms: list, k: int = 10):
        return self._score_docs(terms, self.and_query(terms), k)

    # ---- device-resident ranked top-k (OR / and_scored) --------------------- #

    def _prune_ranked_blocks(self, sa, occs: list, r: int, theta0: int,
                             iq: int = 1 << 16) -> tuple:
        """Block-max prune for occurrence ``r`` of an OR query's term list:
        drop blocks whose upper bound — own block-max plus every other
        occurrence's max code over the block's docid range (BMW-style
        aligned bounds, 0 when the other term has no posting there) plus the
        quantization margin — cannot beat ``theta0``.  Dropped blocks only
        lose contributions of docs provably outside the true top-k (see
        ``repro/index/scores.py``).

        Returns (keep, n_pruned, ub[keep]): the kept blocks' bounds ride to
        the device, where every later round re-tests them against the
        adaptively promoted theta (``kernels/topk``) and self-compacts the
        work-list with zero host syncs.  ``iq`` deflates the static
        threshold under tombstone-only epochs (Q16.16, 65536 = identity)."""
        t = occs[r]
        gen = self._cur().gen
        nb = gen.n_blocks(t)
        if nb == 0:
            return np.arange(0), 0, _EMPTY_I64
        firsts = gen.block_firsts(t)
        lasts = gen.block_lasts(t)
        base = sa.slot[(t, 0)]          # a term's slots are contiguous
        ub = sa.block_max[base:base + nb].astype(np.int64) + len(occs)
        for t2 in occs[:r] + occs[r + 1:]:
            ub += sa.range_max_many(t2, firsts, lasts)
        if theta0 <= 0:
            return np.arange(nb), 0, ub
        keep = np.flatnonzero(ub > (theta0 * iq) >> 16)
        return keep, nb - len(keep), ub[keep]

    def _iq_tomb(self, ts: list, ctx: _ExecCtx) -> int:
        """Per-query Q16.16 threshold deflation ``floor(2**16 / Rmax)`` for
        a tombstone-only epoch (the re-arm note in ``repro/index/scores.py``):
        ``Rmax`` is the worst live/generation idf ratio over the query's
        terms — deletes only shrink df, so every ratio is >= 1 — and the
        integer floor is nudged down until ``iq * Rmax <= 2**16``, so float
        rounding can never push a scaled threshold above theta / Rmax."""
        n = ctx.n_docs
        rmax = 1.0
        for t in ts:
            tp = ctx.gen.terms.get(t)
            if tp is None:
                continue
            dfg = tp.df
            dfl = self._df_live(t, ctx)
            if dfl <= 0 or dfl >= dfg:
                continue
            ig = float(np.log(1.0 + (n - dfg + 0.5) / (dfg + 0.5)))
            il = float(np.log(1.0 + (n - dfl + 0.5) / (dfl + 0.5)))
            if ig > 0.0 and il > ig:
                rmax = max(rmax, il / ig)
        iq = int((1 << 16) / rmax)
        while iq * rmax > (1 << 16):
            iq -= 1
        return max(iq, 1)

    def _ranked_resident(self, queries: list, k: int, mode: str,
                         terms: Mapping[int, TermCaps] | None = None,
                         use_fused: bool = False) -> list:
        """Ranked top-k with scores device-resident across rounds.

        Round r scatters every query's r-th strongest term occurrence
        (quantized impact codes next to the decoded docid rows) into a
        segmented score accumulator (``kernels/topk``) — for ``and_scored``
        gated by the AND-result bitmap, which itself never left the device
        (``_and_bitmap_resident``).  OR work-lists are block-max pruned
        against the static per-query threshold theta0 before any decode.
        The single host copy per batch is the compacted candidate bitmap
        (k-th quantized sum minus the quantization margin — a provable
        superset of the float top-k), which the block-lazy float oracle
        rescores exactly: results are bitwise identical to the host path,
        ties broken by ascending docid.

        After every round the per-query theta is PROMOTED on device: the
        pooled k-th statistic of the accumulated state (``kernels/topk
        .pooled_threshold``) is a sound, monotone lower bound on the final
        k-th sum, and each work-list entry carries its quantized upper bound
        to the device, so later rounds drop entries that can no longer beat
        the promoted theta — the work-list compacts itself against promoted
        bounds with zero per-round host syncs.

        Under a delta-bearing mutation epoch the quantized tables carry
        generation-time stats, so the theta cut is disarmed (theta0 = 0,
        margin so large the compact keeps every member — the candidate set
        degrades to the full live membership bitmap, still an exact
        superset) and OR rounds gate with the epoch's live row
        (``gated=True``: tombstoned docs never enter the accumulator or the
        membership bitmap — no new downloads).  TOMBSTONE-ONLY epochs stay
        armed instead: deletes only raise idf, so a per-query Q16.16
        deflation ``iq = floor(2**16 / Rmax)`` keeps every threshold
        comparison sound against the generation-time tables (the re-arm
        note in ``repro/index/scores.py``), with theta0 re-derived from the
        tombstone-filtered top-code tables (``ScoreArena.theta0_live``).
        The final rescore unions the delta-segment scan per query and runs
        the live-stat float oracle; a fresh compaction re-arms fully.
        """
        ctx = self._cur()
        idx = ctx.gen
        nq = len(queries)
        if nq == 0:
            return []
        known, base_ts, tomb_only, armed, margins_l, iqs_l = \
            self._ranked_params(queries, k, ctx)
        if known is None:
            return [[] for _ in queries]
        acc, member, margins, iq_dev, width, _ = self._ranked_accumulate(
            queries, k, mode, terms, use_fused, base_ts=base_ts, armed=armed,
            tomb_only=tomb_only, margins_l=margins_l, iqs_l=iqs_l)
        theta = topk.topk_threshold(acc, min(k, width))
        cand_bm = topk.candidate_bitmap(acc, member, theta,
                                        jnp.asarray(margins), iq_dev)
        # the single host copy: candidate bitmaps -> exact float rescore
        self.metrics.inc("final_syncs")
        cand = intersect_rounds.extract_ids(np.asarray(cand_bm)[:nq],
                                            idx.n_docs)
        return self._ranked_rescore(queries, cand, k, mode, known, ctx)

    def _ranked_params(self, queries: list, k: int, ctx: _ExecCtx):
        """The batch's epoch-derived ranked parameters, resolved once
        against the GLOBAL ctx (sharded execution computes them on the
        parent and injects them into every shard — a shard's own view would
        mis-derive them: shard-local dfs deflate iq unsoundly, and a shard
        never sees the delta, so it would wrongly re-arm a delta-bearing
        epoch).  Returns (known, base_ts, tomb_only, armed, margins_l,
        iqs_l), with known None when the batch trivially yields empties."""
        idx = ctx.gen
        if ctx.mutated:
            known = [[t for t in q if self._df_live(t, ctx) > 0]
                     for q in queries]
            base_ts = [[t for t in ts if t in idx.terms] for ts in known]
        else:
            known = [[t for t in q if t in idx.terms] for q in queries]
            base_ts = known
        if k <= 0 or not any(known):
            return None, None, False, False, None, None
        # tombstone-only epoch: no delta docs and corpus stats untouched
        # (deletes never shrink the doc space or rewrite doclens — the
        # array check guards the doclen-override corner), so pruning stays
        # armed through the idf-ratio deflation
        tomb_only = (ctx.mutated and len(ctx.delta) == 0
                     and ctx.n_docs == idx.n_docs
                     and np.array_equal(ctx.doclen, idx.doclen))
        armed = not ctx.mutated or tomb_only
        margins_l = [len(ts) if armed else _KEEP_ALL_MARGIN for ts in known]
        iqs_l = ([self._iq_tomb(ts, ctx) if ts else 1 << 16 for ts in known]
                 if tomb_only else [1 << 16] * len(queries))
        return known, base_ts, tomb_only, armed, margins_l, iqs_l

    def _ranked_accumulate(self, queries: list, k: int, mode: str,
                           terms: Mapping[int, TermCaps] | None,
                           use_fused: bool, *, base_ts: list, armed: bool,
                           tomb_only: bool, margins_l: list, iqs_l: list,
                           qterms: list | None = None,
                           theta0_l: list | None = None):
        """The round-loop core of :meth:`_ranked_resident`: accumulate the
        batch's quantized impact codes device-resident and return the final
        device state ``(acc, member, margins, iq_dev, width, words)`` — no
        threshold, no download.  Epoch-derived inputs (``base_ts`` ...
        ``iqs_l``) are INJECTED (:meth:`_ranked_params`): under sharded
        execution this engine serves one doc-range shard and they must come
        from the parent's global epoch.  ``theta0_l`` optionally overrides
        the static OR thresholds — the sharded path pools per-shard theta0
        host-side (max over shards is sound: some shard provably holds k
        docs reaching it) and seeds every shard with the pooled value; the
        per-round adaptive promotion stays shard-local, so rounds still run
        with zero cross-shard syncs."""
        ctx = self._cur()
        idx = ctx.gen
        nq = len(queries)
        self.arena.ensure_scores()
        sa = self.arena.scores
        words, crows = intersect_rounds.bitmap_geometry(idx.n_docs)
        nqp = _bucket(nq)
        width = topk.accum_width(idx.n_docs)
        acc = jnp.zeros((nqp, width), jnp.uint32)
        member = jnp.zeros((nqp, words), jnp.uint32)
        gate = cov = None
        if mode == "and_scored":
            gate, _, cov = self._and_bitmap_resident(queries, terms,
                                                     use_fused, qterms=qterms)
        eff_gate = gate
        if gate is None and ctx.mutated and len(ctx.dead):
            # OR mode under deletes: the epoch's live row gates every lane
            with self.tracer.span("ranked/tomb_gate", lane=self.trace_lane,
                                  dead=len(ctx.dead)):
                eff_gate = jnp.broadcast_to(ctx.live_dev(words),
                                            (nqp, words))
            self.metrics.inc("tomb_gates")
        gate_tiles = None
        if use_fused:       # the probe target of the fused rounds: the AND
            # bitmap (live-gated under mutation), the live row, or (OR mode,
            # no deletes) all-ones so only lane validity gates
            gate_tiles = (eff_gate if eff_gate is not None else
                          jnp.full((nqp, words), jnp.uint32(0xFFFFFFFF))
                          ).reshape(nqp * crows, -1)
        ar = self.arena
        order = [sorted(ts, key=lambda t: -sa.term_max[t]) for ts in base_ts]
        margins = np.zeros(nqp, np.int32)
        margins[:nq] = margins_l
        iqs = np.full(nqp, 1 << 16, np.int64)
        iqs[:nq] = iqs_l
        if mode == "or" and armed:
            theta0 = (list(theta0_l) if theta0_l is not None else
                      [(sa.theta0_live(ts, k, ctx.dead) if tomb_only
                        else sa.theta0(ts, k)) for ts in base_ts])
        else:
            theta0 = [0] * nq
        th0 = np.zeros(nqp, np.uint32)
        th0[:nq] = theta0
        theta_dev = jnp.asarray(th0)
        iq_dev = jnp.asarray(iqs.astype(np.uint32))
        nrounds = max((len(ts) for ts in order), default=0)
        for r in range(nrounds):
            # detached span (begin/end): covers work-list selection +
            # block-max pruning + the round's kernel calls without
            # re-indenting the loop body; decode/<codec> child spans nest
            # under the thread's enclosing CM (engine/execute) instead
            _rsp = self.tracer.begin("ranked/round", lane=self.trace_lane,
                                     r=r, mode=mode)
            plain, fused_pairs, dense = [], [], []
            plain_ub, fused_ub, dense_ub = [], [], []
            for i in range(nq):
                ts = order[i]
                if len(ts) <= r or (cov is not None and i not in cov):
                    continue        # done, or AND seed empty -> nothing scores
                t = ts[r]
                if mode == "or":
                    sel, pruned, ubs_i = self._prune_ranked_blocks(
                        sa, ts, r, theta0[i], int(iqs[i]))
                else:
                    sel, pruned, ubs_i = (
                        self._select_blocks_static(t, *cov[i]), 0, None)
                self.metrics.inc("blocks_pruned", pruned)
                self.metrics.inc("blocks_scored", len(sel))
                f = use_fused and (terms[t].fused if terms is not None
                                   else ar.has_fused(t, sel))
                for j, bi in enumerate(sel):
                    e = (i, t, int(bi))
                    u = int(ubs_i[j]) if ubs_i is not None else _UB_ALWAYS
                    if ((t, int(bi)) in ar.dense_slot
                            and (t, int(bi)) in sa.dense_slot):
                        dense.append(e)
                        dense_ub.append(u)
                        self.metrics.inc("blocks_dense")
                    elif f:
                        fused_pairs.append(e)
                        fused_ub.append(u)
                    else:
                        plain.append(e)
                        plain_ub.append(u)
            self.metrics.inc("score_rounds")
            if plain:
                rows, qs, ns, p = self._stack_worklist(plain)
                codes = self._score_rows(sa, [(t, bi) for _, t, bi in plain],
                                         p)
                ubp = np.zeros(p, np.int32)
                ubp[:len(plain)] = plain_ub
                acc, member = topk.score_round(
                    acc, member, rows, jnp.asarray(qs), codes,
                    jnp.asarray(ns),
                    eff_gate if eff_gate is not None else member,
                    jnp.asarray(ubp), theta_dev, iq_dev,
                    gated=eff_gate is not None)
            if fused_pairs:
                ids, hits, codes, qs, ubf = ar.fused_round_scored(
                    fused_pairs, gate_tiles, fused_ub)
                acc, member = topk.score_round_masked(
                    acc, member, ids.reshape(len(qs), -1), jnp.asarray(qs),
                    codes.reshape(len(qs), -1), hits.reshape(len(qs), -1),
                    jnp.asarray(ubf), theta_dev, iq_dev)
            if dense:
                dw, dtiles, dqs, dw0, _, dub = self._stack_dense(
                    dense, dense_ub, with_codes=True)
                acc, member = topk.dense_score_round(
                    acc, member, dtiles, dw, dqs, dw0, dub, theta_dev,
                    iq_dev, eff_gate if eff_gate is not None else member,
                    gated=eff_gate is not None)
            if mode == "or" and armed and k <= width // 32 and r + 1 < nrounds:
                # adaptive promotion: the pooled k-th is a sound, monotone
                # lower bound on the final k-th sum (sound only with the
                # full k — fewer pooled groups than k would over-promote)
                theta_dev = jnp.maximum(theta_dev,
                                        topk.pooled_threshold(acc, k))
            self.tracer.fence(acc)
            self.tracer.end(_rsp, plain=len(plain), fused=len(fused_pairs),
                            dense=len(dense))
        return acc, member, margins, iq_dev, width, words

    def _ranked_rescore(self, queries: list, cand: list, k: int, mode: str,
                        known: list, ctx: _ExecCtx) -> list:
        """The exact float tail shared by the unsharded and sharded ranked
        paths: block-lazy batch rescore on an unmutated epoch, else the
        per-query delta-segment union + live-stat oracle.  ``cand`` holds
        GLOBAL sorted docids (sharded execution translates each shard's
        extraction by its range base before concatenating), so the tail is
        bitwise identical either way.  Span ``ranked/rescore``."""
        with self.tracer.span("ranked/rescore", lane=self.trace_lane,
                              nq=len(queries), mode=mode,
                              cands=sum(len(c) for c in cand)):
            if not ctx.mutated:
                return self._rescore_batch_blockwise(queries, cand, k)
            out = []
            for i, (q, c) in enumerate(zip(queries, cand)):
                if mode == "or":
                    d = ctx.delta.scan_any(known[i])
                else:
                    d = (ctx.delta.scan_and(known[i]) if known[i]
                         else _EMPTY_U32)
                out.append(self._score_docs(q, _merge_disjoint(c, d), k))
            return out

    # ---- doc-range sharded execution ---------------------------------------- #

    def _shard_engines(self, ctx: _ExecCtx):
        """The per-shard serving set for ``ctx``'s generation: a
        :class:`repro.index.shards.ShardSpec` plus one sub-engine per
        NON-EMPTY shard (empty ranges hold ``None``), each over a
        self-contained stats-fixed shard generation
        (:func:`repro.index.shards.shard_generation`).  The whole set is
        built eagerly and cached ON the generation keyed by (bounds, fused),
        so a ``compact()`` swaps every shard atomically: a pinned plan keeps
        the old generation's set addressable through its ctx, and the new
        epoch's first query builds the new generation's set — mixed
        -generation serving is impossible by construction.  With a mesh of
        one device per shard, each shard's arenas (and its rounds, via
        ``_pinned``) are placed on its own device; otherwise the shards run
        logically on the default device with identical results."""
        from . import shards as shards_lib
        cfg = self._shard_cfg
        gen = ctx.gen
        bounds = cfg["bounds"]
        if bounds is not None and bounds[-1] == gen.n_docs:
            spec = shards_lib.ShardSpec(bounds)
        else:
            # derived boundaries — also the fallback when explicit bounds
            # went stale across a compaction (the doc space changed)
            spec = shards_lib.ShardSpec.derive(gen, cfg["n"])
        mesh = cfg["mesh"]
        key = (spec.bounds, self._fused)
        cache = getattr(gen, "_shard_serving", None)
        if cache is None:
            cache = gen._shard_serving = {}
        got = cache.get(key)
        if got is None:
            devs = (list(mesh.devices.flat)
                    if mesh is not None and mesh.devices.size == spec.n_shards
                    else None)
            engs = []
            for s, (lo, hi) in enumerate(spec.ranges()):
                if hi <= lo:
                    engs.append(None)
                    continue
                dev = devs[s] if devs is not None else None
                with (jax.default_device(dev) if dev is not None
                      else contextlib.nullcontext()):
                    sgen = shards_lib.shard_generation(gen, lo, hi)
                    eng = QueryEngine(sgen).to_device(fused=self._fused)
                    eng.arena.ensure_scores()
                eng._shard_device = dev
                eng.trace_lane = f"shard{s}"    # own Perfetto lane
                eng.metrics.relabel(shard=f"s{s}")
                engs.append(eng)
            cache[key] = got = (spec, engs)
        return got[0], got[1], mesh

    def _shard_ctx(self, ctx: _ExecCtx, lo: int, hi: int, sgen) -> _ExecCtx:
        """A shard's frozen view of the parent epoch: tombstones translated
        into the shard's local docid space, an EMPTY delta snapshot (delta
        docids all sit above the generation's doc space, so no shard serves
        them — the parent unions the delta scan into final results), and
        the parent's live stats where they matter.  The packed live bitmap
        is PRE-SLICED at the shard boundary (``pack_live_words_range``), so
        a tombstone epoch uploads only each shard's words, not the whole
        corpus's, on every shard."""
        key = (ctx.skey, lo, hi)
        got = self._sctx_cache.get(key)
        if got is not None:
            return got
        sctx = _ExecCtx.__new__(_ExecCtx)
        sctx.gen = sgen
        sctx.mutated = ctx.mutated
        sctx._df = {}
        sctx._live_dev = None
        sctx._live_host = None
        if ctx.mutated:
            from .segments import DeltaSegment
            sctx.delta = DeltaSegment.empty_snapshot()
        else:
            sctx.delta = None
        dead = ctx.dead
        sctx.dead = ((dead[(dead >= lo) & (dead < hi)] - lo)
                     if len(dead) else _EMPTY_I64)
        sctx.doclen = np.asarray(ctx.doclen)[lo:hi]
        sctx.n_docs = hi - lo
        sctx.avdl = ctx.avdl
        sctx.skey = tuple(ctx.skey) + (lo, hi)
        if len(sctx.dead):
            words, _ = intersect_rounds.bitmap_geometry(sgen.n_docs)
            sctx._live_host = intersect_rounds.pack_live_words_range(
                ctx.dead, lo, hi, words)
        self._sctx_cache[key] = sctx
        return sctx

    @staticmethod
    def _shard_qterms(ts: list, sgen) -> list:
        """One query's global rarest-first AND term list restricted to a
        shard.  A known term with no postings in the shard's doc range means
        NO doc in the range can match the conjunction — the ``[]`` sentinel
        (same convention as the delta-only case).  Otherwise the parent's
        order is kept verbatim: shard dfs are fixed up to the global ones,
        so re-sorting shard-side would reproduce it anyway."""
        if not ts or any(t not in sgen.terms for t in ts):
            return []
        return list(ts)

    @staticmethod
    @contextlib.contextmanager
    def _pinned(eng: "QueryEngine", sctx: _ExecCtx):
        """Run a sub-engine call under its shard ctx (and its mesh device,
        when placed): the shard's rounds then resolve ``_cur()`` to the
        shard's frozen epoch view, never the parent's."""
        prev = eng._ctx
        eng._ctx = sctx
        dev = getattr(eng, "_shard_device", None)
        try:
            if dev is not None:
                with jax.default_device(dev):
                    yield
            else:
                yield
        finally:
            eng._ctx = prev

    def _execute_sharded(self, plan: ExecutionPlan, ctx: _ExecCtx) -> list:
        """Planned execution over the doc-range shard set: every resident
        round runs shard-local (doc-wise partitioning means AND candidates
        and score accumulators never cross shards — zero cross-shard
        candidate syncs), ranked modes merge with ONE collective of
        per-shard (k-th sum, candidate count) statistics, and the exact
        float tail runs on the parent against global docids.  Results are
        bitwise identical to the unsharded paths."""
        queries = [list(q) for q in plan.queries]
        fused = plan.placement == "fused"
        spec, engs, mesh = self._shard_engines(ctx)
        parts = [(lo, hi, eng, self._shard_ctx(ctx, lo, hi, eng.idx))
                 for (lo, hi), eng in zip(spec.ranges(), engs)
                 if eng is not None]
        if plan.mode == "and":
            return self._sharded_and(queries, fused, parts, ctx)
        return self._sharded_ranked(queries, plan.k, plan.mode, fused,
                                    parts, mesh, ctx)

    def _sharded_and(self, queries: list, fused: bool, parts: list,
                     ctx: _ExecCtx) -> list:
        """AND across shards: the parent resolves the batch's known terms
        once, each shard intersects its restriction device-resident, and the
        per-shard extractions concatenate in range order (already globally
        sorted — ranges are disjoint and ascending)."""
        qterms = self._and_qterms(queries, ctx)
        per_q = [[] for _ in queries]
        for lo, hi, eng, sctx in parts:
            sub_q = [self._shard_qterms(ts, eng.idx) for ts in qterms]
            with self._pinned(eng, sctx):
                ids = eng._and_many_resident(queries, None, fused,
                                             qterms=sub_q)
            self.metrics.inc("shard_final_syncs")
            for i, a in enumerate(ids):
                if len(a):
                    per_q[i].append(a + np.uint32(lo))
        base = [(ps[0] if len(ps) == 1 else np.concatenate(ps)) if ps
                else _EMPTY_U32.copy() for ps in per_q]
        if not ctx.mutated:
            return base
        out = []
        for q, b in zip(queries, base):
            known = [t for t in q if self._df_live(t, ctx) > 0]
            d = ctx.delta.scan_and(known) if known else _EMPTY_U32
            out.append(_merge_disjoint(b, d))
        return out

    def _sharded_ranked(self, queries: list, k: int, mode: str, fused: bool,
                        parts: list, mesh, ctx: _ExecCtx) -> list:
        """Ranked top-k across shards, margin-preserving merge:

        1. the parent derives the epoch parameters ONCE
           (:meth:`_ranked_params`) and, for armed OR batches, pools the
           per-shard static thresholds host-side (max over shards — sound:
           the argmax shard provably holds k docs reaching its theta0);
        2. every shard runs the full round loop shard-local
           (:meth:`_ranked_accumulate` under ``_pinned``) — zero cross
           -shard candidate syncs, the adaptive promotion stays per-shard;
        3. the ONE collective: per-shard (k-th quantized sum, candidate
           count) statistics all-gather + max (``collectives
           .merge_topk_stats`` — under ``shard_map`` when a mesh places the
           shards, host-stacked otherwise, same wire bytes either way).
           theta_merged = max_s theta_s <= the global k-th sum, so cutting
           every shard at theta_merged - margin keeps every global top-k
           doc: the union of per-shard candidate bitmaps stays a guaranteed
           superset of the float top-k under the SAME quantization-margin
           contract as the unsharded path (parent margins >= shard margins,
           global iq deflation injected);
        4. per-shard candidate extraction, translated to global docids and
           concatenated in range order, feeds the parent's exact float tail
           (:meth:`_ranked_rescore`) — bitwise identical to unsharded."""
        nq = len(queries)
        known, base_ts, tomb_only, armed, margins_l, iqs_l = \
            self._ranked_params(queries, k, ctx)
        if known is None or not parts:
            return [[] for _ in queries]
        theta0_l = None
        if mode == "or" and armed:
            pooled = [0] * nq
            for lo, hi, eng, sctx in parts:
                sa = eng.arena.ensure_scores().scores
                for i, ts in enumerate(base_ts):
                    sts = [t for t in ts if t in eng.idx.terms]
                    if not sts:
                        continue
                    th = (sa.theta0_live(sts, k, sctx.dead) if tomb_only
                          else sa.theta0(sts, k))
                    if th > pooled[i]:
                        pooled[i] = int(th)
            theta0_l = pooled
        and_q = (self._and_qterms(queries, ctx) if mode == "and_scored"
                 else None)
        per_shard, th_parts, cnt_parts = [], [], []
        for lo, hi, eng, sctx in parts:
            sts = [[t for t in ts if t in eng.idx.terms] for ts in base_ts]
            qt = ([self._shard_qterms(ts, eng.idx) for ts in and_q]
                  if and_q is not None else None)
            with self._pinned(eng, sctx):
                acc, member, margins, iq_dev, _, _ = eng._ranked_accumulate(
                    queries, k, mode, None, fused, base_ts=sts, armed=armed,
                    tomb_only=tomb_only, margins_l=margins_l, iqs_l=iqs_l,
                    qterms=qt, theta0_l=theta0_l)
                # raw k on purpose: a shard holding fewer than k scored docs
                # reports theta 0 (the sound degenerate answer) — min(k,
                # width) would report its width-th sum, which can EXCEED the
                # global k-th and break the superset contract
                th, cnt = topk.topk_stats(acc, k)
            per_shard.append((lo, hi, eng, sctx, acc, member, margins,
                              iq_dev))
            th_parts.append(th)
            cnt_parts.append(cnt)
        from repro.distributed import collectives
        with self.tracer.span("sharded/merge", lane=self.trace_lane,
                              shards=len(parts), nq=nq):
            theta_m, _, wire = collectives.merge_topk_stats(th_parts,
                                                            cnt_parts,
                                                            mesh=mesh)
        self.metrics.inc("merge_syncs")
        self.metrics.inc("collective_bytes", int(wire))
        theta_dev = jnp.asarray(theta_m.astype(np.uint32))
        cand_parts = [[] for _ in queries]
        shard_cands = []
        for lo, hi, eng, sctx, acc, member, margins, iq_dev in per_shard:
            with self._pinned(eng, sctx):
                bm = topk.candidate_bitmap(acc, member, theta_dev,
                                           jnp.asarray(margins), iq_dev)
                self.metrics.inc("shard_final_syncs")
                ids = intersect_rounds.extract_ids(np.asarray(bm)[:nq],
                                                   hi - lo)
            shard_cands.append(ids)
            for i, a in enumerate(ids):
                if len(a):
                    cand_parts[i].append(a + np.uint32(lo))
        self._last_shard_cands = shard_cands
        cand = [(ps[0] if len(ps) == 1 else np.concatenate(ps)) if ps
                else _EMPTY_U32 for ps in cand_parts]
        return self._ranked_rescore(queries, cand, k, mode, known, ctx)

    # ---- planned execution -------------------------------------------------- #

    def plan(self, batch: QueryBatch,
             placement: Optional[str] = None) -> ExecutionPlan:
        """Resolve a batch into a typed :class:`ExecutionPlan` (span
        ``engine/plan``); see :meth:`_plan_impl` for the full contract."""
        with self.tracer.span("engine/plan", lane=self.trace_lane,
                              mode=batch.mode, nq=len(batch.queries)):
            return self._plan_impl(batch, placement)

    def _plan_impl(self, batch: QueryBatch,
                   placement: Optional[str] = None) -> ExecutionPlan:
        """Resolve a batch into a typed :class:`ExecutionPlan`: placement
        (host / device / fused, following the engine's current arena state)
        plus every referenced term's codec capabilities, read once from the
        codec registry's declarations.  ``execute(plan)`` then runs with no
        per-codec or per-flag branching.

        Auto-placement (``placement=None``) demotes small batches to the
        host using the measured :class:`CrossoverTable` from the committed
        ``BENCH_query.json`` when one exists, else the static
        ``HOST_BATCH_MAX`` rule; ``plan.note`` records which source decided.
        An explicit ``placement`` skips the demotion entirely (the serving
        path and benchmarks use this to pin a placement per run) and is
        validated against the engine's arena state up front.

        The plan also pins the current mutation epoch (:class:`_ExecCtx`):
        its generation, a frozen delta snapshot, and the tombstone set.
        Executing the plan after later inserts/deletes/compactions returns
        the SAME results it would have returned at plan time."""
        _check_mode(batch.mode)
        ctx = self._cur()
        note = ""
        resident = self.arena is not None or self._shard_cfg is not None
        if placement is not None:
            if placement not in PLACEMENTS:
                raise ValueError(f"unknown placement {placement!r}; "
                                 f"placements: {PLACEMENTS}")
            if placement != "host" and not resident:
                raise ValueError(
                    f"explicit placement {placement!r} needs device arenas; "
                    "call to_device() on this engine first")
            if placement == "fused" and not self._fused:
                raise ValueError(
                    "explicit placement 'fused' needs fused tile arenas; "
                    "call to_device(fused=True) on this engine first")
            note = f"placement {placement!r} pinned by caller"
        else:
            placement = ("fused" if resident and self._fused
                         else "device" if resident else "host")
            if placement != "host":
                n = len(batch.queries)
                xo = get_crossover()
                cut = xo.cut_for(batch.mode) if xo is not None else None
                if cut is not None:
                    if n <= cut:
                        note = (f"auto-placed host: batch={n} <= "
                                f"host_batch_max={cut} for "
                                f"mode={batch.mode!r} "
                                f"(measured crossover, {xo.source}, "
                                f"sizes={list(xo.sizes)})")
                        placement = "host"
                elif n <= HOST_BATCH_MAX:
                    reason = ("no BENCH_query.json baseline" if xo is None
                              else f"{xo.source}: no host->device crossover "
                                   f"measured for mode={batch.mode!r}")
                    note = (f"auto-placed host: batch={n} <= "
                            f"HOST_BATCH_MAX={HOST_BATCH_MAX} "
                            f"(static rule; {reason})")
                    placement = "host"
        if self._shard_cfg is not None and placement != "host":
            spec, _, mesh = self._shard_engines(ctx)
            snote = (f"sharded x{spec.n_shards} bounds={list(spec.bounds)} "
                     f"({'mesh-placed' if mesh is not None else 'logical'})")
            note = f"{note}; {snote}" if note else snote
        if ctx.mutated:
            mnote = (f"pinned epoch {ctx.skey}: {len(ctx.dead)} tombstone(s), "
                     f"{len(ctx.delta)} delta doc(s)")
            note = f"{note}; {mnote}" if note else mnote
        terms: dict[int, TermCaps] = {}
        for q in batch.queries:
            for t in q:
                if t in terms:
                    continue
                if t in ctx.gen.terms:
                    blocks = ctx.gen.terms[t].blocks
                    name = blocks[0][1].codec if blocks else None
                    spec = codec_lib.get(name) if name is not None else None
                    # sharded plans record the nominal capability only —
                    # each shard re-probes its OWN arena's fused coverage
                    # at execution (its block geometry differs)
                    terms[t] = TermCaps(
                        codec=name,
                        arena=bool(spec is not None and spec.arena is not None),
                        fused=(placement == "fused"
                               and (self._shard_cfg is not None
                                    or self.arena.has_fused(
                                        t, range(len(blocks))))))
                elif ctx.delta is not None and ctx.delta.has_term(t):
                    # delta-only term: no compressed blocks, host scan only
                    terms[t] = TermCaps(codec=None, arena=False, fused=False)
        return ExecutionPlan(mode=batch.mode, k=batch.k, placement=placement,
                             queries=tuple(tuple(q) for q in batch.queries),
                             terms=terms, note=note, ctx=ctx)

    def execute(self, work) -> list:
        """Run an :class:`ExecutionPlan` (span ``engine/execute``); see
        :meth:`_execute_impl` for the full contract."""
        if isinstance(work, QueryBatch):
            work = self.plan(work)
        with self.tracer.span("engine/execute", lane=self.trace_lane,
                              mode=work.mode, placement=work.placement,
                              nq=len(work.queries)):
            return self._execute_impl(work)

    def _execute_impl(self, work) -> list:
        """Run an :class:`ExecutionPlan`; results align with the planned
        queries.  Passing a ``QueryBatch`` is a deprecated shim that plans
        implicitly (bit-identical results).

        Execution happens under the plan's pinned ctx: the generation, delta
        snapshot, and tombstone set resolved at plan time — so a
        ``compact()`` racing an in-flight plan never changes its results
        (the pinned generation's arena and caches stay addressable by gid).

        On the host placement queries are processed grouped by sorted term
        signature so queries sharing terms hit the decoded-block/score caches
        back to back.  On the device/fused placements AND semantics run
        round-batched through ``_and_many_resident`` — one deduped arena
        decode per round across the whole batch — and OR/scored modes run the
        resident ranked accumulator.
        """
        if isinstance(work, QueryBatch):
            work = self.plan(work)
        plan: ExecutionPlan = work
        _check_mode(plan.mode)
        ctx: _ExecCtx = plan.ctx if plan.ctx is not None else self._cur()
        if plan.placement != "host":
            if self._shard_cfg is not None:
                # sharded serving: the shard set (not self.arena) holds the
                # arenas; sub-engines pin their shard ctxs per call
                prev_ctx, self._ctx = self._ctx, ctx
                try:
                    return self._execute_sharded(plan, ctx)
                finally:
                    self._ctx = prev_ctx
            if self.arena is None:
                raise ValueError(
                    f"plan placement {plan.placement!r} needs device arenas; "
                    "call to_device() on this engine (or re-plan on it) first")
            arena = self._arena_ctx(ctx)
            if plan.placement == "fused" and arena._pk is None:
                raise ValueError(
                    "plan placement 'fused' needs fused tile arenas; call "
                    "to_device(fused=True) on this engine (or re-plan on it) "
                    "first")
            prev_ctx, self._ctx = self._ctx, ctx
            prev_arena, self.arena = self.arena, arena
            try:
                return self._execute_device(plan, ctx)
            finally:
                self._ctx, self.arena = prev_ctx, prev_arena
        fn = {"and": self.and_query,
              "or": lambda q: self.or_query(q, plan.k),
              "and_scored": lambda q: self.and_query_scored(q, plan.k)}[plan.mode]
        order = sorted(range(len(plan.queries)),
                       key=lambda i: tuple(sorted(plan.queries[i])))
        results = [None] * len(plan.queries)
        # a host plan stays pinned to host intersection AND host block
        # decodes even on an engine that has arenas — placement is the
        # plan's contract, not a hint (and per-block arena calls would be
        # strictly slower than the numpy oracle for the tiny batches the
        # auto-placement sends here); the bits are identical either way.
        prev_ctx, self._ctx = self._ctx, ctx
        prev_fused, self._fused = self._fused, False
        prev_arena, self.arena = self.arena, None
        try:
            for i in order:
                results[i] = fn(list(plan.queries[i]))
        finally:
            self._ctx = prev_ctx
            self._fused, self.arena = prev_fused, prev_arena
        return results

    def _execute_device(self, plan: ExecutionPlan, ctx: _ExecCtx) -> list:
        queries = [list(q) for q in plan.queries]
        fused = plan.placement == "fused"
        if plan.mode == "and":
            base = self._and_many_resident(queries, plan.terms, fused)
            if not ctx.mutated:
                return base
            out = []
            for q, b in zip(queries, base):
                known = [t for t in q if self._df_live(t, ctx) > 0]
                d = ctx.delta.scan_and(known) if known else _EMPTY_U32
                out.append(_merge_disjoint(b, d))
            return out
        return self._ranked_resident(queries, plan.k, plan.mode,
                                     plan.terms, fused)
