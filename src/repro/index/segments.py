"""LSM delta segments + tombstones: the mutable half of the streaming index.

``InvertedIndex`` generations are immutable — the compressed blocks, skip
tables, impact tables, and device arenas built from them never change after
build/compact.  Writes land here instead (the Upscaledb paper's recipe for
keeping SIMD-compressed integer runs live under updates, PAPERS.md):

  * :class:`DeltaSegment` — a small host-side mutable segment holding whole
    documents (``docid -> (doclen, {term: tf})``).  Inserts and upserts go
    here; queries union the compressed generation's results with a brute
    -force scan of this segment (it is small by construction — ``compact()``
    drains it into the next generation).
  * :class:`Tombstones` — deleted (or upsert-shadowed) base docids.  Serving
    applies them as a *live bitmap* gate on every probe: the device paths
    seed their segmented candidate bitmaps from :meth:`Tombstones.live_words`
    (packed in the ``kernels/intersect_rounds`` geometry, uploaded once per
    mutation epoch, never downloaded), the host paths mask with
    :meth:`Tombstones.mask`.

Shadowing invariant: inserting a docid that exists in the current generation
always tombstones the base copy first, so the generation's postings and the
delta segment are disjoint at all times — query-result unions are plain
sorted merges and every doc has exactly one authoritative version.

Both structures carry a monotonically increasing ``version`` so caches and
execution plans can key on the mutation epoch; ``snapshot()`` returns a
frozen copy that pins a plan's view of the delta while the live segment
keeps absorbing writes.
"""

from __future__ import annotations

import numpy as np


class DeltaSegment:
    """Host-side mutable posting segment, organized doc-major.

    Doc-major (a forward index) rather than term-major because the segment is
    the *write* side: inserts and deletes are whole-document operations, and
    the term-major views queries need (``postings``, ``scan_and``,
    ``scan_any``) are derived on demand and memoized per version.
    """

    def __init__(self):
        self._docs: dict = {}        # docid -> (doclen, {term: tf})
        self.version = 0
        self.frozen = False
        self._views: dict = {}       # (kind, key) -> memoized per-version view

    # ---- mutation ----------------------------------------------------------- #

    def _touch(self) -> None:
        if self.frozen:
            raise RuntimeError("frozen DeltaSegment snapshots are immutable")
        self.version += 1
        self._views.clear()

    def insert(self, docid: int, terms: dict, doclen: int) -> None:
        """Add (or replace) one document.  ``terms`` maps term -> tf (> 0)."""
        docid = int(docid)
        if docid < 0:
            raise ValueError(f"docid must be >= 0, got {docid}")
        if doclen <= 0:
            raise ValueError(f"doclen must be > 0, got {doclen}")
        clean = {}
        for t, tf in terms.items():
            if int(tf) <= 0:
                raise ValueError(f"tf must be > 0, got {tf} for term {t}")
            clean[int(t)] = int(tf)
        self._touch()
        self._docs[docid] = (int(doclen), clean)

    def remove(self, docid: int) -> bool:
        """Drop one document; True if it was present."""
        if int(docid) not in self._docs:
            return False
        self._touch()
        del self._docs[int(docid)]
        return True

    def snapshot(self) -> "DeltaSegment":
        """Frozen copy pinning the current contents (plans hold these)."""
        snap = DeltaSegment()
        snap._docs = dict(self._docs)        # doc payloads are never mutated
        snap.version = self.version
        snap.frozen = True
        return snap

    _empty: "DeltaSegment | None" = None

    @classmethod
    def empty_snapshot(cls) -> "DeltaSegment":
        """The shared frozen empty segment.  Doc-range shard execution
        contexts pin this: shard-local rounds never consult a delta — delta
        docs live outside every shard's generation and are merged once, on
        the parent, after the cross-shard candidate merge."""
        if cls._empty is None:
            cls._empty = cls().snapshot()
        return cls._empty

    # ---- views -------------------------------------------------------------- #

    def __len__(self) -> int:
        return len(self._docs)

    def __bool__(self) -> bool:
        return bool(self._docs)

    def __contains__(self, docid) -> bool:
        return int(docid) in self._docs

    def doclen_of(self, docid: int) -> int:
        return self._docs[int(docid)][0]

    def terms_of(self, docid: int) -> dict:
        return self._docs[int(docid)][1]

    def items(self):
        return self._docs.items()

    def max_docid(self) -> int:
        """Largest docid held, -1 when empty (sizes the doc space)."""
        return max(self._docs) if self._docs else -1

    def df(self, t: int) -> int:
        """Number of delta docs containing term t."""
        return int(np.sum([t in d[1] for d in self._docs.values()], initial=0))

    def has_term(self, t: int) -> bool:
        return any(t in d[1] for d in self._docs.values())

    def n_postings(self) -> int:
        return sum(len(d[1]) for d in self._docs.values())

    def postings(self, t: int):
        """Term-major view: (sorted uint32 docids, aligned uint32 tfs)."""
        key = ("postings", t)
        v = self._views.get(key)
        if v is None:
            ids = sorted(d for d, (_, ts) in self._docs.items() if t in ts)
            v = (np.asarray(ids, np.uint32),
                 np.asarray([self._docs[d][1][t] for d in ids], np.uint32))
            self._views[key] = v
        return v

    def scan_and(self, terms) -> np.ndarray:
        """Sorted uint32 docids of delta docs containing EVERY term (the
        brute-force AND half of a query; empty term list -> empty)."""
        terms = list(terms)
        if not terms:
            return np.zeros(0, np.uint32)
        ids = sorted(d for d, (_, ts) in self._docs.items()
                     if all(t in ts for t in terms))
        return np.asarray(ids, np.uint32)

    def scan_any(self, terms) -> np.ndarray:
        """Sorted uint32 docids of delta docs containing ANY term (the
        ranked-candidate half of a query)."""
        tset = set(terms)
        ids = sorted(d for d, (_, ts) in self._docs.items()
                     if tset.intersection(ts))
        return np.asarray(ids, np.uint32)


class Tombstones:
    """Deleted / shadowed base docids, with packed live-bitmap views.

    The docid set is host-side truth; serving consumes it as masks:
    ``mask(n)`` for the numpy paths, ``live_words(n)`` packed LSB-first in
    the exact geometry of ``kernels.intersect_rounds.bitmap_geometry`` so the
    device paths can seed their segmented candidate bitmaps from it (one
    upload per mutation epoch — the gate itself never syncs anything back).
    """

    def __init__(self):
        self._dead: set = set()
        self.version = 0
        self._views: dict = {}

    def add(self, docid: int) -> bool:
        """Tombstone one docid; True if newly dead."""
        docid = int(docid)
        if docid in self._dead:
            return False
        self._dead.add(docid)
        self.version += 1
        self._views.clear()
        return True

    def __len__(self) -> int:
        return len(self._dead)

    def __bool__(self) -> bool:
        return bool(self._dead)

    def __contains__(self, docid) -> bool:
        return int(docid) in self._dead

    def sorted_ids(self, below: int | None = None) -> np.ndarray:
        """Sorted int64 dead docids (optionally only those < ``below``)."""
        key = ("ids", below)
        v = self._views.get(key)
        if v is None:
            ids = np.asarray(sorted(self._dead), np.int64)
            if below is not None:
                ids = ids[ids < below]
            ids.setflags(write=False)
            self._views[key] = v = ids
        return v

    def mask(self, n_docs: int) -> np.ndarray:
        """Frozen bool live mask over [0, n_docs): True = live."""
        key = ("mask", n_docs)
        v = self._views.get(key)
        if v is None:
            m = np.ones(n_docs, bool)
            m[self.sorted_ids(below=n_docs)] = False
            m.setflags(write=False)
            self._views[key] = v = m
        return v

    def live_words(self, n_docs: int, words: int) -> np.ndarray:
        """Frozen packed uint32 live bitmap: bit d of word d // 32 (LSB
        -first) is 1 iff doc d is live; bits in [n_docs, words * 32) are 0 so
        seeding a candidate bitmap from this never admits out-of-range docs."""
        key = ("words", n_docs, words)
        v = self._views.get(key)
        if v is None:
            bits = np.zeros(words * 32, np.uint8)
            bits[:n_docs] = self.mask(n_docs)
            w = np.packbits(bits, bitorder="little").view(np.uint32)
            w.setflags(write=False)
            self._views[key] = v = w
        return v
