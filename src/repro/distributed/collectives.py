"""Compressed gradient collectives — the paper's bit packing applied to the
collective roofline term (DESIGN.md §3).

``compressed_psum_mean`` replaces a fp32 all-reduce with:

    quantize(int8/int4, per-block scale) -> all_to_all (reduce-scatter phase)
    -> local dequant+sum -> requantize -> all_gather -> dequant

Wire bytes: 2 * N * bits/8 vs ~8 * N for a ring fp32 all-reduce — 8x (int4)
or 4x (int8) off the collective term.  int4 payloads are bit-packed with the
same LSB-first shift+mask scheme as kernels/bitpack (the §3.2 vectorized pack;
on TPU the VPU executes it in-register before the ICI transfer).

Error feedback (1-bit-Adam style): callers keep a residual tree; quantization
error is re-injected next step, so the compression bias vanishes in
expectation.  Must be called INSIDE shard_map (manual axes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------- #
# int4 pack/unpack (pure jnp: shift+mask, 8 nibbles per uint32)
# --------------------------------------------------------------------------- #


def pack4(x: jnp.ndarray) -> jnp.ndarray:
    """int8 values in [-8, 7], length % 8 == 0 -> uint32 (n/8,)."""
    u = (x.astype(jnp.int32) & 0xF).astype(jnp.uint32).reshape(-1, 8)
    out = jnp.zeros(u.shape[0], jnp.uint32)
    for i in range(8):
        out = out | (u[:, i] << jnp.uint32(4 * i))
    return out


def unpack4(w: jnp.ndarray, n: int) -> jnp.ndarray:
    vals = []
    for i in range(8):
        nib = (w >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
        vals.append(nib.astype(jnp.int32))
    v = jnp.stack(vals, axis=1).reshape(-1)[:n]
    return jnp.where(v >= 8, v - 16, v).astype(jnp.int8)


# --------------------------------------------------------------------------- #
# quantization with per-block scales
# --------------------------------------------------------------------------- #

BLOCK = 1024


def _quantize(x: jnp.ndarray, bits: int):
    """x fp32 (n,) n % BLOCK == 0 -> (q int8 (n,), scales fp32 (n/BLOCK,))."""
    qmax = (1 << (bits - 1)) - 1
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1) / qmax
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -qmax - 1, qmax).astype(jnp.int8)
    return q.reshape(-1), scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray):
    return (q.astype(jnp.float32).reshape(-1, BLOCK) * scale[:, None]).reshape(-1)


# --------------------------------------------------------------------------- #
# compressed all-reduce (call inside shard_map over `axis_names`)
# --------------------------------------------------------------------------- #


def _pad_to(x: jnp.ndarray, m: int) -> jnp.ndarray:
    r = (-x.shape[0]) % m
    return jnp.concatenate([x, jnp.zeros(r, x.dtype)]) if r else x


def compressed_allreduce_flat(x: jnp.ndarray, axis_names, bits: int = 8):
    """Mean all-reduce of flat fp32 x over manual mesh axes, 2 quant rounds.

    Returns (reduced (n,), local_residual (n,)): residual = what THIS device's
    transmitted payload lost to quantization (phase-1 error everywhere, plus
    the phase-2 requantization error on the chunk this device owns) — the
    error-feedback term, computed with local knowledge only.
    """
    n = x.shape[0]
    r = jax.lax.psum(1, axis_names)                              # ring size
    me = jax.lax.axis_index(axis_names)
    xp = _pad_to(x.astype(jnp.float32), r * BLOCK)
    chunk = xp.shape[0] // r
    # phase 1: quantize, all_to_all rows (reduce-scatter)
    q, s = _quantize(xp, bits)
    resid = xp - _dequantize(q, s)                               # local phase-1 error
    qr = q.reshape(r, chunk)
    sr = s.reshape(r, chunk // BLOCK)
    if bits == 4:
        payload = jax.vmap(pack4)(qr)
        payload = jax.lax.all_to_all(payload, axis_names, 0, 0, tiled=False)
        got = jax.vmap(lambda w: unpack4(w, chunk))(payload)
    else:
        got = jax.lax.all_to_all(qr, axis_names, 0, 0, tiled=False)
    got_s = jax.lax.all_to_all(sr, axis_names, 0, 0, tiled=False)
    # local sum of everyone's contribution to my chunk
    part = jax.vmap(_dequantize)(got, got_s).sum(axis=0) / r     # mean
    # phase 2: requantize reduced chunk, all_gather
    q2, s2 = _quantize(part, bits)
    resid2 = part - _dequantize(q2, s2)                          # owner-chunk error
    resid = jax.lax.dynamic_update_slice(
        resid, jax.lax.dynamic_slice(resid, (me * chunk,), (chunk,)) + resid2 * r,
        (me * chunk,))
    if bits == 4:
        p2 = pack4(q2)
        allp = jax.lax.all_gather(p2, axis_names, axis=0, tiled=False)
        allq = jax.vmap(lambda w: unpack4(w, chunk))(allp)
    else:
        allq = jax.lax.all_gather(q2, axis_names, axis=0, tiled=False)
    alls = jax.lax.all_gather(s2, axis_names, axis=0, tiled=False)
    out = jax.vmap(_dequantize)(allq, alls).reshape(-1)
    return out[:n], resid[:n]


# --------------------------------------------------------------------------- #
# sharded-serving top-k merge (the serving path's ONE collective per batch)
# --------------------------------------------------------------------------- #


def merge_topk_stats(theta_parts, count_parts, mesh=None,
                     axis_name: str = "shards"):
    """Merge per-shard (k-th sum, candidate-count) statistics into the global
    ranked threshold — doc-range sharded serving's single collective.

    theta_parts / count_parts: per-shard device arrays, each (nqp,).  Returns
    ``(theta_merged (nqp,) int64 np, counts (S, nqp) np, wire_bytes)`` where
    theta_merged[q] = max over shards (a sound lower bound on the global
    k-th sum; see ``kernels/topk.topk_stats``).

    When ``mesh`` spans exactly one device per shard the merge runs as one
    ``all_gather`` + max under ``shard_map`` over ``axis_name``; otherwise
    (logical shards on one device — the CPU CI case) the per-shard vectors
    are stacked host-side, which moves the same ``wire_bytes``.
    """
    import numpy as np
    s = len(theta_parts)
    nqp = int(theta_parts[0].shape[0])
    wire_bytes = s * nqp * 4 * 2                 # u32 theta + i32 count
    if mesh is not None and mesh.devices.size == s and s > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = jax.device_put(jnp.stack([jnp.asarray(p) for p in theta_parts]),
                           NamedSharding(mesh, P(axis_name)))
        c = jax.device_put(jnp.stack([jnp.asarray(p) for p in count_parts]),
                           NamedSharding(mesh, P(axis_name)))

        def gather_max(ts, cs):
            g = jax.lax.all_gather(ts, axis_name, tiled=True)
            gc = jax.lax.all_gather(cs, axis_name, tiled=True)
            return g.max(axis=0), gc

        theta, counts = jax.jit(shard_map(
            gather_max, mesh=mesh, in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(), P()), check_rep=False))(t, c)
        return (np.asarray(theta).astype(np.int64),
                np.asarray(counts), wire_bytes)
    thetas = np.stack([np.asarray(p) for p in theta_parts])
    counts = np.stack([np.asarray(p) for p in count_parts])
    return thetas.max(axis=0).astype(np.int64), counts, wire_bytes


def compressed_psum_mean(tree, axis_names, bits: int = 8, error_feedback=None):
    """Mean-all-reduce a pytree with compression + error feedback.

    error_feedback: residual tree (same structure) or None.  Returns
    (reduced_tree, new_error_feedback).
    """
    leaves, tdef = jax.tree.flatten(tree)
    sizes = [int(l.size) for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    if error_feedback is not None:
        ef = jax.tree.leaves(error_feedback)
        flat = flat + jnp.concatenate([e.astype(jnp.float32).reshape(-1) for e in ef])
    red, new_ef_flat = compressed_allreduce_flat(flat, axis_names, bits)
    outs, efs, off = [], [], 0
    for l, sz in zip(leaves, sizes):
        outs.append(red[off:off + sz].reshape(l.shape).astype(l.dtype))
        efs.append(new_ef_flat[off:off + sz].reshape(l.shape))
        off += sz
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, efs)
