"""Logical-axis sharding: one model code path, many parallelism plans.

A *plan* maps logical axis names (both weight axes like "embed"/"heads"/
"expert" and activation axes like "act_seq") to mesh axis tuples.  Models
declare logical axes only; `shard(x, axes...)` applies
``with_sharding_constraint`` when a (mesh, plan) context is active and is a
no-op otherwise (CPU smoke tests).  Divisibility guard: any mesh axis that
does not evenly divide the dimension is dropped from the spec (recorded), so
every (arch x shape x mesh) cell lowers.

Parallelism vocabulary (DESIGN.md §8): DP/FSDP = ("pod","data") on batch and
weight fan-in dims; TP = "model" on heads/ffn; EP = "model" on expert dims;
SP = "model" on the residual sequence dim (Megatron-SP style: layer internals
re-shard via inserted all-gather / reduce-scatter).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass
class Plan:
    name: str
    rules: dict                     # logical axis -> tuple of mesh axes | None

    def axes_of(self, logical: Optional[str]):
        if logical is None:
            return None
        got = self.rules.get(logical, None)
        if got is None:
            return None
        if isinstance(got, str):
            return (got,)
        return tuple(got)


_STATE = threading.local()


def _active():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activate(mesh: Mesh, plan: Plan):
    prev = _active()
    _STATE.ctx = (mesh, plan)
    try:
        yield
    finally:
        _STATE.ctx = prev


def _mesh_size(mesh: Mesh, axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(logical_axes, dims=None) -> PartitionSpec:
    """Logical axes tuple -> PartitionSpec under the active plan.

    dims (optional): concrete dim sizes for the divisibility guard.
    """
    ctx = _active()
    if ctx is None:
        return PartitionSpec()
    mesh, plan = ctx
    parts = []
    for i, lax_ in enumerate(logical_axes):
        axes = plan.axes_of(lax_)
        if axes is None:
            parts.append(None)
            continue
        # ignore mesh axes absent from the active mesh (e.g. "pod" single-pod)
        axes = tuple(a for a in axes if a in mesh.shape)
        if dims is not None:
            # drop trailing mesh axes until the dim divides evenly
            while axes and dims[i] % _mesh_size(mesh, axes) != 0:
                axes = axes[:-1]
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    return PartitionSpec(*parts)


def shard(x, *logical_axes):
    """Apply a sharding constraint to an activation (no-op without a context)."""
    ctx = _active()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = spec_for(logical_axes, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def balanced_range_bounds(weights, n_parts: int) -> list:
    """Contiguous prefix partition of ``weights`` into ``n_parts`` with near
    -equal mass: boundary i lands where the cumulative mass is closest to
    ``i * total / n_parts``.  Returns ``n_parts + 1`` non-decreasing indices
    into [0, len(weights)]; empty parts (repeated bounds) are legal when the
    mass is too lumpy to split.

    Doc-range sharded serving uses this over per-tile posting mass (derived
    from the skip tables, no decode) to pick the shard boundaries — the
    build-derived analogue of a size-balanced split.
    """
    import numpy as np
    w = np.asarray(weights, np.float64)
    if n_parts <= 1 or not len(w):
        return [0, len(w)]
    cum = np.concatenate([[0.0], np.cumsum(w)])
    total = cum[-1]
    bounds = [0]
    for i in range(1, n_parts):
        target = total * i / n_parts
        j = int(np.argmin(np.abs(cum - target)))
        bounds.append(max(j, bounds[-1]))
    bounds.append(len(w))
    return bounds


def sharding_for_axes_tree(axes_tree, shape_tree):
    """Map a tree of logical-axes tuples (+ shapes) to NamedShardings."""
    ctx = _active()
    assert ctx is not None, "sharding_for_axes_tree requires an active plan"
    mesh, _ = ctx

    def one(axes, arr):
        return NamedSharding(mesh, spec_for(axes, dims=arr.shape))

    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda t: isinstance(t, tuple) and all(
                            isinstance(e, (str, type(None))) for e in t))


# --------------------------------------------------------------------------- #
# plans
# --------------------------------------------------------------------------- #

DP = ("pod", "data")                # data-parallel axes (pod collapses single-pod)


def lm_dense_plan() -> Plan:
    """Dense LMs (starcoder2, smollm): FSDP + sequence parallelism.

    Head counts (24/36/9) don't divide the 16-way model axis, so attention
    keeps heads local and shards the *sequence* over "model" (KV all-gathered
    — cheap under GQA with 2-4 KV heads).  Weights ZeRO-3-sharded over
    (DP x model), all-gathered per layer by SPMD.
    """
    return Plan("lm_dense_sp", {
        "batch": DP,
        "act_seq": ("model",), "act_seq_attn": ("model",),
        "act_seq_ffn": ("model",),
        "act_heads": None, "act_ffn": None, "act_embed": None,
        "act_expert": None, "act_ffn_expert": None,
        "embed": DP, "ffn": ("model",), "vocab": ("model",),
        "heads": None, "kv_heads": None,
    })


def lm_moe_plan(expert_parallel: bool, capacity_parallel: bool = False) -> Plan:
    """MoE LMs: Megatron-SP residual (seq over "model") + TP over heads/ffn
    inside the blocks + FSDP over DP.

    Expert compute, one of three modes:
      * EP (expert_parallel, E >= axis): experts over "model" (deepseek 64e)
      * TP (default): expert hidden dim over "model" — replicates the
        gathered token tensor across the axis (cotangent all-reduces)
      * CP (capacity_parallel): the capacity dim over "model" — tokens stay
        sharded through the expert matmuls; weights all-gathered bf16.
    """
    mode = "_ep" if expert_parallel else ("_cp" if capacity_parallel else "_tp")
    return Plan("lm_moe" + mode, {
        "batch": DP,
        "act_seq": ("model",),            # residual stream: sequence-sharded
        "act_seq_attn": None, "act_seq_ffn": None,
        "act_heads": ("model",), "act_ffn": ("model",), "act_embed": None,
        "act_expert": ("model",) if expert_parallel else None,
        "act_capacity": ("model",) if capacity_parallel else None,
        "act_ffn_expert": None if (expert_parallel or capacity_parallel) else ("model",),
        "embed": DP, "ffn": ("model",), "vocab": ("model",),
        "heads": ("model",), "kv_heads": ("model",),
        "expert": ("model",) if expert_parallel else None,
        "ffn_expert": None if (expert_parallel or capacity_parallel) else ("model",),
    })


def lm_serve_plan(dense: bool) -> Plan:
    """Serving: batch over DP, KV-cache sequence over "model" (split-K /
    flash-decoding style partial-softmax reductions inserted by SPMD)."""
    rules = {
        "batch": DP, "act_seq": None, "act_seq_attn": None,
        "act_seq_ffn": None, "act_cache": ("model",),
        "embed": DP, "ffn": ("model",), "vocab": ("model",),
        "heads": None if dense else ("model",),
        "kv_heads": None, "act_heads": None if dense else ("model",),
        "act_ffn": None if dense else ("model",),
        "expert": None if dense else ("model",),
        "ffn_expert": None,
        "act_expert": None, "act_ffn_expert": None,
        "act_embed": None,
    }
    return Plan("lm_serve", rules)


def gnn_plan() -> Plan:
    """GNN: edges sharded over all axes (segment-sum + psum), nodes replicated
    or row-sharded where divisible."""
    return Plan("gnn_edge_dp", {
        "batch": DP, "edges": ("pod", "data", "model"), "nodes": None,
        "feat": None, "act_embed": None, "embed": DP, "ffn": ("model",),
    })


def recsys_plan() -> Plan:
    """RecSys: embedding-table rows over "model" (EP), batch over DP axes,
    candidate corpus over "model" for retrieval scoring."""
    return Plan("recsys_ep", {
        "batch": DP, "table_rows": ("model",), "embed_dim": None,
        "act_embed": None, "embed": DP, "ffn": None, "mlp": ("model",),
        "candidates": ("model",),
    })
