"""2-stage GPipe pipeline parallelism across the "pod" axis (DESIGN §8).

The multi-pod mesh's "pod" axis defaults to data parallelism; for models too
deep/large for one pod, this module instead splits the layer stack in two
stages and microbatches activations across pods via collective-permute —
the inter-pod hop is the only DCN traffic, once per microbatch, overlapping
with the other pod's compute (GPipe schedule, bubble = 1/(n_micro+1)).

SPMD formulation: stacked layer params are sharded on the layer dim over
"pod" (each pod materializes only its half); both pods run the same program;
`ppermute` forwards stage-0 outputs to stage 1 one step delayed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def pipeline_2stage(layer_fn, params_stacked, x_micro, mesh, *, pod_axis="pod"):
    """Run x through L stacked layers split across 2 pods.

    layer_fn(lp, x) -> x              (one layer)
    params_stacked: pytree, leaves (L, ...) with L even
    x_micro: (n_micro, mb, ...) microbatched input (replicated over pod)
    Returns (n_micro, mb, ...) outputs after all L layers.
    """
    n_micro = x_micro.shape[0]

    def local(params_local, xm):
        # params_local leaves: (L/2, ...) — this pod's stage
        me = jax.lax.axis_index(pod_axis)

        def run_stage(x):
            def body(h, lp):
                return layer_fn(lp, h), None
            h, _ = jax.lax.scan(body, x, params_local)
            return h

        zero = jnp.zeros_like(xm[0])

        def step(buf, t):
            # stage 0 consumes microbatch t (valid for t < n_micro);
            # stage 1 consumes the buffer received from stage 0.
            inp = jnp.where(me == 0, xm[jnp.minimum(t, n_micro - 1)], buf)
            out = run_stage(inp)
            sent = jax.lax.ppermute(out, pod_axis, [(0, 1), (1, 0)])
            return sent, out

        _, outs = jax.lax.scan(step, zero, jnp.arange(n_micro + 1))
        # stage-1 outputs for steps 1..n_micro are the pipeline results
        return outs[1:]

    pspecs = jax.tree.map(lambda _: PS(pod_axis), params_stacked)
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(
            local, mesh=mesh,
            in_specs=(pspecs, PS()),
            out_specs=PS(pod_axis),       # (2*n_micro, ...) stacked by pod
            axis_names=frozenset({pod_axis}),
            check_vma=False,
        )
    else:  # jax < 0.5: shard_map lives in experimental, no axis_names/check_vma
        from jax.experimental.shard_map import shard_map
        mapped = shard_map(
            local, mesh=mesh,
            in_specs=(pspecs, PS()),
            out_specs=PS(pod_axis),
            check_rep=False,
        )
    out = mapped(params_stacked, x_micro)
    # pod 1's block holds the completed microbatches
    return out[n_micro:]
