from . import pipeline, synth  # noqa: F401
