"""Compressed data pipeline: the paper's codecs as a first-class storage layer.

Three integer-stream stores (DESIGN.md §3):
  * TokenStore    — LM token streams, blocked + Group-compressed; the training
    loader decodes blocks on the fly (host numpy decode or on-device
    vectorized decode).
  * AdjacencyStore — GNN CSR adjacency: per-row sorted column ids -> d-gap ->
    codec.  Reconstructing a row is decode + prefix-sum (the kernels/scan_add
    hot path on TPU).
  * BagStore      — recsys multi-hot id bags: sorted ids per bag -> d-gap.

All stores report exact compressed/raw byte ratios, feeding the pipeline
section of EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import codec as codec_lib
from repro.core.dgap import dgap_decode_np, dgap_encode_np


@dataclasses.dataclass
class TokenStore:
    codec: str
    block: int
    blocks: list
    n: int

    @staticmethod
    def build(tokens: np.ndarray, codec: str = "bp128", block: int = 65536) -> "TokenStore":
        spec = codec_lib.get(codec)
        tokens = np.asarray(tokens, np.uint32)
        blocks = [spec.encode(tokens[i:i + block]) for i in range(0, len(tokens), block)]
        return TokenStore(codec, block, blocks, len(tokens))

    def read(self, start: int, count: int) -> np.ndarray:
        spec = codec_lib.get(self.codec)
        b0, b1 = start // self.block, (start + count - 1) // self.block
        parts = [spec.decode(self.blocks[b]) for b in range(b0, b1 + 1)]
        flat = np.concatenate(parts)
        off = start - b0 * self.block
        return flat[off:off + count]

    def compressed_bytes(self) -> int:
        return sum(e.nbytes() for e in self.blocks)

    @property
    def raw_bytes(self) -> int:
        return self.n * 4


@dataclasses.dataclass
class AdjacencyStore:
    codec: str
    rows: list                    # Encoded per row (or raw for tiny rows)
    indptr: np.ndarray
    n_nodes: int
    n_edges: int

    @staticmethod
    def build(indptr: np.ndarray, indices: np.ndarray, codec: str = "group_pfd",
              min_compress: int = 64) -> "AdjacencyStore":
        spec = codec_lib.get(codec)
        vb = codec_lib.get("varbyte")
        rows = []
        for r in range(len(indptr) - 1):
            cols = np.sort(indices[indptr[r]:indptr[r + 1]]).astype(np.uint32)
            gaps = dgap_encode_np(cols)
            rows.append((spec if len(cols) >= min_compress else vb).encode(gaps))
        return AdjacencyStore(codec, rows, np.asarray(indptr), len(indptr) - 1, len(indices))

    def neighbors(self, r: int) -> np.ndarray:
        enc = self.rows[r]
        gaps = codec_lib.get(enc.codec).decode(enc)
        return dgap_decode_np(gaps)

    def compressed_bytes(self) -> int:
        return sum(e.nbytes() for e in self.rows)

    @property
    def raw_bytes(self) -> int:
        return self.n_edges * 4


@dataclasses.dataclass
class BagStore:
    codec: str
    bags: list
    n_ids: int

    @staticmethod
    def build(bags: list, codec: str = "group_scheme_8-IU") -> "BagStore":
        spec = codec_lib.get(codec)
        enc = []
        n = 0
        for b in bags:
            ids = np.sort(np.asarray(b, np.uint32))
            n += len(ids)
            enc.append(spec.encode(dgap_encode_np(ids)))
        return BagStore(codec, enc, n)

    def read(self, i: int) -> np.ndarray:
        enc = self.bags[i]
        return dgap_decode_np(codec_lib.get(enc.codec).decode(enc))

    def compressed_bytes(self) -> int:
        return sum(e.nbytes() for e in self.bags)

    @property
    def raw_bytes(self) -> int:
        return self.n_ids * 4


def lm_batch_iter(store: TokenStore, batch: int, seq: int):
    """Deterministic loader over a compressed token stream; the cursor is the
    checkpointable data position (runtime/train_loop resume contract)."""
    per = batch * (seq + 1)

    def next_batch(cursor: int):
        start = (cursor * per) % max(store.n - per, 1)
        flat = store.read(start, per).astype(np.int64).reshape(batch, seq + 1)
        return {"tokens": flat[:, :-1].astype(np.int32),
                "labels": flat[:, 1:].astype(np.int32)}, cursor + 1

    return next_batch
