"""Synthetic datasets with paper-matched statistics (Table V, scaled 1/1000).

TREC GOV2 / ClueWeb09B / Wikipedia / Twitter are not redistributable; we
generate Zipf-distributed corpora whose *d-gap and TF statistics* match the
paper's reported characteristics: ">90% of d-gap and TF on all four datasets
can be represented in 8 bits" (§7.1). The validation targets are compression-
ratio ORDERINGS and speed RATIOS, not absolute dataset-specific numbers.

Each dataset yields posting lists (docids sorted ascending + term
frequencies) for the most frequent terms, mimicking the paper's protocol of
compressing the posting lists of TREC query terms.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

# name -> (n_docs, n_terms_sampled, avg_doclen_tokens, zipf_s)
DATASETS = {
    "gov2": (25_000, 2_000, 778, 1.15),
    "clueweb09b": (50_000, 2_000, 576, 1.12),
    "wikipedia": (10_000, 1_500, 344, 1.25),
    "twitter": (9_000, 1_500, 397, 1.30),
}


@dataclasses.dataclass
class PostingList:
    term: int
    docids: np.ndarray       # uint32 sorted ascending
    tfs: np.ndarray          # uint32 >= 1

    @property
    def dgaps(self) -> np.ndarray:
        out = self.docids.copy()
        out[1:] = self.docids[1:] - self.docids[:-1]
        return out


def make_dataset(name: str, seed: int = 0, n_lists: int = 200) -> list:
    """Posting lists for the n_lists most frequent sampled terms."""
    n_docs, n_terms, avg_len, s = DATASETS[name]
    # crc32, NOT hash(): str hashing is randomized per process, which made
    # every benchmark run draw a different corpus — the same (name, seed)
    # must yield the same dataset in every process for the committed
    # BENCH_query.json baseline to be reproducible
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (1 << 16))
    # document frequency per term rank (Zipf), clipped to corpus size
    ranks = np.arange(1, n_terms + 1, dtype=np.float64)
    df = np.minimum((n_docs * 0.6) / ranks ** (s - 0.05), n_docs).astype(np.int64)
    df = np.maximum(df, 8)
    lists = []
    for t in range(min(n_lists, n_terms)):
        ids = np.sort(rng.choice(n_docs, size=int(df[t]), replace=False)).astype(np.uint32)
        # TF: geometric-ish, >90% fit one byte
        tf = rng.geometric(0.35, size=len(ids)).astype(np.uint32)
        tf = np.minimum(tf, 4096)
        lists.append(PostingList(t, ids, tf))
    return lists


def dataset_stats(lists) -> dict:
    gaps = np.concatenate([pl.dgaps for pl in lists])
    tfs = np.concatenate([pl.tfs for pl in lists])
    return {
        "n_postings": int(sum(len(pl.docids) for pl in lists)),
        "gap_fit8": float(np.mean(gaps < 256)),
        "tf_fit8": float(np.mean(tfs < 256)),
        "gap_mean": float(gaps.mean()),
    }


def concat_gaps(lists) -> np.ndarray:
    return np.concatenate([pl.dgaps for pl in lists]).astype(np.uint32)


def concat_tfs(lists) -> np.ndarray:
    return np.concatenate([pl.tfs for pl in lists]).astype(np.uint32)


def make_corpus(name: str, seed: int = 0):
    """Token-level corpus for the query-processing benchmark: returns
    (doc_lengths, postings dict term -> (docids, tfs))."""
    lists = make_dataset(name, seed)
    n_docs = DATASETS[name][0]
    doclen = np.full(n_docs, DATASETS[name][2], np.int64)
    return doclen, {pl.term: (pl.docids, pl.tfs) for pl in lists}
