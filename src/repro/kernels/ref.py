"""Pure-jnp oracles for the Pallas kernels.

Layout (TPU-wide generalization of the paper's 4-way vertical layout,
DESIGN.md §2): a *frame* is 4096 integers arranged as a (32, 128) tile — 128
lanes, 32 slots per lane, linear stream order i = 32*128*f + 128*r + l.  A
frame packed at bit width bw occupies exactly (bw, 128) uint32 words: lane l
packs its 32 values LSB-first into bw words (32*bw bits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

FRAME_ROWS = 32
LANES = 128
FRAME_INTS = FRAME_ROWS * LANES


def _mask(bw: int) -> jnp.ndarray:
    return jnp.uint32(0xFFFFFFFF if bw >= 32 else (1 << bw) - 1)


def pack_frames_ref(x: jnp.ndarray, bw: int) -> jnp.ndarray:
    """(F*32, 128) uint32 -> (F*bw, 128) packed at bw bits/value."""
    f = x.shape[0] // FRAME_ROWS
    x = x.reshape(f, FRAME_ROWS, LANES)
    out = jnp.zeros((f, bw, LANES), jnp.uint32)
    m = _mask(bw)
    for r in range(FRAME_ROWS):
        v = x[:, r, :] & m
        start = r * bw
        w, off = start // 32, start % 32
        out = out.at[:, w, :].set(out[:, w, :] | (v << jnp.uint32(off)))
        if off + bw > 32:
            out = out.at[:, w + 1, :].set(out[:, w + 1, :] | (v >> jnp.uint32(32 - off)))
    return out.reshape(f * bw, LANES)


def unpack_frames_ref(packed: jnp.ndarray, bw: int) -> jnp.ndarray:
    """(F*bw, 128) -> (F*32, 128)."""
    f = packed.shape[0] // bw
    p = packed.reshape(f, bw, LANES)
    out = jnp.zeros((f, FRAME_ROWS, LANES), jnp.uint32)
    m = _mask(bw)
    for r in range(FRAME_ROWS):
        start = r * bw
        w, off = start // 32, start % 32
        v = p[:, w, :] >> jnp.uint32(off)
        if off + bw > 32:
            v = v | (p[:, w + 1, :] << jnp.uint32(32 - off))
        out = out.at[:, r, :].set(v & m)
    return out.reshape(f * FRAME_ROWS, LANES)


def frame_or_ref(x: jnp.ndarray) -> jnp.ndarray:
    """(F*32, 128) -> (F, 128) per-frame per-lane OR (pseudo-max, paper §4.4)."""
    f = x.shape[0] // FRAME_ROWS
    x = x.reshape(f, FRAME_ROWS, LANES)
    out = x[:, 0, :]
    for r in range(1, FRAME_ROWS):
        out = out | x[:, r, :]
    return out


def prefix_sum_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum over the linear stream order of (R, 128) blocks."""
    shape = x.shape
    return jnp.cumsum(x.reshape(-1).astype(jnp.uint32), dtype=jnp.uint32).reshape(shape)


def unpack_delta_ref(packed: jnp.ndarray, bw: int) -> jnp.ndarray:
    """Fused bit-unpack + d-gap prefix sum (decode gaps -> docids)."""
    return prefix_sum_ref(unpack_frames_ref(packed, bw))
