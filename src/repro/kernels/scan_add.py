"""Pallas kernel: blocked inclusive prefix sum (d-gap decode, paper §2.1.1).

Reconstructing docids from d-gaps is a prefix sum.  The TPU grid executes
sequentially on a core, so the running carry lives in SMEM scratch and flows
across grid steps — each step scans one (R, 128) VMEM block in linear
(row-major) stream order: lane-axis cumsum + exclusive row-total prefix +
carry.  uint32 wraparound is intentional (docids < 2**32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _scan_kernel(x_ref, o_ref, carry_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        carry_ref[0, 0] = jnp.uint32(0)

    x = x_ref[...]
    c = jnp.cumsum(x, axis=1, dtype=jnp.uint32)                 # within-row (lane) scan
    row_tot = c[:, -1]
    row_pref = (jnp.cumsum(row_tot, dtype=jnp.uint32) - row_tot)  # exclusive row prefix
    o_ref[...] = c + row_pref[:, None] + carry_ref[0, 0]
    carry_ref[0, 0] = carry_ref[0, 0] + jnp.sum(row_tot, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def prefix_sum_blocks(x: jnp.ndarray, rows_per_block: int = 256, interpret: bool = True) -> jnp.ndarray:
    """(R, 128) uint32 -> inclusive prefix sum in linear row-major order."""
    rows = x.shape[0]
    rpb = min(rows_per_block, rows)
    while rows % rpb:
        rpb -= 1
    return pl.pallas_call(
        _scan_kernel,
        grid=(rows // rpb,),
        in_specs=[pl.BlockSpec((rpb, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rpb, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.uint32)],
        interpret=interpret,
    )(x)
