"""Pallas TPU kernels for the paper's compute hot-spots.

kernels: bitpack/bitunpack (fixed-bw shift+mask, the §3.2 inner loop),
quadmax (OR pseudo-max, §4.4), scan_add (d-gap decode prefix sum),
unpack_delta (beyond-paper fused unpack+scan), intersect (vectorized
galloping + block-skip bitmap intersection for the query engine),
decode_fused (work-list block decode fused with the candidate bitmap-AND
for the device-resident serving path), intersect_rounds (segmented
candidate bitmaps + per-round probe/scatter for device-resident AND),
topk (segmented quantized score accumulate + threshold-and-compact
candidate selection + the score-column unpack tile for ranked top-k).
ops.py holds jit wrappers; ref.py the pure-jnp oracles.
"""

from . import (bitpack, decode_fused, intersect, intersect_rounds, ops,
               quadmax, ref, scan_add, topk, unpack_delta)

__all__ = ["bitpack", "decode_fused", "intersect", "intersect_rounds", "ops",
           "quadmax", "ref", "scan_add", "topk", "unpack_delta"]
