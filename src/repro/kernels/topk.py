"""Segmented device-resident top-k: quantized score accumulation + threshold
-and-compact candidate selection for the ranked (OR / and_scored) modes.

The state mirrors ``intersect_rounds``'s segmented candidate bitmaps, with a
score accumulator next to them:

  * **segmented score accumulator** — ONE (n_queries, n_docs_padded) uint32
    device array; query q owns row q and accumulates the quantized impact
    codes (``repro.index.scores``) of its terms, one term occurrence per
    round, via an exact integer scatter-add.
  * **membership bitmap** — the same (n_queries, words) packed geometry as
    the AND candidate bitmaps: a bit per doc that contributed anything
    (needed because a code can floor to 0 while the float impact is > 0).
  * ``score_round`` / ``score_round_masked`` — one jitted call per round:
    every work-list lane scatters its decoded block's codes into its query's
    accumulator row.  For ``and_scored`` the lanes first probe the AND-result
    bitmap (``gate``) so only intersection docs accumulate; the fused path
    arrives with the probe already applied (``hits`` from the segmented
    Pallas decode) and uses the ``_masked`` form.
  * ``topk_threshold`` + ``candidate_bitmap`` — the bounded "heap" as
    iterative threshold-and-compact: the per-query k-th largest accumulated
    code sum is the threshold theta; the compact keeps every member doc with
    ``acc >= theta - margin`` (the quantization margin of
    ``repro.index.scores`` — a provable superset of the true float top-k)
    packed as a bitmap, which is the batch's single host sync.  The k-th
    statistic is found by a per-bit binary descend over rank counts instead
    of ``lax.top_k`` — a sort-free fixed 16-step reduce that is the single
    biggest ranked-path cost on the XLA lowering, and exact for every
    quantized sum below 2**16 (above, it saturates low, which only widens
    the candidate superset).
  * ``pooled_threshold`` — the cheap per-round form of the same statistic
    for **adaptive theta promotion**: the k-th largest *32-group pooled
    maximum*.  The top-k pooled values are maxima of k distinct groups,
    hence k distinct accumulator entries, so the pooled k-th is a sound
    lower bound on the true k-th — and the accumulator only grows across
    rounds, so ``theta = max(theta, pooled_threshold(acc, k))`` after every
    round is monotone and never exceeds the final k-th sum.  Rounds mask
    work-list entries whose precomputed upper bound cannot beat the promoted
    theta (``ub <= (theta * iq) >> 16``) entirely on device: the work-list
    compacts itself against promoted bounds with zero per-round host syncs.
  * ``unpack_codes`` — the Pallas tile for the score side of the fused
    placement: each grid step DMAs one block's packed (1, 128) score words
    (slot selected by a scalar-prefetched work-list array, double-buffered
    like the gap tiles) and shifts/masks them into (4, 128) code tiles —
    the bw=8 instantiation of the paper's static shift/mask unroll.

Correctness does not depend on work-list selection: scattering a superset of
blocks is exact (codes of docs outside the gate fail the probe), and pruned
blocks only drop docs provably outside the top-k (see the parity-contract
note in ``repro/index/scores.py``).

Tombstone gating (the streaming mutable index) needs no new kernel: under a
mutation epoch the engine passes the epoch's packed live bitmap
(``intersect_rounds.pack_live_words``, broadcast per query row) as ``gate``
with ``gated=True`` for OR rounds — deleted docs fail the probe and never
enter ``acc``/``member``, so ``topk_threshold``/``candidate_bitmap`` only
ever see live docs and the gate adds zero host syncs.  ``and_scored`` rounds
are already gated by the AND bitmap, which the engine live-gates at seed
time.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import accumulate
from .bitpack import LANES, auto_interpret
from .decode_fused import BLOCK_ROWS

THRESH_BITS = 16        # binary-descend range: exact for sums < 2**16


def accum_width(n_docs: int) -> int:
    """Accumulator row width: [0, n_docs) padded to the bitmap geometry of
    ``intersect_rounds`` (whole 32-bit words, whole 128-lane tiles) so the
    compacted candidate bitmap packs without a remainder."""
    from .intersect_rounds import bitmap_geometry
    return bitmap_geometry(n_docs)[0] * 32


def _scale_q16(theta, iq):
    """floor(theta * iq / 2**16) per query, exact in 32-bit arithmetic.

    ``iq`` is a Q16.16 scale in [1, 2**16] (65536 = identity; smaller values
    deflate theta to stay a sound bound when tombstones raise live idf — see
    ``repro/index/scores.py``).  Split theta into hi/lo 16-bit halves so no
    intermediate exceeds uint32: hi * iq is already an integer multiple of
    the floor, and (lo * iq) >> 16 supplies the exact remainder floor.
    """
    t = theta.astype(jnp.uint32)
    s = iq.astype(jnp.uint32)
    return ((t >> 16) * s + (((t & jnp.uint32(0xFFFF)) * s) >> 16)).astype(
        jnp.int32)


def _scatter(acc, member, ids, qslot, codes, surv):
    """Exact scatter: per round a (query, term occurrence) contributes every
    docid at most once, so the integer add is a plain sum and the bit add is
    an exact OR."""
    contrib = jnp.where(surv, codes, jnp.uint32(0))
    acc = accumulate.scatter_add(acc, ids, qslot, contrib)
    mem = accumulate.scatter_bits(member, ids, qslot, surv)
    return acc, member | mem


@functools.partial(jax.jit, static_argnames=("gated",))
def score_round(acc, member, ids, qslot, codes, ns, gate, ub, theta, iq, *,
                gated: bool):
    """One ranked round over the whole batch.

    acc:    (Q, width) uint32 — segmented score accumulator (old state).
    member: (Q, words) uint32 — packed membership bitmap (old state).
    ids:    (P, out_width) uint32 — decoded docid rows per work-list entry.
    qslot:  (P,) int32 — owning query row per entry.
    codes:  (P, out_width) uint32 — quantized impact codes aligned with ids.
    ns:     (P,) int32 — valid posting count per entry (0 for jit padding).
    gate:   (Q, words) uint32 — AND-result bitmap; probed when ``gated``
            (the ``and_scored`` path) so only intersection docs accumulate.
    ub:     (P,) int32 — quantized upper bound of the entry's block against
            its query (block max + margin + other terms' range maxes); the
            entry is skipped when it cannot beat the promoted theta.
            Entries that must always run carry a huge ub.
    theta:  (Q,) uint32 — promoted per-query threshold (0 before promotion).
    iq:     (Q,) uint32 — Q16.16 idf-ratio deflation (65536 = identity).

    Returns (acc, member), both still on device.  Dropping an entry with
    ``ub <= scaled theta`` is sound: every doc in it ends below
    theta_final - margin, outside the candidate superset.
    """
    ns = jnp.where(ub > _scale_q16(theta, iq)[qslot], ns, 0)
    lane = jnp.arange(ids.shape[1], dtype=jnp.int32)
    surv = lane[None, :] < ns[:, None]
    if gated:
        word = (ids >> 5).astype(jnp.int32)
        hit = (gate[qslot[:, None], word] >> (ids & 31)) & jnp.uint32(1)
        surv = surv & (hit == 1)
    return _scatter(acc, member, ids, qslot, codes, surv)


@jax.jit
def score_round_masked(acc, member, ids, qslot, codes, hits, ub, theta, iq):
    """Like :func:`score_round` with the probe already applied — ``hits`` is
    the per-lane survivor mask the fused Pallas decode produced."""
    keep = ub > _scale_q16(theta, iq)[qslot]
    return _scatter(acc, member, ids, qslot, codes,
                    (hits != 0) & keep[:, None])


def _kth_descend(vals, k: int):
    """Largest t with |{v : v >= t}| >= k, by THRESH_BITS halving steps.

    That t *is* the k-th largest value when it fits the bit range; when
    fewer than k values are >= 1 the descend stays at 0 (keep-everything),
    which is the right degenerate answer for k > candidate count."""
    a = vals.astype(jnp.int32)
    lo = jnp.zeros(vals.shape[0], jnp.int32)
    for b in range(THRESH_BITS - 1, -1, -1):
        mid = lo + (1 << b)
        cnt = jnp.sum(a >= mid[:, None], axis=1, dtype=jnp.int32)
        lo = jnp.where(cnt >= k, mid, lo)
    return lo.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k",))
def _topk_threshold_jit(acc, k: int):
    return _kth_descend(acc, k)


def topk_threshold(acc, k: int):
    """Per-query threshold theta: the k-th largest accumulated code sum."""
    from repro.obs.trace import get_tracer
    with get_tracer().span("kernel/topk", lane="device", k=k,
                           nq=int(acc.shape[0])):
        return _topk_threshold_jit(acc, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _topk_stats_jit(acc, k: int):
    """Per-query (theta, count) merge statistics for doc-range sharded top-k.

    theta is the shard-local k-th largest accumulated sum — with the RAW k,
    not ``min(k, width)``: a shard holding fewer than k scored docs must
    report 0 (``_kth_descend`` stays at 0 when fewer than k entries are
    >= 1), because its local "k-th" over fewer candidates would not be a
    sound lower bound on the global k-th.  count is the shard's candidate
    population at its own threshold (reporting / collective accounting).

    Soundness of the merge (the shard-local margin argument): shard s has at
    least k docs with sum >= theta_s, so globally at least k docs reach
    theta_s and the global k-th sum is >= max_s theta_s.  Compacting every
    shard at ``max_s theta_s`` therefore keeps a superset of the unsharded
    candidate set — the one all-gather of these (theta, count) pairs is the
    only cross-shard traffic in a ranked batch.
    """
    theta = _kth_descend(acc, k)
    count = jnp.sum(acc >= jnp.maximum(theta, 1)[:, None], axis=1,
                    dtype=jnp.int32)
    return theta, count


def topk_stats(acc, k: int):
    """Traced wrapper over :func:`_topk_stats_jit` (same contract)."""
    from repro.obs.trace import get_tracer
    with get_tracer().span("kernel/topk", lane="device", k=k,
                           nq=int(acc.shape[0]), stats=True):
        return _topk_stats_jit(acc, k)


@functools.partial(jax.jit, static_argnames=("k",))
def pooled_threshold(acc, k: int):
    """Sound per-round lower bound on the k-th largest sum, over the 32-group
    max pool (32x fewer rank-count columns than :func:`topk_threshold`)."""
    q, width = acc.shape
    pooled = acc.reshape(q, width // 32, 32).max(axis=-1)
    return _kth_descend(pooled, k)


@jax.jit
def candidate_bitmap(acc, member, theta, margin, iq):
    """Compact the accumulator against (theta * iq / 2**16 - margin) into a
    packed candidate bitmap — every member doc whose quantized sum could
    still reach the true top-k (the provable superset of
    ``repro/index/scores.py``; ``iq`` deflates theta under tombstone epochs,
    65536 = identity)."""
    # int32 is exact here: sums of u8 codes stay far below 2**31
    thr = _scale_q16(theta, iq) - margin.astype(jnp.int32)
    keep = acc.astype(jnp.int32) >= thr[:, None]
    q, width = acc.shape
    bits = keep.reshape(q, width // 32, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(
        axis=-1, dtype=jnp.uint32)
    return words & member


# --------------------------------------------------------------------------- #
# dense-bitmap score round (density-adaptive posting blocks)
# --------------------------------------------------------------------------- #


@functools.partial(jax.jit, static_argnames=("gated",))
def dense_score_round(acc, member, tiles, words, qslot, w0, ub, theta, iq,
                      gate, *, gated: bool):
    """One ranked round over the batch's dense-bitmap work-list entries.

    tiles: (P, 1024) uint32 — packed code windows, four u8 codes per word in
           window-position order (position p lives in word p >> 2, byte
           p & 3); positions with no posting carry code 0.
    words: (P, 128) uint32 — the entry's posting bitmap window
           (``dense_bitmap`` words, realigned to the arena's 4-word phase).
    w0:    (P,) int32 — first word of the entry's window in the bitmap
           geometry; 4-word aligned, so column w0 * 32 is lane-tile aligned.

    No unpack/prefix-sum: codes add as one contiguous 4096-column window
    (:func:`repro.kernels.accumulate.dense_add`) and membership/gating stay
    word-parallel on the packed windows.  Composes exactly with the sparse
    :func:`score_round` of the same round — integer adds sum and the bit
    adds OR, whichever call order.
    """
    act = ub > _scale_q16(theta, iq)[qslot]
    p = tiles.shape[0]
    codes = ((tiles[:, :, None] >> (jnp.uint32(8) *
                                    jnp.arange(4, dtype=jnp.uint32)))
             & jnp.uint32(0xFF)).reshape(p, -1)
    win = words
    if gated:
        win = win & accumulate.dense_window_gather(gate, qslot, w0)
        bits = ((win[:, :, None] >> jnp.arange(32, dtype=jnp.uint32))
                & jnp.uint32(1)).reshape(p, -1)
        codes = codes * bits
    acc = accumulate.dense_add(acc, codes, qslot,
                               (w0 * 32).astype(jnp.int32), act)
    mem = accumulate.dense_window_add(jnp.zeros_like(member), win, qslot,
                                      w0, act)
    return acc, member | mem


# --------------------------------------------------------------------------- #
# Pallas score-unpack tile (the fused placement's score side)
# --------------------------------------------------------------------------- #


def _unpack_kernel(slot_ref, tile_ref, out_ref):
    del slot_ref
    for r in range(BLOCK_ROWS):
        out_ref[r, :] = (tile_ref[0, :] >> jnp.uint32(8 * r)) & jnp.uint32(0xFF)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_codes(tiles, slots, interpret=None) -> jnp.ndarray:
    """Unpack a work-list of packed score tiles in one call.

    tiles: (S, 128) uint32 — the score arena (four codes per word).
    slots: (W,) int32 — arena row per work-list entry; drives the
           scalar-prefetched DMA index map exactly like the gap tiles.

    Returns (W * 4, 128) uint32 codes; entry j owns rows [4j, 4j + 4) in the
    linear order of the docid rows it accompanies.
    """
    w = slots.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[pl.BlockSpec((1, LANES), lambda i, s: (s[i], 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w * BLOCK_ROWS, LANES), jnp.uint32),
        interpret=auto_interpret(interpret),
    )(slots, tiles)
