"""Segmented device-resident top-k: quantized score accumulation + threshold
-and-compact candidate selection for the ranked (OR / and_scored) modes.

The state mirrors ``intersect_rounds``'s segmented candidate bitmaps, with a
score accumulator next to them:

  * **segmented score accumulator** — ONE (n_queries, n_docs_padded) uint32
    device array; query q owns row q and accumulates the quantized impact
    codes (``repro.index.scores``) of its terms, one term occurrence per
    round, via an exact integer scatter-add.
  * **membership bitmap** — the same (n_queries, words) packed geometry as
    the AND candidate bitmaps: a bit per doc that contributed anything
    (needed because a code can floor to 0 while the float impact is > 0).
  * ``score_round`` / ``score_round_masked`` — one jitted call per round:
    every work-list lane scatters its decoded block's codes into its query's
    accumulator row.  For ``and_scored`` the lanes first probe the AND-result
    bitmap (``gate``) so only intersection docs accumulate; the fused path
    arrives with the probe already applied (``hits`` from the segmented
    Pallas decode) and uses the ``_masked`` form.
  * ``topk_threshold`` + ``candidate_bitmap`` — the bounded "heap" as
    iterative threshold-and-compact: the per-query k-th largest accumulated
    code sum is the threshold theta; the compact keeps every member doc with
    ``acc >= theta - margin`` (the quantization margin of
    ``repro.index.scores`` — a provable superset of the true float top-k)
    packed as a bitmap, which is the batch's single host sync.
  * ``unpack_codes`` — the Pallas tile for the score side of the fused
    placement: each grid step DMAs one block's packed (1, 128) score words
    (slot selected by a scalar-prefetched work-list array, double-buffered
    like the gap tiles) and shifts/masks them into (4, 128) code tiles —
    the bw=8 instantiation of the paper's static shift/mask unroll.

Correctness does not depend on work-list selection: scattering a superset of
blocks is exact (codes of docs outside the gate fail the probe), and pruned
blocks only drop docs provably outside the top-k (see the parity-contract
note in ``repro/index/scores.py``).

Tombstone gating (the streaming mutable index) needs no new kernel: under a
mutation epoch the engine passes the epoch's packed live bitmap
(``intersect_rounds.pack_live_words``, broadcast per query row) as ``gate``
with ``gated=True`` for OR rounds — deleted docs fail the probe and never
enter ``acc``/``member``, so ``topk_threshold``/``candidate_bitmap`` only
ever see live docs and the gate adds zero host syncs.  ``and_scored`` rounds
are already gated by the AND bitmap, which the engine live-gates at seed
time.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitpack import LANES, auto_interpret
from .decode_fused import BLOCK_ROWS


def accum_width(n_docs: int) -> int:
    """Accumulator row width: [0, n_docs) padded to the bitmap geometry of
    ``intersect_rounds`` (whole 32-bit words, whole 128-lane tiles) so the
    compacted candidate bitmap packs without a remainder."""
    from .intersect_rounds import bitmap_geometry
    return bitmap_geometry(n_docs)[0] * 32


def _scatter(acc, member, ids, qslot, codes, surv):
    """Exact scatter: per round a (query, term occurrence) contributes every
    docid at most once, so the integer add is a plain sum and the bit add is
    an exact OR."""
    contrib = jnp.where(surv, codes, jnp.uint32(0))
    acc = acc.at[qslot[:, None], ids].add(contrib)
    word = (ids >> 5).astype(jnp.int32)
    bits = jnp.where(surv, jnp.uint32(1) << (ids & 31), jnp.uint32(0))
    mem = jnp.zeros_like(member).at[qslot[:, None], word].add(bits)
    return acc, member | mem


@functools.partial(jax.jit, static_argnames=("gated",))
def score_round(acc, member, ids, qslot, codes, ns, gate, *, gated: bool):
    """One ranked round over the whole batch.

    acc:    (Q, width) uint32 — segmented score accumulator (old state).
    member: (Q, words) uint32 — packed membership bitmap (old state).
    ids:    (P, out_width) uint32 — decoded docid rows per work-list entry.
    qslot:  (P,) int32 — owning query row per entry.
    codes:  (P, out_width) uint32 — quantized impact codes aligned with ids.
    ns:     (P,) int32 — valid posting count per entry (0 for jit padding).
    gate:   (Q, words) uint32 — AND-result bitmap; probed when ``gated``
            (the ``and_scored`` path) so only intersection docs accumulate.

    Returns (acc, member), both still on device.
    """
    lane = jnp.arange(ids.shape[1], dtype=jnp.int32)
    surv = lane[None, :] < ns[:, None]
    if gated:
        word = (ids >> 5).astype(jnp.int32)
        hit = (gate[qslot[:, None], word] >> (ids & 31)) & jnp.uint32(1)
        surv = surv & (hit == 1)
    return _scatter(acc, member, ids, qslot, codes, surv)


@jax.jit
def score_round_masked(acc, member, ids, qslot, codes, hits):
    """Like :func:`score_round` with the probe already applied — ``hits`` is
    the per-lane survivor mask the fused Pallas decode produced."""
    return _scatter(acc, member, ids, qslot, codes, hits != 0)


@functools.partial(jax.jit, static_argnames=("k",))
def topk_threshold(acc, k: int):
    """Per-query threshold theta: the k-th largest accumulated code sum."""
    return jax.lax.top_k(acc, k)[0][:, -1]


@jax.jit
def candidate_bitmap(acc, member, theta, margin):
    """Compact the accumulator against (theta - margin) into a packed
    candidate bitmap — every member doc whose quantized sum could still reach
    the true top-k (the provable superset of ``repro/index/scores.py``)."""
    # int32 is exact here: sums of u8 codes stay far below 2**31
    thr = theta.astype(jnp.int32) - margin.astype(jnp.int32)
    keep = acc.astype(jnp.int32) >= thr[:, None]
    q, width = acc.shape
    bits = keep.reshape(q, width // 32, 32).astype(jnp.uint32)
    words = (bits << jnp.arange(32, dtype=jnp.uint32)).sum(
        axis=-1, dtype=jnp.uint32)
    return words & member


# --------------------------------------------------------------------------- #
# Pallas score-unpack tile (the fused placement's score side)
# --------------------------------------------------------------------------- #


def _unpack_kernel(slot_ref, tile_ref, out_ref):
    del slot_ref
    for r in range(BLOCK_ROWS):
        out_ref[r, :] = (tile_ref[0, :] >> jnp.uint32(8 * r)) & jnp.uint32(0xFF)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_codes(tiles, slots, interpret=None) -> jnp.ndarray:
    """Unpack a work-list of packed score tiles in one call.

    tiles: (S, 128) uint32 — the score arena (four codes per word).
    slots: (W,) int32 — arena row per work-list entry; drives the
           scalar-prefetched DMA index map exactly like the gap tiles.

    Returns (W * 4, 128) uint32 codes; entry j owns rows [4j, 4j + 4) in the
    linear order of the docid rows it accompanies.
    """
    w = slots.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(w,),
        in_specs=[pl.BlockSpec((1, LANES), lambda i, s: (s[i], 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        _unpack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w * BLOCK_ROWS, LANES), jnp.uint32),
        interpret=auto_interpret(interpret),
    )(slots, tiles)
