"""jit'd stream-level wrappers around the Pallas kernels.

`interpret` defaults to auto: Pallas interpret mode on CPU (this container),
compiled Mosaic on TPU.  Streams are flat uint32 arrays; wrappers handle the
pad-to-frame plumbing and expose the encoder/decoder entry points used by the
compressed data pipeline and the gradient compressor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import bitpack, quadmax, scan_add, unpack_delta
from .bitpack import FRAME_INTS, FRAME_ROWS, LANES, auto_interpret as _auto_interpret


def pad_to_frames(x: jnp.ndarray) -> jnp.ndarray:
    """Flat (n,) -> (F*32, 128) row-major tiles (linear order preserved)."""
    n = x.shape[0]
    f = max(1, -(-n // FRAME_INTS))
    pad = f * FRAME_INTS - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros(pad, x.dtype)])
    return x.reshape(f * FRAME_ROWS, LANES)


def pack_stream(x: jnp.ndarray, bw: int, interpret=None) -> jnp.ndarray:
    """Pack a flat uint32 stream at fixed bit width bw -> (F*bw, 128) words."""
    return bitpack.pack_frames(pad_to_frames(x.astype(jnp.uint32)), bw,
                               interpret=_auto_interpret(interpret))


def unpack_stream(packed: jnp.ndarray, bw: int, n: int, interpret=None) -> jnp.ndarray:
    out = bitpack.unpack_frames(packed, bw, interpret=_auto_interpret(interpret))
    return out.reshape(-1)[:n]


def select_bw(x: jnp.ndarray, interpret=None) -> jnp.ndarray:
    """Per-frame bit width from the OR pseudo-max (paper §4.4 on TPU tiles)."""
    t = quadmax.frame_or(pad_to_frames(x.astype(jnp.uint32)),
                         interpret=_auto_interpret(interpret))   # (F, 128)
    # cross-lane OR epilogue (cheap: F x 128) via log-step folding
    w = LANES
    while w > 1:
        t = t[:, : w // 2] | t[:, w // 2: w]
        w //= 2
    acc = t[:, 0]
    return jnp.maximum(32 - jax.lax.clz(acc), 1).astype(jnp.int32)


def prefix_sum(x: jnp.ndarray, interpret=None) -> jnp.ndarray:
    """Inclusive prefix sum of a flat uint32 stream (d-gap decode)."""
    n = x.shape[0]
    tiles = pad_to_frames(x.astype(jnp.uint32))
    out = scan_add.prefix_sum_blocks(tiles, interpret=_auto_interpret(interpret))
    return out.reshape(-1)[:n]


def unpack_delta_stream(packed: jnp.ndarray, bw: int, n: int, interpret=None) -> jnp.ndarray:
    """Fused unpack + prefix sum: packed gaps -> docids."""
    out = unpack_delta.unpack_delta_frames(packed, bw, interpret=_auto_interpret(interpret))
    return out.reshape(-1)[:n]
