"""Device-resident AND rounds: segmented candidate bitmaps + per-round
intersection that never copies candidates back to the host.

The PR-2 device AND loop kept the *decode* on device but synced every query's
candidate set to the host between rounds: round r downloaded the surviving
docids, ran ``searchsorted`` pruning + per-block intersection in numpy, and
re-uploaded the shrunken set for round r+1.  Lemire & Boytsov's intersection
work (PAPERS.md) makes the case for keeping the whole multi-round pipeline
vectorized; this module is that pipeline's state + kernels:

  * **segmented candidate bitmap** — the whole batch's candidate sets as ONE
    device array of shape (n_queries, words): query q owns row q, a packed
    LSB-first bitmap over [0, n_docs) (``intersect.bitmap_build_np`` order,
    padded to whole (rows, 128) tiles so the Pallas path can treat row q as a
    (rows, 128) tile block).
  * ``bitmap_round`` — one jitted call per AND round: every work-list lane
    probes its query's segment of the *old* bitmap (decode results feed in
    directly as padded (out_width,) docid rows), and survivors are scattered
    into the *new* bitmap.  Distinct docids per (query, term) guarantee the
    scatter-add is an exact bitwise OR.  Inactive queries carry their segment
    forward untouched.  When one round mixes representations (sparse arena
    decode, fused Pallas decode, dense bitmap windows), the round splits into
    ``round_accumulate*`` calls that all probe the *old* bitmap and OR
    survivors into one shared *new* bitmap — sound because a block is served
    by exactly one representation, so the calls' docid sets are disjoint —
    followed by a single ``round_commit``.
  * ``dense_round_accumulate`` — the density-adaptive representation's round
    (``repro.core.dense_bitmap``): a dense block arrives as its raw 128-word
    window, is ANDed word-parallel against the query's old-bitmap window and
    committed back — no unpack, no prefix-sum, no per-posting lanes at all.
  * ``segmented_decode_and`` — the Pallas form for the fused placement: the
    ``kernels/decode_fused`` unpack + prefix-sum + bitmap-probe kernel,
    generalized so every work-list entry selects *its own query's* candidate
    tile block via a scalar-prefetched query-slot array (the candidate DMA is
    double-buffered exactly like the gap-tile DMA).
  * ``extract_ids`` — the single final host copy: bitmap rows back to sorted
    uint32 docid arrays, once per batch, after the last round.

Correctness does not depend on block selection: decoding a superset of the
blocks that could hold candidates is sound, because ids outside the current
candidate set fail the probe and scatter nothing.

Tombstone gating (the streaming mutable index, ``repro.index.segments``) rides
the same geometry: :func:`pack_live_words` packs the live-doc mask of a
mutation epoch into one ``(words,)`` row, and the engine ANDs it into the seed
bitmap (and the ranked membership gate) right after round 0 — deleted docs
fail every subsequent probe exactly like non-candidates, so the gate costs one
host->device upload per epoch and zero downloads.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import accumulate
from .bitpack import LANES, _mask, auto_interpret
from .decode_fused import BLOCK_ROWS, rows_per_block


def bitmap_geometry(n_docs: int) -> tuple[int, int]:
    """(words, rows) of one query's candidate bitmap segment: enough uint32
    words to cover [0, n_docs), padded to whole (rows, 128) lane tiles."""
    cw = max(1, -(-n_docs // 32))
    rows = -(-cw // LANES)
    return rows * LANES, rows


def pack_live_words(dead: np.ndarray, n_docs: int, words: int) -> np.ndarray:
    """Pack one mutation epoch's live-doc mask into a ``(words,)`` uint32
    bitmap row in this module's segmented-bitmap order (LSB-first: bit d of
    word d // 32 is 1 iff doc d is live).

    ``dead`` is the sorted tombstoned docid array (all < ``n_docs``); bits in
    [n_docs, words * 32) are 0, so ANDing this row into a candidate bitmap
    never admits out-of-range docs.  The result is host-side — the caller
    uploads it once per epoch and reuses the device copy across rounds."""
    bits = np.zeros(words * 32, np.uint8)
    bits[:n_docs] = 1
    if len(dead):
        bits[dead] = 0
    return np.packbits(bits, bitorder="little").view(np.uint32)


def pack_live_words_range(dead: np.ndarray, lo: int, hi: int,
                          words: int) -> np.ndarray:
    """Per-shard form of :func:`pack_live_words`: the live row of the doc
    range [lo, hi) in the range's *local* docid space (bit d is doc lo + d).

    Doc-range sharded serving slices one mutation epoch's live mask at the
    shard boundaries, so each shard uploads only its own ``words`` (sized by
    ``bitmap_geometry(hi - lo)``) instead of the full doc-space bitmap.
    ``dead`` is the epoch's sorted global tombstone array; entries outside
    [lo, hi) are dropped before packing."""
    dead = np.asarray(dead, np.int64)
    sub = dead[(dead >= lo) & (dead < hi)] - lo
    return pack_live_words(sub, hi - lo, words)


# --------------------------------------------------------------------------- #
# probe + scatter round (jnp; the generic-arena placement)
# --------------------------------------------------------------------------- #


def _scatter_survivors(bm, ids, qslot, surv):
    """OR survivor docids into a fresh bitmap: scatter-add is exact because
    every (query, term) contributes each docid at most once per round."""
    return accumulate.scatter_bits(bm, ids, qslot, surv)


@functools.partial(jax.jit, static_argnames=("probe",))
def round_accumulate(new, ids, qslot, ns, bm_old, *, probe: bool = True):
    """Probe ``bm_old``, OR survivors into the shared ``new`` bitmap.

    One AND round may split across several accumulate calls (sparse arena
    decode, fused Pallas decode, dense windows) — every call probes the same
    *old* state and adds into the same *new* state, and the calls' docid
    sets are disjoint, so the adds compose into an exact OR regardless of
    call order.  ``round_commit`` folds the result back per query.
    """
    lane = jnp.arange(ids.shape[1], dtype=jnp.int32)
    surv = lane[None, :] < ns[:, None]
    if probe:
        word = (ids >> 5).astype(jnp.int32)
        bit = (ids & 31).astype(jnp.uint32)
        hit = (bm_old[qslot[:, None], word] >> bit) & jnp.uint32(1)
        surv = surv & (hit == 1)
    return new | _scatter_survivors(new, ids, qslot, surv)


@jax.jit
def round_accumulate_masked(new, ids, qslot, hits):
    """:func:`round_accumulate` with the probe already applied — ``hits`` is
    the per-lane survivor mask a fused kernel produced."""
    return new | _scatter_survivors(new, ids, qslot, hits != 0)


@functools.partial(jax.jit, static_argnames=("probe",))
def dense_round_accumulate(new, words, qslot, w0, act, bm_old, *,
                           probe: bool = True):
    """Dense-bitmap blocks' AND round: pure word-parallel bitmap algebra.

    words: (P, 128) uint32 — each entry's posting window
           (``repro.core.dense_bitmap`` words at the arena's 4-word phase).
    w0:    (P,) int32 — the window's first word in the bitmap geometry.
    act:   (P,) bool — live entries (False for jit padding).

    The probe is 128 word ANDs against the query's old-bitmap window — no
    unpack, no prefix-sum, no per-posting lanes.
    """
    surv = words
    if probe:
        surv = surv & accumulate.dense_window_gather(bm_old, qslot, w0)
    return accumulate.dense_window_add(new, surv, qslot, w0, act)


@jax.jit
def round_commit(bm_old, new, active):
    """Fold a round's accumulated ``new`` bitmap back into the batch state:
    active queries take their new segment, inactive rows keep the old one."""
    return jnp.where(active[:, None], new, bm_old)


@functools.partial(jax.jit, static_argnames=("probe",))
def bitmap_round(bm, ids, qslot, ns, active, *, probe: bool = True):
    """One single-call device-resident AND round over the whole batch.

    bm:     (Q, words) uint32 — segmented candidate bitmap (old state).
    ids:    (P, out_width) uint32 — decoded docid rows, one per work-list
            (query, block) pair, zero-padded past ``ns``.
    qslot:  (P,) int32 — owning query row per pair.
    ns:     (P,) int32 — valid posting count per pair (0 for jit padding).
    active: (Q,) bool — queries intersecting this round; inactive rows keep
            their old segment.
    probe:  False builds the seed bitmap (round 0: no old candidates yet).

    Returns the new (Q, words) bitmap, still on device.  (The accumulate /
    commit split above is the multi-call generalization of this.)
    """
    new = round_accumulate(jnp.zeros_like(bm), ids, qslot, ns, bm,
                           probe=probe)
    return round_commit(bm, new, active)


@jax.jit
def bitmap_round_masked(bm, ids, qslot, hits, active):
    """Like :func:`bitmap_round` but with the probe already applied — ``hits``
    is the per-lane survivor mask a fused kernel produced."""
    new = round_accumulate_masked(jnp.zeros_like(bm), ids, qslot, hits)
    return round_commit(bm, new, active)


# --------------------------------------------------------------------------- #
# segmented fused decode + probe (Pallas; the fused placement)
# --------------------------------------------------------------------------- #


def _seg_kernel(slot_ref, qs_ref, first_ref, n_ref, tile_ref, cand_ref,
                ids_ref, hit_ref, *, bw: int, cand_words: int):
    """decode_fused's unpack + d-gap prefix sum + bitmap probe, against the
    candidate tile block of *this entry's query* (both the gap tile and the
    candidate block are selected by scalar-prefetched work-list arrays, so
    the next entry's DMAs pipeline while the current one computes)."""
    i = pl.program_id(0)
    m = _mask(bw)
    base = first_ref[i]
    nn = n_ref[i]
    cand = cand_ref[...].reshape(-1)
    lane = jnp.arange(LANES, dtype=jnp.int32)
    for r in range(BLOCK_ROWS):
        start = r * bw
        w, off = start // 32, start % 32
        v = tile_ref[w, :] >> jnp.uint32(off)
        if off + bw > 32:
            v = v | (tile_ref[w + 1, :] << jnp.uint32(32 - off))
        v = v & m
        c = jnp.cumsum(v, dtype=jnp.uint32)
        d = c + base
        base = base + c[-1]
        word = cand[jnp.minimum(d >> 5, jnp.uint32(cand_words - 1)).astype(jnp.int32)]
        hit = (word >> (d & 31)) & jnp.uint32(1)
        valid = (lane + r * LANES) < nn
        ids_ref[r, :] = d
        hit_ref[r, :] = jnp.where(valid, hit, jnp.uint32(0))


@functools.partial(jax.jit, static_argnames=("bw", "crows", "interpret"))
def segmented_decode_and(tiles, slots, qslots, firsts, ns, cand_tiles,
                         bw: int, crows: int,
                         interpret=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode + probe a round's work-list against per-query bitmap segments.

    tiles:      (S * rows_per_block(bw), 128) uint32 packed gap arena.
    slots:      (W,) int32 arena tile index per entry.
    qslots:     (W,) int32 owning query row per entry — selects the entry's
                candidate tile block.
    firsts:     (W,) uint32 first docid per entry (skip-table value).
    ns:         (W,) int32 posting count per entry (0 entries hit nothing).
    cand_tiles: (Q * crows, 128) uint32 — the segmented bitmap, query q
                owning rows [q * crows, (q + 1) * crows).

    Returns (docids, hits), each (W * 4, 128) uint32; entry j owns rows
    [4j, 4j + 4) in linear order.
    """
    w = slots.shape[0]
    rpb = rows_per_block(bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(w,),
        in_specs=[pl.BlockSpec((rpb, LANES), lambda i, s, q, f, n: (s[i], 0)),
                  pl.BlockSpec((crows, LANES), lambda i, s, q, f, n: (q[i], 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i, s, q, f, n: (i, 0)),
                   pl.BlockSpec((BLOCK_ROWS, LANES), lambda i, s, q, f, n: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_seg_kernel, bw=bw, cand_words=crows * LANES),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((w * BLOCK_ROWS, LANES), jnp.uint32),
                   jax.ShapeDtypeStruct((w * BLOCK_ROWS, LANES), jnp.uint32)],
        interpret=auto_interpret(interpret),
    )(slots, qslots, firsts, ns, tiles, cand_tiles)


# --------------------------------------------------------------------------- #
# final extraction (the one host copy per batch)
# --------------------------------------------------------------------------- #


def extract_ids(bm_np: np.ndarray, n_docs: int) -> list:
    """Bitmap rows -> sorted uint32 docid arrays (fresh, caller-owned)."""
    from repro.obs.trace import get_tracer
    with get_tracer().span("kernel/extract_ids", lane="device",
                           rows=int(bm_np.shape[0]), n_docs=n_docs):
        bits = np.unpackbits(np.ascontiguousarray(bm_np).view(np.uint8),
                             axis=1, bitorder="little")[:, :n_docs]
        return [np.flatnonzero(b).astype(np.uint32) for b in bits]
