"""Vectorized sorted-set intersection kernels for the query engine.

Two complementary strategies (Lemire/Boytsov/Kurz, "SIMD Compression and the
Intersection of Sorted Integers"):

  * galloping — when one list is much shorter, binary-probe each of its
    elements into the longer list.  ``np.searchsorted`` runs the whole probe
    front in one vectorized call, which is the data-parallel analogue of the
    paper's per-element gallop.
  * block-skip bitmap — when both lists are dense over a shared docid range,
    materialize each as a packed uint32 bitmap and AND word-by-word.  On the
    host serving path the AND is a numpy ``&``; ``bitmap_and_tiles`` is the
    TPU-resident analogue (same tile/grid idiom as ``bitpack.pack_frames``:
    (rows, 128) uint32 VMEM tiles, one grid step per row-block, pure VPU
    bitwise work), reachable via ``bitmap_intersect_np(..., use_pallas=True)``
    and the target of the device-resident-postings roadmap item.

``intersect_sorted`` dispatches between the two on a density heuristic and is
what the fused decode-and-intersect path in ``repro.index.engine`` calls per
posting block.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitpack import LANES, auto_interpret

# bitmap intersection pays off when the shorter list covers at least this
# fraction of the candidate docid span (one uint32 word per 32 docids)
BITMAP_DENSITY = 1.0 / 16.0


# --------------------------------------------------------------------------- #
# galloping (vectorized binary probe)
# --------------------------------------------------------------------------- #


def gallop_contains_np(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Boolean mask over ``needles``: which appear in sorted ``haystack``."""
    if len(haystack) == 0 or len(needles) == 0:
        return np.zeros(len(needles), bool)
    pos = np.searchsorted(haystack, needles)
    hit = pos < len(haystack)
    safe = np.minimum(pos, len(haystack) - 1)
    return hit & (haystack[safe] == needles)


def gallop_intersect_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Intersection of two sorted unique uint32 arrays; probes the shorter."""
    if len(a) > len(b):
        a, b = b, a
    return a[gallop_contains_np(b, a)]


def gallop_contains_jnp(haystack: jnp.ndarray, needles: jnp.ndarray) -> jnp.ndarray:
    """JAX analogue of ``gallop_contains_np`` (static shapes, mask output)."""
    if haystack.shape[0] == 0 or needles.shape[0] == 0:
        return jnp.zeros(needles.shape[0], bool)
    pos = jnp.searchsorted(haystack, needles)
    safe = jnp.minimum(pos, haystack.shape[0] - 1)
    return (pos < haystack.shape[0]) & (haystack[safe] == needles)


# --------------------------------------------------------------------------- #
# packed bitmaps + Pallas AND kernel
# --------------------------------------------------------------------------- #


def bitmap_build_np(ids: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Pack sorted docids in [lo, hi) into a uint32 bitmap (LSB-first)."""
    span = hi - lo
    nw = (span + 31) // 32
    words = np.zeros(nw, np.uint32)
    rel = ids.astype(np.int64) - lo
    np.bitwise_or.at(words, rel >> 5, (np.uint32(1) << (rel & 31).astype(np.uint32)))
    return words


def bitmap_extract_np(words: np.ndarray, lo: int) -> np.ndarray:
    """Inverse of ``bitmap_build_np``: set bit positions + lo, ascending."""
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")
    return (np.flatnonzero(bits) + lo).astype(np.uint32)


def bitmap_intersect_np(a: np.ndarray, b: np.ndarray,
                        use_pallas: bool = False) -> np.ndarray:
    """Intersect two sorted unique arrays via packed-bitmap AND."""
    if len(a) == 0 or len(b) == 0:
        return np.zeros(0, np.uint32)
    lo = int(max(a[0], b[0]))
    hi = int(min(a[-1], b[-1])) + 1
    if lo >= hi:
        return np.zeros(0, np.uint32)
    a = a[np.searchsorted(a, lo):np.searchsorted(a, hi)]
    b = b[np.searchsorted(b, lo):np.searchsorted(b, hi)]
    if len(a) == 0 or len(b) == 0:
        return np.zeros(0, np.uint32)
    wa = bitmap_build_np(a, lo, hi)
    wb = bitmap_build_np(b, lo, hi)
    return bitmap_extract_np(bitmap_and_words(wa, wb, use_pallas=use_pallas), lo)


def _and_kernel(a_ref, b_ref, o_ref, *, rows: int):
    for r in range(rows):
        o_ref[r, :] = a_ref[r, :] & b_ref[r, :]


@functools.partial(jax.jit, static_argnames=("interpret", "rows_per_block"))
def bitmap_and_tiles(a: jnp.ndarray, b: jnp.ndarray, interpret=None,
                     rows_per_block: int = 8) -> jnp.ndarray:
    """(R, 128) uint32 bitmap tiles -> elementwise AND, tiled through VMEM.

    ``interpret=None`` resolves per backend (compiled Mosaic on TPU,
    interpreter elsewhere) so TPU runs get the real kernel by default.
    """
    interpret = auto_interpret(interpret)
    rows = a.shape[0]
    rpb = min(rows_per_block, rows)
    while rows % rpb:
        rpb -= 1
    return pl.pallas_call(
        functools.partial(_and_kernel, rows=rpb),
        grid=(rows // rpb,),
        in_specs=[pl.BlockSpec((rpb, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rpb, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rpb, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.uint32),
        interpret=interpret,
    )(a, b)


def bitmap_and_words(wa: np.ndarray, wb: np.ndarray, use_pallas: bool = False) -> np.ndarray:
    """AND two equal-length uint32 bitmap word streams.

    ``use_pallas`` routes through the tiled TPU kernel (padding to a whole
    (rows, 128) tile); the default is the host AND, which is what the CPU
    serving path wants.
    """
    if not use_pallas:
        return wa & wb
    n = len(wa)
    rows = max(1, -(-n // LANES))
    pad = rows * LANES - n
    ta = np.concatenate([wa, np.zeros(pad, np.uint32)]).reshape(rows, LANES)
    tb = np.concatenate([wb, np.zeros(pad, np.uint32)]).reshape(rows, LANES)
    out = np.asarray(bitmap_and_tiles(jnp.asarray(ta), jnp.asarray(tb)))
    return out.reshape(-1)[:n]


# --------------------------------------------------------------------------- #
# dispatcher
# --------------------------------------------------------------------------- #


def intersect_sorted(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Intersect sorted unique uint32 arrays, choosing gallop vs bitmap."""
    if len(a) == 0 or len(b) == 0:
        return np.zeros(0, np.uint32)
    if len(a) > len(b):
        a, b = b, a
    lo = int(max(a[0], b[0]))
    hi = int(min(a[-1], b[-1])) + 1
    span = max(hi - lo, 1)
    if lo < hi and len(a) >= span * BITMAP_DENSITY and len(a) >= 64:
        return bitmap_intersect_np(a, b)
    return a[gallop_contains_np(b, a)]
