"""Pallas kernel: FUSED block decode + candidate bitmap-AND (device serving path).

The host query engine intersects one decoded block at a time: decode gaps,
prefix-sum to docids, probe the candidate list — three passes with the decoded
block round-tripping through HBM (or host memory) in between.  This kernel is
the device-resident version of that whole inner loop for the arena's packed
block tiles (``repro.index.device.DeviceArena``): one grid step per work-list
entry

  1. DMAs the entry's packed gap tile into VMEM — the tile is selected by a
     *scalar-prefetched* work-list array, so Pallas's pipelined grid issues the
     DMA for the skip-selected *next* block while the current one computes
     (double-buffered prefetch: exactly the async-prefetch item on the
     ROADMAP),
  2. unpacks the fixed-width gaps (static shift/mask unroll, the §3.2/§4.4
     idiom of ``bitpack``),
  3. prefix-sums them and adds the block's first docid (skip-table entry) to
     reconstruct docids without writing gaps anywhere, and
  4. probes each docid against the query's packed candidate bitmap resident in
     VMEM — the bitmap-AND tile of ``kernels/intersect`` fused directly after
     decode.

Outputs are (4, 128) docid and hit-mask tiles per entry; the engine compresses
``docids[hits]`` per block on the way out.  Work-list entries index *blocks*,
so one call replaces the engine's whole per-term Python loop.

Layout: a block of up to 512 postings is one (rows_per_block, 128) uint32
tile.  Value ``i`` of the block lives at row ``i // 128``, lane ``i % 128``
(the linear order of ``ops.pad_to_frames``), packed LSB-first at the arena's
uniform bit width: lane ``l`` squeezes its 4 values into ``ceil(4*bw/32)``
words.  The candidate bitmap covers docids [0, n_docs) as (rows, 128) uint32
words, LSB-first (``intersect.bitmap_build_np`` order).

The per-lane bitmap probe is a VMEM gather; on CPU/interpret (this container)
it lowers to the reference semantics, on TPU it requires Mosaic dynamic-gather
support (v4+).  ``interpret=None`` resolves per backend like every other
kernel wrapper here.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitpack import LANES, _mask, auto_interpret

BLOCK_ROWS = 4                       # 512 postings = 4 rows x 128 lanes

# per-block bit widths round up to one of these buckets, so a single outlier
# gap widens only its own bucket instead of the whole arena (and the kernel
# compiles at most this many bw variants)
BW_BUCKETS = (4, 8, 12, 16, 24, 32)


def rows_per_block(bw: int) -> int:
    """Packed tile rows for one 512-posting block at bit width ``bw``."""
    return -(-BLOCK_ROWS * bw // 32)


def pack_gaps(gaps: np.ndarray, bw: int) -> np.ndarray:
    """Pack one block's d-gaps (<= 512 values, each < 2**bw) into the
    (rows_per_block(bw), 128) uint32 tile ``_fused_kernel`` consumes: value
    ``i`` at row ``i // 128``, lane ``i % 128``, LSB-first at width ``bw``."""
    vals = np.zeros(BLOCK_ROWS * LANES, np.uint32)
    vals[: len(gaps)] = gaps
    vals = vals.reshape(BLOCK_ROWS, LANES).astype(np.uint64)
    tile = np.zeros((rows_per_block(bw), LANES), np.uint32)
    for r in range(BLOCK_ROWS):
        start = r * bw
        w, off = start // 32, start % 32
        tile[w] |= ((vals[r] << off) & 0xFFFFFFFF).astype(np.uint32)
        if off + bw > 32:
            tile[w + 1] |= (vals[r] >> (32 - off)).astype(np.uint32)
    return tile


def _fused_kernel(slot_ref, first_ref, n_ref, tile_ref, cand_ref,
                  ids_ref, hit_ref, *, bw: int, cand_words: int):
    i = pl.program_id(0)
    m = _mask(bw)
    base = first_ref[i]
    nn = n_ref[i]
    cand = cand_ref[...].reshape(-1)
    lane = jnp.arange(LANES, dtype=jnp.int32)
    for r in range(BLOCK_ROWS):
        # unpack row r: 128 gaps at static bit offset r*bw within each lane
        start = r * bw
        w, off = start // 32, start % 32
        v = tile_ref[w, :] >> jnp.uint32(off)
        if off + bw > 32:
            v = v | (tile_ref[w + 1, :] << jnp.uint32(32 - off))
        v = v & m
        # fused d-gap decode: running prefix sum across rows (linear order)
        c = jnp.cumsum(v, dtype=jnp.uint32)
        d = c + base
        base = base + c[-1]
        # fused AND: probe the candidate bitmap word holding each docid
        word = cand[jnp.minimum(d >> 5, jnp.uint32(cand_words - 1)).astype(jnp.int32)]
        hit = (word >> (d & 31)) & jnp.uint32(1)
        valid = (lane + r * LANES) < nn
        ids_ref[r, :] = d
        hit_ref[r, :] = jnp.where(valid, hit, jnp.uint32(0))


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def fused_decode_and(tiles: jnp.ndarray, slots: jnp.ndarray,
                     firsts: jnp.ndarray, ns: jnp.ndarray,
                     cand_rows: jnp.ndarray, bw: int,
                     interpret=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode + intersect a work-list of packed block tiles in one call.

    tiles:     (S * rows_per_block(bw), 128) uint32 — the packed gap arena.
    slots:     (W,) int32 — arena tile index per work-list entry (the engine's
               skip-selected blocks; drives the prefetched DMA index map).
    firsts:    (W,) uint32 — first docid per entry (skip-table value).
    ns:        (W,) int32 — posting count per entry (<= 512).
    cand_rows: (R, 128) uint32 — candidate bitmap over [0, R*4096).

    Returns (docids, hits), each (W * 4, 128) uint32; entry j owns rows
    [4j, 4j+4) and its intersection is ``docids[hits == 1]`` in linear order.
    """
    w = slots.shape[0]
    rpb = rows_per_block(bw)
    crows = cand_rows.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(w,),
        in_specs=[pl.BlockSpec((rpb, LANES), lambda i, s, f, n: (s[i], 0)),
                  pl.BlockSpec((crows, LANES), lambda i, s, f, n: (0, 0))],
        out_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i, s, f, n: (i, 0)),
                   pl.BlockSpec((BLOCK_ROWS, LANES), lambda i, s, f, n: (i, 0))],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, bw=bw, cand_words=crows * LANES),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((w * BLOCK_ROWS, LANES), jnp.uint32),
                   jax.ShapeDtypeStruct((w * BLOCK_ROWS, LANES), jnp.uint32)],
        interpret=auto_interpret(interpret),
    )(slots, firsts, ns, tiles, cand_rows)
