"""Segmented accumulate kernels: the scatter half of every serving round.

Every device-resident round ends the same way: per work-list entry, combine a
(lane,) contribution vector into the owning query's row of a batch-segmented
state array — quantized impact codes into the (Q, width) score accumulator,
survivor bits into the (Q, words) candidate/membership bitmaps.  This module
is the single home for that step, in three shapes:

* :func:`scatter_add` / :func:`scatter_bits` — the *sparse* form: per-lane
  docids address arbitrary columns.  On TPU these lower to a segmented Pallas
  kernel that pins the owning query's row in VMEM while the next entry's
  contribution tile DMAs in (scalar-prefetched work-list indices, the
  ``decode_fused`` double-buffering pattern); elsewhere they stay the XLA
  scatter — compiled Mosaic only exists on TPU, and interpreter-mode Pallas
  would be strictly slower than the scatter it replaces (the same policy as
  ``bitpack.auto_interpret``, decided in :func:`use_pallas`).
* :func:`dense_add` — the *dense window* form for bitmap blocks
  (``repro.core.dense_bitmap``): each entry adds a contiguous 4096-column
  window at a 128-aligned offset, so on TPU the kernel is one aliased
  VMEM row load/store per entry with no gather at all; the fallback is a
  sequential ``fori_loop`` of ``dynamic_update_slice`` adds, which beats the
  general scatter by an order of magnitude on CPU because the window is
  contiguous.
* :func:`dense_window_gather` / :func:`dense_window_add` — 128-word window
  probe/commit for the dense AND rounds.

Exactness contract (shared with the callers' docstrings): within one round a
(query, term occurrence) contributes to each docid at most once, so integer adds are
plain sums and bit adds are exact ORs; across calls that accumulate into the
same state the contributing docid sets are disjoint, so add still equals OR.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitpack import LANES

DENSE_WINDOW = 4096          # dense score window: 128 words * 32 bits
WINDOW_WORDS = 128


def use_pallas(flag=None) -> bool:
    """Route the accumulate step to compiled Pallas only where compiled
    Pallas exists (TPU); everywhere else the XLA scatter / fori_loop
    fallbacks are the faster lowering of the same contract."""
    if flag is not None:
        return bool(flag)
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------------- #
# sparse segmented accumulate
# --------------------------------------------------------------------------- #

_SPARSE_CHUNK = 2048         # columns of the one-hot tile kept in VMEM


def _sparse_kernel(qs_ref, ids_ref, contrib_ref, acc_ref, *, width: int):
    """Accumulate one entry's (lane,) contributions into its query row.

    The row block is selected by the scalar-prefetched ``qslot`` array and
    aliased in place; entries arrive sorted by qslot so revisits of the same
    row are consecutive grid steps and the block stays resident in VMEM.
    The per-lane docids are expanded chunk-by-chunk as a one-hot
    compare-and-reduce — 512 x 2048 stays well inside VMEM and the reduce is
    a plain VPU sum (contributions are u8-bounded, far below f32 precision).
    """
    ids = ids_ref[0, :]
    contrib = contrib_ref[0, :]
    for c in range(width // _SPARSE_CHUNK):
        cols = (jnp.arange(_SPARSE_CHUNK, dtype=jnp.uint32)
                + jnp.uint32(c * _SPARSE_CHUNK))
        onehot = (ids[:, None] == cols[None, :]).astype(jnp.uint32)
        add = jnp.sum(onehot * contrib[:, None], axis=0, dtype=jnp.uint32)
        sl = pl.ds(c * _SPARSE_CHUNK, _SPARSE_CHUNK)
        acc_ref[0, sl] = acc_ref[0, sl] + add


def _sparse_pallas(acc, ids, qslot, contrib):
    p = ids.shape[0]
    width = acc.shape[1]
    order = jnp.argsort(qslot)            # same-row entries -> consecutive
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[pl.BlockSpec((1, ids.shape[1]), lambda i, q: (i, 0)),
                  pl.BlockSpec((1, ids.shape[1]), lambda i, q: (i, 0))],
        out_specs=pl.BlockSpec((1, width), lambda i, q: (q[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_sparse_kernel, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        input_output_aliases={3: 0},
    )(qslot[order].astype(jnp.int32), ids[order], contrib[order], acc)


def scatter_add(acc, ids, qslot, contrib):
    """acc[qslot[j], ids[j, l]] += contrib[j, l] — exact (docids distinct per
    entry; masked lanes carry contrib == 0)."""
    if use_pallas():
        return _sparse_pallas(acc, ids, qslot, contrib)
    return acc.at[qslot[:, None], ids].add(contrib)


def scatter_bits(bm, ids, qslot, surv):
    """OR survivor docids into a zeroed copy of ``bm``'s geometry: the
    sparse accumulate instantiated for packed bitmap words."""
    word = (ids >> 5).astype(jnp.int32)
    contrib = jnp.where(surv, jnp.uint32(1) << (ids & 31), jnp.uint32(0))
    if use_pallas():
        return _sparse_pallas(jnp.zeros_like(bm), word.astype(jnp.uint32),
                              qslot, contrib)
    return jnp.zeros_like(bm).at[qslot[:, None], word].add(contrib)


# --------------------------------------------------------------------------- #
# dense 4096-column window accumulate (score side of bitmap blocks)
# --------------------------------------------------------------------------- #


def _dense_kernel(qs_ref, col_ref, act_ref, codes_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(act_ref[i] != 0)
    def _():
        sl = (0, pl.ds(col_ref[i], DENSE_WINDOW))
        pl.store(acc_ref, sl, pl.load(acc_ref, sl) + codes_ref[0, :])


def _dense_pallas(acc, codes, qslot, col0, act):
    p = codes.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(p,),
        in_specs=[pl.BlockSpec((1, DENSE_WINDOW), lambda i, q, c, a: (i, 0))],
        out_specs=pl.BlockSpec((1, acc.shape[1]), lambda i, q, c, a: (q[i], 0)),
    )
    return pl.pallas_call(
        _dense_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        input_output_aliases={4: 0},
    )(qslot.astype(jnp.int32), col0.astype(jnp.int32),
      act.astype(jnp.int32), codes, acc)


@jax.jit
def _dense_loop(acc, codes, qslot, col0, act):
    def body(i, a):
        row = jax.lax.dynamic_slice(a, (qslot[i], col0[i]), (1, DENSE_WINDOW))
        add = jnp.where(act[i], codes[i], jnp.uint32(0))[None, :]
        return jax.lax.dynamic_update_slice(a, row + add, (qslot[i], col0[i]))
    return jax.lax.fori_loop(0, codes.shape[0], body, acc)


def dense_add(acc, codes, qslot, col0, act):
    """acc[qslot[j], col0[j] : col0[j] + 4096] += codes[j] where act[j].

    ``col0`` is 128-aligned (the arena aligns dense windows at build time so
    the lane-dimension dynamic slice is tile-aligned on TPU).  Entries must
    arrive sorted by qslot: the TPU row block stays write-resident across
    consecutive same-row grid steps, and the fallback loop is sequential
    either way.
    """
    if use_pallas():
        return _dense_pallas(acc, codes, qslot, col0, act)
    return _dense_loop(acc, codes, qslot, col0, act)


# --------------------------------------------------------------------------- #
# 128-word window probe / commit (bitmap AND rounds, membership bitmaps)
# --------------------------------------------------------------------------- #


@jax.jit
def dense_window_gather(bm, qslot, w0):
    """(P, 128) uint32: each entry's word window of its query's bitmap row."""
    return jax.vmap(
        lambda q, s: jax.lax.dynamic_slice(bm[q], (s,), (WINDOW_WORDS,))
    )(qslot, w0)


@jax.jit
def dense_window_add(dst, vals, qslot, w0, act):
    """dst[qslot[j], w0[j] : w0[j] + 128] += vals[j] where act[j] — exact OR
    under the disjoint-bits contract.  Windows are 128 contiguous words, so
    the XLA scatter stays cheap (one word-aligned segment per entry)."""
    contrib = jnp.where(act[:, None], vals, jnp.uint32(0))
    cols = w0[:, None] + jnp.arange(WINDOW_WORDS, dtype=jnp.int32)[None, :]
    return dst.at[qslot[:, None], cols].add(contrib)
