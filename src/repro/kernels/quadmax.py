"""Pallas kernel: per-frame pseudo-max via OR reduction (paper §4.4).

The paper replaces the 4-way compare-max with a logical OR — same effective
bit width, no comparisons.  On TPU the group is a frame tile: OR-reduce a
(32, 128) block over its sublane (row) axis -> (1, 128); the final cross-lane
OR (128 -> 1) is a cheap host-side epilogue on F*128 values.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitpack import FRAME_ROWS, LANES


def _frame_or_kernel(x_ref, o_ref, *, frames: int):
    for f in range(frames):
        acc = x_ref[f * FRAME_ROWS, :]
        for r in range(1, FRAME_ROWS):
            acc = acc | x_ref[f * FRAME_ROWS + r, :]
        o_ref[f, :] = acc


@functools.partial(jax.jit, static_argnames=("interpret", "frames_per_block"))
def frame_or(x: jnp.ndarray, interpret: bool = True, frames_per_block: int = 8) -> jnp.ndarray:
    """(F*32, 128) -> (F, 128) per-frame, per-lane OR."""
    f = x.shape[0] // FRAME_ROWS
    fpb = min(frames_per_block, f)
    while f % fpb:
        fpb -= 1
    return pl.pallas_call(
        functools.partial(_frame_or_kernel, frames=fpb),
        grid=(f // fpb,),
        in_specs=[pl.BlockSpec((fpb * FRAME_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((fpb, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((f, LANES), jnp.uint32),
        interpret=interpret,
    )(x)
