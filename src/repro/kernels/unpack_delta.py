"""Pallas kernel: FUSED bit-unpack + d-gap prefix sum (beyond-paper, DESIGN §2).

The paper decodes gaps, writes them to memory, then reconstructs docids in a
second pass.  On TPU both passes are HBM-bandwidth-bound, so fusing them
halves the dominant roofline term: one kernel reads the packed words
(bw/32 bytes per integer), unpacks in VMEM, scans, and writes docids —
packed-in, docids-out, no intermediate gap array in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitpack import FRAME_ROWS, LANES, _mask, auto_interpret


def _unpack_delta_kernel(p_ref, o_ref, carry_ref, *, bw: int, frames: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        carry_ref[0, 0] = jnp.uint32(0)

    m = _mask(bw)
    base = carry_ref[0, 0]
    for f in range(frames):
        for r in range(FRAME_ROWS):
            start = r * bw
            w, off = start // 32, start % 32
            v = p_ref[f * bw + w, :] >> jnp.uint32(off)
            if off + bw > 32:
                v = v | (p_ref[f * bw + w + 1, :] << jnp.uint32(32 - off))
            v = v & m
            c = jnp.cumsum(v, dtype=jnp.uint32)
            o_ref[f * FRAME_ROWS + r, :] = c + base
            base = base + c[-1]
    carry_ref[0, 0] = base


@functools.partial(jax.jit, static_argnames=("bw", "interpret", "frames_per_block"))
def unpack_delta_frames(packed: jnp.ndarray, bw: int, interpret=None,
                        frames_per_block: int = 4) -> jnp.ndarray:
    """(F*bw, 128) packed gaps -> (F*32, 128) docids (prefix-summed).

    ``interpret=None`` resolves per backend (compiled Mosaic on TPU,
    interpreter elsewhere).
    """
    interpret = auto_interpret(interpret)
    f = packed.shape[0] // bw
    fpb = min(frames_per_block, f)
    while f % fpb:
        fpb -= 1
    return pl.pallas_call(
        functools.partial(_unpack_delta_kernel, bw=bw, frames=fpb),
        grid=(f // fpb,),
        in_specs=[pl.BlockSpec((fpb * bw, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((fpb * FRAME_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((f * FRAME_ROWS, LANES), jnp.uint32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.uint32)],
        interpret=interpret,
    )(packed)
