"""Pallas TPU kernels: fixed-bit-width pack/unpack over the wide vertical layout.

This is the paper's hot loop (vectorized shift+mask, §3.2/§4.4) adapted to the
TPU: a frame of 4096 integers lives in a (32, 128) VMEM tile — 128 lanes play
the role of the four SSE components, 32 slots per lane.  Packing at bit width
``bw`` emits exactly (bw, 128) words per frame: each lane squeezes its 32
values (32*bw bits) into bw words, LSB-first.  All shift amounts are static
(the bit width is closed over at trace time — the TPU analogue of the paper's
per-selector SWITCH-CASE specialization, §4.4), so the unrolled body is pure
VPU shift/AND/OR work with no data-dependent control flow.

Grid: one step per frame (or several frames per step via the ``frames_per_block``
knob — fewer grid steps, bigger VMEM tiles).  BlockSpecs tile HBM -> VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FRAME_ROWS = 32
LANES = 128
FRAME_INTS = FRAME_ROWS * LANES


def auto_interpret(interpret) -> bool:
    """Resolve an ``interpret`` kwarg: None means "compile only on TPU".

    TPU runs compile the real Mosaic kernels by default; every other backend
    (this container's CPU, but also GPU, whose Triton lowering has no
    ``pltpu`` grid-spec/scratch dialect) keeps the interpreter path.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _mask(bw: int) -> jnp.ndarray:
    return jnp.uint32(0xFFFFFFFF if bw >= 32 else (1 << bw) - 1)


def _pack_kernel(x_ref, o_ref, *, bw: int, frames: int):
    m = _mask(bw)
    for f in range(frames):
        acc = jnp.zeros((LANES,), jnp.uint32)
        off = 0
        w = 0
        for r in range(FRAME_ROWS):
            v = x_ref[f * FRAME_ROWS + r, :] & m
            acc = acc | (v << jnp.uint32(off)) if off else (acc | v)
            if off + bw >= 32:
                o_ref[f * bw + w, :] = acc
                w += 1
                rem = off + bw - 32
                acc = (v >> jnp.uint32(bw - rem)) if rem else jnp.zeros((LANES,), jnp.uint32)
                off = rem
            else:
                off += bw
        assert w == bw and off == 0  # 32*bw bits == bw words, always exact


def _unpack_kernel(p_ref, o_ref, *, bw: int, frames: int):
    m = _mask(bw)
    for f in range(frames):
        for r in range(FRAME_ROWS):
            start = r * bw
            w, off = start // 32, start % 32
            v = p_ref[f * bw + w, :] >> jnp.uint32(off)
            if off + bw > 32:
                v = v | (p_ref[f * bw + w + 1, :] << jnp.uint32(32 - off))
            o_ref[f * FRAME_ROWS + r, :] = v & m


@functools.partial(jax.jit, static_argnames=("bw", "interpret", "frames_per_block"))
def pack_frames(x: jnp.ndarray, bw: int, interpret: bool = True, frames_per_block: int = 4) -> jnp.ndarray:
    """(F*32, 128) uint32 -> (F*bw, 128) uint32; F must be a multiple of frames_per_block."""
    f = x.shape[0] // FRAME_ROWS
    fpb = min(frames_per_block, f)
    while f % fpb:
        fpb -= 1
    grid = (f // fpb,)
    return pl.pallas_call(
        functools.partial(_pack_kernel, bw=bw, frames=fpb),
        grid=grid,
        in_specs=[pl.BlockSpec((fpb * FRAME_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((fpb * bw, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((f * bw, LANES), jnp.uint32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("bw", "interpret", "frames_per_block"))
def unpack_frames(packed: jnp.ndarray, bw: int, interpret: bool = True, frames_per_block: int = 4) -> jnp.ndarray:
    """(F*bw, 128) uint32 -> (F*32, 128) uint32."""
    f = packed.shape[0] // bw
    fpb = min(frames_per_block, f)
    while f % fpb:
        fpb -= 1
    grid = (f // fpb,)
    return pl.pallas_call(
        functools.partial(_unpack_kernel, bw=bw, frames=fpb),
        grid=grid,
        in_specs=[pl.BlockSpec((fpb * bw, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((fpb * FRAME_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((f * FRAME_ROWS, LANES), jnp.uint32),
        interpret=interpret,
    )(packed)
