"""Fault-tolerant training loop.

Features (DESIGN.md §8):
  * periodic atomic checkpoints (params, opt incl. error-feedback, data
    cursor, python RNG) and automatic resume from the latest intact step;
  * deterministic data skipping on resume (the cursor is part of the
    checkpoint, so a killed-and-restarted run replays the same batches);
  * straggler watchdog: EMA of step wall-time; steps slower than
    ``straggler_factor`` x EMA are logged and counted — on a real pod this
    hook triggers shard rebalancing / backup-task dispatch, here it feeds the
    fault-injection test;
  * crash injection for tests (``crash_at_step``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import Checkpointer, latest_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0
    crash_at_step: Optional[int] = None      # fault-injection (tests)


class StragglerWatchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.ema = None
        self.flagged = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.flagged.append((step, dt, self.ema))
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        return slow


def run(step_fn: Callable, params, opt, batch_iter_fn: Callable, cfg: LoopConfig,
        log_fn=print):
    """batch_iter_fn(cursor) -> (batch, new_cursor).  Returns final state.

    Resumes from the newest intact checkpoint in cfg.ckpt_dir if present.
    """
    ckpt = Checkpointer(cfg.ckpt_dir)
    start, cursor = 0, 0
    if latest_step(cfg.ckpt_dir) is not None:
        (params, opt), start, extra = ckpt.restore((params, opt))
        cursor = extra.get("cursor", 0)
        log_fn(f"[resume] restored step {start} cursor {cursor}")
    dog = StragglerWatchdog(cfg.straggler_factor)
    metrics_hist = []
    for step in range(start, cfg.total_steps):
        if cfg.crash_at_step is not None and step == cfg.crash_at_step:
            raise RuntimeError(f"injected crash at step {step}")
        batch, cursor = batch_iter_fn(cursor)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = dog.observe(step, dt)
        metrics_hist.append({k: float(v) for k, v in metrics.items()})
        if step % cfg.log_every == 0 or slow:
            log_fn(f"[step {step}] loss={float(metrics['loss']):.4f} dt={dt*1e3:.1f}ms"
                   + (" STRAGGLER" if slow else ""))
        if (step + 1) % cfg.ckpt_every == 0 or step + 1 == cfg.total_steps:
            ckpt.save(step + 1, (params, opt), {"cursor": int(cursor)})
    return params, opt, {"metrics": metrics_hist, "stragglers": dog.flagged}
