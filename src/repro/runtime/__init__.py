from . import trainer, train_loop  # noqa: F401
