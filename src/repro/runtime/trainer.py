"""Train-step factories.

Two paths:

  * ``make_train_step`` — global-jit GSPMD: loss -> grad -> AdamW; gradients
    are reduced by XLA-inserted collectives per the sharding plan (FSDP/TP/
    EP/SP).  Used by the dry-run and the full-scale launcher.

  * ``make_compressed_dp_train_step`` — shard_map manual over the DP axes
    ("pod","data"), auto over "model": per-device grads are synchronized with
    the COMPRESSED all-reduce (int8/int4 + error feedback, collectives.py) —
    the paper's bit packing applied to the gradient exchange.  Params are
    replicated over DP (TP/EP still available via the auto axis).  The
    error-feedback residual rides in the optimizer state and is checkpointed.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.distributed.collectives import compressed_psum_mean
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig, grad_transform=None):
    """loss_fn(params, batch) -> (loss, metrics).

    grad_transform (optional): applied to the grad tree before the update —
    e.g. constraining grads to the parameter shardings so GSPMD emits
    reduce-scatters instead of full fp32 all-reduces (§Perf HC2 iteration 2).
    """

    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt, om = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, {"loss": loss, **metrics, **om}

    return step


def make_compressed_dp_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                                  mesh, batch_specs, dp_axes=("pod", "data"),
                                  bits: int = 8, auto_axes=("model",)):
    """Manual-DP trainer with compressed gradient all-reduce.

    batch_specs: pytree of PartitionSpecs for the batch (DP axes only).
    Params/opt replicated over DP.  Returns (step_fn, init_opt_fn).
    """
    dp = tuple(a for a in dp_axes if a in mesh.shape)
    auto = frozenset(a for a in auto_axes if a in mesh.shape)

    def init_opt(params):
        opt = adamw_init(params)
        opt["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return opt

    def local_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if bits is None:                      # uncompressed control (fp32 pmean)
            grads = jax.tree.map(lambda g: jax.lax.pmean(g.astype(jnp.float32), dp), grads)
            ef = opt["ef"]
        else:
            grads, ef = compressed_psum_mean(grads, dp, bits=bits, error_feedback=opt["ef"])
        loss = jax.lax.pmean(loss, dp)
        opt_core = {"m": opt["m"], "v": opt["v"], "step": opt["step"]}
        params, opt_core, om = adamw_update(params, grads, opt_core, opt_cfg)
        opt_core["ef"] = ef
        return params, opt_core, {"loss": loss, **metrics, **om}

    rep = PS()
    step = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, batch_specs),
        out_specs=(rep, rep, rep),
        axis_names=frozenset(dp),            # manual over DP; "model" stays auto
        check_vma=False,
    )
    return step, init_opt
