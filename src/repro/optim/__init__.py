from . import adamw  # noqa: F401
from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr  # noqa: F401
