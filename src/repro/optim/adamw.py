"""AdamW from scratch (no optax in this environment): decoupled weight decay,
global-norm clipping, cosine schedule with linear warmup.  Optimizer state
mirrors the parameter pytree (and inherits its sharding)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def cosine_lr(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def _needs_master(params) -> bool:
    return any(x.dtype != jnp.float32 for x in jax.tree.leaves(params))


def adamw_init(params):
    """m/v in fp32.  When working params are low-precision (bf16 ZeRO-3 —
    halves the weight all-gather wire bytes, EXPERIMENTS.md §Perf HC2), a
    fp32 master copy rides in the optimizer state."""
    opt = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if _needs_master(params):
        opt["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return opt


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt, cfg: AdamWConfig):
    step = opt["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = cosine_lr(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        ref = master if master is not None else p.astype(jnp.float32)
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * ref
        new_master = ref - lr * step_
        return new_master.astype(p.dtype), m, v, new_master

    has_master = "master" in opt
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    flat_ma = jax.tree.leaves(opt["master"]) if has_master else [None] * len(flat_p)
    new = [upd(p, g, m, v, ma) for p, g, m, v, ma in
           zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    params = jax.tree.unflatten(tdef, [n[0] for n in new])
    m = jax.tree.unflatten(tdef, [n[1] for n in new])
    v = jax.tree.unflatten(tdef, [n[2] for n in new])
    out = {"m": m, "v": v, "step": step}
    if has_master:
        out["master"] = jax.tree.unflatten(tdef, [n[3] for n in new])
    return params, out, {"grad_norm": gn, "lr": lr}
