"""Sharded embedding tables + EmbeddingBag.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — lookups are built
from ``jnp.take`` + masking + segment-style reductions (kernel_taxonomy
§RecSys: "this IS part of the system").  Two paths per op:

  * plain path (no mesh / replicated table): jnp.take.
  * EP path (table rows sharded over "model"): shard_map mask-gather-psum —
    each shard gathers only the rows it owns, zeros the rest, psums.  Wire
    bytes per lookup: batch*dim psum instead of all-gathering the table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.distributed import sharding as shlib


def _ep_ctx(table_rows: int):
    """Returns (mesh, row_axis, batch_axes) when the EP path applies."""
    ctx = shlib._active()
    if ctx is None:
        return None
    mesh, plan = ctx
    axes = tuple(a for a in (plan.axes_of("table_rows") or ()) if a in mesh.shape)
    if not axes or table_rows % shlib._mesh_size(mesh, axes) != 0:
        return None
    batch_axes = tuple(a for a in (plan.axes_of("batch") or ()) if a in mesh.shape)
    return mesh, axes[0], batch_axes


def _local_gather(tbl, loc, ok):
    """tbl (..., r, D); loc int (B, ...) same leading rank as ids; per-table."""
    if tbl.ndim == 2:
        v = jnp.take(tbl, loc, axis=0)
    else:  # stacked (T, r, D); loc (..., T) -> gather per table
        v = jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, -1), out_axes=-2)(tbl, loc)
        # out (..., T, D)
    return jnp.where(ok[..., None], v, 0)


def lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Single table (R, D), ids (...,) -> (..., D)."""
    ep = _ep_ctx(table.shape[0])
    if ep is None:
        return jnp.take(table, ids, axis=0)
    mesh, raxis, baxes = ep

    def local(tbl, ids_l):
        me = jax.lax.axis_index(raxis)
        r = tbl.shape[0]
        loc = ids_l - me * r
        ok = (loc >= 0) & (loc < r)
        return jax.lax.psum(_local_gather(tbl, jnp.clip(loc, 0, r - 1), ok), raxis)

    ids_spec = PS(baxes if baxes else None, *([None] * (ids.ndim - 1)))
    out_spec = PS(baxes if baxes else None, *([None] * ids.ndim))
    return shard_map(local, mesh=mesh, in_specs=(PS(raxis, None), ids_spec),
                     out_specs=out_spec, check_rep=False)(table, ids)


def lookup_stacked(tables: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Stacked tables (T, R, D), ids (..., T) -> (..., T, D): out[..., t, :] =
    tables[t, ids[..., t], :]."""
    ep = _ep_ctx(tables.shape[1])
    if ep is None:
        return jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, -1), out_axes=-2)(tables, ids)
    mesh, raxis, baxes = ep

    def local(tbl, ids_l):
        me = jax.lax.axis_index(raxis)
        r = tbl.shape[1]
        loc = ids_l - me * r
        ok = (loc >= 0) & (loc < r)
        return jax.lax.psum(_local_gather(tbl, jnp.clip(loc, 0, r - 1), ok), raxis)

    ids_spec = PS(baxes if baxes else None, *([None] * (ids.ndim - 1)))
    out_spec = PS(baxes if baxes else None, *([None] * ids.ndim))
    return shard_map(local, mesh=mesh, in_specs=(PS(None, raxis, None), ids_spec),
                     out_specs=out_spec, check_rep=False)(tables, ids)


def bag_sum(table: jnp.ndarray, ids: jnp.ndarray, valid=None) -> jnp.ndarray:
    """EmbeddingBag(sum): ids (..., L) -> (..., D); valid (..., L) bool."""
    v = lookup(table, ids)
    if valid is not None:
        v = v * valid[..., None].astype(v.dtype)
    return v.sum(axis=-2)


def bag_mean(table: jnp.ndarray, ids: jnp.ndarray, valid=None) -> jnp.ndarray:
    v = lookup(table, ids)
    if valid is None:
        return v.mean(axis=-2)
    m = valid[..., None].astype(v.dtype)
    return (v * m).sum(axis=-2) / jnp.maximum(m.sum(axis=-2), 1.0)
