"""Attention variants: GQA (+ sliding window), MLA; chunked online-softmax
("flash-style") full forward for train/prefill and O(window|cache) decode.

The chunked implementation is pure jnp + lax.scan so it lowers on every
backend (the dry-run compiles on 512 host devices); on real TPU the same call
site can swap in a Pallas flash kernel — the math and the sharding contract
(B->data, H->model, optional S_kv->model for long-context decode) are
identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

NEG_INF = -1e30


def _chunk_attn(q, k, v, q0: int, causal: bool, window, kv_chunk: int):
    """Online-softmax attention of q (B,Sq,H,D) over full k/v (B,Skv,KH,D).

    q0 = absolute position of q[0] (queries are at q0..q0+Sq-1, keys at
    0..Skv-1).  GQA: H % KH == 0, heads grouped.  window: only keys within
    (pos_q - window, pos_q] attend (SWA).
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]                      # may differ from d (MLA)
    g = h // kh
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, d)
    nchunks = -(-skv // kv_chunk)
    pad = nchunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, kv_chunk, kh, d)
    vc = v.reshape(b, nchunks, kv_chunk, kh, dv)
    qpos = q0 + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        kblk, vblk, ci = inp
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qf, kblk.astype(jnp.float32)) * scale
        # pin the score sharding: without this the partitioner cannot split
        # the (KH, G) head factorization over the model axis and falls back
        # to replicating the full score tensor (§Perf HC2: a 2.9e12 B/chip
        # all-gather on mixtral train)
        s = shard(s, "batch", "act_seq_attn", "act_heads", None, None)
        mask = kpos[None, :] <= skv - 1  # drop right-pad
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqkgs,bskd->bqkgd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = shard(jnp.full((b, sq, kh, g), NEG_INF, jnp.float32),
               "batch", "act_seq_attn", "act_heads", None)
    l0 = shard(jnp.zeros((b, sq, kh, g), jnp.float32),
               "batch", "act_seq_attn", "act_heads", None)
    a0 = shard(jnp.zeros((b, sq, kh, g, dv), jnp.float32),
               "batch", "act_seq_attn", "act_heads", None, None)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    # nested remat: without it, the scan's backward stacks every chunk's fp32
    # score tensor in HBM ((nchunks, B, Sq, H, kv_chunk) — the dominant memory
    # term of every LM train/prefill cell); with it, backward recomputes
    # scores per chunk from the (m, l, acc) carry.  EXPERIMENTS.md §Perf HC1.
    step = jax.checkpoint(step, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc_t, vc_t, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool = True, window=None,
                   q_chunk: int = 1024, kv_chunk: int = 1024):
    """Train/prefill attention, scanning over q chunks to bound VMEM/HBM."""
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    if sq <= q_chunk:
        return _chunk_attn(q, k, v, 0, causal, window, min(kv_chunk, k.shape[1]))
    nq = -(-sq // q_chunk)
    pad = nq * q_chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qs = jnp.moveaxis(qp.reshape(b, nq, q_chunk, h, d), 1, 0)

    def step(_, inp):
        qi, ci = inp
        o = _chunk_attn(qi, k, v, ci * q_chunk, causal, window, kv_chunk)
        return None, o

    _, outs = jax.lax.scan(step, None, (qs, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, h, dv)
    return out[:, :sq]


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None):
    """One-token decode: q (B,1,H,D) over caches (B,S,KH,D); cache_len scalar
    = number of valid cache entries (the new token's k/v already written)."""
    b, _, h, d = q.shape
    skv, kh = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // kh
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, kh, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(skv)
    mask = kpos < cache_len                                      # cache_len: scalar
    if window is not None:
        mask = mask & (kpos >= cache_len - window)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# --------------------------------------------------------------------------- #
# MLA (DeepSeek-V2): low-rank latent KV cache
# --------------------------------------------------------------------------- #


def mla_decode_attention(q_nope, q_rope, latent_cache, rope_cache, cache_len,
                         w_uk, w_uv):
    """Absorbed MLA decode (memory-optimal: cache holds only latents).

    q_nope (B,H,Dn), q_rope (B,H,Dr); latent_cache (B,S,L); rope_cache (B,S,Dr)
    w_uk (H,L,Dn)  (key up-proj per head), w_uv (H,L,Dv).
    Returns (B,1,H,Dv).
    """
    scale = 1.0 / jnp.sqrt(q_nope.shape[-1] + q_rope.shape[-1]).astype(jnp.float32)
    qn = q_nope.astype(jnp.float32)
    qr = q_rope.astype(jnp.float32)
    lat = latent_cache.astype(jnp.float32)
    rop = rope_cache.astype(jnp.float32)
    # absorb key up-projection into the query: q_abs (B,H,L)
    q_abs = jnp.einsum("bhd,hld->bhl", qn, w_uk.astype(jnp.float32))
    s = jnp.einsum("bhl,bsl->bhs", q_abs, lat)
    s = s + jnp.einsum("bhd,bsd->bhs", qr, rop)
    s = s * scale
    mask = jnp.arange(lat.shape[1]) < cache_len                  # cache_len: scalar
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", p, lat)                   # attend over latents
    out = jnp.einsum("bhl,hld->bhd", o_lat, w_uv.astype(jnp.float32))
    return out[:, None].astype(q_nope.dtype)
