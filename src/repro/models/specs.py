"""Parameter specs with logical sharding axes (MaxText-style).

Every parameter is declared once as ``P(shape, axes)`` where ``axes`` are
*logical* names ("embed", "heads", "ffn", "expert", "vocab", ...).  The
distribution layer maps logical names -> mesh axes per architecture
(repro.distributed.sharding), so the same model code runs single-device,
single-pod (16x16) and multi-pod (2x16x16).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: tuple
    axes: tuple                      # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | embed
    scale: Optional[float] = None    # None -> 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: P, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    scale = spec.scale
    if scale is None:
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    if spec.init == "embed":
        scale = 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, P)


def init_params(specs, key) -> Any:
    """Materialize a pytree of P specs into arrays (deterministic per path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs) -> Any:
    """ShapeDtypeStruct tree (for dry-run / eval_shape paths)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
                        is_leaf=is_spec)


def axes_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_layers(specs, n_layers: int) -> Any:
    """Add a scanned leading 'layers' dim to every spec in the tree."""
    return jax.tree.map(
        lambda s: P((n_layers,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.dtype),
        specs, is_leaf=is_spec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
