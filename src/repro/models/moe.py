"""Mixture-of-Experts with group-local top-k dispatch (GShard/MaxText-style
"dropping" implementation, static shapes, no global sort).

Tokens are routed within fixed groups (one group = one sequence for training,
one batch row for decode).  Per group: top-k -> stable sort of S*k expert
assignments -> capacity-clipped gather indices (E, C).  Expert compute is a
batched einsum (G, E, C, D) x (E, D, F); with G sharded over data axes and the
expert/ffn dims sharded per the arch plan (EP for DeepSeek's 64 experts, TP
over d_ff for Mixtral's 8), GSPMD inserts the dispatch all-to-alls.

Flops: 2 * T * k * cf * (3 D F) — the correct active-expert cost, no dense
dispatch einsum (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def route_group(x, router_w, *, top_k: int, capacity: int):
    """x (S, D) -> (idx (E*C,), weight (E*C,), aux_loss scalar).

    idx[e*C+c] = token slot assigned to expert e at capacity position c, or S
    (sentinel = dropped/empty).
    """
    s, d = x.shape
    e = router_w.shape[-1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (S, E)
    gate, expert = jax.lax.top_k(probs, top_k)                  # (S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # aux load-balancing loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros(e, jnp.float32).at[expert.reshape(-1)].add(1.0) / (s * top_k)
    aux = e * jnp.sum(me * ce)
    # group-local stable sort of assignments by expert
    eid = expert.reshape(-1)                                    # (S*k,)
    order = jnp.argsort(eid, stable=True)
    sorted_eid = eid[order]
    seg_start = jnp.searchsorted(sorted_eid, jnp.arange(e), side="left")
    pos_in_seg = jnp.arange(s * top_k) - seg_start[sorted_eid]
    tok = order // top_k
    gflat = gate.reshape(-1)[order]
    keep = pos_in_seg < capacity
    dest = jnp.where(keep, sorted_eid * capacity + pos_in_seg, e * capacity)
    idx = jnp.full(e * capacity + 1, s, jnp.int32).at[dest].set(tok.astype(jnp.int32), mode="drop")
    wgt = jnp.zeros(e * capacity + 1, jnp.float32).at[dest].set(gflat, mode="drop")
    return idx[:-1], wgt[:-1], aux


def moe_ffn(x, router_w, w1, w3, w2, *, top_k: int, capacity_factor: float = 1.25):
    """x (G, S, D); experts w1/w3 (E, D, F), w2 (E, F, D). Returns (G,S,D), aux."""
    g, s, d = x.shape
    e = router_w.shape[-1]
    cap = max(1, int(-(-s * top_k * capacity_factor // e)))
    idx, wgt, aux = jax.vmap(
        lambda xi: route_group(xi, router_w, top_k=top_k, capacity=cap))(x)
    xpad = jnp.concatenate([x, jnp.zeros((g, 1, d), x.dtype)], axis=1)   # sentinel row
    gathered = jnp.take_along_axis(xpad, idx[:, :, None], axis=1)        # (G, E*C, D)
    gathered = gathered.reshape(g, e, cap, d)
    # EP: experts over "model" (all-to-all inserted here); TP: hidden over
    # "model"; or capacity-parallel ("act_capacity" -> model): tokens stay
    # sharded through the expert matmuls and weights are gathered bf16
    # instead of replicating activations (EXPERIMENTS §Perf HC2 iter 4).
    gathered = shard(gathered, "batch", "act_expert", "act_capacity", None)
    h1 = jnp.einsum("gecd,edf->gecf", gathered, w1.astype(gathered.dtype))
    h3 = jnp.einsum("gecd,edf->gecf", gathered, w3.astype(gathered.dtype))
    h = jax.nn.silu(h1) * h3
    h = shard(h, "batch", "act_expert", "act_capacity", "act_ffn_expert")
    y = jnp.einsum("gecf,efd->gecd", h, w2.astype(h.dtype))
    y = (y.reshape(g, e * cap, d) * wgt[:, :, None].astype(y.dtype))
    out = jnp.zeros((g, s + 1, d), y.dtype).at[
        jnp.arange(g)[:, None], idx, :].add(y)
    return out[:, :s], aux.mean()
