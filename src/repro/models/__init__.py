"""Model substrate: transformer LMs (GQA/MLA/MoE/SWA), EGNN, recsys."""

from . import attention, common, egnn, embedding, moe, recsys, sampler, specs, transformer

__all__ = ["attention", "common", "egnn", "embedding", "moe", "recsys",
           "sampler", "specs", "transformer"]
