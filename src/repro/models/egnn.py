"""E(n)-Equivariant GNN (EGNN, Satorras et al. 2021, arXiv:2102.09844).

Message passing via ``jax.ops.segment_sum`` over an edge index — JAX has no
sparse message-passing primitive, so the scatter/gather IS part of the system
(kernel_taxonomy §GNN).  Supports the four assigned shapes: full-batch node
classification (cora / ogb-products), sampled-subgraph training (reddit-like,
fanout sampler in models/sampler.py), and batched small graphs (molecule,
graph-level regression via a segment-sum readout).

Layer (eq. 3-6 of the paper):
  m_ij   = phi_e([h_i, h_j, ||x_i - x_j||^2])
  x_i'   = x_i + mean_j (x_i - x_j) * phi_x(m_ij)
  h_i'   = phi_h([h_i, sum_j m_ij])
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from .specs import P, abstract_params, axes_tree, init_params, stack_layers


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 16
    task: str = "node_class"          # node_class | graph_reg
    coord_dim: int = 3
    dtype: Any = jnp.float32


def _mlp_specs(d_in: int, d_hid: int, d_out: int) -> dict:
    return {
        "w0": P((d_in, d_hid), ("embed", "ffn")),
        "b0": P((d_hid,), (None,), "zeros"),
        "w1": P((d_hid, d_out), ("ffn", "embed")),
        "b1": P((d_out,), (None,), "zeros"),
    }


def _mlp(p, x):
    h = jax.nn.silu(x @ p["w0"].astype(x.dtype) + p["b0"].astype(x.dtype))
    return h @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype)


def param_specs(cfg: EGNNConfig) -> dict:
    dh = cfg.d_hidden
    layer = {
        "phi_e": _mlp_specs(2 * dh + 1, dh, dh),
        "phi_x": _mlp_specs(dh, dh, 1),
        "phi_h": _mlp_specs(2 * dh, dh, dh),
    }
    return {
        "embed_in": P((cfg.d_feat, dh), ("embed", "ffn")),
        "layers": stack_layers(layer, cfg.n_layers),
        "head": _mlp_specs(dh, dh, cfg.n_classes if cfg.task == "node_class" else 1),
    }


def init(cfg: EGNNConfig, key):
    return init_params(param_specs(cfg), key)


def abstract(cfg: EGNNConfig):
    return abstract_params(param_specs(cfg))


def axes(cfg: EGNNConfig):
    return axes_tree(param_specs(cfg))


def _layer(p, h, x, src, dst, n_nodes: int):
    """One EGNN layer. src/dst (E,) int32: message j->i along edge (src=j, dst=i)."""
    hi, hj = h[dst], h[src]
    xi, xj = x[dst], x[src]
    diff = xi - xj
    d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
    m = _mlp(p["phi_e"], jnp.concatenate([hi, hj, d2], axis=-1))
    m = shard(m, "edges", None)
    wx = _mlp(p["phi_x"], m)                                   # (E, 1)
    num = jax.ops.segment_sum(diff * wx, dst, num_segments=n_nodes)
    cnt = jax.ops.segment_sum(jnp.ones((src.shape[0], 1), x.dtype), dst, num_segments=n_nodes)
    x = x + num / jnp.maximum(cnt, 1.0)
    agg = jax.ops.segment_sum(m, dst, num_segments=n_nodes)
    h = h + _mlp(p["phi_h"], jnp.concatenate([h, agg], axis=-1))
    return h, x


def forward(params, feats, coords, src, dst, cfg: EGNNConfig):
    """feats (N, d_feat), coords (N, 3), edges (E,). Returns node embeddings."""
    n = feats.shape[0]
    h = (feats.astype(cfg.dtype) @ params["embed_in"].astype(cfg.dtype))
    h = shard(h, "nodes", None)
    x = coords.astype(cfg.dtype)

    def body(carry, lp):
        h, x = carry
        h, x = _layer(lp, h, x, src, dst, n)
        return (h, x), None

    (h, x), _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False), (h, x), params["layers"])
    return h


def node_class_loss(params, batch, cfg: EGNNConfig):
    """batch: feats, coords, src, dst, labels (N,), label_mask (N,)."""
    h = forward(params, batch["feats"], batch["coords"], batch["src"], batch["dst"], cfg)
    logits = _mlp(params["head"], h).astype(jnp.float32)
    lz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch["label_mask"].astype(jnp.float32)
    loss = jnp.sum((lz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss, {"ce": loss}


def graph_reg_loss(params, batch, cfg: EGNNConfig):
    """Batched small graphs: graph_id (N,) segments, targets (G,)."""
    h = forward(params, batch["feats"], batch["coords"], batch["src"], batch["dst"], cfg)
    g = int(batch["targets"].shape[0])
    pooled = jax.ops.segment_sum(h, batch["graph_id"], num_segments=g)
    pred = _mlp(params["head"], pooled)[:, 0].astype(jnp.float32)
    loss = jnp.mean((pred - batch["targets"].astype(jnp.float32)) ** 2)
    return loss, {"mse": loss}


def loss_fn(params, batch, cfg: EGNNConfig):
    if cfg.task == "graph_reg":
        return graph_reg_loss(params, batch, cfg)
    return node_class_loss(params, batch, cfg)
