"""Shared layers: RMSNorm, RoPE, cross-entropy."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x (..., S, H, D) with positions pos (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                               # (D/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs           # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask=None) -> jnp.ndarray:
    """logits (..., V) fp32-accumulated token-mean cross entropy."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
