"""RecSys models: DLRM (RM2), Wide&Deep, DIN, DIEN.

Common substrate: huge sparse embedding tables (row-sharded over "model" via
models.embedding) -> feature interaction (dot / concat / target-attention /
AUGRU) -> small MLP.  Four shapes per arch: train_batch (BCE loss),
serve_p99 / serve_bulk (forward), retrieval_cand (1 query vs 10^6 candidates,
batched scoring + global top-k — never a loop).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from . import embedding as emb
from .specs import P, abstract_params, axes_tree, init_params


@dataclasses.dataclass(frozen=True)
class RecConfig:
    name: str
    model: str                        # dlrm | wide_deep | din | dien
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    table_rows: int = 1 << 20
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256, 1)
    mlp: tuple = (200, 80)
    attn_mlp: tuple = (80, 40)
    seq_len: int = 100
    gru_dim: int = 108
    item_vocab: int = 1 << 20
    cate_vocab: int = 1 << 14
    n_profile: int = 4
    profile_vocab: int = 1 << 16
    dtype: Any = jnp.float32

    @property
    def pair_dim(self) -> int:        # din/dien: item+cate concat
        return 2 * self.embed_dim


def _mlp_specs(d_in: int, dims: tuple, prefix: str = "") -> dict:
    out = {}
    cur = d_in
    for i, d in enumerate(dims):
        out[f"w{i}"] = P((cur, d), ("embed", "mlp" if d >= 256 else None))
        out[f"b{i}"] = P((d,), (None,), "zeros")
        cur = d
    return out


def _mlp(p, x, n: int, final_act: bool = False):
    for i in range(n):
        x = x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _gru_specs(d_in: int, d_h: int) -> dict:
    return {
        "wx": P((d_in, 3 * d_h), ("embed", None)),
        "wh": P((d_h, 3 * d_h), ("embed", None)),
        "b": P((3 * d_h,), (None,), "zeros"),
    }


def _gru_cell(p, h, xt, a=None):
    """GRU step; a (B,1) in [0,1] scales the update gate (AUGRU, DIEN)."""
    d_h = h.shape[-1]
    gx = xt @ p["wx"].astype(xt.dtype)
    gh = h @ p["wh"].astype(h.dtype)
    zr_x, n_x = gx[..., : 2 * d_h], gx[..., 2 * d_h:]
    zr_h, n_h = gh[..., : 2 * d_h], gh[..., 2 * d_h:]
    zr = jax.nn.sigmoid(zr_x + zr_h + p["b"][: 2 * d_h].astype(h.dtype))
    z, r = zr[..., :d_h], zr[..., d_h:]
    n = jnp.tanh(n_x + r * n_h + p["b"][2 * d_h:].astype(h.dtype))
    if a is not None:
        z = a * z
    return (1.0 - z) * h + z * n


def _gru_scan(p, x, mask, a=None):
    """x (B, L, D) -> final hidden (B, H) (masked positions keep state)."""
    b, l, _ = x.shape
    d_h = p["wh"].shape[0]
    xs = jnp.moveaxis(x, 1, 0)
    ms = jnp.moveaxis(mask, 1, 0)
    as_ = jnp.moveaxis(a, 1, 0) if a is not None else None

    def step(h, inp):
        if as_ is None:
            xt, mt = inp
            hn = _gru_cell(p, h, xt)
        else:
            xt, mt, at = inp
            hn = _gru_cell(p, h, xt, at[:, None])
        h = jnp.where(mt[:, None], hn, h)
        return h, h

    inps = (xs, ms) if as_ is None else (xs, ms, as_)
    h, hs = jax.lax.scan(step, jnp.zeros((b, d_h), x.dtype), inps)
    return h, jnp.moveaxis(hs, 0, 1)


# --------------------------------------------------------------------------- #
# param specs
# --------------------------------------------------------------------------- #


def param_specs(cfg: RecConfig) -> dict:
    d = cfg.embed_dim
    if cfg.model == "dlrm":
        n_feat = cfg.n_sparse + 1
        n_pairs = n_feat * (n_feat - 1) // 2
        return {
            "tables": P((cfg.n_sparse, cfg.table_rows, d), (None, "table_rows", None), "embed"),
            "bot": _mlp_specs(cfg.n_dense, cfg.bot_mlp),
            "top": _mlp_specs(cfg.bot_mlp[-1] + n_pairs, cfg.top_mlp),
        }
    if cfg.model == "wide_deep":
        return {
            "tables": P((cfg.n_sparse, cfg.table_rows, d), (None, "table_rows", None), "embed"),
            "wide": P((cfg.n_sparse, cfg.table_rows, 1), (None, "table_rows", None), "embed"),
            "deep": _mlp_specs(cfg.n_sparse * d, cfg.top_mlp),
        }
    # din / dien
    pair = cfg.pair_dim
    specs = {
        "item_table": P((cfg.item_vocab, d), ("table_rows", None), "embed"),
        "cate_table": P((cfg.cate_vocab, d), ("table_rows", None), "embed"),
        "profile_tables": P((cfg.n_profile, cfg.profile_vocab, d), (None, "table_rows", None), "embed"),
    }
    head_in = 3 * pair + cfg.n_profile * d
    if cfg.model == "din":
        specs["attn"] = _mlp_specs(4 * pair, cfg.attn_mlp + (1,))
        specs["head"] = _mlp_specs(head_in, cfg.mlp + (1,))
    else:  # dien
        specs["gru1"] = _gru_specs(pair, cfg.gru_dim)
        specs["augru"] = _gru_specs(cfg.gru_dim, cfg.gru_dim)
        specs["t_proj"] = P((pair, cfg.gru_dim), ("embed", None))
        specs["attn"] = _mlp_specs(2 * cfg.gru_dim, cfg.attn_mlp + (1,))
        specs["head"] = _mlp_specs(cfg.gru_dim + 2 * pair + cfg.n_profile * d, cfg.mlp + (1,))
    return specs


def init(cfg: RecConfig, key):
    return init_params(param_specs(cfg), key)


def abstract(cfg: RecConfig):
    return abstract_params(param_specs(cfg))


def axes(cfg: RecConfig):
    return axes_tree(param_specs(cfg))


# --------------------------------------------------------------------------- #
# forwards
# --------------------------------------------------------------------------- #


def _dlrm_forward(params, batch, cfg: RecConfig):
    dense = batch["dense"].astype(cfg.dtype)
    v = _mlp(params["bot"], dense, len(cfg.bot_mlp), final_act=True)      # (B, d)
    e = emb.lookup_stacked(params["tables"], batch["sparse"])             # (B, T, d)
    z = jnp.concatenate([v[:, None, :], e.astype(cfg.dtype)], axis=1)     # (B, T+1, d)
    zz = jnp.einsum("bid,bjd->bij", z, z)
    n = z.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    pairs = zz[:, iu, ju]                                                 # (B, n(n-1)/2)
    top_in = jnp.concatenate([v, pairs], axis=-1)
    return _mlp(params["top"], top_in, len(cfg.top_mlp))[:, 0]


def _wide_deep_forward(params, batch, cfg: RecConfig):
    ids = batch["sparse"]
    e = emb.lookup_stacked(params["tables"], ids).astype(cfg.dtype)       # (B, T, d)
    wide = emb.lookup_stacked(params["wide"], ids).astype(cfg.dtype)      # (B, T, 1)
    deep_in = e.reshape(e.shape[0], -1)
    deep = _mlp(params["deep"], deep_in, len(cfg.top_mlp))[:, 0]
    return deep + wide.sum(axis=(1, 2))


def _din_user_vec(params, hist, target, mask, cfg: RecConfig):
    """Target attention (DIN): hist (B,L,P), target (B,P) -> (B,P)."""
    t = jnp.broadcast_to(target[:, None, :], hist.shape)
    feat = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = _mlp(params["attn"], feat, len(cfg.attn_mlp) + 1)[..., 0]         # (B, L)
    w = w * mask.astype(w.dtype)
    return jnp.einsum("bl,blp->bp", w, hist)


def _hist_embed(params, batch, cfg: RecConfig):
    hi = emb.lookup(params["item_table"], batch["hist_items"]).astype(cfg.dtype)
    hc = emb.lookup(params["cate_table"], batch["hist_cates"]).astype(cfg.dtype)
    hist = jnp.concatenate([hi, hc], axis=-1)                             # (B, L, P)
    ti = emb.lookup(params["item_table"], batch["target_item"]).astype(cfg.dtype)
    tc = emb.lookup(params["cate_table"], batch["target_cate"]).astype(cfg.dtype)
    target = jnp.concatenate([ti, tc], axis=-1)                           # (B, P)
    prof = emb.lookup_stacked(params["profile_tables"], batch["profile"]).astype(cfg.dtype)
    prof = prof.reshape(prof.shape[0], -1)                                # (B, n_profile*d)
    mask = jnp.arange(batch["hist_items"].shape[1])[None, :] < batch["hist_len"][:, None]
    return hist, target, prof, mask


def _din_forward(params, batch, cfg: RecConfig):
    hist, target, prof, mask = _hist_embed(params, batch, cfg)
    user = _din_user_vec(params, hist, target, mask, cfg)
    x = jnp.concatenate([user, target, user * target, prof], axis=-1)
    return _mlp(params["head"], x, len(cfg.mlp) + 1)[:, 0]


def _dien_forward(params, batch, cfg: RecConfig):
    hist, target, prof, mask = _hist_embed(params, batch, cfg)
    _, hs = _gru_scan(params["gru1"], hist, mask)                         # (B, L, H)
    tproj = (target @ params["t_proj"].astype(target.dtype))[:, None, :]  # (B,1,H)
    feat = jnp.concatenate([hs, jnp.broadcast_to(tproj, hs.shape)], axis=-1)
    scores = _mlp(params["attn"], feat, len(cfg.attn_mlp) + 1)[..., 0]
    scores = jnp.where(mask, scores, -1e30)
    a = jax.nn.softmax(scores, axis=-1) * mask.astype(scores.dtype)       # (B, L)
    hfinal, _ = _gru_scan(params["augru"], hs, mask, a=a)
    x = jnp.concatenate([hfinal, target, target, prof], axis=-1)
    return _mlp(params["head"], x, len(cfg.mlp) + 1)[:, 0]


FORWARDS = {
    "dlrm": _dlrm_forward,
    "wide_deep": _wide_deep_forward,
    "din": _din_forward,
    "dien": _dien_forward,
}


def forward(params, batch, cfg: RecConfig):
    logit = FORWARDS[cfg.model](params, batch, cfg)
    return shard(logit, "batch")


def loss_fn(params, batch, cfg: RecConfig):
    logit = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss, {"bce": loss}


def serve(params, batch, cfg: RecConfig):
    return jax.nn.sigmoid(forward(params, batch, cfg))


# --------------------------------------------------------------------------- #
# retrieval scoring: 1 query vs n_candidates, batched + global top-k
# --------------------------------------------------------------------------- #


def retrieval_topk(params, batch, cfg: RecConfig, k: int = 100):
    """batch carries the single query context + candidate ids (C,).

    Candidate tensors are model-axis shardable ("candidates" rule); scoring is
    one batched forward, never a loop.
    """
    cand = batch["cand_items"]                                            # (C,)
    c = cand.shape[0]
    k = min(k, c)
    if cfg.model in ("din", "dien"):
        q = {kk: jnp.broadcast_to(v, (c,) + v.shape[1:]) for kk, v in batch.items()
             if kk in ("hist_items", "hist_cates", "hist_len", "profile")}
        q["target_item"] = cand
        q["target_cate"] = batch["cand_cates"]
        logit = FORWARDS[cfg.model](params, q, cfg)
    elif cfg.model == "dlrm":
        sparse = jnp.broadcast_to(batch["sparse"], (c, cfg.n_sparse)).at[:, 0].set(cand)
        dense = jnp.broadcast_to(batch["dense"], (c, cfg.n_dense))
        logit = _dlrm_forward(params, {"dense": dense, "sparse": sparse}, cfg)
    else:
        sparse = jnp.broadcast_to(batch["sparse"], (c, cfg.n_sparse)).at[:, 0].set(cand)
        logit = _wide_deep_forward(params, {"sparse": sparse}, cfg)
    logit = shard(logit, "candidates")
    scores, idx = jax.lax.top_k(logit, k)
    return scores, cand[idx]
