"""Decoder-only LM covering the assigned architectures:

  * GQA dense (starcoder2-3b/7b, smollm-135m)
  * MLA + fine-grained MoE with shared experts (deepseek-v2-lite)
  * GQA + SWA + MoE (mixtral-8x22b)

One code path, three entry points: ``loss_fn`` (training), ``prefill``
(build KV caches for a full sequence), ``decode_step`` (one token against a
cache).  Layers are scanned (stacked params) with rematerialization; logits /
cross-entropy are computed in sequence chunks so the (B, S, V) tensor never
materializes.  All sharding is via logical axes (distributed.sharding).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from . import attention as attn_lib
from . import moe as moe_lib
from .common import apply_rope, cross_entropy, rmsnorm
from .specs import P, abstract_params, axes_tree, init_params, stack_layers


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    attn: str = "gqa"                 # "gqa" | "mla"
    window: Optional[int] = None      # SWA window
    expand_kv: bool = False           # replicate KV heads to full H under TP
                                      # (Megatron behaviour; needed when
                                      # neither KH nor H/KH divides the axis)
    rope_theta: float = 10000.0
    # MLA dims (deepseek-v2-lite)
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # MoE
    n_experts: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0           # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32    # bf16 halves ZeRO-3 gather bytes (HC2)
    q_chunk: Optional[int] = 1024     # None -> kv-scan only (SP-friendly)
    kv_chunk: int = 1024
    loss_chunk: int = 512
    aux_weight: float = 0.01

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.moe else 0

    @property
    def qk_dim(self) -> int:
        return (self.qk_nope + self.qk_rope) if self.attn == "mla" else self.head_dim


# --------------------------------------------------------------------------- #
# parameter specs
# --------------------------------------------------------------------------- #


def _attn_specs(cfg: LMConfig) -> dict:
    d = cfg.d_model
    if cfg.attn == "mla":
        return {
            "wq": P((d, cfg.n_heads, cfg.qk_nope + cfg.qk_rope), ("embed", "heads", None)),
            "w_dkv": P((d, cfg.kv_lora + cfg.qk_rope), ("embed", None)),
            "kv_norm": P((cfg.kv_lora,), (None,), "ones"),
            "w_uk": P((cfg.n_heads, cfg.kv_lora, cfg.qk_nope), ("heads", None, None)),
            "w_uv": P((cfg.n_heads, cfg.kv_lora, cfg.v_head), ("heads", None, None)),
            "wo": P((cfg.n_heads, cfg.v_head, d), ("heads", None, "embed")),
        }
    return {
        "wq": P((d, cfg.n_heads, cfg.head_dim), ("embed", "heads", None)),
        "wk": P((d, cfg.n_kv, cfg.head_dim), ("embed", "kv_heads", None)),
        "wv": P((d, cfg.n_kv, cfg.head_dim), ("embed", "kv_heads", None)),
        "wo": P((cfg.n_heads, cfg.head_dim, d), ("heads", None, "embed")),
    }


def _dense_ffn_specs(cfg: LMConfig, d_ff: int) -> dict:
    d = cfg.d_model
    return {
        "w1": P((d, d_ff), ("embed", "ffn")),
        "w3": P((d, d_ff), ("embed", "ffn")),
        "w2": P((d_ff, d), ("ffn", "embed")),
    }


def _moe_ffn_specs(cfg: LMConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    out = {
        "router": P((d, e), ("embed", None)),
        "w1": P((e, d, f), ("expert", "embed", "ffn_expert")),
        "w3": P((e, d, f), ("expert", "embed", "ffn_expert")),
        "w2": P((e, f, d), ("expert", "ffn_expert", "embed")),
    }
    if cfg.n_shared:
        out["shared"] = _dense_ffn_specs(cfg, cfg.n_shared * f)
    return out


def _layer_specs(cfg: LMConfig, moe: bool) -> dict:
    d = cfg.d_model
    return {
        "attn_norm": P((d,), (None,), "ones"),
        "ffn_norm": P((d,), (None,), "ones"),
        "attn": _attn_specs(cfg),
        "ffn": _moe_ffn_specs(cfg) if moe else _dense_ffn_specs(cfg, cfg.d_ff),
    }


def param_specs(cfg: LMConfig) -> dict:
    specs = {
        "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "final_norm": P((cfg.d_model,), (None,), "ones"),
    }
    n_dense = cfg.n_dense_layers if cfg.moe else cfg.n_layers
    if n_dense:
        specs["dense_layers"] = stack_layers(_layer_specs(cfg, moe=False), n_dense)
    if cfg.n_moe_layers:
        specs["moe_layers"] = stack_layers(_layer_specs(cfg, moe=True), cfg.n_moe_layers)
    if cfg.param_dtype != jnp.float32:
        import dataclasses as _dc
        specs = jax.tree.map(
            lambda s: _dc.replace(s, dtype=cfg.param_dtype), specs,
            is_leaf=lambda x: isinstance(x, P))
    return specs


def init(cfg: LMConfig, key) -> dict:
    return init_params(param_specs(cfg), key)


def abstract(cfg: LMConfig) -> dict:
    return abstract_params(param_specs(cfg))


def axes(cfg: LMConfig) -> dict:
    return axes_tree(param_specs(cfg))


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #


def _gqa_attention(p, h, pos, cfg: LMConfig):
    c = lambda w: w.astype(h.dtype)
    q = jnp.einsum("bsd,dhk->bshk", h, c(p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", h, c(p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", h, c(p["wv"]))
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q = shard(q, "batch", "act_seq_attn", "act_heads", None)
    kv_out = (k, v)
    if cfg.expand_kv and cfg.n_kv != cfg.n_heads:
        rep = cfg.n_heads // cfg.n_kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    o = attn_lib.full_attention(q, k, v, causal=True, window=cfg.window,
                                q_chunk=cfg.q_chunk or 1 << 30, kv_chunk=cfg.kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, c(p["wo"])), kv_out


def _mla_attention(p, h, pos, cfg: LMConfig):
    c = lambda w: w.astype(h.dtype)
    b, s, _ = h.shape
    q = jnp.einsum("bsd,dhk->bshk", h, c(p["wq"]))
    q_nope, q_rope = q[..., : cfg.qk_nope], q[..., cfg.qk_nope:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    lat_all = jnp.einsum("bsd,dl->bsl", h, c(p["w_dkv"]))
    lat = rmsnorm(lat_all[..., : cfg.kv_lora], p["kv_norm"])
    k_rope = apply_rope(lat_all[..., None, cfg.kv_lora:], pos, cfg.rope_theta)  # (B,S,1,Dr)
    k_nope = jnp.einsum("bsl,hln->bshn", lat, c(p["w_uk"]))
    v = jnp.einsum("bsl,hlv->bshv", lat, c(p["w_uv"]))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, cfg.n_heads, cfg.qk_rope))], axis=-1)
    q_full = shard(q_full, "batch", "act_seq_attn", "act_heads", None)
    o = attn_lib.full_attention(q_full, k_full, v, causal=True, window=cfg.window,
                                q_chunk=cfg.q_chunk or 1 << 30, kv_chunk=cfg.kv_chunk)
    return jnp.einsum("bshv,hvd->bsd", o, c(p["wo"])), (lat, k_rope[:, :, 0, :])


def _dense_ffn(p, h):
    c = lambda w: w.astype(h.dtype)
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, c(p["w1"])))
    up = jnp.einsum("bsd,df->bsf", h, c(p["w3"]))
    hidden = shard(gate * up, "batch", "act_seq_ffn", "act_ffn")
    return jnp.einsum("bsf,fd->bsd", hidden, c(p["w2"]))


def _moe_ffn(p, h, cfg: LMConfig):
    out, aux = moe_lib.moe_ffn(
        h, p["router"], p["w1"], p["w3"], p["w2"],
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
    if cfg.n_shared:
        out = out + _dense_ffn(p["shared"], h)
    return out, aux


def _layer(p, x, pos, cfg: LMConfig, moe: bool, collect_cache: bool):
    h = rmsnorm(x, p["attn_norm"])
    attn_fn = _mla_attention if cfg.attn == "mla" else _gqa_attention
    a, kv = attn_fn(p["attn"], h, pos, cfg)
    x = shard(x + a, "batch", "act_seq", "act_embed")
    h = rmsnorm(x, p["ffn_norm"])
    if moe:
        f, aux = _moe_ffn(p["ffn"], h, cfg)
    else:
        f, aux = _dense_ffn(p["ffn"], h), jnp.float32(0.0)
    x = shard(x + f, "batch", "act_seq", "act_embed")
    return x, aux, (kv if collect_cache else None)


# --------------------------------------------------------------------------- #
# trunk / loss
# --------------------------------------------------------------------------- #


def _run_stack(params_stack, x, pos, cfg: LMConfig, moe: bool, collect_cache: bool):
    def body(carry, lp):
        y, aux, cache = _layer(lp, carry, pos, cfg, moe, collect_cache)
        return y, (aux, cache) if collect_cache else (aux, 0)

    body = jax.checkpoint(body, prevent_cse=False)
    x, (auxs, caches) = jax.lax.scan(body, x, params_stack)
    return x, jnp.sum(auxs), caches


def trunk(params, tokens, cfg: LMConfig, collect_cache: bool = False):
    """tokens (B, S) -> final-normed activations (B, S, D) [+ caches]."""
    s = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = shard(x, "batch", "act_seq", "act_embed")
    pos = jnp.arange(s)
    aux_total = jnp.float32(0.0)
    caches = {}
    if "dense_layers" in params:
        x, aux, c = _run_stack(params["dense_layers"], x, pos, cfg, False, collect_cache)
        aux_total += aux
        caches["dense"] = c
    if "moe_layers" in params:
        x, aux, c = _run_stack(params["moe_layers"], x, pos, cfg, True, collect_cache)
        aux_total += aux
        caches["moe"] = c
    x = rmsnorm(x, params["final_norm"])
    return x, aux_total, caches


def loss_fn(params, tokens, labels, cfg: LMConfig):
    """Chunked cross entropy: the (B,S,V) logits tensor never materializes."""
    x, aux, _ = trunk(params, tokens, cfg)
    b, s, d = x.shape
    ck = min(cfg.loss_chunk, s)
    while s % ck:
        ck -= 1
    xc = jnp.moveaxis(x.reshape(b, s // ck, ck, d), 1, 0)
    yc = jnp.moveaxis(labels.reshape(b, s // ck, ck), 1, 0)
    emb = params["embed"]

    def step(tot, inp):
        xs, ys = inp
        logits = jnp.einsum("bcd,vd->bcv", xs, emb.astype(xs.dtype))
        logits = shard(logits, "batch", None, "vocab")
        lz = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32), ys[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lz - gold), None

    tot, _ = jax.lax.scan(step, jnp.float32(0.0), (xc, yc))
    loss = tot / (b * s)
    return loss + cfg.aux_weight * aux, {"ce": loss, "aux": aux}


# --------------------------------------------------------------------------- #
# serving: prefill + decode
# --------------------------------------------------------------------------- #


def cache_spec(cfg: LMConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs of the decode cache (for input_specs / allocation)."""
    eff = min(cache_len, cfg.window) if cfg.window else cache_len
    l = cfg.n_layers
    if cfg.attn == "mla":
        return {
            "lat": jax.ShapeDtypeStruct((l, batch, eff, cfg.kv_lora), cfg.dtype),
            "rope": jax.ShapeDtypeStruct((l, batch, eff, cfg.qk_rope), cfg.dtype),
        }
    return {
        "k": jax.ShapeDtypeStruct((l, batch, eff, cfg.n_kv, cfg.head_dim), cfg.dtype),
        "v": jax.ShapeDtypeStruct((l, batch, eff, cfg.n_kv, cfg.head_dim), cfg.dtype),
    }


def cache_axes(cfg: LMConfig):
    if cfg.attn == "mla":
        return {"lat": (None, "batch", "act_cache", None),
                "rope": (None, "batch", "act_cache", None)}
    return {"k": (None, "batch", "act_cache", "kv_heads", None),
            "v": (None, "batch", "act_cache", "kv_heads", None)}


def prefill(params, tokens, cfg: LMConfig):
    """Full-sequence forward; returns last-position logits + stacked caches."""
    x, _, caches = trunk(params, tokens, cfg, collect_cache=True)
    last = x[:, -1, :]
    logits = jnp.einsum("bd,vd->bv", last, params["embed"].astype(x.dtype))
    stacked = _merge_cache_stacks(caches, cfg)
    if cfg.window:  # keep only the trailing window (ring layout, slot = pos % W)
        s = tokens.shape[1]
        w = min(cfg.window, s)
        slots = (jnp.arange(s - w, s)) % w

        def ring(c):
            tail = c[:, :, -w:]
            return jnp.zeros_like(tail).at[:, :, slots].set(tail)

        stacked = jax.tree.map(ring, stacked)
    return logits, stacked


def _merge_cache_stacks(caches, cfg: LMConfig):
    """Concatenate dense-stack and moe-stack caches into (L, B, S, ...)."""
    parts = [c for c in (caches.get("dense"), caches.get("moe")) if c is not None]
    names = ("lat", "rope") if cfg.attn == "mla" else ("k", "v")
    out = {}
    for i, name in enumerate(names):
        arrs = [p[i] for p in parts]
        # scan ys come out (L, B, S, ...) already; kv from _gqa is (B,S,KH,hd)
        out[name] = jnp.concatenate(arrs, axis=0) if len(arrs) > 1 else arrs[0]
    return out


def decode_step(params, cache, token, pos, cfg: LMConfig):
    """One-token decode. token (B,) int32; pos: scalar int32 count of cached
    positions.  Returns (logits (B,V), updated cache)."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)[:, None, :]
    x = shard(x, "batch", None, "act_embed")
    w = cache[next(iter(cache))].shape[2]
    slot = (pos % w) if cfg.window else pos
    pos_arr = jnp.full((b, 1), pos, jnp.int32)

    n_dense = cfg.n_dense_layers if cfg.moe else cfg.n_layers

    def layer_at(stack_name, li, x, cache):
        lp = jax.tree.map(lambda a: a[li], params[stack_name])
        moe = stack_name == "moe_layers"
        h = rmsnorm(x, lp["attn_norm"])
        c = lambda wgt: wgt.astype(h.dtype)
        gi = li if stack_name == "dense_layers" else li + n_dense
        if cfg.attn == "mla":
            ap = lp["attn"]
            q = jnp.einsum("bsd,dhk->bshk", h, c(ap["wq"]))
            q_nope, q_rope = q[..., : cfg.qk_nope], q[..., cfg.qk_nope:]
            q_rope = apply_rope(q_rope, pos_arr, cfg.rope_theta)
            lat_all = jnp.einsum("bsd,dl->bsl", h, c(ap["w_dkv"]))
            lat = rmsnorm(lat_all[..., : cfg.kv_lora], ap["kv_norm"])
            k_rope = apply_rope(lat_all[..., None, cfg.kv_lora:], pos_arr, cfg.rope_theta)[:, :, 0]
            lat_c = jax.lax.dynamic_update_slice(cache["lat"], lat[None].astype(cfg.dtype),
                                                 (gi, 0, slot, 0))
            rope_c = jax.lax.dynamic_update_slice(cache["rope"], k_rope[None].astype(cfg.dtype),
                                                  (gi, 0, slot, 0))
            cache = {"lat": lat_c, "rope": rope_c}
            o = attn_lib.mla_decode_attention(
                q_nope[:, 0], q_rope[:, 0], lat_c[gi], rope_c[gi],
                jnp.minimum(pos + 1, w), ap["w_uk"].astype(cfg.dtype), ap["w_uv"].astype(cfg.dtype))
            a = jnp.einsum("bshv,hvd->bsd", o, c(ap["wo"]))
        else:
            ap = lp["attn"]
            q = apply_rope(jnp.einsum("bsd,dhk->bshk", h, c(ap["wq"])), pos_arr, cfg.rope_theta)
            k = apply_rope(jnp.einsum("bsd,dhk->bshk", h, c(ap["wk"])), pos_arr, cfg.rope_theta)
            v = jnp.einsum("bsd,dhk->bshk", h, c(ap["wv"]))
            k_c = jax.lax.dynamic_update_slice(cache["k"], k[None].astype(cfg.dtype), (gi, 0, slot, 0, 0))
            v_c = jax.lax.dynamic_update_slice(cache["v"], v[None].astype(cfg.dtype), (gi, 0, slot, 0, 0))
            cache = {"k": k_c, "v": v_c}
            o = attn_lib.decode_attention(q, k_c[gi], v_c[gi], jnp.minimum(pos + 1, w),
                                          window=None)  # ring layout already bounds SWA
            a = jnp.einsum("bshk,hkd->bsd", o, c(ap["wo"]))
        x = x + a
        h2 = rmsnorm(x, lp["ffn_norm"])
        if moe:
            f, _ = _moe_ffn(lp["ffn"], h2, cfg)
        else:
            f = _dense_ffn(lp["ffn"], h2)
        return x + f, cache

    if n_dense:
        def dense_body(li, carry):
            x, cache = carry
            return layer_at("dense_layers", li, x, cache)
        x, cache = jax.lax.fori_loop(0, n_dense, dense_body, (x, cache))
    if cfg.n_moe_layers:
        def moe_body(li, carry):
            x, cache = carry
            return layer_at("moe_layers", li, x, cache)
        x, cache = jax.lax.fori_loop(0, cfg.n_moe_layers, moe_body, (x, cache))

    x = rmsnorm(x, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"].astype(x.dtype))
    return shard(logits, "batch", "vocab"), cache
