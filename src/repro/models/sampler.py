"""Host-side CSR neighbor sampler for sampled-subgraph GNN training
(GraphSAGE-style fanout, used by the egnn `minibatch_lg` shape).

Produces fixed-size padded subgraphs (static shapes for jit): for a seed
batch B and fanouts (f1, f2), layer-0 nodes = B, layer-1 <= B*f1, layer-2 <=
B*f1*f2; edges <= B*f1 + B*f1*f2.  Padding uses a sentinel node whose
features are zero and which receives no loss.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray            # (N+1,) int64
    indices: np.ndarray           # (E,) int32 — sorted per row (d-gap friendly)
    n_nodes: int

    @staticmethod
    def random(n_nodes: int, n_edges: int, seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRGraph(indptr, dst.astype(np.int32), n_nodes)


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanouts: tuple,
                    rng: np.random.Generator):
    """Returns dict of padded arrays: nodes (M,), src, dst (E_max,) (indices
    into the node list), valid edge mask, plus n_seed."""
    layers = [np.asarray(seeds, np.int64)]
    edges = []
    for f in fanouts:
        frontier = layers[-1]
        deg = g.indptr[frontier + 1] - g.indptr[frontier]
        # sample up to f neighbors per frontier node (with replacement when deg>0)
        has = deg > 0
        offs = rng.integers(0, np.maximum(deg, 1)[:, None], (len(frontier), f))
        nbrs = g.indices[(g.indptr[frontier, None] + offs).astype(np.int64)]
        nbrs = np.where(has[:, None], nbrs, -1)
        src = nbrs.reshape(-1)
        dst = np.repeat(np.arange(len(frontier)), f)  # local index into frontier
        edges.append((layers[-1], src, dst))
        layers.append(src[src >= 0])
    # build node list: unique of all layers
    all_nodes = np.concatenate([l for l in layers])
    all_nodes = all_nodes[all_nodes >= 0]
    uniq, inv = np.unique(all_nodes, return_inverse=True)
    remap = {int(n): i for i, n in enumerate(uniq)}
    max_edges = sum(len(l) * f for l, f in zip(layers[:-1], fanouts))
    src_out = np.full(max_edges, len(uniq), np.int32)   # sentinel
    dst_out = np.full(max_edges, len(uniq), np.int32)
    k = 0
    for (frontier, src, dst) in edges:
        ok = src >= 0
        s = np.asarray([remap[int(x)] for x in src[ok]], np.int32)
        d = np.asarray([remap[int(frontier[j])] for j in dst[ok]], np.int32)
        src_out[k:k + len(s)] = s
        dst_out[k:k + len(d)] = d
        k += len(s)
    return {
        "nodes": uniq.astype(np.int64),
        "src": src_out, "dst": dst_out,
        "edge_valid": (src_out < len(uniq)),
        "n_seed": len(seeds),
    }
