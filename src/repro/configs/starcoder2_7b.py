"""starcoder2-7b [arXiv:2402.19173; hf]: 32L d_model=4608 36H (GQA kv=4)
d_ff=18432 vocab=49152, RoPE.  long_500k skipped (pure full attention)."""

import jax.numpy as jnp

from repro.models.transformer import LMConfig
from .base import ArchSpec, lm_batch_axes, lm_input_specs, lm_plan_for, lm_shapes


def make_config() -> LMConfig:
    return LMConfig(
        name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
        n_kv=4, head_dim=128, d_ff=18432, vocab=49152,
        dtype=jnp.bfloat16, q_chunk=None, kv_chunk=1024,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="starcoder2-7b-smoke", n_layers=2, d_model=72, n_heads=6,
        n_kv=2, head_dim=12, d_ff=144, vocab=512,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="starcoder2-7b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ok=False),
    plan_for=lm_plan_for(dense=True),
    input_specs=lm_input_specs, batch_axes=lm_batch_axes,
)
