"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M]: 30L d_model=576 9H (GQA kv=3)
d_ff=1536 vocab=49152, llama-arch.  long_500k skipped (full attention)."""

import jax.numpy as jnp

from repro.models.transformer import LMConfig
from .base import ArchSpec, lm_batch_axes, lm_input_specs, lm_plan_for, lm_shapes


def make_config() -> LMConfig:
    return LMConfig(
        name="smollm-135m", n_layers=30, d_model=576, n_heads=9,
        n_kv=3, head_dim=64, d_ff=1536, vocab=49152,
        dtype=jnp.bfloat16, q_chunk=None, kv_chunk=1024,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="smollm-smoke", n_layers=2, d_model=48, n_heads=3,
        n_kv=3, head_dim=16, d_ff=96, vocab=512,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="smollm-135m", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ok=False),
    plan_for=lm_plan_for(dense=True),
    input_specs=lm_input_specs, batch_axes=lm_batch_axes,
)
