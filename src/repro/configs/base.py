"""Architecture registry: config + shapes + sharding plan + input specs.

Every assigned architecture contributes an ``ArchSpec`` (one module per arch,
``ARCH`` symbol).  A *cell* is (arch x shape); ``input_specs`` returns
ShapeDtypeStruct stand-ins (no allocation) and ``batch_axes`` the logical
sharding axes for each input leaf — everything the dry-run needs to lower
``step_fn`` on the production meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shlib

I32 = jnp.int32
F32 = jnp.float32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                      # train | prefill | decode | serve | retrieval
    dims: dict
    skip_reason: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # lm | gnn | recsys
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict
    plan_for: Callable[[Any, ShapeCell], shlib.Plan]
    input_specs: Callable[[Any, ShapeCell], dict]
    batch_axes: Callable[[Any, ShapeCell], dict]
    notes: str = ""
    # per-cell config adaptation (e.g. egnn d_feat/classes differ per graph)
    config_for_cell: Callable[[Any, ShapeCell], Any] = lambda cfg, cell: cfg


# --------------------------------------------------------------------------- #
# LM family shared machinery
# --------------------------------------------------------------------------- #

LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeCell("decode_32k", "decode", {"seq": 32768, "batch": 128}),
    "long_500k": ShapeCell("long_500k", "decode", {"seq": 524288, "batch": 1}),
}


def lm_shapes(long_ok: bool, skip_note: str = "") -> dict:
    out = dict(LM_SHAPES)
    if not long_ok:
        out["long_500k"] = dataclasses.replace(
            out["long_500k"],
            skip_reason=skip_note or "pure full attention: 500k decode has no "
            "sub-quadratic mechanism in the assigned config (DESIGN.md §5)")
    return out


def lm_input_specs(cfg, cell: ShapeCell) -> dict:
    from repro.models import transformer as T
    b, s = cell.dims["batch"], cell.dims["seq"]
    if cell.kind == "train":
        return {"tokens": sds((b, s), I32), "labels": sds((b, s), I32)}
    if cell.kind == "prefill":
        return {"tokens": sds((b, s), I32)}
    # decode: one token against a cache of length s
    return {
        "token": sds((b,), I32),
        "pos": sds((), I32),
        "cache": T.cache_spec(cfg, b, s),
    }


def lm_batch_axes(cfg, cell: ShapeCell) -> dict:
    from repro.models import transformer as T
    if cell.kind == "train":
        return {"tokens": ("batch", None), "labels": ("batch", None)}
    if cell.kind == "prefill":
        return {"tokens": ("batch", None)}
    return {
        "token": ("batch",),
        "pos": (),
        "cache": T.cache_axes(cfg),
    }


def lm_plan_for(dense: bool):
    def plan(cfg, cell: ShapeCell):
        if cell.kind in ("decode",):
            return shlib.lm_serve_plan(dense=dense)
        if dense:
            return shlib.lm_dense_plan()
        expert_parallel = cfg.n_experts >= 16
        # capacity-parallel measured WORSE than TP once the score-sharding
        # fix landed (wire 6.10e12 vs 5.13e12 B/chip on mixtral train —
        # EXPERIMENTS §Perf HC2 iter 4, refuted); kept as an option.
        return shlib.lm_moe_plan(expert_parallel, capacity_parallel=False)
    return plan


# --------------------------------------------------------------------------- #
# step functions (lowered by the dry-run and used by launch/train|serve)
# --------------------------------------------------------------------------- #


def lm_step_fn(cfg, cell: ShapeCell, opt_cfg=None):
    from repro.models import transformer as T
    from repro.optim import AdamWConfig
    from repro.runtime.trainer import make_train_step
    if cell.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()

        def loss(params, batch):
            return T.loss_fn(params, batch["tokens"], batch["labels"], cfg)

        ax = T.axes(cfg)

        def grads_like_params(grads):
            # grads inherit param shardings -> GSPMD reduce-scatters instead
            # of all-reducing full fp32 weight grads (§Perf HC2 iteration 2)
            return jax.tree.map(lambda g, a: shlib.shard(g, *a), grads, ax)

        return make_train_step(loss, opt_cfg, grad_transform=grads_like_params), True
    if cell.kind == "prefill":
        def prefill(params, batch):
            return T.prefill(params, batch["tokens"], cfg)
        return prefill, False

    def decode(params, batch):
        return T.decode_step(params, batch["cache"], batch["token"], batch["pos"], cfg)
    return decode, False


def gnn_step_fn(cfg, cell: ShapeCell, opt_cfg=None):
    from repro.models import egnn as E
    from repro.optim import AdamWConfig
    from repro.runtime.trainer import make_train_step
    opt_cfg = opt_cfg or AdamWConfig()

    def loss(params, batch):
        return E.loss_fn(params, batch, cfg)

    return make_train_step(loss, opt_cfg), True


def recsys_step_fn(cfg, cell: ShapeCell, opt_cfg=None):
    from repro.models import recsys as R
    from repro.optim import AdamWConfig
    from repro.runtime.trainer import make_train_step
    if cell.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()

        def loss(params, batch):
            return R.loss_fn(params, batch, cfg)

        return make_train_step(loss, opt_cfg), True
    if cell.kind == "retrieval":
        def retr(params, batch):
            return R.retrieval_topk(params, batch, cfg, k=100)
        return retr, False

    def serve_fn(params, batch):
        return R.serve(params, batch, cfg)
    return serve_fn, False


STEP_FNS = {"lm": lm_step_fn, "gnn": gnn_step_fn, "recsys": recsys_step_fn}


# --------------------------------------------------------------------------- #
# recsys shared shapes/specs
# --------------------------------------------------------------------------- #

RECSYS_SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeCell("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1_000_000}),
}


def recsys_input_specs(cfg, cell: ShapeCell) -> dict:
    b = cell.dims["batch"]
    if cfg.model in ("dlrm", "wide_deep"):
        specs = {"sparse": sds((b, cfg.n_sparse), I32)}
        if cfg.model == "dlrm":
            specs["dense"] = sds((b, cfg.n_dense), F32)
    else:
        specs = {
            "target_item": sds((b,), I32), "target_cate": sds((b,), I32),
            "hist_items": sds((b, cfg.seq_len), I32),
            "hist_cates": sds((b, cfg.seq_len), I32),
            "hist_len": sds((b,), I32),
            "profile": sds((b, cfg.n_profile), I32),
        }
    if cell.kind == "train":
        specs["label"] = sds((b,), I32)
    if cell.kind == "retrieval":
        c = cell.dims["n_candidates"]
        specs["cand_items"] = sds((c,), I32)
        if cfg.model in ("din", "dien"):
            specs["cand_cates"] = sds((c,), I32)
    return specs


def recsys_batch_axes(cfg, cell: ShapeCell) -> dict:
    specs = recsys_input_specs(cfg, cell)
    out = {}
    for k, v in specs.items():
        if k.startswith("cand_"):
            out[k] = ("candidates",) + (None,) * (len(v.shape) - 1)
        else:
            out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def recsys_plan_for(cfg, cell: ShapeCell):
    return shlib.recsys_plan()
