"""Architecture registry: the 10 assigned architectures (``--arch <id>``)."""

from . import (deepseek_v2_lite_16b, dien, din, dlrm_rm2, egnn, mixtral_8x22b,
               smollm_135m, starcoder2_3b, starcoder2_7b, wide_deep)

ARCHS = {
    m.ARCH.arch_id: m.ARCH
    for m in (deepseek_v2_lite_16b, mixtral_8x22b, starcoder2_3b,
              starcoder2_7b, smollm_135m, egnn, din, wide_deep, dlrm_rm2, dien)
}


def get(arch_id: str):
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = True):
    """Yield (arch_id, shape_name, cell) for the full 40-cell matrix."""
    for aid, spec in ARCHS.items():
        for sname, cell in spec.shapes.items():
            if not include_skipped and cell.skip_reason:
                continue
            yield aid, sname, cell
