"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse features, embed_dim=64,
bot MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction.  Tables:
26 x 2^20 rows (1.7B embedding params), row-sharded over "model" (EP)."""

from repro.models.recsys import RecConfig
from .base import (ArchSpec, RECSYS_SHAPES, recsys_batch_axes,
                   recsys_input_specs, recsys_plan_for)


def make_config() -> RecConfig:
    return RecConfig(
        name="dlrm-rm2", model="dlrm", n_dense=13, n_sparse=26, embed_dim=64,
        table_rows=1 << 20, bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1))


def make_smoke_config() -> RecConfig:
    return RecConfig(
        name="dlrm-smoke", model="dlrm", n_dense=13, n_sparse=6, embed_dim=8,
        table_rows=64, bot_mlp=(16, 8), top_mlp=(16, 8, 1))


ARCH = ArchSpec(
    arch_id="dlrm-rm2", family="recsys",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=RECSYS_SHAPES, plan_for=recsys_plan_for,
    input_specs=recsys_input_specs, batch_axes=recsys_batch_axes,
    notes="multi-hot id bags in the input pipeline are sorted -> d-gapped -> "
          "Group-compressed (paper integration point)",
)
