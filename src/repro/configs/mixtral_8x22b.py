"""mixtral-8x22b [arXiv:2401.04088; hf]: 56L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=32768, 8 experts top-2, SWA (window 4096 per assignment).
long_500k runs: the sliding window caps the KV cache at 4096."""

import jax.numpy as jnp

from repro.models.transformer import LMConfig
from .base import ArchSpec, lm_batch_axes, lm_input_specs, lm_plan_for, lm_shapes


def make_config() -> LMConfig:
    return LMConfig(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv=8, head_dim=128, d_ff=16384, vocab=32768, window=4096,
        n_experts=8, n_shared=0, top_k=2, d_ff_expert=16384, n_dense_layers=0,
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,  # HC2: bf16 ZeRO-3 + fp32 master
        expand_kv=True,  # HC2: 48H/8KV cannot split (8,6) over 16-way TP
        q_chunk=None, kv_chunk=1024,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv=2, head_dim=8, d_ff=128, vocab=512, window=16,
        n_experts=4, n_shared=0, top_k=2, d_ff_expert=32, n_dense_layers=0,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="mixtral-8x22b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ok=True),
    plan_for=lm_plan_for(dense=False),
    input_specs=lm_input_specs, batch_axes=lm_batch_axes,
)
