"""din [arXiv:1706.06978]: embed_dim=18, behaviour seq_len=100, target
attention MLP 80-40, final MLP 200-80."""

from repro.models.recsys import RecConfig
from .base import (ArchSpec, RECSYS_SHAPES, recsys_batch_axes,
                   recsys_input_specs, recsys_plan_for)


def make_config() -> RecConfig:
    return RecConfig(
        name="din", model="din", embed_dim=18, seq_len=100,
        attn_mlp=(80, 40), mlp=(200, 80),
        item_vocab=1 << 20, cate_vocab=1 << 14, n_profile=2,
        profile_vocab=1 << 16, table_rows=1 << 20)


def make_smoke_config() -> RecConfig:
    return RecConfig(
        name="din-smoke", model="din", embed_dim=8, seq_len=10,
        attn_mlp=(8, 4), mlp=(16, 8), item_vocab=128, cate_vocab=32,
        n_profile=2, profile_vocab=32, table_rows=64)


ARCH = ArchSpec(
    arch_id="din", family="recsys",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=RECSYS_SHAPES, plan_for=recsys_plan_for,
    input_specs=recsys_input_specs, batch_axes=recsys_batch_axes,
)
