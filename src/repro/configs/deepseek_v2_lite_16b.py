"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]: 27L d_model=2048 16H MLA
(kv_lora=512, qk_nope=128, qk_rope=64, v_head=128) vocab=102400; MoE: 64
routed experts top-6 + 2 shared, d_ff_expert=1408, first layer dense
(d_ff=10944).  long_500k runs: the MLA latent cache is 576/token."""

import jax.numpy as jnp

from repro.models.transformer import LMConfig
from .base import ArchSpec, lm_batch_axes, lm_input_specs, lm_plan_for, lm_shapes


def make_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
        n_kv=16, head_dim=128, d_ff=10944, vocab=102400, attn="mla",
        kv_lora=512, qk_nope=128, qk_rope=64, v_head=128,
        n_experts=64, n_shared=2, top_k=6, d_ff_expert=1408, n_dense_layers=1,
        dtype=jnp.bfloat16, q_chunk=None, kv_chunk=1024,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv=4, head_dim=16, d_ff=96, vocab=512, attn="mla",
        kv_lora=32, qk_nope=16, qk_rope=8, v_head=16,
        n_experts=8, n_shared=2, top_k=2, d_ff_expert=32, n_dense_layers=1,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="deepseek-v2-lite-16b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ok=True),
    plan_for=lm_plan_for(dense=False),
    input_specs=lm_input_specs, batch_axes=lm_batch_axes,
    notes="assignment lists '2 shared+160 routed' alongside 'MoE 64e top-6'; "
          "the 64-routed figure matches V2-Lite (160 belongs to full V2) and "
          "is used here.",
)
