"""starcoder2-3b [arXiv:2402.19173; hf]: 30L d_model=3072 24H (GQA kv=2)
d_ff=12288 vocab=49152, RoPE.  long_500k skipped (pure full attention)."""

import jax.numpy as jnp

from repro.models.transformer import LMConfig
from .base import ArchSpec, lm_batch_axes, lm_input_specs, lm_plan_for, lm_shapes


def make_config() -> LMConfig:
    return LMConfig(
        name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24,
        n_kv=2, head_dim=128, d_ff=12288, vocab=49152,
        dtype=jnp.bfloat16, q_chunk=None, kv_chunk=1024,
    )


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="starcoder2-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv=2, head_dim=16, d_ff=128, vocab=512,
        dtype=jnp.float32, q_chunk=16, kv_chunk=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="starcoder2-3b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ok=False),
    plan_for=lm_plan_for(dense=True),
    input_specs=lm_input_specs, batch_axes=lm_batch_axes,
)
