"""dien [arXiv:1809.03672]: embed_dim=18, seq_len=100, interest-extractor GRU
(hidden 108) + attentional AUGRU, final MLP 200-80.  (DIEN's auxiliary
next-item loss is omitted — noted in DESIGN.md.)"""

from repro.models.recsys import RecConfig
from .base import (ArchSpec, RECSYS_SHAPES, recsys_batch_axes,
                   recsys_input_specs, recsys_plan_for)


def make_config() -> RecConfig:
    return RecConfig(
        name="dien", model="dien", embed_dim=18, seq_len=100, gru_dim=108,
        attn_mlp=(80, 40), mlp=(200, 80),
        item_vocab=1 << 20, cate_vocab=1 << 14, n_profile=2,
        profile_vocab=1 << 16, table_rows=1 << 20)


def make_smoke_config() -> RecConfig:
    return RecConfig(
        name="dien-smoke", model="dien", embed_dim=8, seq_len=10, gru_dim=12,
        attn_mlp=(8, 4), mlp=(16, 8), item_vocab=128, cate_vocab=32,
        n_profile=2, profile_vocab=32, table_rows=64)


ARCH = ArchSpec(
    arch_id="dien", family="recsys",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=RECSYS_SHAPES, plan_for=recsys_plan_for,
    input_specs=recsys_input_specs, batch_axes=recsys_batch_axes,
)
