"""wide-deep [arXiv:1606.07792]: 40 sparse features, embed_dim=32,
deep MLP 1024-512-256, wide = linear over sparse ids, concat interaction."""

from repro.models.recsys import RecConfig
from .base import (ArchSpec, RECSYS_SHAPES, recsys_batch_axes,
                   recsys_input_specs, recsys_plan_for)


def make_config() -> RecConfig:
    return RecConfig(
        name="wide-deep", model="wide_deep", n_dense=0, n_sparse=40,
        embed_dim=32, table_rows=1 << 20, top_mlp=(1024, 512, 256, 1))


def make_smoke_config() -> RecConfig:
    return RecConfig(
        name="wide-deep-smoke", model="wide_deep", n_dense=0, n_sparse=10,
        embed_dim=8, table_rows=64, top_mlp=(16, 8, 1))


ARCH = ArchSpec(
    arch_id="wide-deep", family="recsys",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=RECSYS_SHAPES, plan_for=recsys_plan_for,
    input_specs=recsys_input_specs, batch_axes=recsys_batch_axes,
)
