"""egnn [arXiv:2102.09844]: 4 layers, d_hidden=64, E(n)-equivariant.

Four graph regimes (kernel_taxonomy §GNN): full-batch small (cora-like),
sampled-subgraph training (reddit-like, real CSR fanout sampler), full-batch
large (ogb-products-like), and batched small graphs (molecule regression).
Message passing is segment_sum over an edge index; edge arrays are padded to
multiples of 512 so they shard evenly over the production meshes; padding
edges point at a sentinel node."""

import jax.numpy as jnp

from repro.models.egnn import EGNNConfig
from repro.distributed import sharding as shlib
from .base import ArchSpec, ShapeCell, sds, I32, F32


def _pad512(n: int) -> int:
    return -(-n // 512) * 512


# fanout 15-10 over 1024 seed nodes
_MB_NODES = 1024 * (1 + 15) + 1024 * 15 * 10 + 1     # + sentinel
_MB_EDGES = 1024 * 15 + 1024 * 15 * 10

SHAPES = {
    "full_graph_sm": ShapeCell("full_graph_sm", "train", {
        "n_nodes": 2708, "n_edges": _pad512(10556), "d_feat": 1433,
        "n_classes": 7, "task": "node_class"}),
    "minibatch_lg": ShapeCell("minibatch_lg", "train", {
        "n_nodes": _pad512(_MB_NODES), "n_edges": _pad512(_MB_EDGES),
        "d_feat": 602, "n_classes": 41, "task": "node_class",
        "graph_nodes": 232965, "graph_edges": 114615892,
        "batch_nodes": 1024, "fanout": (15, 10)}),
    "ogb_products": ShapeCell("ogb_products", "train", {
        "n_nodes": _pad512(2449029), "n_edges": _pad512(61859140),
        "d_feat": 100, "n_classes": 47, "task": "node_class"}),
    "molecule": ShapeCell("molecule", "train", {
        "n_nodes": 30 * 128, "n_edges": 64 * 128, "d_feat": 11,
        "n_graphs": 128, "task": "graph_reg"}),
}


def make_config() -> EGNNConfig:
    return EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_feat=1433,
                      n_classes=47)


def make_smoke_config() -> EGNNConfig:
    return EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_feat=8,
                      n_classes=4)


def config_for_cell(cfg: EGNNConfig, cell: ShapeCell) -> EGNNConfig:
    import dataclasses
    return dataclasses.replace(
        cfg, d_feat=cell.dims["d_feat"],
        n_classes=cell.dims.get("n_classes", cfg.n_classes),
        task=cell.dims["task"])


def input_specs(cfg: EGNNConfig, cell: ShapeCell) -> dict:
    n, e = cell.dims["n_nodes"], cell.dims["n_edges"]
    specs = {
        "feats": sds((n, cell.dims["d_feat"]), F32),
        "coords": sds((n, 3), F32),
        "src": sds((e,), I32),
        "dst": sds((e,), I32),
    }
    if cell.dims["task"] == "node_class":
        specs["labels"] = sds((n,), I32)
        specs["label_mask"] = sds((n,), F32)
    else:
        specs["graph_id"] = sds((n,), I32)
        specs["targets"] = sds((cell.dims["n_graphs"],), F32)
    return specs


def batch_axes(cfg: EGNNConfig, cell: ShapeCell) -> dict:
    ax = {
        "feats": ("nodes", None), "coords": ("nodes", None),
        "src": ("edges",), "dst": ("edges",),
    }
    if cell.dims["task"] == "node_class":
        ax["labels"] = ("nodes",)
        ax["label_mask"] = ("nodes",)
    else:
        ax["graph_id"] = ("nodes",)
        ax["targets"] = ("batch",)
    return ax


def plan_for(cfg: EGNNConfig, cell: ShapeCell) -> shlib.Plan:
    return shlib.gnn_plan()


ARCH = ArchSpec(
    arch_id="egnn", family="gnn",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=SHAPES, plan_for=plan_for,
    input_specs=input_specs, batch_axes=batch_axes,
    config_for_cell=config_for_cell,
    notes="paper technique applies to the adjacency store (d-gapped CSR "
          "columns, Group-compressed in the data pipeline), not the model "
          "math (DESIGN.md §Arch-applicability)",
)
