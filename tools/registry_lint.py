#!/usr/bin/env python
"""Registry lint: fail CI if any codec breaks the Codec protocol contract.

Checks, per registered codec:

  1. required protocol fields are present and well-typed (name, category,
     encode, decode_np, max_bits);
  2. declared capabilities are structurally valid (JaxDecode's three
     callables; every ArenaLayout column named, positively sized, with a
     callable extractor);
  3. every declared ArenaLayout actually honors the fixed-shape contract on a
     smoke input — one padded slice per declared column, dynamic lengths,
     zero padding past ``n_valid`` (the same harness the conformance tests
     use);
  4. every arena capability is covered by the device/host parity sweep: the
     sweep's codec list (``tests/test_device_arena.py::ARENA_CODECS``) must
     be derived from the declarations, so a codec declaring an arena without
     parity coverage (or a hand-pinned test list drifting from the registry)
     fails here;
  5. exception-column consistency: a codec whose encoder stores a non-empty
     ``Encoded.exceptions`` patch stream on a heavy-tailed probe round-trip
     MUST declare an ``"exceptions"`` arena column — otherwise its arena
     decode would silently drop the patches;
  6. score block-max consistency (lint corpus): the ``ScoreArena`` block-max
     tables the ranked top-k prunes with must equal the max over each
     block's stored quantized impacts (and the quantized build-time float
     maxima, and the term-max / stripe range-bound tables) — a drifted
     table would prune blocks whose docs can still reach the top-k.

Run: PYTHONPATH=src python tools/registry_lint.py
"""

from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import codec  # noqa: E402

CATEGORIES = ("bit", "byte", "word", "frame")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def lint_protocol(errors: list) -> None:
    for name in codec.names():
        spec = codec.get(name)
        if spec.name != name:
            _fail(errors, f"{name}: registered under mismatched name {spec.name!r}")
        if spec.category not in CATEGORIES:
            _fail(errors, f"{name}: category {spec.category!r} not in {CATEGORIES}")
        if not callable(spec.encode) or not callable(spec.decode_np):
            _fail(errors, f"{name}: encode/decode_np must be callable")
        if not isinstance(spec.max_bits, int) or not 1 <= spec.max_bits <= 32:
            _fail(errors, f"{name}: max_bits {spec.max_bits!r} outside 1..32")
        if spec.jax is not None:
            for field in ("args", "scalar", "vec"):
                if not callable(getattr(spec.jax, field)):
                    _fail(errors, f"{name}: JaxDecode.{field} not callable")
        if spec.arena is not None:
            lay = spec.arena
            if len(lay.columns) < 2:
                _fail(errors, f"{name}: ArenaLayout declares "
                              f"{len(lay.columns)} column(s); need >= 2")
            for col in lay.columns:
                if not col.name or col.width <= 0 or not callable(col.extract):
                    _fail(errors, f"{name}: ArenaLayout column {col.name!r} "
                                  f"malformed (width {col.width})")
            if min(lay.out_width, lay.max_n) <= 0:
                _fail(errors, f"{name}: ArenaLayout out_width/max_n must be "
                              f"positive")
            if lay.out_width < lay.max_n:
                _fail(errors, f"{name}: out_width {lay.out_width} < max_n {lay.max_n}")
            for field in ("decode_block", "supports"):
                if not callable(getattr(lay, field)):
                    _fail(errors, f"{name}: ArenaLayout.{field} not callable")


def _load(module: str, *relpath: str):
    path = os.path.join(_REPO, *relpath)
    spec = importlib.util.spec_from_file_location(module, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def lint_arena_contract(errors: list) -> None:
    # the ONE arena round-trip harness lives in the conformance tests; lint
    # reuses it on a smoke input so CI and pytest enforce the same contract
    harness = _load("test_codec_protocol", "tests", "test_codec_protocol.py")
    rng = np.random.default_rng(0)
    x = rng.integers(0, 1 << 12, 200, dtype=np.int64).astype(np.uint32)
    for name in codec.names():
        spec = codec.get(name)
        if spec.arena is None:
            continue
        try:
            harness._arena_roundtrip(spec, x)
        except AssertionError as e:
            _fail(errors, f"{name}: arena contract violated: {e}")


def lint_exception_columns(errors: list) -> None:
    """A codec that stores exceptions must declare an arena column for them.

    The probe is heavy-tailed (mostly tiny values, sparse huge outliers) —
    the shape that drives patched codecs (the Group-PFD family) to emit a
    non-empty ``Encoded.exceptions`` stream.  A declared ArenaLayout without
    an ``"exceptions"`` column would decode such blocks with the patches
    silently dropped, so that combination fails the lint.
    """
    rng = np.random.default_rng(5)
    for name in codec.names():
        spec = codec.get(name)
        if spec.arena is None:
            continue
        probe = rng.integers(0, 16, 400, dtype=np.int64).astype(np.uint32)
        probe[::50] = np.uint32(2 ** min(spec.max_bits, 32) - 1)
        enc = spec.encode(probe)
        np.testing.assert_array_equal(spec.decode_np(enc), probe)
        if (enc.exceptions is not None and len(enc.exceptions)
                and not any(c.name == "exceptions"
                            for c in spec.arena.columns)):
            _fail(errors, f"{name}: stores a non-empty exception stream but "
                          f"declares an ArenaLayout without an 'exceptions' "
                          f"column")


def lint_parity_coverage(errors: list) -> None:
    mod = _load("test_device_arena", "tests", "test_device_arena.py")
    declared = {n for n in codec.names() if codec.get(n).arena is not None}
    covered = set(getattr(mod, "ARENA_CODECS", ()))
    for name in sorted(declared - covered):
        _fail(errors, f"{name}: declares an arena capability but is missing "
                      f"from the device/host parity sweep (ARENA_CODECS)")
    for name in sorted(covered - declared):
        _fail(errors, f"{name}: in the parity sweep but declares no arena "
                      f"capability")


def lint_score_tables(errors: list) -> None:
    """WAND metadata soundness on the lint corpus: for every posting block,
    the stored block-max equals the max of the stored quantized impacts
    (== the quantized build-time float maximum: floor is monotone), term-max
    is the max block-max, and the stripe range-bound table dominates every
    posting's code.  Heavy-tailed postings keep the exception-bearing codecs
    honest on the same probe."""
    from repro.index.invindex import InvertedIndex
    from repro.index.scores import ScoreArena, unpack_words_np

    rng = np.random.default_rng(17)
    n_docs = 100_000
    postings = {}
    for t, df in enumerate([12, 64, 300, 513, 900]):
        gaps = rng.integers(1, 8, df).astype(np.int64)
        gaps[rng.random(df) < 0.02] += rng.integers(1 << 8, 1 << 12)
        ids = np.cumsum(gaps)
        assert int(ids[-1]) < n_docs
        postings[t] = (ids.astype(np.uint32),
                       rng.geometric(0.4, df).astype(np.uint32))
    doclen = rng.integers(50, 500, n_docs).astype(np.int64)
    for name in ("group_simple", "group_pfd"):
        idx = InvertedIndex.build(doclen, postings, codec=name)
        sa = ScoreArena.from_index(idx)
        tiles = np.asarray(sa.tiles)
        for t, tp in idx.terms.items():
            per_block = []
            for bi in range(len(tp.blocks)):
                ids, _ = idx.decode_block(t, bi)
                s = sa.slot[(t, bi)]
                codes = unpack_words_np(tiles[s], len(ids))
                stored = int(sa.block_max[s])
                per_block.append(stored)
                if stored != int(codes.max(initial=0)):
                    _fail(errors, f"{name}: score block-max table "
                                  f"[{t},{bi}] = {stored} != max stored "
                                  f"impact {int(codes.max(initial=0))}")
                built = min(int(idx.impact_block_max(t)[bi] / sa.delta), 255)
                if stored != built:
                    _fail(errors, f"{name}: score block-max table "
                                  f"[{t},{bi}] = {stored} != quantized "
                                  f"build-time float max {built}")
                if np.any(sa.stripes[t][ids // sa.stripe_width]
                          < codes.astype(np.int64)):
                    _fail(errors, f"{name}: stripe range-bound table "
                                  f"under-bounds term {t} block {bi}")
            if sa.term_max[t] != max(per_block, default=0):
                _fail(errors, f"{name}: term-max table for term {t} "
                              f"inconsistent with block maxima")


def main() -> int:
    errors: list = []
    lint_protocol(errors)
    lint_arena_contract(errors)
    lint_exception_columns(errors)
    lint_parity_coverage(errors)
    lint_score_tables(errors)
    n_arena = sum(codec.get(n).arena is not None for n in codec.names())
    n_jax = sum(codec.get(n).jax is not None for n in codec.names())
    print(f"registry lint: {len(codec.names())} codecs "
          f"({n_jax} JaxDecode, {n_arena} ArenaLayout), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
