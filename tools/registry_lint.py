#!/usr/bin/env python
"""Registry lint: fail CI if any codec breaks the Codec protocol contract.

Checks, per registered codec:

  1. required protocol fields are present and well-typed (name, category,
     encode, decode_np, max_bits);
  2. declared capabilities are structurally valid (JaxDecode's three
     callables; ArenaLayout's positive padded widths and callables);
  3. every declared ArenaLayout actually honors the fixed-shape contract on a
     smoke input — padded ctrl/data slices, dynamic lengths, zero padding
     past ``n_valid`` (the same harness the conformance tests use);
  4. every arena capability is covered by the device/host parity sweep: the
     sweep's codec list (``tests/test_device_arena.py::ARENA_CODECS``) must
     be derived from the declarations, so a codec declaring an arena without
     parity coverage (or a hand-pinned test list drifting from the registry)
     fails here.

Run: PYTHONPATH=src python tools/registry_lint.py
"""

from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import codec  # noqa: E402

CATEGORIES = ("bit", "byte", "word", "frame")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def lint_protocol(errors: list) -> None:
    for name in codec.names():
        spec = codec.get(name)
        if spec.name != name:
            _fail(errors, f"{name}: registered under mismatched name {spec.name!r}")
        if spec.category not in CATEGORIES:
            _fail(errors, f"{name}: category {spec.category!r} not in {CATEGORIES}")
        if not callable(spec.encode) or not callable(spec.decode_np):
            _fail(errors, f"{name}: encode/decode_np must be callable")
        if not isinstance(spec.max_bits, int) or not 1 <= spec.max_bits <= 32:
            _fail(errors, f"{name}: max_bits {spec.max_bits!r} outside 1..32")
        if spec.jax is not None:
            for field in ("args", "scalar", "vec"):
                if not callable(getattr(spec.jax, field)):
                    _fail(errors, f"{name}: JaxDecode.{field} not callable")
        if spec.arena is not None:
            lay = spec.arena
            if min(lay.ctrl_width, lay.data_width, lay.out_width, lay.max_n) <= 0:
                _fail(errors, f"{name}: ArenaLayout widths must be positive")
            if lay.out_width < lay.max_n:
                _fail(errors, f"{name}: out_width {lay.out_width} < max_n {lay.max_n}")
            for field in ("decode_block", "block_ctrl", "block_data"):
                if not callable(getattr(lay, field)):
                    _fail(errors, f"{name}: ArenaLayout.{field} not callable")


def _load(module: str, *relpath: str):
    path = os.path.join(_REPO, *relpath)
    spec = importlib.util.spec_from_file_location(module, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def lint_arena_contract(errors: list) -> None:
    # the ONE arena round-trip harness lives in the conformance tests; lint
    # reuses it on a smoke input so CI and pytest enforce the same contract
    harness = _load("test_codec_protocol", "tests", "test_codec_protocol.py")
    rng = np.random.default_rng(0)
    x = rng.integers(0, 1 << 12, 200, dtype=np.int64).astype(np.uint32)
    for name in codec.names():
        spec = codec.get(name)
        if spec.arena is None:
            continue
        try:
            harness._arena_roundtrip(spec, x)
        except AssertionError as e:
            _fail(errors, f"{name}: arena contract violated: {e}")


def lint_parity_coverage(errors: list) -> None:
    mod = _load("test_device_arena", "tests", "test_device_arena.py")
    declared = {n for n in codec.names() if codec.get(n).arena is not None}
    covered = set(getattr(mod, "ARENA_CODECS", ()))
    for name in sorted(declared - covered):
        _fail(errors, f"{name}: declares an arena capability but is missing "
                      f"from the device/host parity sweep (ARENA_CODECS)")
    for name in sorted(covered - declared):
        _fail(errors, f"{name}: in the parity sweep but declares no arena "
                      f"capability")


def main() -> int:
    errors: list = []
    lint_protocol(errors)
    lint_arena_contract(errors)
    lint_parity_coverage(errors)
    n_arena = sum(codec.get(n).arena is not None for n in codec.names())
    n_jax = sum(codec.get(n).jax is not None for n in codec.names())
    print(f"registry lint: {len(codec.names())} codecs "
          f"({n_jax} JaxDecode, {n_arena} ArenaLayout), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
