#!/usr/bin/env python
"""Registry lint: fail CI if any codec breaks the Codec protocol contract.

Checks, per registered codec:

  1. required protocol fields are present and well-typed (name, category,
     encode, decode_np, max_bits);
  2. declared capabilities are structurally valid (JaxDecode's three
     callables; every ArenaLayout column named, positively sized, with a
     callable extractor);
  3. every declared ArenaLayout actually honors the fixed-shape contract on a
     smoke input — one padded slice per declared column, dynamic lengths,
     zero padding past ``n_valid`` (the same harness the conformance tests
     use);
  4. every arena capability is covered by the device/host parity sweep: the
     sweep's codec list (``tests/test_device_arena.py::ARENA_CODECS``) must
     be derived from the declarations, so a codec declaring an arena without
     parity coverage (or a hand-pinned test list drifting from the registry)
     fails here;
  5. exception-column consistency: a codec whose encoder stores a non-empty
     ``Encoded.exceptions`` patch stream on a heavy-tailed probe round-trip
     MUST declare an ``"exceptions"`` arena column — otherwise its arena
     decode would silently drop the patches;
  6. score block-max consistency (lint corpus): the ``ScoreArena`` block-max
     tables the ranked top-k prunes with must equal the max over each
     block's stored quantized impacts (and the quantized build-time float
     maxima, and the term-max / stripe range-bound tables) — a drifted
     table would prune blocks whose docs can still reach the top-k;
  7. segment consistency (streaming mutation, lint corpus): the tombstone
     set must agree with its live-doc tables (count, bool mask, packed
     bitmap — the host and kernel packers bit-identical), and after a
     ``compact()`` merge the new generation's score block-max tables must
     match its stored impacts and a from-scratch rebuild of the same live
     corpus;
  8. dense-bitmap block boundaries: any codec declaring the bitmap-block
     layout (``ArenaLayout.bitmap_words`` / ``is_bitmap``) must round-trip
     the density boundary cases — a block exactly at the ``DENSE_GAP``
     cutoff (chosen as a bitmap), one gap past it (policy rejects it), a
     singleton block, and a window-overflowing stream (raw fallback keeps
     the codec total);
  9. serving-trace discipline: every ``ServerStats`` trace record from a
     lint-sized serve stream carries monotone non-decreasing stage
     timestamps (enqueue <= batch-close <= plan <= execute <= done), served
     traces carry all five stamps plus batch metadata, and batch records'
     own stamps are ordered;
 10. shard consistency (doc-range sharded serving, lint corpus): every
     ``ShardSpec`` partitions [0, n_docs) into disjoint covering ranges;
     every per-shard generation carries the parent gid and global dfs, its
     postings are bit-identical to the parent slice (translated by -lo,
     union over shards == the parent), and its quantized impact codes and
     block-max tables equal the parent's at the same (term, global doc) —
     the statistics fixup the margin-preserving top-k merge depends on;
 11. metrics-registry discipline (``repro.obs.metrics``): snake_case metric
     names, labels drawn from the fixed ``LABEL_KEYS`` vocabulary,
     duplicate registration raising, and an identical metric schema
     (name -> kind + label set) across engine instances.

Run: PYTHONPATH=src python tools/registry_lint.py
"""

from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import codec  # noqa: E402

CATEGORIES = ("bit", "byte", "word", "frame")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def lint_protocol(errors: list) -> None:
    for name in codec.names():
        spec = codec.get(name)
        if spec.name != name:
            _fail(errors, f"{name}: registered under mismatched name {spec.name!r}")
        if spec.category not in CATEGORIES:
            _fail(errors, f"{name}: category {spec.category!r} not in {CATEGORIES}")
        if not callable(spec.encode) or not callable(spec.decode_np):
            _fail(errors, f"{name}: encode/decode_np must be callable")
        if not isinstance(spec.max_bits, int) or not 1 <= spec.max_bits <= 32:
            _fail(errors, f"{name}: max_bits {spec.max_bits!r} outside 1..32")
        if spec.jax is not None:
            for field in ("args", "scalar", "vec"):
                if not callable(getattr(spec.jax, field)):
                    _fail(errors, f"{name}: JaxDecode.{field} not callable")
        if spec.arena is not None:
            lay = spec.arena
            if len(lay.columns) < 2:
                _fail(errors, f"{name}: ArenaLayout declares "
                              f"{len(lay.columns)} column(s); need >= 2")
            for col in lay.columns:
                if not col.name or col.width <= 0 or not callable(col.extract):
                    _fail(errors, f"{name}: ArenaLayout column {col.name!r} "
                                  f"malformed (width {col.width})")
            if min(lay.out_width, lay.max_n) <= 0:
                _fail(errors, f"{name}: ArenaLayout out_width/max_n must be "
                              f"positive")
            if lay.out_width < lay.max_n:
                _fail(errors, f"{name}: out_width {lay.out_width} < max_n {lay.max_n}")
            for field in ("decode_block", "supports"):
                if not callable(getattr(lay, field)):
                    _fail(errors, f"{name}: ArenaLayout.{field} not callable")


def _load(module: str, *relpath: str):
    path = os.path.join(_REPO, *relpath)
    spec = importlib.util.spec_from_file_location(module, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def lint_arena_contract(errors: list) -> None:
    # the ONE arena round-trip harness lives in the conformance tests; lint
    # reuses it on a smoke input so CI and pytest enforce the same contract
    harness = _load("test_codec_protocol", "tests", "test_codec_protocol.py")
    rng = np.random.default_rng(0)
    x = rng.integers(0, 1 << 12, 200, dtype=np.int64).astype(np.uint32)
    for name in codec.names():
        spec = codec.get(name)
        if spec.arena is None:
            continue
        try:
            harness._arena_roundtrip(spec, x)
        except AssertionError as e:
            _fail(errors, f"{name}: arena contract violated: {e}")


def lint_exception_columns(errors: list) -> None:
    """A codec that stores exceptions must declare an arena column for them.

    The probe is heavy-tailed (mostly tiny values, sparse huge outliers) —
    the shape that drives patched codecs (the Group-PFD family) to emit a
    non-empty ``Encoded.exceptions`` stream.  A declared ArenaLayout without
    an ``"exceptions"`` column would decode such blocks with the patches
    silently dropped, so that combination fails the lint.
    """
    rng = np.random.default_rng(5)
    for name in codec.names():
        spec = codec.get(name)
        if spec.arena is None:
            continue
        probe = rng.integers(0, 16, 400, dtype=np.int64).astype(np.uint32)
        probe[::50] = np.uint32(2 ** min(spec.max_bits, 32) - 1)
        enc = spec.encode(probe)
        np.testing.assert_array_equal(spec.decode_np(enc), probe)
        if (enc.exceptions is not None and len(enc.exceptions)
                and not any(c.name == "exceptions"
                            for c in spec.arena.columns)):
            _fail(errors, f"{name}: stores a non-empty exception stream but "
                          f"declares an ArenaLayout without an 'exceptions' "
                          f"column")


def lint_parity_coverage(errors: list) -> None:
    mod = _load("test_device_arena", "tests", "test_device_arena.py")
    declared = {n for n in codec.names() if codec.get(n).arena is not None}
    covered = set(getattr(mod, "ARENA_CODECS", ()))
    for name in sorted(declared - covered):
        _fail(errors, f"{name}: declares an arena capability but is missing "
                      f"from the device/host parity sweep (ARENA_CODECS)")
    for name in sorted(covered - declared):
        _fail(errors, f"{name}: in the parity sweep but declares no arena "
                      f"capability")


def lint_score_tables(errors: list) -> None:
    """WAND metadata soundness on the lint corpus: for every posting block,
    the stored block-max equals the max of the stored quantized impacts
    (== the quantized build-time float maximum: floor is monotone), term-max
    is the max block-max, and the stripe range-bound table dominates every
    posting's code.  Heavy-tailed postings keep the exception-bearing codecs
    honest on the same probe."""
    from repro.index.invindex import InvertedIndex
    from repro.index.scores import ScoreArena, unpack_words_np

    rng = np.random.default_rng(17)
    n_docs = 100_000
    postings = {}
    for t, df in enumerate([12, 64, 300, 513, 900]):
        gaps = rng.integers(1, 8, df).astype(np.int64)
        gaps[rng.random(df) < 0.02] += rng.integers(1 << 8, 1 << 12)
        ids = np.cumsum(gaps)
        assert int(ids[-1]) < n_docs
        postings[t] = (ids.astype(np.uint32),
                       rng.geometric(0.4, df).astype(np.uint32))
    doclen = rng.integers(50, 500, n_docs).astype(np.int64)
    for name in ("group_simple", "group_pfd"):
        idx = InvertedIndex.build(doclen, postings, codec=name)
        sa = ScoreArena.from_index(idx)
        tiles = np.asarray(sa.tiles)
        for t, tp in idx.terms.items():
            per_block = []
            for bi in range(len(tp.blocks)):
                ids, _ = idx.decode_block(t, bi)
                s = sa.slot[(t, bi)]
                codes = unpack_words_np(tiles[s], len(ids))
                stored = int(sa.block_max[s])
                per_block.append(stored)
                if stored != int(codes.max(initial=0)):
                    _fail(errors, f"{name}: score block-max table "
                                  f"[{t},{bi}] = {stored} != max stored "
                                  f"impact {int(codes.max(initial=0))}")
                built = min(int(idx.impact_block_max(t)[bi] / sa.delta), 255)
                if stored != built:
                    _fail(errors, f"{name}: score block-max table "
                                  f"[{t},{bi}] = {stored} != quantized "
                                  f"build-time float max {built}")
                if np.any(sa.stripes[t][ids // sa.stripe_width]
                          < codes.astype(np.int64)):
                    _fail(errors, f"{name}: stripe range-bound table "
                                  f"under-bounds term {t} block {bi}")
            if sa.term_max[t] != max(per_block, default=0):
                _fail(errors, f"{name}: term-max table for term {t} "
                              f"inconsistent with block maxima")


def lint_segments(errors: list) -> None:
    """Streaming-mutation consistency on the lint corpus: the tombstone set
    and its live-doc views must agree (count, bool mask, packed bitmap —
    host and kernel packers bit-identical), the doclen overrides must span
    the append-only doc space, and after a ``compact()`` merge the new
    generation's score block-max tables must match its stored impacts AND
    the tables of a from-scratch rebuild of the same live corpus."""
    from repro.index.invindex import InvertedIndex
    from repro.index.scores import ScoreArena
    from repro.kernels.intersect_rounds import bitmap_geometry, pack_live_words

    rng = np.random.default_rng(23)
    n_docs = 5000
    postings = {}
    for t, df in enumerate([30, 120, 400, 900]):
        ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    doclen = rng.integers(30, 300, n_docs).astype(np.int64)
    idx = InvertedIndex.build(doclen, postings, codec="group_pfd")
    dead = sorted(int(d) for d in rng.choice(n_docs, 200, replace=False))
    for d in dead:
        idx.delete(d)
    inserts = {}
    for j in range(40):
        t = int(rng.integers(0, 4))
        inserts[n_docs + j] = (t, int(rng.integers(1, 5)))
        idx.insert(n_docs + j, {t: inserts[n_docs + j][1]},
                   int(rng.integers(10, 100)))

    # tombstone count vs the live-doc tables (bool mask + packed bitmap)
    mask = idx.tomb.mask(idx.n_docs)
    if int((~mask).sum()) != len(idx.tomb):
        _fail(errors, f"segments: live mask drops {int((~mask).sum())} docs "
                      f"but the tombstone set holds {len(idx.tomb)}")
    words, _ = bitmap_geometry(idx.n_docs)
    lw = idx.tomb.live_words(idx.n_docs, words)
    pop = int(np.unpackbits(lw.view(np.uint8), bitorder="little").sum())
    if pop != int(mask.sum()):
        _fail(errors, f"segments: packed live bitmap popcount {pop} != live "
                      f"mask count {int(mask.sum())}")
    kernel_packed = pack_live_words(idx.tomb.sorted_ids(below=idx.n_docs),
                                    idx.n_docs, words)
    if not np.array_equal(kernel_packed, lw):
        _fail(errors, "segments: kernels.pack_live_words disagrees with "
                      "Tombstones.live_words — device and host gates differ")
    dl = idx.doclen_now()
    if len(dl) != idx.doc_space:
        _fail(errors, f"segments: doclen_now length {len(dl)} != doc_space "
                      f"{idx.doc_space}")

    # the merge: compact, then the new generation's per-segment block-max
    # tables must match its stored impacts and a from-scratch rebuild
    deadset = set(dead)
    live = {}
    for t, (ids, tfs) in postings.items():
        keep = [j for j, d in enumerate(ids.tolist()) if d not in deadset]
        live[t] = ([int(ids[j]) for j in keep], [int(tfs[j]) for j in keep])
    for d, (t, tf) in inserts.items():
        live[t][0].append(d)
        live[t][1].append(tf)
    live = {t: (np.asarray(i, np.uint32), np.asarray(f, np.uint32))
            for t, (i, f) in live.items() if i}
    gen = idx.compact()
    if idx.mutated:
        _fail(errors, "segments: handle still mutated after compact()")
    rebuilt = InvertedIndex.build(np.array(dl), live, codec="group_pfd").gen
    sa, sr = ScoreArena.from_index(gen), ScoreArena.from_index(rebuilt)
    if abs(sa.delta - sr.delta) > 0:
        _fail(errors, "segments: compacted quantizer delta differs from the "
                      "from-scratch rebuild's")
    for t, tp in gen.terms.items():
        rp = rebuilt.terms.get(t)
        if rp is None or rp.df != tp.df:
            _fail(errors, f"segments: term {t} df {tp.df} != rebuild "
                          f"{getattr(rp, 'df', None)}")
            continue
        base, rbase = sa.slot[(t, 0)], sr.slot[(t, 0)]
        nb = len(tp.blocks)
        for bi in range(nb):
            stored = int(sa.block_max[base + bi])
            built = min(int(gen.impact_block_max(t)[bi] / sa.delta), 255)
            if stored != built:
                _fail(errors, f"segments: compacted score block-max [{t},{bi}]"
                              f" = {stored} != quantized stored impact {built}")
            if stored != int(sr.block_max[rbase + bi]):
                _fail(errors, f"segments: compacted score block-max [{t},{bi}]"
                              f" = {stored} != rebuild "
                              f"{int(sr.block_max[rbase + bi])}")
        if sa.term_max[t] != sr.term_max[t]:
            _fail(errors, f"segments: compacted term-max for {t} != rebuild")


def lint_bitmap_blocks(errors: list) -> None:
    """Density boundary cases for every bitmap-block codec (the word-parallel
    dense representation): exactly-at-threshold and singleton blocks must be
    *chosen* as bitmaps and round-trip exactly; one gap past the cutoff the
    build policy must decline; a window-overflowing stream must fall back to
    the raw format and still round-trip (the codec stays total)."""
    from repro.core import dense_bitmap as dbm

    def gaps_of(ids: np.ndarray) -> np.ndarray:
        return np.diff(ids, prepend=np.int64(0)).astype(np.uint32)

    n = 512
    base = 4096                                   # 128-bit aligned window base
    at = base + np.arange(n, dtype=np.int64) * dbm.DENSE_GAP
    at[-1] = base + dbm.DENSE_GAP * n - 1         # span == DENSE_GAP * n
    past = at.copy()
    past[-1] += 1                                 # span == DENSE_GAP * n + 1
    single = np.array([12345], np.int64)
    overflow = np.array([0, dbm.WINDOW_BITS + 7], np.int64)   # no window fits
    for name in codec.names():
        spec = codec.get(name)
        lay = spec.arena
        if lay is None or not lay.bitmap_words:
            continue
        if not callable(lay.is_bitmap):
            _fail(errors, f"{name}: declares bitmap_words="
                          f"{lay.bitmap_words} without a callable is_bitmap")
            continue
        for tag, ids, want_eligible, want_bitmap in (
                ("at-threshold", at, True, True),
                ("past-threshold", past, False, None),
                ("singleton", single, True, True),
                ("window-overflow", overflow, False, False)):
            if dbm.eligible(ids) != want_eligible:
                _fail(errors, f"{name}: {tag} block eligibility "
                              f"{dbm.eligible(ids)} != {want_eligible}")
            enc = spec.encode(gaps_of(ids))
            if want_bitmap is not None and lay.is_bitmap(enc) != want_bitmap:
                _fail(errors, f"{name}: {tag} block stored as "
                              f"{'bitmap' if lay.is_bitmap(enc) else 'raw'}; "
                              f"expected {'bitmap' if want_bitmap else 'raw'}")
            got = spec.decode_np(enc)
            if not np.array_equal(got, gaps_of(ids)):
                _fail(errors, f"{name}: {tag} block does not round-trip")


def lint_shards(errors: list) -> None:
    """Doc-range shard consistency on the lint corpus (both a mass-balanced
    derived split and an explicit uneven one with an EMPTY shard): the spec
    must partition the docid space; each shard generation must carry the
    parent gid and GLOBAL dfs; the union of shard postings (translated back
    by +lo) must equal the parent's; and each shard's quantized impact codes
    and block-max tables must equal the parent's for the same (term, global
    doc).  That last check is the one the sharded ranked path stands on: the
    merged k-th threshold is only comparable across shards because every
    shard quantizes with the parent's statistics."""
    from repro.index.invindex import InvertedIndex
    from repro.index.scores import ScoreArena, unpack_words_np
    from repro.index.shards import ShardSpec, shard_generation

    rng = np.random.default_rng(41)
    n_docs = 40_000
    postings = {}
    for t, df in enumerate([40, 300, 900, 2000, 3500]):
        ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    doclen = rng.integers(30, 300, n_docs).astype(np.int64)
    gen = InvertedIndex.build(doclen, postings, codec="group_simple").gen
    sa = ScoreArena.from_index(gen)
    ptiles = np.asarray(sa.tiles)
    pcodes = {}                       # term -> {global docid: quantized code}
    for t, tp in gen.terms.items():
        m = {}
        for bi in range(len(tp.blocks)):
            ids = gen.decode_block_ids(t, bi)
            codes = unpack_words_np(ptiles[sa.slot[(t, bi)]], len(ids))
            m.update(zip(ids.tolist(), codes.tolist()))
        pcodes[t] = m

    for spec in (ShardSpec.derive(gen, 3),
                 ShardSpec((0, 100, 100, 33_000, n_docs))):
        b = spec.bounds
        if b[0] != 0 or b[-1] != n_docs:
            _fail(errors, f"shards: {spec!r} does not cover [0, {n_docs})")
            continue
        union = {t: [] for t in gen.terms}
        for lo, hi in spec.ranges():
            if hi == lo:
                continue
            sg = shard_generation(gen, lo, hi)
            if sg.gid != gen.gid:
                _fail(errors, f"shards: [{lo},{hi}) gid {sg.gid} != parent "
                              f"{gen.gid} (epoch pinning would break)")
            ssa = ScoreArena.from_index(sg)
            if ssa.delta != sa.delta:
                _fail(errors, f"shards: [{lo},{hi}) quantizer delta "
                              f"{ssa.delta} != parent {sa.delta}")
            stiles = np.asarray(ssa.tiles)
            for t, tp in sg.terms.items():
                if tp.df != gen.terms[t].df:
                    _fail(errors, f"shards: [{lo},{hi}) term {t} df {tp.df} "
                                  f"!= global {gen.terms[t].df}")
                for bi in range(len(tp.blocks)):
                    ids = sg.decode_block_ids(t, bi)
                    s = ssa.slot[(t, bi)]
                    codes = unpack_words_np(stiles[s], len(ids))
                    stored = int(ssa.block_max[s])
                    if stored != int(codes.max(initial=0)):
                        _fail(errors, f"shards: [{lo},{hi}) block-max "
                                      f"[{t},{bi}] = {stored} != max stored "
                                      f"code {int(codes.max(initial=0))}")
                    want = [pcodes[t].get(int(d) + lo, -1) for d in ids]
                    if codes.tolist() != want:
                        _fail(errors, f"shards: [{lo},{hi}) term {t} block "
                                      f"{bi} codes drift from the parent's "
                                      f"at the same global docs")
                    union[t].extend(int(d) + lo for d in ids)
        for t in gen.terms:
            parent_ids = np.concatenate(
                [gen.decode_block_ids(t, bi)
                 for bi in range(gen.n_blocks(t))]).astype(np.int64)
            if union[t] != parent_ids.tolist():
                _fail(errors, f"shards: {spec!r} union of term {t} postings "
                              f"!= the parent postings (lost or duplicated "
                              f"docs at the cuts)")


def lint_serving_traces(errors: list) -> None:
    """Serving-trace discipline on a lint-sized stream: drive a short burst
    through the :class:`~repro.index.serve.IndexServer` and check every
    :class:`TraceRecord`'s stage timestamps are monotone non-decreasing
    (enqueue <= close <= plan <= execute <= done), every served trace
    carries all five stamps plus its batch metadata, and every
    :class:`BatchRecord`'s own stamps are ordered.  A regression here means
    the latency percentiles and the per-stage breakdowns in
    ``BENCH_serving.json`` are built on garbage clocks."""
    from repro.index.invindex import InvertedIndex
    from repro.index.engine import QueryEngine
    from repro.index.serve import Request, ServeConfig, serve_stream, STAGES

    rng = np.random.default_rng(29)
    n_docs = 4000
    postings = {}
    for t, df in enumerate([40, 150, 500, 800]):
        ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    doclen = rng.integers(30, 300, n_docs).astype(np.int64)
    idx = InvertedIndex.build(doclen, postings)
    engine = QueryEngine(idx)
    reqs = ([Request([0, 2], deadline_ms=500) for _ in range(12)]
            + [Request([1, 3], deadline_ms=0)])      # one expired-at-enqueue
    offsets = np.arange(len(reqs)) * 1e-4
    _, stats = serve_stream(engine, reqs, offsets,
                            ServeConfig(max_batch=4, max_wait_ms=1.0,
                                        warm_terms=4))
    if not stats.traces:
        _fail(errors, "serving: lint stream produced no trace records")
    n_stamps = len(STAGES)
    for tr in stats.traces:
        s = tr.stages()
        if any(b < a for a, b in zip(s, s[1:])):
            _fail(errors, f"serving: trace rid={tr.rid} ({tr.outcome}) has "
                          f"non-monotone stage timestamps {s}")
        if tr.outcome == "served":
            if len(s) != n_stamps:
                _fail(errors, f"serving: served trace rid={tr.rid} carries "
                              f"{len(s)}/{n_stamps} stage stamps")
            if tr.batch_size < 1 or tr.placement not in ("host", "device",
                                                         "fused"):
                _fail(errors, f"serving: served trace rid={tr.rid} missing "
                              f"batch metadata (size={tr.batch_size}, "
                              f"placement={tr.placement!r})")
    for b in stats.batches:
        s = (b.t_close, b.t_plan, b.t_execute, b.t_done)
        if any(y < x for x, y in zip(s, s[1:])):
            _fail(errors, f"serving: batch {b.batch_id} has non-monotone "
                          f"stage timestamps {s}")


def lint_metrics(errors: list) -> None:
    """Metrics-registry discipline (``repro.obs.metrics``): every metric
    name is snake_case, every label is drawn from the fixed
    ``LABEL_KEYS`` vocabulary, duplicate registration raises, and the
    metric schema (name -> kind + label set) is identical across engine
    instances — two engines exposing the same counter with different
    label sets would make their expositions un-joinable."""
    import re

    from repro.index.engine import QueryEngine
    from repro.index.invindex import InvertedIndex
    from repro.index.serve import ServerStats
    from repro.obs.metrics import LABEL_KEYS, MetricsRegistry

    rng = np.random.default_rng(7)
    n_docs = 2000
    postings = {}
    for t, df in enumerate([50, 200, 400]):
        ids = np.sort(rng.choice(n_docs, df, replace=False)).astype(np.uint32)
        postings[t] = (ids, rng.geometric(0.4, df).astype(np.uint32))
    doclen = rng.integers(30, 300, n_docs).astype(np.int64)
    idx = InvertedIndex.build(doclen, postings)
    regs = [("engine-a", QueryEngine(idx).metrics),
            ("engine-b", QueryEngine(idx).metrics),
            ("server", ServerStats().metrics)]

    snake = re.compile(r"^[a-z][a-z0-9_]*$")
    for owner, reg in regs:
        for name, m in reg.metrics().items():
            if not snake.match(name):
                _fail(errors, f"metrics: {owner} metric {name!r} is not "
                              f"snake_case")
            bad = set(m.labelnames) - set(LABEL_KEYS)
            if bad:
                _fail(errors, f"metrics: {owner} metric {name!r} labelled "
                              f"outside the vocabulary: {sorted(bad)}")
        bad = set(reg.const_labels) - set(LABEL_KEYS)
        if bad:
            _fail(errors, f"metrics: {owner} const labels outside the "
                          f"vocabulary: {sorted(bad)}")

    # same metric schema (kind + label set) on every engine instance
    sa, sb = regs[0][1].schema(), regs[1][1].schema()
    if sa != sb:
        drift = {k for k in sa.keys() | sb.keys() if sa.get(k) != sb.get(k)}
        _fail(errors, f"metrics: engine metric schemas drift across "
                      f"instances: {sorted(drift)}")

    # duplicate registration must raise, in-vocabulary enforcement must hold
    reg = MetricsRegistry(namespace="lint")
    reg.counter("dup_probe")
    try:
        reg.counter("dup_probe")
        _fail(errors, "metrics: duplicate registration did not raise")
    except ValueError:
        pass
    try:
        reg.counter("bad_labels", labelnames=("no_such_label",))
        _fail(errors, "metrics: out-of-vocabulary label did not raise")
    except ValueError:
        pass


def main() -> int:
    errors: list = []
    lint_protocol(errors)
    lint_arena_contract(errors)
    lint_exception_columns(errors)
    lint_parity_coverage(errors)
    lint_score_tables(errors)
    lint_segments(errors)
    lint_bitmap_blocks(errors)
    lint_shards(errors)
    lint_serving_traces(errors)
    lint_metrics(errors)
    n_arena = sum(codec.get(n).arena is not None for n in codec.names())
    n_jax = sum(codec.get(n).jax is not None for n in codec.names())
    print(f"registry lint: {len(codec.names())} codecs "
          f"({n_jax} JaxDecode, {n_arena} ArenaLayout), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
