#!/usr/bin/env python
"""CI perf-regression gate over the committed ``BENCH_*.json`` baselines.

Usage (what ``.github/workflows/ci.yml`` runs)::

    # stash the committed baselines before the bench smoke overwrites them
    mkdir -p /tmp/bench_baseline
    cp BENCH_query.json BENCH_mutation.json BENCH_serving.json \
       BENCH_tolerances.json /tmp/bench_baseline/
    PYTHONPATH=src python benchmarks/run.py --smoke      # fresh reports
    PYTHONPATH=src python tools/bench_gate.py \
        --fresh-dir . --baseline-dir /tmp/bench_baseline
    PYTHONPATH=src python tools/bench_gate.py \
        --fresh-dir . --baseline-dir /tmp/bench_baseline --self-test

Exit status: 0 = gate passed, 1 = violations, 2 = usage/setup error.

``--self-test`` proves the gate has teeth without waiting for a real
regression: for each artifact it (a) gates the fresh report against itself
(must pass — same numbers, ratio 1.0) and (b) synthesizes a 2x qps
regression (every ratio-gated leaf halved) and asserts the gate FAILS it.
A tolerance floor that quietly drifted above 1.0 or below 0.5 breaks the
self-test immediately.

Tolerances live in ``BENCH_tolerances.json`` next to the baselines — see
``repro.obs.regress`` for the format and the hard-invariant list.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.obs import regress  # noqa: E402


def self_test(fresh_dir: str, tolerances_path: str) -> int:
    """Prove the gate passes identity and fails a synthetic 2x regression."""
    tol = regress.load_tolerances(tolerances_path)
    tested = 0
    for kind, fname, stamp_keys in regress.ARTIFACTS:
        path = os.path.join(fresh_dir, fname)
        if not os.path.exists(path):
            continue
        report = regress.load_report(path)
        # (a) identity must pass: fresh vs itself is ratio 1.0 everywhere
        v, n = regress.compare_reports(kind, report, report, tol)
        if v:
            print(f"self-test FAIL [{kind}]: identity comparison violated:")
            for x in v:
                print(f"  {x}")
            return 1
        if n == 0:
            print(f"self-test FAIL [{kind}]: no ratio-gated metrics found "
                  f"in {fname} — the gate would never catch a regression")
            return 1
        # (b) a synthetic 2x regression must fail
        regressed = regress.synthesize_regression(report, factor=0.5)
        v, _ = regress.compare_reports(kind, regressed, report, tol)
        if not v:
            print(f"self-test FAIL [{kind}]: a synthetic 2x qps regression "
                  f"passed the gate — tolerances have no teeth "
                  f"(min_ratio must stay above 0.5)")
            return 1
        print(f"self-test ok [{kind}]: {n} metric(s) gated, identity "
              f"passes, 2x regression raises {len(v)} violation(s)")
        tested += 1
    if not tested:
        print(f"self-test FAIL: no BENCH_*.json reports in {fresh_dir}")
        return 2
    print("bench-gate self-test passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="diff fresh BENCH_*.json reports against committed "
                    "baselines (tolerances + hard invariants)")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding freshly produced BENCH_*.json")
    ap.add_argument("--baseline-dir", default=_ROOT,
                    help="directory holding the committed baselines "
                         "(default: repo root)")
    ap.add_argument("--tolerances", default=None,
                    help="tolerances JSON (default: BENCH_tolerances.json "
                         "in --baseline-dir)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate passes identity and fails a "
                         "synthetic 2x qps regression, then exit")
    ap.add_argument("--json-out", default=None,
                    help="also write the gate result as JSON")
    args = ap.parse_args()

    tol_path = args.tolerances or os.path.join(args.baseline_dir,
                                               regress.TOLERANCES_FILE)
    if args.self_test:
        return self_test(args.fresh_dir, tol_path)

    res = regress.run_gate(args.fresh_dir, args.baseline_dir, tol_path)
    print(res.summary())
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"passed": res.passed,
                       "checked_ratios": res.checked_ratios,
                       "checked_invariants": res.checked_invariants,
                       "violations": [vars(v) for v in res.violations]},
                      f, indent=2, sort_keys=True)
    if res.checked_ratios == 0 and res.checked_invariants == 0:
        print("bench gate: nothing checked (no baselines found?)",
              file=sys.stderr)
        return 2
    return 0 if res.passed else 1


if __name__ == "__main__":
    sys.exit(main())
